//! # Labyrinth — imperative control flow compiled to a single cyclic dataflow
//!
//! Reproduction of *Labyrinth: Compiling Imperative Control Flow to Parallel
//! Dataflows* (Gévay, Rabl, Breß, Madai-Tahy, Markl; EDBT 2019).
//!
//! Labyrinth takes a data-analytics program written with **imperative**
//! control flow (while-loops, if-statements, mutable variables over parallel
//! `Bag` collections), lowers it to **SSA form**, compiles the SSA into a
//! **single cyclic parallel dataflow job**, and coordinates the distributed
//! execution of control flow with a **bag-identifier / execution-path**
//! protocol. Because the whole program — all iteration steps included — is
//! one dataflow job, per-step scheduling overhead disappears and
//! cross-iteration optimizations (hash-join build-side reuse over
//! loop-invariant inputs, loop pipelining) become possible.
//!
//! ## Pipeline
//!
//! ```text
//!  LabyLang source ──lex/parse──▶ AST ──type──▶ TAC IR over basic blocks
//!        │ (or the [`frontend::builder`] Rust API)
//!        ▼
//!  CFG (dominators, natural loops)  ──▶  SSA (Φ insertion + renaming)
//!        ▼
//!  non-bag lifting (§5.2)  ──▶  logical dataflow graph (§5.3)
//!        ▼
//!  opt:: plan optimizer — pass manager over the dataflow graph
//!        (predicate pushdown, cost-gated loop-invariant hoisting into
//!        loop preambles, hash-join build-side selection, element-wise
//!        operator fusion, dead-operator elimination — §7's
//!        cross-iteration optimizations as compiler passes, driven by
//!        the opt::cost cardinality/trip-count model)
//!        ▼
//!  executors:
//!    · exec::            Labyrinth engine — single cyclic job, bag-ID
//!                        coordination (§6), pipelined or barrier mode
//!    · baselines::       separate-jobs (Spark-/Flink-like, via the
//!                        sched:: scheduler substrate), fixpoint-only
//!                        in-dataflow (Flink/Naiad-like), single-threaded
//!    · serve::           resident JobService for high-throughput repeated
//!                        jobs — plan-template cache keyed by program +
//!                        config fingerprints, persistent worker pools
//!                        (jobs are message-delimited epochs), bounded
//!                        admission queue with per-request parameter
//!                        binding and adaptive re-optimization
//! ```
//!
//! ## Layers
//!
//! The numeric hot spots of the evaluation programs (PageRank rank update,
//! page-visit histogram) are available as **AOT-compiled XLA artifacts**
//! authored as JAX + Pallas kernels in `python/compile/` and executed from
//! dataflow operators through [`runtime`] (PJRT CPU client). Python never
//! runs at request time.

pub mod bag;
pub mod baselines;
pub mod bench_harness;
pub mod bench_throughput;
pub mod cfg;
pub mod config;
pub mod coord;
pub mod dataflow;
pub mod error;
pub mod exec;
pub mod frontend;
pub mod metrics;
pub mod obs;
pub mod opt;
pub mod ops;
pub mod programs;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod ssa;
pub mod util;
pub mod value;
pub mod workload;

pub use error::{Error, Result};
pub use value::Value;

/// Convenience re-exports for building and running programs.
pub mod prelude {
    pub use crate::dataflow::DataflowGraph;
    pub use crate::exec::{run, ExecConfig, ExecMode};
    pub use crate::frontend::builder::{udf1, udf2, BagHandle, ProgramBuilder, ScalarHandle};
    pub use crate::serve::{JobRequest, JobService, ServeConfig};
    pub use crate::value::Value;
    pub use crate::{compile, compile_source};
}

/// Compile an IR [`frontend::Program`] all the way to an optimized
/// logical [`dataflow::DataflowGraph`]
/// (CFG → SSA → lifting → dataflow → [`opt::optimize`] with the default
/// pass pipeline). Use [`compile_with`] to control the optimizer or read
/// its explain report.
///
/// ```
/// use labyrinth::frontend::parse_and_lower;
///
/// let program = parse_and_lower(
///     "a = bag(1, 2, 3); b = a.map(|x| x * 10); collect(b, \"b\");",
/// )?;
/// let graph = labyrinth::compile(&program)?;
/// let out = labyrinth::exec::run(&graph, &Default::default())?;
/// let mut b = out.collected("b").to_vec();
/// b.sort();
/// assert_eq!(b, vec![10, 20, 30].into_iter().map(labyrinth::Value::I64).collect::<Vec<_>>());
/// # Ok::<(), labyrinth::Error>(())
/// ```
pub fn compile(program: &frontend::Program) -> Result<dataflow::DataflowGraph> {
    Ok(compile_with(program, &opt::OptConfig::default())?.0)
}

/// Compile with an explicit optimizer configuration; returns the graph
/// and the optimizer's [`opt::ExplainReport`]
/// (`OptConfig::none()` yields the raw §5.3 translation).
pub fn compile_with(
    program: &frontend::Program,
    opt_cfg: &opt::OptConfig,
) -> Result<(dataflow::DataflowGraph, opt::ExplainReport)> {
    compile_pipeline(program, opt_cfg, &workload::registry::global(), None)
}

/// [`compile_with`] against an explicit named-source registry (size
/// hints for `source("name")` resolve here instead of the process-global
/// registry). Used by the `serve::` job service so a request's dataset
/// bindings inform the cost model of the compiled plan template.
pub fn compile_with_registry(
    program: &frontend::Program,
    opt_cfg: &opt::OptConfig,
    registry: &workload::registry::Registry,
) -> Result<(dataflow::DataflowGraph, opt::ExplainReport)> {
    compile_pipeline(program, opt_cfg, registry, None)
}

/// [`compile_with_registry`] plus observed-cardinality feedback: row
/// estimates of nodes named in `feedback` are pinned to runtime-measured
/// values (see [`opt::optimize_with_feedback`]). The `serve::` service
/// uses this to re-optimize a cached template from its own statistics.
pub fn compile_with_feedback(
    program: &frontend::Program,
    opt_cfg: &opt::OptConfig,
    registry: &workload::registry::Registry,
    feedback: &opt::RowFeedback,
) -> Result<(dataflow::DataflowGraph, opt::ExplainReport)> {
    compile_pipeline(program, opt_cfg, registry, Some(feedback))
}

fn compile_pipeline(
    program: &frontend::Program,
    opt_cfg: &opt::OptConfig,
    registry: &workload::registry::Registry,
    feedback: Option<&opt::RowFeedback>,
) -> Result<(dataflow::DataflowGraph, opt::ExplainReport)> {
    let cfg = cfg::Cfg::from_program(program)?;
    let ssa = ssa::construct(&cfg)?;
    let lifted = ssa::lift::lift(ssa)?;
    let mut graph = dataflow::build_with(&lifted, registry)?;
    let report = match feedback {
        Some(f) => opt::optimize_with_feedback(&mut graph, opt_cfg, f)?,
        None => opt::optimize(&mut graph, opt_cfg)?,
    };
    Ok((graph, report))
}

/// Compile LabyLang source text to an optimized logical dataflow graph.
pub fn compile_source(src: &str) -> Result<dataflow::DataflowGraph> {
    let program = frontend::parse_and_lower(src)?;
    compile(&program)
}

