//! `labyrinth` — the leader entrypoint / CLI.
//!
//! ```text
//! labyrinth run <program.laby> [--workers N] [--mode pipelined|barrier]
//!               [--executor labyrinth|spark|flink|single] [--no-reuse]
//!               [--no-opt] [--no-hoist] [--no-fuse] [--no-dce]
//!               [--no-pushdown] [--no-join-sides] [--no-delta] [--no-columnar]
//!               [--speculate auto|always|never] [--columnar auto|always|never]
//!               [--explain] [--io-dir DIR] [--config FILE] [--sched] [--metrics]
//! labyrinth compile <program.laby> [--dump ir|ssa|dataflow|dot|opt]
//! labyrinth trace <program.laby> [--workers N] [--mode pipelined|barrier]
//!               [--out trace.json] [--metrics]
//! labyrinth serve <program.laby> [--workers N] [--lanes S | --slots S]
//!               [--min-workers N] [--max-workers N]
//!               [--tenants name:weight[:budget],...] [--requests R]
//!               [--param name=value]... [--no-adaptive] [--metrics]
//! labyrinth bench-serve [--smoke]
//! labyrinth bench-throughput [--smoke]
//! labyrinth generate visitcount --days N --visits M --pages P --out DIR
//! labyrinth config --dump [--config FILE]
//! ```
//!
//! Argument parsing is handwritten (clap is unavailable offline; see
//! DESIGN.md §2). Config-file values are overridden by CLI flags.

use labyrinth::baselines::{self, separate_jobs};
use labyrinth::config::Config;
use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::Result;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("labyrinth: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Pull `--key value` / `--flag` options out of the argument list.
struct Opts {
    positional: Vec<String>,
    options: Vec<(String, Option<String>)>,
}

const VALUE_OPTS: &[&str] = &[
    "--workers", "--mode", "--executor", "--io-dir", "--config", "--dump", "--days",
    "--visits", "--pages", "--out", "--batch", "--scale",
    // Speculative-hoist policy (config key opt.speculate): auto|always|never.
    "--speculate",
    // Typed columnar data plane (config key opt.columnar): auto|always|never.
    "--columnar",
    // serve / bench-serve: job slots, request count, per-request scalar
    // parameters (repeatable `--param name=value`), and the sharded
    // elastic tier: `--lanes` (alias for --slots), `--tenants`
    // name:weight[:budget],... (DRR weights + shed budgets), and the
    // elastic pool bounds `--min-workers` / `--max-workers`.
    "--slots", "--requests", "--param",
    "--lanes", "--tenants", "--min-workers", "--max-workers",
    // recovery:: knobs — superstep-boundary checkpoint cadence and a
    // seeded fault-injection plan (overrides LABY_FAULTS).
    "--checkpoint-every", "--faults",
];
const FLAG_OPTS: &[&str] = &[
    "--no-reuse", "--metrics", "--sched", "--dump-plan",
    // Optimizer toggles (config keys opt.hoist / opt.fuse / opt.dce /
    // opt.pushdown / opt.join_sides).
    "--no-opt", "--no-hoist", "--no-fuse", "--no-dce", "--no-pushdown",
    "--no-join-sides", "--no-delta", "--no-columnar", "--explain",
    // bench-serve CI mode; serve adaptive-reoptimization and cross-job
    // preamble-sharing toggles.
    "--smoke", "--no-adaptive", "--no-share-preambles",
];

fn parse_opts(args: &[String]) -> Result<Opts> {
    let mut positional = Vec::new();
    let mut options = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if VALUE_OPTS.contains(&a.as_str()) {
            let v = args.get(i + 1).ok_or_else(|| {
                labyrinth::Error::Config(format!("option {a} needs a value"))
            })?;
            options.push((a.clone(), Some(v.clone())));
            i += 2;
        } else if FLAG_OPTS.contains(&a.as_str()) {
            options.push((a.clone(), None));
            i += 1;
        } else if a.starts_with("--") {
            return Err(labyrinth::Error::Config(format!("unknown option {a}")));
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Opts { positional, options })
}

impl Opts {
    fn get(&self, key: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }
    fn has(&self, key: &str) -> bool {
        self.options.iter().any(|(k, _)| k == key)
    }
    /// Every value given for a repeatable option, in order.
    fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }
}

/// Merge config file + CLI into one [`Config`] namespace.
fn load_config(opts: &Opts) -> Result<Config> {
    let mut cfg = match opts.get("--config") {
        Some(path) => Config::load(path)?,
        None => Config::new(),
    };
    for (k, v) in &opts.options {
        if let Some(v) = v {
            cfg.set(format!("cli.{}", k.trim_start_matches("--")), v.clone());
        }
    }
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = parse_opts(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&opts),
        "compile" => cmd_compile(&opts),
        "trace" => cmd_trace(&opts),
        "generate" => cmd_generate(&opts),
        "config" => cmd_config(&opts),
        "serve" => cmd_serve(&opts),
        "bench-serve" => {
            labyrinth::serve::bench::serving_benchmark(opts.has("--smoke"));
            // Under LABY_TRACE=1 every service in the benchmark recorded
            // its serve lifecycle (queue → compile → bind → epoch →
            // reply, pool resizes) into the process-global tracer —
            // export the timeline for the CI serve-storm artifact.
            if let Some(tracer) = labyrinth::obs::default_tracer() {
                let trace = tracer.take();
                let events = labyrinth::obs::chrome::chrome_events(&trace, None);
                if let Err(e) = labyrinth::obs::chrome::validate(&events) {
                    eprintln!("warning: serve trace failed structural validation: {e}");
                }
                std::fs::write(
                    "serve_trace.json",
                    labyrinth::obs::chrome::render(&events),
                )?;
                println!(
                    "wrote serve_trace.json: {} events ({} dropped)",
                    events.len(),
                    trace.dropped
                );
            }
            Ok(())
        }
        "bench-throughput" => {
            labyrinth::bench_throughput::throughput_benchmark(opts.has("--smoke"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(labyrinth::Error::Config(format!("unknown command '{other}'"))),
    }
}

fn print_usage() {
    println!(
        "labyrinth — imperative control flow compiled to a single cyclic dataflow\n\
         \n\
         USAGE:\n\
         \x20 labyrinth run <program.laby> [--workers N] [--mode pipelined|barrier]\n\
         \x20            [--executor labyrinth|spark|flink|single] [--no-reuse]\n\
         \x20            [--no-opt] [--no-hoist] [--no-fuse] [--no-dce]\n\
         \x20            [--no-pushdown] [--no-join-sides] [--no-delta] [--no-columnar]\n\
         \x20            [--speculate auto|always|never] [--columnar auto|always|never]\n\
         \x20            [--explain] [--io-dir DIR] [--config FILE] [--sched] [--metrics]\n\
         \x20            [--checkpoint-every K] [--faults SEED]\n\
         \x20 labyrinth compile <program.laby> [--dump ir|ssa|dataflow|dot|opt]\n\
         \x20 labyrinth trace <program.laby> [--workers N] [--mode pipelined|barrier]\n\
         \x20            [--out trace.json] [--metrics]\n\
         \x20 labyrinth serve <program.laby> [--workers N] [--lanes S | --slots S]\n\
         \x20            [--min-workers N] [--max-workers N]\n\
         \x20            [--tenants name:weight[:budget],...] [--requests R]\n\
         \x20            [--param name=value]... [--no-adaptive] [--no-share-preambles]\n\
         \x20            [--metrics]\n\
         \x20 labyrinth bench-serve [--smoke]\n\
         \x20 labyrinth bench-throughput [--smoke]\n\
         \x20 labyrinth generate visitcount --days N [--visits M] [--pages P] --out DIR\n\
         \x20 labyrinth config --dump [--config FILE]"
    );
}

/// Optimizer configuration: config file `opt.*` keys overridden by CLI
/// flags (`--no-opt` disables every pass; `--no-hoist` / `--no-fuse` /
/// `--no-dce` / `--no-pushdown` / `--no-join-sides` / `--no-delta` /
/// `--no-columnar` disable one each;
/// `--speculate auto|always|never` sets the hoist speculation policy and
/// `--columnar auto|always|never` gates the typed columnar data plane).
fn opt_config(opts: &Opts, cfg: &Config) -> Result<labyrinth::opt::OptConfig> {
    let mut ocfg = labyrinth::opt::OptConfig::from_config(cfg)?;
    if opts.has("--no-opt") {
        ocfg = labyrinth::opt::OptConfig::none();
    }
    if opts.has("--no-hoist") {
        ocfg.hoist = false;
    }
    if opts.has("--no-fuse") {
        ocfg.fuse = false;
    }
    if opts.has("--no-dce") {
        ocfg.dce = false;
    }
    if opts.has("--no-pushdown") {
        ocfg.pushdown = false;
    }
    if opts.has("--no-join-sides") {
        ocfg.join_sides = false;
    }
    if opts.has("--no-delta") {
        ocfg.delta = labyrinth::opt::DeltaGate::Never;
    }
    if let Some(s) = opts.get("--columnar") {
        ocfg.columnar = labyrinth::opt::ColumnarGate::parse(s)?;
    }
    if opts.has("--no-columnar") {
        ocfg.columnar = labyrinth::opt::ColumnarGate::Never;
    }
    if let Some(s) = opts.get("--speculate") {
        ocfg.speculate = labyrinth::opt::Speculate::parse(s)?;
    }
    Ok(ocfg)
}

/// Recovery knobs shared by `run` and `trace`: `--checkpoint-every K`
/// snapshots loop state every K supersteps (config key
/// `exec.checkpoint_every`), `--faults SEED` arms a seeded
/// fault-injection plan — absent both, the `LABY_FAULTS` env default
/// applies.
fn recovery_opts(
    cfg: &Config,
) -> Result<(Option<u32>, Option<std::sync::Arc<labyrinth::exec::FaultPlan>>)> {
    let checkpoint_every =
        match cfg.get("cli.checkpoint-every").or(cfg.get("exec.checkpoint_every")) {
            Some(s) => Some(s.parse::<u32>().ok().filter(|&k| k > 0).ok_or_else(|| {
                labyrinth::Error::Config(format!(
                    "--checkpoint-every expects a positive integer, got {s:?}"
                ))
            })?),
            None => None,
        };
    let faults = match cfg.get("cli.faults") {
        Some(s) => {
            let seed = s.parse::<u64>().map_err(|_| {
                labyrinth::Error::Config(format!("--faults expects a u64 seed, got {s:?}"))
            })?;
            Some(std::sync::Arc::new(labyrinth::exec::FaultPlan::seeded(seed)))
        }
        None => labyrinth::exec::default_faults(),
    };
    Ok((checkpoint_every, faults))
}

fn read_program(opts: &Opts) -> Result<labyrinth::frontend::Program> {
    let path = opts
        .positional
        .first()
        .ok_or_else(|| labyrinth::Error::Config("expected a <program.laby> path".into()))?;
    let src = std::fs::read_to_string(path)?;
    labyrinth::frontend::parse_and_lower(&src)
}

fn cmd_run(opts: &Opts) -> Result<()> {
    let cfg = load_config(opts)?;
    let program = read_program(opts)?;
    let workers = cfg.get_usize("cli.workers", cfg.get_usize("exec.workers", 2)?)?;
    let io_dir = std::path::PathBuf::from(
        cfg.get("cli.io-dir").or(cfg.get("exec.io_dir")).unwrap_or("."),
    );
    let executor = cfg.get_or("cli.executor", &cfg.get_or("exec.executor", "labyrinth"));
    let t0 = std::time::Instant::now();

    match executor.as_str() {
        "labyrinth" => {
            let mode = match cfg.get_or("cli.mode", &cfg.get_or("exec.mode", "pipelined")).as_str()
            {
                "barrier" => ExecMode::Barrier,
                _ => ExecMode::Pipelined,
            };
            let (graph, explain) = labyrinth::compile_with(&program, &opt_config(opts, &cfg)?)?;
            if opts.has("--explain") {
                print!("{}", explain.render());
            }
            let (checkpoint_every, faults) = recovery_opts(&cfg)?;
            let run_cfg = ExecConfig {
                workers,
                mode,
                batch: cfg.get_usize("cli.batch", cfg.get_usize("exec.batch", 256)?)?,
                reuse_state: !opts.has("--no-reuse"),
                io_dir,
                sched: opts.has("--sched").then(labyrinth::sched::LatencyModel::flink_like),
                checkpoint_every,
                faults,
                ..Default::default()
            };
            let out = labyrinth::exec::run(&graph, &run_cfg)?;
            report_collected(out.collected.iter().map(|(k, v)| (k.as_str(), v.as_slice())));
            println!(
                "ok: {} control-flow steps, {} in dataflow ({} job scheduling)",
                out.path_len,
                labyrinth::util::fmt_duration(out.elapsed),
                labyrinth::util::fmt_duration(out.sched_overhead),
            );
            if opts.has("--metrics") {
                print!("{}", out.metrics.report());
            }
        }
        "spark" | "flink" => {
            let mut scfg = if executor == "spark" {
                separate_jobs::SeparateJobsConfig::spark(workers)
            } else {
                separate_jobs::SeparateJobsConfig::flink(workers)
            };
            scfg.io_dir = io_dir;
            let out = separate_jobs::run(&program, &scfg)?;
            report_collected(out.collected.iter().map(|(k, v)| (k.as_str(), v.as_slice())));
            println!(
                "ok: {} jobs launched, {} total ({} scheduling)",
                out.jobs_launched,
                labyrinth::util::fmt_duration(out.elapsed),
                labyrinth::util::fmt_duration(out.sched_time),
            );
        }
        "single" => {
            let scfg = baselines::single_thread::SingleThreadConfig {
                io_dir,
                ..Default::default()
            };
            let out = baselines::single_thread::run(&program, &scfg)?;
            report_collected(out.collected.iter().map(|(k, v)| (k.as_str(), v.as_slice())));
            println!("ok: single-threaded in {}", labyrinth::util::fmt_duration(out.elapsed));
        }
        other => {
            return Err(labyrinth::Error::Config(format!(
                "unknown executor '{other}' (labyrinth|spark|flink|single)"
            )))
        }
    }
    println!("total wall time {}", labyrinth::util::fmt_duration(t0.elapsed()));
    Ok(())
}

fn report_collected<'a>(collected: impl Iterator<Item = (&'a str, &'a [labyrinth::Value])>) {
    let mut entries: Vec<_> = collected.collect();
    entries.sort_by_key(|(k, _)| k.to_string());
    for (label, items) in entries {
        let preview: Vec<String> = items.iter().take(8).map(|v| format!("{v:?}")).collect();
        println!(
            "collected '{label}': {} elements [{}{}]",
            items.len(),
            preview.join(", "),
            if items.len() > 8 { ", …" } else { "" }
        );
    }
}

fn cmd_compile(opts: &Opts) -> Result<()> {
    let cfg = load_config(opts)?;
    let program = read_program(opts)?;
    let dump = opts.get("--dump").unwrap_or("dataflow");
    match dump {
        "ir" => print!("{}", program.listing()),
        "ssa" => {
            let cfg = labyrinth::cfg::Cfg::from_program(&program)?;
            let ssa = labyrinth::ssa::construct(&cfg)?;
            print!("{}", ssa.listing());
        }
        "opt" => {
            let (_, explain) = labyrinth::compile_with(&program, &opt_config(opts, &cfg)?)?;
            print!("{}", explain.render());
        }
        "dataflow" => {
            let (graph, explain) = labyrinth::compile_with(&program, &opt_config(opts, &cfg)?)?;
            if opts.has("--explain") {
                print!("{}", explain.render());
            }
            println!("-- SSA --\n{}", graph.ssa_listing);
            println!("-- dataflow: {} nodes --", graph.num_nodes());
            for n in &graph.nodes {
                let ins: Vec<String> = n
                    .inputs
                    .iter()
                    .map(|i| {
                        format!(
                            "{}{}",
                            graph.nodes[i.src].name,
                            if i.conditional { "*" } else { "" }
                        )
                    })
                    .collect();
                println!(
                    "  [{}] {} := {}({})  block=bb{} par={:?}{}",
                    n.id,
                    n.name,
                    n.op.mnemonic(),
                    ins.join(", "),
                    n.block,
                    n.par,
                    if n.cond.is_some() { " [condition]" } else { "" }
                );
            }
        }
        "dot" => {
            let (graph, _) = labyrinth::compile_with(&program, &opt_config(opts, &cfg)?)?;
            print!("{}", labyrinth::dataflow::dot::to_dot(&graph));
        }
        other => {
            return Err(labyrinth::Error::Config(format!(
                "unknown dump '{other}' (ir|ssa|dataflow|dot|opt)"
            )))
        }
    }
    Ok(())
}

/// `labyrinth trace <program.laby>`: run the program once with the span
/// tracer enabled, print the per-superstep / per-operator breakdown, and
/// write a Chrome-trace (Perfetto) JSON timeline to `--out`.
fn cmd_trace(opts: &Opts) -> Result<()> {
    let cfg = load_config(opts)?;
    let program = read_program(opts)?;
    let workers = cfg.get_usize("cli.workers", cfg.get_usize("exec.workers", 2)?)?;
    let mode = match cfg.get_or("cli.mode", &cfg.get_or("exec.mode", "pipelined")).as_str() {
        "barrier" => ExecMode::Barrier,
        _ => ExecMode::Pipelined,
    };
    let io_dir = std::path::PathBuf::from(
        cfg.get("cli.io-dir").or(cfg.get("exec.io_dir")).unwrap_or("."),
    );
    let (graph, explain) = labyrinth::compile_with(&program, &opt_config(opts, &cfg)?)?;
    if opts.has("--explain") {
        print!("{}", explain.render());
    }

    let tracer = std::sync::Arc::new(labyrinth::obs::Tracer::new(true));
    let (checkpoint_every, faults) = recovery_opts(&cfg)?;
    let run_cfg = ExecConfig {
        workers,
        mode,
        batch: cfg.get_usize("cli.batch", cfg.get_usize("exec.batch", 256)?)?,
        io_dir,
        trace: Some(tracer.clone()),
        checkpoint_every,
        faults,
        ..Default::default()
    };
    let out = labyrinth::exec::run(&graph, &run_cfg)?;
    let trace = tracer.take();

    print!("{}", labyrinth::obs::report::render_breakdown(&trace, &graph, &out));

    let events = labyrinth::obs::chrome::chrome_events(&trace, Some(&graph));
    if let Err(e) = labyrinth::obs::chrome::validate(&events) {
        eprintln!("warning: trace failed structural validation: {e}");
    }
    let path = opts.get("--out").unwrap_or("trace.json");
    std::fs::write(path, labyrinth::obs::chrome::render(&events))?;
    println!(
        "wrote {path}: {} events ({} dropped) — open in https://ui.perfetto.dev \
         or chrome://tracing",
        events.len(),
        trace.dropped,
    );
    if opts.has("--metrics") {
        print!("{}", out.metrics.report());
    }
    Ok(())
}

/// `labyrinth serve <program.laby>`: start a resident `JobService`, feed
/// it `--requests` submissions of the program (with optional per-request
/// `--param name=value` bindings as singleton named sources), and print
/// per-request latencies plus the service report. A demonstration driver
/// for the `serve::` API — real deployments embed `JobService` directly.
fn cmd_serve(opts: &Opts) -> Result<()> {
    let cfg = load_config(opts)?;
    let path = opts
        .positional
        .first()
        .ok_or_else(|| labyrinth::Error::Config("expected a <program.laby> path".into()))?;
    let src = std::fs::read_to_string(path)?;
    let workers = cfg.get_usize("cli.workers", cfg.get_usize("serve.workers", 2)?)?;
    // `--lanes` is the sharded-tier name for `--slots` (one shard-
    // pinnable worker-pool lane each); either spelling works.
    let slots = cfg.get_usize(
        "cli.lanes",
        cfg.get_usize("cli.slots", cfg.get_usize("serve.slots", 2)?)?,
    )?;
    let min_workers =
        cfg.get_usize("cli.min-workers", cfg.get_usize("serve.min_workers", 0)?)?;
    let max_workers =
        cfg.get_usize("cli.max-workers", cfg.get_usize("serve.max_workers", 0)?)?;
    let tenants = match cfg.get("cli.tenants").or(cfg.get("serve.tenants")) {
        Some(spec) => parse_tenants(spec)?,
        None => Vec::new(),
    };
    let requests = cfg.get_usize("cli.requests", cfg.get_usize("serve.requests", 8)?)?;
    let io_dir = std::path::PathBuf::from(
        cfg.get("cli.io-dir").or(cfg.get("exec.io_dir")).unwrap_or("."),
    );

    let mut params: Vec<(String, labyrinth::Value)> = Vec::new();
    for kv in opts.get_all("--param") {
        let (k, v) = kv.split_once('=').ok_or_else(|| {
            labyrinth::Error::Config(format!("--param expects name=value, got {kv:?}"))
        })?;
        let value = match v.parse::<i64>() {
            Ok(i) => labyrinth::Value::I64(i),
            Err(_) => match v.parse::<f64>() {
                Ok(f) => labyrinth::Value::F64(f),
                Err(_) => labyrinth::Value::str(v),
            },
        };
        params.push((k.to_string(), value));
    }

    let (checkpoint_every, fault_seed) = {
        let (ck, _) = recovery_opts(&cfg)?;
        let seed = match cfg.get("cli.faults") {
            Some(s) => Some(s.parse::<u64>().map_err(|_| {
                labyrinth::Error::Config(format!("--faults expects a u64 seed, got {s:?}"))
            })?),
            None => None,
        };
        (ck, seed)
    };
    let svc = labyrinth::serve::JobService::new(labyrinth::serve::ServeConfig {
        slots,
        workers,
        min_workers,
        max_workers,
        tenants,
        io_dir,
        opt: opt_config(opts, &cfg)?,
        adaptive: !opts.has("--no-adaptive"),
        share_preambles: !opts.has("--no-share-preambles"),
        checkpoint_every,
        ..Default::default()
    });
    let elastic = if min_workers != 0 || max_workers != 0 {
        format!(" (elastic {min_workers}..{max_workers})")
    } else {
        String::new()
    };
    println!(
        "serving {path} on {slots} lane(s) x {workers} worker(s){elastic}, \
         {requests} request(s)"
    );
    for i in 0..requests {
        let mut req = labyrinth::serve::JobRequest::source(src.clone());
        for (k, v) in &params {
            req = req.param(k.clone(), v.clone());
        }
        if let Some(seed) = fault_seed {
            req = req.faults(labyrinth::exec::FaultPlan::seeded(seed));
        }
        let t0 = std::time::Instant::now();
        let res = svc.run(req)?;
        println!(
            "request {i}: {:?} rev{} in {} (queued {}, compile {})",
            res.cache,
            res.revision,
            labyrinth::util::fmt_duration(t0.elapsed()),
            labyrinth::util::fmt_duration(res.queued),
            labyrinth::util::fmt_duration(res.compile),
        );
        if i == requests.saturating_sub(1) {
            report_collected(
                res.output.collected.iter().map(|(k, v)| (k.as_str(), v.as_slice())),
            );
        }
    }
    // Shutdown snapshot: the full metrics report (counters + latency
    // histograms) always prints — a resident service's operational record
    // should not hide behind a flag. `--metrics` is still accepted.
    print!("{}", svc.report());
    Ok(())
}

/// Parse `--tenants name:weight[:budget],...` into
/// [`labyrinth::serve::TenantSpec`]s —
/// e.g. `--tenants analytics:1,interactive:8:50000` gives the
/// interactive tenant 8× the DRR share and sheds its submissions past
/// 50k queued estimated cost.
fn parse_tenants(spec: &str) -> Result<Vec<labyrinth::serve::TenantSpec>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|entry| {
            let mut parts = entry.trim().split(':');
            let name = parts.next().unwrap_or_default();
            if name.is_empty() {
                return Err(labyrinth::Error::Config(format!(
                    "--tenants entry {entry:?} has no name (want name:weight[:budget])"
                )));
            }
            let weight = match parts.next() {
                Some(w) => w.parse::<f64>().map_err(|_| {
                    labyrinth::Error::Config(format!(
                        "--tenants {entry:?}: weight {w:?} is not a number"
                    ))
                })?,
                None => 1.0,
            };
            let budget = match parts.next() {
                Some(b) => b.parse::<f64>().map_err(|_| {
                    labyrinth::Error::Config(format!(
                        "--tenants {entry:?}: budget {b:?} is not a number"
                    ))
                })?,
                None => 0.0,
            };
            if parts.next().is_some() {
                return Err(labyrinth::Error::Config(format!(
                    "--tenants entry {entry:?} has too many fields (want name:weight[:budget])"
                )));
            }
            Ok(labyrinth::serve::TenantSpec::new(name, weight).budget(budget))
        })
        .collect()
}

fn cmd_generate(opts: &Opts) -> Result<()> {
    let what = opts
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| labyrinth::Error::Config("generate what? (visitcount)".into()))?;
    let out = opts
        .get("--out")
        .ok_or_else(|| labyrinth::Error::Config("--out DIR required".into()))?;
    match what {
        "visitcount" => {
            let w = labyrinth::workload::VisitCountWorkload {
                days: opts.get("--days").map(|s| s.parse().unwrap()).unwrap_or(10),
                visits_per_day: opts.get("--visits").map(|s| s.parse().unwrap()).unwrap_or(10_000),
                num_pages: opts.get("--pages").map(|s| s.parse().unwrap()).unwrap_or(1_000),
                ..Default::default()
            };
            w.write_files(std::path::Path::new(out))?;
            println!(
                "generated {} day logs + pageAttributes under {out} ({} visits/day, {} pages)",
                w.days, w.visits_per_day, w.num_pages
            );
            Ok(())
        }
        other => Err(labyrinth::Error::Config(format!("unknown workload '{other}'"))),
    }
}

fn cmd_config(opts: &Opts) -> Result<()> {
    let cfg = load_config(opts)?;
    for k in cfg.keys() {
        println!("{k} = {}", cfg.get(&k).unwrap_or(""));
    }
    Ok(())
}
