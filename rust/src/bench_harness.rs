//! Minimal benchmarking harness (criterion is unavailable offline; see
//! DESIGN.md §2). Every `cargo bench` target (`rust/benches/*.rs`,
//! `harness = false`) uses this module to time closures with warmup,
//! report median / mean / p95, and print the paper-style result tables.

use crate::util::fmt_duration;
use std::time::{Duration, Instant};

/// One benchmark measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Label (e.g. "labyrinth w=25").
    pub label: String,
    /// Per-repetition wall times, sorted ascending.
    pub samples: Vec<Duration>,
}

impl Measurement {
    /// Median sample.
    pub fn median(&self) -> Duration {
        self.samples[self.samples.len() / 2]
    }
    /// Arithmetic mean.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }
    /// 95th percentile (nearest-rank).
    pub fn p95(&self) -> Duration {
        let idx = ((self.samples.len() as f64) * 0.95).ceil() as usize;
        self.samples[idx.saturating_sub(1).min(self.samples.len() - 1)]
    }
    /// Minimum sample.
    pub fn min(&self) -> Duration {
        self.samples[0]
    }
}

/// Benchmark runner: `warmup` untimed runs then `reps` timed runs.
pub struct Bencher {
    warmup: usize,
    reps: usize,
}

impl Bencher {
    /// Create a runner with explicit warmup/repetition counts.
    pub fn new(warmup: usize, reps: usize) -> Bencher {
        Bencher { warmup, reps: reps.max(1) }
    }

    /// Quick-mode heuristic: honor `LABY_BENCH_QUICK=1` to slash rep counts
    /// (used in CI / `make bench-quick`).
    pub fn from_env(warmup: usize, reps: usize) -> Bencher {
        if std::env::var("LABY_BENCH_QUICK").ok().as_deref() == Some("1") {
            Bencher::new(warmup.min(1), (reps / 3).max(1))
        } else {
            Bencher::new(warmup, reps)
        }
    }

    /// Time `f` (which should perform one full run of the workload).
    pub fn run(&self, label: impl Into<String>, mut f: impl FnMut()) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let m = Measurement { label: label.into(), samples };
        eprintln!(
            "  {:<38} median {:>10}  mean {:>10}  p95 {:>10}  (n={})",
            m.label,
            fmt_duration(m.median()),
            fmt_duration(m.mean()),
            fmt_duration(m.p95()),
            m.samples.len()
        );
        m
    }
}

/// A paper-style results table: one row per x-value (e.g. worker count),
/// one column per series (e.g. system), cells are median durations.
pub struct Table {
    /// Table title, printed as a header.
    pub title: String,
    /// Name of the x-axis (first column header).
    pub x_name: String,
    /// Series names (column headers).
    pub series: Vec<String>,
    /// Rows: (x, cells aligned with `series`; None = not run).
    pub rows: Vec<(String, Vec<Option<Duration>>)>,
}

impl Table {
    /// Create an empty table.
    pub fn new(
        title: impl Into<String>,
        x_name: impl Into<String>,
        series: Vec<String>,
    ) -> Table {
        Table { title: title.into(), x_name: x_name.into(), series, rows: Vec::new() }
    }

    /// Append a row.
    pub fn push_row(&mut self, x: impl Into<String>, cells: Vec<Option<Duration>>) {
        assert_eq!(cells.len(), self.series.len());
        self.rows.push((x.into(), cells));
    }

    /// Render as an aligned ASCII table (the benches print these; the
    /// harness in EXPERIMENTS.md copies them verbatim).
    pub fn render(&self) -> String {
        let mut widths = vec![self.x_name.len()];
        widths.extend(self.series.iter().map(|s| s.len().max(10)));
        for (x, cells) in &self.rows {
            widths[0] = widths[0].max(x.len());
            for (i, c) in cells.iter().enumerate() {
                let s = c.map(fmt_duration).unwrap_or_else(|| "-".into());
                widths[i + 1] = widths[i + 1].max(s.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&crate::util::pad(&self.x_name, widths[0]));
        for (i, s) in self.series.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&crate::util::pad(s, widths[i + 1]));
        }
        out.push('\n');
        for (x, cells) in &self.rows {
            out.push_str(&crate::util::pad(x, widths[0]));
            for (i, c) in cells.iter().enumerate() {
                let s = c.map(fmt_duration).unwrap_or_else(|| "-".into());
                out.push_str("  ");
                out.push_str(&crate::util::pad(&s, widths[i + 1]));
            }
            out.push('\n');
        }
        out
    }

    /// Print to stdout (captured by `cargo bench | tee bench_output.txt`).
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            label: "t".into(),
            samples: (1..=100).map(Duration::from_millis).collect(),
        };
        assert_eq!(m.median(), Duration::from_millis(51));
        assert_eq!(m.p95(), Duration::from_millis(95));
        assert_eq!(m.min(), Duration::from_millis(1));
    }

    #[test]
    fn bencher_runs_expected_reps() {
        let mut count = 0;
        let b = Bencher::new(2, 5);
        let m = b.run("x", || count += 1);
        assert_eq!(count, 7);
        assert_eq!(m.samples.len(), 5);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("T", "workers", vec!["a".into(), "b".into()]);
        t.push_row("1", vec![Some(Duration::from_millis(3)), None]);
        t.push_row("25", vec![Some(Duration::from_micros(14)), Some(Duration::from_secs(1))]);
        let r = t.render();
        assert!(r.contains("workers"));
        assert!(r.contains("3.000ms"));
        assert!(r.contains("1.000s"));
        assert!(r.contains('-'));
    }
}
