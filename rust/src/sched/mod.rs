//! Centralized-scheduler substrate: simulates the per-job scheduling cost
//! of launching a dataflow job on a cluster (Fig. 4 of the paper).
//!
//! A real Spark/Flink job launch serializes one task descriptor per
//! (operator × worker slot) and dispatches each through a centralized
//! scheduler over the network. We reproduce that *shape*: the scheduler
//! loop really iterates over task descriptors, "serializes" them (hashes
//! the bytes), and spin-waits one RPC latency per dispatch — so the cost
//! is linear in `operators × workers`, exactly like the paper's
//! measurement (254 ms Spark / 376 ms Flink at 25 workers). Latencies are
//! µs-scale by default so the benches finish; the linearity and the
//! orders-of-magnitude gap to Labyrinth's in-job coordination are
//! preserved (DESIGN.md §2, §6).

use std::time::{Duration, Instant};

/// Latency model of one cluster scheduler.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyModel {
    /// Fixed per-job setup cost (client → master RPC, job graph build).
    pub job_setup: Duration,
    /// Per-task dispatch cost (scheduling decision + task RPC).
    pub rpc_dispatch: Duration,
    /// Per-job result/ack collection cost.
    pub result_fetch: Duration,
    /// Tasks per (operator, worker): Spark uses 2× cores, Flink 1× (paper ref \[34\]).
    pub tasks_per_slot: usize,
}

impl LatencyModel {
    /// Spark-like defaults (heavier per-job setup, 2 tasks per slot).
    pub fn spark_like() -> LatencyModel {
        LatencyModel {
            job_setup: Duration::from_micros(900),
            rpc_dispatch: Duration::from_micros(55),
            result_fetch: Duration::from_micros(300),
            tasks_per_slot: 2,
        }
    }

    /// Flink-like defaults (heavier per-task dispatch, 1 task per slot —
    /// net: larger per-job overhead at scale, as in Fig. 4).
    pub fn flink_like() -> LatencyModel {
        LatencyModel {
            job_setup: Duration::from_micros(700),
            rpc_dispatch: Duration::from_micros(160),
            result_fetch: Duration::from_micros(250),
            tasks_per_slot: 1,
        }
    }

    /// Scale all latencies (sensitivity sweeps / quick test mode).
    pub fn scaled(&self, f: f64) -> LatencyModel {
        let s = |d: Duration| Duration::from_nanos((d.as_nanos() as f64 * f) as u64);
        LatencyModel {
            job_setup: s(self.job_setup),
            rpc_dispatch: s(self.rpc_dispatch),
            result_fetch: s(self.result_fetch),
            tasks_per_slot: self.tasks_per_slot,
        }
    }

    /// The modelled overhead of one job launch (without executing it).
    pub fn job_launch_cost(&self, operators: usize, workers: usize) -> Duration {
        let tasks = operators.max(1) * workers.max(1) * self.tasks_per_slot;
        self.job_setup + self.rpc_dispatch * tasks as u32 + self.result_fetch
    }

    /// Actually *spend* the scheduling time: run the centralized dispatch
    /// loop over task descriptors. Returns the elapsed duration.
    pub fn simulate_job_launch(&self, operators: usize, workers: usize) -> Duration {
        let start = Instant::now();
        spin_for(self.job_setup);
        let scheduler = Scheduler::new();
        for op in 0..operators.max(1) {
            for w in 0..workers.max(1) {
                for t in 0..self.tasks_per_slot {
                    let desc = TaskDescriptor { op, worker: w, attempt: t };
                    scheduler.dispatch(&desc, self.rpc_dispatch);
                }
            }
        }
        spin_for(self.result_fetch);
        start.elapsed()
    }
}

/// A task descriptor (what a real scheduler would serialize per task).
#[derive(Debug)]
pub struct TaskDescriptor {
    /// Logical operator index.
    pub op: usize,
    /// Target worker.
    pub worker: usize,
    /// Task attempt / slot index.
    pub attempt: usize,
}

/// The centralized scheduler: dispatches tasks one at a time (this
/// single-threaded loop is precisely the bottleneck the paper's Fig. 4
/// measures growing linearly with cluster size).
pub struct Scheduler {
    dispatched: std::cell::Cell<u64>,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    /// New scheduler.
    pub fn new() -> Scheduler {
        Scheduler { dispatched: std::cell::Cell::new(0) }
    }

    /// Serialize + dispatch one task with the given RPC latency.
    pub fn dispatch(&self, task: &TaskDescriptor, rpc: Duration) {
        // "Serialize": fold the descriptor into a checksum so the work is
        // not optimized away.
        let mut h = 0xcbf29ce484222325u64;
        for b in [task.op as u64, task.worker as u64, task.attempt as u64] {
            h = (h ^ b).wrapping_mul(0x100000001b3);
        }
        self.dispatched.set(self.dispatched.get().wrapping_add(h | 1));
        spin_for(rpc);
    }

    /// Number of dispatch calls folded into the checksum (nonzero).
    pub fn checksum(&self) -> u64 {
        self.dispatched.get()
    }
}

/// Busy-wait for a duration (sleep() cannot hit µs precision).
pub fn spin_for(d: Duration) {
    if d.is_zero() {
        return;
    }
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_cost_linear_in_workers() {
        let m = LatencyModel::flink_like();
        let c5 = m.job_launch_cost(3, 5);
        let c25 = m.job_launch_cost(3, 25);
        let fixed = m.job_setup + m.result_fetch;
        // Variable part scales 5x.
        assert_eq!((c25 - fixed).as_nanos(), (c5 - fixed).as_nanos() * 5);
    }

    #[test]
    fn spark_uses_double_tasks() {
        let s = LatencyModel::spark_like();
        let f = LatencyModel::flink_like();
        assert_eq!(s.tasks_per_slot, 2);
        assert_eq!(f.tasks_per_slot, 1);
    }

    #[test]
    fn simulate_actually_spends_time() {
        let m = LatencyModel {
            job_setup: Duration::from_micros(50),
            rpc_dispatch: Duration::from_micros(10),
            result_fetch: Duration::from_micros(50),
            tasks_per_slot: 1,
        };
        let elapsed = m.simulate_job_launch(4, 2);
        let modelled = m.job_launch_cost(4, 2);
        assert!(elapsed >= modelled, "{elapsed:?} < {modelled:?}");
        // And not wildly more (spin precision).
        assert!(elapsed < modelled * 3, "{elapsed:?} vs {modelled:?}");
    }

    #[test]
    fn scaled_model_scales() {
        let m = LatencyModel::spark_like().scaled(0.5);
        assert_eq!(m.rpc_dispatch, LatencyModel::spark_like().rpc_dispatch / 2);
    }
}
