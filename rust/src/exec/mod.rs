//! The Labyrinth execution engine: runs a compiled dataflow graph as a
//! **single cyclic job** on a simulated cluster (one thread per worker,
//! channels as the network), coordinating control flow with the §6.3
//! protocol. Supports the default *pipelined* mode (§9.3) and a per-step
//! *barrier* mode for the loop-pipelining ablation (Fig. 6).

pub mod driver;
pub mod instance;
pub mod message;
pub mod plan;
pub mod pool;
pub mod recovery;
pub mod worker;

use crate::dataflow::{DataflowGraph, NodeId};
use crate::error::Result;
use crate::metrics::Metrics;
use crate::value::Value;
use rustc_hash::FxHashMap;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use plan::ExecPlan;
pub use pool::WorkerPool;
pub use recovery::{EpochCheckpoint, FaultKind, FaultPlan, RetryPolicy};

/// Default driver stall limit (see [`ExecConfig::stall_timeout`]).
pub const DEFAULT_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Default: operators of different iteration steps overlap freely
    /// (loop pipelining, §9.3).
    Pipelined,
    /// Control-flow decisions are withheld until every bag of the current
    /// path prefix is complete — emulating per-step synchronization
    /// barriers (Flink-style supersteps).
    Barrier,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// Simulated worker (machine) count.
    pub workers: usize,
    /// Pipelined vs barrier execution.
    pub mode: ExecMode,
    /// Element-batch size on channels.
    pub batch: usize,
    /// §7 build-side state reuse (Fig. 8 "Laby-noreuse" turns this off).
    pub reuse_state: bool,
    /// Base directory for file I/O operators.
    pub io_dir: std::path::PathBuf,
    /// Optional scheduler substrate: simulate the one-time job submission
    /// cost (`sched::LatencyModel`) before execution starts.
    pub sched: Option<crate::sched::LatencyModel>,
    /// Named-source registry for this run. Defaults to the process-global
    /// registry; the `serve::` job service passes a per-request
    /// [`crate::workload::registry::Registry::overlay`] here so requests
    /// bind their own datasets without touching global state.
    pub registry: Arc<crate::workload::registry::Registry>,
    /// Optional absolute deadline: the driver aborts the run (shutting the
    /// epoch down cleanly) once this instant passes. Used by the `serve::`
    /// admission queue's per-job deadlines.
    pub deadline: Option<std::time::Instant>,
    /// Optional cooperative cancellation token. The driver polls it in its
    /// recv loop (alongside the deadline check) and every worker checks it
    /// between messages — superstep/batch boundaries — so a set token
    /// aborts a running epoch within one superstep, with the same clean
    /// teardown as a deadline abort (queues drained, pool threads back to
    /// resident idle). `serve::JobTicket::cancel` sets it.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Cross-job sharing of materialized loop-invariant preamble bags
    /// (`serve::` only; `None` = every epoch recomputes its preambles).
    pub preamble: Option<PreambleSharing>,
    /// Force the legacy element-at-a-time data plane: instances feed
    /// transformations through `push_in_element` (cloning each value) and
    /// route emissions one element at a time, instead of the batched
    /// `push_in_batch` + per-batch scatter path. Kept as a reference
    /// implementation for differential testing and the throughput
    /// benchmark's before/after series; `LABY_ELEMENT_PATH=1` sets it
    /// process-wide through [`ExecConfig::default`].
    pub element_path: bool,
    /// Optional span tracer (`obs::`). `None` — the default unless
    /// `LABY_TRACE=1` — keeps the data plane free of any timing calls;
    /// with a tracer whose gate is on, the driver and every worker
    /// record epoch/superstep/per-node spans into per-thread ring
    /// buffers. The gate is re-checked once per epoch, so one tracer
    /// can be toggled across the runs of a resident `serve::` pool.
    pub trace: Option<Arc<crate::obs::Tracer>>,
    /// Superstep-boundary checkpointing: `Some(k)` snapshots loop state
    /// every k control-flow decisions, so a retried epoch resumes from
    /// the last completed superstep instead of rerunning from scratch
    /// (`recovery::`). `None` (the default) takes no checkpoints and
    /// adds no cost — the driver never tracks the completion frontier.
    pub checkpoint_every: Option<u32>,
    /// Deterministic fault injection ([`recovery::FaultPlan`]): a
    /// seeded schedule of worker-panic / message-drop / slow-worker
    /// events keyed to `(worker, superstep)`. `None` unless
    /// `LABY_FAULTS=<seed>` arms a process-wide seeded plan (see
    /// [`default_faults`]). Setting this (or `checkpoint_every`) routes
    /// `run_plan_on_pool` through `recovery::run_plan_with_recovery`,
    /// so injected crashes are retried with the default policy.
    pub faults: Option<Arc<recovery::FaultPlan>>,
    /// Driver stall limit: if no coordination message arrives for this
    /// long, the run is declared deadlocked ([`crate::Error::Coordination`])
    /// instead of hanging. Defaults to [`DEFAULT_STALL_TIMEOUT`];
    /// fault-injection tests that starve consumers (dropped messages)
    /// shrink it so recovery kicks in quickly.
    pub stall_timeout: Duration,
}

/// Materialized invariant-preamble outputs: shareable node id → the items
/// each physical instance emitted for its (single) output bag, in
/// emission order. Which nodes are shareable is decided at plan build
/// time ([`ExecPlan::shareable`]: hoisted into a depth-0 preamble — or
/// consumed ONLY by such nodes — with a deterministic, Φ-free input
/// closure).
pub type PreambleBags = FxHashMap<NodeId, Vec<Vec<Value>>>;

/// Cross-job invariant-preamble sharing for one epoch (see
/// `serve::template`). At most one of the two sides is normally set:
/// `replay` feeds instances the bags a previous epoch with a matching
/// binding signature materialized (the invariant subgraph is skipped
/// entirely — transforms never run); `capture` collects this epoch's
/// preamble bags so the service can store them for later epochs.
#[derive(Clone, Debug, Default)]
pub struct PreambleSharing {
    /// Bags to replay instead of recomputing.
    pub replay: Option<Arc<PreambleBags>>,
    /// Sink filled with `(node, instance, items)` at bag completion.
    pub capture: Option<Arc<Mutex<Vec<(NodeId, usize, Vec<Value>)>>>>,
}

/// Process-default channel batch size: 256, overridable once per process
/// via `LABY_BATCH=N` (CI runs the whole tier-1 suite at `LABY_BATCH=1`
/// to pin that batched and element-wise execution agree). Read once and
/// cached — `Default` construction sits on the serving submit path.
pub fn default_batch() -> usize {
    static BATCH: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *BATCH.get_or_init(|| {
        std::env::var("LABY_BATCH")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&b| b > 0)
            .unwrap_or(256)
    })
}

/// Process-default data-plane selection: batched, unless
/// `LABY_ELEMENT_PATH=1` forces the legacy element-at-a-time path
/// (cached like [`default_batch`]).
pub fn default_element_path() -> bool {
    static ELEMENT: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ELEMENT
        .get_or_init(|| std::env::var("LABY_ELEMENT_PATH").ok().as_deref() == Some("1"))
}

/// Process-default fault plan: `None`, unless `LABY_FAULTS=<seed>`
/// (a u64) arms chaos mode — then every [`ExecConfig::default`] gets a
/// FRESH seeded [`recovery::FaultPlan`] (each plan carries its own
/// one-shot/cap bookkeeping, so independent runs each see up to
/// [`recovery::FaultPlan::seeded`]'s capped fault budget). The seed is
/// parsed once per process; CI's chaos-smoke leg runs the whole tier-1
/// suite this way.
pub fn default_faults() -> Option<Arc<recovery::FaultPlan>> {
    static SEED: std::sync::OnceLock<Option<u64>> = std::sync::OnceLock::new();
    SEED.get_or_init(|| {
        std::env::var("LABY_FAULTS").ok().and_then(|s| s.trim().parse::<u64>().ok())
    })
    .map(|seed| Arc::new(recovery::FaultPlan::seeded(seed)))
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            workers: 2,
            mode: ExecMode::Pipelined,
            batch: default_batch(),
            reuse_state: true,
            io_dir: std::path::PathBuf::from("."),
            sched: None,
            registry: crate::workload::registry::global(),
            deadline: None,
            cancel: None,
            preamble: None,
            element_path: default_element_path(),
            trace: crate::obs::default_tracer(),
            checkpoint_every: None,
            faults: default_faults(),
            stall_timeout: DEFAULT_STALL_TIMEOUT,
        }
    }
}

/// Observed output cardinality of one logical node over a whole run
/// (summed across instances and iteration steps). Recorded cheaply on the
/// emission path — per batch, never per element — and fed back into the
/// `opt::cost` model by the `serve::` job service (adaptive
/// re-optimization of cached plan templates).
#[derive(Clone, Debug, Default)]
pub struct NodeRows {
    /// Elements emitted by all instances of the node, all steps summed.
    pub rows: u64,
    /// Output bags completed (one per instance per step).
    pub bags: u64,
    /// For `Rhs::Fused` nodes: output rows per interior stage
    /// (stage-parallel with the node's `stages`/`lineage`), summed like
    /// `rows`. Interior filter/flatMap cardinalities are invisible from
    /// the tail's output count; these counters let adaptive
    /// re-optimization pin every pre-fusion stage. Empty for other ops.
    pub stage_rows: Vec<u64>,
    /// Measured self-time (ns) spent inside this node's transformation
    /// across all instances and steps — batch pushes, bag closes, and
    /// generator runs. Zero unless the run was traced
    /// ([`ExecConfig::trace`]): cardinality counters are always on, but
    /// timing is only collected behind the tracer gate.
    pub self_time_ns: u64,
    /// Indexed-state rows the node holds at run end (delta solution
    /// sets, retained accumulators, reused hash-join builds), summed
    /// across instances. Kept separate from `rows` so delta loops stay
    /// honest: `rows` counts the per-superstep delta traffic, this
    /// gauge the solution-set size — adaptive re-optimization must read
    /// `rows` as cardinality, never this.
    pub state_size: u64,
}

/// Result of a run.
#[derive(Debug)]
pub struct RunOutput {
    /// Collected bags by label (all steps concatenated, in step order).
    pub collected: FxHashMap<String, Vec<Value>>,
    /// Per-label, per-bag outputs `(bag_len, items)` in completion order.
    pub outputs: Vec<(String, u32, Vec<Value>)>,
    /// Wall time of the dataflow execution (excluding compile).
    pub elapsed: Duration,
    /// One-time job scheduling cost simulated by the `sched` substrate.
    pub sched_overhead: Duration,
    /// Engine metrics.
    pub metrics: Arc<Metrics>,
    /// Number of control-flow steps (path length).
    pub path_len: usize,
    /// Observed per-node output cardinalities (indexed by `NodeId`).
    pub node_rows: Vec<NodeRows>,
}

impl RunOutput {
    /// Collected bag for a label (empty slice if absent).
    pub fn collected(&self, label: &str) -> &[Value] {
        self.collected.get(label).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Compile-and-run convenience over [`driver::run_plan`].
pub fn run(graph: &DataflowGraph, cfg: &ExecConfig) -> Result<RunOutput> {
    let plan = Arc::new(ExecPlan::new(Arc::new(graph.clone()), cfg.workers));
    driver::run_plan(plan, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::value::Value;

    fn run_src(src: &str, workers: usize) -> RunOutput {
        let g = crate::compile(&parse_and_lower(src).unwrap()).unwrap();
        run(&g, &ExecConfig { workers, ..Default::default() }).unwrap()
    }

    #[test]
    fn straightline_map() {
        let out = run_src("a = bag(1, 2, 3); b = a.map(|x| x * 10); collect(b, \"b\");", 2);
        let mut got = out.collected("b").to_vec();
        got.sort();
        assert_eq!(got, vec![Value::I64(10), Value::I64(20), Value::I64(30)]);
    }

    #[test]
    fn simple_loop_counts_steps() {
        // Loop runs 3 iterations; collect in exit block sees final bag.
        let out = run_src(
            "d = 1; b = bag(); while (d <= 3) { b = bag(7).map(|x| x + d); d = d + 1; } collect(b, \"b\");",
            2,
        );
        // b after loop = bag(7 + 3) = [10]
        assert_eq!(out.collected("b"), &[Value::I64(10)]);
        // Path: entry, (header, body) x3, header, after.
        assert_eq!(out.path_len, 1 + 3 * 2 + 1 + 1);
    }

    #[test]
    fn if_else_selects_branch() {
        let out = run_src(
            "x = 5; y = bag(); if (x > 3) { y = bag(1); } else { y = bag(2); } collect(y, \"y\");",
            2,
        );
        assert_eq!(out.collected("y"), &[Value::I64(1)]);
    }

    #[test]
    fn collect_inside_loop_concatenates_steps() {
        let out = run_src(
            "d = 1; while (d <= 3) { c = bag(0).map(|x| x + d); collect(c, \"c\"); d = d + 1; }",
            3,
        );
        let mut got = out.collected("c").to_vec();
        got.sort();
        assert_eq!(got, vec![Value::I64(1), Value::I64(2), Value::I64(3)]);
        assert_eq!(out.outputs.iter().filter(|(l, _, _)| l == "c").count(), 3);
    }

    #[test]
    fn reduce_by_key_across_workers() {
        let out = run_src(
            "a = bag(1, 2, 1, 3, 2, 1).map(|x| pair(x, 1)); c = a.reduceByKey(|p, q| p + q); collect(c, \"c\");",
            4,
        );
        let mut got = out.collected("c").to_vec();
        got.sort();
        assert_eq!(
            got,
            vec![
                Value::pair(Value::I64(1), Value::I64(3)),
                Value::pair(Value::I64(2), Value::I64(2)),
                Value::pair(Value::I64(3), Value::I64(1)),
            ]
        );
    }

    #[test]
    fn barrier_mode_gives_same_results() {
        let src = "d = 1; s = bag(); while (d <= 4) { s = bag(1, 2).map(|x| x * d); d = d + 1; } collect(s, \"s\");";
        let a = run_src(src, 2);
        let g = crate::compile(&parse_and_lower(src).unwrap()).unwrap();
        let b = run(
            &g,
            &ExecConfig { workers: 2, mode: ExecMode::Barrier, ..Default::default() },
        )
        .unwrap();
        let mut av = a.collected("s").to_vec();
        let mut bv = b.collected("s").to_vec();
        av.sort();
        bv.sort();
        assert_eq!(av, bv);
    }

    #[test]
    fn loop_carried_bag_via_phi() {
        // yesterday-pattern: bag carried across steps.
        let out = run_src(
            "y = bag(0); d = 1; while (d <= 3) { y = y.map(|x| x + 1); d = d + 1; } collect(y, \"y\");",
            2,
        );
        assert_eq!(out.collected("y"), &[Value::I64(3)]);
    }

    #[test]
    fn node_rows_record_emitted_cardinalities() {
        let out = run_src("a = bag(1, 2, 3); b = a.map(|x| x * 10); collect(b, \"b\");", 2);
        let g = crate::compile_source("a = bag(1, 2, 3); b = a.map(|x| x * 10); collect(b, \"b\");")
            .unwrap();
        assert_eq!(out.node_rows.len(), g.num_nodes());
        // Every live node completed at least one bag; the map emitted 3 rows.
        let map = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::Map { .. } | crate::frontend::Rhs::Fused { .. }))
            .unwrap();
        assert_eq!(out.node_rows[map.id].rows, 3);
        assert!(out.node_rows[map.id].bags >= 1);
    }

    #[test]
    fn join_with_loop_invariant_build_side() {
        let out = run_src(
            r#"
            attrs = bag(1, 2, 3).map(|x| pair(x, x * 100));
            d = 1;
            while (d <= 3) {
                v = bag(1, 2, 9).map(|x| pair(x, d));
                j = v.join(attrs);
                t = j.map(|p| fst(snd(p)));
                collect(t, "t");
                d = d + 1;
            }
            "#,
            3,
        );
        // Each step: pages 1,2 match attrs (9 does not) -> build payloads
        // 100 and 200 from the invariant side.
        let got = out.collected("t");
        assert_eq!(got.len(), 6);
        let sum: i64 = got.iter().map(|v| v.as_i64()).sum();
        assert_eq!(sum, 3 * 300);
    }
}
