//! The driver (leader): dispatches worker epochs, relays condition-node
//! decisions as execution-path broadcasts (§6.3.1), tracks completion for
//! barrier mode and termination, and gathers `collect` outputs.
//!
//! Centralizing the path *relay* in the driver (the paper broadcasts from
//! condition nodes directly) keeps the global block order trivially
//! consistent; the cost per decision is one extra hop and remains O(1)
//! per appended block.
//!
//! A job runs as one **epoch** on a [`WorkerPool`]: per-job channels are
//! created here, each pooled thread processes its receiver until the
//! driver's `Shutdown`, and the driver waits for every epoch-done report
//! before returning so the pool is immediately reusable. [`run_plan`] is
//! the one-shot wrapper that spins up a temporary pool (the historical
//! spawn-per-run behavior); `serve::JobService` keeps pools warm across
//! jobs instead.

use super::message::{DriverMsg, WorkerMsg};
use super::plan::ExecPlan;
use super::pool::WorkerPool;
use super::recovery::{EpochCheckpoint, InstanceSnapshot};
use super::{ExecConfig, ExecMode, NodeRows, RunOutput};
use crate::coord::ExecPath;
use crate::error::{Error, Result};
use crate::frontend::{BlockId, Terminator};
use crate::metrics::Metrics;
use rustc_hash::FxHashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Poll interval for the cooperative cancellation token while the driver
/// is blocked in `recv` (only applied when a token is configured): the
/// worst-case latency from `JobTicket::cancel` to the driver noticing on
/// a fully idle channel. Busy epochs notice faster — every worker checks
/// the token between messages and reports `DriverMsg::Canceled`.
const CANCEL_POLL: Duration = Duration::from_millis(10);

/// Execute a physical plan on a temporary pool (one-shot: spawn, run one
/// epoch, join). Kept as the plain-API entry point; repeated jobs should
/// share a [`WorkerPool`] via [`run_plan_on_pool`] (or the `serve::`
/// job service, which also caches compiled plans).
pub fn run_plan(plan: Arc<ExecPlan>, cfg: &ExecConfig) -> Result<RunOutput> {
    let pool = WorkerPool::new(plan.workers);
    run_plan_on_pool(plan, cfg, &pool)
}

/// Execute a physical plan as one epoch of a resident [`WorkerPool`].
/// The plan must have been instantiated for exactly `pool.size()`
/// workers. On return — success, error, or deadline abort — every pool
/// thread has finished the epoch and the pool is ready for the next job.
///
/// With fault injection armed ([`ExecConfig::faults`], e.g. via
/// `LABY_FAULTS`) or checkpointing requested
/// ([`ExecConfig::checkpoint_every`]), the run is routed through
/// [`super::recovery::run_plan_with_recovery`] with the default
/// [`super::recovery::RetryPolicy`], so injected crashes are retried —
/// resuming from the last superstep-boundary checkpoint when one
/// exists. Otherwise this is a single attempt with zero recovery
/// overhead.
pub fn run_plan_on_pool(
    plan: Arc<ExecPlan>,
    cfg: &ExecConfig,
    pool: &WorkerPool,
) -> Result<RunOutput> {
    if cfg.faults.is_some() || cfg.checkpoint_every.is_some() {
        return super::recovery::run_plan_with_recovery(
            plan,
            cfg,
            pool,
            &super::recovery::RetryPolicy::default(),
        );
    }
    run_plan_attempt(plan, cfg, pool, None, None)
}

/// One epoch attempt: the single-shot engine under the recovery layer.
/// `resume` seeds the epoch from a superstep-boundary checkpoint
/// (drivers re-seed the path and re-broadcast the withheld chain,
/// workers restore their instances); `ckpt_sink` receives every
/// checkpoint this attempt takes (cuts only happen when both the sink
/// and [`ExecConfig::checkpoint_every`] are present).
pub(crate) fn run_plan_attempt(
    plan: Arc<ExecPlan>,
    cfg: &ExecConfig,
    pool: &WorkerPool,
    resume: Option<Arc<EpochCheckpoint>>,
    ckpt_sink: Option<&Arc<Mutex<Option<Arc<EpochCheckpoint>>>>>,
) -> Result<RunOutput> {
    if plan.workers != pool.size() {
        return Err(Error::exec(format!(
            "plan instantiated for {} workers but the pool has {}",
            plan.workers,
            pool.size()
        )));
    }
    // Optional scheduler substrate: Labyrinth schedules ONCE per program
    // (vs once per step for the separate-jobs baselines — Fig. 4/5).
    let sched_overhead = match &cfg.sched {
        Some(m) => m.simulate_job_launch(plan.graph.num_nodes(), cfg.workers),
        None => Duration::ZERO,
    };

    let metrics = Arc::new(Metrics::new());
    // Surface the compile-time optimizer summary next to the runtime
    // counters (`opt.*` keys from `opt::optimize`).
    for (k, v) in &plan.graph.opt_summary {
        metrics.add(k, *v);
    }
    metrics.add("exec.hoisted_nodes", plan.hoisted.iter().filter(|&&h| h).count() as u64);
    let start = Instant::now();

    // Tracing: gate-checked ONCE per epoch. A `None` (or switched-off)
    // tracer costs nothing past this point — workers get `trace: None`
    // and every instrument site below is a never-taken branch.
    let tracer = cfg.trace.as_ref().filter(|t| t.on()).cloned();
    let mut dspans = tracer.as_ref().map(|t| {
        let lane = t.lane("driver");
        t.local(lane)
    });
    let trace_lanes: Vec<u32> = tracer
        .as_ref()
        .map(|t| (0..plan.workers).map(|w| t.lane(&format!("worker {w}"))).collect())
        .unwrap_or_default();
    // Epoch span opens here (covers dispatch → teardown done); each
    // control-path append is marked and lowered to `Superstep` spans at
    // epoch end (a superstep lasts until the next append).
    let epoch_t0 = dspans.as_ref().map(|sp| sp.now());
    let mut chain_marks: Vec<(u32, BlockId, u32, u64)> = Vec::new();

    let mut worker_txs: Vec<Sender<WorkerMsg>> = Vec::with_capacity(plan.workers);
    let mut worker_rxs = Vec::with_capacity(plan.workers);
    for _ in 0..plan.workers {
        let (tx, rx) = channel::<WorkerMsg>();
        worker_txs.push(tx);
        worker_rxs.push(rx);
    }
    let (driver_tx, driver_rx) = channel::<DriverMsg>();

    let node_counters: Arc<Vec<super::worker::NodeCounters>> = Arc::new(
        plan.graph.nodes.iter().map(super::worker::NodeCounters::for_node).collect(),
    );
    // Resumed epoch: restore the observed cardinalities captured at the
    // cut BEFORE workers start adding to them, so adaptive feedback
    // sees one epoch's worth of rows rather than a partial recount.
    if let Some(ck) = &resume {
        for (n, r) in ck.node_rows.iter().enumerate() {
            let c = &node_counters[n];
            c.rows.store(r.rows, std::sync::atomic::Ordering::Relaxed);
            c.bags.store(r.bags, std::sync::atomic::Ordering::Relaxed);
            for (s, v) in r.stage_rows.iter().enumerate() {
                if let Some(slot) = c.stage_rows.get(s) {
                    slot.store(*v, std::sync::atomic::Ordering::Relaxed);
                }
            }
            c.self_ns.store(r.self_time_ns, std::sync::atomic::Ordering::Relaxed);
            // `state_size` deliberately stays 0: it is a live gauge, and
            // each restored instance re-reports the full size of its
            // restored store on its first post-resume bag.
        }
    }
    // Bag-completion tracking: barrier mode needs it for its per-step
    // release, checkpointing needs it to find a quiescent cut.
    let track_frontier = cfg.mode == ExecMode::Barrier || cfg.checkpoint_every.is_some();
    let shared = Arc::new(super::worker::WorkerShared {
        plan: plan.clone(),
        workers: worker_txs.clone(),
        driver: driver_tx.clone(),
        batch: cfg.batch,
        reuse: cfg.reuse_state,
        counters: Arc::new(super::worker::EngineCounters::new(&metrics)),
        metrics: metrics.clone(),
        report_bag_done: track_frontier,
        io_dir: cfg.io_dir.clone(),
        registry: cfg.registry.clone(),
        node_counters: node_counters.clone(),
        cancel: cfg.cancel.clone(),
        preamble: cfg.preamble.clone(),
        element_path: cfg.element_path,
        trace: tracer.clone(),
        trace_lanes,
        resume: resume.clone(),
        faults: cfg.faults.clone(),
    });
    if let Some(replay) = cfg.preamble.as_ref().and_then(|p| p.replay.as_ref()) {
        metrics.add("exec.preamble_replay_nodes", replay.len() as u64);
    }

    // Start the epoch on every pooled worker.
    let (done_tx, done_rx) = channel::<usize>();
    for (w, rx) in worker_rxs.into_iter().enumerate() {
        pool.dispatch(w, shared.clone(), rx, done_tx.clone())?;
    }
    drop(done_tx);
    drop(driver_tx);
    if let (Some(sp), Some(t0)) = (dspans.as_mut(), epoch_t0) {
        sp.record(crate::obs::SpanKind::Dispatch, t0);
    }

    // Driver state.
    let graph = &plan.graph;
    let mut path = ExecPath::new(graph.cfg.num_blocks());
    let mut done_at: Vec<usize> = Vec::new(); // completions per path position
    let mut frontier: usize = 0; // positions [0, frontier) fully complete
    let mut pending_decision: Option<(Vec<BlockId>, bool)> = None;
    let mut dones = 0usize;
    let mut done_who: Vec<(usize, usize)> = Vec::new();
    let mut collected: FxHashMap<String, Vec<Value_>> = FxHashMap::default();
    let mut outputs: Vec<(String, u32, Vec<Value_>)> = Vec::new();
    type Value_ = crate::value::Value;

    let chain_is_final = |chain: &[BlockId]| -> bool {
        matches!(
            graph.cfg.program.blocks[*chain.last().expect("empty chain")].term,
            Terminator::End
        )
    };

    let broadcast = |path: &mut ExecPath,
                     done_at: &mut Vec<usize>,
                     blocks: &[BlockId],
                     final_: bool,
                     txs: &[Sender<WorkerMsg>]| {
        let start_pos = path.len() as usize;
        path.append(start_pos, blocks, final_);
        done_at.resize(path.len() as usize, 0);
        for tx in txs {
            let _ = tx.send(WorkerMsg::Append {
                start: start_pos,
                blocks: blocks.to_vec(),
                final_,
            });
        }
    };

    // Driver-loop counters, resolved once: the recv loop bumps these per
    // message, and `Metrics::add`'s name-map lock per event would sit on
    // the decision-relay critical path.
    let d_appends = metrics.handle("driver.appends");
    let d_decisions = metrics.handle("driver.decisions");
    let d_bag_dones = metrics.handle("driver.bag_dones");

    // Kick off: a resumed epoch re-seeds the checkpointed prefix (all
    // of it already complete — workers restored their instances and
    // never re-run prefix bags) and broadcasts the checkpoint's
    // withheld decision chain; a fresh epoch broadcasts the entry
    // chain.
    match &resume {
        Some(ck) => {
            path.append(0, &ck.blocks, false);
            done_at = plan.full_done_at(&path);
            frontier = path.len() as usize;
            for (label, _, items) in &ck.outputs {
                collected.entry(label.clone()).or_default().extend(items.iter().cloned());
            }
            outputs = ck.outputs.clone();
            if let Some(sp) = dspans.as_mut() {
                sp.instant(crate::obs::SpanKind::Recover { pos: path.len() });
            }
            let (chain, final_) = ck.pending.clone();
            if let Some(t) = &tracer {
                chain_marks.push((path.len() + 1, chain[0], chain.len() as u32, t.now_ns()));
            }
            d_appends.add(chain.len() as u64);
            broadcast(&mut path, &mut done_at, &chain, final_, &worker_txs);
        }
        None => {
            let entry = graph.entry_chain.clone();
            let final_ = chain_is_final(&entry);
            if let Some(t) = &tracer {
                chain_marks.push((path.len() + 1, entry[0], entry.len() as u32, t.now_ns()));
            }
            broadcast(&mut path, &mut done_at, &entry, final_, &worker_txs);
            d_appends.add(entry.len() as u64);
        }
    }

    let advance_frontier =
        |frontier: &mut usize, done_at: &[usize], path: &ExecPath, plan: &ExecPlan| {
            while *frontier < done_at.len() {
                let block = path.at((*frontier + 1) as u32);
                if done_at[*frontier] >= plan.insts_per_block[block] {
                    *frontier += 1;
                } else {
                    break;
                }
            }
        };

    // Superstep-boundary checkpointing (`recovery::`): every k-th
    // decision chain is withheld; once all bags of the frozen prefix
    // report done (frontier == path length — a quiescent, message-free
    // cut), every worker snapshots its instances and the assembled
    // checkpoint lands in `ckpt_sink` before the chain is released.
    let checkpointing = ckpt_sink.is_some() && cfg.checkpoint_every.is_some();
    let mut decisions_since_ckpt: u32 = 0;
    let mut pending_ckpt: Option<(Vec<BlockId>, bool)> = None;
    let mut snap_requested = false;
    let mut snaps: Vec<Option<Vec<InstanceSnapshot>>> = vec![None; plan.workers];
    let mut snaps_got = 0usize;
    let mut ckpt_t0: Option<u64> = None;

    let mut error: Option<Error> = None;
    // Stall detection is measured from the last received message, not per
    // recv call: the cancel poll shortens individual recv timeouts far
    // below the stall limit, so a bare recv timeout no longer implies a
    // stall.
    let mut last_msg = Instant::now();
    loop {
        // Cooperative cancel (serve:: JobTicket) and per-job deadlines
        // (serve:: admission queue) bound the wait; a stall past
        // `cfg.stall_timeout` is a coordination bug either way.
        if cfg.cancel.as_ref().map_or(false, |c| c.load(std::sync::atomic::Ordering::SeqCst)) {
            error = Some(Error::Canceled);
            break;
        }
        let now = Instant::now();
        if cfg.deadline.map_or(false, |d| now >= d) {
            error = Some(Error::DeadlineExceeded);
            break;
        }
        let stall_left = cfg.stall_timeout.saturating_sub(now.duration_since(last_msg));
        if stall_left.is_zero() {
            let done_ref = &done_who;
            let stuck: Vec<String> = graph
                .nodes
                .iter()
                .flat_map(|n| {
                    (0..plan.num_insts[n.id]).filter_map(move |i| {
                        if done_ref.contains(&(n.id, i)) {
                            None
                        } else {
                            Some(format!("{}[{i}]", n.name))
                        }
                    })
                })
                .collect();
            error = Some(Error::coord(format!(
                "driver stalled: path len {}, {dones}/{} instances done; stuck: {}",
                path.len(),
                plan.total_instances,
                stuck.join(", ")
            )));
            break;
        }
        let mut timeout = stall_left;
        if let Some(d) = cfg.deadline {
            timeout = timeout.min(d.saturating_duration_since(now));
        }
        if cfg.cancel.is_some() {
            timeout = timeout.min(CANCEL_POLL);
        }
        let msg = match driver_rx.recv_timeout(timeout) {
            Ok(m) => {
                last_msg = Instant::now();
                m
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                continue; // loop head re-checks cancel / deadline / stall
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                error = Some(Error::exec("all workers disconnected"));
                break;
            }
        };
        // A chain that became broadcastable this iteration (decision
        // relay, or a barrier release) funnels through here so the
        // checkpoint cut below can intercept it uniformly.
        let mut ready_chain: Option<(Vec<BlockId>, bool)> = None;
        match msg {
            DriverMsg::Decision { node, bag_len, value } => {
                debug_assert_eq!(
                    bag_len,
                    path.len(),
                    "decision for stale path position (node {node})"
                );
                let spec = graph.nodes[node]
                    .cond
                    .as_ref()
                    .expect("decision from non-condition node");
                let chain =
                    if value { spec.then_chain.clone() } else { spec.else_chain.clone() };
                let final_ = chain_is_final(&chain);
                d_decisions.incr();
                d_appends.add(chain.len() as u64);
                match cfg.mode {
                    ExecMode::Pipelined => ready_chain = Some((chain, final_)),
                    ExecMode::Barrier => {
                        // Withhold until every bag of the current prefix is
                        // complete (per-step synchronization barrier).
                        advance_frontier(&mut frontier, &done_at, &path, &plan);
                        if frontier >= path.len() as usize {
                            ready_chain = Some((chain, final_));
                        } else {
                            pending_decision = Some((chain, final_));
                        }
                    }
                }
            }
            DriverMsg::BagDone { node: _, inst: _, bag_len } => {
                let idx = (bag_len - 1) as usize;
                done_at[idx] += 1;
                d_bag_dones.incr();
                if cfg.mode == ExecMode::Barrier {
                    advance_frontier(&mut frontier, &done_at, &path, &plan);
                    if frontier >= path.len() as usize {
                        if let Some(pd) = pending_decision.take() {
                            ready_chain = Some(pd);
                        }
                    }
                }
            }
            DriverMsg::Snapshot { worker, insts } => {
                debug_assert!(snap_requested, "unsolicited snapshot from worker {worker}");
                if snaps[worker].is_none() {
                    snaps_got += 1;
                }
                snaps[worker] = Some(insts);
                if snaps_got == plan.workers {
                    let (chain, final_) =
                        pending_ckpt.take().expect("snapshot without a pending checkpoint");
                    let ck = EpochCheckpoint {
                        blocks: path.blocks().to_vec(),
                        pending: (chain.clone(), final_),
                        outputs: outputs.clone(),
                        node_rows: load_node_rows(&node_counters),
                        insts: snaps.iter_mut().filter_map(|s| s.take()).flatten().collect(),
                    };
                    if let Some(sink) = ckpt_sink {
                        *sink.lock().unwrap() = Some(Arc::new(ck));
                    }
                    metrics.add("exec.checkpoints_taken", 1);
                    if let (Some(sp), Some(t0)) = (dspans.as_mut(), ckpt_t0.take()) {
                        sp.record(crate::obs::SpanKind::Checkpoint { pos: path.len() }, t0);
                    }
                    snaps_got = 0;
                    snap_requested = false;
                    // Release the withheld chain: the epoch continues
                    // exactly where it paused.
                    if let Some(t) = &tracer {
                        chain_marks.push((
                            path.len() + 1,
                            chain[0],
                            chain.len() as u32,
                            t.now_ns(),
                        ));
                    }
                    broadcast(&mut path, &mut done_at, &chain, final_, &worker_txs);
                }
            }
            DriverMsg::Output { label, bag_len, items } => {
                collected.entry(label.clone()).or_default().extend(items.iter().cloned());
                outputs.push((label, bag_len, items));
            }
            DriverMsg::Done { node, inst } => {
                done_who.push((node, inst));
                dones += 1;
                if dones >= plan.total_instances {
                    break;
                }
            }
            DriverMsg::Panic { msg } => {
                error = Some(Error::exec(msg));
                break;
            }
            DriverMsg::Canceled { worker: _ } => {
                // A worker saw the token before the driver's own poll; it
                // is already draining. Abort and tear the epoch down.
                error = Some(Error::Canceled);
                break;
            }
        }

        // Relay (or withhold) the chain that became ready this iteration.
        // A checkpoint cut never targets a final chain: the epoch is about
        // to finish, so snapshotting it buys nothing.
        if let Some((chain, final_)) = ready_chain {
            decisions_since_ckpt += 1;
            let cut = checkpointing
                && !final_
                && pending_ckpt.is_none()
                && cfg.checkpoint_every.map_or(false, |k| decisions_since_ckpt >= k);
            if cut {
                decisions_since_ckpt = 0;
                ckpt_t0 = dspans.as_ref().map(|sp| sp.now());
                pending_ckpt = Some((chain, final_));
            } else {
                if let Some(t) = &tracer {
                    chain_marks.push((
                        path.len() + 1,
                        chain[0],
                        chain.len() as u32,
                        t.now_ns(),
                    ));
                }
                broadcast(&mut path, &mut done_at, &chain, final_, &worker_txs);
            }
        }

        // With the chain withheld the path is frozen, so the prefix
        // drains to quiescence: once the frontier covers the whole path
        // every instance is idle and the cut is consistent. Request the
        // snapshots exactly once per cut.
        if pending_ckpt.is_some() && !snap_requested {
            advance_frontier(&mut frontier, &done_at, &path, &plan);
            if frontier >= path.len() as usize {
                snap_requested = true;
                for tx in &worker_txs {
                    let _ = tx.send(WorkerMsg::Checkpoint);
                }
            }
        }
    }

    // End the epoch: workers drain their queues, see Shutdown, and report
    // done to the pool. Waiting for every report keeps the pool reusable
    // (the next job must not race a straggler from this one). This runs
    // on EVERY exit — success, deadline, stall, panic, or cancel — so an
    // aborted epoch can never poison the pool for the next job.
    let drain_t0 = dspans.as_ref().map(|sp| sp.now());
    for tx in &worker_txs {
        let _ = tx.send(WorkerMsg::Shutdown);
    }
    for _ in 0..pool.size() {
        let _ = done_rx.recv();
    }

    // Close out the driver lane (workers absorbed their own rings on
    // shutdown): drain span, the superstep spans derived from the chain
    // marks, and the whole-epoch span. Runs on error exits too, so a
    // canceled or deadlined epoch still leaves a coherent trace.
    if let Some(sp) = dspans.as_mut() {
        if let Some(t0) = drain_t0 {
            sp.record(crate::obs::SpanKind::Drain, t0);
        }
        let end = sp.now();
        for (i, &(pos, block, blocks, ts)) in chain_marks.iter().enumerate() {
            let until = chain_marks.get(i + 1).map_or(end, |m| m.3);
            sp.record_span(
                crate::obs::SpanKind::Superstep { pos, block, blocks },
                ts,
                until.saturating_sub(ts),
            );
        }
        if let Some(t0) = epoch_t0 {
            sp.record_span(crate::obs::SpanKind::Epoch, t0, end.saturating_sub(t0));
        }
    }
    if let (Some(t), Some(sp)) = (tracer.as_ref(), dspans) {
        t.absorb(sp);
    }

    if let Some(e) = error {
        return Err(e);
    }

    // Recovery accounting (checked by the chaos tests): a resumed epoch
    // skipped `supersteps_recovered` positions and only executed the
    // remainder.
    if let Some(ck) = &resume {
        metrics.add("exec.supersteps_recovered", ck.blocks.len() as u64);
        metrics.add(
            "exec.supersteps_replayed",
            path.len() as u64 - ck.blocks.len() as u64,
        );
    }

    let node_rows = load_node_rows(&node_counters);

    Ok(RunOutput {
        collected,
        outputs,
        elapsed: start.elapsed(),
        sched_overhead,
        metrics,
        path_len: path.len() as usize,
        node_rows,
    })
}

/// Materialize the per-node counters into plain [`NodeRows`] — used both
/// for the final [`RunOutput`] and for embedding live totals into an
/// [`EpochCheckpoint`] (a resumed attempt re-seeds its counters from them
/// so per-node stats stay cumulative across the fault).
fn load_node_rows(counters: &[super::worker::NodeCounters]) -> Vec<NodeRows> {
    counters
        .iter()
        .map(|c| NodeRows {
            rows: c.rows.load(std::sync::atomic::Ordering::Relaxed),
            bags: c.bags.load(std::sync::atomic::Ordering::Relaxed),
            stage_rows: c
                .stage_rows
                .iter()
                .map(|s| s.load(std::sync::atomic::Ordering::Relaxed))
                .collect(),
            self_time_ns: c.self_ns.load(std::sync::atomic::Ordering::Relaxed),
            state_size: c.state_size.load(std::sync::atomic::Ordering::Relaxed),
        })
        .collect()
}
