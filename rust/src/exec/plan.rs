//! Physical execution plan: the logical dataflow graph annotated with
//! instance counts, output-edge metadata, and §6.3 coordination constants,
//! shared read-only by all workers.

use crate::dataflow::{DataflowGraph, NodeId, Par, Route};
use crate::frontend::{BlockId, Rhs};
use std::sync::Arc;

/// One output edge of a node, precomputed for the send path.
#[derive(Clone, Debug)]
pub struct OutEdgeMeta {
    /// Consumer node.
    pub dst_node: NodeId,
    /// Consumer's logical input index.
    pub dst_input: usize,
    /// Consumer's instance count.
    pub dst_insts: usize,
    /// Element routing.
    pub route: Route,
    /// Cross-block edge (conditional output, §6.3.4)?
    pub conditional: bool,
    /// Consumer's block (b2).
    pub target_block: BlockId,
    /// §6.3.4 blockers: producer's block, plus sibling-input blocks when
    /// the consumer is a Φ.
    pub blockers: Vec<BlockId>,
    /// The producer is a delta-mode Φ and this edge leaves its loop: the
    /// consumer must receive the materialized solution set, not the
    /// per-superstep delta the Φ circulates in-loop (see `ops::delta`).
    pub wants_full: bool,
}

/// One input edge of a node, precomputed for the receive path.
#[derive(Clone, Debug)]
pub struct InEdgeMeta {
    /// Producer node.
    pub src_node: NodeId,
    /// Producer's block (b1 of §6.3.3).
    pub src_block: BlockId,
    /// Producer's instance count.
    pub src_insts: usize,
    /// Element routing.
    pub route: Route,
    /// Number of `Close` markers that complete one bag partition.
    pub expected_closes: usize,
    /// Blocks whose recurrence supersedes a buffered bag on this edge
    /// (consumer-side GC, §6.3.3): the producer's block, plus sibling
    /// input blocks when this node is a Φ.
    pub supersede_blocks: Vec<BlockId>,
    /// The producer's block is outside every loop (and this consumer is
    /// not a Φ): at most ONE bag ever travels this edge, it is never
    /// superseded, and the consumer pins its buffer until the path is
    /// final. `opt::hoist` manufactures these edges; the engine skips the
    /// §6.3.3 GC scan for them (see `Instance::gc_inputs`).
    pub invariant: bool,
}

/// The physical plan.
pub struct ExecPlan {
    /// Logical graph.
    pub graph: Arc<DataflowGraph>,
    /// Worker count the plan was instantiated for.
    pub workers: usize,
    /// Physical instances per node.
    pub num_insts: Vec<usize>,
    /// Output edges per node.
    pub out_edges: Vec<Vec<OutEdgeMeta>>,
    /// Input edges per node (parallel to `node.inputs`).
    pub in_edges: Vec<Vec<InEdgeMeta>>,
    /// Total physical instances (driver's Done target).
    pub total_instances: usize,
    /// Per block: total instances of nodes in that block (barrier mode).
    pub insts_per_block: Vec<usize>,
    /// Per node: was it moved into a loop preamble by `opt::hoist`?
    /// (Scheduled before the loop's first step via its preamble block's
    /// position in the execution path.)
    pub hoisted: Vec<bool>,
    /// Per node: which logical input is the hash-join build side (0 for
    /// non-joins and unannotated joins; 1 when `opt::joinside` flipped
    /// it). `Instance::new` hands this to `ops::join::HashJoinT`.
    pub join_build: Vec<usize>,
    /// Per node: is its (single, depth-0 preamble) output bag fully
    /// determined by the template plus its named-source bindings, so the
    /// `serve::` service may replay a previous epoch's materialized bag
    /// instead of recomputing it? See
    /// [`crate::opt::analysis::binding_determined_preamble`].
    pub shareable: Vec<bool>,
    /// Named-source names the shareable closure reads (sorted, deduped) —
    /// the inputs a preamble binding signature must cover.
    pub shareable_sources: Vec<String>,
    /// Per node: inferred output element type (`opt::types::infer`) —
    /// the type every out-edge of the node carries. `Dyn` when the
    /// optimizer did not run or inference gave up. `Instance::new` reads
    /// this (together with `graph.columnar`) to install monomorphic
    /// columnar kernels; a wrong entry costs the fast path, never
    /// correctness (kernels re-verify batch layouts at runtime).
    pub edge_types: Vec<crate::value::ElemType>,
}

impl ExecPlan {
    /// Build the plan for `workers` workers.
    pub fn new(graph: Arc<DataflowGraph>, workers: usize) -> ExecPlan {
        let workers = workers.max(1);
        let num_insts: Vec<usize> = graph
            .nodes
            .iter()
            .map(|n| match n.par {
                Par::One => 1,
                Par::All => workers,
            })
            .collect();

        // Loop depth per block: an edge whose producer block sits outside
        // every loop carries at most one bag for the whole run.
        let loop_depth = {
            let dt = crate::cfg::dom::dominators(&graph.cfg);
            crate::cfg::loops::find_loops(&graph.cfg, &dt).depth
        };

        let mut out_edges: Vec<Vec<OutEdgeMeta>> = vec![Vec::new(); graph.nodes.len()];
        let mut in_edges: Vec<Vec<InEdgeMeta>> = vec![Vec::new(); graph.nodes.len()];
        for node in &graph.nodes {
            let is_phi = matches!(node.op, Rhs::Phi(_));
            for (i, inp) in node.inputs.iter().enumerate() {
                let mut blockers = vec![node.inputs[i].src_block];
                let mut supersede = vec![inp.src_block];
                if is_phi {
                    for s in graph.phi_sibling_blocks(node.id, i) {
                        blockers.push(s);
                        supersede.push(s);
                    }
                }
                // Producer's own block is always a §6.3.4 blocker: a newer
                // bag supersedes. (It is blockers[0] == src_block already.)
                let wants_full = graph.nodes[inp.src]
                    .delta
                    .as_ref()
                    .is_some_and(|d| d.is_phi() && !d.in_loop(node.block));
                out_edges[inp.src].push(OutEdgeMeta {
                    dst_node: node.id,
                    dst_input: i,
                    dst_insts: num_insts[node.id],
                    route: inp.route,
                    conditional: inp.conditional,
                    target_block: node.block,
                    blockers,
                    wants_full,
                });
                let expected_closes = match inp.route {
                    Route::Forward => 1,
                    _ => num_insts[inp.src],
                };
                in_edges[node.id].push(InEdgeMeta {
                    src_node: inp.src,
                    src_block: inp.src_block,
                    src_insts: num_insts[inp.src],
                    route: inp.route,
                    expected_closes,
                    supersede_blocks: supersede,
                    invariant: loop_depth[inp.src_block] == 0 && !is_phi,
                });
            }
        }

        let total_instances = num_insts.iter().sum();
        let mut insts_per_block = vec![0usize; graph.cfg.num_blocks()];
        for n in &graph.nodes {
            insts_per_block[n.block] += num_insts[n.id];
        }

        let hoisted: Vec<bool> = graph.nodes.iter().map(|n| n.hoisted_from.is_some()).collect();
        let shareable = crate::opt::analysis::binding_determined_preamble(&graph, &loop_depth);
        let shareable_sources = crate::opt::analysis::preamble_source_names(&graph, &shareable);
        let join_build = graph
            .nodes
            .iter()
            .map(|n| match n.op {
                Rhs::Join { .. } => n.build_side.unwrap_or(0),
                _ => 0,
            })
            .collect();
        let edge_types = (0..graph.nodes.len()).map(|i| graph.elem_type(i)).collect();
        ExecPlan {
            graph,
            workers,
            num_insts,
            out_edges,
            in_edges,
            total_instances,
            insts_per_block,
            hoisted,
            join_build,
            shareable,
            shareable_sources,
            edge_types,
        }
    }

    /// A `done_at` vector marking every position of `path` fully
    /// complete — used when resuming from a superstep-boundary
    /// checkpoint, whose prefix bags were all finished at the cut.
    pub fn full_done_at(&self, path: &crate::coord::ExecPath) -> Vec<usize> {
        (1..=path.len()).map(|p| self.insts_per_block[path.at(p)]).collect()
    }

    /// Which worker hosts instance `inst` of `node`.
    pub fn worker_of(&self, node: NodeId, inst: usize) -> usize {
        if self.num_insts[node] == 1 {
            0
        } else {
            inst
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    fn plan(src: &str, workers: usize) -> ExecPlan {
        let g = crate::compile(&parse_and_lower(src).unwrap()).unwrap();
        ExecPlan::new(Arc::new(g), workers)
    }

    #[test]
    fn instance_counts_respect_parallelism() {
        let p = plan(
            "a = bag(1, 2, 3).map(|x| pair(x, 1)); b = a.reduceByKey(|x, y| x + y); n = b.count(); writeFile(b, \"o\" + str(n));",
            4,
        );
        // map & reduceByKey: 4 instances; count/collect sinks: 1.
        let g = &p.graph;
        for n in &g.nodes {
            match &n.op {
                // Lifted-scalar maps are singletons (Par::One).
                Rhs::Map { .. } if !n.singleton => {
                    assert_eq!(p.num_insts[n.id], 4, "{}", n.name)
                }
                Rhs::ReduceByKey { .. } => {
                    assert_eq!(p.num_insts[n.id], 4, "{}", n.name)
                }
                Rhs::BagLit(items) if items.len() > 1 => {
                    assert_eq!(p.num_insts[n.id], 4, "{}", n.name)
                }
                Rhs::Count { .. } | Rhs::Collect { .. } => {
                    assert_eq!(p.num_insts[n.id], 1, "{}", n.name)
                }
                _ => {}
            }
        }
        assert_eq!(p.total_instances, p.num_insts.iter().sum::<usize>());
    }

    #[test]
    fn forward_edges_expect_one_close() {
        let p = plan("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"o\");", 3);
        let g = &p.graph;
        let map = g.nodes.iter().find(|n| matches!(n.op, Rhs::Map { .. })).unwrap();
        let ie = &p.in_edges[map.id][0];
        assert_eq!(ie.route, Route::Forward);
        assert_eq!(ie.expected_closes, 1);
        // collect gathers from 3 map instances.
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        let ce = &p.in_edges[col.id][0];
        assert_eq!(ce.route, Route::Gather);
        assert_eq!(ce.expected_closes, 3);
    }

    #[test]
    fn phi_edges_carry_sibling_blockers() {
        let p = plan("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");", 2);
        let g = &p.graph;
        let phi = g.nodes.iter().find(|n| matches!(n.op, Rhs::Phi(_))).unwrap();
        for ie in &p.in_edges[phi.id] {
            assert_eq!(ie.supersede_blocks.len(), 2, "own block + sibling");
        }
        // The producers' out-edges to the phi carry both blockers too.
        let mut phi_edges = 0;
        for n in &g.nodes {
            for oe in &p.out_edges[n.id] {
                if oe.dst_node == phi.id {
                    phi_edges += 1;
                    assert_eq!(oe.blockers.len(), 2);
                    assert!(oe.conditional);
                }
            }
        }
        assert_eq!(phi_edges, 2);
    }

    #[test]
    fn hoisted_plan_marks_invariant_edges() {
        // compile() runs the optimizer: the invariant bag+map chain is
        // hoisted into the loop preamble, so the collect inside the loop
        // reads over a pinned invariant edge.
        let p = plan(
            "d = 1; while (d <= 3) { v = bag(1, 2).map(|x| x * 10); collect(v, \"v\"); d = d + 1; }",
            2,
        );
        assert!(p.hoisted.iter().any(|&h| h), "optimizer hoisted the invariant chain");
        let g = &p.graph;
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        assert!(p.in_edges[col.id][0].invariant, "collect reads a preamble bag");
        // Φ edges are never invariant (their buffers turn over per step).
        let phi = g.nodes.iter().find(|n| matches!(n.op, Rhs::Phi(_))).unwrap();
        for e in &p.in_edges[phi.id] {
            assert!(!e.invariant);
        }
    }

    #[test]
    fn shareable_marks_binding_determined_preamble_nodes() {
        crate::workload::registry::global()
            .put("plan_share_src", vec![crate::value::Value::I64(3), crate::value::Value::I64(4)]);
        let p = plan(
            "d = 1; while (d <= 3) { v = source(\"plan_share_src\").map(|x| x * 2); collect(v, \"v\"); d = d + 1; }",
            2,
        );
        crate::workload::registry::global().clear_prefix("plan_share_src");
        let g = &p.graph;
        let src = g.nodes.iter().find(|n| matches!(n.op, Rhs::NamedSource(_))).unwrap();
        assert!(p.shareable[src.id], "hoisted source is shareable");
        assert_eq!(p.shareable_sources, vec!["plan_share_src".to_string()]);
        // The in-loop collect, the Φ, and the condition node never share.
        for n in &g.nodes {
            if matches!(n.op, Rhs::Phi(_) | Rhs::Collect { .. }) || n.cond.is_some() {
                assert!(!p.shareable[n.id], "{} must not be shareable", n.name);
            }
        }
    }

    #[test]
    fn worker_of_pins_singletons_to_zero() {
        let p = plan("a = bag(1, 2); n = a.count(); writeFile(a, \"o\" + str(n));", 4);
        let g = &p.graph;
        let cnt = g.nodes.iter().find(|n| matches!(n.op, Rhs::Count { .. })).unwrap();
        assert_eq!(p.worker_of(cnt.id, 0), 0);
        let src = g.nodes.iter().find(|n| matches!(n.op, Rhs::BagLit(_))).unwrap();
        assert_eq!(p.worker_of(src.id, 3), 3);
    }
}
