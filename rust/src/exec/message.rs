//! Messages of the simulated cluster: worker-bound data/control and
//! driver-bound coordination reports.

use super::recovery::InstanceSnapshot;
use crate::dataflow::NodeId;
use crate::frontend::BlockId;
use crate::value::Value;

/// Messages delivered to worker threads.
#[derive(Debug)]
pub enum WorkerMsg {
    /// A batch of elements of one input bag partition, optionally also
    /// carrying this producer instance's close marker (piggybacked to
    /// halve hot-path message count — see EXPERIMENTS.md §Perf).
    Data {
        /// Target logical node.
        node: NodeId,
        /// Target logical input index.
        input: usize,
        /// Target physical instance.
        dst_inst: usize,
        /// Bag id: length of the execution-path prefix at creation.
        bag_len: u32,
        /// The elements.
        items: Box<[Value]>,
        /// True: this batch is the producer instance's last for the bag.
        close: bool,
    },
    /// One producer instance finished its partition of one input bag.
    Close {
        /// Target logical node.
        node: NodeId,
        /// Target logical input index.
        input: usize,
        /// Target physical instance.
        dst_inst: usize,
        /// Bag id (path-prefix length).
        bag_len: u32,
    },
    /// Execution-path extension broadcast (§6.3.1), relayed by the driver.
    Append {
        /// 0-based start position of `blocks` within the global path.
        start: usize,
        /// The appended chain.
        blocks: Vec<BlockId>,
        /// True when the chain ends at a terminal block.
        final_: bool,
    },
    /// Snapshot request at a superstep-boundary checkpoint cut: the
    /// driver has verified every bag of the current path prefix is
    /// complete (all instances quiescent), so the worker replies with a
    /// [`DriverMsg::Snapshot`] of every instance it hosts.
    Checkpoint,
    /// Stop the worker loop.
    Shutdown,
}

/// Messages delivered to the driver.
#[derive(Debug)]
pub enum DriverMsg {
    /// A condition node evaluated its singleton boolean bag (§5.3).
    Decision {
        /// The condition node.
        node: NodeId,
        /// Bag id — must equal the current path length.
        bag_len: u32,
        /// The boolean.
        value: bool,
    },
    /// An instance completed one output bag (barrier mode + metrics).
    BagDone {
        /// Node.
        node: NodeId,
        /// Instance.
        inst: usize,
        /// Bag id.
        bag_len: u32,
    },
    /// A `collect` sink delivered a bag to the driver.
    Output {
        /// Collect label.
        label: String,
        /// Bag id.
        bag_len: u32,
        /// Elements.
        items: Vec<Value>,
    },
    /// An instance has finished all work (path final, no pending bags).
    Done {
        /// Node.
        node: NodeId,
        /// Instance.
        inst: usize,
    },
    /// Reply to [`WorkerMsg::Checkpoint`]: the state of every instance
    /// this worker hosts, captured at the quiescent cut.
    Snapshot {
        /// Reporting worker id.
        worker: usize,
        /// One snapshot per hosted instance.
        insts: Vec<InstanceSnapshot>,
    },
    /// A worker thread panicked.
    Panic {
        /// Panic payload rendered to a string.
        msg: String,
    },
    /// A worker observed the epoch's cancellation token set
    /// (`ExecConfig::cancel`). The reporting worker keeps draining its
    /// queue without processing further work; the driver aborts the run
    /// and tears the epoch down cleanly. Sent at most once per worker.
    Canceled {
        /// Reporting worker id.
        worker: usize,
    },
}
