//! Crash-safe epochs: superstep-boundary checkpointing + bounded retry,
//! plus first-class **deterministic fault injection**.
//!
//! A compiled Labyrinth program runs as ONE cyclic dataflow job — which
//! also makes it one failure domain: without recovery, a single worker
//! panic throws away every completed superstep (the trade-off *Spinning
//! Fast Iterative Data Flows* resolves with iteration-boundary
//! recovery). This module adds exactly that recovery shape, at the
//! natural granularity the paper's single-job loop structure provides:
//! the **superstep boundary**.
//!
//! ## Checkpointing
//!
//! With [`super::ExecConfig::checkpoint_every`] = `Some(k)`, the driver
//! withholds every k-th control-flow decision until all bags of the
//! current path prefix are complete (the same frontier tracking barrier
//! mode uses), asks every worker for an [`InstanceSnapshot`] of each
//! hosted instance, and assembles an [`EpochCheckpoint`]: the execution
//! path prefix, the withheld decision chain (the lifted scalar control
//! state — Φ values live in the dataflow and are covered by the
//! instance snapshots), collected outputs so far, observed node
//! cardinalities, and per-instance operator state (input-bag buffers
//! backing hash-join builds / reduceByKey partials, plus §6.3.4
//! retained conditional outputs). The cut is consistent by
//! construction: every instance is quiescent (no open output bag, no
//! staged or buffered emissions) and no worker-to-worker message is in
//! flight once every bag of the prefix has reported done.
//!
//! ## Retry
//!
//! [`run_plan_with_recovery`] wraps `driver::run_plan_attempt` in a
//! bounded retry loop: a retryable failure (worker panic →
//! [`Error::Exec`], stall → [`Error::Coordination`]) re-runs the epoch,
//! resuming from the latest checkpoint when one exists (workers restore
//! their instances, the driver re-seeds the path and re-broadcasts the
//! withheld chain) or from scratch otherwise. The original
//! [`super::ExecConfig::deadline`] keeps being enforced *across*
//! attempts, and typed aborts ([`Error::Canceled`],
//! [`Error::DeadlineExceeded`]) are never retried.
//!
//! ## Fault injection
//!
//! [`FaultPlan`] is a deterministic, seeded schedule of worker-panic /
//! slow-worker / message-drop events keyed to `(worker, superstep)`,
//! threaded through `exec::pool`/`worker`/`driver` via
//! [`super::ExecConfig::faults`] — zero-cost when unset (one `Option`
//! branch per path append). `LABY_FAULTS=<seed>` arms a seeded plan
//! process-wide (see [`super::default_faults`]), which is how CI's
//! chaos-smoke leg runs the whole tier-1 suite under injected panics.

use super::plan::ExecPlan;
use super::pool::WorkerPool;
use super::{ExecConfig, NodeRows, RunOutput};
use crate::dataflow::NodeId;
use crate::error::{Error, Result};
use crate::frontend::BlockId;
use crate::util::rng::Rng;
use crate::value::Value;
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One injected fault event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker thread panics (caught by the pool, surfaced as
    /// [`Error::Exec`] — the retryable crash class).
    Panic,
    /// The worker sleeps for the given duration before processing the
    /// superstep (straggler simulation).
    Slow(Duration),
    /// The worker silently drops its next `Data` message (consumer
    /// starves → driver stall timeout → retryable
    /// [`Error::Coordination`]). Pair with a short
    /// [`super::ExecConfig::stall_timeout`] in tests.
    DropData,
}

/// Cap on how many faults a *seeded* plan fires over its lifetime
/// (explicit [`FaultPlan::panic_at`]-style events are uncapped, but
/// one-shot each). Two fires + the default two retries means the final
/// attempt of a default-policy run is always clean — so arming
/// `LABY_FAULTS` over the whole test suite perturbs every epoch without
/// ever exhausting the retry budget by itself.
const SEEDED_CAP: u32 = 2;

/// Seeded-plan fire rate: one in `SEEDED_ONE_IN` `(worker, superstep)`
/// coordinates draws a panic.
const SEEDED_ONE_IN: u64 = 8;

#[derive(Debug, Default)]
struct Fired {
    /// Coordinates that already fired (every event is one-shot, so a
    /// retried epoch does not hit the same fault forever).
    set: FxHashSet<(usize, u32)>,
    /// Seeded fires so far (bounded by [`SEEDED_CAP`]).
    seeded: u32,
}

/// A deterministic schedule of fault-injection events keyed to
/// `(worker, superstep)`. Explicit events ([`FaultPlan::panic_at`],
/// [`FaultPlan::slow_at`], [`FaultPlan::drop_at`]) fire exactly once
/// each; a seeded plan ([`FaultPlan::seeded`]) additionally draws
/// pseudo-random panics from the seed — reproducibly, since the draw is
/// a pure function of `(seed, worker, superstep)`. Share one plan
/// across the attempts of a run (an `Arc` in
/// [`super::ExecConfig::faults`]) so retries move *past* injected
/// faults instead of replaying them.
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: FxHashMap<(usize, u32), FaultKind>,
    seed: Option<u64>,
    fired: Mutex<Fired>,
}

impl FaultPlan {
    /// Empty plan: the fault-injection gate is present but never fires.
    /// (The bench-throughput `checkpoint_gate_overhead` series measures
    /// exactly this configuration against no plan at all.)
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a one-shot worker panic at a 1-based superstep.
    pub fn panic_at(mut self, worker: usize, superstep: u32) -> FaultPlan {
        self.events.insert((worker, superstep), FaultKind::Panic);
        self
    }

    /// Add a one-shot slow-worker stall at a 1-based superstep.
    pub fn slow_at(mut self, worker: usize, superstep: u32, delay: Duration) -> FaultPlan {
        self.events.insert((worker, superstep), FaultKind::Slow(delay));
        self
    }

    /// Add a one-shot dropped `Data` message: the worker discards the
    /// next data batch it receives after reaching the superstep.
    pub fn drop_at(mut self, worker: usize, superstep: u32) -> FaultPlan {
        self.events.insert((worker, superstep), FaultKind::DropData);
        self
    }

    /// Seeded plan: pseudo-random panics (about one per
    /// [`SEEDED_ONE_IN`] `(worker, superstep)` coordinates, at most
    /// [`SEEDED_CAP`] total) drawn deterministically from `seed`.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed: Some(seed), ..FaultPlan::default() }
    }

    /// True when the plan can never fire (no events, no seed).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.seed.is_none()
    }

    /// Total events fired over the plan's lifetime (cumulative across
    /// the retry attempts sharing it — each attempt's own metrics die
    /// with the attempt, so [`run_plan_with_recovery`] stamps this onto
    /// the surviving output as `exec.faults_injected`).
    pub fn fired_count(&self) -> u64 {
        self.fired.lock().unwrap().set.len() as u64
    }

    /// Consult the plan for `(worker, superstep)` — called by the
    /// worker loop at each path append. Each coordinate fires at most
    /// once over the plan's lifetime.
    pub(crate) fn check(&self, worker: usize, superstep: u32) -> Option<FaultKind> {
        if self.is_empty() {
            return None;
        }
        let key = (worker, superstep);
        if let Some(&kind) = self.events.get(&key) {
            let mut fired = self.fired.lock().unwrap();
            if fired.set.insert(key) {
                return Some(kind);
            }
            return None;
        }
        if let Some(seed) = self.seed {
            // Pure function of (seed, worker, superstep): mix the
            // coordinates into an independent stream and draw once.
            let mut rng = Rng::new(
                seed ^ (worker as u64).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (superstep as u64).rotate_left(32),
            );
            if rng.gen_range(SEEDED_ONE_IN) == 0 {
                let mut fired = self.fired.lock().unwrap();
                if fired.seeded < SEEDED_CAP && fired.set.insert(key) {
                    fired.seeded += 1;
                    return Some(FaultKind::Panic);
                }
            }
        }
        None
    }
}

/// Retry policy for [`run_plan_with_recovery`]: how many times a
/// retryable epoch failure is re-attempted (so a run makes at most
/// `max_retries + 1` attempts).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (default 2).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 2 }
    }
}

/// State of one physical operator instance at a checkpoint cut, taken
/// while the instance is quiescent (no open output bag, nothing
/// staged or buffered for send). What IS captured: input-bag buffers —
/// including the bags backing §7 reused state (hash-join builds,
/// reduceByKey partials rebuild from them on restore) — and §6.3.4
/// retained conditional-output bags with their watcher send flags.
/// What is NOT: transformation-internal state rebuildable by re-feeding
/// the buffered bags, and anything derivable from the path replica.
/// The exception is `op_state`: delta-incremental solution sets
/// (`ops::state`) accumulate across supersteps from deltas the GC
/// discarded long ago, so they checkpoint as first-class state.
#[derive(Clone, Debug)]
pub struct InstanceSnapshot {
    /// Logical node.
    pub node: NodeId,
    /// Physical instance index.
    pub inst: usize,
    /// Per logical input: buffered bags as `(bag_id, items, closes)`,
    /// sorted by bag id for determinism.
    pub bufs: Vec<Vec<(u32, Vec<Value>, usize)>>,
    /// Retained conditional-output bags as
    /// `(bag_id, items, [(out_edge_idx, sent)])`, sorted by bag id.
    /// Watchers are rebuilt against the restored path on resume.
    pub retained: Vec<(u32, Vec<Value>, Vec<(usize, bool)>)>,
    /// Delta-incremental operator state (solution set / retained
    /// accumulator), canonically sorted; `None` for non-delta
    /// transforms.
    pub op_state: Option<crate::ops::state::StateSnapshot>,
}

/// A completed superstep-boundary checkpoint: everything a fresh epoch
/// needs to resume as if the prefix had just executed.
#[derive(Clone, Debug)]
pub struct EpochCheckpoint {
    /// The execution-path prefix (all blocks appended so far).
    pub blocks: Vec<BlockId>,
    /// The withheld decision chain `(blocks, final)` — broadcast on
    /// resume instead of the entry chain. Never final: final chains are
    /// not worth checkpointing (the epoch is about to end).
    pub pending: (Vec<BlockId>, bool),
    /// `collect` bags delivered to the driver so far, as
    /// `(label, bag_id, items)` in completion order.
    pub outputs: Vec<(String, u32, Vec<Value>)>,
    /// Observed per-node output cardinalities at the cut (restored into
    /// the resumed epoch's counters so adaptive feedback sees one
    /// epoch's worth of rows, not a partial double-count).
    pub node_rows: Vec<NodeRows>,
    /// Every instance's snapshot (all workers).
    pub insts: Vec<InstanceSnapshot>,
}

/// Execute a plan with bounded retry and (when
/// [`ExecConfig::checkpoint_every`] is set) superstep-boundary
/// checkpointing. Retryable failures — worker panics
/// ([`Error::Exec`]) and coordination stalls ([`Error::Coordination`])
/// — re-run the epoch, resuming from the latest checkpoint if one was
/// taken; cancellation and deadline aborts are surfaced immediately,
/// and the deadline keeps being enforced across attempts. On success
/// the returned metrics carry `exec.epoch_retries` (attempts beyond
/// the first), and resumed runs additionally report
/// `exec.supersteps_recovered` / `exec.supersteps_replayed`.
pub fn run_plan_with_recovery(
    plan: Arc<ExecPlan>,
    cfg: &ExecConfig,
    pool: &WorkerPool,
    policy: &RetryPolicy,
) -> Result<RunOutput> {
    let sink: Arc<Mutex<Option<Arc<EpochCheckpoint>>>> = Arc::new(Mutex::new(None));
    let mut attempts: u32 = 0;
    loop {
        let resume = sink.lock().unwrap().clone();
        match super::driver::run_plan_attempt(plan.clone(), cfg, pool, resume, Some(&sink)) {
            Ok(out) => {
                if attempts > 0 {
                    out.metrics.add("exec.epoch_retries", attempts as u64);
                }
                // Fired events accumulate on the plan, not on any one
                // attempt's metrics (failed attempts drop theirs).
                if let Some(fp) = &cfg.faults {
                    let fired = fp.fired_count();
                    if fired > 0 {
                        out.metrics.add("exec.faults_injected", fired);
                    }
                }
                return Ok(out);
            }
            Err(e) => {
                let retryable = matches!(e, Error::Exec(_) | Error::Coordination(_));
                if !retryable || attempts >= policy.max_retries {
                    return Err(e);
                }
                // The ORIGINAL deadline binds the whole recovery loop,
                // not each attempt: no retry may start past it.
                if cfg.deadline.map_or(false, |d| Instant::now() >= d) {
                    return Err(Error::DeadlineExceeded);
                }
                attempts += 1;
                if sink.lock().unwrap().is_none() {
                    // From-scratch retry: drop any preamble bags the
                    // failed attempt captured — the fresh attempt
                    // recomputes and recaptures them, and stale entries
                    // would collide in `serve::assemble_preamble`.
                    // (Checkpointed retries KEEP the sink: restored
                    // instances never recompute their preamble bags, so
                    // the captured entries are the only copies.)
                    if let Some(cap) = cfg.preamble.as_ref().and_then(|p| p.capture.as_ref()) {
                        cap.lock().unwrap().clear();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_events_fire_exactly_once() {
        let fp = FaultPlan::new().panic_at(1, 3).slow_at(0, 2, Duration::from_millis(1));
        assert_eq!(fp.check(0, 1), None);
        assert_eq!(fp.check(1, 3), Some(FaultKind::Panic));
        assert_eq!(fp.check(1, 3), None, "one-shot: a retry must get past the fault");
        assert_eq!(fp.check(0, 2), Some(FaultKind::Slow(Duration::from_millis(1))));
        assert_eq!(fp.check(0, 2), None);
    }

    #[test]
    fn seeded_plan_is_deterministic_and_capped() {
        let a = FaultPlan::seeded(0x1AB);
        let b = FaultPlan::seeded(0x1AB);
        let mut fires_a = Vec::new();
        for s in 1..10_000u32 {
            if a.check(0, s).is_some() {
                fires_a.push(s);
            }
        }
        assert_eq!(fires_a.len() as u32, SEEDED_CAP, "cap bounds total seeded fires");
        // Same seed, same coordinates, same draws.
        for &s in &fires_a {
            assert_eq!(b.check(0, s), Some(FaultKind::Panic));
        }
        // After the cap, nothing more fires even at would-fire coords.
        let c = FaultPlan::seeded(0x1AB);
        for s in 1..10_000u32 {
            let _ = c.check(0, s);
        }
        assert!(c.check(0, 100_000).is_none());
    }

    #[test]
    fn empty_plan_never_fires() {
        let fp = FaultPlan::new();
        assert!(fp.is_empty());
        for w in 0..4 {
            for s in 1..100 {
                assert_eq!(fp.check(w, s), None);
            }
        }
    }

    #[test]
    fn retry_policy_default_allows_three_attempts() {
        assert_eq!(RetryPolicy::default().max_retries, 2);
    }
}
