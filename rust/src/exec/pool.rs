//! The persistent worker pool: simulated-cluster worker threads that
//! survive across jobs.
//!
//! `run_plan` historically spawned one OS thread per worker per run and
//! joined them at the end — fine for a single benchmark run, but a real
//! per-job cost (thread spawn + stack + teardown) that dominates short
//! jobs under high submission rates. A [`WorkerPool`] keeps the threads
//! resident; a job becomes a message-delimited **epoch**: the driver
//! hands each pooled thread an [`Arc<WorkerShared>`] (plan + per-job
//! channels) plus that worker's job receiver, the thread runs
//! [`run_worker`] to `Shutdown` exactly as before, reports the epoch
//! complete, and parks waiting for the next job.
//!
//! Isolation between epochs is structural: `run_worker` builds every
//! piece of per-job state (path replica, operator instances, §7 reuse
//! tables) on entry and drops it on return, so consecutive jobs — even
//! from different tenants of the `serve::` job service — cannot observe
//! each other's state. A worker panic is caught per epoch, reported to
//! that job's driver, and the thread stays usable for the next job.
//! Aborted epochs (deadline, mid-run cancel via `ExecConfig::cancel`)
//! end the same way as successful ones: the driver still sends
//! `Shutdown`, the thread still drains its queue and reports done, so
//! an abort can never poison the pool for the job that follows it.

use super::message::{DriverMsg, WorkerMsg};
use super::worker::{run_worker, WorkerShared};
use crate::error::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum PoolCmd {
    /// Run one job epoch: process `rx` until `Shutdown`, then report on
    /// `done`.
    Run {
        shared: Arc<WorkerShared>,
        rx: Receiver<WorkerMsg>,
        done: Sender<usize>,
    },
    /// Terminate the pool thread.
    Shutdown,
}

/// A set of resident worker threads, reused across job epochs.
///
/// The pool runs ONE job at a time (every thread participates in each
/// epoch); concurrency across jobs comes from multiple pools — the
/// `serve::JobService` owns one pool per job slot.
pub struct WorkerPool {
    ctrl: Vec<Sender<PoolCmd>>,
    handles: Vec<JoinHandle<()>>,
    epochs: Arc<AtomicU64>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` resident threads (min 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let epochs = Arc::new(AtomicU64::new(0));
        let mut ctrl = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<PoolCmd>();
            let epochs = epochs.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("laby-pool-{w}"))
                    .spawn(move || pool_main(w, rx, epochs))
                    .expect("spawn pool worker"),
            );
            ctrl.push(tx);
        }
        WorkerPool { ctrl, handles, epochs }
    }

    /// Number of resident worker threads.
    pub fn size(&self) -> usize {
        self.ctrl.len()
    }

    /// Resize the pool to `workers` resident threads (min 1).
    ///
    /// Grow spawns fresh `laby-pool-{w}` threads; shrink sends `Shutdown`
    /// to the excess threads and joins them. The caller must only resize
    /// **between** job epochs — the pool runs one job at a time and every
    /// thread participates in each epoch, so there is never an in-flight
    /// job to disturb as long as the owner (a `serve::` lane) resizes
    /// from its own dispatch loop. Plan width must match `size()` at
    /// dispatch time (`run_plan_on_pool` checks), which the serve tier
    /// guarantees by caching one compiled template per worker width.
    pub fn set_size(&mut self, workers: usize) {
        let workers = workers.max(1);
        let cur = self.ctrl.len();
        if workers > cur {
            for w in cur..workers {
                let (tx, rx) = channel::<PoolCmd>();
                let epochs = self.epochs.clone();
                self.handles.push(
                    std::thread::Builder::new()
                        .name(format!("laby-pool-{w}"))
                        .spawn(move || pool_main(w, rx, epochs))
                        .expect("spawn pool worker"),
                );
                self.ctrl.push(tx);
            }
        } else if workers < cur {
            for tx in &self.ctrl[workers..] {
                let _ = tx.send(PoolCmd::Shutdown);
            }
            self.ctrl.truncate(workers);
            for h in self.handles.drain(workers..) {
                let _ = h.join();
            }
        }
    }

    /// Total worker epochs completed (each job contributes `size()`).
    pub fn epochs(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    /// Thread ids of the resident workers (stable across epochs — used by
    /// the reuse tests to prove no thread churn).
    pub fn thread_ids(&self) -> Vec<std::thread::ThreadId> {
        self.handles.iter().map(|h| h.thread().id()).collect()
    }

    /// Hand worker `w` its share of a job epoch.
    pub(crate) fn dispatch(
        &self,
        w: usize,
        shared: Arc<WorkerShared>,
        rx: Receiver<WorkerMsg>,
        done: Sender<usize>,
    ) -> Result<()> {
        self.ctrl[w]
            .send(PoolCmd::Run { shared, rx, done })
            .map_err(|_| crate::Error::exec(format!("pool worker {w} is gone")))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.ctrl {
            let _ = tx.send(PoolCmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn pool_main(w: usize, ctrl: Receiver<PoolCmd>, epochs: Arc<AtomicU64>) {
    while let Ok(cmd) = ctrl.recv() {
        match cmd {
            PoolCmd::Shutdown => break,
            PoolCmd::Run { shared, rx, done } => {
                // Keep a driver handle past the move so a panic can still
                // be reported to THIS job's driver.
                let driver = shared.driver.clone();
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_worker(w, shared, rx);
                }));
                if let Err(p) = result {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "worker panic".into());
                    let _ = driver.send(DriverMsg::Panic { msg: format!("worker {w}: {msg}") });
                }
                epochs.fetch_add(1, Ordering::Relaxed);
                let _ = done.send(w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{driver, ExecConfig, ExecPlan};
    use crate::frontend::parse_and_lower;

    fn plan(src: &str, workers: usize) -> Arc<ExecPlan> {
        let g = crate::compile(&parse_and_lower(src).unwrap()).unwrap();
        Arc::new(ExecPlan::new(Arc::new(g), workers))
    }

    #[test]
    fn pool_reuses_threads_across_epochs() {
        let pool = WorkerPool::new(3);
        let ids_before = pool.thread_ids();
        let p = plan("a = bag(1, 2, 3); b = a.map(|x| x + 1); collect(b, \"b\");", 3);
        let cfg = ExecConfig { workers: 3, ..Default::default() };
        for _ in 0..5 {
            let out = driver::run_plan_on_pool(p.clone(), &cfg, &pool).unwrap();
            let mut got = out.collected("b").to_vec();
            got.sort();
            assert_eq!(got.len(), 3);
        }
        if crate::exec::default_faults().is_some() {
            // Under `LABY_FAULTS` injected panics add retry epochs.
            assert!(pool.epochs() >= 5 * 3, "every job runs one epoch per worker");
        } else {
            assert_eq!(pool.epochs(), 5 * 3, "every job runs one epoch per worker");
        }
        assert_eq!(pool.thread_ids(), ids_before, "no thread churn across jobs");
    }

    #[test]
    fn pool_survives_a_worker_panic() {
        let pool = WorkerPool::new(2);
        // `source` of an unregistered name panics inside the worker.
        let bad = plan(
            "s = source(\"pool_test_definitely_unregistered\"); collect(s, \"s\");",
            2,
        );
        let cfg = ExecConfig { workers: 2, ..Default::default() };
        let err = driver::run_plan_on_pool(bad.clone(), &cfg, &pool).unwrap_err();
        assert!(err.to_string().contains("not registered"), "{err}");
        // The pool remains usable.
        let good = plan("a = bag(7); collect(a, \"a\");", 2);
        let out = driver::run_plan_on_pool(good, &cfg, &pool).unwrap();
        assert_eq!(out.collected("a").len(), 1);
    }

    #[test]
    fn pool_survives_a_mid_run_cancel() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let pool = WorkerPool::new(2);
        // Without the cancel this loop runs for a very long time.
        let long =
            plan("d = 1; while (d <= 20000000) { d = d + 1; } collect(bag(1), \"x\");", 2);
        let cancel = Arc::new(AtomicBool::new(false));
        let cfg = ExecConfig { workers: 2, cancel: Some(cancel.clone()), ..Default::default() };
        let setter = {
            let cancel = cancel.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                cancel.store(true, Ordering::SeqCst);
            })
        };
        let err = driver::run_plan_on_pool(long, &cfg, &pool).unwrap_err();
        setter.join().unwrap();
        assert!(err.to_string().contains("canceled"), "{err}");
        // Clean teardown: the SAME pool serves the next epoch.
        let good = plan("a = bag(7); collect(a, \"a\");", 2);
        let out = driver::run_plan_on_pool(
            good,
            &ExecConfig { workers: 2, ..Default::default() },
            &pool,
        )
        .unwrap();
        assert_eq!(out.collected("a").len(), 1);
    }

    #[test]
    fn pool_grows_and_shrinks_between_epochs() {
        let mut pool = WorkerPool::new(2);
        let cfg2 = ExecConfig { workers: 2, ..Default::default() };
        let p2 = plan("a = bag(1, 2); b = a.map(|x| x * 2); collect(b, \"b\");", 2);
        assert_eq!(driver::run_plan_on_pool(p2.clone(), &cfg2, &pool).unwrap().collected("b").len(), 2);

        // Grow: new threads join, a wider plan runs on the same pool.
        pool.set_size(4);
        assert_eq!(pool.size(), 4);
        let p4 = plan("a = bag(1, 2); b = a.map(|x| x * 2); collect(b, \"b\");", 4);
        let cfg4 = ExecConfig { workers: 4, ..Default::default() };
        assert_eq!(driver::run_plan_on_pool(p4, &cfg4, &pool).unwrap().collected("b").len(), 2);

        // Shrink: excess threads are joined, the narrow plan still runs.
        pool.set_size(1);
        assert_eq!(pool.size(), 1);
        let p1 = plan("a = bag(1, 2); b = a.map(|x| x * 2); collect(b, \"b\");", 1);
        let cfg1 = ExecConfig { workers: 1, ..Default::default() };
        assert_eq!(driver::run_plan_on_pool(p1, &cfg1, &pool).unwrap().collected("b").len(), 2);

        // Floor: a resize to zero clamps to one thread.
        pool.set_size(0);
        assert_eq!(pool.size(), 1);
    }

    #[test]
    fn pool_rejects_mismatched_plan_width() {
        let pool = WorkerPool::new(2);
        let p = plan("a = bag(1); collect(a, \"a\");", 4);
        let cfg = ExecConfig { workers: 4, ..Default::default() };
        assert!(driver::run_plan_on_pool(p, &cfg, &pool).is_err());
    }
}
