//! One physical operator instance: the §6.1 transformation wrapped with
//! the §6.3 coordination state machine — output-bag selection, input-bag
//! selection (Φ-aware), conditional-output watchers, input-buffer GC, and
//! §7 state reuse.

use super::message::{DriverMsg, WorkerMsg};
use super::plan::ExecPlan;
use crate::coord::{
    choose_phi_input, required_input_len, ExecPath, OutWatcher, SendDecision,
};
use crate::dataflow::{NodeId, Route};
use crate::frontend::Rhs;
use crate::bag::ColumnBatch;
use crate::ops::{Collector, Transformation};
use crate::value::Value;
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::sync::mpsc::Sender;

/// Shared per-event environment handed from the worker to instances.
pub struct Env<'a> {
    /// Worker-local replica of the execution path.
    pub path: &'a ExecPath,
    /// Senders to every worker (indexed by worker id).
    pub workers: &'a [Sender<WorkerMsg>],
    /// Sender to the driver.
    pub driver: &'a Sender<DriverMsg>,
    /// Shared plan.
    pub plan: &'a ExecPlan,
    /// Data-batch size for element sends.
    pub batch: usize,
    /// §7 state reuse enabled? (Fig. 8 ablation switch.)
    pub reuse: bool,
    /// Pre-resolved hot-path counters (see `worker::EngineCounters`).
    pub counters: &'a super::worker::EngineCounters,
    /// Per-node observed output counters (see `worker::NodeCounters`).
    pub node_counters: &'a [super::worker::NodeCounters],
    /// Report per-bag completions to the driver (barrier mode only).
    pub report_bag_done: bool,
    /// Cross-job invariant-preamble sharing (replay source / capture
    /// sink) for this epoch, if active (`serve::`).
    pub preamble: Option<&'a super::PreambleSharing>,
    /// Legacy element-at-a-time data plane (see
    /// [`super::ExecConfig::element_path`]).
    pub element_path: bool,
    /// This worker's span ring when the epoch is traced (`None` on
    /// untraced runs — the instrument sites below reduce to a branch).
    pub spans: Option<&'a mut crate::obs::SpanBuf>,
}

use std::sync::atomic::Ordering;

/// Per-bag staging sink between the transformation and `route_staging`.
/// Typed kernels deliver whole [`ColumnBatch`]es; the override derives
/// the routing key hashes column-at-a-time *before* decoding to
/// `Value`s, so hash-routed edges skip the per-`Value` hash walk.
/// Invariant: `hashes` is either exactly aligned with `items`
/// (`hashes[i] == items[i].key_hash()`) or empty — any dynamic emission
/// invalidates it, and `route_staging` only consumes it when aligned.
#[derive(Default)]
struct StagingCollector {
    items: Vec<Value>,
    hashes: Vec<u64>,
}

impl Collector for StagingCollector {
    fn emit(&mut self, v: Value) {
        self.hashes.clear();
        self.items.push(v);
    }
    fn emit_batch(&mut self, vs: &mut Vec<Value>) {
        self.hashes.clear();
        self.items.append(vs);
    }
    fn emit_columns(&mut self, cols: ColumnBatch) {
        if self.hashes.len() == self.items.len() {
            cols.key_hashes_into(&mut self.hashes);
        } else {
            self.hashes.clear();
        }
        let mut vs = cols.into_values();
        self.items.append(&mut vs);
    }
}

struct InBuf {
    items: Vec<Value>,
    closes: usize,
}

struct ActiveIn {
    required: u32,
    fed: usize,
    closed_delivered: bool,
    reused: bool,
}

struct CurOut {
    len: u32,
    /// Per logical input: `None` = inactive (Φ non-chosen edge).
    active: Vec<Option<ActiveIn>>,
    cond_value: Option<bool>,
    collect_items: Vec<Value>,
}

struct Retained {
    items: Vec<Value>,
    computing: bool,
    /// Per conditional out-edge index: watcher + sent flag.
    watchers: Vec<(usize, OutWatcher, bool)>,
}

/// A physical operator instance.
pub struct Instance {
    /// Logical node id.
    pub node: NodeId,
    /// Instance index within the node.
    pub inst: usize,
    transform: Box<dyn Transformation>,
    pending_out: VecDeque<u32>,
    cur: Option<CurOut>,
    bufs: Vec<FxHashMap<u32, InBuf>>,
    prev_req: Vec<Option<u32>>,
    retained: FxHashMap<u32, Retained>,
    send_bufs: Vec<Vec<Vec<Value>>>,
    staging: StagingCollector,
    /// Per-batch key hashes, computed once per emission batch and shared
    /// by every hash-routed out edge (reused across batches).
    hash_buf: Vec<u64>,
    done_sent: bool,
    is_phi: bool,
    is_cond: bool,
    collect_label: Option<String>,
    /// The current bag was replayed from a cached preamble result: the
    /// transform was never opened and must not be closed. Sticky, which
    /// is sound because a shareable node produces exactly one bag per run.
    replayed: bool,
    /// Items emitted for the current bag, accumulated for the cross-job
    /// preamble capture sink (`None` when not capturing).
    capture: Option<Vec<Value>>,
    /// Delta-incremental role assigned by `opt::delta`, if any (Φ
    /// solution set or back-edge changed-rows operator).
    delta: Option<crate::dataflow::DeltaSpec>,
    /// Last output-bag position a delta transform processed: the
    /// loop-re-entry reset scan covers the path since this position.
    last_delta_bag: u32,
    /// Solution-set size last folded into the `state_size` gauge.
    last_state_size: u64,
}

impl Instance {
    /// Create the instance for `(node, inst)`.
    pub fn new(
        plan: &ExecPlan,
        node: NodeId,
        inst: usize,
        io_dir: &std::path::Path,
        registry: std::sync::Arc<crate::workload::registry::Registry>,
        columnar: bool,
    ) -> Instance {
        let n = &plan.graph.nodes[node];
        let ctx = crate::ops::MakeCtx {
            inst,
            insts: plan.num_insts[node],
            registry,
            io_dir: io_dir.to_path_buf(),
            in_types: n.inputs.iter().map(|i| plan.edge_types[i.src].clone()).collect(),
            out_type: plan.edge_types[node].clone(),
            columnar,
        };
        let transform = crate::ops::make_node(n, plan.join_build[node], &ctx)
            .unwrap_or_else(|e| panic!("instantiating {}: {e}", n.name));
        let n_inputs = n.inputs.len();
        let send_bufs = plan.out_edges[node]
            .iter()
            .map(|oe| vec![Vec::new(); oe.dst_insts])
            .collect();
        Instance {
            node,
            inst,
            transform,
            pending_out: VecDeque::new(),
            cur: None,
            bufs: (0..n_inputs).map(|_| FxHashMap::default()).collect(),
            prev_req: vec![None; n_inputs],
            retained: FxHashMap::default(),
            send_bufs,
            staging: StagingCollector::default(),
            hash_buf: Vec::new(),
            done_sent: false,
            is_phi: matches!(n.op, Rhs::Phi(_)),
            is_cond: n.cond.is_some(),
            collect_label: match &n.op {
                Rhs::Collect { label, .. } => Some(label.clone()),
                _ => None,
            },
            replayed: false,
            capture: None,
            delta: n.delta.clone(),
            last_delta_bag: 0,
            last_state_size: 0,
        }
    }

    // ---- event entry points (called by the worker loop) -----------------

    /// A data batch arrived on `input` for bag `bag_len` (possibly also
    /// carrying the producer's close marker).
    pub fn on_data(
        &mut self,
        input: usize,
        bag_len: u32,
        items: Box<[Value]>,
        close: bool,
        env: &mut Env,
    ) {
        let buf = self.bufs[input].entry(bag_len).or_insert_with(|| InBuf {
            items: Vec::new(),
            closes: 0,
        });
        buf.items.extend(items.into_vec());
        if close {
            buf.closes += 1;
        }
        self.try_advance(env);
    }

    /// A close marker arrived on `input` for bag `bag_len`.
    pub fn on_close(&mut self, input: usize, bag_len: u32, env: &mut Env) {
        let buf = self.bufs[input].entry(bag_len).or_insert_with(|| InBuf {
            items: Vec::new(),
            closes: 0,
        });
        buf.closes += 1;
        debug_assert!(
            buf.closes <= env.plan.in_edges[self.node][input].expected_closes,
            "too many closes on node {} input {input} bag {bag_len}",
            self.node
        );
        self.try_advance(env);
    }

    /// The execution path grew by `blocks` starting at 0-based `start`.
    pub fn on_append(&mut self, start: usize, blocks: &[crate::frontend::BlockId], env: &mut Env) {
        let my_block = env.plan.graph.nodes[self.node].block;
        for (k, &b) in blocks.iter().enumerate() {
            let pos = (start + k + 1) as u32; // 1-based
            if b == my_block {
                self.pending_out.push_back(pos);
            }
            // §6.3.4: update conditional-output watchers.
            self.process_watchers(|w| w.on_block(pos, b), env);
        }
        if env.path.is_final() {
            self.process_watchers(|w| w.on_final(), env);
        }
        self.gc_inputs(env);
        self.try_advance(env);
    }

    /// Idle hook: re-check progress and completion (used at startup).
    pub fn poke(&mut self, env: &mut Env) {
        self.try_advance(env);
    }

    // ---- coordination core ----------------------------------------------

    fn process_watchers(&mut self, mut f: impl FnMut(&mut OutWatcher) -> SendDecision, env: &mut Env) {
        // 1. Update watcher states; collect newly-latched sends of
        //    finished (non-computing) bags.
        let mut to_send: Vec<(u32, usize, Vec<Value>)> = Vec::new();
        for (&len, r) in self.retained.iter_mut() {
            let computing = r.computing;
            for (edge_idx, w, sent) in r.watchers.iter_mut() {
                let st = f(w);
                if st == SendDecision::Send && !*sent && !computing {
                    *sent = true;
                    // A loop-exit edge of a delta Φ receives the
                    // materialized solution set, not the per-superstep
                    // delta the retained bag holds. Sound here because
                    // the bag is no longer computing: its delta was
                    // already merged into the store.
                    let items = if env.plan.out_edges[self.node][*edge_idx].wants_full {
                        let mut full = Vec::new();
                        self.transform.materialize_state(&mut full);
                        full
                    } else {
                        r.items.clone()
                    };
                    to_send.push((len, *edge_idx, items));
                }
            }
        }
        // 2. Transmit.
        for (len, edge_idx, items) in to_send {
            self.transmit_retained(len, edge_idx, &items, env);
        }
        // 3. Sweep fully-resolved retained bags.
        let before = self.retained.len();
        self.retained.retain(|_, r| {
            r.computing
                || r.watchers.iter().any(|(_, w, sent)| match w.state() {
                    SendDecision::Undecided => true,
                    SendDecision::Send => !*sent,
                    SendDecision::Dead => false,
                })
        });
        env.counters.retained_dropped.fetch_add((before - self.retained.len()) as u64, Ordering::Relaxed);
    }

    fn try_advance(&mut self, env: &mut Env) {
        loop {
            if self.cur.is_none() {
                let Some(&len) = self.pending_out.front() else { break };
                self.start_bag(len, env);
                self.pending_out.pop_front();
            }
            if self.feed(env) {
                self.finish_bag(env);
                self.gc_inputs(env);
                continue;
            }
            break;
        }
        self.maybe_done(env);
    }

    fn start_bag(&mut self, len: u32, env: &mut Env) {
        let n = &env.plan.graph.nodes[self.node];
        debug_assert_eq!(env.path.at(len), n.block, "output bag at foreign block");
        // Delta state is loop-scoped: if the path left the loop since
        // this node's previous bag (outer-loop re-entry runs the loop
        // again from scratch), the retained solution set belongs to a
        // finished loop execution — drop it before opening the bag.
        if let Some(spec) = &self.delta {
            let prev = self.last_delta_bag;
            if (prev + 1..len).any(|p| !spec.in_loop(env.path.at(p))) {
                self.transform.reset_state();
            }
            self.last_delta_bag = len;
        }
        // Cross-job preamble sharing (`serve::`): a shareable invariant
        // node whose output a previous epoch materialized under a
        // matching binding signature REPLAYS the cached bag — the
        // transform is never touched, inputs are ignored (the cached
        // items already embody them), and downstream coordination is
        // indistinguishable from a recompute.
        let replay: Option<Vec<Value>> = if env.plan.shareable[self.node] {
            env.preamble
                .and_then(|p| p.replay.as_ref())
                .and_then(|r| r.get(&self.node))
                .and_then(|per_inst| per_inst.get(self.inst))
                .cloned()
        } else {
            None
        };
        let replaying = replay.is_some();
        if !replaying {
            self.transform.open_out_bag();
            // Capture the bag we are about to compute so later epochs
            // with a matching binding signature can replay it.
            if env.plan.shareable[self.node]
                && env.preamble.map_or(false, |p| p.capture.is_some())
            {
                self.capture = Some(Vec::new());
            }
        }

        // §6.3.4: retained entry with one watcher per conditional out-edge.
        let cond_edges: Vec<usize> = env.plan.out_edges[self.node]
            .iter()
            .enumerate()
            .filter(|(_, e)| e.conditional)
            .map(|(i, _)| i)
            .collect();
        if !cond_edges.is_empty() {
            let mut watchers: Vec<(usize, OutWatcher, bool)> = cond_edges
                .iter()
                .map(|&i| {
                    let oe = &env.plan.out_edges[self.node][i];
                    (i, OutWatcher::new(len, oe.target_block, oe.blockers.clone()), false)
                })
                .collect();
            // The path may already extend beyond this bag (control flow can
            // run ahead of slow data operators — that is loop pipelining):
            // replay the positions the watchers have missed. Latched sends
            // fire at finish_bag (the bag is still computing).
            for (_, w, _) in watchers.iter_mut() {
                for pos in (len + 1)..=env.path.len() {
                    w.on_block(pos, env.path.at(pos));
                }
                if env.path.is_final() {
                    w.on_final();
                }
            }
            self.retained.insert(len, Retained { items: Vec::new(), computing: true, watchers });
        }

        // §6.3.3: choose input bags.
        let n_inputs = n.inputs.len();
        let mut active: Vec<Option<ActiveIn>> = (0..n_inputs).map(|_| None).collect();
        if self.is_phi {
            let blocks: Vec<_> = env.plan.in_edges[self.node]
                .iter()
                .map(|ie| ie.src_block)
                .collect();
            let (idx, req) = choose_phi_input(env.path.blocks(), len, &blocks, n.block)
                .unwrap_or_else(|| panic!("Φ node {} has no available input at len {len}", n.name));
            active[idx] = Some(ActiveIn {
                required: req,
                fed: 0,
                closed_delivered: false,
                reused: false,
            });
        } else {
            for i in 0..n_inputs {
                let src_block = env.plan.in_edges[self.node][i].src_block;
                let req = required_input_len(env.path.blocks(), len, src_block)
                    .unwrap_or_else(|| {
                        panic!(
                            "node {} input {i} (block {src_block}) unavailable at len {len}",
                            n.name
                        )
                    });
                if replaying {
                    // Inputs satisfied without feeding: the replayed bag
                    // already embodies them. Data that still arrives is
                    // buffered, ignored, and reclaimed at run end.
                    self.prev_req[i] = Some(req);
                    active[i] = Some(ActiveIn {
                        required: req,
                        fed: 0,
                        closed_delivered: true,
                        reused: true,
                    });
                    continue;
                }
                let keeps = self.transform.keeps_input_state(i);
                let mut reused = false;
                if keeps {
                    if env.reuse && self.prev_req[i] == Some(req) {
                        reused = true;
                        env.counters.state_reused.fetch_add(1, Ordering::Relaxed);
                    } else if self.prev_req[i].is_some() {
                        self.transform.drop_state(i);
                        env.counters.state_dropped.fetch_add(1, Ordering::Relaxed);
                    }
                }
                self.prev_req[i] = Some(req);
                active[i] = Some(ActiveIn { required: req, fed: 0, closed_delivered: reused, reused });
            }
        }
        self.cur = Some(CurOut { len, active, cond_value: None, collect_items: Vec::new() });

        if let Some(items) = replay {
            // Emit the cached bag; `feed` sees every input satisfied and
            // `finish_bag` closes without running the transform.
            self.replayed = true;
            env.counters.preamble_replays.fetch_add(1, Ordering::Relaxed);
            // Interior shareable node — every consumer replays its OWN
            // cached bag, so nobody reads this one: skip the emission
            // (and its clones/sends) entirely. Only the row counter is
            // kept, so adaptive feedback sees identical statistics on
            // replayed and computed epochs. Frontier nodes (any consumer
            // outside the replay set, e.g. in-loop operators) still emit.
            let interior = !env.plan.out_edges[self.node].is_empty()
                && env.plan.out_edges[self.node].iter().all(|oe| {
                    env.preamble
                        .and_then(|p| p.replay.as_ref())
                        .map_or(false, |r| r.contains_key(&oe.dst_node))
                });
            if interior {
                env.node_counters[self.node]
                    .rows
                    .fetch_add(items.len() as u64, Ordering::Relaxed);
            } else {
                self.staging.items.extend(items);
                self.route_staging(env);
            }
        } else if n_inputs == 0 {
            // Sources generate immediately.
            let t0 = env.spans.as_ref().map(|sp| sp.now());
            self.transform.generate(&mut self.staging);
            if let (Some(sp), Some(t0)) = (env.spans.as_mut(), t0) {
                let kind = crate::obs::SpanKind::Generate { node: self.node as u32, step: len };
                let dur = sp.record(kind, t0);
                env.node_counters[self.node].self_ns.fetch_add(dur, Ordering::Relaxed);
            }
            self.route_staging(env);
        }
        env.counters.bags_started.fetch_add(1, Ordering::Relaxed);
    }

    /// Feed buffered input to the transformation. Returns true when the
    /// output bag is complete.
    ///
    /// New items are handed over as ONE `push_in_batch` slice per arrival
    /// — no per-element clone, no per-element virtual dispatch (the
    /// pre-batching loop cloned every element and crossed the trait
    /// boundary once each; `element_path` keeps that behavior available
    /// for differential runs).
    fn feed(&mut self, env: &mut Env) -> bool {
        let Some(cur) = &mut self.cur else { return false };
        let step = cur.len;
        let mut all_done = true;
        for i in 0..self.bufs.len() {
            let Some(a) = &mut cur.active[i] else { continue };
            if a.reused {
                continue;
            }
            if let Some(buf) = self.bufs[i].get(&a.required) {
                if a.fed < buf.items.len() {
                    let new = &buf.items[a.fed..];
                    a.fed = buf.items.len();
                    let t0 = env.spans.as_ref().map(|sp| sp.now());
                    if env.element_path {
                        for v in new {
                            // Faithful legacy cost profile: one clone +
                            // one trait crossing per element.
                            let v = v.clone();
                            self.transform.push_in_element(i, &v, &mut self.staging);
                        }
                    } else {
                        env.counters.batch_pushes.fetch_add(1, Ordering::Relaxed);
                        self.transform.push_in_batch(i, new, &mut self.staging);
                    }
                    if let (Some(sp), Some(t0)) = (env.spans.as_mut(), t0) {
                        let kind =
                            crate::obs::SpanKind::NodeBatch { node: self.node as u32, step };
                        let dur = sp.record(kind, t0);
                        env.node_counters[self.node].self_ns.fetch_add(dur, Ordering::Relaxed);
                    }
                }
                let expected = env.plan.in_edges[self.node][i].expected_closes;
                if buf.closes >= expected && !a.closed_delivered {
                    a.closed_delivered = true;
                    let t0 = env.spans.as_ref().map(|sp| sp.now());
                    self.transform.close_in_bag(i, &mut self.staging);
                    if let (Some(sp), Some(t0)) = (env.spans.as_mut(), t0) {
                        let kind =
                            crate::obs::SpanKind::NodeClose { node: self.node as u32, step };
                        let dur = sp.record(kind, t0);
                        env.node_counters[self.node].self_ns.fetch_add(dur, Ordering::Relaxed);
                    }
                }
            }
            if !a.closed_delivered {
                all_done = false;
            }
        }
        // Route whatever was emitted so far (pipelining).
        self.route_staging(env);
        all_done
    }

    fn finish_bag(&mut self, env: &mut Env) {
        if !self.replayed {
            // A replayed bag's transform was never opened; everything it
            // emits was already routed in `start_bag`.
            let step = self.cur.as_ref().map_or(0, |c| c.len);
            let t0 = env.spans.as_ref().map(|sp| sp.now());
            self.transform.close_out_bag(&mut self.staging);
            if let (Some(sp), Some(t0)) = (env.spans.as_mut(), t0) {
                let kind = crate::obs::SpanKind::NodeClose { node: self.node as u32, step };
                let dur = sp.record(kind, t0);
                env.node_counters[self.node].self_ns.fetch_add(dur, Ordering::Relaxed);
            }
            self.route_staging(env);
        }
        let cur = self.cur.take().expect("finish without current bag");
        let len = cur.len;

        // Fold the fused chain's interior per-stage row counts into the
        // shared node counters — once per completed bag, never per
        // element. Adaptive feedback reads these through
        // `RunOutput::node_rows[..].stage_rows`.
        if let Some(rows) = self.transform.take_stage_rows() {
            let slots = &env.node_counters[self.node].stage_rows;
            for (i, r) in rows.into_iter().enumerate() {
                if let Some(slot) = slots.get(i) {
                    slot.fetch_add(r, Ordering::Relaxed);
                }
            }
        }

        // Rows a batch kernel consumed straight from the borrowed input
        // (fused stage-0 borrow / columnar pipelines) — the move-not-clone
        // evidence the batch-path tests pin.
        let borrowed = self.transform.take_borrowed_rows();
        if borrowed != 0 {
            env.counters.fused_borrowed_rows.fetch_add(borrowed, Ordering::Relaxed);
        }

        // Fold the solution-set (or retained-build) size into the gauge:
        // signed diff vs the last report, so concurrent instances of one
        // node sum to the node's total current size.
        if let Some(sz) = self.transform.state_size() {
            let d = sz.wrapping_sub(self.last_state_size);
            if d != 0 {
                env.node_counters[self.node].state_size.fetch_add(d, Ordering::Relaxed);
            }
            self.last_state_size = sz;
        }

        // Hand the completed bag to the cross-job preamble capture sink.
        if let Some(items) = self.capture.take() {
            if let Some(sink) = env.preamble.and_then(|p| p.capture.as_ref()) {
                sink.lock().unwrap().push((self.node, self.inst, items));
            }
        }

        // Flush unconditional sends, piggybacking close markers on the
        // final batch per destination; destinations with no buffered data
        // get a bare Close.
        for ei in 0..self.send_bufs.len() {
            let oe = env.plan.out_edges[self.node][ei].clone();
            if oe.conditional {
                continue;
            }
            for dst in close_targets(oe.route, self.inst, oe.dst_insts) {
                if !self.flush_one(ei, dst, len, true, env) {
                    let _ =
                        env.workers[env.plan.worker_of(oe.dst_node, dst)].send(WorkerMsg::Close {
                            node: oe.dst_node,
                            input: oe.dst_input,
                            dst_inst: dst,
                            bag_len: len,
                        });
                }
            }
        }

        // Retained entry: computation finished; transmit any already-latched
        // sends (§6.3.4 decisions can arrive while the bag is computing).
        let mut latched: Vec<(usize, Vec<Value>)> = Vec::new();
        let mut resolved = false;
        if let Some(r) = self.retained.get_mut(&len) {
            r.computing = false;
            for (e, w, sent) in r.watchers.iter_mut() {
                if w.state() == SendDecision::Send && !*sent {
                    *sent = true;
                    // Loop-exit edges of a delta Φ get the materialized
                    // solution set (see `process_watchers`); the bag just
                    // finished, so the store is fully merged.
                    let items = if env.plan.out_edges[self.node][*e].wants_full {
                        let mut full = Vec::new();
                        self.transform.materialize_state(&mut full);
                        full
                    } else {
                        r.items.clone()
                    };
                    latched.push((*e, items));
                }
            }
            resolved = r.watchers.iter().all(|(_, w, sent)| match w.state() {
                SendDecision::Send => *sent,
                SendDecision::Dead => true,
                SendDecision::Undecided => false,
            });
        }
        for (e, items) in latched {
            self.transmit_retained(len, e, &items, env);
        }
        if resolved {
            self.retained.remove(&len);
        }

        // Condition node: report the decision (§5.3 / §6.3.1).
        if self.is_cond {
            let value = cur
                .cond_value
                .unwrap_or_else(|| panic!("condition node produced no boolean"));
            let _ = env.driver.send(DriverMsg::Decision { node: self.node, bag_len: len, value });
        }
        // Collect sink: ship the bag to the driver.
        if let Some(label) = &self.collect_label {
            let _ = env.driver.send(DriverMsg::Output {
                label: label.clone(),
                bag_len: len,
                items: cur.collect_items,
            });
        }
        if env.report_bag_done {
            let _ = env.driver.send(DriverMsg::BagDone {
                node: self.node,
                inst: self.inst,
                bag_len: len,
            });
        }
        env.counters.bags_completed.fetch_add(1, Ordering::Relaxed);
        env.node_counters[self.node].bags.fetch_add(1, Ordering::Relaxed);
    }

    // ---- emission routing -------------------------------------------------

    /// Route one emission batch to the send buffers. The batched path is
    /// a per-batch **scatter**: `Value::key_hash` is computed once per
    /// element for the whole batch (shared by every hash-routed edge,
    /// instead of per element per edge), destinations are bucketed with
    /// tight per-edge loops, and a batch with a single unconditional
    /// consumer is MOVED into its send buffer without cloning.
    fn route_staging(&mut self, env: &mut Env) {
        if self.staging.items.is_empty() {
            return;
        }
        let mut items = std::mem::take(&mut self.staging.items);
        // Column-derived key hashes, valid only when they cover the whole
        // staged batch (see `StagingCollector`).
        let mut staged_hashes = std::mem::take(&mut self.staging.hashes);
        let precomputed = staged_hashes.len() == items.len();
        env.node_counters[self.node].rows.fetch_add(items.len() as u64, Ordering::Relaxed);
        if let Some(cap) = self.capture.as_mut() {
            cap.extend(items.iter().cloned());
        }
        let cur = self.cur.as_mut().expect("emission outside a bag");
        let len = cur.len;
        if self.is_cond {
            for v in &items {
                debug_assert!(cur.cond_value.is_none(), "condition bag not a singleton");
                cur.cond_value = Some(v.as_bool());
            }
        }
        if self.collect_label.is_some() {
            cur.collect_items.extend(items.iter().cloned());
        }
        let has_conditional = self.retained.contains_key(&len);
        let out_edges = &env.plan.out_edges[self.node];

        if env.element_path {
            // Legacy per-element routing (reference implementation).
            for v in items {
                for (ei, oe) in out_edges.iter().enumerate() {
                    if oe.conditional {
                        continue;
                    }
                    match route_target(oe.route, &v, self.inst, oe.dst_insts) {
                        Target::One(d) => self.send_bufs[ei][d].push(v.clone()),
                        Target::All => {
                            for d in 0..oe.dst_insts {
                                self.send_bufs[ei][d].push(v.clone());
                            }
                        }
                    }
                }
                if has_conditional {
                    self.retained.get_mut(&len).unwrap().items.push(v);
                }
            }
            self.flush_large_send_bufs(len, env);
            return;
        }

        // Hash the batch once if any unconditional edge routes by key to
        // more than one destination.
        let needs_hash = out_edges
            .iter()
            .any(|oe| !oe.conditional && oe.route == Route::HashKey && oe.dst_insts > 1);
        let mut hashes = std::mem::take(&mut self.hash_buf);
        if needs_hash {
            hashes.clear();
            if precomputed {
                // Typed kernels already derived the hashes column-at-a-time.
                env.counters.columnar_hash_reuse.fetch_add(1, Ordering::Relaxed);
                hashes.append(&mut staged_hashes);
            } else {
                hashes.extend(items.iter().map(|v| v.key_hash()));
            }
        }
        staged_hashes.clear();
        self.staging.hashes = staged_hashes;

        // Clone-scatter into every unconditional consumer but the last;
        // the last takes the batch by move when no retained copy needs it.
        let last_uncond = out_edges.iter().rposition(|oe| !oe.conditional);
        for (ei, oe) in out_edges.iter().enumerate() {
            if oe.conditional {
                continue;
            }
            let take = !has_conditional && Some(ei) == last_uncond;
            match oe.route {
                Route::Forward | Route::Gather => {
                    let d = if oe.route == Route::Gather {
                        0
                    } else {
                        forward_dest(self.inst, oe.dst_insts)
                    };
                    if take {
                        env.counters.scatter_moves.fetch_add(1, Ordering::Relaxed);
                        self.send_bufs[ei][d].append(&mut items);
                    } else {
                        self.send_bufs[ei][d].extend(items.iter().cloned());
                    }
                }
                Route::Broadcast => {
                    // All but the final destination clone; the final one
                    // takes the batch by move when nothing else needs it.
                    let last_d = oe.dst_insts - 1;
                    for d in 0..last_d {
                        self.send_bufs[ei][d].extend(items.iter().cloned());
                    }
                    if take {
                        env.counters.scatter_moves.fetch_add(1, Ordering::Relaxed);
                        self.send_bufs[ei][last_d].append(&mut items);
                    } else {
                        self.send_bufs[ei][last_d].extend(items.iter().cloned());
                    }
                }
                Route::HashKey => {
                    if oe.dst_insts == 1 {
                        if take {
                            env.counters.scatter_moves.fetch_add(1, Ordering::Relaxed);
                            self.send_bufs[ei][0].append(&mut items);
                        } else {
                            self.send_bufs[ei][0].extend(items.iter().cloned());
                        }
                    } else if take {
                        env.counters.scatter_moves.fetch_add(1, Ordering::Relaxed);
                        for (v, &h) in items.drain(..).zip(&hashes) {
                            self.send_bufs[ei][hash_dest(h, oe.dst_insts)].push(v);
                        }
                    } else {
                        for (v, &h) in items.iter().zip(&hashes) {
                            self.send_bufs[ei][hash_dest(h, oe.dst_insts)].push(v.clone());
                        }
                    }
                }
            }
        }
        if has_conditional {
            // §6.3.4 retained copy takes the originals (edges above cloned).
            self.retained.get_mut(&len).unwrap().items.append(&mut items);
        }
        self.hash_buf = hashes;
        // Flush large buffers eagerly (pipelined transfer).
        self.flush_large_send_bufs(len, env);
    }

    fn flush_large_send_bufs(&mut self, len: u32, env: &mut Env) {
        for ei in 0..self.send_bufs.len() {
            for d in 0..self.send_bufs[ei].len() {
                if self.send_bufs[ei][d].len() >= env.batch {
                    self.flush_one(ei, d, len, false, env);
                }
            }
        }
    }



    /// Flush one (edge, dst) buffer; returns true if a batch was sent.
    /// `close`: piggyback the producer's close marker on the batch.
    fn flush_one(&mut self, ei: usize, d: usize, len: u32, close: bool, env: &mut Env) -> bool {
        if self.send_bufs[ei][d].is_empty() {
            return false;
        }
        let oe = &env.plan.out_edges[self.node][ei];
        let items: Box<[Value]> = std::mem::take(&mut self.send_bufs[ei][d]).into_boxed_slice();
        env.counters.batches_sent.fetch_add(1, Ordering::Relaxed);
        env.counters.elements_sent.fetch_add(items.len() as u64, Ordering::Relaxed);
        let _ = env.workers[env.plan.worker_of(oe.dst_node, d)].send(WorkerMsg::Data {
            node: oe.dst_node,
            input: oe.dst_input,
            dst_inst: d,
            bag_len: len,
            items,
            close,
        });
        true
    }

    fn transmit_retained(&mut self, len: u32, edge_idx: usize, items: &[Value], env: &mut Env) {
        let oe = &env.plan.out_edges[self.node][edge_idx];
        env.counters.conditional_sends.fetch_add(1, Ordering::Relaxed);
        // Partition and send the full bag, then close.
        let mut per_dst: Vec<Vec<Value>> = vec![Vec::new(); oe.dst_insts];
        for v in items {
            match route_target(oe.route, v, self.inst, oe.dst_insts) {
                Target::One(d) => per_dst[d].push(v.clone()),
                Target::All => {
                    for dst in per_dst.iter_mut() {
                        dst.push(v.clone());
                    }
                }
            }
        }
        let close_to = close_targets(oe.route, self.inst, oe.dst_insts);
        for d in close_to {
            let batch = std::mem::take(&mut per_dst[d]);
            if batch.is_empty() {
                let _ = env.workers[env.plan.worker_of(oe.dst_node, d)].send(WorkerMsg::Close {
                    node: oe.dst_node,
                    input: oe.dst_input,
                    dst_inst: d,
                    bag_len: len,
                });
            } else {
                env.counters.elements_sent.fetch_add(batch.len() as u64, Ordering::Relaxed);
                let _ = env.workers[env.plan.worker_of(oe.dst_node, d)].send(WorkerMsg::Data {
                    node: oe.dst_node,
                    input: oe.dst_input,
                    dst_inst: d,
                    bag_len: len,
                    items: batch.into_boxed_slice(),
                    close: true,
                });
            }
        }
    }

    // ---- GC and completion ------------------------------------------------

    /// Consumer-side buffer GC (§6.3.3). A buffered bag with id `len` on
    /// edge `i` is superseded once any supersede block (the input's own
    /// block; for Φ consumers also the sibling blocks) occurs at some
    /// `j > len`: every output at a position `> j` selects a candidate
    /// with prefix ≥ j instead. The bag therefore stays needed only by
    /// outputs at positions `< j` — plus, exactly at `j`, a Φ
    /// *self-argument* (the output at `j` reads the Φ's own PREVIOUS bag).
    /// With in-order output processing this gives an O(1)-per-bag rule on
    /// `min_pending` (the earliest uncompleted output position):
    ///
    /// * `min_pending < j`  → keep (still selectable);
    /// * `min_pending == j` → exact §6.3.3 selection test at `j`;
    /// * `min_pending > j` or none pending → dead.
    ///
    /// (An earlier version scanned ALL pending outputs per buffered bag —
    /// O(pending²) when the control path runs far ahead of slow data
    /// operators under pipelining; see EXPERIMENTS.md §Perf #5.)
    fn gc_inputs(&mut self, env: &mut Env) {
        let path_final = env.path.is_final();
        let own_block = env.plan.graph.nodes[self.node].block;
        let min_pending: Option<u32> = self
            .cur
            .as_ref()
            .map(|c| c.len)
            .or_else(|| self.pending_out.front().copied());
        let phi_blocks: Vec<crate::frontend::BlockId> = if self.is_phi {
            env.plan.in_edges[self.node].iter().map(|e| e.src_block).collect()
        } else {
            Vec::new()
        };
        let is_phi = self.is_phi;
        for i in 0..self.bufs.len() {
            let ie = &env.plan.in_edges[self.node][i];
            // Invariant edge (producer outside every loop — e.g. a node
            // hoisted into a loop preamble): the single bag it carries is
            // never superseded, so the §6.3.3 retain-scan is pure
            // overhead. Pin the buffer; `maybe_done` reclaims it at the
            // end of the run.
            if ie.invariant && !self.bufs[i].is_empty() {
                env.counters.invariant_gc_skips.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let src_block = ie.src_block;
            let supersede = &ie.supersede_blocks;
            let path = env.path;
            let keeps = self.transform.keeps_input_state(i) && env.reuse;
            let prev = self.prev_req[i];
            let phi_blocks = &phi_blocks;
            self.bufs[i].retain(|&len, _| {
                // Keep the bag backing reused operator state (its `closes`
                // entry anchors the §7 reuse bookkeeping).
                if keeps && prev == Some(len) && !path_final {
                    return true;
                }
                let needed_at = |p: u32| -> bool {
                    if is_phi {
                        choose_phi_input(path.blocks(), p, phi_blocks, own_block)
                            .map(|(e, l)| e == i && l == len)
                            .unwrap_or(false)
                    } else {
                        required_input_len(path.blocks(), p, src_block) == Some(len)
                    }
                };
                match (path.next_occurrence_of_any(supersede, len), min_pending) {
                    (None, Some(_)) => true,         // still the latest candidate
                    (None, None) => !path_final,     // may serve future outputs
                    (Some(_), None) => false,        // all selectable outputs done
                    (Some(j), Some(mp)) => {
                        if mp < j {
                            true
                        } else if mp == j {
                            needed_at(j) // Φ self-argument boundary case
                        } else {
                            false
                        }
                    }
                }
            });
        }
    }

    // ---- checkpoint / restore (recovery::) --------------------------------

    /// Capture this instance's state at a superstep-boundary checkpoint
    /// cut. The driver only requests snapshots once every bag of the
    /// current path prefix has reported done, which makes the instance
    /// quiescent: no open output bag, no queued bag starts, nothing
    /// staged or buffered for send, and no retained bag still
    /// computing. Everything else an epoch would need is either in the
    /// snapshot (input-bag buffers — including the ones backing §7
    /// reused state — and §6.3.4 retained conditional outputs) or
    /// derivable from the restored path replica. Entries are sorted by
    /// bag id so identical cuts produce identical snapshots.
    pub fn snapshot(&self) -> super::recovery::InstanceSnapshot {
        debug_assert!(self.cur.is_none(), "checkpoint with an open output bag");
        debug_assert!(self.pending_out.is_empty(), "checkpoint with queued bag starts");
        debug_assert!(self.staging.items.is_empty(), "checkpoint with staged emissions");
        debug_assert!(
            self.send_bufs.iter().all(|per| per.iter().all(|b| b.is_empty())),
            "checkpoint with buffered sends"
        );
        let bufs = self
            .bufs
            .iter()
            .map(|m| {
                let mut v: Vec<(u32, Vec<Value>, usize)> =
                    m.iter().map(|(&len, b)| (len, b.items.clone(), b.closes)).collect();
                v.sort_by_key(|e| e.0);
                v
            })
            .collect();
        let mut retained: Vec<(u32, Vec<Value>, Vec<(usize, bool)>)> = self
            .retained
            .iter()
            .map(|(&len, r)| {
                debug_assert!(!r.computing, "checkpoint with a computing retained bag");
                (
                    len,
                    r.items.clone(),
                    r.watchers.iter().map(|&(e, _, sent)| (e, sent)).collect(),
                )
            })
            .collect();
        retained.sort_by_key(|e| e.0);
        super::recovery::InstanceSnapshot {
            node: self.node,
            inst: self.inst,
            bufs,
            retained,
            // Delta solution sets (and retained accumulators) cannot be
            // rebuilt from input buffers — the deltas that built them
            // were GC'd long ago — so they checkpoint as first-class
            // state. `None` for every non-delta transform.
            op_state: self.transform.snapshot_state(),
        }
    }

    /// Rebuild instance state from a checkpoint snapshot, against a
    /// path already seeded with the checkpointed prefix. Input buffers
    /// and retained bags come back verbatim; §6.3.4 watchers are
    /// reconstructed by replaying the restored path (never final at a
    /// cut — final chains are not checkpointed). `prev_req` stays
    /// `None` on purpose: the first post-resume bag of a
    /// state-keeping input re-feeds its (restored) backing buffer into
    /// the fresh transformation, rebuilding §7 state exactly as a
    /// reuse-disabled step would.
    pub fn restore(&mut self, snap: &super::recovery::InstanceSnapshot, path: &ExecPath, plan: &ExecPlan) {
        debug_assert_eq!(self.node, snap.node, "snapshot restored into wrong node");
        debug_assert_eq!(self.inst, snap.inst, "snapshot restored into wrong instance");
        for (i, bags) in snap.bufs.iter().enumerate() {
            for (len, items, closes) in bags {
                self.bufs[i]
                    .insert(*len, InBuf { items: items.clone(), closes: *closes });
            }
        }
        for (len, items, watchers) in &snap.retained {
            let rebuilt: Vec<(usize, OutWatcher, bool)> = watchers
                .iter()
                .map(|&(edge_idx, sent)| {
                    let oe = &plan.out_edges[self.node][edge_idx];
                    let mut w = OutWatcher::new(*len, oe.target_block, oe.blockers.clone());
                    for pos in (*len + 1)..=path.len() {
                        w.on_block(pos, path.at(pos));
                    }
                    (edge_idx, w, sent)
                })
                .collect();
            self.retained.insert(
                *len,
                Retained { items: items.clone(), computing: false, watchers: rebuilt },
            );
        }
        if let Some(st) = &snap.op_state {
            self.transform.restore_state(st);
            // The restored store covers the checkpointed prefix; the
            // re-entry reset scan resumes past it. `last_state_size`
            // stays 0: the gauge is live (not re-seeded from the
            // checkpoint), so the first post-resume bag re-reports the
            // full size.
            self.last_delta_bag = path.len();
        }
    }

    fn maybe_done(&mut self, env: &mut Env) {
        if self.done_sent || !env.path.is_final() {
            return;
        }
        if self.cur.is_none() && self.pending_out.is_empty() {
            // All watchers resolved at finalization; drop leftovers.
            self.retained.clear();
            for b in &mut self.bufs {
                b.clear();
            }
            self.done_sent = true;
            let _ = env.driver.send(DriverMsg::Done { node: self.node, inst: self.inst });
        }
    }
}

enum Target {
    One(usize),
    All,
}

/// `Route::Forward` destination — shared by the per-element
/// `route_target` and the batched scatter so the two paths can never
/// partition differently.
#[inline]
fn forward_dest(self_inst: usize, dst_insts: usize) -> usize {
    self_inst.min(dst_insts - 1)
}

/// `Route::HashKey` destination for a precomputed key hash (shared by
/// both routing paths, see [`forward_dest`]).
#[inline]
fn hash_dest(hash: u64, dst_insts: usize) -> usize {
    (hash as usize) % dst_insts
}

fn route_target(route: Route, v: &Value, self_inst: usize, dst_insts: usize) -> Target {
    match route {
        Route::Forward => Target::One(forward_dest(self_inst, dst_insts)),
        Route::HashKey => Target::One(hash_dest(v.key_hash(), dst_insts)),
        Route::Broadcast => Target::All,
        Route::Gather => Target::One(0),
    }
}

fn close_targets(route: Route, self_inst: usize, dst_insts: usize) -> Vec<usize> {
    match route {
        Route::Forward => vec![self_inst.min(dst_insts - 1)],
        Route::Gather => vec![0],
        Route::HashKey | Route::Broadcast => (0..dst_insts).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_targets() {
        assert!(matches!(
            route_target(Route::Forward, &Value::I64(1), 2, 4),
            Target::One(2)
        ));
        assert!(matches!(
            route_target(Route::Gather, &Value::I64(1), 2, 1),
            Target::One(0)
        ));
        assert!(matches!(route_target(Route::Broadcast, &Value::I64(1), 0, 3), Target::All));
        let Target::One(d) = route_target(Route::HashKey, &Value::I64(42), 0, 3) else {
            panic!()
        };
        assert!(d < 3);
    }

    #[test]
    fn close_target_sets() {
        assert_eq!(close_targets(Route::Forward, 2, 4), vec![2]);
        assert_eq!(close_targets(Route::Gather, 2, 1), vec![0]);
        assert_eq!(close_targets(Route::HashKey, 0, 3), vec![0, 1, 2]);
    }

    #[test]
    fn hash_routing_is_consistent_per_key() {
        let a = Value::pair(Value::I64(7), Value::I64(1));
        let b = Value::pair(Value::I64(7), Value::I64(2));
        let Target::One(da) = route_target(Route::HashKey, &a, 0, 5) else { panic!() };
        let Target::One(db) = route_target(Route::HashKey, &b, 0, 5) else { panic!() };
        assert_eq!(da, db, "same key must co-partition");
    }
}
