//! Worker thread: hosts one physical instance of every logical node
//! assigned to it, maintains the local execution-path replica, and runs
//! the event loop over its message queue.

use super::instance::{Env, Instance};
use super::message::{DriverMsg, WorkerMsg};
use super::plan::ExecPlan;
use crate::coord::ExecPath;
use crate::metrics::Metrics;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Hot-path counters, resolved from [`Metrics`] once per run (the generic
/// `Metrics::add` locks a map and formats a key — too slow per element).
pub struct EngineCounters {
    /// Output bags opened.
    pub bags_started: Arc<AtomicU64>,
    /// Output bags completed.
    pub bags_completed: Arc<AtomicU64>,
    /// Data batches sent.
    pub batches_sent: Arc<AtomicU64>,
    /// Elements sent (all edges).
    pub elements_sent: Arc<AtomicU64>,
    /// §7 build-side reuses.
    pub state_reused: Arc<AtomicU64>,
    /// §7 drop_state calls.
    pub state_dropped: Arc<AtomicU64>,
    /// Conditional-output transmissions (§6.3.4).
    pub conditional_sends: Arc<AtomicU64>,
    /// Retained bags discarded (§6.3.4).
    pub retained_dropped: Arc<AtomicU64>,
    /// GC scans skipped on pinned invariant edges (loop preamble bags).
    pub invariant_gc_skips: Arc<AtomicU64>,
    /// Invariant-preamble bags replayed from a previous epoch instead of
    /// recomputed (cross-job sharing, `serve::`).
    pub preamble_replays: Arc<AtomicU64>,
    /// `push_in_batch` calls on the engine feed path (one per newly
    /// arrived input slice — the data plane's unit of work).
    pub batch_pushes: Arc<AtomicU64>,
    /// Emission batches moved into a send buffer without cloning (the
    /// single-consumer scatter fast path).
    pub scatter_moves: Arc<AtomicU64>,
    /// Rows batch kernels consumed straight from the borrowed input
    /// slice — no upfront clone (fused stage-0 borrow and the typed
    /// columnar pipelines).
    pub fused_borrowed_rows: Arc<AtomicU64>,
    /// Emission batches whose routing key hashes were derived
    /// column-at-a-time by a typed kernel instead of per-`Value`.
    pub columnar_hash_reuse: Arc<AtomicU64>,
}

impl EngineCounters {
    /// Resolve all handles.
    pub fn new(m: &Metrics) -> EngineCounters {
        EngineCounters {
            bags_started: m.counter("coord.bags_started"),
            bags_completed: m.counter("coord.bags_completed"),
            batches_sent: m.counter("exec.batches_sent"),
            elements_sent: m.counter("exec.elements_sent"),
            state_reused: m.counter("coord.state_reused"),
            state_dropped: m.counter("coord.state_dropped"),
            conditional_sends: m.counter("coord.conditional_sends"),
            retained_dropped: m.counter("coord.retained_dropped"),
            invariant_gc_skips: m.counter("coord.invariant_gc_skips"),
            preamble_replays: m.counter("coord.preamble_replays"),
            batch_pushes: m.counter("exec.batch_pushes"),
            scatter_moves: m.counter("exec.scatter_moves"),
            fused_borrowed_rows: m.counter("exec.fused_borrowed_rows"),
            columnar_hash_reuse: m.counter("exec.columnar_hash_reuse"),
        }
    }
}

/// Per-logical-node observed output counters (indexed by `NodeId`),
/// shared by all workers of a run and folded into
/// [`super::RunOutput::node_rows`] by the driver. One atomic add per
/// staging flush / completed bag — off the per-element hot path.
#[derive(Default)]
pub struct NodeCounters {
    /// Elements emitted (all instances, all steps).
    pub rows: AtomicU64,
    /// Output bags completed (per instance per step).
    pub bags: AtomicU64,
    /// Fused nodes only: output rows per interior stage (sized to the
    /// stage count at creation, empty otherwise). Accumulated once per
    /// completed bag from [`crate::ops::Transformation::take_stage_rows`].
    pub stage_rows: Vec<AtomicU64>,
    /// Measured transformation self-time in nanoseconds (batch pushes +
    /// bag closes + generator runs). Only written on traced runs — one
    /// atomic add per traced span, zero cost otherwise.
    pub self_ns: AtomicU64,
    /// Current indexed-state size in rows (delta solution sets, retained
    /// accumulators, reused hash-join builds). A gauge, not a counter:
    /// each instance folds in the *signed* size change once per
    /// completed bag (two's-complement wrapping, so concurrent
    /// instances sum correctly), keeping `rows` an honest delta-rows
    /// count distinct from how much state the node holds.
    pub state_size: AtomicU64,
}

impl NodeCounters {
    /// Create the counters for one logical node, sizing the per-stage
    /// slots for fused chains.
    pub fn for_node(n: &crate::dataflow::Node) -> NodeCounters {
        let stages = match &n.op {
            crate::frontend::Rhs::Fused { stages, .. } => stages.len(),
            _ => 0,
        };
        NodeCounters {
            rows: AtomicU64::new(0),
            bags: AtomicU64::new(0),
            stage_rows: (0..stages).map(|_| AtomicU64::new(0)).collect(),
            self_ns: AtomicU64::new(0),
            state_size: AtomicU64::new(0),
        }
    }
}

/// Parameters shared by all workers of a run.
pub struct WorkerShared {
    /// The physical plan.
    pub plan: Arc<ExecPlan>,
    /// Senders to all workers.
    pub workers: Vec<Sender<WorkerMsg>>,
    /// Sender to the driver.
    pub driver: Sender<DriverMsg>,
    /// Data batch size.
    pub batch: usize,
    /// §7 state reuse switch.
    pub reuse: bool,
    /// Metrics sink.
    pub metrics: Arc<Metrics>,
    /// Pre-resolved hot-path counters.
    pub counters: Arc<EngineCounters>,
    /// Report per-bag completions to the driver (barrier mode only — the
    /// pipelined driver never reads them).
    pub report_bag_done: bool,
    /// I/O base directory.
    pub io_dir: std::path::PathBuf,
    /// Named-source registry for this run (per-request overlay under the
    /// `serve::` job service, the process-global registry otherwise).
    pub registry: Arc<crate::workload::registry::Registry>,
    /// Observed per-node output cardinalities (indexed by `NodeId`).
    pub node_counters: Arc<Vec<NodeCounters>>,
    /// Cooperative cancellation token for this epoch (see
    /// [`super::ExecConfig::cancel`]); `None` = uncancelable.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Cross-job invariant-preamble sharing for this epoch (replay
    /// source and/or capture sink).
    pub preamble: Option<super::PreambleSharing>,
    /// Legacy element-at-a-time data plane (see
    /// [`super::ExecConfig::element_path`]).
    pub element_path: bool,
    /// Span tracer for this epoch, already gate-checked by the driver
    /// (`Some` only when tracing is enabled right now).
    pub trace: Option<Arc<crate::obs::Tracer>>,
    /// Pre-allocated trace lane per worker index (empty when untraced).
    pub trace_lanes: Vec<u32>,
    /// Checkpoint to resume from (`recovery::`): each worker seeds its
    /// path replica with the checkpointed prefix and restores the
    /// instances it hosts before entering the event loop. `None` for
    /// fresh epochs.
    pub resume: Option<Arc<super::recovery::EpochCheckpoint>>,
    /// Deterministic fault-injection schedule for this epoch
    /// ([`super::ExecConfig::faults`]); consulted per appended
    /// superstep — `None` costs one branch per append.
    pub faults: Option<Arc<super::recovery::FaultPlan>>,
}

/// Run one worker for one job **epoch**: process messages until
/// `Shutdown`. Instances hosted: instance `w` of every `Par::All` node,
/// instance 0 of `Par::One` nodes when `w == 0`. All per-job state (the
/// path replica and every operator instance, including §7 reuse state) is
/// created here and dropped on return, so a pooled thread running
/// back-to-back epochs (`exec::pool`) starts every job clean — nothing
/// bleeds between jobs or tenants.
pub fn run_worker(w: usize, shared: Arc<WorkerShared>, rx: Receiver<WorkerMsg>) {
    let plan = shared.plan.clone();
    // Traced epochs get a thread-owned span ring; absorbed into the
    // tracer sink once, on epoch teardown. `None` on untraced runs, so
    // the data plane's only cost is the `Option` branch per batch.
    let mut spans = shared.trace.as_ref().map(|t| t.local(shared.trace_lanes[w]));
    let mut path = ExecPath::new(plan.graph.cfg.num_blocks());
    // node id -> hosted instance (if any).
    // Resolve the graph's columnar gate against the engine's batch size
    // once: it decides whether `Instance::new` installs typed kernels.
    let columnar = plan.graph.columnar.enabled(shared.batch);
    let mut instances: Vec<Option<Instance>> = plan
        .graph
        .nodes
        .iter()
        .map(|n| {
            let insts = plan.num_insts[n.id];
            if w < insts {
                Some(Instance::new(
                    &plan,
                    n.id,
                    w,
                    &shared.io_dir,
                    shared.registry.clone(),
                    columnar,
                ))
            } else {
                None
            }
        })
        .collect();

    // Resumed epoch (`recovery::`): seed the path replica with the
    // checkpointed prefix and restore hosted instances BEFORE any
    // message arrives. Instances never re-run prefix bags (the replica
    // append bypasses `on_append`, so nothing is queued), but restored
    // buffers serve future bags and `maybe_done` still reports Done at
    // path finalization.
    if let Some(ck) = &shared.resume {
        path.append(0, &ck.blocks, false);
        for snap in &ck.insts {
            if plan.worker_of(snap.node, snap.inst) == w {
                if let Some(inst) = instances[snap.node].as_mut() {
                    inst.restore(snap, &path, &plan);
                }
            }
        }
    }

    let mut cancel_reported = false;
    // Set by a `FaultKind::DropData` event: the next Data message is
    // silently discarded (its consumer starves and the driver's stall
    // timeout converts that into a retryable coordination error).
    let mut drop_next_data = false;
    while let Ok(msg) = rx.recv() {
        // Cooperative mid-run cancel: between messages (superstep/batch
        // boundaries) check the token; once set, report to the driver
        // (at most once) and drain the remaining queue WITHOUT
        // processing it, so the epoch tears down exactly like a normal
        // shutdown — channels emptied, per-job state dropped, thread
        // back to resident idle for the pool's next job.
        if let Some(c) = &shared.cancel {
            if c.load(Ordering::Relaxed) {
                if !cancel_reported {
                    cancel_reported = true;
                    let _ = shared.driver.send(DriverMsg::Canceled { worker: w });
                }
                if matches!(msg, WorkerMsg::Shutdown) {
                    break;
                }
                continue;
            }
        }
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Checkpoint => {
                // The driver only asks once every bag of the current
                // prefix is complete, so every hosted instance is
                // quiescent and snapshot-able right now.
                let insts: Vec<_> = instances
                    .iter()
                    .filter_map(|o| o.as_ref().map(|inst| inst.snapshot()))
                    .collect();
                let _ = shared.driver.send(DriverMsg::Snapshot { worker: w, insts });
            }
            WorkerMsg::Append { start, blocks, final_ } => {
                // Deterministic fault injection, keyed to the 1-based
                // superstep positions this append introduces. Fires
                // BEFORE the path replica grows, so a panicking worker
                // dies with pre-superstep state — exactly the crash a
                // checkpoint at the previous boundary covers.
                // (Fires are counted on the plan itself — the recovery
                // wrapper stamps `exec.faults_injected` on the run that
                // survives, since a failed attempt's metrics die with it.)
                if let Some(fp) = &shared.faults {
                    for k in 0..blocks.len() {
                        let pos = (start + k + 1) as u32;
                        match fp.check(w, pos) {
                            None => {}
                            Some(super::recovery::FaultKind::Panic) => {
                                panic!("injected fault: worker {w} panics at superstep {pos}");
                            }
                            Some(super::recovery::FaultKind::Slow(d)) => {
                                std::thread::sleep(d);
                            }
                            Some(super::recovery::FaultKind::DropData) => {
                                drop_next_data = true;
                            }
                        }
                    }
                }
                path.append(start, &blocks, final_);
                for node in 0..instances.len() {
                    if let Some(inst) = instances[node].as_mut() {
                        let mut env = Env {
                            path: &path,
                            workers: &shared.workers,
                            driver: &shared.driver,
                            plan: &plan,
                            batch: shared.batch,
                            reuse: shared.reuse,
                            counters: &shared.counters,
                            node_counters: &shared.node_counters,
                            report_bag_done: shared.report_bag_done,
                            preamble: shared.preamble.as_ref(),
                            element_path: shared.element_path,
                            spans: spans.as_mut(),
                        };
                        inst.on_append(start, &blocks, &mut env);
                    }
                }
            }
            WorkerMsg::Data { node, input, dst_inst, bag_len, items, close } => {
                if drop_next_data {
                    // Injected message loss (`FaultKind::DropData`).
                    drop_next_data = false;
                    continue;
                }
                debug_assert_eq!(plan.worker_of(node, dst_inst), w);
                let inst = instances[node]
                    .as_mut()
                    .unwrap_or_else(|| panic!("worker {w} has no instance of node {node}"));
                debug_assert_eq!(inst.inst, dst_inst);
                let mut env = Env {
                    path: &path,
                    workers: &shared.workers,
                    driver: &shared.driver,
                    plan: &plan,
                    batch: shared.batch,
                    reuse: shared.reuse,
                    counters: &shared.counters,
                    node_counters: &shared.node_counters,
                    report_bag_done: shared.report_bag_done,
                    preamble: shared.preamble.as_ref(),
                    element_path: shared.element_path,
                    spans: spans.as_mut(),
                };
                inst.on_data(input, bag_len, items, close, &mut env);
            }
            WorkerMsg::Close { node, input, dst_inst, bag_len } => {
                debug_assert_eq!(plan.worker_of(node, dst_inst), w);
                let inst = instances[node]
                    .as_mut()
                    .unwrap_or_else(|| panic!("worker {w} has no instance of node {node}"));
                let mut env = Env {
                    path: &path,
                    workers: &shared.workers,
                    driver: &shared.driver,
                    plan: &plan,
                    batch: shared.batch,
                    reuse: shared.reuse,
                    counters: &shared.counters,
                    node_counters: &shared.node_counters,
                    report_bag_done: shared.report_bag_done,
                    preamble: shared.preamble.as_ref(),
                    element_path: shared.element_path,
                    spans: spans.as_mut(),
                };
                inst.on_close(input, bag_len, &mut env);
            }
        }
    }
    if let (Some(t), Some(buf)) = (shared.trace.as_ref(), spans) {
        t.absorb(buf);
    }
}
