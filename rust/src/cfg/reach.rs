//! CFG reachability queries used by the coordination protocol:
//!
//! * §6.3.4 — a producer may discard a retained conditional-output bag
//!   "once the execution path reaches a basic block from which every path
//!   to b2 goes through b1" — i.e. when `b2` is *not* reachable while
//!   avoiding `b1`.
//! * §6.3.3 — same machinery decides when consumer-side input buffers
//!   (and reusable operator state, §7) can be dropped early.
//!
//! The runtime combines these static tables with exact dynamic checks on
//! the evolving execution path (see `coord::tracker`).

use super::Cfg;
use crate::frontend::BlockId;

/// Is there a walk `from ⇝ target` of length ≥ 0 that never *enters*
/// `avoid`? (`from == target` counts as reaching, unless `target == avoid`.)
pub fn can_reach_avoiding(
    cfg: &Cfg,
    from: BlockId,
    target: BlockId,
    avoid: Option<BlockId>,
) -> bool {
    if Some(target) == avoid {
        return false;
    }
    if Some(from) == avoid {
        return false;
    }
    let n = cfg.num_blocks();
    let mut seen = vec![false; n];
    let mut stack = vec![from];
    seen[from] = true;
    while let Some(b) = stack.pop() {
        if b == target {
            return true;
        }
        for &s in &cfg.succs[b] {
            if Some(s) != avoid && !seen[s] {
                seen[s] = true;
                stack.push(s);
            }
        }
    }
    false
}

/// For a conditional edge `b1 → b2`: the per-block table
/// `dead_from[x] == true` iff a retained output bag is provably dead once
/// the execution path stands at `x` — no continuation from `x` can reach
/// the consumer block `b2` without first passing the producer block `b1`
/// (where the bag would be superseded by a newer one).
///
/// The *next step* out of `x` matters, not `x` itself: the caller applies
/// this after having already checked whether `x` is the send (`b2`) or
/// supersede (`b1`) block.
pub fn dead_from_table(cfg: &Cfg, b1: BlockId, b2: BlockId) -> Vec<bool> {
    let n = cfg.num_blocks();
    (0..n)
        .map(|x| {
            // From x, explore successors while avoiding b1; if b2 is never
            // met, the bag is dead.
            let mut seen = vec![false; n];
            let mut stack: Vec<BlockId> = cfg.succs[x]
                .iter()
                .copied()
                .filter(|&s| s != b1)
                .collect();
            for &s in &stack {
                seen[s] = true;
            }
            let mut reached = false;
            while let Some(b) = stack.pop() {
                if b == b2 {
                    reached = true;
                    break;
                }
                for &s in &cfg.succs[b] {
                    if s != b1 && !seen[s] {
                        seen[s] = true;
                        stack.push(s);
                    }
                }
            }
            !reached
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::testutil::cfg_from_shape;
    use super::*;

    /// Loop: 0 -> 1(hdr) -> {2(body), 3(exit)}; 2 -> 1.
    #[test]
    fn reach_avoiding_in_loop() {
        let cfg = cfg_from_shape(0, &[&[1], &[2, 3], &[1], &[]]);
        assert!(can_reach_avoiding(&cfg, 0, 3, None));
        assert!(can_reach_avoiding(&cfg, 2, 3, None));
        // Cannot reach the exit while avoiding the header.
        assert!(!can_reach_avoiding(&cfg, 2, 3, Some(1)));
        // from == target reaches trivially.
        assert!(can_reach_avoiding(&cfg, 2, 2, None));
        // ... unless avoided.
        assert!(!can_reach_avoiding(&cfg, 2, 2, Some(2)));
    }

    /// Invariant-producer case: producer in pre-loop block 0, consumer in
    /// body 2. The bag is only dead at the exit (3), because 0 never recurs
    /// but 2 stays reachable while looping.
    #[test]
    fn invariant_edge_dead_only_at_exit() {
        let cfg = cfg_from_shape(0, &[&[1], &[2, 3], &[1], &[]]);
        let dead = dead_from_table(&cfg, 0, 2);
        assert!(!dead[0]);
        assert!(!dead[1]);
        assert!(!dead[2]);
        assert!(dead[3]);
    }

    /// Loop-carried edge: producer in body (2), consumer Φ in header (1).
    /// From the exit block the bag is dead; from inside it is not.
    #[test]
    fn carried_edge_dead_at_exit() {
        let cfg = cfg_from_shape(0, &[&[1], &[2, 3], &[1], &[]]);
        let dead = dead_from_table(&cfg, 2, 1);
        assert!(dead[3]);
        assert!(!dead[2]);
        // From the header: reaching the Φ again (next header occurrence)
        // requires going through the body (2 = b1), superseding the bag.
        assert!(dead[1]);
    }

    /// Diamond: 0 -> {1, 2} -> 3; edge from then-branch 1 to merge 3.
    #[test]
    fn if_branch_edge_dead_after_merge_when_unreachable() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[3], &[3], &[]]);
        let dead = dead_from_table(&cfg, 1, 3);
        // At the merge itself, nothing can re-reach 3 (no loop): dead.
        assert!(dead[3]);
        // From 1, the merge is ahead: not dead.
        assert!(!dead[1]);
        assert!(!dead[0]);
    }
}
