//! Natural-loop detection. Used for loop-invariant analysis (the static
//! side of §7's build-side reuse), plan diagnostics, and the pipelining
//! ablation reports.

use super::dom::DomTree;
use super::Cfg;
use crate::frontend::BlockId;

/// One natural loop.
#[derive(Clone, Debug)]
pub struct NaturalLoop {
    /// The loop header (target of the back edge).
    pub header: BlockId,
    /// Source of the back edge (the latch).
    pub latch: BlockId,
    /// All blocks in the loop body, including the header.
    pub body: Vec<BlockId>,
}

/// Loop nesting information.
#[derive(Clone, Debug, Default)]
pub struct LoopInfo {
    /// All natural loops (one per back edge), unordered.
    pub loops: Vec<NaturalLoop>,
    /// Loop-nesting depth per block (0 = not in any loop).
    pub depth: Vec<usize>,
}

/// Find natural loops: for each back edge `latch -> header` (where the
/// header dominates the latch), collect the blocks that can reach the
/// latch without passing through the header.
pub fn find_loops(cfg: &Cfg, dom: &DomTree) -> LoopInfo {
    let n = cfg.num_blocks();
    let mut loops = Vec::new();
    for &b in &cfg.rpo {
        for &s in &cfg.succs[b] {
            if dom.dominates(s, b) {
                // Back edge b -> s.
                let header = s;
                let latch = b;
                let mut in_body = vec![false; n];
                in_body[header] = true;
                let mut stack = vec![latch];
                while let Some(x) = stack.pop() {
                    if in_body[x] {
                        continue;
                    }
                    in_body[x] = true;
                    for &p in &cfg.preds[x] {
                        if !in_body[p] {
                            stack.push(p);
                        }
                    }
                }
                let body: Vec<BlockId> = (0..n).filter(|&x| in_body[x]).collect();
                loops.push(NaturalLoop { header, latch, body });
            }
        }
    }
    let mut depth = vec![0usize; n];
    for l in &loops {
        for &b in &l.body {
            depth[b] += 1;
        }
    }
    LoopInfo { loops, depth }
}

impl LoopInfo {
    /// Is `b` inside any loop?
    pub fn in_loop(&self, b: BlockId) -> bool {
        self.depth[b] > 0
    }

    /// The innermost loop containing `b` (smallest body), if any.
    pub fn innermost(&self, b: BlockId) -> Option<&NaturalLoop> {
        self.loops
            .iter()
            .filter(|l| l.body.contains(&b))
            .min_by_key(|l| l.body.len())
    }
}

#[cfg(test)]
mod tests {
    use super::super::dom::dominators;
    use super::super::testutil::cfg_from_shape;
    use super::*;

    #[test]
    fn simple_while_loop_found() {
        // 0 -> 1(header) -> {2(body), 3}; 2 -> 1.
        let cfg = cfg_from_shape(0, &[&[1], &[2, 3], &[1], &[]]);
        let li = find_loops(&cfg, &dominators(&cfg));
        assert_eq!(li.loops.len(), 1);
        let l = &li.loops[0];
        assert_eq!(l.header, 1);
        assert_eq!(l.latch, 2);
        assert_eq!(l.body, vec![1, 2]);
        assert_eq!(li.depth, vec![0, 1, 1, 0]);
    }

    #[test]
    fn nested_loops_have_depth_two() {
        // 0; 1 outer hdr {2, 5}; 2 inner hdr {3, 4}; 3 -> 2; 4 -> 1; 5 end.
        let cfg = cfg_from_shape(0, &[&[1], &[2, 5], &[3, 4], &[2], &[1], &[]]);
        let li = find_loops(&cfg, &dominators(&cfg));
        assert_eq!(li.loops.len(), 2);
        assert_eq!(li.depth[3], 2);
        assert_eq!(li.depth[4], 1);
        assert_eq!(li.depth[5], 0);
        let inner = li.innermost(3).unwrap();
        assert_eq!(inner.header, 2);
    }

    #[test]
    fn if_statement_is_not_a_loop() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[3], &[3], &[]]);
        let li = find_loops(&cfg, &dominators(&cfg));
        assert!(li.loops.is_empty());
        assert!(!li.in_loop(1));
    }

    #[test]
    fn loop_with_if_inside_includes_branches() {
        // 0; 1 hdr {2, 6}; 2 {3, 4} if; 3 -> 5; 4 -> 5; 5 latch -> 1; 6 end.
        let cfg = cfg_from_shape(0, &[&[1], &[2, 6], &[3, 4], &[5], &[5], &[1], &[]]);
        let li = find_loops(&cfg, &dominators(&cfg));
        assert_eq!(li.loops.len(), 1);
        assert_eq!(li.loops[0].body, vec![1, 2, 3, 4, 5]);
    }
}
