//! Dominator tree (Cooper–Harvey–Kennedy) and dominance frontiers —
//! the machinery behind Φ-insertion in SSA construction.

use super::Cfg;
use crate::frontend::BlockId;

/// Immediate-dominator tree plus dominance frontiers.
#[derive(Clone, Debug)]
pub struct DomTree {
    /// `idom[b]` — immediate dominator of `b` (entry's idom is itself);
    /// `usize::MAX` for unreachable blocks.
    pub idom: Vec<BlockId>,
    /// Dominance frontier per block.
    pub frontier: Vec<Vec<BlockId>>,
    /// Children in the dominator tree.
    pub children: Vec<Vec<BlockId>>,
}

/// Compute dominators with the Cooper–Harvey–Kennedy iterative algorithm.
pub fn dominators(cfg: &Cfg) -> DomTree {
    let n = cfg.num_blocks();
    let undef = usize::MAX;
    let mut idom = vec![undef; n];
    idom[cfg.program.entry] = cfg.program.entry;

    let intersect = |idom: &[usize], rpo_pos: &[usize], mut a: usize, mut b: usize| -> usize {
        while a != b {
            while rpo_pos[a] > rpo_pos[b] {
                a = idom[a];
            }
            while rpo_pos[b] > rpo_pos[a] {
                b = idom[b];
            }
        }
        a
    };

    let mut changed = true;
    while changed {
        changed = false;
        for &b in &cfg.rpo {
            if b == cfg.program.entry {
                continue;
            }
            // First processed predecessor.
            let mut new_idom = undef;
            for &p in &cfg.preds[b] {
                if idom[p] != undef {
                    new_idom = if new_idom == undef {
                        p
                    } else {
                        intersect(&idom, &cfg.rpo_pos, new_idom, p)
                    };
                }
            }
            if new_idom != undef && idom[b] != new_idom {
                idom[b] = new_idom;
                changed = true;
            }
        }
    }

    // Dominance frontiers (Cytron et al. via CHK formulation).
    let mut frontier = vec![Vec::new(); n];
    for &b in &cfg.rpo {
        if cfg.preds[b].len() >= 2 {
            for &p in &cfg.preds[b] {
                if idom[p] == usize::MAX {
                    continue;
                }
                let mut runner = p;
                while runner != idom[b] {
                    if !frontier[runner].contains(&b) {
                        frontier[runner].push(b);
                    }
                    runner = idom[runner];
                }
            }
        }
    }

    let mut children = vec![Vec::new(); n];
    for &b in &cfg.rpo {
        if b != cfg.program.entry && idom[b] != undef {
            children[idom[b]].push(b);
        }
    }

    DomTree { idom, frontier, children }
}

impl DomTree {
    /// Does `a` dominate `b`? (Both must be reachable.)
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            let next = self.idom[cur];
            if next == cur || next == usize::MAX {
                return false;
            }
            cur = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::cfg_from_shape;
    use super::*;

    /// Diamond: 0 -> {1,2} -> 3.
    #[test]
    fn diamond_frontiers() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[3], &[3], &[]]);
        let dt = dominators(&cfg);
        assert_eq!(dt.idom[1], 0);
        assert_eq!(dt.idom[2], 0);
        assert_eq!(dt.idom[3], 0);
        assert_eq!(dt.frontier[1], vec![3]);
        assert_eq!(dt.frontier[2], vec![3]);
        assert!(dt.frontier[0].is_empty());
        assert!(dt.dominates(0, 3));
        assert!(!dt.dominates(1, 3));
    }

    /// While loop: 0 -> 1(header) -> {2(body), 3(after)}; 2 -> 1.
    #[test]
    fn loop_header_in_own_frontier_of_body() {
        let cfg = cfg_from_shape(0, &[&[1], &[2, 3], &[1], &[]]);
        let dt = dominators(&cfg);
        assert_eq!(dt.idom[1], 0);
        assert_eq!(dt.idom[2], 1);
        assert_eq!(dt.idom[3], 1);
        // The back edge puts the header in the body's frontier — and in the
        // header's own frontier (it doesn't strictly dominate itself).
        assert_eq!(dt.frontier[2], vec![1]);
        assert!(dt.frontier[1].contains(&1));
    }

    /// Nested loops: 0 -> 1 -> {2,5}; 2 -> 3 -> {2-ish...}
    #[test]
    fn nested_loop_frontiers() {
        // 0 entry; 1 outer header {2 body, 5 exit}; 2 inner header {3 inner
        // body, 4 outer latch}; 3 -> 2; 4 -> 1.
        let cfg = cfg_from_shape(0, &[&[1], &[2, 5], &[3, 4], &[2], &[1], &[]]);
        let dt = dominators(&cfg);
        assert_eq!(dt.idom[2], 1);
        assert_eq!(dt.idom[3], 2);
        assert_eq!(dt.idom[4], 2);
        assert!(dt.frontier[3].contains(&2));
        assert!(dt.frontier[4].contains(&1));
        assert!(dt.frontier[2].contains(&2)); // inner header via back edge
        assert!(dt.frontier[2].contains(&1)); // outer header via latch path
    }

    #[test]
    fn straight_line_has_empty_frontiers() {
        let cfg = cfg_from_shape(0, &[&[1], &[2], &[]]);
        let dt = dominators(&cfg);
        for f in &dt.frontier {
            assert!(f.is_empty());
        }
        assert!(dt.dominates(0, 2));
        assert!(dt.dominates(1, 2));
    }

    #[test]
    fn children_form_tree() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[3], &[3], &[]]);
        let dt = dominators(&cfg);
        let mut kids = dt.children[0].clone();
        kids.sort();
        assert_eq!(kids, vec![1, 2, 3]);
    }
}
