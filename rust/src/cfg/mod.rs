//! Control-flow graph over the pre-SSA IR: predecessors/successors,
//! reverse post-order, dominators, dominance frontiers, natural loops, and
//! the reachability tables the coordination protocol queries (§6.3.3/4).

pub mod dom;
pub mod loops;
pub mod reach;

use crate::error::{Error, Result};
use crate::frontend::{Block, BlockId, Program, Terminator, VarId};

/// A validated CFG wrapping a [`Program`].
#[derive(Clone, Debug)]
pub struct Cfg {
    /// The underlying program (blocks own the instructions).
    pub program: Program,
    /// Predecessors per block.
    pub preds: Vec<Vec<BlockId>>,
    /// Successors per block.
    pub succs: Vec<Vec<BlockId>>,
    /// Reverse post-order over reachable blocks.
    pub rpo: Vec<BlockId>,
    /// Position of each block in `rpo` (usize::MAX if unreachable).
    pub rpo_pos: Vec<usize>,
}

impl Cfg {
    /// Build and validate the CFG of a program.
    ///
    /// Validation: terminator targets in range; branch conditions are
    /// variables defined in the branching block (§5.3 requires the
    /// condition to be a plain variable reference whose node lives in the
    /// deciding block); every reachable block terminates.
    pub fn from_program(program: &Program) -> Result<Cfg> {
        let n = program.blocks.len();
        if n == 0 {
            return Err(Error::Ir("program has no blocks".into()));
        }
        if program.entry >= n {
            return Err(Error::Ir(format!("entry block {} out of range", program.entry)));
        }
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for (b, blk) in program.blocks.iter().enumerate() {
            for s in blk.term.successors() {
                if s >= n {
                    return Err(Error::Ir(format!("block bb{b} jumps to missing bb{s}")));
                }
                succs[b].push(s);
                preds[s].push(b);
            }
            if let Terminator::Branch { cond, .. } = blk.term {
                let defined_here = blk.instrs.iter().any(|i| i.var == cond);
                if !defined_here {
                    return Err(Error::Ir(format!(
                        "branch condition '{}' must be defined in the branching block bb{b}",
                        program.vars[cond].name
                    )));
                }
            }
        }
        // DFS post-order from entry.
        let mut visited = vec![false; n];
        let mut post = Vec::with_capacity(n);
        let mut stack: Vec<(BlockId, usize)> = vec![(program.entry, 0)];
        visited[program.entry] = true;
        while let Some(&mut (b, ref mut i)) = stack.last_mut() {
            if *i < succs[b].len() {
                let s = succs[b][*i];
                *i += 1;
                if !visited[s] {
                    visited[s] = true;
                    stack.push((s, 0));
                }
            } else {
                post.push(b);
                stack.pop();
            }
        }
        let rpo: Vec<BlockId> = post.into_iter().rev().collect();
        let mut rpo_pos = vec![usize::MAX; n];
        for (i, &b) in rpo.iter().enumerate() {
            rpo_pos[b] = i;
        }
        Ok(Cfg { program: program.clone(), preds, succs, rpo, rpo_pos })
    }

    /// Number of blocks.
    pub fn num_blocks(&self) -> usize {
        self.program.blocks.len()
    }

    /// Whether `b` is reachable from the entry.
    pub fn reachable(&self, b: BlockId) -> bool {
        self.rpo_pos[b] != usize::MAX
    }

    /// Borrow a block.
    pub fn block(&self, b: BlockId) -> &Block {
        &self.program.blocks[b]
    }

    /// The *chain* starting at `b` (§6.3.1): `b` followed by successive
    /// single-successor blocks. A condition node that appends `b` to the
    /// execution path also appends this whole chain, because blocks with
    /// one successor have no condition node of their own. The chain stops
    /// at (and includes) the first block with 0 or ≥2 successors.
    pub fn chain(&self, b: BlockId) -> Vec<BlockId> {
        let mut out = vec![b];
        let mut cur = b;
        let mut guard = 0;
        while self.succs[cur].len() == 1 {
            cur = self.succs[cur][0];
            out.push(cur);
            guard += 1;
            // A single-successor cycle (infinite empty loop) is malformed.
            assert!(guard <= self.num_blocks(), "single-successor cycle in CFG");
        }
        out
    }

    /// The condition variable of a branching block, if any.
    pub fn branch_cond(&self, b: BlockId) -> Option<VarId> {
        match self.program.blocks[b].term {
            Terminator::Branch { cond, .. } => Some(cond),
            _ => None,
        }
    }

    /// The terminal (End) blocks.
    pub fn exit_blocks(&self) -> Vec<BlockId> {
        (0..self.num_blocks())
            .filter(|&b| self.reachable(b) && matches!(self.program.blocks[b].term, Terminator::End))
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::frontend::{Instr, Rhs, Ty, Udf1};
    use crate::value::Value;

    /// Build a CFG from a shape description: per block, the list of
    /// successors; blocks with 2 successors get a synthetic boolean
    /// condition instruction. Used by cfg/ssa unit tests.
    pub fn cfg_from_shape(entry: BlockId, succs: &[&[BlockId]]) -> Cfg {
        let mut p = Program::default();
        for _ in 0..succs.len() {
            p.new_block();
        }
        p.entry = entry;
        for (b, ss) in succs.iter().enumerate() {
            p.blocks[b].term = match ss {
                [] => Terminator::End,
                [t] => Terminator::Jump(*t),
                [t, e] => {
                    let c = p.vars.len();
                    p.vars.push(crate::frontend::VarInfo {
                        name: format!("c{b}"),
                        ty: Ty::Scalar,
                    });
                    p.blocks[b].instrs.push(Instr {
                        var: c,
                        rhs: Rhs::ScalarUn {
                            input: c, // self-reference placeholder; tests only use shape
                            udf: Udf1::new("t", |_: &Value| Value::Bool(true)),
                        },
                    });
                    Terminator::Branch { cond: c, then_b: *t, else_b: *e }
                }
                _ => panic!("at most 2 successors"),
            };
        }
        // Bypass from_program's self-reference validation issues by fixing
        // the placeholder: give condition instrs a constant rhs instead.
        for blk in &mut p.blocks {
            for ins in &mut blk.instrs {
                ins.rhs = Rhs::Const(Value::Bool(true));
            }
        }
        Cfg::from_program(&p).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::cfg_from_shape;
    use super::*;
    use crate::frontend::parse_and_lower;

    #[test]
    fn while_cfg_shape() {
        let p = parse_and_lower("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");")
            .unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        // entry -> header; header -> {body, after}; body -> header.
        let header = cfg.succs[p.entry][0];
        assert_eq!(cfg.succs[header].len(), 2);
        let body = cfg.succs[header][0];
        assert_eq!(cfg.succs[body], vec![header]);
        assert!(cfg.preds[header].contains(&p.entry));
        assert!(cfg.preds[header].contains(&body));
    }

    #[test]
    fn rpo_starts_at_entry() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[3], &[3], &[]]);
        assert_eq!(cfg.rpo[0], 0);
        assert_eq!(cfg.rpo.len(), 4);
        // entry precedes its dominated blocks
        assert!(cfg.rpo_pos[0] < cfg.rpo_pos[3]);
    }

    #[test]
    fn unreachable_block_excluded_from_rpo() {
        let cfg = cfg_from_shape(0, &[&[1], &[], &[1]]);
        assert!(!cfg.reachable(2));
        assert_eq!(cfg.rpo.len(), 2);
    }

    #[test]
    fn chain_follows_single_successors() {
        // 0 -> 1 -> 2 -> {3,4}; chain(1) = [1, 2]
        let cfg = cfg_from_shape(0, &[&[1], &[2], &[3, 4], &[], &[]]);
        assert_eq!(cfg.chain(1), vec![1, 2]);
        assert_eq!(cfg.chain(3), vec![3]);
        assert_eq!(cfg.chain(0), vec![0, 1, 2]);
    }

    #[test]
    fn exit_blocks_found() {
        let cfg = cfg_from_shape(0, &[&[1, 2], &[], &[]]);
        assert_eq!(cfg.exit_blocks(), vec![1, 2]);
    }

    #[test]
    fn branch_cond_must_be_local() {
        use crate::frontend::{Instr, Rhs, Ty};
        let mut p = Program::default();
        let b0 = p.new_block();
        let b1 = p.new_block();
        let _b2 = p.new_block();
        p.entry = b0;
        let c = p.new_var("c", Ty::Scalar);
        p.blocks[b0].instrs.push(Instr { var: c, rhs: Rhs::Const(crate::Value::Bool(true)) });
        p.blocks[b0].term = Terminator::Jump(b1);
        p.blocks[b1].term = Terminator::Branch { cond: c, then_b: 2, else_b: 2 };
        assert!(Cfg::from_program(&p).is_err());
    }
}
