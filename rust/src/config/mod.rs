//! Configuration: an INI-style config file (`[section] key = value`) plus
//! `--key value` CLI overrides. Handwritten because serde/toml are
//! unavailable offline (DESIGN.md §2).

use crate::error::{Error, Result};
use rustc_hash::FxHashMap;
use std::path::Path;

/// A parsed configuration: flat `section.key → value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: FxHashMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse INI-style text: `[section]` headers, `key = value` lines,
    /// `#`/`;` comments. Keys outside a section are top-level.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if line.starts_with('[') {
                let end = line.find(']').ok_or_else(|| {
                    Error::Config(format!("line {}: unterminated section header", lineno + 1))
                })?;
                section = line[1..end].trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = line[..eq].trim();
            let mut value = line[eq + 1..].trim().to_string();
            if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value = value[1..value.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.values.insert(full, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    /// Set a value (CLI override).
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.values.insert(key.into(), value.into());
    }

    /// Get a raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Get with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed getter: usize.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected integer, got {s:?}"))),
        }
    }

    /// Typed getter: u64.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected integer, got {s:?}"))),
        }
    }

    /// Typed getter: f64.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected float, got {s:?}"))),
        }
    }

    /// Typed getter: bool (`true/false/1/0/yes/no`).
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                other => Err(Error::Config(format!("{key}: expected bool, got {other:?}"))),
            },
        }
    }

    /// All keys (sorted) — used by `labyrinth config --dump`.
    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.values.keys().cloned().collect();
        k.sort();
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::parse(
            "# comment\nworkers = 4\n[exec]\nmode = \"pipelined\"\nbatch = 256\n; other\n[sched]\nrpc_us = 120\n",
        )
        .unwrap();
        assert_eq!(cfg.get("workers"), Some("4"));
        assert_eq!(cfg.get("exec.mode"), Some("pipelined"));
        assert_eq!(cfg.get_usize("exec.batch", 0).unwrap(), 256);
        assert_eq!(cfg.get_u64("sched.rpc_us", 0).unwrap(), 120);
    }

    #[test]
    fn typed_getters_use_defaults() {
        let cfg = Config::new();
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
        assert!(cfg.get_bool("missing", true).unwrap());
    }

    #[test]
    fn bad_values_error() {
        let cfg = Config::parse("x = abc").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
        assert!(cfg.get_bool("x", false).is_err());
    }

    #[test]
    fn overrides_replace() {
        let mut cfg = Config::parse("a = 1").unwrap();
        cfg.set("a", "2");
        assert_eq!(cfg.get("a"), Some("2"));
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Config::parse("[nope").is_err());
        assert!(Config::parse("keyonly").is_err());
    }
}
