//! Non-bag lifting (§5.2): wrap every scalar value in a one-element bag
//! and rewrite scalar operations into bag operations, so that the whole
//! program — loop counters and condition booleans included — lives inside
//! the single dataflow job:
//!
//! * scalar constants become singleton bag literals;
//! * a unary scalar function becomes a `map` whose UDF is the function;
//! * a binary scalar function becomes a `cross` (producing the one-element
//!   pair bag) followed by a `map` applying the function to the pair.

use super::SsaProgram;
use crate::error::Result;
use crate::frontend::ast::Expr;
use crate::frontend::{Instr, Rhs, Ty, Udf1, VarInfo};
use crate::value::Value;

/// Rewrite a two-parameter UDF body into a one-parameter body over the
/// crossed pair: `a` becomes `fst(p$)`, `b` becomes `snd(p$)`. Returns
/// `None` for body forms the rewrite does not cover (nested lambdas,
/// method chains) — the lifted map then simply carries no metadata and
/// `opt::types` treats it as opaque. `p$` cannot collide with a user
/// identifier: the lexer rejects `$` in names.
fn subst_pair(e: &Expr, a: &str, b: &str) -> Option<Expr> {
    let recur = |x: &Expr| subst_pair(x, a, b);
    Some(match e {
        Expr::Var(n) if n == a => Expr::Call("fst".into(), vec![Expr::Var("p$".into())]),
        Expr::Var(n) if n == b => Expr::Call("snd".into(), vec![Expr::Var("p$".into())]),
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) | Expr::Var(_) => e.clone(),
        Expr::Bin(op, l, r) => Expr::Bin(*op, Box::new(recur(l)?), Box::new(recur(r)?)),
        Expr::Un(op, x) => Expr::Un(*op, Box::new(recur(x)?)),
        Expr::Call(f, args) => Expr::Call(
            f.clone(),
            args.iter().map(recur).collect::<Option<Vec<_>>>()?,
        ),
        Expr::Method(..) | Expr::Lambda(..) => return None,
    })
}

/// Lift all scalar variables and operations to bags. After this pass every
/// variable has `Ty::Bag` and no `ScalarUn` / `ScalarBin` / scalar `Const`
/// remains.
pub fn lift(mut ssa: SsaProgram) -> Result<SsaProgram> {
    for bi in 0..ssa.blocks.len() {
        let old = std::mem::take(&mut ssa.blocks[bi].instrs);
        let mut new_instrs = Vec::with_capacity(old.len());
        for instr in old {
            match instr.rhs {
                Rhs::Const(v) => {
                    new_instrs.push(Instr { var: instr.var, rhs: Rhs::BagLit(vec![v]) });
                }
                Rhs::ScalarUn { input, udf } => {
                    new_instrs.push(Instr { var: instr.var, rhs: Rhs::Map { input, udf } });
                }
                Rhs::ScalarBin { left, right, udf } => {
                    // cross: one-element bag of Pair(l, r)
                    let tmp = ssa.vars.len();
                    ssa.vars.push(VarInfo {
                        name: format!("{}×", ssa.vars[instr.var].name),
                        ty: Ty::Bag,
                    });
                    ssa.def_block.push(bi);
                    new_instrs.push(Instr { var: tmp, rhs: Rhs::Cross { left, right } });
                    // map: apply the binary function to the pair
                    let lifted_expr = udf.expr.as_ref().and_then(|e| {
                        let (params, body) = (&e.0, &e.1);
                        if params.len() == 2 {
                            subst_pair(body, &params[0], &params[1])
                        } else {
                            None
                        }
                    });
                    let inner = udf;
                    let name = format!("lift<{}>", inner.name);
                    let mut udf1 = Udf1::new(name, move |p: &Value| match p {
                        Value::Pair(ab) => inner.call(&ab.0, &ab.1),
                        other => panic!("lifted binary op expects a pair, got {other:?}"),
                    });
                    if let Some(body) = lifted_expr {
                        udf1 = udf1.with_expr(vec!["p$".into()], body);
                    }
                    new_instrs.push(Instr {
                        var: instr.var,
                        rhs: Rhs::Map { input: tmp, udf: udf1 },
                    });
                }
                rhs => new_instrs.push(Instr { var: instr.var, rhs }),
            }
        }
        ssa.blocks[bi].instrs = new_instrs;
    }
    for v in &mut ssa.vars {
        v.ty = Ty::Bag;
    }
    Ok(ssa)
}

#[cfg(test)]
mod tests {
    use crate::cfg::Cfg;
    use crate::frontend::{parse_and_lower, Rhs, Ty};
    use crate::ssa;

    fn lifted(src: &str) -> ssa::SsaProgram {
        let p = parse_and_lower(src).unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        ssa::lift::lift(ssa::construct(&cfg).unwrap()).unwrap()
    }

    #[test]
    fn scalars_become_singleton_bags() {
        let s = lifted("a = 1; b = a + 2; writeFile(bag(9), \"o\" + str(b));");
        for b in &s.blocks {
            for i in &b.instrs {
                assert!(
                    !matches!(
                        i.rhs,
                        Rhs::Const(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. }
                    ),
                    "unlifted scalar op remains: {}",
                    i.rhs.mnemonic()
                );
            }
        }
        for v in &s.vars {
            assert_eq!(v.ty, Ty::Bag);
        }
    }

    #[test]
    fn binary_scalar_becomes_cross_plus_map() {
        let s = lifted("a = 1; b = a + 2; writeFile(bag(9), \"o\" + str(b));");
        let has_cross = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .any(|i| matches!(i.rhs, Rhs::Cross { .. }));
        assert!(has_cross, "{}", s.listing());
        // The cross result feeds a map in the same block.
        let cross_var = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .find(|i| matches!(i.rhs, Rhs::Cross { .. }))
            .unwrap()
            .var;
        let consumed_by_map = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .any(|i| matches!(&i.rhs, Rhs::Map { input, .. } if *input == cross_var));
        assert!(consumed_by_map);
    }

    #[test]
    fn lifted_udf_applies_to_pair() {
        // Execute the lifted cross+map chain by hand.
        let s = lifted("a = 2; b = a * 3; writeFile(bag(1), \"o\" + str(b));");
        let map = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter_map(|i| match &i.rhs {
                Rhs::Map { udf, .. } if udf.name.starts_with("lift<") => Some(udf.clone()),
                _ => None,
            })
            .next()
            .unwrap();
        let out = map.call(&crate::Value::pair(crate::Value::I64(2), crate::Value::I64(3)));
        assert_eq!(out, crate::Value::I64(6));
    }

    #[test]
    fn loop_counter_lifts_inside_loop() {
        let s = lifted("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");");
        // Phi for the (now bag-typed) loop counter survives lifting.
        let has_phi = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .any(|i| matches!(i.rhs, Rhs::Phi(_)));
        assert!(has_phi);
    }
}
