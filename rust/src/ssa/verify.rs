//! SSA verifier: checks the invariants the dataflow translation (§5.3)
//! and the coordination protocol (§6.3) rely on.

use super::SsaProgram;
use crate::cfg::dom;
use crate::error::{Error, Result};
use crate::frontend::{Rhs, Terminator, VarId};
use rustc_hash::FxHashMap;

/// Verify:
/// 1. every variable is assigned exactly once;
/// 2. every ordinary use is dominated by its definition;
/// 3. Φ arguments come from distinct predecessor blocks covering all
///    predecessors, and each argument's definition dominates its
///    predecessor block;
/// 4. Φ arguments have pairwise-distinct *defining* blocks (§6.3.3's
///    longest-prefix input selection requires this to disambiguate);
/// 5. branch conditions are defined in the branching block.
pub fn verify(ssa: &SsaProgram) -> Result<()> {
    let dt = dom::dominators(&ssa.cfg);

    // 1. single assignment + def table.
    let mut def_at: FxHashMap<VarId, usize> = FxHashMap::default();
    for (bi, b) in ssa.blocks.iter().enumerate() {
        for i in &b.instrs {
            if def_at.insert(i.var, bi).is_some() {
                return Err(Error::SsaVerify(format!(
                    "variable '{}' assigned more than once",
                    ssa.vars[i.var].name
                )));
            }
            if ssa.def_block[i.var] != bi {
                return Err(Error::SsaVerify(format!(
                    "def_block table stale for '{}'",
                    ssa.vars[i.var].name
                )));
            }
        }
    }

    let defined = |v: VarId| -> Result<usize> {
        def_at.get(&v).copied().ok_or_else(|| {
            Error::SsaVerify(format!("use of undefined variable '{}'", ssa.vars[v].name))
        })
    };

    for (bi, b) in ssa.blocks.iter().enumerate() {
        let mut seen_non_phi = false;
        for (pos, i) in b.instrs.iter().enumerate() {
            match &i.rhs {
                Rhs::Phi(args) => {
                    if seen_non_phi {
                        return Err(Error::SsaVerify(format!(
                            "Φ for '{}' appears after ordinary instructions in bb{bi}",
                            ssa.vars[i.var].name
                        )));
                    }
                    // 3a. every arg comes in through an actual predecessor
                    //     it dominates (args may be deduped by variable, so
                    //     one arg can cover several predecessors).
                    for &(p, v) in args {
                        if !ssa.cfg.preds[bi].contains(&p) {
                            return Err(Error::SsaVerify(format!(
                                "Φ for '{}' at bb{bi} has arg from non-pred bb{p}",
                                ssa.vars[i.var].name
                            )));
                        }
                        let db = defined(v)?;
                        if !dt.dominates(db, p) {
                            return Err(Error::SsaVerify(format!(
                                "Φ arg '{}' (def bb{db}) does not dominate pred bb{p}",
                                ssa.vars[v].name
                            )));
                        }
                    }
                    // 3b. coverage: every predecessor is reached by some
                    //     argument's definition.
                    for &p in &ssa.cfg.preds[bi] {
                        let covered = args.iter().any(|&(_, v)| {
                            def_at.get(&v).map(|&db| dt.dominates(db, p)).unwrap_or(false)
                        });
                        if !covered {
                            return Err(Error::SsaVerify(format!(
                                "Φ for '{}' at bb{bi}: predecessor bb{p} carries no value",
                                ssa.vars[i.var].name
                            )));
                        }
                    }
                    // 4. distinct variables with distinct defining blocks —
                    //    the §6.3.3 longest-prefix rule disambiguates by
                    //    definition block.
                    let mut vars_seen: Vec<VarId> = Vec::new();
                    let mut def_blocks: Vec<usize> = Vec::new();
                    for &(_, v) in args {
                        if vars_seen.contains(&v) {
                            return Err(Error::SsaVerify(format!(
                                "Φ for '{}' at bb{bi} repeats argument '{}' (dedupe pass missing)",
                                ssa.vars[i.var].name, ssa.vars[v].name
                            )));
                        }
                        vars_seen.push(v);
                        def_blocks.push(defined(v)?);
                    }
                    let len = def_blocks.len();
                    def_blocks.sort();
                    def_blocks.dedup();
                    if def_blocks.len() != len {
                        return Err(Error::SsaVerify(format!(
                            "Φ for '{}' at bb{bi} has two distinct arguments defined \
                             in the same block; the execution-path input selection \
                             of §6.3.3 cannot disambiguate them",
                            ssa.vars[i.var].name
                        )));
                    }
                }
                rhs => {
                    seen_non_phi = true;
                    for u in rhs.input_vars() {
                        let db = defined(u)?;
                        // 2. def dominates use: same block earlier, or a
                        // strictly dominating block.
                        let ok = if db == bi {
                            b.instrs[..pos].iter().any(|x| x.var == u)
                        } else {
                            dt.dominates(db, bi)
                        };
                        if !ok {
                            return Err(Error::SsaVerify(format!(
                                "use of '{}' in bb{bi} not dominated by its def in bb{db}",
                                ssa.vars[u].name
                            )));
                        }
                    }
                }
            }
        }
        // 5. branch condition local.
        if let Terminator::Branch { cond, .. } = b.term {
            let db = defined(cond)?;
            if db != bi {
                return Err(Error::SsaVerify(format!(
                    "branch condition '{}' of bb{bi} defined in bb{db}; condition \
                     nodes must live in the deciding block (§5.3)",
                    ssa.vars[cond].name
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::cfg::Cfg;
    use crate::frontend::parse_and_lower;
    use crate::ssa;

    #[test]
    fn well_formed_programs_verify() {
        for src in [
            "a = 1; b = a + 1; collect(bag(1), \"x\");",
            "d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");",
            "x = 1; if (x != 1) { x = 2; } else { x = 3; } y = x; collect(bag(1), \"x\");",
            "i = 0; while (i < 2) { j = 0; while (j < 2) { j = j + 1; } i = i + 1; } collect(bag(1), \"x\");",
        ] {
            let p = parse_and_lower(src).unwrap();
            let cfg = Cfg::from_program(&p).unwrap();
            ssa::construct(&cfg).unwrap_or_else(|e| panic!("{src}: {e}"));
        }
    }

    #[test]
    fn verifier_rejects_double_assignment() {
        let src = "d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");";
        let p = parse_and_lower(src).unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        let mut s = ssa::construct(&cfg).unwrap();
        // Corrupt: duplicate an instruction.
        let dup = s.blocks[s.entry].instrs[0].clone();
        s.blocks[s.entry].instrs.push(dup);
        assert!(ssa::verify::verify(&s).is_err());
    }

    #[test]
    fn verifier_rejects_stale_def_block() {
        let src = "a = 1; b = a + 1; collect(bag(1), \"x\");";
        let p = parse_and_lower(src).unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        let mut s = ssa::construct(&cfg).unwrap();
        let live_var = s.blocks[s.entry].instrs[0].var;
        s.def_block[live_var] = 999;
        // Either stale table or undefined-use error; must not verify.
        assert!(ssa::verify::verify(&s).is_err());
    }
}
