//! SSA cleanup passes: copy propagation, Φ simplification, and dead code
//! elimination. These keep the generated dataflow graphs free of identity
//! nodes (every `Rhs::Copy` the frontends emit for `a = b` assignments
//! disappears here rather than becoming a dataflow operator).

use super::SsaProgram;
use crate::frontend::{Rhs, Terminator, VarId};

/// Replace uses of copy targets with their sources and drop the copies.
/// Chains of copies resolve transitively.
pub fn copy_propagate(mut ssa: SsaProgram) -> SsaProgram {
    let nvars = ssa.vars.len();
    // Resolve the copy-of chain for each variable.
    let mut alias: Vec<VarId> = (0..nvars).collect();
    for b in &ssa.blocks {
        for i in &b.instrs {
            if let Rhs::Copy(src) = i.rhs {
                alias[i.var] = src;
            }
        }
    }
    let resolve = |alias: &[VarId], mut v: VarId| -> VarId {
        let mut steps = 0;
        while alias[v] != v {
            v = alias[v];
            steps += 1;
            assert!(steps <= nvars, "copy cycle");
        }
        v
    };
    let resolved: Vec<VarId> = (0..nvars).map(|v| resolve(&alias, v)).collect();

    for b in &mut ssa.blocks {
        b.instrs.retain(|i| !matches!(i.rhs, Rhs::Copy(_)));
        for i in &mut b.instrs {
            i.rhs.map_inputs(|u| resolved[u]);
        }
        if let Terminator::Branch { cond, .. } = &mut b.term {
            *cond = resolved[*cond];
        }
    }
    ssa
}

/// Replace `x = Φ(y, y, ... y)` (all arguments identical) by rewriting
/// uses of `x` to `y` and dropping the Φ. Iterates to a fixpoint (Φs can
/// collapse transitively).
pub fn simplify_phis(mut ssa: SsaProgram) -> SsaProgram {
    loop {
        let nvars = ssa.vars.len();
        let mut alias: Vec<VarId> = (0..nvars).collect();
        let mut any = false;
        for b in &ssa.blocks {
            for i in &b.instrs {
                if let Rhs::Phi(args) = &i.rhs {
                    let first = args[0].1;
                    if args.iter().all(|&(_, v)| v == first) && first != i.var {
                        alias[i.var] = first;
                        any = true;
                    }
                }
            }
        }
        if !any {
            return ssa;
        }
        let resolve = |alias: &[VarId], mut v: VarId| -> VarId {
            let mut steps = 0;
            while alias[v] != v {
                v = alias[v];
                steps += 1;
                assert!(steps <= nvars, "phi alias cycle");
            }
            v
        };
        let resolved: Vec<VarId> = (0..nvars).map(|v| resolve(&alias, v)).collect();
        for b in &mut ssa.blocks {
            b.instrs.retain(|i| resolved[i.var] == i.var || !matches!(i.rhs, Rhs::Phi(_)));
            for i in &mut b.instrs {
                i.rhs.map_inputs(|u| resolved[u]);
            }
            if let Terminator::Branch { cond, .. } = &mut b.term {
                *cond = resolved[*cond];
            }
        }
    }
}

/// Merge Φ arguments that carry the SAME SSA variable from different
/// predecessors (created by `break`/`continue`, where several incoming
/// edges propagate one definition). A Φ argument is a *dataflow input*
/// (§5.3): one variable = one edge, regardless of how many CFG
/// predecessors deliver it. The §6.3.3 longest-prefix selection is
/// per-definition, so the merged edge behaves identically.
pub fn dedupe_phi_args(mut ssa: SsaProgram) -> SsaProgram {
    for b in &mut ssa.blocks {
        for i in &mut b.instrs {
            if let Rhs::Phi(args) = &mut i.rhs {
                let mut seen: Vec<VarId> = Vec::new();
                args.retain(|&(_, v)| {
                    if seen.contains(&v) {
                        false
                    } else {
                        seen.push(v);
                        true
                    }
                });
            }
        }
    }
    ssa
}

/// Remove pure instructions whose results are never used. Side-effecting
/// operations (`writeFile`, `collect`) and branch conditions are roots.
/// Works backwards to a fixpoint so dead chains disappear entirely.
pub fn dead_code_eliminate(mut ssa: SsaProgram) -> SsaProgram {
    let nvars = ssa.vars.len();
    let mut live = vec![false; nvars];
    let mut work: Vec<VarId> = Vec::new();
    for b in &ssa.blocks {
        for i in &b.instrs {
            if matches!(i.rhs, Rhs::WriteFile { .. } | Rhs::Collect { .. }) {
                if !live[i.var] {
                    live[i.var] = true;
                    work.push(i.var);
                }
            }
        }
        if let Terminator::Branch { cond, .. } = b.term {
            if !live[cond] {
                live[cond] = true;
                work.push(cond);
            }
        }
    }
    // Index defs.
    let mut def_rhs: Vec<Option<&Rhs>> = vec![None; nvars];
    for b in &ssa.blocks {
        for i in &b.instrs {
            def_rhs[i.var] = Some(&i.rhs);
        }
    }
    while let Some(v) = work.pop() {
        if let Some(rhs) = def_rhs[v] {
            for u in rhs.input_vars() {
                if !live[u] {
                    live[u] = true;
                    work.push(u);
                }
            }
        }
    }
    drop(def_rhs);
    for b in &mut ssa.blocks {
        b.instrs.retain(|i| live[i.var]);
    }
    ssa
}

#[cfg(test)]
mod tests {
    use crate::cfg::Cfg;
    use crate::frontend::{parse_and_lower, Rhs};
    use crate::ssa;

    fn ssa_of(src: &str) -> ssa::SsaProgram {
        let p = parse_and_lower(src).unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        ssa::construct(&cfg).unwrap()
    }

    #[test]
    fn copies_are_eliminated() {
        let s = ssa_of("a = bag(1, 2); b = a; collect(b, \"x\");");
        for blk in &s.blocks {
            for i in &blk.instrs {
                assert!(!matches!(i.rhs, Rhs::Copy(_)), "{}", s.listing());
            }
        }
        // collect consumes the bag literal directly.
        let collect = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .find(|i| matches!(i.rhs, Rhs::Collect { .. }))
            .unwrap();
        let input = collect.rhs.input_vars()[0];
        assert!(matches!(s.def_instr(input).unwrap().rhs, Rhs::BagLit(_)));
    }

    #[test]
    fn dead_code_removed() {
        let s = ssa_of("a = bag(1); dead = a.map(|x| x + 1); collect(a, \"out\");");
        let maps = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter(|i| matches!(i.rhs, Rhs::Map { .. }))
            .count();
        assert_eq!(maps, 0, "{}", s.listing());
    }

    #[test]
    fn condition_chain_survives_dce() {
        let s = ssa_of("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");");
        // The loop counter arithmetic feeds the condition; it must survive.
        let has_scalar_ops = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .any(|i| matches!(i.rhs, Rhs::ScalarBin { .. }));
        assert!(has_scalar_ops, "{}", s.listing());
    }

    #[test]
    fn side_effects_are_roots() {
        let s = ssa_of("a = bag(1); writeFile(a, \"f\");");
        let writes = s
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter(|i| matches!(i.rhs, Rhs::WriteFile { .. }))
            .count();
        assert_eq!(writes, 1);
    }
}
