//! SSA construction (§4.1 "Compiling to SSA"): pruned-SSA Φ insertion via
//! iterated dominance frontiers, variable renaming over the dominator
//! tree, plus cleanup passes (copy propagation, Φ simplification, dead
//! code elimination) and an SSA verifier.

pub mod lift;
pub mod passes;
pub mod verify;

use crate::cfg::{dom, Cfg};
use crate::error::{Error, Result};
use crate::frontend::{Block, BlockId, Instr, Rhs, Terminator, Ty, VarId, VarInfo};
use rustc_hash::{FxHashMap, FxHashSet};

/// A program in SSA form. Blocks start with Φ instructions
/// (`Rhs::Phi(args)` with `(predecessor block, ssa var)` arguments),
/// followed by ordinary instructions.
#[derive(Clone, Debug)]
pub struct SsaProgram {
    /// Basic blocks (instruction targets are SSA variables).
    pub blocks: Vec<Block>,
    /// Entry block.
    pub entry: BlockId,
    /// SSA variable table.
    pub vars: Vec<VarInfo>,
    /// Defining block of each SSA variable.
    pub def_block: Vec<BlockId>,
    /// The CFG this SSA was built over (shapes are identical).
    pub cfg: Cfg,
}

impl SsaProgram {
    /// Render a readable listing (mirrors Fig. 3a of the paper).
    pub fn listing(&self) -> String {
        let mut out = String::new();
        for (bi, b) in self.blocks.iter().enumerate() {
            out.push_str(&format!(
                "bb{}{}:\n",
                bi,
                if bi == self.entry { " (entry)" } else { "" }
            ));
            for i in &b.instrs {
                match &i.rhs {
                    Rhs::Phi(args) => {
                        let a = args
                            .iter()
                            .map(|(p, v)| format!("{}@bb{}", self.vars[*v].name, p))
                            .collect::<Vec<_>>()
                            .join(", ");
                        out.push_str(&format!("  {} = Φ({a})\n", self.vars[i.var].name));
                    }
                    rhs => {
                        let ins = rhs
                            .input_vars()
                            .iter()
                            .map(|v| self.vars[*v].name.clone())
                            .collect::<Vec<_>>()
                            .join(", ");
                        out.push_str(&format!(
                            "  {} = {}({})\n",
                            self.vars[i.var].name,
                            rhs.mnemonic(),
                            ins
                        ));
                    }
                }
            }
            match &b.term {
                Terminator::Jump(t) => out.push_str(&format!("  jump bb{t}\n")),
                Terminator::Branch { cond, then_b, else_b } => out.push_str(&format!(
                    "  branch {} ? bb{} : bb{}\n",
                    self.vars[*cond].name, then_b, else_b
                )),
                Terminator::End => out.push_str("  end\n"),
            }
        }
        out
    }

    /// Find the (unique) defining instruction of an SSA variable.
    pub fn def_instr(&self, v: VarId) -> Option<&Instr> {
        self.blocks[self.def_block[v]].instrs.iter().find(|i| i.var == v)
    }
}

/// Per-block liveness of the *original* (pre-SSA) variables: `live_in[b]`
/// contains variables whose value may be read before being overwritten on
/// some path from the start of `b`. Used for pruned SSA (no Φs for dead
/// variables, and — critically for the dataflow translation — no
/// undefined-input Φs for variables like `visits` that are reassigned
/// every iteration before use).
fn live_in_sets(cfg: &Cfg) -> Vec<FxHashSet<VarId>> {
    let n = cfg.num_blocks();
    let mut gen_: Vec<FxHashSet<VarId>> = vec![FxHashSet::default(); n];
    let mut kill: Vec<FxHashSet<VarId>> = vec![FxHashSet::default(); n];
    for (b, blk) in cfg.program.blocks.iter().enumerate() {
        for i in &blk.instrs {
            for u in i.rhs.input_vars() {
                if !kill[b].contains(&u) {
                    gen_[b].insert(u);
                }
            }
            kill[b].insert(i.var);
        }
        if let Terminator::Branch { cond, .. } = blk.term {
            if !kill[b].contains(&cond) {
                gen_[b].insert(cond);
            }
        }
    }
    let mut live_in: Vec<FxHashSet<VarId>> = vec![FxHashSet::default(); n];
    let mut changed = true;
    while changed {
        changed = false;
        // Backward: iterate post-order (reverse of rpo).
        for &b in cfg.rpo.iter().rev() {
            let mut live_out: FxHashSet<VarId> = FxHashSet::default();
            for &s in &cfg.succs[b] {
                live_out.extend(live_in[s].iter().copied());
            }
            let mut new_in = gen_[b].clone();
            for v in live_out {
                if !kill[b].contains(&v) {
                    new_in.insert(v);
                }
            }
            if new_in.len() != live_in[b].len() {
                live_in[b] = new_in;
                changed = true;
            }
        }
    }
    live_in
}

/// Construct pruned SSA from a validated CFG, then run cleanup passes
/// (copy propagation, Φ simplification, DCE) and verify the result.
pub fn construct(cfg: &Cfg) -> Result<SsaProgram> {
    let ssa = construct_raw(cfg)?;
    let ssa = passes::copy_propagate(ssa);
    let ssa = passes::simplify_phis(ssa);
    let ssa = passes::dedupe_phi_args(ssa);
    let ssa = passes::dead_code_eliminate(ssa);
    verify::verify(&ssa)?;
    Ok(ssa)
}

/// Φ insertion + renaming, without cleanup.
pub fn construct_raw(cfg: &Cfg) -> Result<SsaProgram> {
    let dt = dom::dominators(cfg);
    let live_in = live_in_sets(cfg);
    let nblocks = cfg.num_blocks();
    let orig_vars = &cfg.program.vars;

    // --- Φ insertion (iterated dominance frontier, pruned by liveness) ---
    // phi_for[b] = ordered list of original variables needing a Φ at b.
    let mut phi_for: Vec<Vec<VarId>> = vec![Vec::new(); nblocks];
    let mut def_blocks: FxHashMap<VarId, FxHashSet<BlockId>> = FxHashMap::default();
    for (b, blk) in cfg.program.blocks.iter().enumerate() {
        if !cfg.reachable(b) {
            continue;
        }
        for i in &blk.instrs {
            def_blocks.entry(i.var).or_default().insert(b);
        }
    }
    for (&v, defs) in def_blocks.iter() {
        if defs.len() < 2 {
            continue;
        }
        let mut has_phi: FxHashSet<BlockId> = FxHashSet::default();
        let mut work: Vec<BlockId> = defs.iter().copied().collect();
        while let Some(x) = work.pop() {
            for &y in &dt.frontier[x] {
                if !has_phi.contains(&y) && live_in[y].contains(&v) {
                    has_phi.insert(y);
                    phi_for[y].push(v);
                    if !defs.contains(&y) {
                        work.push(y);
                    }
                }
            }
        }
    }
    for phis in &mut phi_for {
        phis.sort();
    }

    // --- Renaming over the dominator tree ---
    struct Renamer<'a> {
        cfg: &'a Cfg,
        dt: &'a dom::DomTree,
        phi_for: &'a [Vec<VarId>],
        stacks: Vec<Vec<VarId>>, // per original var: stack of SSA vars
        version: Vec<usize>,     // per original var: next version number
        new_vars: Vec<VarInfo>,
        def_block: Vec<BlockId>,
        // Output blocks: instrs rewritten; Φs are placed first.
        out_blocks: Vec<Block>,
        // For each block: the Φ targets (SSA var per phi_for entry).
        phi_targets: Vec<Vec<VarId>>,
        // Collected Φ args: (block, phi_index) -> Vec<(pred, ssa var)>.
        phi_args: FxHashMap<(BlockId, usize), Vec<(BlockId, VarId)>>,
    }

    impl<'a> Renamer<'a> {
        fn fresh(&mut self, orig: VarId, ty: Ty, block: BlockId) -> VarId {
            let ver = self.version[orig];
            self.version[orig] += 1;
            let name = if ver == 0 {
                self.cfg.program.vars[orig].name.clone()
            } else {
                format!("{}_{}", self.cfg.program.vars[orig].name, ver)
            };
            self.new_vars.push(VarInfo { name, ty });
            self.def_block.push(block);
            self.new_vars.len() - 1
        }

        fn top(&self, orig: VarId) -> Result<VarId> {
            self.stacks[orig].last().copied().ok_or_else(|| {
                Error::Ir(format!(
                    "variable '{}' may be used before assignment",
                    self.cfg.program.vars[orig].name
                ))
            })
        }

        fn rename_block(&mut self, b: BlockId) -> Result<()> {
            let mut pushed: Vec<VarId> = Vec::new();

            // Φ targets first.
            for &orig in &self.phi_for[b] {
                let ty = self.cfg.program.vars[orig].ty;
                let nv = self.fresh(orig, ty, b);
                self.stacks[orig].push(nv);
                pushed.push(orig);
                self.phi_targets[b].push(nv);
            }

            // Ordinary instructions.
            let mut new_instrs: Vec<Instr> = Vec::new();
            for instr in &self.cfg.program.blocks[b].instrs {
                let mut rhs = instr.rhs.clone();
                // Resolve uses against current stacks.
                let mut err: Option<Error> = None;
                rhs.map_inputs(|u| match self.top(u) {
                    Ok(v) => v,
                    Err(e) => {
                        err = Some(e);
                        u
                    }
                });
                if let Some(e) = err {
                    return Err(e);
                }
                let ty = self.cfg.program.vars[instr.var].ty;
                let nv = self.fresh(instr.var, ty, b);
                self.stacks[instr.var].push(nv);
                pushed.push(instr.var);
                new_instrs.push(Instr { var: nv, rhs });
            }

            // Terminator.
            let term = match self.cfg.program.blocks[b].term.clone() {
                Terminator::Branch { cond, then_b, else_b } => {
                    Terminator::Branch { cond: self.top(cond)?, then_b, else_b }
                }
                t => t,
            };
            self.out_blocks[b] = Block { instrs: new_instrs, term };

            // Fill successor Φ arguments.
            for &s in &self.cfg.succs[b] {
                for (pi, &orig) in self.phi_for[s].iter().enumerate() {
                    let arg = self.top(orig)?;
                    self.phi_args.entry((s, pi)).or_default().push((b, arg));
                }
            }

            // Recurse into dominator-tree children.
            for &c in &self.dt.children[b] {
                self.rename_block(c)?;
            }

            for orig in pushed.into_iter().rev() {
                self.stacks[orig].pop();
            }
            Ok(())
        }
    }

    let mut r = Renamer {
        cfg,
        dt: &dt,
        phi_for: &phi_for,
        stacks: vec![Vec::new(); orig_vars.len()],
        version: vec![0; orig_vars.len()],
        new_vars: Vec::new(),
        def_block: Vec::new(),
        out_blocks: vec![Block::default(); nblocks],
        phi_targets: vec![Vec::new(); nblocks],
        phi_args: FxHashMap::default(),
    };
    r.rename_block(cfg.program.entry)?;

    // Materialize Φ instructions at block starts.
    let mut blocks = r.out_blocks;
    for b in (0..nblocks).rev() {
        for (pi, &target) in r.phi_targets[b].iter().enumerate().rev() {
            let args = r.phi_args.remove(&(b, pi)).unwrap_or_default();
            if args.len() < 2 {
                return Err(Error::Ir(format!(
                    "Φ for '{}' at bb{b} has {} argument(s); program has a \
                     maybe-undefined variable on some path",
                    r.new_vars[target].name,
                    args.len()
                )));
            }
            blocks[b].instrs.insert(0, Instr { var: target, rhs: Rhs::Phi(args) });
        }
    }

    Ok(SsaProgram {
        blocks,
        entry: cfg.program.entry,
        vars: r.new_vars,
        def_block: r.def_block,
        cfg: cfg.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    fn ssa_of(src: &str) -> SsaProgram {
        let p = parse_and_lower(src).unwrap();
        let cfg = Cfg::from_program(&p).unwrap();
        construct(&cfg).unwrap()
    }

    #[test]
    fn straightline_renames_reassignment() {
        // Listing 1a of the paper: a=1; b=a+a; a=b+2; c=a*3. After SSA (+
        // copy propagation), every variable is assigned exactly once and
        // the two writes to `a` end up in distinct SSA variables.
        let ssa = ssa_of("a = 1; b = a + a; a = b + 2; c = a * 3; writeFile(bag(1), \"o\" + str(c));");
        let listing = ssa.listing();
        let mut targets: Vec<crate::frontend::VarId> = Vec::new();
        for b in &ssa.blocks {
            for i in &b.instrs {
                assert!(!targets.contains(&i.var), "double assignment:\n{listing}");
                targets.push(i.var);
            }
        }
        // The reassigned `a` keeps only one instruction under its name.
        let a_defs = ssa
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter(|i| ssa.vars[i.var].name == "a")
            .count();
        assert_eq!(a_defs, 1, "{listing}");
        // No Φ in straight-line code.
        assert!(!listing.contains("Φ"), "{listing}");
    }

    #[test]
    fn loop_counter_gets_phi_in_header() {
        let ssa = ssa_of("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"out\");");
        let listing = ssa.listing();
        assert!(listing.contains("Φ"), "{listing}");
        // The Φ must be in the loop header: find the block with a branch.
        let header = ssa
            .blocks
            .iter()
            .position(|b| matches!(b.term, Terminator::Branch { .. }))
            .unwrap();
        assert!(
            ssa.blocks[header].instrs.iter().any(|i| matches!(i.rhs, Rhs::Phi(_))),
            "{listing}"
        );
    }

    #[test]
    fn if_merge_gets_phi() {
        let ssa = ssa_of(
            "x = 1; c = bag(1); if (x != 1) { x = 2; } else { x = 3; } y = x + 1; writeFile(c, \"o\" + str(y));",
        );
        let phi = ssa
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .find(|i| matches!(i.rhs, Rhs::Phi(_)))
            .expect("phi expected");
        match &phi.rhs {
            Rhs::Phi(args) => assert_eq!(args.len(), 2),
            _ => unreachable!(),
        }
    }

    #[test]
    fn pruned_ssa_no_phi_for_loop_local() {
        // `v` is reassigned at the start of every iteration before use:
        // pruned SSA must NOT create a Φ for it (it is not live into the
        // header), otherwise the dataflow would contain an undefined input.
        let ssa = ssa_of(
            "d = 1; while (d <= 3) { v = bag(1, 2); c = v.count(); d = d + c; } collect(bag(0), \"z\");",
        );
        let header = ssa
            .blocks
            .iter()
            .position(|b| matches!(b.term, Terminator::Branch { .. }))
            .unwrap();
        let phis = ssa.blocks[header]
            .instrs
            .iter()
            .filter(|i| matches!(i.rhs, Rhs::Phi(_)))
            .count();
        // Only `d` needs a Φ.
        assert_eq!(phis, 1, "{}", ssa.listing());
    }

    #[test]
    fn use_before_assignment_rejected() {
        let p = parse_and_lower(
            "d = 1; if (d != 1) { x = 2; } y = x + 1; collect(bag(1), \"x\");",
        );
        // `x` is only defined on one path; SSA construction must reject.
        let cfg = Cfg::from_program(&p.unwrap()).unwrap();
        assert!(construct(&cfg).is_err());
    }

    #[test]
    fn nested_loops_phi_at_both_headers() {
        let ssa = ssa_of(
            "i = 0; s = 0; while (i < 3) { j = 0; while (j < 2) { s = s + 1; j = j + 1; } i = i + 1; } collect(bag(1), \"s\");",
        );
        let phi_blocks: Vec<usize> = ssa
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.instrs.iter().any(|i| matches!(i.rhs, Rhs::Phi(_))))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(phi_blocks.len(), 2, "{}", ssa.listing());
        // s needs Φs at both headers; i only at the outer one; j only inner.
    }

    #[test]
    fn visit_count_ssa_matches_paper_structure() {
        let src = r#"
            attrs = source("pageAttributes");
            day = 1;
            yesterday = bag();
            while (day <= 5) {
                visits = source("visits").join(attrs);
                counts = visits.map(|p| pair(fst(p), 1)).reduceByKey(|a, b| a + b);
                if (day != 1) {
                    diffs = counts.join(yesterday).map(|p| snd(p));
                    collect(diffs, "diffs");
                }
                yesterday = counts;
                day = day + 1;
            }
        "#;
        let ssa = ssa_of(src);
        let listing = ssa.listing();
        // Paper Fig. 3a: Φs for day and yesterdayCounts in the loop header.
        let header = ssa
            .blocks
            .iter()
            .position(|b| {
                matches!(b.term, Terminator::Branch { .. })
                    && b.instrs.iter().any(|i| matches!(i.rhs, Rhs::Phi(_)))
            })
            .unwrap_or_else(|| panic!("no header with phis:\n{listing}"));
        let phis = ssa.blocks[header]
            .instrs
            .iter()
            .filter(|i| matches!(i.rhs, Rhs::Phi(_)))
            .count();
        assert_eq!(phis, 2, "{listing}");
        // attrs must NOT have a Φ (loop-invariant).
        assert!(!listing.contains("attrs_1"), "{listing}");
    }
}
