//! Error types for the whole Labyrinth stack.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors raised by the compiler pipeline, the coordination runtime, the
/// executors, and the PJRT bridge.
#[derive(Debug, Error)]
pub enum Error {
    /// Lexer error with 1-based line/column.
    #[error("lex error at {line}:{col}: {msg}")]
    Lex { line: usize, col: usize, msg: String },

    /// Parser error with 1-based line/column.
    #[error("parse error at {line}:{col}: {msg}")]
    Parse { line: usize, col: usize, msg: String },

    /// Semantic / type error in a LabyLang program.
    #[error("type error: {0}")]
    Type(String),

    /// Malformed IR detected while building the CFG or SSA.
    #[error("ir error: {0}")]
    Ir(String),

    /// SSA verification failure (internal compiler invariant).
    #[error("ssa verification failed: {0}")]
    SsaVerify(String),

    /// Dataflow graph construction failure.
    #[error("dataflow build error: {0}")]
    Dataflow(String),

    /// Coordination-protocol invariant violation at runtime.
    #[error("coordination error: {0}")]
    Coordination(String),

    /// Execution engine failure (worker panic, channel breakage, ...).
    #[error("execution error: {0}")]
    Exec(String),

    /// Run aborted by its cooperative cancel token (`ExecConfig::cancel`)
    /// — an expected outcome, not a failure. Typed so callers (the
    /// `serve::` metrics classification) never probe message text, which
    /// could collide with user-chosen names embedded in diagnostics.
    #[error("job canceled mid-run")]
    Canceled,

    /// Run aborted by its deadline (`ExecConfig::deadline`). Typed for
    /// the same reason as [`Error::Canceled`].
    #[error("job deadline exceeded")]
    DeadlineExceeded,

    /// Request shed at admission because the tenant's queued work already
    /// exceeds its cost budget (`serve::TenantSpec::budget`). Carries a
    /// retry hint so clients can back off instead of hammering the front
    /// door. Typed for the same reason as [`Error::Canceled`]: the serve
    /// metrics classify sheds (`serve.jobs_shed`) without probing text.
    #[error("overloaded: tenant backlog over budget, retry after {retry_after_ms} ms")]
    Overloaded { retry_after_ms: u64 },

    /// Errors from the baseline executors.
    #[error("baseline error: {0}")]
    Baseline(String),

    /// Configuration file / CLI parsing problems.
    #[error("config error: {0}")]
    Config(String),

    /// PJRT / XLA artifact problems.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Underlying I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor for coordination-invariant failures.
    pub fn coord(msg: impl Into<String>) -> Error {
        Error::Coordination(msg.into())
    }
    /// Shorthand constructor for execution failures.
    pub fn exec(msg: impl Into<String>) -> Error {
        Error::Exec(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_location() {
        let e = Error::Parse { line: 3, col: 7, msg: "expected ')'".into() };
        assert_eq!(e.to_string(), "parse error at 3:7: expected ')'");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(e.to_string().contains("nope"));
    }
}
