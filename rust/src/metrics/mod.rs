//! Execution metrics: counters and timers collected by the engine and the
//! baselines, reported by the CLI and recorded in EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A shareable metrics sink. All counters are lock-free; the name map is
/// append-mostly and guarded by a mutex.
#[derive(Default, Debug)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
}

impl Metrics {
    /// Create an empty sink.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Get (or create) the counter handle for `name`.
    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Add `v` to counter `name`.
    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds under `name` (sum) and bump
    /// `name.count`, enabling mean computation at report time.
    pub fn record_time(&self, name: &str, d: Duration) {
        self.add(&format!("{name}.ns"), d.as_nanos() as u64);
        self.add(&format!("{name}.count"), 1);
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Value of a single counter (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        for (k, v) in &snap {
            if let Some(base) = k.strip_suffix(".ns") {
                let count = snap.get(&format!("{base}.count")).copied().unwrap_or(0);
                if count > 0 {
                    out.push_str(&format!(
                        "{base}: total {} over {count} events (mean {})\n",
                        crate::util::fmt_duration(Duration::from_nanos(*v)),
                        crate::util::fmt_duration(Duration::from_nanos(v / count)),
                    ));
                    continue;
                }
            }
            if k.ends_with(".count") && snap.contains_key(&format!(
                "{}.ns",
                k.trim_end_matches(".count")
            )) {
                continue; // folded into the .ns line above
            }
            out.push_str(&format!("{k}: {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("msgs", 3);
        m.add("msgs", 4);
        assert_eq!(m.get("msgs"), 7);
        assert_eq!(m.get("absent"), 0);
    }

    #[test]
    fn timing_report_contains_mean() {
        let m = Metrics::new();
        m.record_time("step", Duration::from_micros(10));
        m.record_time("step", Duration::from_micros(30));
        let rep = m.report();
        assert!(rep.contains("step"), "{rep}");
        assert!(rep.contains("2 events"), "{rep}");
        assert!(rep.contains("20.00µs"), "{rep}");
    }

    #[test]
    fn counter_handles_are_shared() {
        let m = Metrics::new();
        let c = m.counter("x");
        c.fetch_add(5, Ordering::Relaxed);
        assert_eq!(m.get("x"), 5);
    }
}
