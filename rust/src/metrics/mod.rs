//! Execution metrics: counters and latency histograms collected by the
//! engine, the serving tier, and the baselines; reported by the CLI and
//! recorded in EXPERIMENTS.md.
//!
//! ## Name convention
//!
//! Every metric name is `<prefix>.<snake_case>`; the prefix states the
//! subsystem that emits it (one prefix per subsystem, documented in the
//! `docs/observability.md` glossary):
//!
//! | prefix    | emitted by                                            |
//! |-----------|-------------------------------------------------------|
//! | `exec.*`  | data plane (batches, elements, scatter, hoisting)     |
//! | `coord.*` | §6.3 coordination (bags, state reuse, watchers)       |
//! | `driver.*`| the driver loop (appends, decisions, bag-dones)       |
//! | `opt.*`   | optimizer pass summary (forwarded at plan build)      |
//! | `serve.*` | job service (queue, cache, jobs, preambles)           |
//!
//! ## Counters vs histograms
//!
//! Counters are monotonic `u64`s. Durations recorded through
//! [`Metrics::record_time`] land in **log-bucketed histograms** (powers
//! of two over nanoseconds), so the report can state p50/p90/p99 — not
//! just a mean — for queue waits, compiles, and epoch latencies.
//!
//! Hot paths never call the name-keyed API per event: resolve once with
//! [`Metrics::counter`] / [`Metrics::handle`] and bump the returned
//! handle (see `exec::worker::EngineCounters`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Histogram bucket count: bucket `i` holds durations in
/// `[2^i, 2^(i+1))` nanoseconds; 48 buckets cover ~3 days.
pub const HIST_BUCKETS: usize = 48;

/// A pre-resolved counter: one atomic add per bump, no name lookup, no
/// lock. Obtain with [`Metrics::handle`]; clones share the counter.
#[derive(Clone, Debug)]
pub struct CounterHandle(Arc<AtomicU64>);

impl CounterHandle {
    /// Add `v`.
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    /// Add 1.
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A log-bucketed latency histogram: lock-free recording (one atomic
/// add into a power-of-two bucket plus count/sum), quantiles estimated
/// by linear interpolation inside the selected bucket — the estimate is
/// always within the bucket holding the true quantile, i.e. within 2×.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Bucket index for a nanosecond value: `floor(log2(ns))`, clamped.
#[inline]
fn bucket_of(ns: u64) -> usize {
    (63 - (ns | 1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

impl Histogram {
    /// Record one duration.
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Record one duration in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations, nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Mean observation (zero when empty).
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns() / c)
    }

    /// Estimated quantile `q` in `[0, 1]`: walk the buckets to the one
    /// holding rank `ceil(q * count)`, then interpolate linearly
    /// between the bucket's bounds by rank position.
    pub fn quantile(&self, q: f64) -> Duration {
        let count = self.count();
        if count == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let in_bucket = b.load(Ordering::Relaxed);
            if in_bucket == 0 {
                continue;
            }
            if seen + in_bucket >= rank {
                let lo = 1u64 << i;
                let hi = if i + 1 >= 64 { u64::MAX } else { (1u64 << (i + 1)) - 1 };
                let frac = (rank - seen) as f64 / in_bucket as f64;
                let est = lo as f64 + (hi - lo) as f64 * frac;
                return Duration::from_nanos(est as u64);
            }
            seen += in_bucket;
        }
        Duration::ZERO
    }

    /// Snapshot the digest most reports want.
    pub fn stats(&self) -> TimeStats {
        TimeStats {
            count: self.count(),
            total: Duration::from_nanos(self.sum_ns()),
            mean: self.mean(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
        }
    }
}

/// Digest of one latency histogram.
#[derive(Clone, Copy, Debug, Default)]
pub struct TimeStats {
    /// Observations recorded.
    pub count: u64,
    /// Sum of all observations.
    pub total: Duration,
    /// Mean observation.
    pub mean: Duration,
    /// Estimated median.
    pub p50: Duration,
    /// Estimated 90th percentile.
    pub p90: Duration,
    /// Estimated 99th percentile.
    pub p99: Duration,
}

/// A shareable metrics sink. All counters and histogram cells are
/// lock-free; the name maps are append-mostly and guarded by mutexes
/// (resolve handles once — never per event — on hot paths).
#[derive(Default, Debug)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Metrics {
    /// Create an empty sink.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Get (or create) the counter handle for `name`.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get (or create) a pre-resolved [`CounterHandle`] for `name` —
    /// the hot-path API: resolve once, bump lock-free forever after.
    pub fn handle(&self, name: &str) -> CounterHandle {
        CounterHandle(self.counter(name))
    }

    /// Add `v` to counter `name` (locks the name map — fine for
    /// low-rate events; use [`Metrics::handle`] in loops).
    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    /// Get (or create) the latency histogram for `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Record a duration under `name` into its log-bucketed histogram
    /// (count, sum, and p50/p90/p99 all derive from it at report time).
    pub fn record_time(&self, name: &str, d: Duration) {
        self.histogram(name).record(d);
    }

    /// Digest of the histogram under `name` (`None` when absent/empty).
    pub fn time_stats(&self, name: &str) -> Option<TimeStats> {
        let h = self.hists.lock().unwrap().get(name).cloned()?;
        let s = h.stats();
        (s.count > 0).then_some(s)
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Value of a single counter (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Render a human-readable report: counters first, then one line
    /// per latency histogram with count, mean, and tail quantiles.
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        for (k, v) in &snap {
            out.push_str(&format!("{k}: {v}\n"));
        }
        let hists: Vec<(String, Arc<Histogram>)> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.clone()))
            .collect();
        for (k, h) in hists {
            let s = h.stats();
            if s.count == 0 {
                continue;
            }
            let f = crate::util::fmt_duration;
            out.push_str(&format!(
                "{k}: total {} over {} events (mean {}, p50 {}, p90 {}, p99 {})\n",
                f(s.total),
                s.count,
                f(s.mean),
                f(s.p50),
                f(s.p90),
                f(s.p99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("msgs", 3);
        m.add("msgs", 4);
        assert_eq!(m.get("msgs"), 7);
        assert_eq!(m.get("absent"), 0);
    }

    #[test]
    fn timing_report_contains_mean() {
        let m = Metrics::new();
        m.record_time("step", Duration::from_micros(10));
        m.record_time("step", Duration::from_micros(30));
        let rep = m.report();
        assert!(rep.contains("step"), "{rep}");
        assert!(rep.contains("2 events"), "{rep}");
        assert!(rep.contains("20.00µs"), "{rep}");
        assert!(rep.contains("p99"), "{rep}");
    }

    #[test]
    fn counter_handles_are_shared() {
        let m = Metrics::new();
        let c = m.counter("x");
        c.fetch_add(5, Ordering::Relaxed);
        assert_eq!(m.get("x"), 5);
        let h = m.handle("x");
        h.incr();
        h.add(4);
        assert_eq!(m.get("x"), 10);
        assert_eq!(h.get(), 10);
    }

    #[test]
    fn bucket_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn quantiles_constant_distribution_land_in_bucket() {
        let h = Histogram::default();
        for _ in 0..1000 {
            h.record(Duration::from_millis(5));
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum_ns(), 5_000_000 * 1000);
        // 5ms sits in bucket [2^22, 2^23) ns = [4.19ms, 8.39ms).
        for q in [0.5, 0.9, 0.99] {
            let v = h.quantile(q);
            assert!(
                v >= Duration::from_nanos(1 << 22) && v < Duration::from_nanos(1 << 23),
                "q{q}: {v:?}"
            );
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.99));
    }

    #[test]
    fn quantiles_uniform_distribution_within_2x() {
        let h = Histogram::default();
        // Uniform 1..=1024 µs: true p50 = 512µs, p90 ≈ 922µs, p99 ≈ 1014µs.
        for us in 1..=1024u64 {
            h.record(Duration::from_micros(us));
        }
        let checks = [(0.50, 512_000u64), (0.90, 921_600), (0.99, 1_013_760)];
        for (q, truth_ns) in checks {
            let est = h.quantile(q).as_nanos() as u64;
            assert!(
                est >= truth_ns / 2 && est <= truth_ns * 2,
                "q{q}: est {est}ns vs true {truth_ns}ns"
            );
        }
    }

    #[test]
    fn empty_histogram_digest_is_none() {
        let m = Metrics::new();
        assert!(m.time_stats("nope").is_none());
        m.record_time("t", Duration::from_micros(7));
        let s = m.time_stats("t").unwrap();
        assert_eq!(s.count, 1);
        assert!(s.p50 > Duration::ZERO);
    }
}
