//! The serving benchmark behind `labyrinth bench-serve` and
//! `benches/fig9_serving.rs` (Fig. 9 — ours; the paper has no serving
//! figure): per-job submission latency under three control-plane
//! regimes, and throughput scaling with job slots.
//!
//! * **cold** — the historical path: every job re-parses + re-compiles +
//!   re-optimizes the program AND spawns a fresh worker pool.
//! * **cached** — the plan template is compiled once and shared, but
//!   each job still spawns (and joins) its own worker threads.
//! * **warm** — the full `serve::JobService` path: cached template +
//!   persistent worker pool; a job is a pool epoch.
//!
//! The interesting number is the cold/warm ratio: how much per-job
//! control-plane cost the template cache and the pool remove together.

use super::{JobRequest, JobService, ServeConfig, TenantSpec};
use crate::bench_harness::{Bencher, Table};
use crate::exec::{driver, ExecConfig, ExecPlan};
use crate::value::Value;
use crate::workload::registry;
use std::sync::Arc;
use std::time::{Duration, Instant};

const WORKERS: usize = 2;

/// The benchmark program: a counter loop around a join against an
/// invariant lookup side — enough frontend + optimizer work to make the
/// compile measurable, over data small enough that execution does not
/// drown the control-plane difference.
fn bench_source() -> &'static str {
    r#"
    lookup = source("fig9_attrs");
    d = 1;
    s = bag();
    while (d <= 3) {
        v = source("fig9_visits").map(|x| pair(x % 32, x));
        j = v.join(lookup);
        t = j.map(|q| fst(snd(q)) + snd(snd(q)));
        f = t.filter(|x| x >= 0);
        s = f;
        d = d + 1;
    }
    collect(s, "out");
    "#
}

/// Register the benchmark datasets in the global registry.
pub fn register_data() {
    let reg = registry::global();
    reg.put("fig9_attrs", (0..32i64).map(|k| Value::pair(Value::I64(k), Value::I64(k * 10))).collect());
    reg.put("fig9_visits", (0..128i64).map(Value::I64).collect());
}

/// Run the full serving benchmark; `smoke` shrinks every count to a CI-
/// friendly size (it still exercises compile, cache, pool, queue, and
/// concurrent submission paths end to end).
pub fn serving_benchmark(smoke: bool) {
    register_data();
    let src = bench_source();
    let (warmup, reps) = if smoke { (1, 3) } else { (3, 25) };
    let bench = Bencher::new(warmup, reps);

    // --- per-job submission latency -----------------------------------
    let mut table = Table::new(
        "Fig 9: per-job latency — control-plane regimes (1 slot)",
        "regime",
        vec!["median".into()],
    );

    let cold = bench.run("cold: compile + spawn per job", || {
        let g = crate::compile_source(src).unwrap();
        let plan = Arc::new(ExecPlan::new(Arc::new(g), WORKERS));
        driver::run_plan(plan, &ExecConfig { workers: WORKERS, ..Default::default() })
            .unwrap();
    });
    table.push_row("cold compile+spawn", vec![Some(cold.median())]);

    let shared_graph = crate::compile_source(src).unwrap();
    let shared_plan = Arc::new(ExecPlan::new(Arc::new(shared_graph), WORKERS));
    let cached = bench.run("cached template, fresh pool per job", || {
        driver::run_plan(
            shared_plan.clone(),
            &ExecConfig { workers: WORKERS, ..Default::default() },
        )
        .unwrap();
    });
    table.push_row("cached template", vec![Some(cached.median())]);

    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: WORKERS,
        share_preambles: false,
        ..Default::default()
    });
    let warm = bench.run("warm: cached template + warm pool", || {
        svc.run(JobRequest::source(src)).unwrap();
    });
    table.push_row("cached + warm pool", vec![Some(warm.median())]);

    // Same warm path, but invariant preamble bags materialized once and
    // replayed across jobs (matching binding signature): the hoisted
    // source scan + keying map + invariant join skip recomputation.
    let svc_share = JobService::new(ServeConfig {
        slots: 1,
        workers: WORKERS,
        ..Default::default()
    });
    svc_share.run(JobRequest::source(src)).unwrap(); // materialize preambles
    let warm_shared = bench.run("warm + shared invariant preambles", || {
        svc_share.run(JobRequest::source(src)).unwrap();
    });
    table.push_row("warm + shared preambles", vec![Some(warm_shared.median())]);
    table.print();

    let ratio = cold.median().as_secs_f64() / warm.median().as_secs_f64().max(1e-9);
    println!(
        "cold / warm submission-latency ratio: {ratio:.1}x (acceptance target: >= 10x)"
    );
    let share_ratio =
        warm.median().as_secs_f64() / warm_shared.median().as_secs_f64().max(1e-9);
    println!(
        "warm-recompute / warm-shared-preambles ratio: {share_ratio:.2}x \
         ({} preamble replays)\n",
        svc_share.metrics().get("serve.preamble_hits")
    );
    // Tail latencies from the serve histograms (log-bucketed; ~2x
    // resolution): queue wait, engine-epoch time, end-to-end request.
    let m = svc.metrics();
    for (label, key) in [
        ("queue-wait", "serve.queue_wait"),
        ("epoch", "serve.job_time"),
        ("request", "serve.request_time"),
    ] {
        if let Some(s) = m.time_stats(key) {
            let f = crate::util::fmt_duration;
            println!(
                "{label:>12}: p50 {}, p90 {}, p99 {} over {} jobs",
                f(s.p50),
                f(s.p90),
                f(s.p99),
                s.count
            );
        }
    }
    println!();
    println!("{}", svc.report());
    drop(svc);
    drop(svc_share);

    // --- throughput vs job slots --------------------------------------
    let jobs = if smoke { 8 } else { 200 };
    let slot_sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let mut tput = Table::new(
        format!("Fig 9b: throughput — {jobs} jobs, N concurrent clients"),
        "slots",
        vec!["per-job".into()],
    );
    for &slots in slot_sweep {
        let svc = Arc::new(JobService::new(ServeConfig {
            slots,
            workers: WORKERS,
            ..Default::default()
        }));
        // Prime the template cache so throughput measures serving, not
        // the first compile.
        svc.run(JobRequest::source(src)).unwrap();
        let clients = slots * 2;
        let per_client = jobs / clients.max(1);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let svc = svc.clone();
                s.spawn(move || {
                    for _ in 0..per_client {
                        svc.run(JobRequest::source(src)).unwrap();
                    }
                });
            }
        });
        let elapsed = t0.elapsed();
        let done = (per_client * clients) as f64;
        let rate = done / elapsed.as_secs_f64().max(1e-9);
        println!(
            "  slots={slots}: {done:.0} jobs in {} -> {rate:.0} jobs/s",
            crate::util::fmt_duration(elapsed)
        );
        tput.push_row(slots.to_string(), vec![Some(elapsed.div_f64(done.max(1.0)))]);
    }
    tput.print();

    registry::global().clear_prefix("fig9_");

    let storm = tenant_storm(smoke);
    write_bench_json(
        "BENCH_serve.json",
        smoke,
        cold.median(),
        cached.median(),
        warm.median(),
        warm_shared.median(),
        &storm,
    );
    println!("wrote BENCH_serve.json\n");

    cancel_storm(smoke);
}

/// One regime's results from the mixed-tenant storm.
pub struct RegimeReport {
    pub regime: &'static str,
    pub heavy_jobs: usize,
    pub light_jobs: usize,
    /// Client-observed light-tenant submit→complete latency percentiles.
    pub light_p50: Duration,
    pub light_p99: Duration,
    /// First heavy submission → last heavy completion.
    pub heavy_makespan: Duration,
    pub jobs_shed: u64,
    pub preamble_hits: u64,
    /// Widest pool observed across lanes during the storm (elastic
    /// regimes grow past the starting width under backlog).
    pub max_pool_width: usize,
}

/// Nearest-rank percentile over an unsorted latency sample.
fn percentile(lat: &mut [Duration], q: f64) -> Duration {
    lat.sort_unstable();
    if lat.is_empty() {
        return Duration::ZERO;
    }
    let rank = ((q * lat.len() as f64).ceil() as usize).clamp(1, lat.len());
    lat[rank - 1]
}

/// Mixed-tenant storm (Fig 9c — ours): two heavy "analytics" affinity
/// groups keep both lanes under standing backlog while a light
/// "interactive" client submits cheap jobs and measures client-side
/// latency. Run twice over identical submission code:
///
/// * **fifo-fixed** — no tenants configured (every request bills the
///   implicit default tenant: per-lane FIFO) and fixed-width pools. The
///   light client's first jobs queue behind the whole heavy backlog on
///   their lane.
/// * **fair-elastic** — DRR tenants (interactive weighted 8× analytics)
///   plus elastic pools (`min_workers=2`, `max_workers=4`). A light job
///   waits for at most the heavy job already running, not the backlog.
///
/// The headline number is the light-tenant p99 ratio between the two
/// (acceptance target: >= 3x better under fair admission).
pub fn tenant_storm(smoke: bool) -> Vec<RegimeReport> {
    let heavy_iters: u64 = if smoke { 60_000 } else { 400_000 };
    let heavy_jobs: usize = if smoke { 5 } else { 6 }; // per affinity group
    let light_jobs: usize = if smoke { 10 } else { 30 };
    let gap = Duration::from_millis(if smoke { 2 } else { 5 });

    let base = ServeConfig { slots: 2, workers: WORKERS, ..Default::default() };
    let fifo = ServeConfig { tenants: Vec::new(), ..base.clone() };
    let fair = ServeConfig {
        tenants: vec![
            TenantSpec::new("analytics", 1.0),
            TenantSpec::new("interactive", 8.0),
        ],
        min_workers: 2,
        max_workers: 4,
        ..base
    };

    let mut reports = Vec::new();
    for (regime, cfg) in [("fifo-fixed", fifo), ("fair-elastic", fair)] {
        reports.push(storm_regime(regime, cfg, heavy_jobs, light_jobs, heavy_iters, gap));
    }

    let mut table = Table::new(
        format!(
            "Fig 9c: mixed-tenant storm — light-tenant latency \
             ({} heavy jobs x 2 groups, {light_jobs} light jobs)",
            heavy_jobs
        ),
        "regime",
        vec!["light p50".into(), "light p99".into(), "heavy makespan".into()],
    );
    for r in &reports {
        table.push_row(
            r.regime,
            vec![Some(r.light_p50), Some(r.light_p99), Some(r.heavy_makespan)],
        );
    }
    table.print();
    if let [fifo, fair] = &reports[..] {
        let ratio =
            fifo.light_p99.as_secs_f64() / fair.light_p99.as_secs_f64().max(1e-9);
        println!(
            "light-tenant p99 improvement under fair admission: {ratio:.1}x \
             (acceptance target: >= 3x); fair-regime pools peaked at \
             {} workers (start {WORKERS}), {} job(s) shed",
            fair.max_pool_width, fair.jobs_shed
        );
    }
    println!();
    reports
}

/// One regime of [`tenant_storm`] — submission code is identical across
/// regimes; only [`ServeConfig`] differs.
fn storm_regime(
    regime: &'static str,
    cfg: ServeConfig,
    heavy_jobs: usize,
    light_jobs: usize,
    heavy_iters: u64,
    gap: Duration,
) -> RegimeReport {
    // Two DISTINCT heavy programs = two affinity groups: group A pins
    // the (idle-tie) first lane; the settle sleep leaves A's backlog
    // queued there, so group B's least-loaded fallback takes the other
    // lane. Both lanes then hold standing heavy backlog.
    let heavy_a = format!(
        "d = 1; while (d <= {heavy_iters}) {{ d = d + 1; }} collect(bag(1), \"a\");"
    );
    let heavy_b = format!(
        "d = 1; while (d <= {}) {{ d = d + 1; }} collect(bag(2), \"b\");",
        heavy_iters + 1
    );
    let light_src =
        "v = bag(1, 2, 3, 4); s = v.map(|x| x * 2 + 1).filter(|x| x > 0); collect(s, \"l\");";

    let svc = JobService::new(cfg);
    let t0 = Instant::now();
    let mut heavy = Vec::with_capacity(heavy_jobs * 2);
    for _ in 0..heavy_jobs {
        heavy.push(
            svc.submit(JobRequest::source(heavy_a.clone()).tenant("analytics")).unwrap(),
        );
    }
    std::thread::sleep(Duration::from_millis(5)); // let lane A start draining
    for _ in 0..heavy_jobs {
        heavy.push(
            svc.submit(JobRequest::source(heavy_b.clone()).tenant("analytics")).unwrap(),
        );
    }

    let mut light_lat = Vec::with_capacity(light_jobs);
    let mut max_pool_width = svc.lane_widths().into_iter().max().unwrap_or(0);
    for _ in 0..light_jobs {
        let t = Instant::now();
        svc.run(JobRequest::source(light_src).tenant("interactive")).unwrap();
        light_lat.push(t.elapsed());
        max_pool_width =
            max_pool_width.max(svc.lane_widths().into_iter().max().unwrap_or(0));
        std::thread::sleep(gap);
    }
    for t in heavy {
        t.wait().unwrap();
    }
    let heavy_makespan = t0.elapsed();
    let m = svc.metrics();
    RegimeReport {
        regime,
        heavy_jobs: heavy_jobs * 2,
        light_jobs,
        light_p50: percentile(&mut light_lat, 0.50),
        light_p99: percentile(&mut light_lat, 0.99),
        heavy_makespan,
        jobs_shed: m.get("serve.jobs_shed"),
        preamble_hits: m.get("serve.preamble_hits"),
        max_pool_width,
    }
}

/// Hand-rolled `BENCH_serve.json` (same no-serde idiom as
/// `BENCH_throughput.json`): the control-plane regime medians plus one
/// entry per storm regime. CI refreshes this file on every main push and
/// appends the fair-regime light p99 to BENCH_TRAJECTORY.md.
fn write_bench_json(
    path: &str,
    smoke: bool,
    cold: Duration,
    cached: Duration,
    warm: Duration,
    warm_shared: Duration,
    storm: &[RegimeReport],
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"benchmark\": \"serve\",\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str(&format!("  \"cold_ns\": {},\n", cold.as_nanos()));
    s.push_str(&format!("  \"cached_ns\": {},\n", cached.as_nanos()));
    s.push_str(&format!("  \"warm_ns\": {},\n", warm.as_nanos()));
    s.push_str(&format!("  \"warm_shared_ns\": {},\n", warm_shared.as_nanos()));
    s.push_str(&format!(
        "  \"cold_over_warm\": {:.2},\n",
        cold.as_secs_f64() / warm.as_secs_f64().max(1e-9)
    ));
    if let [fifo, fair] = storm {
        s.push_str(&format!(
            "  \"light_p99_improvement\": {:.2},\n",
            fifo.light_p99.as_secs_f64() / fair.light_p99.as_secs_f64().max(1e-9)
        ));
    }
    s.push_str("  \"storm\": [\n");
    for (i, r) in storm.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"regime\": \"{}\", \"heavy_jobs\": {}, \"light_jobs\": {}, \
             \"light_p50_ns\": {}, \"light_p99_ns\": {}, \"heavy_makespan_ns\": {}, \
             \"jobs_shed\": {}, \"preamble_hits\": {}, \"max_pool_width\": {}}}{}\n",
            r.regime,
            r.heavy_jobs,
            r.light_jobs,
            r.light_p50.as_nanos(),
            r.light_p99.as_nanos(),
            r.heavy_makespan.as_nanos(),
            r.jobs_shed,
            r.preamble_hits,
            r.max_pool_width,
            if i + 1 < storm.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, s) {
        eprintln!("warning: could not write {path}: {e}");
    }
}

/// Cancel-storm stress (CI `serve-smoke`): submit a burst of long-running
/// jobs, cancel half of them mid-run, and prove the service stays live —
/// every ticket resolves, canceled jobs abort instead of running to
/// completion, the worker pools come back clean, and the caches stay
/// bounded. Job 0 is a sentinel that would run for tens of seconds if
/// mid-run cancel regressed: it is canceled only once a lane is
/// observably RUNNING it, and the storm asserts it aborted — so a silent
/// regression to queued-only cancellation fails CI instead of passing.
pub fn cancel_storm(smoke: bool) {
    let jobs: usize = if smoke { 8 } else { 24 };
    let iters: u64 = if smoke { 150_000 } else { 400_000 };
    let src = format!(
        "d = 1; while (d <= {iters}) {{ d = d + 1; }} collect(bag(1), \"x\");"
    );
    // Far past every wait window below unless cancellation aborts it.
    let sentinel_src =
        "d = 1; while (d <= 20000000) { d = d + 1; } collect(bag(1), \"x\");";
    let svc = JobService::new(ServeConfig { slots: 2, workers: WORKERS, ..Default::default() });
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(jobs);
    tickets.push((0usize, svc.submit(JobRequest::source(sentinel_src)).unwrap()));
    // Wait until a lane has the sentinel off the queue and running.
    while svc.busy_slots() == 0 {
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "sentinel never started");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for i in 1..jobs {
        tickets.push((i, svc.submit(JobRequest::source(src.clone())).unwrap()));
    }
    // Let the storm build before pulling the plug on every even job.
    std::thread::sleep(std::time::Duration::from_millis(30));
    for (i, t) in &tickets {
        if i % 2 == 0 {
            t.cancel();
        }
    }
    let mut completed = 0usize;
    let mut canceled = 0usize;
    let mut sentinel_aborted = false;
    for (i, t) in tickets {
        match t.wait_timeout(std::time::Duration::from_secs(60)) {
            Ok(Some(_)) => completed += 1,
            Ok(None) => panic!("job {i} neither completed nor aborted in time"),
            Err(e) => {
                assert!(
                    i % 2 == 0 && e.to_string().contains("canceled"),
                    "job {i} failed for a non-cancel reason: {e}"
                );
                canceled += 1;
                if i == 0 {
                    sentinel_aborted = true;
                }
            }
        }
    }
    assert_eq!(completed + canceled, jobs);
    assert!(
        sentinel_aborted,
        "the RUNNING sentinel job must abort mid-run on cancel"
    );
    // The service survived the storm: a fresh job runs clean on the same
    // (reused) pools.
    let ok = svc.run(JobRequest::source("collect(bag(9), \"ok\");")).unwrap();
    assert_eq!(ok.output.collected("ok").len(), 1);
    println!(
        "cancel storm: {jobs} jobs ({canceled} canceled, {completed} completed) in {}; \
         service live, {} template(s) resident",
        crate::util::fmt_duration(t0.elapsed()),
        svc.cache().len(),
    );
    // Three distinct programs ran: the sentinel, the storm body, and the
    // liveness probe — the template cache must hold no more than that.
    assert!(svc.cache().len() <= 3, "caches stay bounded under the storm");
}
