//! The serving benchmark behind `labyrinth bench-serve` and
//! `benches/fig9_serving.rs` (Fig. 9 — ours; the paper has no serving
//! figure): per-job submission latency under three control-plane
//! regimes, and throughput scaling with job slots.
//!
//! * **cold** — the historical path: every job re-parses + re-compiles +
//!   re-optimizes the program AND spawns a fresh worker pool.
//! * **cached** — the plan template is compiled once and shared, but
//!   each job still spawns (and joins) its own worker threads.
//! * **warm** — the full `serve::JobService` path: cached template +
//!   persistent worker pool; a job is a pool epoch.
//!
//! The interesting number is the cold/warm ratio: how much per-job
//! control-plane cost the template cache and the pool remove together.

use super::{JobRequest, JobService, ServeConfig};
use crate::bench_harness::{Bencher, Table};
use crate::exec::{driver, ExecConfig, ExecPlan};
use crate::value::Value;
use crate::workload::registry;
use std::sync::Arc;
use std::time::Instant;

const WORKERS: usize = 2;

/// The benchmark program: a counter loop around a join against an
/// invariant lookup side — enough frontend + optimizer work to make the
/// compile measurable, over data small enough that execution does not
/// drown the control-plane difference.
fn bench_source() -> &'static str {
    r#"
    lookup = source("fig9_attrs");
    d = 1;
    s = bag();
    while (d <= 3) {
        v = source("fig9_visits").map(|x| pair(x % 32, x));
        j = v.join(lookup);
        t = j.map(|q| fst(snd(q)) + snd(snd(q)));
        f = t.filter(|x| x >= 0);
        s = f;
        d = d + 1;
    }
    collect(s, "out");
    "#
}

/// Register the benchmark datasets in the global registry.
pub fn register_data() {
    let reg = registry::global();
    reg.put("fig9_attrs", (0..32i64).map(|k| Value::pair(Value::I64(k), Value::I64(k * 10))).collect());
    reg.put("fig9_visits", (0..128i64).map(Value::I64).collect());
}

/// Run the full serving benchmark; `smoke` shrinks every count to a CI-
/// friendly size (it still exercises compile, cache, pool, queue, and
/// concurrent submission paths end to end).
pub fn serving_benchmark(smoke: bool) {
    register_data();
    let src = bench_source();
    let (warmup, reps) = if smoke { (1, 3) } else { (3, 25) };
    let bench = Bencher::new(warmup, reps);

    // --- per-job submission latency -----------------------------------
    let mut table = Table::new(
        "Fig 9: per-job latency — control-plane regimes (1 slot)",
        "regime",
        vec!["median".into()],
    );

    let cold = bench.run("cold: compile + spawn per job", || {
        let g = crate::compile_source(src).unwrap();
        let plan = Arc::new(ExecPlan::new(Arc::new(g), WORKERS));
        driver::run_plan(plan, &ExecConfig { workers: WORKERS, ..Default::default() })
            .unwrap();
    });
    table.push_row("cold compile+spawn", vec![Some(cold.median())]);

    let shared_graph = crate::compile_source(src).unwrap();
    let shared_plan = Arc::new(ExecPlan::new(Arc::new(shared_graph), WORKERS));
    let cached = bench.run("cached template, fresh pool per job", || {
        driver::run_plan(
            shared_plan.clone(),
            &ExecConfig { workers: WORKERS, ..Default::default() },
        )
        .unwrap();
    });
    table.push_row("cached template", vec![Some(cached.median())]);

    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: WORKERS,
        share_preambles: false,
        ..Default::default()
    });
    let warm = bench.run("warm: cached template + warm pool", || {
        svc.run(JobRequest::source(src)).unwrap();
    });
    table.push_row("cached + warm pool", vec![Some(warm.median())]);

    // Same warm path, but invariant preamble bags materialized once and
    // replayed across jobs (matching binding signature): the hoisted
    // source scan + keying map + invariant join skip recomputation.
    let svc_share = JobService::new(ServeConfig {
        slots: 1,
        workers: WORKERS,
        ..Default::default()
    });
    svc_share.run(JobRequest::source(src)).unwrap(); // materialize preambles
    let warm_shared = bench.run("warm + shared invariant preambles", || {
        svc_share.run(JobRequest::source(src)).unwrap();
    });
    table.push_row("warm + shared preambles", vec![Some(warm_shared.median())]);
    table.print();

    let ratio = cold.median().as_secs_f64() / warm.median().as_secs_f64().max(1e-9);
    println!(
        "cold / warm submission-latency ratio: {ratio:.1}x (acceptance target: >= 10x)"
    );
    let share_ratio =
        warm.median().as_secs_f64() / warm_shared.median().as_secs_f64().max(1e-9);
    println!(
        "warm-recompute / warm-shared-preambles ratio: {share_ratio:.2}x \
         ({} preamble replays)\n",
        svc_share.metrics().get("serve.preamble_hits")
    );
    // Tail latencies from the serve histograms (log-bucketed; ~2x
    // resolution): queue wait, engine-epoch time, end-to-end request.
    let m = svc.metrics();
    for (label, key) in [
        ("queue-wait", "serve.queue_wait"),
        ("epoch", "serve.job_time"),
        ("request", "serve.request_time"),
    ] {
        if let Some(s) = m.time_stats(key) {
            let f = crate::util::fmt_duration;
            println!(
                "{label:>12}: p50 {}, p90 {}, p99 {} over {} jobs",
                f(s.p50),
                f(s.p90),
                f(s.p99),
                s.count
            );
        }
    }
    println!();
    println!("{}", svc.report());
    drop(svc);
    drop(svc_share);

    // --- throughput vs job slots --------------------------------------
    let jobs = if smoke { 8 } else { 200 };
    let slot_sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let mut tput = Table::new(
        format!("Fig 9b: throughput — {jobs} jobs, N concurrent clients"),
        "slots",
        vec!["per-job".into()],
    );
    for &slots in slot_sweep {
        let svc = Arc::new(JobService::new(ServeConfig {
            slots,
            workers: WORKERS,
            ..Default::default()
        }));
        // Prime the template cache so throughput measures serving, not
        // the first compile.
        svc.run(JobRequest::source(src)).unwrap();
        let clients = slots * 2;
        let per_client = jobs / clients.max(1);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let svc = svc.clone();
                s.spawn(move || {
                    for _ in 0..per_client {
                        svc.run(JobRequest::source(src)).unwrap();
                    }
                });
            }
        });
        let elapsed = t0.elapsed();
        let done = (per_client * clients) as f64;
        let rate = done / elapsed.as_secs_f64().max(1e-9);
        println!(
            "  slots={slots}: {done:.0} jobs in {} -> {rate:.0} jobs/s",
            crate::util::fmt_duration(elapsed)
        );
        tput.push_row(slots.to_string(), vec![Some(elapsed.div_f64(done.max(1.0)))]);
    }
    tput.print();

    registry::global().clear_prefix("fig9_");

    cancel_storm(smoke);
}

/// Cancel-storm stress (CI `serve-smoke`): submit a burst of long-running
/// jobs, cancel half of them mid-run, and prove the service stays live —
/// every ticket resolves, canceled jobs abort instead of running to
/// completion, the worker pools come back clean, and the caches stay
/// bounded. Job 0 is a sentinel that would run for tens of seconds if
/// mid-run cancel regressed: it is canceled only once a lane is
/// observably RUNNING it, and the storm asserts it aborted — so a silent
/// regression to queued-only cancellation fails CI instead of passing.
pub fn cancel_storm(smoke: bool) {
    let jobs: usize = if smoke { 8 } else { 24 };
    let iters: u64 = if smoke { 150_000 } else { 400_000 };
    let src = format!(
        "d = 1; while (d <= {iters}) {{ d = d + 1; }} collect(bag(1), \"x\");"
    );
    // Far past every wait window below unless cancellation aborts it.
    let sentinel_src =
        "d = 1; while (d <= 20000000) { d = d + 1; } collect(bag(1), \"x\");";
    let svc = JobService::new(ServeConfig { slots: 2, workers: WORKERS, ..Default::default() });
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(jobs);
    tickets.push((0usize, svc.submit(JobRequest::source(sentinel_src)).unwrap()));
    // Wait until a lane has the sentinel off the queue and running.
    while svc.busy_slots() == 0 {
        assert!(t0.elapsed() < std::time::Duration::from_secs(10), "sentinel never started");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for i in 1..jobs {
        tickets.push((i, svc.submit(JobRequest::source(src.clone())).unwrap()));
    }
    // Let the storm build before pulling the plug on every even job.
    std::thread::sleep(std::time::Duration::from_millis(30));
    for (i, t) in &tickets {
        if i % 2 == 0 {
            t.cancel();
        }
    }
    let mut completed = 0usize;
    let mut canceled = 0usize;
    let mut sentinel_aborted = false;
    for (i, t) in tickets {
        match t.wait_timeout(std::time::Duration::from_secs(60)) {
            Ok(Some(_)) => completed += 1,
            Ok(None) => panic!("job {i} neither completed nor aborted in time"),
            Err(e) => {
                assert!(
                    i % 2 == 0 && e.to_string().contains("canceled"),
                    "job {i} failed for a non-cancel reason: {e}"
                );
                canceled += 1;
                if i == 0 {
                    sentinel_aborted = true;
                }
            }
        }
    }
    assert_eq!(completed + canceled, jobs);
    assert!(
        sentinel_aborted,
        "the RUNNING sentinel job must abort mid-run on cancel"
    );
    // The service survived the storm: a fresh job runs clean on the same
    // (reused) pools.
    let ok = svc.run(JobRequest::source("collect(bag(9), \"ok\");")).unwrap();
    assert_eq!(ok.output.collected("ok").len(), 1);
    println!(
        "cancel storm: {jobs} jobs ({canceled} canceled, {completed} completed) in {}; \
         service live, {} template(s) resident",
        crate::util::fmt_duration(t0.elapsed()),
        svc.cache().len(),
    );
    // Three distinct programs ran: the sentinel, the storm body, and the
    // liveness probe — the template cache must hold no more than that.
    assert!(svc.cache().len() <= 3, "caches stay bounded under the storm");
}
