//! `serve::` — a resident **job service** for high-throughput repeated
//! jobs.
//!
//! Labyrinth's core result is that per-job control-plane work dominates
//! iterative analytics — yet the engine itself still paid a per-*run*
//! control plane: every `exec::run_plan` re-spawned worker threads, and
//! every caller re-lexed / re-compiled / re-optimized the program. Under
//! a serving workload (the same parameterized programs submitted over
//! and over, "Execution Templates" style) that cost is pure overhead.
//! This module removes it:
//!
//! * **Plan-template cache** ([`template`]): compile → SSA → dataflow →
//!   `opt::optimize` → `ExecPlan` exactly once per (program, optimizer
//!   config, executor config); later requests instantiate the cached
//!   `Arc<ExecPlan>`. Completed runs feed observed cardinalities back,
//!   and drifted templates are **re-optimized in place** (a cache
//!   *revision*, not an invalidation). Eviction is cost-weighted
//!   (decayed usage × compile cost), so hot or expensive templates
//!   outlive cold, cheap ones.
//! * **Persistent worker pools** (`exec::pool`): one [`WorkerPool`] per
//!   job slot, threads resident across jobs; a job is a
//!   message-delimited epoch, so per-job state isolation is structural
//!   (nothing — including §7 `reuse_state` hash tables — survives an
//!   epoch boundary).
//! * **Cross-job preamble sharing**: the one deliberate, proven-safe
//!   exception to absolute epoch isolation. Hoisted loop-invariant
//!   preamble subgraphs (plus the entry-block inputs only they consume)
//!   whose inputs are fully determined by the
//!   template plus its bindings have their materialized bags cached per
//!   `(template, revision, binding signature)` and **replayed** by
//!   later identical submissions instead of recomputed
//!   (`serve.preamble_hits`). Signatures match by exact dataset
//!   identity/content, so any binding or registry content change
//!   recomputes; a template revision drops the store.
//! * **Admission queue**: `slots` concurrent lanes pull from a bounded
//!   FIFO; overflow submissions are rejected immediately; jobs carry
//!   optional deadlines (enforced while queued AND while running) and
//!   can be canceled at any point before completion — queued jobs never
//!   start, and a RUNNING job is aborted cooperatively within about one
//!   superstep ([`JobTicket::cancel`]), leaving its pool clean for the
//!   next job.
//! * **Per-request parameter binding**: requests attach named datasets
//!   and scalar parameters through a [`Registry::overlay`] — the cached
//!   template is untouched; only the data the sources resolve changes.
//!
//! ```no_run
//! use labyrinth::serve::{JobRequest, JobService, ServeConfig};
//! use labyrinth::value::Value;
//!
//! let svc = JobService::new(ServeConfig::default());
//! let out = svc
//!     .run(
//!         JobRequest::source("v = source(\"visits\"); c = v.count(); collect(v, \"v\");")
//!             .bind("visits", (0..100).map(Value::I64).collect()),
//!     )
//!     .unwrap();
//! assert_eq!(out.output.collected("v").len(), 100);
//! ```

pub mod bench;
pub mod template;

use crate::error::{Error, Result};
use crate::exec::{ExecConfig, ExecMode, PreambleSharing, RunOutput, WorkerPool};
use crate::frontend::{self, Program};
use crate::metrics::Metrics;
use crate::opt::OptConfig;
use crate::value::Value;
use crate::workload::registry::{self, Registry};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub use template::{CacheOutcome, PlanTemplate, TemplateCache, TemplateKey};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent job slots (one persistent worker pool each).
    pub slots: usize,
    /// Simulated workers per slot (plans are instantiated at this width).
    pub workers: usize,
    /// Maximum queued (not-yet-running) jobs before submissions are
    /// rejected.
    pub queue_cap: usize,
    /// Element-batch size on engine channels.
    pub batch: usize,
    /// Pipelined vs barrier execution.
    pub mode: ExecMode,
    /// §7 build-side state reuse (within a job; never across jobs).
    pub reuse_state: bool,
    /// Base directory for file I/O operators.
    pub io_dir: std::path::PathBuf,
    /// Default optimizer configuration (requests may override).
    pub opt: OptConfig,
    /// Re-optimize cached templates from observed runtime statistics.
    pub adaptive: bool,
    /// Plan-template cache capacity.
    pub max_templates: usize,
    /// Share materialized invariant-preamble bags across jobs whose
    /// binding signatures match (see [`template::BindingSignature`]).
    pub share_preambles: bool,
    /// Run jobs on the legacy element-at-a-time data plane (see
    /// [`ExecConfig::element_path`]); defaults from `LABY_ELEMENT_PATH`.
    pub element_path: bool,
    /// Optional span tracer shared by every lane (see
    /// [`ExecConfig::trace`]): records the serve lifecycle
    /// (queue → compile → bind → epoch → reply) per job and is handed to
    /// each job's engine epoch. Defaults from `LABY_TRACE`.
    pub trace: Option<Arc<crate::obs::Tracer>>,
    /// Superstep-boundary checkpoint cadence for job epochs (see
    /// [`ExecConfig::checkpoint_every`]): `Some(k)` snapshots loop state
    /// every k decision chains so a crashed epoch resumes instead of
    /// rerunning. `None` (default) disables checkpointing.
    pub checkpoint_every: Option<u32>,
    /// Retry budget per job for retryable epoch failures (worker
    /// panics, coordination stalls) — see [`crate::exec::RetryPolicy`].
    /// The job's deadline is enforced across ALL attempts. Recovered
    /// jobs count under `serve.epochs_recovered`, not `jobs_failed`.
    pub max_retries: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 2,
            workers: 2,
            queue_cap: 256,
            // Inherits the engine default (honors LABY_BATCH, so the
            // batch=1 CI suite covers the serving path too).
            batch: crate::exec::default_batch(),
            mode: ExecMode::Pipelined,
            reuse_state: true,
            io_dir: std::path::PathBuf::from("."),
            opt: OptConfig::default(),
            adaptive: true,
            max_templates: 64,
            share_preambles: true,
            element_path: crate::exec::default_element_path(),
            trace: crate::obs::default_tracer(),
            checkpoint_every: None,
            max_retries: 2,
        }
    }
}

/// What program a request runs.
#[derive(Clone)]
pub enum JobSpec {
    /// LabyLang source text (cache identity: text hash; parsed only on a
    /// cache miss).
    Source(String),
    /// A pre-lowered IR program (cache identity:
    /// [`frontend::fingerprint`]).
    Program(Arc<Program>),
}

/// One job submission.
#[derive(Clone)]
pub struct JobRequest {
    /// The program.
    pub spec: JobSpec,
    /// Named datasets bound for this request only (registry overlay).
    pub bindings: Vec<(String, Arc<Vec<Value>>)>,
    /// Scalar parameters, bound as singleton named sources — read them
    /// with `source("name")` (+ `.reduce(..)` to scalarize).
    pub params: Vec<(String, Value)>,
    /// Optimizer override (`None` = the service default; a different
    /// config is a different cache key, never a shared template).
    pub opt: Option<OptConfig>,
    /// Deadline relative to submission: expired-in-queue jobs fail
    /// without running; running jobs are aborted by the driver.
    pub deadline: Option<Duration>,
    /// Per-request deterministic fault-injection schedule (chaos
    /// testing; see [`crate::exec::FaultPlan`]). `None` falls back to
    /// the process-wide `LABY_FAULTS` plan when that is set.
    pub faults: Option<Arc<crate::exec::FaultPlan>>,
}

impl JobRequest {
    /// Request running LabyLang source.
    pub fn source(src: impl Into<String>) -> JobRequest {
        JobRequest {
            spec: JobSpec::Source(src.into()),
            bindings: Vec::new(),
            params: Vec::new(),
            opt: None,
            deadline: None,
            faults: None,
        }
    }

    /// Request running a pre-lowered program.
    pub fn program(p: Program) -> JobRequest {
        JobRequest {
            spec: JobSpec::Program(Arc::new(p)),
            bindings: Vec::new(),
            params: Vec::new(),
            opt: None,
            deadline: None,
            faults: None,
        }
    }

    /// Bind a named dataset for this request.
    pub fn bind(mut self, name: impl Into<String>, items: Vec<Value>) -> JobRequest {
        self.bindings.push((name.into(), Arc::new(items)));
        self
    }

    /// Bind an already-shared dataset without copying.
    pub fn bind_shared(mut self, name: impl Into<String>, items: Arc<Vec<Value>>) -> JobRequest {
        self.bindings.push((name.into(), items));
        self
    }

    /// Bind a scalar parameter (a singleton named source).
    pub fn param(mut self, name: impl Into<String>, v: Value) -> JobRequest {
        self.params.push((name.into(), v));
        self
    }

    /// Override the optimizer configuration.
    pub fn opt(mut self, cfg: OptConfig) -> JobRequest {
        self.opt = Some(cfg);
        self
    }

    /// Set a deadline relative to submission.
    pub fn deadline(mut self, d: Duration) -> JobRequest {
        self.deadline = Some(d);
        self
    }

    /// Attach a deterministic fault-injection schedule to this request
    /// (chaos testing): the job's epoch(s) fire the plan's events and
    /// recover via the service's retry policy.
    pub fn faults(mut self, plan: crate::exec::FaultPlan) -> JobRequest {
        self.faults = Some(Arc::new(plan));
        self
    }
}

/// A completed job.
pub struct JobResult {
    /// The engine's run output (collected bags, metrics, timings).
    pub output: RunOutput,
    /// What the template cache did for this request.
    pub cache: CacheOutcome,
    /// Adaptive revision of the template that ran.
    pub revision: u32,
    /// Time spent waiting in the admission queue.
    pub queued: Duration,
    /// Compile time paid by THIS request (zero on cache hits).
    pub compile: Duration,
}

/// Handle to a submitted job.
pub struct JobTicket {
    id: u64,
    rx: Receiver<Result<JobResult>>,
    cancel: Arc<AtomicBool>,
}

impl JobTicket {
    /// The job's service-assigned id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation, effective at any point before completion. A
    /// job still in the admission queue is dropped before it starts; a
    /// RUNNING job is aborted cooperatively — the driver polls the token
    /// and every worker checks it at superstep/batch boundaries, so the
    /// epoch unwinds within about one superstep and the slot's worker
    /// pool is immediately reusable. The ticket resolves to an error
    /// containing `"canceled"`. Canceling a job that already completed
    /// is a no-op (its buffered result is still delivered).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Block until the job completes (or fails / is canceled).
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| Error::exec("job service dropped the job (shut down?)"))?
    }

    /// [`JobTicket::wait`] with a timeout; `Ok(None)` on timeout (the
    /// ticket is consumed — pair with a deadline for hard bounds).
    pub fn wait_timeout(self, d: Duration) -> Result<Option<JobResult>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r.map(Some),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::exec("job service dropped the job (shut down?)"))
            }
        }
    }
}

struct Queued {
    id: u64,
    req: JobRequest,
    enqueued: Instant,
    deadline: Option<Instant>,
    cancel: Arc<AtomicBool>,
    reply: Sender<Result<JobResult>>,
}

struct QueueState {
    queue: VecDeque<Queued>,
    shutdown: bool,
}

struct Inner {
    cfg: ServeConfig,
    cache: TemplateCache,
    metrics: Arc<Metrics>,
    state: Mutex<QueueState>,
    cv: Condvar,
    next_id: AtomicU64,
    busy: AtomicUsize,
    base_registry: Arc<Registry>,
}

/// The resident job service: template cache + persistent worker pools +
/// admission queue. Cheap to share (`&self` submission API); dropping it
/// drains queued jobs and joins every lane.
pub struct JobService {
    inner: Arc<Inner>,
    lanes: Vec<JoinHandle<()>>,
}

impl JobService {
    /// Start the service: spawns `cfg.slots` executor lanes, each owning
    /// a persistent [`WorkerPool`] of `cfg.workers` threads.
    pub fn new(cfg: ServeConfig) -> JobService {
        JobService::with_registry(cfg, registry::global())
    }

    /// [`JobService::new`] over an explicit base registry (request
    /// overlays stack on top of it).
    pub fn with_registry(cfg: ServeConfig, base: Arc<Registry>) -> JobService {
        let slots = cfg.slots.max(1);
        let inner = Arc::new(Inner {
            cache: TemplateCache::new(cfg.max_templates),
            metrics: Arc::new(Metrics::new()),
            state: Mutex::new(QueueState { queue: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            busy: AtomicUsize::new(0),
            base_registry: base,
            cfg,
        });
        let lanes = (0..slots)
            .map(|lane| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("laby-serve-{lane}"))
                    .spawn(move || lane_main(inner))
                    .expect("spawn serve lane")
            })
            .collect();
        JobService { inner, lanes }
    }

    /// Enqueue a job; returns immediately with a ticket. Fails fast when
    /// the admission queue is full or the service is shut down.
    pub fn submit(&self, req: JobRequest) -> Result<JobTicket> {
        let inner = &self.inner;
        let mut st = inner.state.lock().unwrap();
        if st.shutdown {
            return Err(Error::exec("job service is shut down"));
        }
        if st.queue.len() >= inner.cfg.queue_cap {
            inner.metrics.add("serve.jobs_rejected", 1);
            return Err(Error::exec(format!(
                "admission queue full ({} jobs queued)",
                st.queue.len()
            )));
        }
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        let deadline = req.deadline.map(|d| Instant::now() + d);
        st.queue.push_back(Queued {
            id,
            req,
            enqueued: Instant::now(),
            deadline,
            cancel: cancel.clone(),
            reply: tx,
        });
        let depth = st.queue.len() as u64;
        drop(st);
        inner.metrics.add("serve.jobs_submitted", 1);
        inner.metrics.counter("serve.queue_depth_max").fetch_max(depth, Ordering::Relaxed);
        inner.cv.notify_one();
        Ok(JobTicket { id, rx, cancel })
    }

    /// Submit and wait — the synchronous convenience path.
    pub fn run(&self, req: JobRequest) -> Result<JobResult> {
        self.submit(req)?.wait()
    }

    /// Jobs currently executing (≤ `slots`).
    pub fn busy_slots(&self) -> usize {
        self.inner.busy.load(Ordering::SeqCst)
    }

    /// Jobs waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    /// The service's metrics sink (`serve.*` counters; cache counters are
    /// refreshed on export).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.inner.cache.export(&self.inner.metrics);
        self.inner.metrics.clone()
    }

    /// The template cache (hit/miss/revision counters, capacity).
    pub fn cache(&self) -> &TemplateCache {
        &self.inner.cache
    }

    /// Render a service status report (cache, queue, pool counters).
    pub fn report(&self) -> String {
        let m = self.metrics();
        format!(
            "== serve status ==\nslots: {} x {} workers, busy {}, queued {}\n{}",
            self.inner.cfg.slots.max(1),
            self.inner.cfg.workers,
            self.busy_slots(),
            self.queue_depth(),
            m.report()
        )
    }

    /// Stop accepting submissions, drain queued jobs, and join the lanes
    /// (their worker pools shut down with them).
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.lanes.drain(..) {
            let _ = h.join();
        }
    }

    fn begin_shutdown(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.inner.cv.notify_all();
    }
}

impl Drop for JobService {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.lanes.drain(..) {
            let _ = h.join();
        }
    }
}

/// One executor lane: owns a persistent worker pool, pulls jobs FIFO.
fn lane_main(inner: Arc<Inner>) {
    let pool = WorkerPool::new(inner.cfg.workers);
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        inner.busy.fetch_add(1, Ordering::SeqCst);
        execute_one(&inner, &pool, job);
        inner.busy.fetch_sub(1, Ordering::SeqCst);
    }
}

fn execute_one(inner: &Inner, pool: &WorkerPool, job: Queued) {
    let queued_for = job.enqueued.elapsed();
    inner.metrics.record_time("serve.queue_wait", queued_for);
    // Serve lifecycle spans: a handful per job, recorded straight into
    // the tracer's shared sink on a per-job lane (so concurrent slots
    // never interleave their timelines). The queue span is back-dated to
    // the submission instant.
    let tracer = inner.cfg.trace.as_ref().filter(|t| t.on()).cloned();
    let tlane = tracer.as_ref().map(|t| t.lane(&format!("job {}", job.id)));
    let jid = job.id;
    if let (Some(t), Some(l)) = (tracer.as_ref(), tlane) {
        let now = t.now_ns();
        let q = queued_for.as_nanos() as u64;
        t.push(l, crate::obs::SpanKind::Queue { job: jid }, now.saturating_sub(q), q);
    }
    if job.cancel.load(Ordering::SeqCst) {
        inner.metrics.add("serve.jobs_canceled", 1);
        let _ = job.reply.send(Err(Error::exec(format!("job {} canceled", job.id))));
        return;
    }
    if let Some(d) = job.deadline {
        if Instant::now() >= d {
            inner.metrics.add("serve.jobs_deadline_expired", 1);
            let _ = job
                .reply
                .send(Err(Error::exec(format!("job {} deadline expired in queue", job.id))));
            return;
        }
    }

    // Per-request registry overlay: datasets + scalar params stack over
    // the service base without mutating it.
    let bind_t0 = tracer.as_ref().map(|t| t.now_ns());
    let overlay = Arc::new(Registry::overlay(inner.base_registry.clone()));
    for (name, items) in &job.req.bindings {
        overlay.put_shared(name.clone(), items.clone());
    }
    for (name, v) in &job.req.params {
        overlay.put(name.clone(), vec![v.clone()]);
    }
    if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, bind_t0) {
        let now = t.now_ns();
        t.push(l, crate::obs::SpanKind::Bind { job: jid }, t0, now.saturating_sub(t0));
    }

    // Resolve the plan template (compile at most once per key).
    let opt = job.req.opt.unwrap_or(inner.cfg.opt);
    let key = TemplateKey {
        program: match &job.req.spec {
            JobSpec::Source(src) => template::source_fingerprint(src),
            JobSpec::Program(p) => frontend::fingerprint(p),
        },
        opt: template::opt_fingerprint(&opt),
        exec: template::exec_fingerprint(
            inner.cfg.workers,
            inner.cfg.mode,
            inner.cfg.batch,
            inner.cfg.reuse_state,
        ),
    };
    let source_text = match &job.req.spec {
        JobSpec::Source(src) => Some(src.as_str()),
        JobSpec::Program(_) => None,
    };
    let spec = job.req.spec.clone();
    let compile_t0 = tracer.as_ref().map(|t| t.now_ns());
    let resolved = inner.cache.get_or_compile(
        key,
        source_text,
        &opt,
        inner.cfg.workers.max(1),
        &overlay,
        inner.cfg.adaptive,
        move || match spec {
            JobSpec::Source(src) => frontend::parse_and_lower(&src),
            JobSpec::Program(p) => Ok((*p).clone()),
        },
    );
    let (tpl, outcome) = match resolved {
        Ok(x) => x,
        Err(e) => {
            inner.metrics.add("serve.jobs_failed", 1);
            let _ = job.reply.send(Err(e));
            return;
        }
    };
    let compile = match outcome {
        CacheOutcome::Hit => Duration::ZERO,
        _ => tpl.compile_time,
    };
    if compile > Duration::ZERO {
        // Histogrammed and traced only when a compile actually ran
        // (hits would flood the distribution with zero-length spans).
        inner.metrics.record_time("serve.compile", compile);
        if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, compile_t0) {
            let now = t.now_ns();
            t.push(l, crate::obs::SpanKind::Compile { job: jid }, t0, now.saturating_sub(t0));
        }
    }

    // Cross-job preamble sharing: when the template has shareable
    // invariant-preamble nodes, resolve the binding signature of the
    // sources they read. An earlier submission with a MATCHING signature
    // (exact — pointer or content equality, never a bare hash) has its
    // materialized bags replayed; otherwise this epoch captures its own
    // for later jobs. Both sides are skipped entirely for templates with
    // nothing to share.
    let mut preamble: Option<PreambleSharing> = None;
    let mut capture: Option<(
        template::BindingSignature,
        Arc<std::sync::Mutex<Vec<(usize, usize, Vec<Value>)>>>,
    )> = None;
    if inner.cfg.share_preambles && tpl.has_shareable_preamble() {
        let sig = template::BindingSignature::resolve(&tpl.plan, &overlay);
        if let Some(bags) = tpl.preamble_for(&sig) {
            inner.metrics.add("serve.preamble_hits", 1);
            preamble = Some(PreambleSharing { replay: Some(bags), capture: None });
        } else {
            let sink = Arc::new(std::sync::Mutex::new(Vec::new()));
            preamble = Some(PreambleSharing { replay: None, capture: Some(sink.clone()) });
            capture = Some((sig, sink));
        }
    }

    // Run the cached plan as one epoch on this lane's warm pool.
    let run_cfg = ExecConfig {
        workers: inner.cfg.workers.max(1),
        mode: inner.cfg.mode,
        batch: inner.cfg.batch,
        reuse_state: inner.cfg.reuse_state,
        io_dir: inner.cfg.io_dir.clone(),
        sched: None,
        registry: overlay,
        deadline: job.deadline,
        cancel: Some(job.cancel.clone()),
        preamble,
        element_path: inner.cfg.element_path,
        trace: tracer.clone(),
        checkpoint_every: inner.cfg.checkpoint_every,
        faults: job.req.faults.clone().or_else(crate::exec::default_faults),
        stall_timeout: crate::exec::DEFAULT_STALL_TIMEOUT,
    };
    let epochs_before = pool.epochs();
    let run_t0 = tracer.as_ref().map(|t| t.now_ns());
    // Always route through the recovery layer: retryable epoch failures
    // (injected or genuine worker panics, coordination stalls) burn the
    // service's retry budget, resuming from the last superstep-boundary
    // checkpoint when one was taken. The job's absolute deadline spans
    // every attempt; cancel and deadline aborts are never retried.
    let result = crate::exec::recovery::run_plan_with_recovery(
        tpl.plan.clone(),
        &run_cfg,
        pool,
        &crate::exec::RetryPolicy { max_retries: inner.cfg.max_retries },
    );
    if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, run_t0) {
        let now = t.now_ns();
        t.push(l, crate::obs::SpanKind::JobRun { job: jid }, t0, now.saturating_sub(t0));
    }
    inner.metrics.add("serve.pool_epochs", pool.epochs() - epochs_before);
    match result {
        Ok(output) => {
            // Stats only feed adaptive revisions; skip the per-node map
            // build entirely when the service never revises.
            if inner.cfg.adaptive {
                tpl.record_observed(&output);
            }
            // Store this epoch's materialized preamble bags (only a
            // complete capture from a successful run is ever stored).
            if let Some((sig, sink)) = capture {
                let entries = std::mem::take(&mut *sink.lock().unwrap());
                if let Some(bags) = template::assemble_preamble(&tpl.plan, entries) {
                    tpl.store_preamble(sig, Arc::new(bags));
                }
            }
            // An epoch that crashed and recovered still completes — count
            // the recovery separately so dashboards see fault pressure
            // without inflating `jobs_failed`.
            let retries = output.metrics.get("exec.epoch_retries");
            if retries > 0 {
                inner.metrics.add("serve.epochs_recovered", retries);
            }
            inner.metrics.add("serve.jobs_completed", 1);
            inner.metrics.record_time("serve.job_time", output.elapsed);
            let _ = job.reply.send(Ok(JobResult {
                output,
                cache: outcome,
                revision: tpl.revision,
                queued: queued_for,
                compile,
            }));
        }
        Err(e) => {
            // A mid-run cancel is an expected outcome, not a failure. A
            // cancel racing the deadline can surface under either abort
            // reason (the driver checks the token and the clock on the
            // same wakeup) — if the user canceled, both classify as
            // canceled. Genuine failures (panics, compile errors) are
            // never masked: only the TYPED abort variants qualify.
            let aborted = matches!(e, Error::Canceled | Error::DeadlineExceeded);
            if job.cancel.load(Ordering::SeqCst) && aborted {
                inner.metrics.add("serve.jobs_canceled", 1);
            } else {
                inner.metrics.add("serve.jobs_failed", 1);
            }
            let _ = job.reply.send(Err(e));
        }
    }
    // End-to-end request latency (submit → reply), success or not.
    let total = job.enqueued.elapsed();
    inner.metrics.record_time("serve.request_time", total);
    if let (Some(t), Some(l)) = (tracer.as_ref(), tlane) {
        let now = t.now_ns();
        let ns = total.as_nanos() as u64;
        t.push(l, crate::obs::SpanKind::Request { job: jid }, now.saturating_sub(ns), ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_runs_a_source_job_with_bindings_and_params() {
        let svc = JobService::new(ServeConfig {
            slots: 1,
            workers: 2,
            ..Default::default()
        });
        let res = svc
            .run(
                JobRequest::source(
                    "v = source(\"svc_data\"); t = source(\"svc_thresh\"); \
                     k = t.reduce(|a, b| a + b); f = v.map(|x| x * 2); collect(f, \"f\");",
                )
                .bind("svc_data", (1..=4).map(Value::I64).collect())
                .param("svc_thresh", Value::I64(3)),
            )
            .unwrap();
        assert_eq!(res.cache, CacheOutcome::Miss);
        let mut got = res.output.collected("f").to_vec();
        got.sort();
        assert_eq!(
            got,
            vec![Value::I64(2), Value::I64(4), Value::I64(6), Value::I64(8)]
        );
        // Nothing leaked into the global registry.
        assert!(registry::global().get("svc_data").is_none());
        assert!(registry::global().get("svc_thresh").is_none());
    }

    #[test]
    fn repeated_submissions_hit_the_template_cache() {
        let svc = JobService::new(ServeConfig { slots: 1, adaptive: false, ..Default::default() });
        let req = || JobRequest::source("a = bag(1, 2, 3); collect(a, \"a\");");
        let first = svc.run(req()).unwrap();
        assert_eq!(first.cache, CacheOutcome::Miss);
        assert!(first.compile > Duration::ZERO);
        for _ in 0..3 {
            let r = svc.run(req()).unwrap();
            assert_eq!(r.cache, CacheOutcome::Hit);
            assert_eq!(r.compile, Duration::ZERO);
            assert_eq!(r.output.collected("a").len(), 3);
        }
        assert_eq!(svc.cache().hits(), 3);
        assert_eq!(svc.cache().misses(), 1);
    }

    #[test]
    fn queue_cap_rejects_and_metrics_count_it() {
        let svc = JobService::new(ServeConfig { slots: 1, queue_cap: 0, ..Default::default() });
        let err = svc.submit(JobRequest::source("collect(bag(1), \"x\");")).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(svc.metrics().get("serve.jobs_rejected"), 1);
    }

    #[test]
    fn zero_deadline_expires_in_queue() {
        let svc = JobService::new(ServeConfig { slots: 1, ..Default::default() });
        let err = svc
            .run(
                JobRequest::source("collect(bag(1), \"x\");").deadline(Duration::ZERO),
            )
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let svc = JobService::new(ServeConfig { slots: 1, ..Default::default() });
        let ok = svc.run(JobRequest::source("collect(bag(1), \"x\");"));
        assert!(ok.is_ok());
        svc.shutdown();
    }
}
