//! `serve::` — a resident **job service** for high-throughput repeated
//! jobs.
//!
//! Labyrinth's core result is that per-job control-plane work dominates
//! iterative analytics — yet the engine itself still paid a per-*run*
//! control plane: every `exec::run_plan` re-spawned worker threads, and
//! every caller re-lexed / re-compiled / re-optimized the program. Under
//! a serving workload (the same parameterized programs submitted over
//! and over, "Execution Templates" style) that cost is pure overhead.
//! This module removes it:
//!
//! * **Plan-template cache** ([`template`]): compile → SSA → dataflow →
//!   `opt::optimize` → `ExecPlan` exactly once per (program, optimizer
//!   config, executor config); later requests instantiate the cached
//!   `Arc<ExecPlan>`. Completed runs feed observed cardinalities back,
//!   and drifted templates are **re-optimized in place** (a cache
//!   *revision*, not an invalidation). Eviction is cost-weighted
//!   (decayed usage × compile cost), so hot or expensive templates
//!   outlive cold, cheap ones.
//! * **Persistent worker pools** (`exec::pool`): one [`WorkerPool`] per
//!   job slot, threads resident across jobs; a job is a
//!   message-delimited epoch, so per-job state isolation is structural
//!   (nothing — including §7 `reuse_state` hash tables — survives an
//!   epoch boundary).
//! * **Cross-job preamble sharing**: the one deliberate, proven-safe
//!   exception to absolute epoch isolation. Hoisted loop-invariant
//!   preamble subgraphs (plus the entry-block inputs only they consume)
//!   whose inputs are fully determined by the
//!   template plus its bindings have their materialized bags cached per
//!   `(template, revision, binding signature)` and **replayed** by
//!   later identical submissions instead of recomputed
//!   (`serve.preamble_hits`). Signatures match by exact dataset
//!   identity/content, so any binding or registry content change
//!   recomputes; a template revision drops the store.
//! * **Weighted-fair admission** (multi-tenant): each serve lane runs
//!   per-tenant queues drained by deficit round-robin — every round a
//!   tenant's deficit grows by `weight × quantum` and jobs are dequeued
//!   while the deficit covers their **cost-model-estimated size**
//!   ([`PlanTemplate::est_cost`]), so a burst of expensive jobs from one
//!   tenant can no longer starve another tenant's cheap ones. A tenant
//!   whose queued estimated cost would exceed its `budget` is **shed**
//!   at the front door ([`crate::Error::Overloaded`] with a retry-after
//!   hint, counted `serve.jobs_shed`, never `jobs_failed`). With no
//!   tenants configured the single implicit tenant degenerates to the
//!   original bounded FIFO. Global overflow past `queue_cap` is still
//!   rejected immediately; jobs carry optional deadlines (enforced
//!   while queued AND while running) and can be canceled at any point
//!   before completion ([`JobTicket::cancel`]).
//! * **Shard-pinned placement**: the front door routes each request by
//!   **binding-signature affinity** — (program, bound names) sticks to
//!   the lane that already holds its materialized preamble bags
//!   (lane-pinned in the template's preamble store), falling back to
//!   the least-loaded lane (by queued estimated cost) for new groups —
//!   so warm state is reused instead of recaptured per lane.
//! * **Elastic pools**: when `min_workers < max_workers`, each lane
//!   grows its pool (doubling toward `max_workers`) after sustained
//!   backlog — observed queue depth plus the `serve.queue_wait` /
//!   `serve.job_time` histogram ratio — and shrinks (halving toward
//!   `min_workers`) after consecutive idle ticks. Both directions are
//!   hysteresis-gated and resize strictly **between** job epochs, so an
//!   in-flight job never loses workers. Plans are cached per width, so
//!   a resized lane compiles (once) a template at its new width.
//! * **Per-request parameter binding**: requests attach named datasets
//!   and scalar parameters through a [`Registry::overlay`] — the cached
//!   template is untouched; only the data the sources resolve changes.
//!
//! ```no_run
//! use labyrinth::serve::{JobRequest, JobService, ServeConfig};
//! use labyrinth::value::Value;
//!
//! let svc = JobService::new(ServeConfig::default());
//! let out = svc
//!     .run(
//!         JobRequest::source("v = source(\"visits\"); c = v.count(); collect(v, \"v\");")
//!             .bind("visits", (0..100).map(Value::I64).collect()),
//!     )
//!     .unwrap();
//! assert_eq!(out.output.collected("v").len(), 100);
//! ```

pub mod bench;
pub mod template;

use crate::error::{Error, Result};
use crate::exec::{ExecConfig, ExecMode, PreambleSharing, RunOutput, WorkerPool};
use crate::frontend::{self, Program};
use crate::metrics::Metrics;
use crate::opt::OptConfig;
use crate::value::Value;
use crate::workload::registry::{self, Registry};
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub use template::{CacheOutcome, PlanTemplate, TemplateCache, TemplateKey};

/// DRR debit for a job whose program has never been compiled (no
/// resident template to estimate from): one "typical small job" unit.
const DEFAULT_JOB_COST: f64 = 1024.0;

/// Estimated-cost quantum credited per unit weight per DRR round. Set to
/// the default job cost so a weight-1 tenant earns about one typical job
/// per round.
const DRR_QUANTUM: f64 = 1024.0;

/// Consecutive dequeues that must observe backlog pressure before a lane
/// grows its pool (guards against one-off bursts).
const GROW_HYSTERESIS: u32 = 2;

/// Consecutive idle ticks before a lane shrinks its pool one step.
const SHRINK_HYSTERESIS: u32 = 2;

/// Idle-wait granularity for elastic lanes (shrink opportunities only
/// arise this often; non-elastic lanes block indefinitely as before).
const IDLE_TICK: Duration = Duration::from_millis(25);

/// Bound on the front door's affinity table; overflowing clears it (the
/// next request per group re-pins, possibly to a different lane).
const AFFINITY_CAP: usize = 4096;

/// One tenant's admission policy. Configure via [`ServeConfig::tenants`]
/// and tag requests with [`JobRequest::tenant`]; untagged requests (and
/// unknown tenant names) fall to the implicit `default` tenant
/// (weight 1, unlimited budget).
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant name, matched against [`JobRequest::tenant`].
    pub name: String,
    /// Deficit-round-robin weight: relative share of estimated cost
    /// dequeued per round. Clamped to a small positive floor.
    pub weight: f64,
    /// Maximum queued estimated cost before this tenant's submissions
    /// are shed with [`Error::Overloaded`]. `<= 0` means unlimited.
    pub budget: f64,
}

impl TenantSpec {
    /// A tenant with the given relative weight and no budget cap.
    pub fn new(name: impl Into<String>, weight: f64) -> TenantSpec {
        TenantSpec { name: name.into(), weight, budget: 0.0 }
    }

    /// Set the queued-cost budget past which submissions shed.
    pub fn budget(mut self, b: f64) -> TenantSpec {
        self.budget = b;
        self
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent serve lanes (one persistent worker pool each; the
    /// front door shard-pins templates to lanes — CLI `--lanes`).
    pub slots: usize,
    /// Simulated workers per lane pool (plans are instantiated at the
    /// pool's CURRENT width; this is the starting width).
    pub workers: usize,
    /// Elastic lower bound on a lane pool's width. `0` (default) means
    /// "fixed at `workers`" — no elasticity.
    pub min_workers: usize,
    /// Elastic upper bound on a lane pool's width. `0` (default) means
    /// "fixed at `workers`" — no elasticity.
    pub max_workers: usize,
    /// Multi-tenant admission policy: per-tenant DRR weights and shed
    /// budgets. Empty (default) = one implicit FIFO tenant.
    pub tenants: Vec<TenantSpec>,
    /// Maximum queued (not-yet-running) jobs before submissions are
    /// rejected.
    pub queue_cap: usize,
    /// Element-batch size on engine channels.
    pub batch: usize,
    /// Pipelined vs barrier execution.
    pub mode: ExecMode,
    /// §7 build-side state reuse (within a job; never across jobs).
    pub reuse_state: bool,
    /// Base directory for file I/O operators.
    pub io_dir: std::path::PathBuf,
    /// Default optimizer configuration (requests may override).
    pub opt: OptConfig,
    /// Re-optimize cached templates from observed runtime statistics.
    pub adaptive: bool,
    /// Plan-template cache capacity.
    pub max_templates: usize,
    /// Share materialized invariant-preamble bags across jobs whose
    /// binding signatures match (see [`template::BindingSignature`]).
    pub share_preambles: bool,
    /// Run jobs on the legacy element-at-a-time data plane (see
    /// [`ExecConfig::element_path`]); defaults from `LABY_ELEMENT_PATH`.
    pub element_path: bool,
    /// Optional span tracer shared by every lane (see
    /// [`ExecConfig::trace`]): records the serve lifecycle
    /// (queue → compile → bind → epoch → reply) per job and is handed to
    /// each job's engine epoch. Defaults from `LABY_TRACE`.
    pub trace: Option<Arc<crate::obs::Tracer>>,
    /// Superstep-boundary checkpoint cadence for job epochs (see
    /// [`ExecConfig::checkpoint_every`]): `Some(k)` snapshots loop state
    /// every k decision chains so a crashed epoch resumes instead of
    /// rerunning. `None` (default) disables checkpointing.
    pub checkpoint_every: Option<u32>,
    /// Retry budget per job for retryable epoch failures (worker
    /// panics, coordination stalls) — see [`crate::exec::RetryPolicy`].
    /// The job's deadline is enforced across ALL attempts. Recovered
    /// jobs count under `serve.epochs_recovered`, not `jobs_failed`.
    pub max_retries: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 2,
            workers: 2,
            min_workers: 0,
            max_workers: 0,
            tenants: Vec::new(),
            queue_cap: 256,
            // Inherits the engine default (honors LABY_BATCH, so the
            // batch=1 CI suite covers the serving path too).
            batch: crate::exec::default_batch(),
            mode: ExecMode::Pipelined,
            reuse_state: true,
            io_dir: std::path::PathBuf::from("."),
            opt: OptConfig::default(),
            adaptive: true,
            max_templates: 64,
            share_preambles: true,
            element_path: crate::exec::default_element_path(),
            trace: crate::obs::default_tracer(),
            checkpoint_every: None,
            max_retries: 2,
        }
    }
}

impl ServeConfig {
    /// Effective elastic pool bounds `(min, max)`, resolving the
    /// `0 = fixed at workers` sentinels. `min == max` means the pool
    /// never resizes (the default — identical to the pre-elastic tier).
    pub fn worker_bounds(&self) -> (usize, usize) {
        let w = self.workers.max(1);
        let min = if self.min_workers == 0 { w } else { self.min_workers.max(1) };
        let max = if self.max_workers == 0 { w } else { self.max_workers.max(1) };
        (min, max.max(min))
    }
}

/// What program a request runs.
#[derive(Clone)]
pub enum JobSpec {
    /// LabyLang source text (cache identity: text hash; parsed only on a
    /// cache miss).
    Source(String),
    /// A pre-lowered IR program (cache identity:
    /// [`frontend::fingerprint`]).
    Program(Arc<Program>),
}

/// One job submission.
#[derive(Clone)]
pub struct JobRequest {
    /// The program.
    pub spec: JobSpec,
    /// Named datasets bound for this request only (registry overlay).
    pub bindings: Vec<(String, Arc<Vec<Value>>)>,
    /// Scalar parameters, bound as singleton named sources — read them
    /// with `source("name")` (+ `.reduce(..)` to scalarize).
    pub params: Vec<(String, Value)>,
    /// Optimizer override (`None` = the service default; a different
    /// config is a different cache key, never a shared template).
    pub opt: Option<OptConfig>,
    /// Deadline relative to submission: expired-in-queue jobs fail
    /// without running; running jobs are aborted by the driver.
    pub deadline: Option<Duration>,
    /// Per-request deterministic fault-injection schedule (chaos
    /// testing; see [`crate::exec::FaultPlan`]). `None` falls back to
    /// the process-wide `LABY_FAULTS` plan when that is set.
    pub faults: Option<Arc<crate::exec::FaultPlan>>,
    /// Tenant this request bills against ([`ServeConfig::tenants`]).
    /// `None` or an unconfigured name = the implicit default tenant.
    pub tenant: Option<String>,
}

impl JobRequest {
    /// Request running LabyLang source.
    pub fn source(src: impl Into<String>) -> JobRequest {
        JobRequest {
            spec: JobSpec::Source(src.into()),
            bindings: Vec::new(),
            params: Vec::new(),
            opt: None,
            deadline: None,
            faults: None,
            tenant: None,
        }
    }

    /// Request running a pre-lowered program.
    pub fn program(p: Program) -> JobRequest {
        JobRequest {
            spec: JobSpec::Program(Arc::new(p)),
            bindings: Vec::new(),
            params: Vec::new(),
            opt: None,
            deadline: None,
            faults: None,
            tenant: None,
        }
    }

    /// Bill this request against a configured tenant (weighted-fair
    /// admission + shed budget). Unknown names fall to the default
    /// tenant rather than erroring, so rollouts can tag requests before
    /// the service config catches up.
    pub fn tenant(mut self, name: impl Into<String>) -> JobRequest {
        self.tenant = Some(name.into());
        self
    }

    /// Bind a named dataset for this request.
    pub fn bind(mut self, name: impl Into<String>, items: Vec<Value>) -> JobRequest {
        self.bindings.push((name.into(), Arc::new(items)));
        self
    }

    /// Bind an already-shared dataset without copying.
    pub fn bind_shared(mut self, name: impl Into<String>, items: Arc<Vec<Value>>) -> JobRequest {
        self.bindings.push((name.into(), items));
        self
    }

    /// Bind a scalar parameter (a singleton named source).
    pub fn param(mut self, name: impl Into<String>, v: Value) -> JobRequest {
        self.params.push((name.into(), v));
        self
    }

    /// Override the optimizer configuration.
    pub fn opt(mut self, cfg: OptConfig) -> JobRequest {
        self.opt = Some(cfg);
        self
    }

    /// Set a deadline relative to submission.
    pub fn deadline(mut self, d: Duration) -> JobRequest {
        self.deadline = Some(d);
        self
    }

    /// Attach a deterministic fault-injection schedule to this request
    /// (chaos testing): the job's epoch(s) fire the plan's events and
    /// recover via the service's retry policy.
    pub fn faults(mut self, plan: crate::exec::FaultPlan) -> JobRequest {
        self.faults = Some(Arc::new(plan));
        self
    }
}

/// A completed job.
pub struct JobResult {
    /// The engine's run output (collected bags, metrics, timings).
    pub output: RunOutput,
    /// What the template cache did for this request.
    pub cache: CacheOutcome,
    /// Adaptive revision of the template that ran.
    pub revision: u32,
    /// Time spent waiting in the admission queue.
    pub queued: Duration,
    /// Compile time paid by THIS request (zero on cache hits).
    pub compile: Duration,
    /// The serve lane that executed the job (shard routing, tests).
    pub lane: usize,
}

/// Handle to a submitted job.
pub struct JobTicket {
    id: u64,
    rx: Receiver<Result<JobResult>>,
    cancel: Arc<AtomicBool>,
}

impl JobTicket {
    /// The job's service-assigned id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation, effective at any point before completion. A
    /// job still in the admission queue is dropped before it starts; a
    /// RUNNING job is aborted cooperatively — the driver polls the token
    /// and every worker checks it at superstep/batch boundaries, so the
    /// epoch unwinds within about one superstep and the slot's worker
    /// pool is immediately reusable. The ticket resolves to an error
    /// containing `"canceled"`. Canceling a job that already completed
    /// is a no-op (its buffered result is still delivered).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Block until the job completes (or fails / is canceled).
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| Error::exec("job service dropped the job (shut down?)"))?
    }

    /// [`JobTicket::wait`] with a timeout; `Ok(None)` on timeout (the
    /// ticket is consumed — pair with a deadline for hard bounds).
    pub fn wait_timeout(self, d: Duration) -> Result<Option<JobResult>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r.map(Some),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::exec("job service dropped the job (shut down?)"))
            }
        }
    }
}

struct Queued {
    id: u64,
    req: JobRequest,
    enqueued: Instant,
    deadline: Option<Instant>,
    cancel: Arc<AtomicBool>,
    reply: Sender<Result<JobResult>>,
    /// Index into `Inner::tenants` (0 = the implicit default tenant).
    tenant: usize,
    /// Cost-model-estimated job size — the DRR debit and budget unit.
    cost: f64,
}

/// One tenant's per-lane DRR queue.
struct TenantQueue {
    queue: VecDeque<Queued>,
    /// DRR deficit: estimated cost this tenant may dequeue right now.
    deficit: f64,
    weight: f64,
}

/// One lane's admission state: per-tenant queues + the DRR cursor.
struct LaneQueue {
    tenants: Vec<TenantQueue>,
    cursor: usize,
    /// Queued jobs on this lane (all tenants).
    len: usize,
    /// Queued estimated cost on this lane — the front door's
    /// least-loaded routing signal.
    cost: f64,
}

impl LaneQueue {
    fn new(tenants: &[TenantSpec]) -> LaneQueue {
        LaneQueue {
            tenants: tenants
                .iter()
                .map(|t| TenantQueue {
                    queue: VecDeque::new(),
                    deficit: 0.0,
                    weight: t.weight.max(0.01),
                })
                .collect(),
            cursor: 0,
            len: 0,
            cost: 0.0,
        }
    }

    fn push(&mut self, tenant: usize, job: Queued) {
        self.len += 1;
        self.cost += job.cost;
        self.tenants[tenant].queue.push_back(job);
    }

    /// Deficit-round-robin dequeue: starting at the cursor, an empty
    /// tenant forfeits its deficit; a non-empty tenant whose deficit
    /// covers its head job's estimated cost pops it (debiting the
    /// deficit); otherwise the tenant is credited `weight × quantum` and
    /// the round moves on. With one tenant this is exactly FIFO.
    /// Terminates: some queue is non-empty and weights are positive, so
    /// deficits grow every full round until one covers its head job.
    fn pop(&mut self) -> Option<Queued> {
        if self.len == 0 {
            return None;
        }
        let nt = self.tenants.len();
        loop {
            let i = self.cursor % nt;
            let t = &mut self.tenants[i];
            if t.queue.is_empty() {
                t.deficit = 0.0;
                self.cursor = (self.cursor + 1) % nt;
                continue;
            }
            let head_cost = t.queue.front().expect("non-empty").cost;
            if t.deficit >= head_cost {
                let job = t.queue.pop_front().expect("non-empty");
                t.deficit -= job.cost;
                if t.queue.is_empty() {
                    // An idle tenant must not bank credit (standard DRR).
                    t.deficit = 0.0;
                }
                self.len -= 1;
                self.cost -= job.cost;
                return Some(job);
            }
            t.deficit += t.weight * DRR_QUANTUM;
            self.cursor = (self.cursor + 1) % nt;
        }
    }
}

struct ServiceState {
    lanes: Vec<LaneQueue>,
    /// Affinity-group key → pinned lane (sticky shard placement).
    affinity: FxHashMap<u64, usize>,
    /// Queued estimated cost per tenant, summed across lanes — the shed
    /// budget is enforced against this.
    tenant_cost: Vec<f64>,
    /// Queued jobs per tenant (retry-after hint for sheds).
    tenant_jobs: Vec<usize>,
    /// Total queued jobs (global `queue_cap` enforcement).
    total_len: usize,
    shutdown: bool,
}

struct Inner {
    cfg: ServeConfig,
    cache: TemplateCache,
    metrics: Arc<Metrics>,
    state: Mutex<ServiceState>,
    cv: Condvar,
    next_id: AtomicU64,
    busy: AtomicUsize,
    /// Tenant 0 is the implicit default; configured tenants follow.
    tenants: Vec<TenantSpec>,
    /// Current pool width per lane (lanes publish after each resize).
    lane_widths: Vec<AtomicUsize>,
    base_registry: Arc<Registry>,
}

/// The affinity-group key: program identity × the SET of names the
/// request binds (datasets and params). Values are deliberately NOT
/// hashed — this is a routing hint, not a correctness check (exact
/// binding-signature matching in the preamble store stays authoritative)
/// — so re-submissions of a workload land on the lane holding its warm
/// state regardless of dataset re-allocation.
fn affinity_key(program: u64, req: &JobRequest) -> u64 {
    let mut names: Vec<&str> = req
        .bindings
        .iter()
        .map(|(n, _)| n.as_str())
        .chain(req.params.iter().map(|(n, _)| n.as_str()))
        .collect();
    names.sort_unstable();
    let mut h = rustc_hash::FxHasher::default();
    program.hash(&mut h);
    for n in names {
        n.hash(&mut h);
    }
    h.finish()
}

/// The resident job service: template cache + persistent worker pools +
/// admission queue. Cheap to share (`&self` submission API); dropping it
/// drains queued jobs and joins every lane.
pub struct JobService {
    inner: Arc<Inner>,
    lanes: Vec<JoinHandle<()>>,
}

impl JobService {
    /// Start the service: spawns `cfg.slots` executor lanes, each owning
    /// a persistent [`WorkerPool`] of `cfg.workers` threads.
    pub fn new(cfg: ServeConfig) -> JobService {
        JobService::with_registry(cfg, registry::global())
    }

    /// [`JobService::new`] over an explicit base registry (request
    /// overlays stack on top of it).
    pub fn with_registry(cfg: ServeConfig, base: Arc<Registry>) -> JobService {
        let slots = cfg.slots.max(1);
        // Tenant 0 is the implicit default every untagged (or unknown-
        // tagged) request bills against: weight 1, unlimited budget.
        let mut tenants = vec![TenantSpec::new("default", 1.0)];
        tenants.extend(cfg.tenants.iter().cloned());
        let inner = Arc::new(Inner {
            cache: TemplateCache::new(cfg.max_templates),
            metrics: Arc::new(Metrics::new()),
            state: Mutex::new(ServiceState {
                lanes: (0..slots).map(|_| LaneQueue::new(&tenants)).collect(),
                affinity: FxHashMap::default(),
                tenant_cost: vec![0.0; tenants.len()],
                tenant_jobs: vec![0; tenants.len()],
                total_len: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            busy: AtomicUsize::new(0),
            tenants,
            lane_widths: (0..slots).map(|_| AtomicUsize::new(0)).collect(),
            base_registry: base,
            cfg,
        });
        let lanes = (0..slots)
            .map(|lane| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("laby-serve-{lane}"))
                    .spawn(move || lane_main(inner, lane))
                    .expect("spawn serve lane")
            })
            .collect();
        JobService { inner, lanes }
    }

    /// Enqueue a job; returns immediately with a ticket. Fails fast when
    /// the admission queue is globally full, the tenant's queued
    /// estimated cost exceeds its shed budget ([`Error::Overloaded`]),
    /// or the service is shut down.
    pub fn submit(&self, req: JobRequest) -> Result<JobTicket> {
        let inner = &self.inner;
        // Estimated job size: the resident template's summed row
        // estimates when this program has been compiled before, a
        // typical-job default otherwise. Resolved before taking the
        // state lock (the cache has its own).
        let program_fp = match &req.spec {
            JobSpec::Source(src) => template::source_fingerprint(src),
            JobSpec::Program(p) => frontend::fingerprint(p),
        };
        let cost = inner.cache.peek_cost(program_fp).unwrap_or(DEFAULT_JOB_COST);
        let tenant = req
            .tenant
            .as_deref()
            .and_then(|name| inner.tenants.iter().position(|t| t.name == name))
            .unwrap_or(0);
        let akey = affinity_key(program_fp, &req);

        let mut st = inner.state.lock().unwrap();
        if st.shutdown {
            return Err(Error::exec("job service is shut down"));
        }
        if st.total_len >= inner.cfg.queue_cap {
            inner.metrics.add("serve.jobs_rejected", 1);
            return Err(Error::exec(format!(
                "admission queue full ({} jobs queued)",
                st.total_len
            )));
        }
        // Per-tenant overload shedding: queued estimated cost (across
        // all lanes) past the budget rejects with a retry hint scaled by
        // the tenant's backlog. Shed ≠ failed: the job never entered the
        // queue, and the client is told when to come back.
        let spec = &inner.tenants[tenant];
        if spec.budget > 0.0 && st.tenant_cost[tenant] + cost > spec.budget {
            let retry_after_ms = (25 * (st.tenant_jobs[tenant] as u64 + 1)).clamp(10, 2_000);
            drop(st);
            inner.metrics.add("serve.jobs_shed", 1);
            inner.metrics.add(&format!("serve.tenant.{}.shed", spec.name), 1);
            return Err(Error::Overloaded { retry_after_ms });
        }
        // Shard-pinned placement: sticky affinity lane when the group
        // has one, else the least-loaded lane (queued estimated cost,
        // ties to the shorter queue) — which the group then pins.
        let lane = match st.affinity.get(&akey) {
            Some(&l) if l < st.lanes.len() => l,
            _ => {
                let l = st
                    .lanes
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        a.cost.total_cmp(&b.cost).then(a.len.cmp(&b.len))
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if st.affinity.len() >= AFFINITY_CAP {
                    st.affinity.clear();
                }
                st.affinity.insert(akey, l);
                l
            }
        };
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        let deadline = req.deadline.map(|d| Instant::now() + d);
        st.lanes[lane].push(
            tenant,
            Queued {
                id,
                req,
                enqueued: Instant::now(),
                deadline,
                cancel: cancel.clone(),
                reply: tx,
                tenant,
                cost,
            },
        );
        st.tenant_cost[tenant] += cost;
        st.tenant_jobs[tenant] += 1;
        st.total_len += 1;
        let depth = st.total_len as u64;
        drop(st);
        inner.metrics.add("serve.jobs_submitted", 1);
        inner.metrics.counter("serve.queue_depth_max").fetch_max(depth, Ordering::Relaxed);
        inner.cv.notify_all();
        Ok(JobTicket { id, rx, cancel })
    }

    /// Submit and wait — the synchronous convenience path.
    pub fn run(&self, req: JobRequest) -> Result<JobResult> {
        self.submit(req)?.wait()
    }

    /// Jobs currently executing (≤ `slots`).
    pub fn busy_slots(&self) -> usize {
        self.inner.busy.load(Ordering::SeqCst)
    }

    /// Jobs waiting in the admission queues (all lanes, all tenants).
    pub fn queue_depth(&self) -> usize {
        self.inner.state.lock().unwrap().total_len
    }

    /// Current worker-pool width per lane (elastic sizing; a `0` means
    /// that lane has not started yet).
    pub fn lane_widths(&self) -> Vec<usize> {
        self.inner.lane_widths.iter().map(|w| w.load(Ordering::SeqCst)).collect()
    }

    /// The service's metrics sink (`serve.*` counters; cache counters are
    /// refreshed on export).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.inner.cache.export(&self.inner.metrics);
        self.inner.metrics.clone()
    }

    /// The template cache (hit/miss/revision counters, capacity).
    pub fn cache(&self) -> &TemplateCache {
        &self.inner.cache
    }

    /// Render a service status report (cache, queue, pool counters).
    pub fn report(&self) -> String {
        let m = self.metrics();
        let (min_w, max_w) = self.inner.cfg.worker_bounds();
        let widths: Vec<String> =
            self.lane_widths().iter().map(|w| w.to_string()).collect();
        format!(
            "== serve status ==\nlanes: {} (pool widths [{}], bounds {}..{}), \
             tenants: {}, busy {}, queued {}\n{}",
            self.inner.cfg.slots.max(1),
            widths.join(", "),
            min_w,
            max_w,
            self.inner.tenants.len(),
            self.busy_slots(),
            self.queue_depth(),
            m.report()
        )
    }

    /// Stop accepting submissions, drain queued jobs, and join the lanes
    /// (their worker pools shut down with them).
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.lanes.drain(..) {
            let _ = h.join();
        }
    }

    fn begin_shutdown(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.inner.cv.notify_all();
    }
}

impl Drop for JobService {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.lanes.drain(..) {
            let _ = h.join();
        }
    }
}

/// What a lane's wait loop woke up with.
enum LaneWork {
    /// A dequeued job plus the lane backlog left behind it (the elastic
    /// grow signal, read under the same lock as the pop).
    Job(Box<Queued>, usize),
    /// An elastic lane's idle tick (shrink opportunity).
    Tick,
    Stop,
}

/// One executor lane: owns a persistent (elastic) worker pool and pulls
/// jobs from ITS queue by deficit round-robin across tenants.
fn lane_main(inner: Arc<Inner>, lane: usize) {
    let (min_w, max_w) = inner.cfg.worker_bounds();
    let mut pool = WorkerPool::new(inner.cfg.workers.max(1).clamp(min_w, max_w));
    inner.lane_widths[lane].store(pool.size(), Ordering::SeqCst);
    let elastic = min_w < max_w;
    let mut grow_streak: u32 = 0;
    let mut idle_streak: u32 = 0;
    let mut resize_obs_lane: Option<u32> = None;
    // Publish a pool resize: width gauge, grow/shrink counters, and an
    // instant span on this lane's timeline when tracing is on.
    let note_resize = |inner: &Inner, from: usize, to: usize, lane_id: &mut Option<u32>| {
        inner.lane_widths[lane].store(to, Ordering::SeqCst);
        inner
            .metrics
            .add(if to > from { "serve.pool_grows" } else { "serve.pool_shrinks" }, 1);
        if let Some(t) = inner.cfg.trace.as_ref().filter(|t| t.on()) {
            let l = *lane_id
                .get_or_insert_with(|| t.lane(&format!("serve lane {lane} sizing")));
            t.push(
                l,
                crate::obs::SpanKind::PoolResize {
                    lane: lane as u32,
                    from: from as u32,
                    to: to as u32,
                },
                t.now_ns(),
                0,
            );
        }
    };
    loop {
        let work = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(j) = st.lanes[lane].pop() {
                    st.total_len -= 1;
                    st.tenant_cost[j.tenant] = (st.tenant_cost[j.tenant] - j.cost).max(0.0);
                    st.tenant_jobs[j.tenant] = st.tenant_jobs[j.tenant].saturating_sub(1);
                    let backlog = st.lanes[lane].len;
                    break LaneWork::Job(Box::new(j), backlog);
                }
                if st.shutdown {
                    break LaneWork::Stop;
                }
                if elastic {
                    let (guard, timeout) = inner.cv.wait_timeout(st, IDLE_TICK).unwrap();
                    st = guard;
                    if timeout.timed_out() {
                        break LaneWork::Tick;
                    }
                } else {
                    st = inner.cv.wait(st).unwrap();
                }
            }
        };
        match work {
            LaneWork::Stop => return,
            LaneWork::Tick => {
                // Idle epoch boundary: nothing in flight, nothing queued.
                // Shrink one step (halving) after consecutive idle ticks.
                grow_streak = 0;
                idle_streak += 1;
                if idle_streak >= SHRINK_HYSTERESIS && pool.size() > min_w {
                    let from = pool.size();
                    let to = (from / 2).max(min_w);
                    pool.set_size(to);
                    note_resize(&inner, from, to, &mut resize_obs_lane);
                    idle_streak = 0;
                }
            }
            LaneWork::Job(job, backlog) => {
                idle_streak = 0;
                if elastic && pool.size() < max_w {
                    // Grow signal: jobs queued behind this one, or queue
                    // wait dominating service time in the histograms.
                    let waiting_dominates = || {
                        match (
                            inner.metrics.time_stats("serve.queue_wait"),
                            inner.metrics.time_stats("serve.job_time"),
                        ) {
                            (Some(q), Some(j)) => q.p50 > j.p50,
                            _ => false,
                        }
                    };
                    if backlog >= 2 || (backlog >= 1 && waiting_dominates()) {
                        grow_streak += 1;
                    } else {
                        grow_streak = 0;
                    }
                    if grow_streak >= GROW_HYSTERESIS {
                        let from = pool.size();
                        let to = (from * 2).min(max_w);
                        pool.set_size(to);
                        note_resize(&inner, from, to, &mut resize_obs_lane);
                        grow_streak = 0;
                    }
                }
                inner.busy.fetch_add(1, Ordering::SeqCst);
                execute_one(&inner, &pool, lane, *job);
                inner.busy.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

fn execute_one(inner: &Inner, pool: &WorkerPool, lane: usize, job: Queued) {
    // Plans are instantiated at the pool's CURRENT width (elastic lanes
    // resize between jobs); the width is part of the template key, so
    // each width compiles at most once.
    let width = pool.size().max(1);
    let tenant_name = inner.tenants[job.tenant].name.as_str();
    let queued_for = job.enqueued.elapsed();
    inner.metrics.record_time("serve.queue_wait", queued_for);
    inner.metrics.add(&format!("serve.lane.{lane}.jobs"), 1);
    // Serve lifecycle spans: a handful per job, recorded straight into
    // the tracer's shared sink on a per-job lane (so concurrent slots
    // never interleave their timelines). The queue span is back-dated to
    // the submission instant.
    let tracer = inner.cfg.trace.as_ref().filter(|t| t.on()).cloned();
    let tlane = tracer.as_ref().map(|t| t.lane(&format!("job {}", job.id)));
    let jid = job.id;
    if let (Some(t), Some(l)) = (tracer.as_ref(), tlane) {
        let now = t.now_ns();
        let q = queued_for.as_nanos() as u64;
        t.push(l, crate::obs::SpanKind::Queue { job: jid }, now.saturating_sub(q), q);
    }
    if job.cancel.load(Ordering::SeqCst) {
        inner.metrics.add("serve.jobs_canceled", 1);
        let _ = job.reply.send(Err(Error::exec(format!("job {} canceled", job.id))));
        return;
    }
    if let Some(d) = job.deadline {
        if Instant::now() >= d {
            inner.metrics.add("serve.jobs_deadline_expired", 1);
            let _ = job
                .reply
                .send(Err(Error::exec(format!("job {} deadline expired in queue", job.id))));
            return;
        }
    }

    // Per-request registry overlay: datasets + scalar params stack over
    // the service base without mutating it.
    let bind_t0 = tracer.as_ref().map(|t| t.now_ns());
    let overlay = Arc::new(Registry::overlay(inner.base_registry.clone()));
    for (name, items) in &job.req.bindings {
        overlay.put_shared(name.clone(), items.clone());
    }
    for (name, v) in &job.req.params {
        overlay.put(name.clone(), vec![v.clone()]);
    }
    if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, bind_t0) {
        let now = t.now_ns();
        t.push(l, crate::obs::SpanKind::Bind { job: jid }, t0, now.saturating_sub(t0));
    }

    // Resolve the plan template (compile at most once per key).
    let opt = job.req.opt.unwrap_or(inner.cfg.opt);
    let key = TemplateKey {
        program: match &job.req.spec {
            JobSpec::Source(src) => template::source_fingerprint(src),
            JobSpec::Program(p) => frontend::fingerprint(p),
        },
        opt: template::opt_fingerprint(&opt),
        exec: template::exec_fingerprint(
            width,
            inner.cfg.mode,
            inner.cfg.batch,
            inner.cfg.reuse_state,
        ),
    };
    let source_text = match &job.req.spec {
        JobSpec::Source(src) => Some(src.as_str()),
        JobSpec::Program(_) => None,
    };
    let spec = job.req.spec.clone();
    let compile_t0 = tracer.as_ref().map(|t| t.now_ns());
    let resolved = inner.cache.get_or_compile(
        key,
        source_text,
        &opt,
        width,
        &overlay,
        inner.cfg.adaptive,
        move || match spec {
            JobSpec::Source(src) => frontend::parse_and_lower(&src),
            JobSpec::Program(p) => Ok((*p).clone()),
        },
    );
    let (tpl, outcome) = match resolved {
        Ok(x) => x,
        Err(e) => {
            inner.metrics.add("serve.jobs_failed", 1);
            let _ = job.reply.send(Err(e));
            return;
        }
    };
    let compile = match outcome {
        CacheOutcome::Hit => Duration::ZERO,
        _ => tpl.compile_time,
    };
    if compile > Duration::ZERO {
        // Histogrammed and traced only when a compile actually ran
        // (hits would flood the distribution with zero-length spans).
        inner.metrics.record_time("serve.compile", compile);
        if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, compile_t0) {
            let now = t.now_ns();
            t.push(l, crate::obs::SpanKind::Compile { job: jid }, t0, now.saturating_sub(t0));
        }
    }

    // Cross-job preamble sharing: when the template has shareable
    // invariant-preamble nodes, resolve the binding signature of the
    // sources they read. An earlier submission with a MATCHING signature
    // (exact — pointer or content equality, never a bare hash) has its
    // materialized bags replayed; otherwise this epoch captures its own
    // for later jobs. Both sides are skipped entirely for templates with
    // nothing to share.
    let mut preamble: Option<PreambleSharing> = None;
    let mut capture: Option<(
        template::BindingSignature,
        Arc<std::sync::Mutex<Vec<(usize, usize, Vec<Value>)>>>,
    )> = None;
    if inner.cfg.share_preambles && tpl.has_shareable_preamble() {
        let sig = template::BindingSignature::resolve(&tpl.plan, &overlay);
        if let Some(bags) = tpl.preamble_for(&sig, lane) {
            inner.metrics.add("serve.preamble_hits", 1);
            preamble = Some(PreambleSharing { replay: Some(bags), capture: None });
        } else {
            let sink = Arc::new(std::sync::Mutex::new(Vec::new()));
            preamble = Some(PreambleSharing { replay: None, capture: Some(sink.clone()) });
            capture = Some((sig, sink));
        }
    }

    // Run the cached plan as one epoch on this lane's warm pool.
    let run_cfg = ExecConfig {
        workers: width,
        mode: inner.cfg.mode,
        batch: inner.cfg.batch,
        reuse_state: inner.cfg.reuse_state,
        io_dir: inner.cfg.io_dir.clone(),
        sched: None,
        registry: overlay,
        deadline: job.deadline,
        cancel: Some(job.cancel.clone()),
        preamble,
        element_path: inner.cfg.element_path,
        trace: tracer.clone(),
        checkpoint_every: inner.cfg.checkpoint_every,
        faults: job.req.faults.clone().or_else(crate::exec::default_faults),
        stall_timeout: crate::exec::DEFAULT_STALL_TIMEOUT,
    };
    let epochs_before = pool.epochs();
    let run_t0 = tracer.as_ref().map(|t| t.now_ns());
    // Always route through the recovery layer: retryable epoch failures
    // (injected or genuine worker panics, coordination stalls) burn the
    // service's retry budget, resuming from the last superstep-boundary
    // checkpoint when one was taken. The job's absolute deadline spans
    // every attempt; cancel and deadline aborts are never retried.
    let result = crate::exec::recovery::run_plan_with_recovery(
        tpl.plan.clone(),
        &run_cfg,
        pool,
        &crate::exec::RetryPolicy { max_retries: inner.cfg.max_retries },
    );
    if let (Some(t), Some(l), Some(t0)) = (tracer.as_ref(), tlane, run_t0) {
        let now = t.now_ns();
        t.push(l, crate::obs::SpanKind::JobRun { job: jid }, t0, now.saturating_sub(t0));
    }
    inner.metrics.add("serve.pool_epochs", pool.epochs() - epochs_before);
    match result {
        Ok(output) => {
            // Stats only feed adaptive revisions; skip the per-node map
            // build entirely when the service never revises.
            if inner.cfg.adaptive {
                tpl.record_observed(&output);
            }
            // Store this epoch's materialized preamble bags (only a
            // complete capture from a successful run is ever stored).
            if let Some((sig, sink)) = capture {
                let entries = std::mem::take(&mut *sink.lock().unwrap());
                if let Some(bags) = template::assemble_preamble(&tpl.plan, entries) {
                    tpl.store_preamble(sig, lane, Arc::new(bags));
                }
            }
            // An epoch that crashed and recovered still completes — count
            // the recovery separately so dashboards see fault pressure
            // without inflating `jobs_failed`.
            let retries = output.metrics.get("exec.epoch_retries");
            if retries > 0 {
                inner.metrics.add("serve.epochs_recovered", retries);
            }
            inner.metrics.add("serve.jobs_completed", 1);
            inner.metrics.add(&format!("serve.tenant.{tenant_name}.completed"), 1);
            inner.metrics.record_time("serve.job_time", output.elapsed);
            let _ = job.reply.send(Ok(JobResult {
                output,
                cache: outcome,
                revision: tpl.revision,
                queued: queued_for,
                compile,
                lane,
            }));
        }
        Err(e) => {
            // A mid-run cancel is an expected outcome, not a failure. A
            // cancel racing the deadline can surface under either abort
            // reason (the driver checks the token and the clock on the
            // same wakeup) — if the user canceled, both classify as
            // canceled. Genuine failures (panics, compile errors) are
            // never masked: only the TYPED abort variants qualify.
            let aborted = matches!(e, Error::Canceled | Error::DeadlineExceeded);
            if job.cancel.load(Ordering::SeqCst) && aborted {
                inner.metrics.add("serve.jobs_canceled", 1);
            } else {
                inner.metrics.add("serve.jobs_failed", 1);
            }
            let _ = job.reply.send(Err(e));
        }
    }
    // End-to-end request latency (submit → reply), success or not — the
    // per-tenant series is what the fairness suite and `bench-serve`
    // tail-latency storm read.
    let total = job.enqueued.elapsed();
    inner.metrics.record_time("serve.request_time", total);
    inner
        .metrics
        .record_time(&format!("serve.tenant.{tenant_name}.request_time"), total);
    if let (Some(t), Some(l)) = (tracer.as_ref(), tlane) {
        let now = t.now_ns();
        let ns = total.as_nanos() as u64;
        t.push(l, crate::obs::SpanKind::Request { job: jid }, now.saturating_sub(ns), ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_runs_a_source_job_with_bindings_and_params() {
        let svc = JobService::new(ServeConfig {
            slots: 1,
            workers: 2,
            ..Default::default()
        });
        let res = svc
            .run(
                JobRequest::source(
                    "v = source(\"svc_data\"); t = source(\"svc_thresh\"); \
                     k = t.reduce(|a, b| a + b); f = v.map(|x| x * 2); collect(f, \"f\");",
                )
                .bind("svc_data", (1..=4).map(Value::I64).collect())
                .param("svc_thresh", Value::I64(3)),
            )
            .unwrap();
        assert_eq!(res.cache, CacheOutcome::Miss);
        let mut got = res.output.collected("f").to_vec();
        got.sort();
        assert_eq!(
            got,
            vec![Value::I64(2), Value::I64(4), Value::I64(6), Value::I64(8)]
        );
        // Nothing leaked into the global registry.
        assert!(registry::global().get("svc_data").is_none());
        assert!(registry::global().get("svc_thresh").is_none());
    }

    #[test]
    fn repeated_submissions_hit_the_template_cache() {
        let svc = JobService::new(ServeConfig { slots: 1, adaptive: false, ..Default::default() });
        let req = || JobRequest::source("a = bag(1, 2, 3); collect(a, \"a\");");
        let first = svc.run(req()).unwrap();
        assert_eq!(first.cache, CacheOutcome::Miss);
        assert!(first.compile > Duration::ZERO);
        for _ in 0..3 {
            let r = svc.run(req()).unwrap();
            assert_eq!(r.cache, CacheOutcome::Hit);
            assert_eq!(r.compile, Duration::ZERO);
            assert_eq!(r.output.collected("a").len(), 3);
        }
        assert_eq!(svc.cache().hits(), 3);
        assert_eq!(svc.cache().misses(), 1);
    }

    #[test]
    fn queue_cap_rejects_and_metrics_count_it() {
        let svc = JobService::new(ServeConfig { slots: 1, queue_cap: 0, ..Default::default() });
        let err = svc.submit(JobRequest::source("collect(bag(1), \"x\");")).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(svc.metrics().get("serve.jobs_rejected"), 1);
    }

    #[test]
    fn zero_deadline_expires_in_queue() {
        let svc = JobService::new(ServeConfig { slots: 1, ..Default::default() });
        let err = svc
            .run(
                JobRequest::source("collect(bag(1), \"x\");").deadline(Duration::ZERO),
            )
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let svc = JobService::new(ServeConfig { slots: 1, ..Default::default() });
        let ok = svc.run(JobRequest::source("collect(bag(1), \"x\");"));
        assert!(ok.is_ok());
        svc.shutdown();
    }

    fn dummy_job(tenant: usize, cost: f64, id: u64) -> Queued {
        let (tx, _rx) = channel();
        Queued {
            id,
            req: JobRequest::source("collect(bag(1), \"x\");"),
            enqueued: Instant::now(),
            deadline: None,
            cancel: Arc::new(AtomicBool::new(false)),
            reply: tx,
            tenant,
            cost,
        }
    }

    #[test]
    fn drr_dequeues_weighted_fair_across_tenants() {
        let tenants =
            vec![TenantSpec::new("default", 1.0), TenantSpec::new("light", 3.0)];
        let mut q = LaneQueue::new(&tenants);
        for i in 0..6 {
            q.push(0, dummy_job(0, DEFAULT_JOB_COST, i));
        }
        for i in 0..6 {
            q.push(1, dummy_job(1, DEFAULT_JOB_COST, 100 + i));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|j| j.id).collect();
        assert_eq!(order.len(), 12, "every queued job dequeues");
        // Weight 3 vs 1 with equal costs: while both tenants have
        // backlog, the light tenant dequeues ~3 jobs per heavy one.
        let light_in_first_8 = order.iter().take(8).filter(|&&id| id >= 100).count();
        assert!(light_in_first_8 >= 5, "weighted share respected: {order:?}");
        // Per-tenant order stays FIFO.
        let light: Vec<u64> = order.iter().copied().filter(|&id| id >= 100).collect();
        assert_eq!(light, vec![100, 101, 102, 103, 104, 105]);
    }

    #[test]
    fn single_tenant_drr_is_fifo() {
        let mut q = LaneQueue::new(&[TenantSpec::new("default", 1.0)]);
        for i in 0..5 {
            // Mixed costs must not reorder a single tenant's queue.
            q.push(0, dummy_job(0, DEFAULT_JOB_COST * ((i % 3) + 1) as f64, i));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|j| j.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn tenant_budget_sheds_with_retry_after_never_failed() {
        let svc = JobService::new(ServeConfig {
            slots: 1,
            tenants: vec![TenantSpec::new("capped", 1.0).budget(1.0)],
            ..Default::default()
        });
        let err = svc
            .submit(JobRequest::source("collect(bag(1), \"x\");").tenant("capped"))
            .unwrap_err();
        match err {
            Error::Overloaded { retry_after_ms } => assert!(retry_after_ms >= 10),
            other => panic!("expected Overloaded, got {other}"),
        }
        let m = svc.metrics();
        assert_eq!(m.get("serve.jobs_shed"), 1);
        assert_eq!(m.get("serve.tenant.capped.shed"), 1);
        assert_eq!(m.get("serve.jobs_failed"), 0, "shed is not a failure");
        // The default tenant (unlimited budget) is unaffected.
        svc.run(JobRequest::source("collect(bag(1), \"x\");")).unwrap();
    }

    #[test]
    fn affinity_pins_repeat_submissions_to_one_lane() {
        let svc = JobService::new(ServeConfig { slots: 2, ..Default::default() });
        let req = || JobRequest::source("a = bag(1, 2); collect(a, \"a\");");
        let first = svc.run(req()).unwrap().lane;
        for _ in 0..3 {
            assert_eq!(svc.run(req()).unwrap().lane, first, "sticky affinity lane");
        }
    }
}
