//! The plan-template cache: compile once, instantiate per request.
//!
//! A **template** is a fully compiled execution plan — lex/parse (source
//! submissions), CFG, SSA, dataflow build, `opt::optimize`, and
//! `ExecPlan` physical instantiation — cached under a [`TemplateKey`]:
//! the program's identity hash plus fingerprints of the optimizer and
//! executor configurations (differing opt flags MUST NOT share a
//! template; a plan is only valid for the worker count / mode it was
//! instantiated for). Requests then run the shared `Arc<ExecPlan>`
//! directly, binding their datasets through a registry overlay — the
//! whole per-job control-plane cost collapses to a hash lookup.
//!
//! **Adaptive re-optimization**: each completed run records per-node
//! observed output cardinalities (`RunOutput::node_rows`). When the
//! observations drift from what the current plan was optimized with, the
//! next instantiation recompiles the template with the measured rows
//! pinned into the cost model (`opt::optimize_with_feedback`). This is a
//! cache **revision** — the entry stays resident, its revision counter
//! increments — not an invalidation.

use crate::error::Result;
use crate::exec::{ExecMode, ExecPlan, RunOutput};
use crate::frontend::Program;
use crate::metrics::Metrics;
use crate::opt::{OptConfig, RowFeedback, Speculate};
use crate::workload::registry::Registry;
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Cap on adaptive revisions per template (feedback is deterministic per
/// workload, so this is a safety bound, not an expected ceiling).
const MAX_REVISIONS: u32 = 8;

/// Relative drift between an observed mean and the value the current
/// revision was optimized with before a re-optimization is worth it.
const DRIFT_THRESHOLD: f64 = 0.5;

/// The cache key: program identity × optimizer config × executor config.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TemplateKey {
    /// Program identity: source-text hash or `frontend::fingerprint`.
    pub program: u64,
    /// Optimizer configuration fingerprint.
    pub opt: u64,
    /// Executor configuration fingerprint (workers, mode, batch, reuse).
    pub exec: u64,
}

/// Fingerprint an optimizer configuration for the cache key.
pub fn opt_fingerprint(cfg: &OptConfig) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    cfg.hoist.hash(&mut h);
    cfg.fuse.hash(&mut h);
    cfg.dce.hash(&mut h);
    cfg.pushdown.hash(&mut h);
    cfg.join_sides.hash(&mut h);
    match cfg.speculate {
        Speculate::Auto => 0u8.hash(&mut h),
        Speculate::Always => 1u8.hash(&mut h),
        Speculate::Never => 2u8.hash(&mut h),
    }
    cfg.speculate_threshold.to_bits().hash(&mut h);
    cfg.default_trips.hash(&mut h);
    cfg.max_rounds.hash(&mut h);
    h.finish()
}

/// Fingerprint the executor-relevant configuration for the cache key.
pub fn exec_fingerprint(workers: usize, mode: ExecMode, batch: usize, reuse: bool) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    workers.hash(&mut h);
    matches!(mode, ExecMode::Barrier).hash(&mut h);
    batch.hash(&mut h);
    reuse.hash(&mut h);
    h.finish()
}

/// Hash LabyLang source text for the cache key.
pub fn source_fingerprint(src: &str) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    src.hash(&mut h);
    h.finish()
}

#[derive(Default)]
struct ObservedStats {
    /// name → mean rows per logical output bag, from the latest run.
    latest: Option<RowFeedback>,
    /// The feedback the CURRENT revision was optimized from.
    based_on: Option<RowFeedback>,
}

/// One cached, instantiated plan.
pub struct PlanTemplate {
    /// The cache key this template lives under.
    pub key: TemplateKey,
    /// The source text this template was lowered from (`None` for
    /// pre-lowered `Program` submissions). Checked on every cache hit so
    /// a 64-bit key collision between different source texts can never
    /// serve one tenant another tenant's compiled plan — the collision
    /// degrades to a recompile, not to wrong results. (Program
    /// submissions hash opaque closure identities, which are not
    /// attacker-choosable; the residual 2⁻⁶⁴ accidental risk is
    /// documented.)
    pub source: Option<Arc<str>>,
    /// The lowered program (kept for adaptive recompiles).
    pub program: Arc<Program>,
    /// Optimizer configuration the template was compiled with.
    pub opt: OptConfig,
    /// The shared physical plan requests execute.
    pub plan: Arc<ExecPlan>,
    /// Adaptive revision counter (0 = as first compiled).
    pub revision: u32,
    /// Wall time of the compile that produced this revision.
    pub compile_time: Duration,
    observed: Mutex<ObservedStats>,
}

impl PlanTemplate {
    /// Record observed per-node output cardinalities from a completed run
    /// (mean rows per **logical** bag: totals are summed across
    /// instances, bag counts are per instance).
    pub fn record_observed(&self, out: &RunOutput) {
        let g = &self.plan.graph;
        let mut m: RowFeedback = FxHashMap::default();
        for n in &g.nodes {
            let Some(s) = out.node_rows.get(n.id) else { continue };
            if s.bags == 0 || n.singleton {
                continue;
            }
            let insts = self.plan.num_insts[n.id] as f64;
            m.insert(n.name.clone(), (s.rows as f64) * insts / (s.bags as f64));
        }
        if !m.is_empty() {
            self.observed.lock().unwrap().latest = Some(m);
        }
    }

    /// Mean observed rows recorded for a node name (tests/debugging).
    pub fn observed_rows(&self, name: &str) -> Option<f64> {
        self.observed.lock().unwrap().latest.as_ref().and_then(|m| m.get(name).copied())
    }
}

fn drifted(latest: &RowFeedback, based_on: Option<&RowFeedback>) -> bool {
    let Some(base) = based_on else { return true };
    for (k, &v) in latest {
        let Some(&b) = base.get(k) else { return true };
        if (v - b).abs() / b.abs().max(1.0) > DRIFT_THRESHOLD {
            return true;
        }
    }
    false
}

/// What the cache did for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Compiled fresh (first request under this key, or evicted).
    Miss,
    /// Served the cached template unchanged.
    Hit,
    /// Served the cached entry re-optimized from observed statistics
    /// (counts as a hit *and* a revision).
    Revised,
}

struct CacheMap {
    map: FxHashMap<TemplateKey, Arc<PlanTemplate>>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<TemplateKey>,
}

/// The template cache: bounded, thread-safe, revision-aware.
pub struct TemplateCache {
    inner: Mutex<CacheMap>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    revisions: AtomicU64,
}

impl TemplateCache {
    /// Create a cache holding at most `cap` templates (min 1).
    pub fn new(cap: usize) -> TemplateCache {
        TemplateCache {
            inner: Mutex::new(CacheMap { map: FxHashMap::default(), order: VecDeque::new() }),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            revisions: AtomicU64::new(0),
        }
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    /// Cache misses (fresh compiles) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    /// Adaptive revisions so far.
    pub fn revisions(&self) -> u64 {
        self.revisions.load(Ordering::Relaxed)
    }
    /// Resident templates.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }
    /// True when no template is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy the cache counters into a metrics sink (`serve.cache_*`).
    pub fn export(&self, m: &Metrics) {
        m.counter("serve.cache_hits").store(self.hits(), Ordering::Relaxed);
        m.counter("serve.cache_misses").store(self.misses(), Ordering::Relaxed);
        m.counter("serve.cache_revisions").store(self.revisions(), Ordering::Relaxed);
        m.counter("serve.cache_templates").store(self.len() as u64, Ordering::Relaxed);
    }

    /// Look up (or compile) the template for `key`. `source` is the
    /// submission's source text when it has one — verified against the
    /// cached entry on hits (hash-collision guard). `lower` produces the
    /// program on a miss (source submissions parse here — never on a
    /// hit); `registry` feeds compile-time size hints; `adaptive` enables
    /// feedback revisions. Compilation happens OUTSIDE the cache lock so
    /// lanes never serialize on each other's compiles.
    pub fn get_or_compile(
        &self,
        key: TemplateKey,
        source: Option<&str>,
        opt: &OptConfig,
        workers: usize,
        registry: &Registry,
        adaptive: bool,
        lower: impl FnOnce() -> Result<Program>,
    ) -> Result<(Arc<PlanTemplate>, CacheOutcome)> {
        // Bind the lookup BEFORE the branch: an `if let` scrutinee keeps
        // its temporaries (the lock guard) alive for the whole body, and
        // `maybe_revise` re-locks the cache to swap the entry.
        let cached = {
            let inner = self.inner.lock().unwrap();
            inner.map.get(&key).cloned()
        };
        // A hit must be the SAME program, not merely the same 64-bit
        // hash: on a source-text mismatch fall through and recompile
        // (last-writer-wins overwrite) instead of serving another
        // tenant's plan.
        let collided = |tpl: &PlanTemplate| -> bool {
            matches!((&tpl.source, source), (Some(a), Some(b)) if a.as_ref() != b)
        };
        if let Some(tpl) = cached {
            if !collided(&tpl) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if adaptive {
                    if let Some(revised) = self.maybe_revise(&tpl, workers, registry) {
                        return Ok((revised, CacheOutcome::Revised));
                    }
                }
                return Ok((tpl, CacheOutcome::Hit));
            }
        }

        // Miss: compile outside the lock, then insert (first wins on a
        // race — both compiles are identical by construction; the loser
        // counts as a hit so hits + misses always equals lookups).
        let t0 = Instant::now();
        let program = Arc::new(lower()?);
        let (graph, _report) = crate::compile_with_registry(&program, opt, registry)?;
        // Baseline for drift detection: the model's own row estimates for
        // the optimized graph. The first adaptive revision then fires
        // only when reality disagrees with the estimates — not merely
        // because stats exist.
        let baseline = {
            let rows =
                crate::opt::cost::estimate_rows(&graph, &crate::opt::cost::CostParams::default());
            let mut m: RowFeedback = FxHashMap::default();
            for n in &graph.nodes {
                if !n.singleton {
                    m.insert(n.name.clone(), rows[n.id]);
                }
            }
            m
        };
        let plan = Arc::new(ExecPlan::new(Arc::new(graph), workers));
        let tpl = Arc::new(PlanTemplate {
            key,
            source: source.map(Arc::from),
            program,
            opt: *opt,
            plan,
            revision: 0,
            compile_time: t0.elapsed(),
            observed: Mutex::new(ObservedStats { latest: None, based_on: Some(baseline) }),
        });
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(&key).cloned() {
            // Raced: someone else compiled the same program meanwhile.
            Some(existing) if !collided(&existing) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((existing, CacheOutcome::Hit));
            }
            // Collision overwrite: the key stays in `order` exactly once.
            Some(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                inner.map.insert(key, tpl.clone());
                return Ok((tpl, CacheOutcome::Miss));
            }
            None => {}
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if inner.map.len() >= self.cap {
            if let Some(victim) = inner.order.pop_front() {
                inner.map.remove(&victim);
            }
        }
        inner.map.insert(key, tpl.clone());
        inner.order.push_back(key);
        Ok((tpl, CacheOutcome::Miss))
    }

    /// Re-optimize a cached template from its observed statistics when
    /// they drifted from what the current revision was built with.
    /// Returns the revised template (already swapped into the cache), or
    /// `None` when no revision is warranted — including when the
    /// feedback compile FAILS: a revision is an optimization, so an
    /// error must neither fail the request (the resident plan is valid)
    /// nor retry forever (the triggering stats are retired). The
    /// template's stats mutex is held across the compile so concurrent
    /// lanes cannot duplicate a revision.
    fn maybe_revise(
        &self,
        tpl: &Arc<PlanTemplate>,
        workers: usize,
        registry: &Registry,
    ) -> Option<Arc<PlanTemplate>> {
        let mut obs = tpl.observed.lock().unwrap();
        let latest = obs.latest.clone()?;
        if tpl.revision >= MAX_REVISIONS || !drifted(&latest, obs.based_on.as_ref()) {
            return None;
        }
        let t0 = Instant::now();
        let (graph, _report) =
            match crate::compile_with_feedback(&tpl.program, &tpl.opt, registry, &latest) {
                Ok(x) => x,
                Err(_) => {
                    obs.based_on = obs.latest.take();
                    return None;
                }
            };
        let revised = Arc::new(PlanTemplate {
            key: tpl.key,
            source: tpl.source.clone(),
            program: tpl.program.clone(),
            opt: tpl.opt,
            plan: Arc::new(ExecPlan::new(Arc::new(graph), workers)),
            revision: tpl.revision + 1,
            compile_time: t0.elapsed(),
            observed: Mutex::new(ObservedStats { latest: None, based_on: Some(latest) }),
        });
        // Mark the old entry as revised-from so a racing lane that still
        // holds it does not immediately revise again.
        obs.based_on = obs.latest.take();
        drop(obs);
        self.revisions.fetch_add(1, Ordering::Relaxed);
        // Swap the cache entry in place — but only if the key is still
        // resident. Re-inserting after a concurrent eviction would create
        // an entry with no `order` slot: unevictable forever, silently
        // breaking the capacity bound. An evicted template's revision
        // still serves THIS request; the next one recompiles.
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&tpl.key) {
            inner.map.insert(tpl.key, revised.clone());
        }
        Some(revised)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    const SRC: &str = "a = bag(1, 2, 3); b = a.map(|x| x * 2); collect(b, \"b\");";

    fn key_for(src: &str, opt: &OptConfig) -> TemplateKey {
        TemplateKey {
            program: source_fingerprint(src),
            opt: opt_fingerprint(opt),
            exec: exec_fingerprint(2, ExecMode::Pipelined, 256, true),
        }
    }

    #[test]
    fn differing_opt_flags_do_not_share_a_template() {
        let on = OptConfig::default();
        let off = OptConfig::none();
        assert_ne!(opt_fingerprint(&on), opt_fingerprint(&off));
        assert_ne!(key_for(SRC, &on), key_for(SRC, &off));
        // Exec dimensions separate too.
        assert_ne!(
            exec_fingerprint(2, ExecMode::Pipelined, 256, true),
            exec_fingerprint(4, ExecMode::Pipelined, 256, true)
        );
        assert_ne!(
            exec_fingerprint(2, ExecMode::Pipelined, 256, true),
            exec_fingerprint(2, ExecMode::Barrier, 256, true)
        );
    }

    #[test]
    fn second_lookup_hits_without_lowering() {
        let cache = TemplateCache::new(8);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let key = key_for(SRC, &opt);
        let (t1, o1) = cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || parse_and_lower(SRC))
            .unwrap();
        assert_eq!(o1, CacheOutcome::Miss);
        let (t2, o2) = cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || {
                panic!("hit must not re-lower the program")
            })
            .unwrap();
        assert_eq!(o2, CacheOutcome::Hit);
        assert!(Arc::ptr_eq(&t1.plan, &t2.plan), "the physical plan is shared");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn capacity_evicts_fifo() {
        let cache = TemplateCache::new(1);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let src2 = "a = bag(9); collect(a, \"a\");";
        cache
            .get_or_compile(key_for(SRC, &opt), Some(SRC), &opt, 2, &reg, false, || {
                parse_and_lower(SRC)
            })
            .unwrap();
        cache
            .get_or_compile(key_for(src2, &opt), Some(src2), &opt, 2, &reg, false, || {
                parse_and_lower(src2)
            })
            .unwrap();
        assert_eq!(cache.len(), 1, "capacity 1 evicts the older entry");
        // The evicted key misses again.
        let (_, o) = cache
            .get_or_compile(key_for(SRC, &opt), Some(SRC), &opt, 2, &reg, false, || {
                parse_and_lower(SRC)
            })
            .unwrap();
        assert_eq!(o, CacheOutcome::Miss);
    }

    #[test]
    fn key_collision_recompiles_instead_of_serving_wrong_plan() {
        // Simulate a 64-bit key collision: a DIFFERENT source arriving
        // under an already-cached key must recompile (Miss + overwrite),
        // never serve the resident tenant's plan.
        let cache = TemplateCache::new(4);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let key = key_for(SRC, &opt);
        cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || parse_and_lower(SRC))
            .unwrap();
        let other = "z = bag(7, 8, 9, 10); collect(z, \"z\");";
        let (tpl, o) = cache
            .get_or_compile(key, Some(other), &opt, 2, &reg, false, || parse_and_lower(other))
            .unwrap();
        assert_eq!(o, CacheOutcome::Miss, "collision must not be a hit");
        assert_eq!(tpl.source.as_deref(), Some(other));
        assert_eq!(cache.len(), 1, "overwrite, not a duplicate entry");
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn drift_detection_thresholds() {
        let mut latest = RowFeedback::default();
        latest.insert("n".into(), 100.0);
        assert!(drifted(&latest, None), "no baseline → revise");
        let mut base = RowFeedback::default();
        base.insert("n".into(), 95.0);
        assert!(!drifted(&latest, Some(&base)), "5% drift is noise");
        base.insert("n".into(), 10.0);
        assert!(drifted(&latest, Some(&base)), "10 → 100 is real drift");
    }
}
