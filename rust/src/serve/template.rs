//! The plan-template cache: compile once, instantiate per request.
//!
//! A **template** is a fully compiled execution plan — lex/parse (source
//! submissions), CFG, SSA, dataflow build, `opt::optimize`, and
//! `ExecPlan` physical instantiation — cached under a [`TemplateKey`]:
//! the program's identity hash plus fingerprints of the optimizer and
//! executor configurations (differing opt flags MUST NOT share a
//! template; a plan is only valid for the worker count / mode it was
//! instantiated for). Requests then run the shared `Arc<ExecPlan>`
//! directly, binding their datasets through a registry overlay — the
//! whole per-job control-plane cost collapses to a hash lookup.
//!
//! **Adaptive re-optimization**: each completed run records per-node
//! observed output cardinalities (`RunOutput::node_rows`). When the
//! observations drift from what the current plan was optimized with, the
//! next instantiation recompiles the template with the measured rows
//! pinned into the cost model (`opt::optimize_with_feedback`). This is a
//! cache **revision** — the entry stays resident, its revision counter
//! increments — not an invalidation. Fused nodes carry a per-stage
//! *lineage* of pre-fusion SSA names, so observations recorded against a
//! fused operator still pin the corresponding nodes of the fresh
//! (pre-fusion) graph on the recompile.
//!
//! **Cross-job preamble sharing**: templates whose plan contains
//! binding-determined preamble nodes
//! ([`crate::opt::analysis::binding_determined_preamble`]) keep a small
//! per-template store of materialized preamble bags keyed by **binding
//! signature** ([`BindingSignature`]: the datasets every named source in
//! the preamble closure resolved to). A later job on the same template
//! revision whose signature matches — Arc pointer equality per dataset
//! when possible, exact content comparison otherwise, never a bare hash
//! — replays those bags instead of recomputing the invariant subgraph.
//! Invalidation is structural: any registry / binding content change
//! fails the match, and a revision carries the store over **only** when
//! the revised plan leaves the preamble subgraph structurally unchanged
//! (same nodes / ops / instance counts / wiring — `NodeId`s are remapped
//! by SSA name; see `carry_preambles`); any difference starts it empty.
//!
//! **Eviction** is cost-weighted, not FIFO: see [`TemplateCache`].

use crate::dataflow::{Node, NodeId};
use crate::error::Result;
use crate::exec::{ExecMode, ExecPlan, PreambleBags, RunOutput};
use crate::frontend::{FusedStage, Program, Rhs};
use crate::metrics::Metrics;
use crate::opt::{OptConfig, RowFeedback, Speculate};
use crate::value::Value;
use crate::workload::registry::Registry;
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Cap on adaptive revisions per template (feedback is deterministic per
/// workload, so this is a safety bound, not an expected ceiling).
const MAX_REVISIONS: u32 = 8;

/// Relative drift between an observed mean and the value the current
/// revision was optimized with before a re-optimization is worth it.
const DRIFT_THRESHOLD: f64 = 0.5;

/// Half-life of the usage decay in the eviction score: a template's hit
/// count loses half its weight per this much idle time, so a once-hot
/// entry that went cold eventually loses to a steadily used one.
const EVICT_HALF_LIFE: Duration = Duration::from_secs(60);

/// Materialized preamble results retained per template (one per distinct
/// binding signature). Small: the dominant serving pattern is one hot
/// binding per template, and each entry holds full bags in memory.
const PREAMBLE_CACHE_CAP: usize = 4;

/// The cache key: program identity × optimizer config × executor config.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TemplateKey {
    /// Program identity: source-text hash or `frontend::fingerprint`.
    pub program: u64,
    /// Optimizer configuration fingerprint.
    pub opt: u64,
    /// Executor configuration fingerprint (workers, mode, batch, reuse).
    pub exec: u64,
}

/// Fingerprint an optimizer configuration for the cache key.
pub fn opt_fingerprint(cfg: &OptConfig) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    cfg.hoist.hash(&mut h);
    cfg.fuse.hash(&mut h);
    cfg.dce.hash(&mut h);
    cfg.pushdown.hash(&mut h);
    cfg.join_sides.hash(&mut h);
    match cfg.speculate {
        Speculate::Auto => 0u8.hash(&mut h),
        Speculate::Always => 1u8.hash(&mut h),
        Speculate::Never => 2u8.hash(&mut h),
    }
    cfg.speculate_threshold.to_bits().hash(&mut h);
    cfg.default_trips.hash(&mut h);
    cfg.max_rounds.hash(&mut h);
    h.finish()
}

/// Fingerprint the executor-relevant configuration for the cache key.
pub fn exec_fingerprint(workers: usize, mode: ExecMode, batch: usize, reuse: bool) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    workers.hash(&mut h);
    matches!(mode, ExecMode::Barrier).hash(&mut h);
    batch.hash(&mut h);
    reuse.hash(&mut h);
    h.finish()
}

/// Hash LabyLang source text for the cache key.
pub fn source_fingerprint(src: &str) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    src.hash(&mut h);
    h.finish()
}

#[derive(Default)]
struct ObservedStats {
    /// name → mean rows per logical output bag, from the latest run.
    latest: Option<RowFeedback>,
    /// The feedback the CURRENT revision was optimized from.
    based_on: Option<RowFeedback>,
}

/// One cached, instantiated plan.
pub struct PlanTemplate {
    /// The cache key this template lives under.
    pub key: TemplateKey,
    /// The source text this template was lowered from (`None` for
    /// pre-lowered `Program` submissions). Checked on every cache hit so
    /// a 64-bit key collision between different source texts can never
    /// serve one tenant another tenant's compiled plan — the collision
    /// degrades to a recompile, not to wrong results. (Program
    /// submissions hash opaque closure identities, which are not
    /// attacker-choosable; the residual 2⁻⁶⁴ accidental risk is
    /// documented.)
    pub source: Option<Arc<str>>,
    /// The lowered program (kept for adaptive recompiles).
    pub program: Arc<Program>,
    /// Optimizer configuration the template was compiled with.
    pub opt: OptConfig,
    /// The shared physical plan requests execute.
    pub plan: Arc<ExecPlan>,
    /// Adaptive revision counter (0 = as first compiled).
    pub revision: u32,
    /// Wall time of the compile that produced this revision.
    pub compile_time: Duration,
    observed: Mutex<ObservedStats>,
    /// Requests served from this template (carried across revisions) —
    /// the usage half of the cost-weighted eviction score.
    uses: AtomicU64,
    /// Last time a request resolved this template (eviction decay).
    last_used: Mutex<Instant>,
    /// Estimated total cost of one run of this plan (the cost model's
    /// summed row estimates) — the admission tier's DRR debit and
    /// per-tenant budget unit. Never zero.
    pub est_cost: f64,
    /// Materialized invariant-preamble bags by binding signature
    /// (cross-job sharing). A revision is a NEW `PlanTemplate`; the store
    /// starts empty UNLESS the revised plan's preamble subgraph is
    /// structurally identical, in which case the entries are carried
    /// over with their `NodeId` keys remapped (see `carry_preambles`).
    preambles: Mutex<PreambleStore>,
}

#[derive(Default)]
struct PreambleStore {
    /// `(signature, lane, bags)` in insertion order — matched by linear
    /// scan (the bound is tiny) with exact signature comparison.
    /// Entries are **lane-pinned**: a bag materialized by lane L's pool
    /// replays only for jobs routed back to lane L (the shard-placement
    /// model — in a distributed deployment the bags live in that lane's
    /// worker memory), so the front door's affinity routing is what
    /// makes warm state reusable.
    entries: VecDeque<(BindingSignature, usize, Arc<PreambleBags>)>,
}

/// The resolved inputs a template's shareable preamble reads: each named
/// source in the shareable closure paired with the dataset it resolved to
/// through the request's registry overlay (request bindings and the
/// service base registry both covered; `None` = unbound). Preamble
/// results are stored and matched by **exact** signature — Arc pointer
/// equality per dataset first (free for `bind_shared` / base-registry
/// data), full content comparison otherwise — so, unlike a 64-bit
/// fingerprint, a hash collision can never replay another tenant's bags;
/// this is the same standard as the template cache's source-text
/// collision guard. A stored signature holds `Arc`s to its datasets,
/// keeping them alive for the (bounded) life of the store entry.
/// Matching signatures on the same template revision imply equal
/// preamble bags — UDFs are assumed pure, the optimizer's standing
/// contract.
#[derive(Clone, Debug)]
pub struct BindingSignature {
    sources: Vec<(String, Option<Arc<Vec<Value>>>)>,
}

impl BindingSignature {
    /// Resolve the signature of `plan`'s shareable sources against a
    /// request registry. O(#sources) Arc clones — no dataset content is
    /// read here.
    pub fn resolve(plan: &ExecPlan, registry: &Registry) -> BindingSignature {
        BindingSignature {
            sources: plan
                .shareable_sources
                .iter()
                .map(|name| (name.clone(), registry.get(name)))
                .collect(),
        }
    }

    /// Exact equality, with a pointer fast path per dataset. Content
    /// comparison only runs for datasets re-bound as fresh allocations —
    /// the same order of work the request already paid to build them,
    /// and it exits on the first difference.
    fn matches(&self, other: &BindingSignature) -> bool {
        self.sources.len() == other.sources.len()
            && self.sources.iter().zip(&other.sources).all(|((an, ad), (bn, bd))| {
                an == bn
                    && match (ad, bd) {
                        (None, None) => true,
                        (Some(a), Some(b)) => Arc::ptr_eq(a, b) || a == b,
                        _ => false,
                    }
            })
    }
}

/// Fallback: insert `rows` for `n` into a feedback map — and, for fused
/// chains, map the value back onto the **pre-fusion** SSA names via the
/// stage lineage. Only 1:1 (`Map`) stages can be inverted this way:
/// walking backward from the output, a `Map` stage's input cardinality
/// equals its output cardinality, so every lineage name from the tail
/// back to (and including) the first non-`Map` boundary gets the same
/// row count; past that the walk stops.
///
/// The primary path no longer needs the inversion: `FusedT` counts every
/// interior stage's output at runtime (`NodeRows::stage_rows`), so
/// filter/flatMap interiors reach the recompile with MEASURED rows (see
/// [`PlanTemplate::record_observed`]). This walk remains for runs whose
/// stage counters are absent or incomplete (e.g. a bag replayed from the
/// cross-job preamble store never runs the transform).
fn insert_with_fused_lineage(m: &mut RowFeedback, n: &Node, rows: f64) {
    m.insert(n.name.clone(), rows);
    if let Rhs::Fused { stages, lineage, .. } = &n.op {
        for i in (0..stages.len()).rev() {
            if let Some(name) = lineage.get(i) {
                m.insert(name.clone(), rows);
            }
            if !matches!(stages[i], FusedStage::Map(_)) {
                break;
            }
        }
    }
}

impl PlanTemplate {
    /// Record observed per-node output cardinalities from a completed run
    /// (mean rows per **logical** bag: totals are summed across
    /// instances, bag counts are per instance). Fused nodes additionally
    /// record under their pre-fusion lineage names: preferentially from
    /// the engine's per-stage runtime counters (`NodeRows::stage_rows` —
    /// exact for EVERY stage, filter/flatMap interiors included), falling
    /// back to the 1:1 backward walk of `insert_with_fused_lineage` when
    /// the counters are absent (stage counts can undercount `rows` when
    /// bags were replayed from the preamble store without running the
    /// transform — detected by comparing the tail count to `rows`).
    pub fn record_observed(&self, out: &RunOutput) {
        let g = &self.plan.graph;
        let mut m: RowFeedback = FxHashMap::default();
        for n in &g.nodes {
            let Some(s) = out.node_rows.get(n.id) else { continue };
            if s.bags == 0 || n.singleton {
                continue;
            }
            // Delta-mode nodes circulate per-superstep changed rows, so
            // their `rows` counter is delta traffic — not the operator's
            // logical cardinality. Pinning it would convince the cost
            // model the loop is near-empty; skip (the solution-set size
            // is reported separately as `NodeRows::state_size`).
            if n.delta.is_some() {
                continue;
            }
            let insts = self.plan.num_insts[n.id] as f64;
            let scale = insts / (s.bags as f64);
            if let Rhs::Fused { stages, lineage, .. } = &n.op {
                // Counted runs satisfy tail == rows; an UNCOUNTED run
                // (element-path reference, replayed bags) leaves every
                // stage counter zero, which is indistinguishable from a
                // measured all-zero chain only when nothing flowed at
                // all — so additionally require that something was
                // counted somewhere before trusting the stage values.
                let complete = s.stage_rows.len() == stages.len()
                    && lineage.len() == stages.len()
                    && s.stage_rows.last() == Some(&s.rows)
                    && (s.rows > 0 || s.stage_rows.iter().any(|&r| r > 0));
                if complete {
                    m.insert(n.name.clone(), (s.rows as f64) * scale);
                    for (name, &rows) in lineage.iter().zip(&s.stage_rows) {
                        m.insert(name.clone(), (rows as f64) * scale);
                    }
                    continue;
                }
            }
            insert_with_fused_lineage(&mut m, n, (s.rows as f64) * scale);
        }
        if !m.is_empty() {
            self.observed.lock().unwrap().latest = Some(m);
        }
    }

    /// Mean observed rows recorded for a node name (tests/debugging).
    pub fn observed_rows(&self, name: &str) -> Option<f64> {
        self.observed.lock().unwrap().latest.as_ref().and_then(|m| m.get(name).copied())
    }

    /// Does this template's plan contain any node whose preamble bag may
    /// be shared across jobs?
    pub fn has_shareable_preamble(&self) -> bool {
        self.plan.shareable.iter().any(|&s| s)
    }

    /// Materialized preamble bags whose binding signature exactly
    /// matches AND were captured on `lane` (lane-pinned shard state), if
    /// cached. A hit promotes the entry to most-recent, so eviction is
    /// LRU: rotating through more than `PREAMBLE_CACHE_CAP` distinct
    /// bindings cannot starve a steadily-hit one.
    pub fn preamble_for(&self, sig: &BindingSignature, lane: usize) -> Option<Arc<PreambleBags>> {
        let mut st = self.preambles.lock().unwrap();
        let idx = st.entries.iter().position(|(s, l, _)| *l == lane && s.matches(sig))?;
        let entry = st.entries.remove(idx).expect("matched index is in bounds");
        let bags = entry.2.clone();
        st.entries.push_back(entry);
        Some(bags)
    }

    /// Store materialized preamble bags under a binding signature, pinned
    /// to the lane whose pool materialized them (bounded at
    /// `PREAMBLE_CACHE_CAP` entries, least-recently-matched out first; a
    /// matching same-lane signature is replaced in place).
    pub fn store_preamble(&self, sig: BindingSignature, lane: usize, bags: Arc<PreambleBags>) {
        let mut st = self.preambles.lock().unwrap();
        if let Some(entry) =
            st.entries.iter_mut().find(|(s, l, _)| *l == lane && s.matches(&sig))
        {
            entry.2 = bags;
            return;
        }
        st.entries.push_back((sig, lane, bags));
        if st.entries.len() > PREAMBLE_CACHE_CAP {
            st.entries.pop_front();
        }
    }

    /// Cached preamble results resident for this template (tests).
    pub fn preamble_entries(&self) -> usize {
        self.preambles.lock().unwrap().entries.len()
    }

    /// Bump the usage counters consulted by cost-weighted eviction.
    fn touch(&self) {
        self.uses.fetch_add(1, Ordering::Relaxed);
        *self.last_used.lock().unwrap() = Instant::now();
    }
}

/// Structural signature of a plan's shareable preamble subgraph: one row
/// per shareable node — SSA name, op mnemonic, condition-freeness,
/// instance count, and every input as `(producer name, route)` — sorted
/// by name. Two plans of the SAME program with equal signatures (and
/// equal [`ExecPlan::shareable_sources`]) compute identical preamble bags
/// for identical bindings: node names are SSA values, so an equal name in
/// both plans denotes the same program value, and equal instance counts +
/// routes mean the per-instance partitioning matches too. The name
/// correspondence doubles as the `NodeId` remap for carried bags.
fn preamble_shape(plan: &ExecPlan) -> Vec<(String, String, usize, Vec<String>)> {
    let g = &plan.graph;
    let mut shape: Vec<(String, String, usize, Vec<String>)> = g
        .nodes
        .iter()
        .filter(|n| plan.shareable[n.id])
        .map(|n| {
            let inputs: Vec<String> = n
                .inputs
                .iter()
                .map(|e| format!("{}:{:?}", g.nodes[e.src].name, e.route))
                .collect();
            let op = format!("{}{}", n.op.mnemonic(), if n.cond.is_some() { "?" } else { "" });
            (n.name.clone(), op, plan.num_insts[n.id], inputs)
        })
        .collect();
    shape.sort();
    shape
}

/// Carry a template's materialized preamble store across a **revision**
/// when the revised plan leaves the shareable preamble subgraph
/// structurally unchanged (see [`preamble_shape`]): the cached bags are
/// still byte-valid, only the `NodeId`s they are keyed by may have
/// shifted — remap them by SSA name instead of dropping the store.
/// Returns an empty store when anything about the subgraph differs (the
/// previous, always-safe behavior).
fn carry_preambles(old: &ExecPlan, new: &ExecPlan, store: &PreambleStore) -> PreambleStore {
    if store.entries.is_empty()
        || old.shareable_sources != new.shareable_sources
        || preamble_shape(old) != preamble_shape(new)
    {
        return PreambleStore::default();
    }
    let new_ids: FxHashMap<&str, NodeId> = new
        .graph
        .nodes
        .iter()
        .filter(|n| new.shareable[n.id])
        .map(|n| (n.name.as_str(), n.id))
        .collect();
    let mut out = PreambleStore::default();
    for (sig, lane, bags) in &store.entries {
        let mut remapped = PreambleBags::default();
        let mut ok = true;
        for (&id, per_inst) in bags.iter() {
            let Some(&nid) = old
                .graph
                .nodes
                .get(id)
                .and_then(|n| new_ids.get(n.name.as_str()))
            else {
                ok = false;
                break;
            };
            remapped.insert(nid, per_inst.clone());
        }
        if ok {
            out.entries.push_back((sig.clone(), *lane, Arc::new(remapped)));
        }
    }
    out
}

/// The cost model's summed row estimates over a compiled graph — the
/// admission tier's estimate of "how much work is one run of this
/// plan". Floored at 1 so DRR debits and budget arithmetic never see a
/// zero-cost job.
pub(crate) fn estimated_cost(g: &crate::dataflow::DataflowGraph) -> f64 {
    let params = crate::opt::cost::CostParams::default();
    let rows = crate::opt::cost::estimate_rows(g, &params);
    rows.iter().filter(|r| r.is_finite()).sum::<f64>().max(1.0)
}

/// Assemble per-instance capture-sink entries into [`PreambleBags`],
/// validating completeness: every shareable node must have every physical
/// instance's bag reported exactly once (an epoch whose control flow
/// skipped a preamble, or a partial capture, yields `None` and nothing is
/// stored). Exposed to `serve::execute_one`.
pub(crate) fn assemble_preamble(
    plan: &ExecPlan,
    entries: Vec<(NodeId, usize, Vec<Value>)>,
) -> Option<PreambleBags> {
    let mut slots: FxHashMap<NodeId, Vec<Option<Vec<Value>>>> = FxHashMap::default();
    for (node, inst, items) in entries {
        if node >= plan.shareable.len() || !plan.shareable[node] {
            return None;
        }
        let per = slots.entry(node).or_insert_with(|| vec![None; plan.num_insts[node]]);
        if inst >= per.len() || per[inst].is_some() {
            return None;
        }
        per[inst] = Some(items);
    }
    for (id, &s) in plan.shareable.iter().enumerate() {
        if s && !slots.get(&id).map_or(false, |per| per.iter().all(|o| o.is_some())) {
            return None;
        }
    }
    Some(
        slots
            .into_iter()
            .map(|(id, per)| (id, per.into_iter().map(|o| o.unwrap_or_default()).collect()))
            .collect(),
    )
}

/// The cost-weighted eviction score: decayed usage × compile cost. Low
/// score = cheap to lose — rarely used, long idle, or trivial to
/// recompile. Floors keep a never-hit or instant-compile entry from
/// scoring exactly zero (ties then still order by the other factor).
fn eviction_score(uses: u64, idle: Duration, compile: Duration) -> f64 {
    let decayed = (uses as f64)
        * 0.5_f64.powf(idle.as_secs_f64() / EVICT_HALF_LIFE.as_secs_f64());
    decayed.max(1e-3) * compile.as_secs_f64().max(1e-6)
}

fn drifted(latest: &RowFeedback, based_on: Option<&RowFeedback>) -> bool {
    let Some(base) = based_on else { return true };
    for (k, &v) in latest {
        let Some(&b) = base.get(k) else { return true };
        if (v - b).abs() / b.abs().max(1.0) > DRIFT_THRESHOLD {
            return true;
        }
    }
    false
}

/// What the cache did for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Compiled fresh (first request under this key, or evicted).
    Miss,
    /// Served the cached template unchanged.
    Hit,
    /// Served the cached entry re-optimized from observed statistics
    /// (counts as a hit *and* a revision).
    Revised,
}

struct CacheMap {
    map: FxHashMap<TemplateKey, Arc<PlanTemplate>>,
}

/// The template cache: bounded, thread-safe, revision-aware. Eviction is
/// **cost-weighted** (not FIFO): when full, the entry with the lowest
/// `eviction_score` — time-decayed hit count × measured compile
/// latency — is dropped, so a hot or expensive-to-rebuild template
/// outlives a cold, cheap one regardless of insertion order.
pub struct TemplateCache {
    inner: Mutex<CacheMap>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    revisions: AtomicU64,
    evictions: AtomicU64,
    /// Preamble-store entries carried across revisions (structurally
    /// unchanged preamble subgraphs; see `carry_preambles`).
    preambles_carried: AtomicU64,
}

impl TemplateCache {
    /// Create a cache holding at most `cap` templates (min 1).
    pub fn new(cap: usize) -> TemplateCache {
        TemplateCache {
            inner: Mutex::new(CacheMap { map: FxHashMap::default() }),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            revisions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            preambles_carried: AtomicU64::new(0),
        }
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    /// Cache misses (fresh compiles) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    /// Adaptive revisions so far.
    pub fn revisions(&self) -> u64 {
        self.revisions.load(Ordering::Relaxed)
    }
    /// Cost-weighted evictions so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
    /// Preamble-store entries carried across revisions so far.
    pub fn preambles_carried(&self) -> u64 {
        self.preambles_carried.load(Ordering::Relaxed)
    }
    /// Resident templates.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }
    /// True when no template is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Estimated run cost of any resident template compiled from the
    /// program with fingerprint `program` (regardless of opt/exec key
    /// dimensions — cost estimates differ little across them and the
    /// admission tier only needs an order of magnitude). `None` when the
    /// program has never been compiled; the caller then debits a default
    /// cost. O(cap) scan, off every hot path (one lookup per submit).
    pub fn peek_cost(&self, program: u64) -> Option<f64> {
        let inner = self.inner.lock().unwrap();
        inner
            .map
            .iter()
            .find(|(k, _)| k.program == program)
            .map(|(_, t)| t.est_cost)
    }

    /// Copy the cache counters into a metrics sink (`serve.cache_*`).
    pub fn export(&self, m: &Metrics) {
        m.counter("serve.cache_hits").store(self.hits(), Ordering::Relaxed);
        m.counter("serve.cache_misses").store(self.misses(), Ordering::Relaxed);
        m.counter("serve.cache_revisions").store(self.revisions(), Ordering::Relaxed);
        m.counter("serve.cache_templates").store(self.len() as u64, Ordering::Relaxed);
        m.counter("serve.evictions_cost_weighted").store(self.evictions(), Ordering::Relaxed);
        m.counter("serve.preambles_carried").store(self.preambles_carried(), Ordering::Relaxed);
    }

    /// Look up (or compile) the template for `key`. `source` is the
    /// submission's source text when it has one — verified against the
    /// cached entry on hits (hash-collision guard). `lower` produces the
    /// program on a miss (source submissions parse here — never on a
    /// hit); `registry` feeds compile-time size hints; `adaptive` enables
    /// feedback revisions. Compilation happens OUTSIDE the cache lock so
    /// lanes never serialize on each other's compiles.
    pub fn get_or_compile(
        &self,
        key: TemplateKey,
        source: Option<&str>,
        opt: &OptConfig,
        workers: usize,
        registry: &Registry,
        adaptive: bool,
        lower: impl FnOnce() -> Result<Program>,
    ) -> Result<(Arc<PlanTemplate>, CacheOutcome)> {
        // Bind the lookup BEFORE the branch: an `if let` scrutinee keeps
        // its temporaries (the lock guard) alive for the whole body, and
        // `maybe_revise` re-locks the cache to swap the entry.
        let cached = {
            let inner = self.inner.lock().unwrap();
            inner.map.get(&key).cloned()
        };
        // A hit must be the SAME program, not merely the same 64-bit
        // hash: on a source-text mismatch fall through and recompile
        // (last-writer-wins overwrite) instead of serving another
        // tenant's plan.
        let collided = |tpl: &PlanTemplate| -> bool {
            matches!((&tpl.source, source), (Some(a), Some(b)) if a.as_ref() != b)
        };
        if let Some(tpl) = cached {
            if !collided(&tpl) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                tpl.touch();
                if adaptive {
                    if let Some(revised) = self.maybe_revise(&tpl, workers, registry) {
                        return Ok((revised, CacheOutcome::Revised));
                    }
                }
                return Ok((tpl, CacheOutcome::Hit));
            }
        }

        // Miss: compile outside the lock, then insert (first wins on a
        // race — both compiles are identical by construction; the loser
        // counts as a hit so hits + misses always equals lookups).
        let t0 = Instant::now();
        let program = Arc::new(lower()?);
        let (graph, _report) = crate::compile_with_registry(&program, opt, registry)?;
        // Baseline for drift detection: the model's own row estimates for
        // the optimized graph. The first adaptive revision then fires
        // only when reality disagrees with the estimates — not merely
        // because stats exist.
        let baseline = {
            let params = crate::opt::cost::CostParams::default();
            let rows = crate::opt::cost::estimate_rows(&graph, &params);
            let mut m: RowFeedback = FxHashMap::default();
            for n in &graph.nodes {
                if n.singleton {
                    continue;
                }
                m.insert(n.name.clone(), rows[n.id]);
                // Lineage names get per-stage MODEL estimates, symmetric
                // with the per-stage runtime counters `record_observed`
                // reads — so observed-vs-baseline drift is compared
                // stage by stage for fused chains (interior filter /
                // flatMap stages included).
                if let Rhs::Fused { stages, lineage, .. } = &n.op {
                    let input_rows =
                        n.inputs.first().map(|e| rows[e.src]).unwrap_or(0.0);
                    let per = crate::opt::cost::fused_stage_rows(stages, input_rows, &params);
                    for (name, est) in lineage.iter().zip(per) {
                        m.insert(name.clone(), est);
                    }
                }
            }
            m
        };
        let est_cost = estimated_cost(&graph);
        let plan = Arc::new(ExecPlan::new(Arc::new(graph), workers));
        let tpl = Arc::new(PlanTemplate {
            key,
            source: source.map(Arc::from),
            program,
            opt: *opt,
            plan,
            revision: 0,
            compile_time: t0.elapsed(),
            est_cost,
            observed: Mutex::new(ObservedStats { latest: None, based_on: Some(baseline) }),
            uses: AtomicU64::new(1),
            last_used: Mutex::new(Instant::now()),
            preambles: Mutex::new(PreambleStore::default()),
        });
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(&key).cloned() {
            // Raced: someone else compiled the same program meanwhile.
            Some(existing) if !collided(&existing) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                existing.touch();
                return Ok((existing, CacheOutcome::Hit));
            }
            // Collision overwrite: replaces the resident entry in place.
            Some(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                inner.map.insert(key, tpl.clone());
                return Ok((tpl, CacheOutcome::Miss));
            }
            None => {}
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if inner.map.len() >= self.cap {
            // Cost-weighted eviction: drop the entry with the lowest
            // decayed-usage × compile-cost score. O(cap) scan — the cap
            // is small and eviction is off the hit path.
            let now = Instant::now();
            let victim = inner
                .map
                .iter()
                .map(|(k, t)| {
                    let idle = now.saturating_duration_since(*t.last_used.lock().unwrap());
                    (eviction_score(t.uses.load(Ordering::Relaxed), idle, t.compile_time), *k)
                })
                .min_by(|a, b| a.0.total_cmp(&b.0))
                .map(|(_, k)| k);
            if let Some(v) = victim {
                inner.map.remove(&v);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(key, tpl.clone());
        Ok((tpl, CacheOutcome::Miss))
    }

    /// Re-optimize a cached template from its observed statistics when
    /// they drifted from what the current revision was built with.
    /// Returns the revised template (already swapped into the cache), or
    /// `None` when no revision is warranted — including when the
    /// feedback compile FAILS: a revision is an optimization, so an
    /// error must neither fail the request (the resident plan is valid)
    /// nor retry forever (the triggering stats are retired). The
    /// template's stats mutex is held across the compile so concurrent
    /// lanes cannot duplicate a revision.
    fn maybe_revise(
        &self,
        tpl: &Arc<PlanTemplate>,
        workers: usize,
        registry: &Registry,
    ) -> Option<Arc<PlanTemplate>> {
        let mut obs = tpl.observed.lock().unwrap();
        let latest = obs.latest.clone()?;
        if tpl.revision >= MAX_REVISIONS || !drifted(&latest, obs.based_on.as_ref()) {
            return None;
        }
        let t0 = Instant::now();
        let (graph, _report) =
            match crate::compile_with_feedback(&tpl.program, &tpl.opt, registry, &latest) {
                Ok(x) => x,
                Err(_) => {
                    obs.based_on = obs.latest.take();
                    return None;
                }
            };
        let est_cost = estimated_cost(&graph);
        let new_plan = Arc::new(ExecPlan::new(Arc::new(graph), workers));
        // Materialized preamble results survive the revision ONLY when
        // the binding-determined preamble subgraph is structurally
        // unchanged (same nodes, ops, instance counts, wiring): the
        // cached bags are then still exact, and only their NodeId keys
        // need remapping. Any structural difference — re-partitioning,
        // different hoisting or fusion inside the preamble — drops the
        // store (the previous, always-safe behavior).
        let carried = {
            let store = tpl.preambles.lock().unwrap();
            carry_preambles(&tpl.plan, &new_plan, &store)
        };
        let carried_entries = carried.entries.len() as u64;
        let revised = Arc::new(PlanTemplate {
            key: tpl.key,
            source: tpl.source.clone(),
            program: tpl.program.clone(),
            opt: tpl.opt,
            plan: new_plan,
            revision: tpl.revision + 1,
            compile_time: t0.elapsed(),
            est_cost,
            observed: Mutex::new(ObservedStats { latest: None, based_on: Some(latest) }),
            // Usage history survives the revision (the entry is the same
            // logical template for eviction purposes).
            uses: AtomicU64::new(tpl.uses.load(Ordering::Relaxed)),
            last_used: Mutex::new(*tpl.last_used.lock().unwrap()),
            preambles: Mutex::new(carried),
        });
        self.preambles_carried.fetch_add(carried_entries, Ordering::Relaxed);
        // Mark the old entry as revised-from so a racing lane that still
        // holds it does not immediately revise again.
        obs.based_on = obs.latest.take();
        drop(obs);
        self.revisions.fetch_add(1, Ordering::Relaxed);
        // Swap the cache entry in place — but only if the key is still
        // resident. Re-inserting after a concurrent eviction would exceed
        // the capacity bound (the insert path only evicts on misses). An
        // evicted template's revision still serves THIS request; the next
        // one recompiles.
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&tpl.key) {
            inner.map.insert(tpl.key, revised.clone());
        }
        Some(revised)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    const SRC: &str = "a = bag(1, 2, 3); b = a.map(|x| x * 2); collect(b, \"b\");";

    fn key_for(src: &str, opt: &OptConfig) -> TemplateKey {
        TemplateKey {
            program: source_fingerprint(src),
            opt: opt_fingerprint(opt),
            exec: exec_fingerprint(2, ExecMode::Pipelined, 256, true),
        }
    }

    #[test]
    fn differing_opt_flags_do_not_share_a_template() {
        let on = OptConfig::default();
        let off = OptConfig::none();
        assert_ne!(opt_fingerprint(&on), opt_fingerprint(&off));
        assert_ne!(key_for(SRC, &on), key_for(SRC, &off));
        // Exec dimensions separate too.
        assert_ne!(
            exec_fingerprint(2, ExecMode::Pipelined, 256, true),
            exec_fingerprint(4, ExecMode::Pipelined, 256, true)
        );
        assert_ne!(
            exec_fingerprint(2, ExecMode::Pipelined, 256, true),
            exec_fingerprint(2, ExecMode::Barrier, 256, true)
        );
    }

    #[test]
    fn second_lookup_hits_without_lowering() {
        let cache = TemplateCache::new(8);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let key = key_for(SRC, &opt);
        let (t1, o1) = cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || parse_and_lower(SRC))
            .unwrap();
        assert_eq!(o1, CacheOutcome::Miss);
        let (t2, o2) = cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || {
                panic!("hit must not re-lower the program")
            })
            .unwrap();
        assert_eq!(o2, CacheOutcome::Hit);
        assert!(Arc::ptr_eq(&t1.plan, &t2.plan), "the physical plan is shared");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn capacity_bound_holds_and_evictions_are_counted() {
        let cache = TemplateCache::new(1);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let src2 = "a = bag(9); collect(a, \"a\");";
        cache
            .get_or_compile(key_for(SRC, &opt), Some(SRC), &opt, 2, &reg, false, || {
                parse_and_lower(SRC)
            })
            .unwrap();
        cache
            .get_or_compile(key_for(src2, &opt), Some(src2), &opt, 2, &reg, false, || {
                parse_and_lower(src2)
            })
            .unwrap();
        assert_eq!(cache.len(), 1, "capacity 1 keeps exactly one entry");
        assert_eq!(cache.evictions(), 1);
        // The evicted key misses again.
        let (_, o) = cache
            .get_or_compile(key_for(SRC, &opt), Some(SRC), &opt, 2, &reg, false, || {
                parse_and_lower(SRC)
            })
            .unwrap();
        assert_eq!(o, CacheOutcome::Miss);
    }

    #[test]
    fn eviction_prefers_cold_entries_over_hot_ones() {
        // FIFO would evict the OLDEST entry; the cost-weighted policy
        // must instead evict the entry with the least (decayed) usage.
        let cache = TemplateCache::new(2);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let hot = SRC;
        let cold = "a = bag(9); collect(a, \"a\");";
        let newer = "z = bag(4, 5); collect(z, \"z\");";
        let compile = |src: &str| {
            cache
                .get_or_compile(key_for(src, &opt), Some(src), &opt, 2, &reg, false, || {
                    parse_and_lower(src)
                })
                .unwrap()
        };
        compile(hot); // oldest entry...
        for _ in 0..10 {
            let (_, o) = compile(hot); // ...but heavily used
            assert_eq!(o, CacheOutcome::Hit);
        }
        compile(cold);
        compile(newer); // cache full: someone must go
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        let (_, o) = compile(hot);
        assert_eq!(o, CacheOutcome::Hit, "the hot entry survived despite being oldest");
        let (_, o) = compile(cold);
        assert_eq!(o, CacheOutcome::Miss, "the cold entry was the victim");
    }

    #[test]
    fn eviction_score_orders_by_usage_decay_and_compile_cost() {
        let c = Duration::from_millis(10);
        // More usage, same idle/compile → higher score.
        assert!(eviction_score(10, Duration::ZERO, c) > eviction_score(1, Duration::ZERO, c));
        // Longer idle decays the same usage.
        assert!(
            eviction_score(8, Duration::from_secs(600), c) < eviction_score(8, Duration::ZERO, c)
        );
        // A compile 100x more expensive outweighs equal usage.
        assert!(
            eviction_score(2, Duration::ZERO, Duration::from_millis(1000))
                > eviction_score(2, Duration::ZERO, c)
        );
        // Decay is a half-life: one half-life halves the weight.
        let full = eviction_score(4, Duration::ZERO, c);
        let halved = eviction_score(4, EVICT_HALF_LIFE, c);
        assert!((halved / full - 0.5).abs() < 1e-6);
    }

    #[test]
    fn key_collision_recompiles_instead_of_serving_wrong_plan() {
        // Simulate a 64-bit key collision: a DIFFERENT source arriving
        // under an already-cached key must recompile (Miss + overwrite),
        // never serve the resident tenant's plan.
        let cache = TemplateCache::new(4);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let key = key_for(SRC, &opt);
        cache
            .get_or_compile(key, Some(SRC), &opt, 2, &reg, false, || parse_and_lower(SRC))
            .unwrap();
        let other = "z = bag(7, 8, 9, 10); collect(z, \"z\");";
        let (tpl, o) = cache
            .get_or_compile(key, Some(other), &opt, 2, &reg, false, || parse_and_lower(other))
            .unwrap();
        assert_eq!(o, CacheOutcome::Miss, "collision must not be a hit");
        assert_eq!(tpl.source.as_deref(), Some(other));
        assert_eq!(cache.len(), 1, "overwrite, not a duplicate entry");
        assert_eq!(cache.misses(), 2);
    }

    fn sig_of(n: i64) -> BindingSignature {
        use crate::value::Value;
        BindingSignature {
            sources: vec![("k".to_string(), Some(Arc::new(vec![Value::I64(n)])))],
        }
    }

    #[test]
    fn preamble_store_is_bounded_and_replaces_matching_signatures() {
        let cache = TemplateCache::new(4);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let (tpl, _) = cache
            .get_or_compile(key_for(SRC, &opt), Some(SRC), &opt, 2, &reg, false, || {
                parse_and_lower(SRC)
            })
            .unwrap();
        assert!(tpl.preamble_for(&sig_of(1), 0).is_none());
        let n_sigs = PREAMBLE_CACHE_CAP as i64 + 3;
        for b in 0..n_sigs {
            tpl.store_preamble(sig_of(b), 0, Arc::new(PreambleBags::default()));
        }
        assert!(tpl.preamble_entries() <= PREAMBLE_CACHE_CAP, "store stays bounded");
        assert!(tpl.preamble_for(&sig_of(n_sigs - 1), 0).is_some(), "latest entry resident");
        assert!(tpl.preamble_for(&sig_of(0), 0).is_none(), "oldest entry evicted");
        // Re-storing a matching signature replaces in place, no growth.
        let before = tpl.preamble_entries();
        tpl.store_preamble(sig_of(n_sigs - 1), 0, Arc::new(PreambleBags::default()));
        assert_eq!(tpl.preamble_entries(), before);
        // LRU promotion: matching the oldest resident entry makes it the
        // most recent, so the NEXT insertion evicts its neighbor instead.
        let oldest_resident = n_sigs - PREAMBLE_CACHE_CAP as i64;
        assert!(tpl.preamble_for(&sig_of(oldest_resident), 0).is_some());
        tpl.store_preamble(sig_of(n_sigs), 0, Arc::new(PreambleBags::default()));
        assert!(
            tpl.preamble_for(&sig_of(oldest_resident), 0).is_some(),
            "a steadily-hit signature survives rotation"
        );
        assert!(
            tpl.preamble_for(&sig_of(oldest_resident + 1), 0).is_none(),
            "the least-recently-matched entry was the victim"
        );
        // Lane pinning: an entry captured on lane 0 never replays for a
        // job routed to lane 1 — shard state does not bleed across lanes.
        assert!(tpl.preamble_for(&sig_of(n_sigs - 1), 1).is_none(), "lane-pinned entries");
    }

    #[test]
    fn preamble_store_carries_only_across_structurally_unchanged_plans() {
        use crate::value::Value;
        crate::workload::registry::global()
            .put("tplcarry_src", vec![Value::I64(1), Value::I64(2)]);
        let g = crate::compile_source(
            "d = 1; while (d <= 3) { v = source(\"tplcarry_src\").map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        )
        .unwrap();
        crate::workload::registry::global().clear_prefix("tplcarry_src");
        let plan_a = ExecPlan::new(Arc::new(g.clone()), 2);
        let plan_b = ExecPlan::new(Arc::new(g.clone()), 2);
        let plan_w4 = ExecPlan::new(Arc::new(g), 4);
        assert!(plan_a.shareable.iter().any(|&s| s), "premise: shareable preamble");

        let mut store = PreambleStore::default();
        let bags: PreambleBags = plan_a
            .shareable
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(id, _)| (id, vec![Vec::new(); plan_a.num_insts[id]]))
            .collect();
        store.entries.push_back((sig_of(1), 0, Arc::new(bags)));

        // Identical structure: the entry is carried, keys land on the
        // same shareable node set.
        let carried = carry_preambles(&plan_a, &plan_b, &store);
        assert_eq!(carried.entries.len(), 1, "structurally unchanged plan keeps the store");
        let (_, _, carried_bags) = &carried.entries[0];
        for (id, &s) in plan_b.shareable.iter().enumerate() {
            assert_eq!(s, carried_bags.contains_key(&id), "node {id} remap");
        }

        // Different worker count changes instance counts: dropped.
        let dropped = carry_preambles(&plan_a, &plan_w4, &store);
        assert!(dropped.entries.is_empty(), "re-partitioned preamble must drop the store");
    }

    #[test]
    fn binding_signature_matches_content_not_allocation_identity() {
        use crate::value::Value;
        crate::workload::registry::global().put(
            "tplfp_src",
            vec![Value::I64(1), Value::I64(2)],
        );
        let g = crate::compile_source(
            "d = 1; while (d <= 3) { v = source(\"tplfp_src\").map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        )
        .unwrap();
        crate::workload::registry::global().clear_prefix("tplfp_src");
        let plan = ExecPlan::new(Arc::new(g), 2);
        assert!(plan.shareable.iter().any(|&s| s), "premise: chain hoisted + shareable");
        let reg_a = Registry::new();
        reg_a.put("tplfp_src", vec![Value::I64(1), Value::I64(2)]);
        let reg_a2 = Registry::new();
        reg_a2.put("tplfp_src", vec![Value::I64(1), Value::I64(2)]);
        let reg_b = Registry::new();
        reg_b.put("tplfp_src", vec![Value::I64(1), Value::I64(3)]);
        let reg_missing = Registry::new();
        let sig_a = BindingSignature::resolve(&plan, &reg_a);
        assert!(
            sig_a.matches(&BindingSignature::resolve(&plan, &reg_a)),
            "same registry (pointer-equal datasets) matches"
        );
        assert!(
            sig_a.matches(&BindingSignature::resolve(&plan, &reg_a2)),
            "equal content in a different allocation matches"
        );
        assert!(
            !sig_a.matches(&BindingSignature::resolve(&plan, &reg_b)),
            "content change must not match"
        );
        assert!(
            !sig_a.matches(&BindingSignature::resolve(&plan, &reg_missing)),
            "unbound source must not match a bound one"
        );
    }

    #[test]
    fn observed_rows_map_back_through_fused_lineage() {
        // filter → map fuses into one node (named after the tail). After
        // a real run, the recorded feedback must contain the PRE-fusion
        // names too: the tail's observed output attributed to the map,
        // and — via the 1:1 backward walk — to the filter as well.
        let src = "a = bag(1, 2, 3, 4); f = a.filter(|x| x >= 0); m = f.map(|x| x * 2); k = m.map(|x| pair(x % 3, x)); o = k.reduceByKey(|p, q| p + q); collect(o, \"o\");";
        // Pre-fusion names of the chain members.
        let (raw, _) =
            crate::compile_with(&parse_and_lower(src).unwrap(), &OptConfig::none()).unwrap();
        let f_name = raw
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::Filter { .. }))
            .unwrap()
            .name
            .clone();
        let cache = TemplateCache::new(4);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let (tpl, _) = cache
            .get_or_compile(key_for(src, &opt), Some(src), &opt, 2, &reg, false, || {
                parse_and_lower(src)
            })
            .unwrap();
        let fused = tpl
            .plan
            .graph
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::Fused { .. }))
            .expect("filter/map chain fused");
        let out = crate::exec::driver::run_plan(
            tpl.plan.clone(),
            &crate::exec::ExecConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        assert!(!out.collected("o").is_empty());
        tpl.record_observed(&out);
        let fused_rows = tpl.observed_rows(&fused.name).expect("fused node observed");
        assert_eq!(
            tpl.observed_rows(&f_name),
            Some(fused_rows),
            "filter's pre-fusion name carries the fused observation (maps are 1:1)"
        );
    }

    #[test]
    fn interior_stage_observations_use_measured_rows() {
        // map(+1) → filter(even) → map(pair) fuses into one chain. The
        // HEAD map's cardinality (all 64 input rows) is invisible from
        // the fused tail's output (32 rows) — the old 1:1 backward walk
        // stopped at the filter. The per-stage runtime counters must pin
        // the MEASURED value for every interior stage.
        let lit = (0..64).map(|i| i.to_string()).collect::<Vec<_>>().join(", ");
        let owned = format!(
            "v = bag({lit}); a = v.map(|x| x + 1); f = a.filter(|x| x % 2 == 0); t = f.map(|x| pair(x % 4, x)); o = t.reduceByKey(|p, q| p + q); collect(o, \"out\");"
        );
        let src: &str = &owned;
        // Pre-fusion names of the chain members.
        let (raw, _) =
            crate::compile_with(&parse_and_lower(src).unwrap(), &OptConfig::none()).unwrap();
        let head_map = raw
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::Map { .. }) && !n.singleton)
            .unwrap()
            .name
            .clone();
        let filt = raw
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::Filter { .. }))
            .unwrap()
            .name
            .clone();
        let cache = TemplateCache::new(4);
        let reg = Registry::new();
        let opt = OptConfig::default();
        let (tpl, _) = cache
            .get_or_compile(key_for(src, &opt), Some(src), &opt, 2, &reg, false, || {
                parse_and_lower(src)
            })
            .unwrap();
        assert!(
            tpl.plan
                .graph
                .nodes
                .iter()
                .any(|n| matches!(n.op, crate::frontend::Rhs::Fused { .. })),
            "premise: the chain fused"
        );
        let out = crate::exec::driver::run_plan(
            tpl.plan.clone(),
            &crate::exec::ExecConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        tpl.record_observed(&out);
        // Mean rows per logical bag: totals × insts / bags; one logical
        // bag over 2 instances gives exactly the element totals.
        assert_eq!(
            tpl.observed_rows(&head_map),
            Some(64.0),
            "head map's measured interior cardinality"
        );
        assert_eq!(
            tpl.observed_rows(&filt),
            Some(32.0),
            "filter's measured output cardinality (even survivors)"
        );
    }

    #[test]
    fn drift_detection_thresholds() {
        let mut latest = RowFeedback::default();
        latest.insert("n".into(), 100.0);
        assert!(drifted(&latest, None), "no baseline → revise");
        let mut base = RowFeedback::default();
        base.insert("n".into(), 95.0);
        assert!(!drifted(&latest, Some(&base)), "5% drift is noise");
        base.insert("n".into(), 10.0);
        assert!(drifted(&latest, Some(&base)), "10 → 100 is real drift");
    }
}
