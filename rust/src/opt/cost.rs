//! `opt::cost` — a lightweight cost/cardinality model for the plan
//! optimizer. Two estimates, both shared through
//! [`super::analysis::PlanAnalysis`]:
//!
//! * **Per-node row estimates** ([`estimate_rows`]): propagated from
//!   source sizes (`Node::size_hint`, the workload registry) through
//!   textbook selectivity defaults (filters keep [`CostParams::filter_selectivity`]
//!   of their input, flatMaps expand by [`CostParams::flatmap_expansion`],
//!   keyed aggregations keep [`CostParams::key_ratio`] distinct keys, ...).
//!   Singleton (lifted-scalar) nodes are pinned to 1 row. Used by the
//!   join build-side chooser and the speculative-hoist gate, and rendered
//!   into DOT dumps.
//! * **Loop trip-count estimates** ([`estimate_trips`]): derived from the
//!   *condition structure* of each natural loop. Lifted scalar control
//!   chains (loop counters, their update maps, the condition's comparison)
//!   are closed singleton dataflows over constants, so the model simply
//!   **simulates** them — evaluating the same UDFs the runtime would — up
//!   to a cap. `while (d <= 3)` with `d = 1; d = d + 1` yields
//!   `Exact(3)`; `d = 9; while (d < 3)` yields `Exact(0)` (the zero-trip
//!   case that makes speculation a pure loss); conditions that depend on
//!   bag data (counts, reductions, file contents) yield `Unknown` and the
//!   consumer falls back to a configured default.
//!
//! The simulation executes (a bounded prefix of) the same scalar-UDF
//! sequence the runtime itself would execute — the header condition
//! always evaluates at least once at runtime — so it cannot observe
//! behavior the program would not exhibit, and the pass manager runs it
//! once per `optimize` call. The contract this leans on: scalar control
//! UDFs are **pure and total**, the same assumption the rest of the
//! optimizer makes (a side-effecting loop-counter closure from the
//! builder API would observe up to `sim_trip_cap` compile-time calls).
//! UDF panics during simulation are caught and degrade the estimate to
//! `Unknown`.

use crate::cfg::loops::{LoopInfo, NaturalLoop};
use crate::dataflow::{DataflowGraph, NodeId};
use crate::frontend::{BlockId, FusedStage, Rhs};
use crate::value::Value;
use rustc_hash::{FxHashMap, FxHashSet};

/// Tuning knobs of the cardinality model. Deliberately few and coarse —
/// the passes that consume the estimates only need relative order of
/// magnitude, not accuracy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Fraction of rows a `filter` keeps.
    pub filter_selectivity: f64,
    /// Output-per-input factor of a `flatMap`.
    pub flatmap_expansion: f64,
    /// Scale on `max(|L|, |R|)` for equi-join output size (≈ foreign-key
    /// join).
    pub join_selectivity: f64,
    /// Distinct-key fraction for `reduceByKey` / `distinct`.
    pub key_ratio: f64,
    /// Rows assumed for sources of unknown size (`readFile`, unregistered
    /// `source(..)` names).
    pub default_source_rows: f64,
    /// Iteration cap for the trip-count simulation; loops that run longer
    /// report [`TripCount::Unknown`]. Kept small because the pass manager
    /// recomputes the analysis before every pass run — the consumers only
    /// need `Exact(0)` vs an order of magnitude, and beyond the cap the
    /// `default_trips` fallback is just as good.
    pub sim_trip_cap: u64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            filter_selectivity: 0.25,
            flatmap_expansion: 4.0,
            join_selectivity: 1.0,
            key_ratio: 0.25,
            default_source_rows: 1024.0,
            sim_trip_cap: 4096,
        }
    }
}

/// A loop trip-count estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TripCount {
    /// The control chain is closed over constants and was simulated to
    /// completion: the loop runs exactly this many iterations per entry.
    Exact(u64),
    /// Data-dependent (or pathologically long) control — no estimate.
    Unknown,
}

impl TripCount {
    /// Collapse to a number, substituting `default` for [`TripCount::Unknown`].
    pub fn or_default(self, default: u64) -> u64 {
        match self {
            TripCount::Exact(n) => n,
            TripCount::Unknown => default,
        }
    }
}

/// The computed estimates, shared by all passes through `PlanAnalysis`.
#[derive(Clone, Debug)]
pub struct CostEstimates {
    /// Estimated output rows per node (indexed by [`NodeId`]).
    pub rows: Vec<f64>,
    /// Trip estimate per natural loop (parallel to `LoopInfo::loops`).
    pub trips: Vec<TripCount>,
}

/// Compute both estimates for a graph.
pub fn estimate(g: &DataflowGraph, loops: &LoopInfo, p: &CostParams) -> CostEstimates {
    CostEstimates {
        rows: estimate_rows(g, p),
        trips: loops.loops.iter().map(|l| estimate_trips(g, l, p.sim_trip_cap)).collect(),
    }
}

/// Estimated output rows per node: a bounded fixpoint from the sources
/// (Φ cycles are iterated a few sweeps and clamped, which is plenty for
/// an order-of-magnitude signal).
pub fn estimate_rows(g: &DataflowGraph, p: &CostParams) -> Vec<f64> {
    estimate_rows_inner(g, p, None)
}

/// [`estimate_rows`] with **observed-cardinality feedback**: nodes whose
/// SSA name appears in `seed` are pinned to the observed mean rows per
/// output bag (recorded by the engine in `RunOutput::node_rows`) instead
/// of the model's guess, and the fixpoint propagates the pinned values
/// through everything downstream. Singletons stay pinned to 1 row (their
/// observed mean is 1 by construction; a noisy measurement must not
/// perturb the lifted scalar chains). Used by `serve::` when it
/// re-optimizes a cached plan template from its own runtime stats.
pub fn estimate_rows_seeded(
    g: &DataflowGraph,
    p: &CostParams,
    seed: &FxHashMap<String, f64>,
) -> Vec<f64> {
    estimate_rows_inner(g, p, Some(seed))
}

/// Model estimates for every interior stage of a fused chain, given the
/// chain input's estimated rows: element `i` is the estimated output
/// cardinality of pre-fusion stage `i` (stage-parallel with the chain's
/// `lineage`). Used to seed the adaptive-feedback drift baseline with
/// per-stage values symmetric to the engine's observed
/// `NodeRows::stage_rows`, so interior filter/flatMap drift is detected
/// per stage rather than only at the fused tail.
pub fn fused_stage_rows(stages: &[FusedStage], input_rows: f64, p: &CostParams) -> Vec<f64> {
    let mut acc = input_rows;
    stages
        .iter()
        .map(|s| {
            acc = match s {
                FusedStage::Map(_) => acc,
                FusedStage::Filter(_) => acc * p.filter_selectivity,
                FusedStage::FlatMap(_) => acc * p.flatmap_expansion,
            };
            acc
        })
        .collect()
}

fn estimate_rows_inner(
    g: &DataflowGraph,
    p: &CostParams,
    seed: Option<&FxHashMap<String, f64>>,
) -> Vec<f64> {
    const SWEEPS: usize = 8;
    const CLAMP: f64 = 1e12;
    let mut rows = vec![0.0f64; g.nodes.len()];
    for _ in 0..SWEEPS {
        let mut changed = false;
        for n in &g.nodes {
            let r = |i: usize| rows[n.inputs[i].src];
            let est = if n.singleton {
                1.0
            } else if let Some(&observed) = seed.and_then(|s| s.get(&n.name)) {
                observed
            } else {
                match &n.op {
                    Rhs::BagLit(items) => items.len() as f64,
                    Rhs::NamedSource(_) | Rhs::ReadFile { .. } => n
                        .size_hint
                        .map(|s| s as f64)
                        .unwrap_or(p.default_source_rows),
                    Rhs::Map { .. } | Rhs::XlaCall { .. } | Rhs::Collect { .. } => {
                        if n.inputs.is_empty() {
                            1.0
                        } else {
                            (0..n.inputs.len()).map(r).fold(0.0, f64::max)
                        }
                    }
                    Rhs::Filter { .. } => r(0) * p.filter_selectivity,
                    Rhs::FlatMap { .. } => r(0) * p.flatmap_expansion,
                    Rhs::Fused { stages, .. } => {
                        fused_stage_rows(stages, r(0), p).last().copied().unwrap_or_else(|| r(0))
                    }
                    Rhs::Join { .. } => p.join_selectivity * r(0).max(r(1)),
                    Rhs::ReduceByKey { .. } | Rhs::Distinct { .. } => r(0) * p.key_ratio,
                    Rhs::Union { .. } => r(0) + r(1),
                    Rhs::Cross { .. } => r(0) * r(1),
                    Rhs::Phi(_) => (0..n.inputs.len()).map(r).fold(0.0, f64::max),
                    Rhs::Reduce { .. } | Rhs::Count { .. } | Rhs::WriteFile { .. } => 1.0,
                    Rhs::Const(_)
                    | Rhs::Copy(_)
                    | Rhs::ScalarUn { .. }
                    | Rhs::ScalarBin { .. } => 1.0,
                }
            };
            let est = est.min(CLAMP);
            if (est - rows[n.id]).abs() > 1e-9 {
                rows[n.id] = est;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    rows
}

/// Estimate how many iterations loop `l` runs per entry by simulating its
/// lifted scalar control chain (see the module docs). UDF panics during
/// simulation are caught and reported as [`TripCount::Unknown`].
pub fn estimate_trips(g: &DataflowGraph, l: &NaturalLoop, cap: u64) -> TripCount {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| simulate_trips(g, l, cap)))
        .unwrap_or(TripCount::Unknown)
}

/// The scalar-chain evaluator backing [`estimate_trips`]: evaluates the
/// closed singleton subgraph (literal constants, lifted scalar maps,
/// crosses, tracked header Φs) and bails with `None` on anything else.
struct ScalarSim<'a> {
    g: &'a DataflowGraph,
    /// Current value of each tracked loop-header Φ.
    phi_env: FxHashMap<NodeId, Value>,
    /// Per-iteration memo (cleared when the Φs advance).
    memo: FxHashMap<NodeId, Value>,
    /// Cycle guard.
    visiting: FxHashSet<NodeId>,
}

impl ScalarSim<'_> {
    fn eval(&mut self, id: NodeId) -> Option<Value> {
        if let Some(v) = self.phi_env.get(&id) {
            return Some(v.clone());
        }
        if let Some(v) = self.memo.get(&id) {
            return Some(v.clone());
        }
        if !self.visiting.insert(id) {
            return None; // cycle through an untracked Φ
        }
        let g = self.g;
        let n = &g.nodes[id];
        let v = match &n.op {
            Rhs::BagLit(items) if items.len() == 1 => Some(items[0].clone()),
            Rhs::Map { udf, .. } => self.eval(n.inputs[0].src).map(|x| udf.call(&x)),
            Rhs::Cross { .. } => {
                let a = self.eval(n.inputs[0].src);
                let b = self.eval(n.inputs[1].src);
                match (a, b) {
                    (Some(a), Some(b)) => Some(Value::pair(a, b)),
                    _ => None,
                }
            }
            Rhs::Fused { stages, .. } => {
                let mut cur = self.eval(n.inputs[0].src);
                for s in stages {
                    cur = match (cur, s) {
                        (Some(x), FusedStage::Map(u)) => Some(u.call(&x)),
                        _ => None,
                    };
                }
                cur
            }
            _ => None, // bag-derived / data-dependent: not simulatable
        };
        self.visiting.remove(&id);
        if let Some(v) = &v {
            self.memo.insert(id, v.clone());
        }
        v
    }
}

fn simulate_trips(g: &DataflowGraph, l: &NaturalLoop, cap: u64) -> TripCount {
    let in_body = |b: BlockId| l.body.binary_search(&b).is_ok();

    // The header's condition node decides whether an iteration runs.
    let Some(cond) = g
        .nodes
        .iter()
        .find(|n| n.block == l.header && n.cond.is_some())
    else {
        return TripCount::Unknown;
    };
    let spec = cond.cond.as_ref().expect("checked above");
    let then_enters = spec.then_chain.first().map(|&b| in_body(b)).unwrap_or(false);
    let else_enters = spec.else_chain.first().map(|&b| in_body(b)).unwrap_or(false);
    let continue_on = match (then_enters, else_enters) {
        (true, false) => true,
        (false, true) => false,
        _ => return TripCount::Unknown, // irregular shape
    };

    // Header Φs with a unique entry argument and a unique back-edge
    // argument are trackable loop state; anything the condition slice
    // needs beyond these makes the simulation bail.
    let mut sim = ScalarSim {
        g,
        phi_env: FxHashMap::default(),
        memo: FxHashMap::default(),
        visiting: FxHashSet::default(),
    };
    let mut latches: Vec<(NodeId, NodeId)> = Vec::new(); // (phi, back-edge src)
    for n in &g.nodes {
        if n.block != l.header || !matches!(n.op, Rhs::Phi(_)) {
            continue;
        }
        let entry: Vec<NodeId> = n
            .inputs
            .iter()
            .filter(|i| !in_body(i.src_block))
            .map(|i| i.src)
            .collect();
        let latch: Vec<NodeId> = n
            .inputs
            .iter()
            .filter(|i| in_body(i.src_block))
            .map(|i| i.src)
            .collect();
        let ([e], [b]) = (entry.as_slice(), latch.as_slice()) else {
            continue; // untracked: the slice bails if it needs this Φ
        };
        if let Some(v) = sim.eval(*e) {
            sim.phi_env.insert(n.id, v);
            latches.push((n.id, *b));
        }
    }

    let cond_id = cond.id;
    let mut trips = 0u64;
    loop {
        sim.memo.clear();
        let Some(Value::Bool(cv)) = sim.eval(cond_id) else {
            return TripCount::Unknown;
        };
        if cv != continue_on {
            return TripCount::Exact(trips);
        }
        trips += 1;
        if trips >= cap {
            return TripCount::Unknown;
        }
        // Advance all tracked Φs simultaneously.
        let mut next = Vec::with_capacity(latches.len());
        for &(phi, src) in &latches {
            match sim.eval(src) {
                Some(v) => next.push((phi, v)),
                None => return TripCount::Unknown,
            }
        }
        for (phi, v) in next {
            sim.phi_env.insert(phi, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::{dom, loops};
    use crate::frontend::parse_and_lower;
    use crate::opt::OptConfig;

    fn raw(src: &str) -> DataflowGraph {
        crate::compile_with(&parse_and_lower(src).unwrap(), &OptConfig::none())
            .unwrap()
            .0
    }

    fn trips_of(src: &str) -> Vec<TripCount> {
        let g = raw(src);
        let dt = dom::dominators(&g.cfg);
        let li = loops::find_loops(&g.cfg, &dt);
        li.loops
            .iter()
            .map(|l| estimate_trips(&g, l, CostParams::default().sim_trip_cap))
            .collect()
    }

    #[test]
    fn counter_loop_trips_are_exact() {
        let t = trips_of("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");");
        assert_eq!(t, vec![TripCount::Exact(3)]);
    }

    #[test]
    fn zero_trip_loop_is_detected() {
        let t = trips_of("d = 9; while (d < 3) { d = d + 1; } collect(bag(1), \"x\");");
        assert_eq!(t, vec![TripCount::Exact(0)]);
    }

    #[test]
    fn data_dependent_condition_is_unknown() {
        // The bound comes from a bag reduction — not simulatable.
        let t = trips_of(
            "n = bag(5, 6).reduce(|a, b| a + b); d = 1; while (d <= n) { d = d + 1; } collect(bag(1), \"x\");",
        );
        assert_eq!(t, vec![TripCount::Unknown]);
    }

    #[test]
    fn nested_counter_loops_both_exact() {
        let t = trips_of(
            "i = 0; while (i < 2) { j = 0; while (j < 5) { j = j + 1; } i = i + 1; } collect(bag(1), \"x\");",
        );
        let mut t = t;
        t.sort_by_key(|c| match c {
            TripCount::Exact(n) => *n,
            TripCount::Unknown => u64::MAX,
        });
        assert_eq!(t, vec![TripCount::Exact(2), TripCount::Exact(5)]);
    }

    #[test]
    fn rows_follow_source_sizes_and_selectivities() {
        let g = raw(
            "a = bag(1, 2, 3, 4); b = a.filter(|x| x > 1); c = a.union(a); collect(b, \"b\"); collect(c, \"c\");",
        );
        let p = CostParams::default();
        let rows = estimate_rows(&g, &p);
        let lit = g.nodes.iter().find(|n| matches!(n.op, Rhs::BagLit(ref v) if v.len() == 4)).unwrap();
        assert!((rows[lit.id] - 4.0).abs() < 1e-9);
        let f = g.nodes.iter().find(|n| matches!(n.op, Rhs::Filter { .. })).unwrap();
        assert!((rows[f.id] - 4.0 * p.filter_selectivity).abs() < 1e-9);
        let u = g.nodes.iter().find(|n| matches!(n.op, Rhs::Union { .. })).unwrap();
        assert!((rows[u.id] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn registered_source_rows_use_registry_size() {
        let reg = crate::workload::registry::global();
        reg.put("cost_test_src", (0..37).map(Value::I64).collect());
        let g = raw("s = source(\"cost_test_src\"); collect(s, \"s\");");
        let rows = estimate_rows(&g, &CostParams::default());
        let s = g.nodes.iter().find(|n| matches!(n.op, Rhs::NamedSource(_))).unwrap();
        assert_eq!(s.size_hint, Some(37));
        assert!((rows[s.id] - 37.0).abs() < 1e-9);
        reg.clear_prefix("cost_test_src");
    }

    #[test]
    fn seeded_rows_override_and_propagate() {
        let g = raw(
            "a = bag(1, 2, 3, 4); b = a.filter(|x| x > 1); c = b.distinct(); collect(c, \"c\");",
        );
        let p = CostParams::default();
        let f = g.nodes.iter().find(|n| matches!(n.op, Rhs::Filter { .. })).unwrap();
        let d = g.nodes.iter().find(|n| matches!(n.op, Rhs::Distinct { .. })).unwrap();
        let mut seed = FxHashMap::default();
        // Runtime observed the filter keeping far more than the default
        // 25% selectivity guess.
        seed.insert(f.name.clone(), 1000.0);
        let rows = estimate_rows_seeded(&g, &p, &seed);
        assert!((rows[f.id] - 1000.0).abs() < 1e-9);
        // The pinned value propagates downstream.
        assert!((rows[d.id] - 1000.0 * p.key_ratio).abs() < 1e-9);
        // Unseeded nodes keep the model estimate.
        let lit = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::BagLit(ref v) if v.len() == 4))
            .unwrap();
        assert!((rows[lit.id] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn singletons_are_one_row() {
        let g = raw("n = bag(1, 2, 3).count(); collect(bag(0).map(|x| x + 1), \"x\");");
        let rows = estimate_rows(&g, &CostParams::default());
        for n in &g.nodes {
            if n.singleton {
                assert!((rows[n.id] - 1.0).abs() < 1e-9, "{}", n.name);
            }
        }
    }
}
