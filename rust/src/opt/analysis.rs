//! Shared plan analysis for the optimizer passes: loop structure (from
//! `cfg::loops`), per-node consumer lists, per-node loop-invariance (a
//! fixpoint over input edges), output liveness (reachability to a
//! sink / condition node / Φ), and the [`super::cost`] estimates
//! (per-node rows, per-loop trip counts).
//!
//! Recomputed by the pass manager before every pass run — passes mutate
//! the graph (moving, merging, and removing nodes), so ids and blocks are
//! only valid for the graph snapshot the analysis was computed from.

use super::cost::{self, CostEstimates, CostParams, TripCount};
use crate::cfg::dom::{self, DomTree};
use crate::cfg::loops::{self, LoopInfo, NaturalLoop};
use crate::dataflow::{DataflowGraph, Node, NodeId};
use crate::frontend::{BlockId, Rhs};

/// Analysis results shared by all optimizer passes.
pub struct PlanAnalysis {
    /// Dominator tree of the CFG.
    pub dom: DomTree,
    /// Natural loops and per-block nesting depth.
    pub loops: LoopInfo,
    /// `consumers[n]` = downstream `(consumer, input index)` pairs
    /// (the inverse of `Node::inputs`, precomputed once).
    pub consumers: Vec<Vec<(NodeId, usize)>>,
    /// `live[n]`: the node's output reaches a sink (`collect`/`writeFile`),
    /// a condition node, or a Φ. Dead nodes compute bags nobody reads.
    pub live: Vec<bool>,
    /// Cardinality / trip-count estimates (`opt::cost`).
    pub cost: CostEstimates,
    /// Per-node inferred output element type ([`super::types::infer`]).
    /// `Dyn` where inference gave up; advisory for rewrites the same way
    /// it is for the engine — runtime layout checks keep it safe.
    pub elem_types: Vec<crate::value::ElemType>,
}

/// Is this node a liveness root? Sinks and side effects, condition nodes
/// (they drive control flow), and Φs (they carry loop state).
pub fn is_root(n: &Node) -> bool {
    n.cond.is_some()
        || matches!(n.op, Rhs::Collect { .. } | Rhs::WriteFile { .. } | Rhs::Phi(_))
}

/// Can this operation be moved out of a loop when its inputs are
/// invariant? Pure bag transformations only: sinks (`collect`,
/// `writeFile`) execute per iteration by definition, Φ/condition nodes
/// anchor the coordination protocol, `reduce` errors on empty input and
/// `readFile` touches the filesystem — hoisting would *speculate* those
/// even when the loop runs zero iterations.
///
/// **Cost-gated speculation:** `NamedSource` and `XlaCall` are listed as
/// hoistable here, but a hoisted instance executes once per loop *entry*
/// — including entries where the loop then runs zero iterations — so
/// hoisting them is *speculation* ([`is_speculative_op`]). The hoist pass
/// therefore gates them through the `opt::cost` model
/// ([`PlanAnalysis::invariant_hoistable_gated`]): they move only when the
/// loop's estimated trip count × the chain's estimated rows clears the
/// configured threshold (`opt.speculate_threshold`), with a `speculate`
/// knob (`opt.speculate = auto|always|never`) to force either extreme.
/// Under the default `auto`, a provably zero-trip loop never speculates —
/// in particular, a zero-trip loop over an *unregistered* source name
/// runs clean instead of panicking at loop entry — while the Fig. 8
/// workload (many trips over a large invariant source) still hoists.
/// `always` restores the old always-on contract (the paper's Flink
/// setting, where a job's sources materialize at launch regardless of the
/// control flow taken). UDFs are assumed total.
pub fn is_hoistable_op(op: &Rhs) -> bool {
    matches!(
        op,
        Rhs::BagLit(_)
            | Rhs::NamedSource(_)
            | Rhs::Map { .. }
            | Rhs::Filter { .. }
            | Rhs::FlatMap { .. }
            | Rhs::Fused { .. }
            | Rhs::Join { .. }
            | Rhs::ReduceByKey { .. }
            | Rhs::Count { .. }
            | Rhs::Distinct { .. }
            | Rhs::Union { .. }
            | Rhs::Cross { .. }
            | Rhs::XlaCall { .. }
    )
}

/// Ops whose hoisting *speculates* observable work (or a panic): their
/// chains are what [`PlanAnalysis::invariant_hoistable_gated`] cost-gates.
/// Everything else hoistable is a pure in-memory transformation whose
/// per-entry cost is negligible and which cannot fail on its own.
pub fn is_speculative_op(op: &Rhs) -> bool {
    matches!(op, Rhs::NamedSource(_) | Rhs::XlaCall { .. })
}

/// Preamble nodes whose output bags are **fully determined by the
/// template plus its named-source bindings** — the set whose materialized
/// results the `serve::` job service may share across jobs with a
/// matching binding signature. The set is seeded by nodes that were
/// hoisted into a loop preamble (`hoisted_from.is_some()`) sitting
/// outside every loop (`loop_depth == 0`, so they compute exactly ONE
/// bag per run), then grown **backward**: a deterministic, depth-0,
/// non-condition node whose every consumer is already in the set joins
/// it too — its bag is read only by nodes that replay their own cached
/// results, so recomputing it (an entry-block source feeding only a
/// hoisted join, say) would produce data nobody reads.
///
/// Every member's transitive input closure contains only deterministic
/// in-memory ops — no `readFile`/`writeFile` (filesystem state), no
/// `xla` calls (external artifacts), and no Φ nodes. Excluding Φs keeps
/// the bag's value independent of the execution *path*: a Φ-fed value
/// selects a bag by path position, which could vary across epochs
/// through control flow the binding signature does not cover. UDFs are
/// assumed pure, as everywhere in the optimizer.
///
/// `loop_depth` is per-block nesting depth (`cfg::loops::LoopInfo::depth`
/// for the graph's CFG).
pub fn binding_determined_preamble(g: &DataflowGraph, loop_depth: &[usize]) -> Vec<bool> {
    let allowed = |n: &Node| {
        !matches!(
            n.op,
            Rhs::ReadFile { .. } | Rhs::WriteFile { .. } | Rhs::XlaCall { .. } | Rhs::Phi(_)
        )
    };
    // Deterministic closure: start from per-op admissibility and knock
    // nodes out until a fixpoint (a node with any non-deterministic
    // transitive input is itself non-deterministic). Cycles only exist
    // through Φs, which start excluded, so the fixpoint is conservative.
    let mut det: Vec<bool> = g.nodes.iter().map(allowed).collect();
    loop {
        let mut changed = false;
        for n in &g.nodes {
            if det[n.id] && n.inputs.iter().any(|i| !det[i.src]) {
                det[n.id] = false;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); g.nodes.len()];
    for n in &g.nodes {
        for inp in &n.inputs {
            consumers[inp.src].push(n.id);
        }
    }
    // Seed with the hoisted preamble nodes, then grow backward to the
    // deterministic nodes they fully consume. Condition nodes never
    // join: their decision must be recomputed and reported per epoch.
    let mut shareable: Vec<bool> = g
        .nodes
        .iter()
        .map(|n| det[n.id] && n.hoisted_from.is_some() && loop_depth[n.block] == 0)
        .collect();
    loop {
        let mut changed = false;
        for n in &g.nodes {
            if shareable[n.id]
                || !det[n.id]
                || loop_depth[n.block] != 0
                || n.cond.is_some()
                || consumers[n.id].is_empty()
            {
                continue;
            }
            if consumers[n.id].iter().all(|&c| shareable[c]) {
                shareable[n.id] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    shareable
}

/// The named-source names read by the transitive input closure of the
/// `shareable` nodes (see [`binding_determined_preamble`]) — exactly the
/// bindings a cached preamble result depends on. Sorted and deduplicated
/// so fingerprints are order-stable.
pub fn preamble_source_names(g: &DataflowGraph, shareable: &[bool]) -> Vec<String> {
    let mut seen = vec![false; g.nodes.len()];
    let mut work: Vec<NodeId> =
        (0..g.nodes.len()).filter(|&i| shareable.get(i).copied().unwrap_or(false)).collect();
    for &i in &work {
        seen[i] = true;
    }
    let mut names: Vec<String> = Vec::new();
    while let Some(v) = work.pop() {
        if let Rhs::NamedSource(name) = &g.nodes[v].op {
            names.push(name.clone());
        }
        for inp in &g.nodes[v].inputs {
            if !seen[inp.src] {
                seen[inp.src] = true;
                work.push(inp.src);
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

impl PlanAnalysis {
    /// Compute the analysis for the current graph (default
    /// [`CostParams`]).
    pub fn compute(g: &DataflowGraph) -> PlanAnalysis {
        PlanAnalysis::compute_with(g, &CostParams::default())
    }

    /// Compute the analysis with explicit cost-model parameters.
    pub fn compute_with(g: &DataflowGraph, params: &CostParams) -> PlanAnalysis {
        PlanAnalysis::compute_inner(g, params, None, None)
    }

    /// Like [`compute_with`](Self::compute_with), but reuse previously
    /// simulated trip counts instead of re-running the scalar-chain
    /// simulation. Trip estimates are CFG-level and the optimizer passes
    /// never change the CFG (or program semantics), so the pass manager
    /// simulates once per `optimize` run and hands the result to every
    /// per-pass analysis; row estimates are still recomputed (rewrites
    /// legitimately change them).
    pub fn compute_with_trips(
        g: &DataflowGraph,
        params: &CostParams,
        trips: Vec<TripCount>,
    ) -> PlanAnalysis {
        PlanAnalysis::compute_inner(g, params, Some(trips), None)
    }

    /// [`compute_with_trips`](Self::compute_with_trips) with an
    /// observed-cardinality seed: nodes named in `seed` have their row
    /// estimates pinned ([`cost::estimate_rows_seeded`]) in the single
    /// fixpoint this analysis runs. Used by the pass manager under
    /// `opt::optimize_with_feedback`.
    pub fn compute_with_trips_seeded(
        g: &DataflowGraph,
        params: &CostParams,
        trips: Vec<TripCount>,
        seed: Option<&rustc_hash::FxHashMap<String, f64>>,
    ) -> PlanAnalysis {
        PlanAnalysis::compute_inner(g, params, Some(trips), seed)
    }

    fn compute_inner(
        g: &DataflowGraph,
        params: &CostParams,
        trips: Option<Vec<TripCount>>,
        seed: Option<&rustc_hash::FxHashMap<String, f64>>,
    ) -> PlanAnalysis {
        let dt = dom::dominators(&g.cfg);
        let li = loops::find_loops(&g.cfg, &dt);

        let mut consumers: Vec<Vec<(NodeId, usize)>> = vec![Vec::new(); g.nodes.len()];
        for n in &g.nodes {
            for (i, inp) in n.inputs.iter().enumerate() {
                consumers[inp.src].push((n.id, i));
            }
        }

        // Liveness: backward closure from the roots through input edges.
        let mut live = vec![false; g.nodes.len()];
        let mut work: Vec<NodeId> = Vec::new();
        for n in &g.nodes {
            if is_root(n) {
                live[n.id] = true;
                work.push(n.id);
            }
        }
        while let Some(v) = work.pop() {
            for inp in &g.nodes[v].inputs {
                if !live[inp.src] {
                    live[inp.src] = true;
                    work.push(inp.src);
                }
            }
        }

        let rows = match seed {
            Some(s) => cost::estimate_rows_seeded(g, params, s),
            None => cost::estimate_rows(g, params),
        };
        let est = match trips {
            Some(trips) => CostEstimates { rows, trips },
            None => CostEstimates {
                rows,
                trips: li
                    .loops
                    .iter()
                    .map(|l| cost::estimate_trips(g, l, params.sim_trip_cap))
                    .collect(),
            },
        };
        PlanAnalysis {
            dom: dt,
            loops: li,
            consumers,
            live,
            cost: est,
            elem_types: super::types::infer(g),
        }
    }

    /// The loop's *preamble anchor*: the unique predecessor of the header
    /// outside the loop body. Hoisted nodes are moved into this block, so
    /// they compute exactly once per loop *entry* (once per enclosing-loop
    /// iteration when loops nest). `None` when the entry edge is not
    /// unique — such loops are skipped.
    pub fn preheader(&self, g: &DataflowGraph, l: &NaturalLoop) -> Option<BlockId> {
        let outside: Vec<BlockId> = g.cfg.preds[l.header]
            .iter()
            .copied()
            .filter(|&p| l.body.binary_search(&p).is_err())
            .collect();
        match outside.as_slice() {
            [p] => Some(*p),
            _ => None,
        }
    }

    /// Nodes of loop `l` that are invariant *and* safely hoistable:
    /// a fixpoint over input edges starting from nodes all of whose inputs
    /// are defined outside the loop body. Excludes Φ/condition/sink nodes
    /// (see [`is_hoistable_op`]), nodes that feed a Φ directly (the
    /// coordination protocol requires Φ inputs to keep their defining
    /// blocks — SSA guarantees them pairwise distinct), and nodes in
    /// blocks that do NOT dominate the latch: an if-guarded block inside
    /// the loop may never execute, and hoisting would speculate its
    /// operators (a guarded `source(..)` of an unregistered name must
    /// keep panicking only when the guard is taken).
    pub fn invariant_hoistable(&self, g: &DataflowGraph, l: &NaturalLoop) -> Vec<NodeId> {
        self.invariant_hoistable_allowing(g, l, |_| true)
    }

    /// [`invariant_hoistable`](Self::invariant_hoistable) restricted to
    /// nodes passing `allow` (speculation gating): a node failing `allow`
    /// stays in the loop, and so does everything that depends on it.
    fn invariant_hoistable_allowing(
        &self,
        g: &DataflowGraph,
        l: &NaturalLoop,
        allow: impl Fn(&Node) -> bool,
    ) -> Vec<NodeId> {
        let in_body = |b: BlockId| l.body.binary_search(&b).is_ok();
        let candidate = |n: &Node| -> bool {
            in_body(n.block)
                && self.dom.dominates(n.block, l.latch)
                && n.cond.is_none()
                && is_hoistable_op(&n.op)
                && allow(n)
                && self.consumers[n.id]
                    .iter()
                    .all(|&(c, _)| !matches!(g.nodes[c].op, Rhs::Phi(_)))
        };
        let mut invariant = vec![false; g.nodes.len()];
        loop {
            let mut changed = false;
            for n in &g.nodes {
                if invariant[n.id] || !candidate(n) {
                    continue;
                }
                let ok = n
                    .inputs
                    .iter()
                    .all(|i| !in_body(g.nodes[i.src].block) || invariant[i.src]);
                if ok {
                    invariant[n.id] = true;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        (0..g.nodes.len()).filter(|&i| invariant[i]).collect()
    }

    /// The cost-gated hoist set for loop index `li` (see
    /// [`is_hoistable_op`] for the speculation contract). Returns the
    /// hoistable node ids and how many nodes the gate kept in the loop
    /// (the difference against the ungated set — gated speculative
    /// sources plus their dependent chains).
    ///
    /// `speculate` selects the policy; under [`super::Speculate::Auto`] a
    /// speculative node `s` hoists only when
    /// `trips × rows(s) ≥ threshold`, where `trips` is the loop's
    /// [`TripCount`] estimate (`default_trips` when unknown) and
    /// `rows(s)` the cost model's output-row estimate — a proxy for the
    /// per-iteration work the hoist saves. Additionally, a source that
    /// would *panic* if executed (a `NamedSource` with no compile-time
    /// size hint, i.e. unregistered) never hoists out of a loop whose
    /// trip count is not certainly positive — so a loop that happens to
    /// run zero times at runtime cannot panic on speculated work under
    /// the default configuration, whether its bound is static or
    /// data-dependent.
    pub fn invariant_hoistable_gated(
        &self,
        g: &DataflowGraph,
        li: usize,
        speculate: super::Speculate,
        threshold: f64,
        default_trips: u64,
    ) -> (Vec<NodeId>, usize) {
        let l = &self.loops.loops[li];
        let full = self.invariant_hoistable_allowing(g, l, |_| true);
        let gated = match speculate {
            super::Speculate::Always => return (full, 0),
            super::Speculate::Never => {
                self.invariant_hoistable_allowing(g, l, |n| !is_speculative_op(&n.op))
            }
            super::Speculate::Auto => {
                let est = self.cost.trips.get(li).copied().unwrap_or(TripCount::Unknown);
                let trips = est.or_default(default_trips) as f64;
                // With an Exact(n ≥ 1) estimate the loop certainly runs,
                // so the body would execute the chain anyway and hoisting
                // cannot introduce a failure the program didn't have. An
                // Unknown bound might be zero at runtime, so a source that
                // would PANIC if executed (unregistered — no size hint at
                // compile time) must stay lazy; registered sources merely
                // risk wasted work and go through the threshold test.
                let certain = matches!(est, TripCount::Exact(n) if n > 0);
                self.invariant_hoistable_allowing(g, l, |n| {
                    if !is_speculative_op(&n.op) {
                        return true;
                    }
                    if !certain && matches!(n.op, Rhs::NamedSource(_)) && n.size_hint.is_none()
                    {
                        return false;
                    }
                    trips * self.cost.rows[n.id] >= threshold
                })
            }
        };
        let skipped = full.len() - gated.len();
        (gated, skipped)
    }

    /// [`binding_determined_preamble`] over this analysis's loop nesting:
    /// the nodes whose materialized preamble bags the `serve::` service
    /// may share across jobs with matching binding signatures.
    pub fn shareable_preamble(&self, g: &DataflowGraph) -> Vec<bool> {
        binding_determined_preamble(g, &self.loops.depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::opt::OptConfig;

    fn raw_graph(src: &str) -> DataflowGraph {
        crate::compile_with(&parse_and_lower(src).unwrap(), &OptConfig::none())
            .unwrap()
            .0
    }

    #[test]
    fn consumers_match_graph_inverse() {
        let g = raw_graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");");
        let a = PlanAnalysis::compute(&g);
        for n in &g.nodes {
            assert_eq!(a.consumers[n.id], g.consumers(n.id), "{}", n.name);
        }
    }

    #[test]
    fn everything_reaching_collect_is_live() {
        let g = raw_graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");");
        let a = PlanAnalysis::compute(&g);
        assert!(a.live.iter().all(|&l| l), "straightline collect chain is fully live");
    }

    #[test]
    fn loop_invariant_map_found_with_preheader() {
        // `attrs`-in-loop pattern: the source and its keying map depend on
        // nothing loop-varying — both are invariant; the join is not (its
        // probe side varies with d).
        let g = raw_graph(
            r#"
            d = 1;
            while (d <= 3) {
                attrs = source("x").map(|v| pair(v, v));
                probe = bag(1, 2).map(|v| pair(v + d, d));
                j = probe.join(attrs);
                collect(j, "j");
                d = d + 1;
            }
            "#,
        );
        let a = PlanAnalysis::compute(&g);
        assert_eq!(a.loops.loops.len(), 1);
        let l = &a.loops.loops[0];
        assert!(a.preheader(&g, l).is_some());
        let inv = a.invariant_hoistable(&g, l);
        let names: Vec<&str> = inv.iter().map(|&i| g.nodes[i].name.as_str()).collect();
        assert!(
            inv.iter().any(|&i| matches!(g.nodes[i].op, Rhs::NamedSource(_))),
            "source is invariant: {names:?}"
        );
        // The keying map over the source is invariant too.
        assert!(
            inv.iter().any(|&i| matches!(g.nodes[i].op, Rhs::Map { .. })
                && g.nodes[i].inputs.iter().all(|e| inv.contains(&e.src))),
            "map over source is invariant: {names:?}"
        );
        // The join depends on the loop-varying probe side.
        for &i in &inv {
            assert!(!matches!(g.nodes[i].op, Rhs::Join { .. }), "join must not be invariant");
        }
    }

    #[test]
    fn binding_determined_preamble_finds_hoisted_source_chain() {
        // Full default compile: the invariant source+map chain hoists to
        // the depth-0 preamble and its closure is deterministic — it is
        // shareable. Varying nodes and the collect are not.
        crate::workload::registry::global()
            .put("analysis_pre_src", vec![crate::value::Value::I64(1), crate::value::Value::I64(2)]);
        let g = crate::compile_source(
            r#"
            d = 1;
            while (d <= 3) {
                attrs = source("analysis_pre_src").map(|v| pair(v, v));
                probe = bag(1, 2).map(|v| pair(v + d, d));
                j = probe.join(attrs);
                collect(j, "j");
                d = d + 1;
            }
            "#,
        )
        .unwrap();
        crate::workload::registry::global().clear_prefix("analysis_pre_src");
        let a = PlanAnalysis::compute(&g);
        let shareable = a.shareable_preamble(&g);
        let src = g.nodes.iter().find(|n| matches!(n.op, Rhs::NamedSource(_))).unwrap();
        assert!(shareable[src.id], "hoisted registered source is shareable");
        for n in &g.nodes {
            if shareable[n.id] {
                // Hoisted, or fully consumed by shareable nodes.
                assert!(
                    n.hoisted_from.is_some()
                        || a.consumers[n.id].iter().all(|&(c, _)| shareable[c]),
                    "{} shareable but neither hoisted nor fully consumed by the set",
                    n.name
                );
                assert_eq!(a.loops.depth[n.block], 0, "{} shareable inside a loop", n.name);
            }
            if matches!(n.op, Rhs::Phi(_) | Rhs::Collect { .. }) || n.cond.is_some() {
                assert!(!shareable[n.id], "{} must not be shareable", n.name);
            }
        }
        let names = preamble_source_names(&g, &shareable);
        assert_eq!(names, vec!["analysis_pre_src".to_string()]);
    }

    #[test]
    fn entry_source_consumed_only_by_hoisted_nodes_is_shareable() {
        // `base` is defined OUTSIDE the loop (never hoisted), but its
        // only consumer is the hoisted map — recomputing it per epoch
        // would produce data nobody reads, so the backward extension
        // must pull it into the shareable set.
        crate::workload::registry::global().put(
            "analysis_entry_src",
            vec![crate::value::Value::I64(4), crate::value::Value::I64(5)],
        );
        let g = crate::compile_source(
            "base = source(\"analysis_entry_src\"); d = 1; while (d <= 3) { v = base.map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        )
        .unwrap();
        crate::workload::registry::global().clear_prefix("analysis_entry_src");
        let a = PlanAnalysis::compute(&g);
        let shareable = a.shareable_preamble(&g);
        let base = g.nodes.iter().find(|n| matches!(n.op, Rhs::NamedSource(_))).unwrap();
        assert!(base.hoisted_from.is_none(), "premise: the source was never hoisted");
        let map = g
            .nodes
            .iter()
            .find(|n| n.hoisted_from.is_some() && !n.singleton)
            .expect("premise: the invariant map hoisted");
        assert!(shareable[map.id]);
        assert!(shareable[base.id], "fully-consumed entry source joins the shareable set");
        assert_eq!(preamble_source_names(&g, &shareable), vec!["analysis_entry_src".to_string()]);
    }

    #[test]
    fn read_file_closure_is_never_shareable() {
        // readFile pulls filesystem state a binding signature cannot
        // cover: nothing downstream of it may be shared, hoisted or not.
        let g = crate::compile_source(
            "f = \"nope.txt\"; d = 1; while (d <= 2) { v = readFile(f).map(|x| x); collect(v, \"v\"); d = d + 1; }",
        )
        .unwrap();
        let a = PlanAnalysis::compute(&g);
        let shareable = a.shareable_preamble(&g);
        for n in &g.nodes {
            if matches!(n.op, Rhs::ReadFile { .. }) || n.inputs.iter().any(|i| matches!(g.nodes[i.src].op, Rhs::ReadFile { .. })) {
                assert!(!shareable[n.id], "{} reads the filesystem", n.name);
            }
        }
    }

    #[test]
    fn phi_dependent_hoisted_chain_is_not_shareable() {
        // The second loop's invariant chain captures `d` — the exit value
        // of the FIRST loop's header Φ. It hoists fine, but its value is
        // selected by execution-path position, so it must not be marked
        // binding-determined (shareable across epochs).
        let g = crate::compile_source(
            r#"
            d = 1;
            while (d <= 2) { d = d + 1; }
            e = 1;
            while (e <= 2) {
                v = bag(5, 6).map(|x| x * d);
                collect(v, "v");
                e = e + 1;
            }
            "#,
        )
        .unwrap();
        let a = PlanAnalysis::compute(&g);
        let shareable = a.shareable_preamble(&g);
        // Transitive Φ-dependence per node, for the assertion.
        let mut reads_phi = vec![false; g.nodes.len()];
        loop {
            let mut changed = false;
            for n in &g.nodes {
                let dep = matches!(n.op, Rhs::Phi(_))
                    || n.inputs.iter().any(|i| reads_phi[i.src]);
                if dep && !reads_phi[n.id] {
                    reads_phi[n.id] = true;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        let mut phi_dependent_hoisted = 0;
        for n in &g.nodes {
            if reads_phi[n.id] {
                assert!(!shareable[n.id], "{} reads a Φ and must not be shareable", n.name);
                if n.hoisted_from.is_some() {
                    phi_dependent_hoisted += 1;
                }
            }
        }
        assert!(phi_dependent_hoisted > 0, "test premise: a Φ-dependent chain was hoisted");
    }

    #[test]
    fn phi_fed_nodes_are_not_hoistable() {
        // `y = c` makes the bag literal's map chain feed the loop Φ.
        let g = raw_graph(
            "y = bag(); d = 1; while (d <= 3) { c = bag(1, 2).map(|x| pair(x, 1)); y = c; d = d + 1; } collect(y, \"y\");",
        );
        let a = PlanAnalysis::compute(&g);
        let l = &a.loops.loops[0];
        let inv = a.invariant_hoistable(&g, l);
        for &i in &inv {
            let feeds_phi = a.consumers[i]
                .iter()
                .any(|&(c, _)| matches!(g.nodes[c].op, Rhs::Phi(_)));
            assert!(!feeds_phi, "{} feeds a Φ and must stay", g.nodes[i].name);
        }
    }
}
