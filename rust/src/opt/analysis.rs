//! Shared plan analysis for the optimizer passes: loop structure (from
//! `cfg::loops`), per-node consumer lists, per-node loop-invariance (a
//! fixpoint over input edges), and output liveness (reachability to a
//! sink / condition node / Φ).
//!
//! Recomputed by the pass manager before every pass run — passes mutate
//! the graph (moving, merging, and removing nodes), so ids and blocks are
//! only valid for the graph snapshot the analysis was computed from.

use crate::cfg::dom::{self, DomTree};
use crate::cfg::loops::{self, LoopInfo, NaturalLoop};
use crate::dataflow::{DataflowGraph, Node, NodeId};
use crate::frontend::{BlockId, Rhs};

/// Analysis results shared by all optimizer passes.
pub struct PlanAnalysis {
    /// Dominator tree of the CFG.
    pub dom: DomTree,
    /// Natural loops and per-block nesting depth.
    pub loops: LoopInfo,
    /// `consumers[n]` = downstream `(consumer, input index)` pairs
    /// (the inverse of `Node::inputs`, precomputed once).
    pub consumers: Vec<Vec<(NodeId, usize)>>,
    /// `live[n]`: the node's output reaches a sink (`collect`/`writeFile`),
    /// a condition node, or a Φ. Dead nodes compute bags nobody reads.
    pub live: Vec<bool>,
}

/// Is this node a liveness root? Sinks and side effects, condition nodes
/// (they drive control flow), and Φs (they carry loop state).
pub fn is_root(n: &Node) -> bool {
    n.cond.is_some()
        || matches!(n.op, Rhs::Collect { .. } | Rhs::WriteFile { .. } | Rhs::Phi(_))
}

/// Can this operation be moved out of a loop when its inputs are
/// invariant? Pure bag transformations only: sinks (`collect`,
/// `writeFile`) execute per iteration by definition, Φ/condition nodes
/// anchor the coordination protocol, `reduce` errors on empty input and
/// `readFile` touches the filesystem — hoisting would *speculate* those
/// even when the loop runs zero iterations.
///
/// **Deliberate speculation contract:** `NamedSource` and `XlaCall` ARE
/// hoistable even though a hoisted instance executes once per loop
/// *entry* — including entries where the loop then runs zero iterations.
/// This mirrors the paper's Flink setting, where a job's source operators
/// are materialized at job launch regardless of the control flow actually
/// taken, and it is what makes the Fig. 8 pass-driven hoisting fire. The
/// visible difference: a zero-trip loop over an *unregistered* source
/// name panics under the default optimizer where the raw translation
/// would not (`--no-hoist` / `opt.hoist = off` restores lazy behavior).
/// UDFs are likewise assumed total. See ROADMAP "Cost model for hoisting".
pub fn is_hoistable_op(op: &Rhs) -> bool {
    matches!(
        op,
        Rhs::BagLit(_)
            | Rhs::NamedSource(_)
            | Rhs::Map { .. }
            | Rhs::Filter { .. }
            | Rhs::FlatMap { .. }
            | Rhs::Fused { .. }
            | Rhs::Join { .. }
            | Rhs::ReduceByKey { .. }
            | Rhs::Count { .. }
            | Rhs::Distinct { .. }
            | Rhs::Union { .. }
            | Rhs::Cross { .. }
            | Rhs::XlaCall { .. }
    )
}

impl PlanAnalysis {
    /// Compute the analysis for the current graph.
    pub fn compute(g: &DataflowGraph) -> PlanAnalysis {
        let dt = dom::dominators(&g.cfg);
        let li = loops::find_loops(&g.cfg, &dt);

        let mut consumers: Vec<Vec<(NodeId, usize)>> = vec![Vec::new(); g.nodes.len()];
        for n in &g.nodes {
            for (i, inp) in n.inputs.iter().enumerate() {
                consumers[inp.src].push((n.id, i));
            }
        }

        // Liveness: backward closure from the roots through input edges.
        let mut live = vec![false; g.nodes.len()];
        let mut work: Vec<NodeId> = Vec::new();
        for n in &g.nodes {
            if is_root(n) {
                live[n.id] = true;
                work.push(n.id);
            }
        }
        while let Some(v) = work.pop() {
            for inp in &g.nodes[v].inputs {
                if !live[inp.src] {
                    live[inp.src] = true;
                    work.push(inp.src);
                }
            }
        }

        PlanAnalysis { dom: dt, loops: li, consumers, live }
    }

    /// The loop's *preamble anchor*: the unique predecessor of the header
    /// outside the loop body. Hoisted nodes are moved into this block, so
    /// they compute exactly once per loop *entry* (once per enclosing-loop
    /// iteration when loops nest). `None` when the entry edge is not
    /// unique — such loops are skipped.
    pub fn preheader(&self, g: &DataflowGraph, l: &NaturalLoop) -> Option<BlockId> {
        let outside: Vec<BlockId> = g.cfg.preds[l.header]
            .iter()
            .copied()
            .filter(|&p| l.body.binary_search(&p).is_err())
            .collect();
        match outside.as_slice() {
            [p] => Some(*p),
            _ => None,
        }
    }

    /// Nodes of loop `l` that are invariant *and* safely hoistable:
    /// a fixpoint over input edges starting from nodes all of whose inputs
    /// are defined outside the loop body. Excludes Φ/condition/sink nodes
    /// (see [`is_hoistable_op`]), nodes that feed a Φ directly (the
    /// coordination protocol requires Φ inputs to keep their defining
    /// blocks — SSA guarantees them pairwise distinct), and nodes in
    /// blocks that do NOT dominate the latch: an if-guarded block inside
    /// the loop may never execute, and hoisting would speculate its
    /// operators (a guarded `source(..)` of an unregistered name must
    /// keep panicking only when the guard is taken).
    pub fn invariant_hoistable(&self, g: &DataflowGraph, l: &NaturalLoop) -> Vec<NodeId> {
        let in_body = |b: BlockId| l.body.binary_search(&b).is_ok();
        let candidate = |n: &Node| -> bool {
            in_body(n.block)
                && self.dom.dominates(n.block, l.latch)
                && n.cond.is_none()
                && is_hoistable_op(&n.op)
                && self.consumers[n.id]
                    .iter()
                    .all(|&(c, _)| !matches!(g.nodes[c].op, Rhs::Phi(_)))
        };
        let mut invariant = vec![false; g.nodes.len()];
        loop {
            let mut changed = false;
            for n in &g.nodes {
                if invariant[n.id] || !candidate(n) {
                    continue;
                }
                let ok = n
                    .inputs
                    .iter()
                    .all(|i| !in_body(g.nodes[i.src].block) || invariant[i.src]);
                if ok {
                    invariant[n.id] = true;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        (0..g.nodes.len()).filter(|&i| invariant[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::opt::OptConfig;

    fn raw_graph(src: &str) -> DataflowGraph {
        crate::compile_with(&parse_and_lower(src).unwrap(), &OptConfig::none())
            .unwrap()
            .0
    }

    #[test]
    fn consumers_match_graph_inverse() {
        let g = raw_graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");");
        let a = PlanAnalysis::compute(&g);
        for n in &g.nodes {
            assert_eq!(a.consumers[n.id], g.consumers(n.id), "{}", n.name);
        }
    }

    #[test]
    fn everything_reaching_collect_is_live() {
        let g = raw_graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");");
        let a = PlanAnalysis::compute(&g);
        assert!(a.live.iter().all(|&l| l), "straightline collect chain is fully live");
    }

    #[test]
    fn loop_invariant_map_found_with_preheader() {
        // `attrs`-in-loop pattern: the source and its keying map depend on
        // nothing loop-varying — both are invariant; the join is not (its
        // probe side varies with d).
        let g = raw_graph(
            r#"
            d = 1;
            while (d <= 3) {
                attrs = source("x").map(|v| pair(v, v));
                probe = bag(1, 2).map(|v| pair(v + d, d));
                j = probe.join(attrs);
                collect(j, "j");
                d = d + 1;
            }
            "#,
        );
        let a = PlanAnalysis::compute(&g);
        assert_eq!(a.loops.loops.len(), 1);
        let l = &a.loops.loops[0];
        assert!(a.preheader(&g, l).is_some());
        let inv = a.invariant_hoistable(&g, l);
        let names: Vec<&str> = inv.iter().map(|&i| g.nodes[i].name.as_str()).collect();
        assert!(
            inv.iter().any(|&i| matches!(g.nodes[i].op, Rhs::NamedSource(_))),
            "source is invariant: {names:?}"
        );
        // The keying map over the source is invariant too.
        assert!(
            inv.iter().any(|&i| matches!(g.nodes[i].op, Rhs::Map { .. })
                && g.nodes[i].inputs.iter().all(|e| inv.contains(&e.src))),
            "map over source is invariant: {names:?}"
        );
        // The join depends on the loop-varying probe side.
        for &i in &inv {
            assert!(!matches!(g.nodes[i].op, Rhs::Join { .. }), "join must not be invariant");
        }
    }

    #[test]
    fn phi_fed_nodes_are_not_hoistable() {
        // `y = c` makes the bag literal's map chain feed the loop Φ.
        let g = raw_graph(
            "y = bag(); d = 1; while (d <= 3) { c = bag(1, 2).map(|x| pair(x, 1)); y = c; d = d + 1; } collect(y, \"y\");",
        );
        let a = PlanAnalysis::compute(&g);
        let l = &a.loops.loops[0];
        let inv = a.invariant_hoistable(&g, l);
        for &i in &inv {
            let feeds_phi = a.consumers[i]
                .iter()
                .any(|&(c, _)| matches!(g.nodes[c].op, Rhs::Phi(_)));
            assert!(!feeds_phi, "{} feeds a Φ and must stay", g.nodes[i].name);
        }
    }
}
