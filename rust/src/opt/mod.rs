//! The dataflow plan optimizer: a pass manager over [`DataflowGraph`]
//! running graph-level rewrites between `dataflow::build` and the
//! executors. This is where the paper's "optimizations across iteration
//! steps" (§7) live as *compiler transformations* instead of runtime
//! special cases:
//!
//! * [`hoist`] — **loop-invariant hoisting**: nodes whose inputs are all
//!   invariant w.r.t. an enclosing loop move out of the cycle into the
//!   loop's preamble block, so they compute once per loop entry instead of
//!   once per iteration. This generalizes the join-only build-side reuse:
//!   any invariant chain (sources, maps, joins of invariants, ...) leaves
//!   the loop, and the §7 runtime reuse then fires automatically because
//!   the build side's bag identity becomes step-independent.
//! * [`fuse`] — **operator fusion**: maximal linear chains of pipelineable
//!   element-wise operators (map/filter/flatMap, same block, same
//!   parallelism, `Route::Forward`) collapse into one fused physical
//!   operator ([`crate::ops::fused`]), cutting per-element dispatch and
//!   per-bag coordination messages on the hot path.
//! * [`dce`] — **dead-operator elimination**: nodes whose outputs reach no
//!   sink, condition node, or Φ are dropped.
//!
//! Passes share a [`analysis::PlanAnalysis`] (loop membership, invariance
//! fixpoint, liveness) and run in rounds until a fixpoint, each pass
//! independently toggleable via [`OptConfig`] (`opt.hoist` / `opt.fuse` /
//! `opt.dce` config keys). The manager verifies graph integrity after
//! every pass and reports an [`ExplainReport`] that the engine surfaces
//! through `metrics` and `dataflow::dot` renders as clustered subgraphs.

pub mod analysis;
pub mod dce;
pub mod fuse;
pub mod hoist;

use crate::dataflow::DataflowGraph;
use crate::error::{Error, Result};
use analysis::PlanAnalysis;
use rustc_hash::FxHashMap;

/// Which passes run. All default to on; each is independently toggleable
/// (config keys `opt.hoist`, `opt.fuse`, `opt.dce`, `opt.max_rounds`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptConfig {
    /// Loop-invariant hoisting.
    pub hoist: bool,
    /// Element-wise operator fusion.
    pub fuse: bool,
    /// Dead-operator elimination.
    pub dce: bool,
    /// Maximum pass-manager rounds (each round runs every enabled pass
    /// once; rounds stop early when nothing changes).
    pub max_rounds: usize,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig { hoist: true, fuse: true, dce: true, max_rounds: 3 }
    }
}

impl OptConfig {
    /// Everything off — `compile_with(p, &OptConfig::none())` returns the
    /// raw §5.3 translation. Keeps the default `max_rounds`, so
    /// re-enabling a single pass via struct update
    /// (`OptConfig { fuse: true, ..OptConfig::none() }`) actually runs it.
    pub fn none() -> OptConfig {
        OptConfig { hoist: false, fuse: false, dce: false, ..OptConfig::default() }
    }

    /// Read the `opt.*` section of a [`crate::config::Config`] (missing
    /// keys keep the defaults).
    pub fn from_config(cfg: &crate::config::Config) -> Result<OptConfig> {
        let d = OptConfig::default();
        Ok(OptConfig {
            hoist: cfg.get_bool("opt.hoist", d.hoist)?,
            fuse: cfg.get_bool("opt.fuse", d.fuse)?,
            dce: cfg.get_bool("opt.dce", d.dce)?,
            max_rounds: cfg.get_usize("opt.max_rounds", d.max_rounds)?,
        })
    }
}

/// What one pass run did.
pub struct PassOutcome {
    /// Number of nodes affected (hoisted / eliminated-by-fusion / removed).
    pub changed: usize,
    /// Human-readable one-liners (one per hoisted node / fused chain /
    /// removed node).
    pub details: Vec<String>,
}

/// A graph-rewriting pass.
pub trait Pass {
    /// Pass name (stable; used in reports and metrics keys).
    fn name(&self) -> &'static str;
    /// Rewrite the graph; the analysis matches the graph at entry.
    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome>;
}

/// Statistics of one pass invocation.
pub struct PassStats {
    /// Pass name.
    pub pass: &'static str,
    /// 1-based round number.
    pub round: usize,
    /// Nodes affected.
    pub changed: usize,
    /// Node count after the pass.
    pub nodes_after: usize,
    /// Per-change descriptions.
    pub details: Vec<String>,
}

/// The optimizer's explain report: per-pass node counts and what was
/// hoisted/fused/removed.
#[derive(Default)]
pub struct ExplainReport {
    /// Node count before optimization.
    pub nodes_before: usize,
    /// Node count after optimization.
    pub nodes_after: usize,
    /// Rounds executed.
    pub rounds: usize,
    /// Distinct nodes sitting in loop preambles after optimization (a
    /// node hoisted out of nested loops moves more than once but counts
    /// once; matches the engine's `exec.hoisted_nodes`).
    pub hoisted: usize,
    /// Fused chains created.
    pub fused_chains: usize,
    /// Nodes eliminated by fusion (chain members merged away).
    pub fused_away: usize,
    /// Nodes removed by dead-operator elimination.
    pub dce_removed: usize,
    /// Per-pass statistics, in execution order.
    pub passes: Vec<PassStats>,
}

impl Default for PassOutcome {
    fn default() -> Self {
        PassOutcome { changed: 0, details: Vec::new() }
    }
}

impl ExplainReport {
    /// Summary counters recorded into run metrics (`opt.*`).
    pub fn summary(&self) -> Vec<(String, u64)> {
        vec![
            ("opt.nodes_before".into(), self.nodes_before as u64),
            ("opt.nodes_after".into(), self.nodes_after as u64),
            ("opt.rounds".into(), self.rounds as u64),
            ("opt.hoisted".into(), self.hoisted as u64),
            ("opt.fused_chains".into(), self.fused_chains as u64),
            ("opt.fused_away".into(), self.fused_away as u64),
            ("opt.dce_removed".into(), self.dce_removed as u64),
        ]
    }

    /// Render a human-readable report (CLI `--explain`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "optimizer: {} -> {} nodes in {} round(s) \
             ({} hoisted, {} chains fused [{} nodes away], {} dead removed)\n",
            self.nodes_before,
            self.nodes_after,
            self.rounds,
            self.hoisted,
            self.fused_chains,
            self.fused_away,
            self.dce_removed,
        ));
        for p in &self.passes {
            s.push_str(&format!(
                "  round {} {:<6} changed {:>3}  nodes {}\n",
                p.round, p.pass, p.changed, p.nodes_after
            ));
            for d in &p.details {
                s.push_str(&format!("    - {d}\n"));
            }
        }
        s
    }
}

/// The pass manager: runs the enabled passes in rounds until a fixpoint
/// (or `max_rounds`), recomputing the shared analysis before each pass and
/// verifying graph integrity after each pass.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    max_rounds: usize,
}

impl PassManager {
    /// Build the manager for a configuration.
    pub fn from_config(cfg: &OptConfig) -> PassManager {
        let mut passes: Vec<Box<dyn Pass>> = Vec::new();
        if cfg.hoist {
            passes.push(Box::new(hoist::HoistPass));
        }
        if cfg.fuse {
            passes.push(Box::new(fuse::FusePass));
        }
        if cfg.dce {
            passes.push(Box::new(dce::DcePass));
        }
        PassManager { passes, max_rounds: cfg.max_rounds }
    }

    /// Run the pipeline on a graph.
    pub fn run(&self, g: &mut DataflowGraph) -> Result<ExplainReport> {
        let mut report = ExplainReport { nodes_before: g.num_nodes(), ..Default::default() };
        for round in 1..=self.max_rounds {
            if self.passes.is_empty() {
                break;
            }
            let mut round_changed = 0usize;
            for pass in &self.passes {
                let a = PlanAnalysis::compute(g);
                let out = pass.run(g, &a)?;
                verify_integrity(g).map_err(|e| {
                    Error::Dataflow(format!("opt pass '{}' broke the graph: {e}", pass.name()))
                })?;
                round_changed += out.changed;
                match pass.name() {
                    "fuse" => {
                        report.fused_chains += out.details.len();
                        report.fused_away += out.changed;
                    }
                    "dce" => report.dce_removed += out.changed,
                    _ => {}
                }
                report.passes.push(PassStats {
                    pass: pass.name(),
                    round,
                    changed: out.changed,
                    nodes_after: g.num_nodes(),
                    details: out.details,
                });
            }
            report.rounds = round;
            if round_changed == 0 {
                break;
            }
        }
        report.nodes_after = g.num_nodes();
        report.hoisted = g.nodes.iter().filter(|n| n.hoisted_from.is_some()).count();
        g.opt_summary = report.summary();
        Ok(report)
    }
}

/// Optimize a graph in place; returns the explain report. Runs by default
/// inside [`crate::compile`].
pub fn optimize(g: &mut DataflowGraph, cfg: &OptConfig) -> Result<ExplainReport> {
    PassManager::from_config(cfg).run(g)
}

/// Recompute `src_block` / `conditional` on every edge from the current
/// node blocks (used after a pass moves nodes between blocks).
pub(crate) fn refresh_edges(g: &mut DataflowGraph) {
    for i in 0..g.nodes.len() {
        let nb = g.nodes[i].block;
        for k in 0..g.nodes[i].inputs.len() {
            let src = g.nodes[i].inputs[k].src;
            let sb = g.nodes[src].block;
            let inp = &mut g.nodes[i].inputs[k];
            inp.src_block = sb;
            inp.conditional = sb != nb;
        }
    }
}

/// Drop the nodes where `keep[id]` is false, compacting ids and rebuilding
/// `node_of_var`. Panics (via the integrity check that follows every
/// pass) if a kept node references a dropped one.
pub(crate) fn compact(g: &mut DataflowGraph, keep: &[bool]) {
    debug_assert_eq!(keep.len(), g.nodes.len());
    let mut old2new = vec![usize::MAX; keep.len()];
    let mut new_nodes = Vec::with_capacity(keep.iter().filter(|&&k| k).count());
    for (old, node) in g.nodes.drain(..).enumerate() {
        if keep[old] {
            old2new[old] = new_nodes.len();
            new_nodes.push(node);
        }
    }
    for n in &mut new_nodes {
        n.id = old2new[n.id];
        for inp in &mut n.inputs {
            inp.src = old2new[inp.src];
        }
    }
    g.nodes = new_nodes;
    g.node_of_var = g.nodes.iter().map(|n| (n.var, n.id)).collect::<FxHashMap<_, _>>();
}

/// Structural invariants every pass must preserve. Cheap (O(V+E)) and run
/// after each pass, so a buggy rewrite fails compilation loudly instead of
/// deadlocking the coordination protocol at runtime.
pub fn verify_integrity(g: &DataflowGraph) -> Result<()> {
    let n = g.nodes.len();
    for (i, node) in g.nodes.iter().enumerate() {
        if node.id != i {
            return Err(Error::Dataflow(format!("node id {} at index {i}", node.id)));
        }
        let vars = node.op.input_vars();
        if vars.len() != node.inputs.len() {
            return Err(Error::Dataflow(format!(
                "node '{}': {} edges but op references {} vars",
                node.name,
                node.inputs.len(),
                vars.len()
            )));
        }
        for (k, inp) in node.inputs.iter().enumerate() {
            if inp.src >= n {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k} references missing node {}",
                    node.name, inp.src
                )));
            }
            if g.nodes[inp.src].var != vars[k] {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: edge source disagrees with op variable",
                    node.name
                )));
            }
            if inp.src_block != g.nodes[inp.src].block {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: stale src_block",
                    node.name
                )));
            }
            if inp.conditional != (inp.src_block != node.block) {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: stale conditional flag",
                    node.name
                )));
            }
        }
        if node.cond.is_some() && node.hoisted_from.is_some() {
            return Err(Error::Dataflow(format!(
                "condition node '{}' was hoisted out of its branching block",
                node.name
            )));
        }
        match g.node_of_var.get(&node.var) {
            Some(&id) if id == node.id => {}
            other => {
                return Err(Error::Dataflow(format!(
                    "node_of_var for '{}' is {other:?}, want {}",
                    node.name, node.id
                )))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    #[test]
    fn config_defaults_and_toggles() {
        let d = OptConfig::default();
        assert!(d.hoist && d.fuse && d.dce);
        let n = OptConfig::none();
        assert!(!n.hoist && !n.fuse && !n.dce);
        let cfg = crate::config::Config::parse("[opt]\nhoist = off\nmax_rounds = 7\n").unwrap();
        let o = OptConfig::from_config(&cfg).unwrap();
        assert!(!o.hoist);
        assert!(o.fuse && o.dce);
        assert_eq!(o.max_rounds, 7);
    }

    #[test]
    fn optimize_none_is_identity() {
        let p = parse_and_lower(
            "d = 1; while (d <= 3) { c = bag(7).map(|x| x + 1).map(|x| x * 2); collect(c, \"c\"); d = d + 1; }",
        )
        .unwrap();
        let (raw, rep) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        assert_eq!(rep.nodes_before, rep.nodes_after);
        assert_eq!(rep.hoisted + rep.fused_chains + rep.dce_removed, 0);
        assert!(raw.nodes.iter().all(|n| n.hoisted_from.is_none()));
    }

    #[test]
    fn default_pipeline_hoists_and_fuses_and_reports() {
        let p = parse_and_lower(
            "d = 1; while (d <= 3) { c = bag(7, 8).map(|x| x + 1).map(|x| x * 2); collect(c, \"c\"); d = d + 1; }",
        )
        .unwrap();
        let (g, rep) = crate::compile_with(&p, &OptConfig::default()).unwrap();
        assert!(rep.hoisted > 0, "invariant chain should hoist:\n{}", rep.render());
        assert!(rep.fused_chains > 0, "map.map should fuse:\n{}", rep.render());
        assert!(rep.nodes_after < rep.nodes_before, "{}", rep.render());
        assert!(!g.opt_summary.is_empty());
        assert!(rep.render().contains("optimizer:"));
        verify_integrity(&g).unwrap();
    }

    #[test]
    fn compact_remaps_edges_and_vars() {
        let p = parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");").unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        // Append nothing to remove: keep-all compaction is a no-op.
        let keep = vec![true; g.nodes.len()];
        let before = g.nodes.len();
        compact(&mut g, &keep);
        assert_eq!(g.nodes.len(), before);
        verify_integrity(&g).unwrap();
    }
}
