//! The dataflow plan optimizer: a pass manager over [`DataflowGraph`]
//! running graph-level rewrites between `dataflow::build` and the
//! executors. This is where the paper's "optimizations across iteration
//! steps" (§7) live as *compiler transformations* instead of runtime
//! special cases:
//!
//! * [`hoist`] — **loop-invariant hoisting**: nodes whose inputs are all
//!   invariant w.r.t. an enclosing loop move out of the cycle into the
//!   loop's preamble block, so they compute once per loop entry instead of
//!   once per iteration. This generalizes the join-only build-side reuse:
//!   any invariant chain (sources, maps, joins of invariants, ...) leaves
//!   the loop, and the §7 runtime reuse then fires automatically because
//!   the build side's bag identity becomes step-independent.
//! * [`fuse`] — **operator fusion**: maximal linear chains of pipelineable
//!   element-wise operators (map/filter/flatMap, same block, same
//!   parallelism, `Route::Forward`) collapse into one fused physical
//!   operator ([`crate::ops::fused`]), cutting per-element dispatch and
//!   per-bag coordination messages on the hot path.
//! * [`xfuse`] — **cross-loop fusion**: lifted scalar chains (loop
//!   counters, compound conditions, straight-line scalar code split by
//!   loops) keep fusing where [`fuse`] must stop — literal-⨯ groups
//!   collapse to pair-injecting maps, map-only chains fold *into* their
//!   condition node, and singleton chains merge across dominating
//!   same-loop-context block boundaries — removing per-iteration bag
//!   lifecycles from the control path.
//! * [`dce`] — **dead-operator elimination**: nodes whose outputs reach no
//!   sink, condition node, or Φ are dropped.
//! * [`pushdown`] — **predicate pushdown**: a `filter` whose LabyLang
//!   predicate reads only one side of a `join` (or only the key of a
//!   `reduceByKey`, or anything above a `distinct`) moves below that
//!   operator, dropping rows before the keyed shuffle / hash table.
//! * [`joinside`] — **join build-side selection**: the [`cost`] model
//!   picks the cheaper hash-join build side (smaller estimated rows,
//!   strongly preferring a loop-invariant side so the §7 cross-step
//!   build reuse keeps firing); `ExecPlan`/`ops::join` honor the choice.
//! * [`delta`] — **delta-incremental loop rewriting**: loop-carried bags
//!   whose bodies are proven delta-safe (upsert/re-aggregation or
//!   semi-naive frontier shapes) switch to workset/solution-set
//!   execution — per superstep only changed rows circulate and stateful
//!   operators merge them into indexed solution sets (`ops::state`).
//!   Runs last (on the fully optimized shape), gated by the [`cost`]
//!   trip model under `opt.delta = auto`.
//! * [`types`] — **per-edge element-type inference**: a forward fixpoint
//!   over the lattice `I64 | F64 | Bool | Str | Pair | Tuple | Dyn`
//!   deriving every edge's static element type from source hints, UDF
//!   expression metadata, and operator signatures. Not a rewrite: the
//!   result (`DataflowGraph::elem_types`) selects monomorphic columnar
//!   kernels at `ops::make_node` time, gated by `opt.columnar =
//!   auto|always|never` ([`ColumnarGate`]).
//!
//! Passes share a [`analysis::PlanAnalysis`] (loop membership, invariance
//! fixpoint, liveness, and the [`cost`] row/trip estimates) and run in
//! rounds until a fixpoint, each pass independently toggleable via
//! [`OptConfig`] (`opt.pushdown` / `opt.hoist` / `opt.join_sides` /
//! `opt.fuse` / `opt.dce` config keys; speculative hoisting is governed
//! by `opt.speculate`). The manager verifies graph integrity after every
//! pass and reports an [`ExplainReport`] that the engine surfaces through
//! `metrics` and `dataflow::dot` renders as clustered subgraphs.

pub mod analysis;
pub mod cost;
pub mod dce;
pub mod delta;
pub mod fuse;
pub mod hoist;
pub mod joinside;
pub mod pushdown;
pub mod types;
pub mod xfuse;

pub use delta::DeltaGate;
pub use types::ColumnarGate;

use crate::dataflow::DataflowGraph;
use crate::error::{Error, Result};
use analysis::PlanAnalysis;
use rustc_hash::FxHashMap;

/// Observed-cardinality feedback: SSA variable name → mean rows per
/// output bag, measured by the engine (`RunOutput::node_rows`). Handed to
/// [`optimize_with_feedback`] by the `serve::` job service when it
/// re-optimizes a cached plan template from its own runtime statistics.
pub type RowFeedback = FxHashMap<String, f64>;

/// Speculation policy for hoisting `NamedSource` / `XlaCall` chains out
/// of loops (config key `opt.speculate`, CLI `--speculate`). See
/// [`analysis::is_hoistable_op`] for the contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Speculate {
    /// Cost-gated (default): hoist when estimated
    /// `trips × rows ≥ opt.speculate_threshold`.
    Auto,
    /// Always hoist (the pre-cost-model contract; mirrors Flink's
    /// materialize-sources-at-launch behavior).
    Always,
    /// Never hoist speculative chains (fully lazy sources).
    Never,
}

impl Speculate {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Result<Speculate> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Speculate::Auto),
            "always" => Ok(Speculate::Always),
            "never" => Ok(Speculate::Never),
            other => Err(Error::Config(format!(
                "opt.speculate: expected auto|always|never, got {other:?}"
            ))),
        }
    }
}

/// Which passes run. All default to on; each is independently toggleable
/// (config keys `opt.pushdown`, `opt.hoist`, `opt.join_sides`,
/// `opt.fuse`, `opt.dce`, `opt.max_rounds`, plus the speculation knobs
/// `opt.speculate`, `opt.speculate_threshold`, `opt.default_trips`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptConfig {
    /// Loop-invariant hoisting.
    pub hoist: bool,
    /// Element-wise operator fusion.
    pub fuse: bool,
    /// Dead-operator elimination.
    pub dce: bool,
    /// Predicate pushdown below join / reduceByKey / distinct.
    pub pushdown: bool,
    /// Cost-based hash-join build-side selection.
    pub join_sides: bool,
    /// Speculative-hoist policy (gates `NamedSource`/`XlaCall` chains).
    pub speculate: Speculate,
    /// Delta-incremental loop rewriting policy (config key `opt.delta`,
    /// CLI `--no-delta`, env default `LABY_DELTA`).
    pub delta: DeltaGate,
    /// Columnar (typed SoA) kernel policy (config key `opt.columnar`,
    /// CLI `--no-columnar`, env default `LABY_COLUMNAR`).
    pub columnar: ColumnarGate,
    /// Minimum estimated `trips × rows` for a speculative hoist under
    /// [`Speculate::Auto`].
    pub speculate_threshold: f64,
    /// Trip-count assumed for loops whose bound the cost model cannot
    /// derive (data-dependent conditions).
    pub default_trips: u64,
    /// Maximum pass-manager rounds (each round runs every enabled pass
    /// once; rounds stop early when nothing changes).
    pub max_rounds: usize,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig {
            hoist: true,
            fuse: true,
            dce: true,
            pushdown: true,
            join_sides: true,
            speculate: Speculate::Auto,
            delta: DeltaGate::default_from_env(),
            columnar: ColumnarGate::default_from_env(),
            speculate_threshold: 1.0,
            default_trips: 4,
            max_rounds: 3,
        }
    }
}

impl OptConfig {
    /// Everything off — `compile_with(p, &OptConfig::none())` returns the
    /// raw §5.3 translation. Keeps the default `max_rounds` and cost
    /// knobs, so re-enabling a single pass via struct update
    /// (`OptConfig { fuse: true, ..OptConfig::none() }`) actually runs it.
    pub fn none() -> OptConfig {
        OptConfig {
            hoist: false,
            fuse: false,
            dce: false,
            pushdown: false,
            join_sides: false,
            delta: DeltaGate::Never,
            columnar: ColumnarGate::Never,
            ..OptConfig::default()
        }
    }

    /// Read the `opt.*` section of a [`crate::config::Config`] (missing
    /// keys keep the defaults).
    pub fn from_config(cfg: &crate::config::Config) -> Result<OptConfig> {
        let d = OptConfig::default();
        let speculate = match cfg.get("opt.speculate") {
            None => d.speculate,
            Some(s) => Speculate::parse(s)?,
        };
        let delta = match cfg.get("opt.delta") {
            None => d.delta,
            Some(s) => DeltaGate::parse(s)?,
        };
        let columnar = match cfg.get("opt.columnar") {
            None => d.columnar,
            Some(s) => ColumnarGate::parse(s)?,
        };
        Ok(OptConfig {
            hoist: cfg.get_bool("opt.hoist", d.hoist)?,
            fuse: cfg.get_bool("opt.fuse", d.fuse)?,
            dce: cfg.get_bool("opt.dce", d.dce)?,
            pushdown: cfg.get_bool("opt.pushdown", d.pushdown)?,
            join_sides: cfg.get_bool("opt.join_sides", d.join_sides)?,
            speculate,
            delta,
            columnar,
            speculate_threshold: cfg
                .get_f64("opt.speculate_threshold", d.speculate_threshold)?,
            default_trips: cfg.get_u64("opt.default_trips", d.default_trips)?,
            max_rounds: cfg.get_usize("opt.max_rounds", d.max_rounds)?,
        })
    }
}

/// What one pass run did.
pub struct PassOutcome {
    /// Number of nodes affected (hoisted / eliminated-by-fusion / removed
    /// / filters pushed / build sides flipped).
    pub changed: usize,
    /// Work a cost gate declined (currently: speculative hoists kept in
    /// their loop).
    pub skipped: usize,
    /// Human-readable one-liners (one per hoisted node / fused chain /
    /// removed node / pushed filter / flipped join).
    pub details: Vec<String>,
}

/// A graph-rewriting pass.
pub trait Pass {
    /// Pass name (stable; used in reports and metrics keys).
    fn name(&self) -> &'static str;
    /// Rewrite the graph; the analysis matches the graph at entry.
    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome>;
}

/// Statistics of one pass invocation.
pub struct PassStats {
    /// Pass name.
    pub pass: &'static str,
    /// 1-based round number.
    pub round: usize,
    /// Nodes affected.
    pub changed: usize,
    /// Node count after the pass.
    pub nodes_after: usize,
    /// Per-change descriptions.
    pub details: Vec<String>,
}

/// The optimizer's explain report: per-pass node counts and what was
/// hoisted/fused/removed.
#[derive(Default)]
pub struct ExplainReport {
    /// Node count before optimization.
    pub nodes_before: usize,
    /// Node count after optimization.
    pub nodes_after: usize,
    /// Rounds executed.
    pub rounds: usize,
    /// Distinct nodes sitting in loop preambles after optimization (a
    /// node hoisted out of nested loops moves more than once but counts
    /// once; matches the engine's `exec.hoisted_nodes`).
    pub hoisted: usize,
    /// Fused chains created.
    pub fused_chains: usize,
    /// Nodes eliminated by fusion (chain members merged away).
    pub fused_away: usize,
    /// Cross-loop fusion events ([`xfuse`]): literal-cross folds plus
    /// chain members merged across block/condition boundaries.
    pub cross_loop_fusions: usize,
    /// Nodes removed by dead-operator elimination.
    pub dce_removed: usize,
    /// Filters moved below a join / reduceByKey / distinct.
    pub pushed_filters: usize,
    /// Hash joins whose build side the cost model flipped.
    pub join_flips: usize,
    /// Speculative nodes the hoist cost gate kept in their loop (as of
    /// the last hoist run — a state count, not a sum of per-round events).
    pub hoist_gated: usize,
    /// Nodes whose row estimate was pinned to observed runtime
    /// cardinalities ([`RowFeedback`]); 0 on plain compiles.
    pub feedback_nodes: usize,
    /// Loops rewritten to delta-incremental (workset/solution-set)
    /// execution, as of the last delta run — a state count, not a sum
    /// of per-round events.
    pub delta_loops: usize,
    /// Dataflow edges whose inferred element type is concrete (not
    /// `Dyn`) — the edges eligible for columnar kernels.
    pub typed_edges: usize,
    /// Per-pass statistics, in execution order.
    pub passes: Vec<PassStats>,
}

impl Default for PassOutcome {
    fn default() -> Self {
        PassOutcome { changed: 0, skipped: 0, details: Vec::new() }
    }
}

impl ExplainReport {
    /// Summary counters recorded into run metrics (`opt.*`).
    pub fn summary(&self) -> Vec<(String, u64)> {
        vec![
            ("opt.nodes_before".into(), self.nodes_before as u64),
            ("opt.nodes_after".into(), self.nodes_after as u64),
            ("opt.rounds".into(), self.rounds as u64),
            ("opt.hoisted".into(), self.hoisted as u64),
            ("opt.fused_chains".into(), self.fused_chains as u64),
            ("opt.fused_away".into(), self.fused_away as u64),
            ("opt.cross_loop_fusions".into(), self.cross_loop_fusions as u64),
            ("opt.dce_removed".into(), self.dce_removed as u64),
            ("opt.pushdown_filters".into(), self.pushed_filters as u64),
            ("opt.join_flips".into(), self.join_flips as u64),
            ("opt.hoist_gated_skips".into(), self.hoist_gated as u64),
            ("opt.feedback_rows_pinned".into(), self.feedback_nodes as u64),
            ("opt.delta_loops".into(), self.delta_loops as u64),
            ("opt.typed_edges".into(), self.typed_edges as u64),
        ]
    }

    /// Render a human-readable report (CLI `--explain`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "optimizer: {} -> {} nodes in {} round(s) \
             ({} hoisted [{} gate-skipped], {} chains fused [{} nodes away], \
             {} dead removed, {} filters pushed, {} join sides flipped)\n",
            self.nodes_before,
            self.nodes_after,
            self.rounds,
            self.hoisted,
            self.hoist_gated,
            self.fused_chains,
            self.fused_away,
            self.dce_removed,
            self.pushed_filters,
            self.join_flips,
        ));
        if self.cross_loop_fusions > 0 {
            s.push_str(&format!(
                "  xfuse: {} cross-loop scalar fusion(s) (literal folds + boundary merges)\n",
                self.cross_loop_fusions
            ));
        }
        if self.feedback_nodes > 0 {
            s.push_str(&format!(
                "  adaptive: {} node row estimate(s) pinned to observed runtime cardinalities\n",
                self.feedback_nodes
            ));
        }
        if self.delta_loops > 0 {
            s.push_str(&format!(
                "  delta: {} loop(s) rewritten to workset/solution-set execution\n",
                self.delta_loops
            ));
        }
        if self.typed_edges > 0 {
            s.push_str(&format!(
                "  types: {} edge(s) inferred concrete (columnar-eligible)\n",
                self.typed_edges
            ));
        }
        for p in &self.passes {
            s.push_str(&format!(
                "  round {} {:<6} changed {:>3}  nodes {}\n",
                p.round, p.pass, p.changed, p.nodes_after
            ));
            for d in &p.details {
                s.push_str(&format!("    - {d}\n"));
            }
        }
        s
    }
}

/// The pass manager: runs the enabled passes in rounds until a fixpoint
/// (or `max_rounds`), recomputing the shared analysis before each pass and
/// verifying graph integrity after each pass.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    max_rounds: usize,
    /// Observed-cardinality seed: when set, per-node row estimates for
    /// named nodes are pinned to these values before every pass (see
    /// [`cost::estimate_rows_seeded`]).
    row_seed: Option<RowFeedback>,
    /// Columnar-kernel policy stamped onto the optimized graph, so the
    /// engine selects typed kernels without re-reading the config.
    columnar: ColumnarGate,
}

impl PassManager {
    /// Build the manager for a configuration. Pass order within a round:
    /// pushdown first (filters shrink the row estimates every later
    /// decision uses), then hoisting (moves invariant chains — including
    /// freshly pushed filters — into preambles), then build-side
    /// selection (so it sees post-hoist invariance), then fusion and DCE.
    pub fn from_config(cfg: &OptConfig) -> PassManager {
        let mut passes: Vec<Box<dyn Pass>> = Vec::new();
        if cfg.pushdown {
            passes.push(Box::new(pushdown::PushdownPass));
        }
        if cfg.hoist {
            passes.push(Box::new(hoist::HoistPass {
                speculate: cfg.speculate,
                threshold: cfg.speculate_threshold,
                default_trips: cfg.default_trips,
            }));
        }
        if cfg.join_sides {
            passes.push(Box::new(joinside::JoinSidePass { default_trips: cfg.default_trips }));
        }
        if cfg.fuse {
            passes.push(Box::new(fuse::FusePass));
            // Cross-loop fusion rides the same gate: it extends fusion
            // across block/condition boundaries for singleton scalar
            // chains and relies on the fuse pass collapsing the
            // same-block segments it exposes (next round).
            passes.push(Box::new(xfuse::XfusePass));
        }
        if cfg.dce {
            passes.push(Box::new(dce::DcePass));
        }
        // Delta rewriting runs last so it proves safety on the final
        // shape of each round (post-hoist invariance, post-DCE liveness,
        // settled join build sides). The pass recomputes its annotations
        // from scratch every run, so an earlier round's decision never
        // outlives the shape it was proven on.
        if cfg.delta != DeltaGate::Never {
            passes.push(Box::new(delta::DeltaPass {
                gate: cfg.delta,
                default_trips: cfg.default_trips,
            }));
        }
        PassManager {
            passes,
            max_rounds: cfg.max_rounds,
            row_seed: None,
            columnar: cfg.columnar,
        }
    }

    /// Pin row estimates of named nodes to observed runtime cardinalities
    /// for every analysis this manager computes.
    pub fn with_row_feedback(mut self, feedback: RowFeedback) -> PassManager {
        self.row_seed = Some(feedback);
        self
    }

    /// Run the pipeline on a graph.
    pub fn run(&self, g: &mut DataflowGraph) -> Result<ExplainReport> {
        let mut report = ExplainReport { nodes_before: g.num_nodes(), ..Default::default() };
        // Loop trip estimates are CFG-level and invariant under the
        // graph rewrites (passes preserve semantics and never touch the
        // CFG), so run the scalar-chain simulation ONCE per optimize run
        // — not before every pass — and share the result. With no passes
        // enabled, nothing (including UDF evaluation) runs at all.
        let params = cost::CostParams::default();
        let trips: Vec<cost::TripCount> = if self.passes.is_empty() {
            Vec::new()
        } else {
            let dt = crate::cfg::dom::dominators(&g.cfg);
            let li = crate::cfg::loops::find_loops(&g.cfg, &dt);
            li.loops.iter().map(|l| cost::estimate_trips(g, l, params.sim_trip_cap)).collect()
        };
        if let Some(seed) = &self.row_seed {
            report.feedback_nodes =
                g.nodes.iter().filter(|n| !n.singleton && seed.contains_key(&n.name)).count();
        }
        for round in 1..=self.max_rounds {
            if self.passes.is_empty() {
                break;
            }
            let mut round_changed = 0usize;
            for pass in &self.passes {
                let a = PlanAnalysis::compute_with_trips_seeded(
                    g,
                    &params,
                    trips.clone(),
                    self.row_seed.as_ref(),
                );
                let out = pass.run(g, &a)?;
                verify_integrity(g).map_err(|e| {
                    Error::Dataflow(format!("opt pass '{}' broke the graph: {e}", pass.name()))
                })?;
                round_changed += out.changed;
                match pass.name() {
                    "fuse" => {
                        report.fused_chains += out.details.len();
                        report.fused_away += out.changed;
                    }
                    "xfuse" => report.cross_loop_fusions += out.changed,
                    "dce" => report.dce_removed += out.changed,
                    "pushdown" => report.pushed_filters += out.changed,
                    "joinside" => report.join_flips += out.changed,
                    // Gate skips describe the graph state, not events: a
                    // chain kept in its loop is re-skipped every round, so
                    // take the latest run's count instead of summing.
                    "hoist" => report.hoist_gated = out.skipped,
                    // Same state-not-events convention: the pass
                    // re-annotates from scratch, so count the loops
                    // currently in delta mode.
                    "delta" => report.delta_loops = delta::annotated_loops(g),
                    _ => {}
                }
                report.passes.push(PassStats {
                    pass: pass.name(),
                    round,
                    changed: out.changed,
                    nodes_after: g.num_nodes(),
                    details: out.details,
                });
            }
            report.rounds = round;
            if round_changed == 0 {
                break;
            }
        }
        report.nodes_after = g.num_nodes();
        report.hoisted = g.nodes.iter().filter(|n| n.hoisted_from.is_some()).count();
        // Element-type inference runs on the final shape (fused chains,
        // settled join sides) — the types the engine will actually see.
        // It is an analysis, not a rewrite: a wrong (stale) type could
        // only cost the fast path, never correctness, but inferring last
        // keeps the DOT/explain output faithful to the executed plan.
        g.elem_types = types::infer(g);
        g.columnar = self.columnar;
        report.typed_edges = types::typed_edge_count(g, &g.elem_types);
        g.opt_summary = report.summary();
        Ok(report)
    }
}

/// Optimize a graph in place; returns the explain report. Runs by default
/// inside [`crate::compile`].
pub fn optimize(g: &mut DataflowGraph, cfg: &OptConfig) -> Result<ExplainReport> {
    PassManager::from_config(cfg).run(g)
}

/// Optimize with observed-cardinality feedback: row estimates of nodes
/// named in `feedback` are pinned to the measured values, so cost-driven
/// decisions (join sides, speculative hoists, pushdown ordering) reflect
/// what the engine actually saw instead of the static guesses. Entry
/// point for the `serve::` adaptive template re-optimization.
pub fn optimize_with_feedback(
    g: &mut DataflowGraph,
    cfg: &OptConfig,
    feedback: &RowFeedback,
) -> Result<ExplainReport> {
    PassManager::from_config(cfg).with_row_feedback(feedback.clone()).run(g)
}

/// Recompute `src_block` / `conditional` on every edge from the current
/// node blocks (used after a pass moves nodes between blocks).
pub(crate) fn refresh_edges(g: &mut DataflowGraph) {
    for i in 0..g.nodes.len() {
        let nb = g.nodes[i].block;
        for k in 0..g.nodes[i].inputs.len() {
            let src = g.nodes[i].inputs[k].src;
            let sb = g.nodes[src].block;
            let inp = &mut g.nodes[i].inputs[k];
            inp.src_block = sb;
            inp.conditional = sb != nb;
        }
    }
}

/// Drop the nodes where `keep[id]` is false, compacting ids and rebuilding
/// `node_of_var`. Panics (via the integrity check that follows every
/// pass) if a kept node references a dropped one.
pub(crate) fn compact(g: &mut DataflowGraph, keep: &[bool]) {
    debug_assert_eq!(keep.len(), g.nodes.len());
    let mut old2new = vec![usize::MAX; keep.len()];
    let mut new_nodes = Vec::with_capacity(keep.iter().filter(|&&k| k).count());
    for (old, node) in g.nodes.drain(..).enumerate() {
        if keep[old] {
            old2new[old] = new_nodes.len();
            new_nodes.push(node);
        }
    }
    for n in &mut new_nodes {
        n.id = old2new[n.id];
        for inp in &mut n.inputs {
            inp.src = old2new[inp.src];
        }
    }
    g.nodes = new_nodes;
    g.node_of_var = g.nodes.iter().map(|n| (n.var, n.id)).collect::<FxHashMap<_, _>>();
}

/// Structural invariants every pass must preserve. Cheap (O(V+E)) and run
/// after each pass, so a buggy rewrite fails compilation loudly instead of
/// deadlocking the coordination protocol at runtime.
pub fn verify_integrity(g: &DataflowGraph) -> Result<()> {
    let n = g.nodes.len();
    for (i, node) in g.nodes.iter().enumerate() {
        if node.id != i {
            return Err(Error::Dataflow(format!("node id {} at index {i}", node.id)));
        }
        let vars = node.op.input_vars();
        if vars.len() != node.inputs.len() {
            return Err(Error::Dataflow(format!(
                "node '{}': {} edges but op references {} vars",
                node.name,
                node.inputs.len(),
                vars.len()
            )));
        }
        for (k, inp) in node.inputs.iter().enumerate() {
            if inp.src >= n {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k} references missing node {}",
                    node.name, inp.src
                )));
            }
            if g.nodes[inp.src].var != vars[k] {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: edge source disagrees with op variable",
                    node.name
                )));
            }
            if inp.src_block != g.nodes[inp.src].block {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: stale src_block",
                    node.name
                )));
            }
            if inp.conditional != (inp.src_block != node.block) {
                return Err(Error::Dataflow(format!(
                    "node '{}' input {k}: stale conditional flag",
                    node.name
                )));
            }
        }
        if node.cond.is_some() && node.hoisted_from.is_some() {
            return Err(Error::Dataflow(format!(
                "condition node '{}' was hoisted out of its branching block",
                node.name
            )));
        }
        match g.node_of_var.get(&node.var) {
            Some(&id) if id == node.id => {}
            other => {
                return Err(Error::Dataflow(format!(
                    "node_of_var for '{}' is {other:?}, want {}",
                    node.name, node.id
                )))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    #[test]
    fn config_defaults_and_toggles() {
        let d = OptConfig::default();
        assert!(d.hoist && d.fuse && d.dce && d.pushdown && d.join_sides);
        assert_eq!(d.speculate, Speculate::Auto);
        let n = OptConfig::none();
        assert!(!n.hoist && !n.fuse && !n.dce && !n.pushdown && !n.join_sides);
        let cfg = crate::config::Config::parse(
            "[opt]\nhoist = off\nmax_rounds = 7\npushdown = off\nspeculate = never\nspeculate_threshold = 64\ndefault_trips = 9\n",
        )
        .unwrap();
        let o = OptConfig::from_config(&cfg).unwrap();
        assert!(!o.hoist);
        assert!(o.fuse && o.dce && o.join_sides);
        assert!(!o.pushdown);
        assert_eq!(o.speculate, Speculate::Never);
        assert_eq!(o.speculate_threshold, 64.0);
        assert_eq!(o.default_trips, 9);
        assert_eq!(o.max_rounds, 7);
    }

    #[test]
    fn speculate_parses_and_rejects() {
        assert_eq!(Speculate::parse("auto").unwrap(), Speculate::Auto);
        assert_eq!(Speculate::parse("ALWAYS").unwrap(), Speculate::Always);
        assert_eq!(Speculate::parse("never").unwrap(), Speculate::Never);
        assert!(Speculate::parse("sometimes").is_err());
    }

    #[test]
    fn optimize_none_is_identity() {
        let p = parse_and_lower(
            "d = 1; while (d <= 3) { c = bag(7).map(|x| x + 1).map(|x| x * 2); collect(c, \"c\"); d = d + 1; }",
        )
        .unwrap();
        let (raw, rep) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        assert_eq!(rep.nodes_before, rep.nodes_after);
        assert_eq!(rep.hoisted + rep.fused_chains + rep.dce_removed, 0);
        assert!(raw.nodes.iter().all(|n| n.hoisted_from.is_none()));
    }

    #[test]
    fn default_pipeline_hoists_and_fuses_and_reports() {
        let p = parse_and_lower(
            "d = 1; while (d <= 3) { c = bag(7, 8).map(|x| x + 1).map(|x| x * 2); collect(c, \"c\"); d = d + 1; }",
        )
        .unwrap();
        let (g, rep) = crate::compile_with(&p, &OptConfig::default()).unwrap();
        assert!(rep.hoisted > 0, "invariant chain should hoist:\n{}", rep.render());
        assert!(rep.fused_chains > 0, "map.map should fuse:\n{}", rep.render());
        assert!(rep.nodes_after < rep.nodes_before, "{}", rep.render());
        assert!(!g.opt_summary.is_empty());
        assert!(rep.render().contains("optimizer:"));
        verify_integrity(&g).unwrap();
    }

    #[test]
    fn feedback_pins_rows_and_reports() {
        // Build-side choice flips when feedback says the left input is
        // actually the huge one: join(left=small-estimate, right) with
        // observed left ≫ right should build on the right.
        let src = "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2, 3).map(|v| pair(v, v)); j = a.join(b); collect(j, \"j\");";
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let left_map = {
            let join =
                g.nodes.iter().find(|n| matches!(n.op, crate::frontend::Rhs::Join { .. })).unwrap();
            g.nodes[join.inputs[0].src].name.clone()
        };
        let mut fb = RowFeedback::default();
        fb.insert(left_map, 1_000_000.0);
        let cfg = OptConfig { join_sides: true, ..OptConfig::none() };
        let rep = optimize_with_feedback(&mut g, &cfg, &fb).unwrap();
        assert_eq!(rep.feedback_nodes, 1, "{}", rep.render());
        assert!(rep.render().contains("adaptive:"), "{}", rep.render());
        let join =
            g.nodes.iter().find(|n| matches!(n.op, crate::frontend::Rhs::Join { .. })).unwrap();
        assert_eq!(join.build_side, Some(1), "feedback flips the build to the smaller side");
        verify_integrity(&g).unwrap();
    }

    #[test]
    fn compact_remaps_edges_and_vars() {
        let p = parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");").unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        // Append nothing to remove: keep-all compaction is a no-op.
        let keep = vec![true; g.nodes.len()];
        let before = g.nodes.len();
        compact(&mut g, &keep);
        assert_eq!(g.nodes.len(), before);
        verify_integrity(&g).unwrap();
    }
}
