//! Predicate pushdown: move a `filter` *below* the operator it consumes —
//! before a `join` (onto the side(s) its predicate actually reads),
//! before a `reduceByKey` (when the predicate only reads the group key),
//! and before a `distinct` (always — dedup commutes with any element
//! predicate) — so rows are dropped before the expensive keyed shuffle /
//! hash table instead of after it.
//!
//! Join and reduceByKey rewrites are *structural*: they inspect the
//! LabyLang lambda carried on the predicate ([`Udf1::expr`]) and classify
//! every use of the parameter as a projection of the joined pair
//! `pair(k, pair(left, right))`:
//!
//! * `fst(p)` — the key (available on both inputs),
//! * `fst(snd(p))` — the left (build) payload,
//! * `snd(snd(p))` — the right (probe) payload,
//! * anything else touching `p` — the whole element (not pushable).
//!
//! A predicate reading only `{key, left}` moves to the left input, only
//! `{key, right}` to the right input, and key-only predicates are cloned
//! onto *both* inputs. Projections are rewritten with the `key` /
//! `payload` builtins, which mirror the join's own element-shape handling
//! (`ops::join::key_and_payload`), so the rewrite is exact for every
//! value shape: for any input element `y` and any joined output `o`
//! produced from it, `key(y) = fst(o)` and `payload(y)` is that side's
//! payload — the pushed predicate accepts `y` iff the original accepted
//! every `o` derived from it. Equi-join keys match across sides, so
//! filtering one side on a key predicate already filters the output
//! exactly; filtering both sides just drops dead probe/build work.
//!
//! Rust-builder UDFs are opaque closures (`expr == None`) and are never
//! pushed through joins/aggregations; the `distinct` rewrite needs no
//! expression (the predicate moves verbatim) and fires for both
//! frontends.
//!
//! Pushing is *speculative evaluation*: below the join, the predicate
//! runs on input elements that would never have produced a join output
//! (non-matching keys), so it must not be able to fail on them.
//! Division/remainder with a non-literal divisor and the partial
//! builtins — `nth`, `int`, `field`, and `fst`/`snd`/`len` applied to
//! anything but the recognized projections — are therefore rejected
//! (`x / snd(snd(p))` could divide by zero, `fst(snd(snd(p)))` could hit
//! a non-pair payload, on an element the original program never
//! touched); beyond that, predicates are assumed total over their
//! side's element domain — the same contract
//! [`super::analysis::is_hoistable_op`] states for hoisted UDFs.
//!
//! Rewrites only fire when the filter sits in the *same basic block* as
//! its producer and is the producer's only consumer — same block keeps
//! the §6.3.3 input-bag selection of every downstream consumer literally
//! identical after the filter node is deleted, and sole-consumership
//! keeps the producer's (now filtered) output unobserved by anyone else.

use super::analysis::PlanAnalysis;
use super::{compact, refresh_edges, Pass, PassOutcome};
use crate::dataflow::{DataflowGraph, InputSpec, Node, NodeId, Route};
use crate::error::Result;
use crate::frontend::ast::{BinOp, Expr};
use crate::frontend::{interp_expr, Rhs, Udf1};

/// The predicate-pushdown pass.
pub struct PushdownPass;

/// Which projection of the joined pair a parameter use reads.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Proj {
    /// `fst(p)` — the join key.
    Key,
    /// `fst(snd(p))` — the left payload.
    Left,
    /// `snd(snd(p))` — the right payload.
    Right,
}

/// Match `e` as one of the recognized projections of `param`. `key(p)`
/// counts as a key projection too — on a join output, `key` is exactly
/// `fst` — which is what lets a key predicate this pass itself pushed
/// cascade through the next join upstream. (`payload(p)` of a join
/// output is the whole `(left, right)` pair, so it deliberately stays
/// unrecognized and classifies as a whole-element use.)
fn as_proj(e: &Expr, param: &str) -> Option<Proj> {
    let Expr::Call(f, args) = e else { return None };
    if args.len() != 1 {
        return None;
    }
    match (f.as_str(), &args[0]) {
        ("fst", Expr::Var(v)) | ("key", Expr::Var(v)) if v == param => Some(Proj::Key),
        ("fst", Expr::Call(g, inner)) if g == "snd" && inner.len() == 1 => match &inner[0] {
            Expr::Var(v) if v == param => Some(Proj::Left),
            _ => None,
        },
        ("snd", Expr::Call(g, inner)) if g == "snd" && inner.len() == 1 => match &inner[0] {
            Expr::Var(v) if v == param => Some(Proj::Right),
            _ => None,
        },
        _ => None,
    }
}

/// Collected parameter uses of a predicate body.
#[derive(Default)]
struct Uses {
    key: bool,
    left: bool,
    right: bool,
    /// The parameter escapes the recognized projections.
    whole: bool,
}

/// Reject predicates whose evaluation can fail *by value or shape* on
/// elements the original program never evaluated them on (see the module
/// docs): division/remainder is allowed only with a non-zero literal
/// divisor, and the partial builtins — `nth` (index range), `int`
/// (parse), `field` (missing field), plus `fst`/`snd`/`len` on anything
/// *other than* a recognized param projection (which the rewrite turns
/// into the shape-total `key`/`payload`) — are rejected: a non-matching
/// element may carry a payload shape the surviving elements never have.
/// Plain arithmetic/comparison stays under the documented
/// totality-over-the-domain assumption.
fn is_push_total(e: &Expr, param: &str) -> bool {
    if as_proj(e, param).is_some() {
        return true; // rewritten to key()/payload(): total for any shape
    }
    match e {
        Expr::Bin(op, l, r) => {
            let divisor_ok = match op {
                BinOp::Div | BinOp::Rem => {
                    matches!(**r, Expr::Int(n) if n != 0)
                        || matches!(**r, Expr::Float(f) if f != 0.0)
                }
                _ => true,
            };
            divisor_ok && is_push_total(l, param) && is_push_total(r, param)
        }
        Expr::Un(_, x) => is_push_total(x, param),
        Expr::Call(f, args) => {
            !matches!(f.as_str(), "nth" | "int" | "field" | "fst" | "snd" | "len")
                && args.iter().all(|a| is_push_total(a, param))
        }
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) | Expr::Var(_) => true,
        Expr::Method(..) | Expr::Lambda(..) => false,
    }
}

fn scan(e: &Expr, param: &str, uses: &mut Uses) {
    if let Some(p) = as_proj(e, param) {
        match p {
            Proj::Key => uses.key = true,
            Proj::Left => uses.left = true,
            Proj::Right => uses.right = true,
        }
        return;
    }
    match e {
        Expr::Var(v) => {
            if v == param {
                uses.whole = true;
            }
        }
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) => {}
        Expr::Un(_, x) => scan(x, param, uses),
        Expr::Bin(_, l, r) => {
            scan(l, param, uses);
            scan(r, param, uses);
        }
        Expr::Call(_, args) => {
            for a in args {
                scan(a, param, uses);
            }
        }
        // check_closed rejects these inside lambdas; treat defensively.
        Expr::Method(..) | Expr::Lambda(..) => uses.whole = true,
    }
}

/// Rewrite the predicate body for evaluation against one side's elements:
/// `fst(p) → key(p)` and the target side's payload projection →
/// `payload(p)`. Callers guarantee (via `scan`) that no other parameter
/// uses exist.
fn rewrite(e: &Expr, param: &str, target: Proj) -> Expr {
    if let Some(p) = as_proj(e, param) {
        if p == Proj::Key {
            return Expr::Call("key".into(), vec![Expr::Var(param.to_string())]);
        }
        if p == target {
            return Expr::Call("payload".into(), vec![Expr::Var(param.to_string())]);
        }
    }
    match e {
        Expr::Un(op, x) => Expr::Un(*op, Box::new(rewrite(x, param, target))),
        Expr::Bin(op, l, r) => Expr::Bin(
            *op,
            Box::new(rewrite(l, param, target)),
            Box::new(rewrite(r, param, target)),
        ),
        Expr::Call(f, args) => Expr::Call(
            f.clone(),
            args.iter().map(|a| rewrite(a, param, target)).collect(),
        ),
        other => other.clone(),
    }
}

/// One applicable rewrite, located against current node ids.
struct Found {
    /// The filter node to eliminate.
    filter: NodeId,
    /// Its producer (join / reduceByKey / distinct).
    producer: NodeId,
    /// Input indices of `producer` to interpose a pushed filter on, with
    /// the UDF for each.
    pushes: Vec<(usize, Udf1)>,
}

fn find(g: &DataflowGraph) -> Option<Found> {
    for f in &g.nodes {
        let Rhs::Filter { udf, .. } = &f.op else { continue };
        if f.cond.is_some() || f.inputs.len() != 1 || f.inputs[0].conditional {
            continue;
        }
        let up = f.inputs[0].src;
        let producer = &g.nodes[up];
        if producer.block != f.block || producer.cond.is_some() {
            continue;
        }
        if g.consumers(up).len() != 1 {
            continue; // someone else observes the unfiltered output
        }
        let pushes: Vec<(usize, Udf1)> = match &producer.op {
            Rhs::Distinct { .. } => {
                // Dedup commutes with any element predicate — move it
                // verbatim (works for opaque builder closures too).
                vec![(0, udf.clone())]
            }
            Rhs::Join { .. } | Rhs::ReduceByKey { .. } => {
                let Some(lambda) = &udf.expr else { continue };
                let (params, body) = (&lambda.0, &lambda.1);
                let param = &params[0];
                let mut uses = Uses::default();
                scan(body, param, &mut uses);
                if uses.whole {
                    continue;
                }
                // Below the join/aggregation the predicate evaluates on
                // elements that never produced an output — it must not be
                // able to fail on them.
                if !is_push_total(body, param) {
                    continue;
                }
                let is_join = matches!(producer.op, Rhs::Join { .. });
                let compiled = |target: Proj, tag: &str| -> Option<Udf1> {
                    interp_expr::compile_udf1(
                        params.clone(),
                        rewrite(body, param, target),
                        format!("{}@{tag}", udf.name),
                    )
                    .ok()
                };
                if is_join {
                    match (uses.left, uses.right) {
                        (true, true) => continue, // reads both payloads
                        (true, false) => match compiled(Proj::Left, "left") {
                            Some(u) => vec![(0, u)],
                            None => continue,
                        },
                        (false, true) => match compiled(Proj::Right, "right") {
                            Some(u) => vec![(1, u)],
                            None => continue,
                        },
                        (false, false) => {
                            if !uses.key {
                                // Constant predicate: leave it alone.
                                continue;
                            }
                            // Key-only: clone onto both inputs.
                            match (compiled(Proj::Key, "left"), compiled(Proj::Key, "right")) {
                                (Some(a), Some(b)) => vec![(0, a), (1, b)],
                                _ => continue,
                            }
                        }
                    }
                } else {
                    // reduceByKey: only key predicates survive pushing
                    // below the aggregation (payloads are aggregates).
                    if uses.left || uses.right || !uses.key {
                        continue;
                    }
                    match compiled(Proj::Key, "key") {
                        Some(u) => vec![(0, u)],
                        None => continue,
                    }
                }
            }
            _ => continue,
        };
        return Some(Found { filter: f.id, producer: up, pushes });
    }
    None
}

fn apply(g: &mut DataflowGraph, found: Found, out: &mut PassOutcome) {
    let Found { filter, producer, pushes } = found;
    let mut fresh_var = g.nodes.iter().map(|n| n.var).max().unwrap_or(0);
    let mut detail_sides = Vec::new();

    for (side, udf) in pushes {
        let edge = g.nodes[producer].inputs[side].clone();
        let src = &g.nodes[edge.src];
        let (src_var, src_block, src_par, src_singleton, src_id) =
            (src.var, src.block, src.par, src.singleton, src.id);
        fresh_var += 1;
        let nid = g.nodes.len();
        let name = format!("{}_pd{}", g.nodes[filter].name, side);
        g.nodes.push(Node {
            id: nid,
            name,
            var: fresh_var,
            block: src_block,
            op: Rhs::Filter { input: src_var, udf },
            par: src_par,
            inputs: vec![InputSpec {
                src: src_id,
                src_block,
                route: Route::Forward,
                conditional: false,
            }],
            cond: None,
            singleton: src_singleton,
            hoisted_from: None,
            size_hint: None,
            elem_hint: None,
            build_side: None,
            delta: None,
        });
        g.node_of_var.insert(fresh_var, nid);
        // Re-point the producer's input at the interposed filter. The
        // edge keeps its route (the producer's partitioning requirement
        // did not change); src/src_block/conditional are refreshed.
        let producer_block = g.nodes[producer].block;
        let inp = &mut g.nodes[producer].inputs[side];
        inp.src = nid;
        inp.src_block = src_block;
        inp.conditional = src_block != producer_block;
        match &mut g.nodes[producer].op {
            Rhs::Join { left, right } => {
                if side == 0 {
                    *left = fresh_var;
                } else {
                    *right = fresh_var;
                }
            }
            Rhs::ReduceByKey { input, .. } | Rhs::Distinct { input } => *input = fresh_var,
            other => unreachable!("pushdown producer {}", other.mnemonic()),
        }
        detail_sides.push(side.to_string());
    }

    // Splice the original filter out: its consumers read the (now
    // filtered) producer directly. Same block ⇒ identical §6.3.3 bag
    // selection for every consumer.
    let f_var = g.nodes[filter].var;
    let p_var = g.nodes[producer].var;
    let p_block = g.nodes[producer].block;
    let consumers = g.consumers(filter);
    let mut seen: Vec<NodeId> = Vec::new();
    for (c, k) in consumers {
        let c_block = g.nodes[c].block;
        let inp = &mut g.nodes[c].inputs[k];
        inp.src = producer;
        inp.src_block = p_block;
        inp.conditional = p_block != c_block;
        if !seen.contains(&c) {
            seen.push(c);
            g.nodes[c].op.map_inputs(|v| if v == f_var { p_var } else { v });
        }
    }
    out.details.push(format!(
        "{} [{}] pushed below {} (input {})",
        g.nodes[filter].name,
        g.nodes[filter].op.mnemonic(),
        g.nodes[producer].op.mnemonic(),
        detail_sides.join(","),
    ));
    out.changed += 1;

    let mut keep = vec![true; g.nodes.len()];
    keep[filter] = false;
    compact(g, &keep);
}

impl Pass for PushdownPass {
    fn name(&self) -> &'static str {
        "pushdown"
    }

    fn run(&self, g: &mut DataflowGraph, _a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        // Rewrites cascade (a pushed filter may sit above another join),
        // so fix-point locally; each rewrite deletes one filter node, so
        // the node count bounds the iteration.
        let mut guard = g.nodes.len() + 1;
        while let Some(found) = find(g) {
            apply(g, found, &mut out);
            guard -= 1;
            if guard == 0 {
                break;
            }
        }
        if out.changed > 0 {
            refresh_edges(g);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::single_thread;
    use crate::exec::{run, ExecConfig};
    use crate::frontend::parse_and_lower;
    use crate::opt::{verify_integrity, OptConfig};
    use crate::value::Value;

    fn pushed(src: &str) -> (DataflowGraph, PassOutcome) {
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let out = PushdownPass.run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        (g, out)
    }

    fn check_matches_oracle(src: &str) {
        let program = parse_and_lower(src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (g, out) = {
            let (mut g, _) = crate::compile_with(&program, &OptConfig::none()).unwrap();
            let a = PlanAnalysis::compute(&g);
            let out = PushdownPass.run(&mut g, &a).unwrap();
            (g, out)
        };
        assert!(out.changed > 0, "pushdown should fire on:\n{src}");
        let res = run(&g, &ExecConfig::default()).unwrap();
        let mut got = res.collected("f").to_vec();
        let mut want = oracle.collected("f").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want, "{src}");
    }

    #[test]
    fn probe_side_predicate_moves_below_join() {
        // a.join(b): b is the build (left) side, a the probe (right).
        // The predicate reads only the probe payload.
        let (g, out) = pushed(
            "a = bag(1, 2, 3, 4).map(|v| pair(v % 2, v)); b = bag(1, 2, 3).map(|v| pair(v % 2, v * 10)); j = a.join(b); f = j.filter(|p| snd(snd(p)) > 2); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 1, "{:?}", out.details);
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        // The join's right input is now a filter.
        let right_src = join.inputs[1].src;
        assert!(
            matches!(g.nodes[right_src].op, Rhs::Filter { .. }),
            "right input should be the pushed filter"
        );
        // The collect reads the join directly (original filter removed).
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        assert_eq!(col.inputs[0].src, join.id);
    }

    #[test]
    fn key_only_predicate_moves_to_both_sides() {
        let (g, _) = pushed(
            "a = bag(1, 2, 3, 4).map(|v| pair(v % 2, v)); b = bag(1, 2, 3).map(|v| pair(v % 2, v * 10)); j = a.join(b); f = j.filter(|p| fst(p) == 1); collect(f, \"f\");",
        );
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        for inp in &join.inputs {
            assert!(
                matches!(g.nodes[inp.src].op, Rhs::Filter { .. }),
                "both join inputs should be pushed filters"
            );
        }
    }

    #[test]
    fn whole_element_predicate_stays_put() {
        let (g, out) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2).map(|v| pair(v, v)); j = a.join(b); f = j.filter(|p| hash(snd(p)) % 2 == 0); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        assert!(matches!(g.nodes[col.inputs[0].src].op, Rhs::Filter { .. }));
    }

    #[test]
    fn key_predicate_cascades_through_stacked_joins() {
        // fst(p) == 1 above j2 pushes onto both j2 inputs; the copy that
        // lands above j1 (rewritten to `key(p) == 1`) then pushes again
        // through j1. End state: every join input is a filter (or the
        // inner join), nothing filters above j2.
        let (g, out) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2).map(|v| pair(v, v * 10)); c = bag(1, 2).map(|v| pair(v, v * 100)); j1 = a.join(b); j2 = j1.join(c); f = j2.filter(|p| fst(p) == 1); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 2, "push below j2, then cascade below j1: {:?}", out.details);
        for n in &g.nodes {
            if !matches!(n.op, Rhs::Join { .. }) {
                continue;
            }
            for inp in &n.inputs {
                assert!(
                    matches!(g.nodes[inp.src].op, Rhs::Filter { .. } | Rhs::Join { .. }),
                    "join input should be a pushed filter (or the inner join): {}",
                    g.nodes[inp.src].name
                );
            }
        }
        // Execution still matches the oracle.
        check_matches_oracle(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2).map(|v| pair(v, v * 10)); c = bag(1, 2).map(|v| pair(v, v * 100)); j1 = a.join(b); j2 = j1.join(c); f = j2.filter(|p| fst(p) == 1); collect(f, \"f\");",
        );
    }

    #[test]
    fn nested_projection_into_payload_blocks_pushdown() {
        // `fst(snd(snd(p)))` digs into the probe payload's structure; a
        // non-matching probe element may carry a non-pair payload the
        // original predicate never saw — must stay above the join.
        let (_, out) = pushed(
            "x = bag(1).map(|v| pair(v, pair(v, v))); y = bag(9).map(|v| pair(v, v)); s = x.union(y); a = bag(1).map(|v| pair(v, v)); j = a.join(s); f = j.filter(|p| fst(snd(snd(p))) > 0); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
    }

    #[test]
    fn partial_division_blocks_pushdown() {
        // `10 / snd(snd(p))` can divide by zero on a non-matching probe
        // element the original program never evaluated — must stay put.
        let (_, out) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 9).map(|v| pair(v, v - 1)); j = a.join(b); f = j.filter(|p| 10 / snd(snd(p)) > 1); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
        // Literal divisors are total and still push.
        let (_, out) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 9).map(|v| pair(v, v)); j = a.join(b); f = j.filter(|p| snd(snd(p)) % 2 == 0); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 1, "{:?}", out.details);
    }

    #[test]
    fn shared_join_output_blocks_pushdown() {
        // The join has a second consumer — pushing would filter its view.
        let (_, out) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2).map(|v| pair(v, v)); j = a.join(b); f = j.filter(|p| fst(p) == 1); collect(f, \"f\"); collect(j, \"j\");",
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
    }

    #[test]
    fn key_predicate_moves_below_reduce_by_key() {
        let (g, out) = pushed(
            "a = bag(1, 2, 3, 4, 5, 6).map(|v| pair(v % 3, v)); r = a.reduceByKey(|x, y| x + y); f = r.filter(|p| fst(p) != 0); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 1, "{:?}", out.details);
        let rbk = g.nodes.iter().find(|n| matches!(n.op, Rhs::ReduceByKey { .. })).unwrap();
        assert!(matches!(g.nodes[rbk.inputs[0].src].op, Rhs::Filter { .. }));
    }

    #[test]
    fn any_predicate_moves_below_distinct() {
        let (g, out) = pushed(
            "a = bag(1, 1, 2, 3, 3, 4); d = a.distinct(); f = d.filter(|v| v > 1); collect(f, \"f\");",
        );
        assert_eq!(out.changed, 1, "{:?}", out.details);
        let d = g.nodes.iter().find(|n| matches!(n.op, Rhs::Distinct { .. })).unwrap();
        assert!(matches!(g.nodes[d.inputs[0].src].op, Rhs::Filter { .. }));
    }

    #[test]
    fn pushed_plans_match_the_oracle() {
        for src in [
            "a = bag(1, 2, 3, 4).map(|v| pair(v % 2, v)); b = bag(1, 2, 3).map(|v| pair(v % 2, v * 10)); j = a.join(b); f = j.filter(|p| snd(snd(p)) > 2); collect(f, \"f\");",
            "a = bag(1, 2, 3, 4).map(|v| pair(v % 2, v)); b = bag(1, 2, 3).map(|v| pair(v % 2, v * 10)); j = a.join(b); f = j.filter(|p| fst(snd(p)) >= 10); collect(f, \"f\");",
            "a = bag(1, 2, 3, 4).map(|v| pair(v % 2, v)); b = bag(1, 2, 3).map(|v| pair(v % 2, v * 10)); j = a.join(b); f = j.filter(|p| fst(p) == 1 && snd(snd(p)) > 1); collect(f, \"f\");",
            "a = bag(1, 2, 3, 4, 5, 6).map(|v| pair(v % 3, v)); r = a.reduceByKey(|x, y| x + y); f = r.filter(|p| fst(p) != 0); collect(f, \"f\");",
            "a = bag(1, 1, 2, 3, 3, 4); d = a.distinct(); f = d.filter(|v| v > 1); collect(f, \"f\");",
            // Scalar (non-pair) join elements: `key`/`payload` must match
            // the join's own shape handling.
            "a = bag(1, 2, 3, 5); b = bag(2, 3, 4); j = a.join(b); f = j.filter(|p| fst(p) > 2); collect(f, \"f\");",
        ] {
            check_matches_oracle(src);
        }
    }

    #[test]
    fn pushdown_preserves_loop_program_semantics() {
        // Filter above an in-loop join; the pushed filter lands on the
        // loop-varying probe side inside the loop body.
        let src = r#"
            lookup = bag(0, 1, 2, 3, 4).map(|v| pair(v, v * 100));
            i = 0;
            while (i < 4) {
                kv = bag(3, 4, 5, 6, 7).map(|v| pair((v + i) % 5, v));
                j = kv.join(lookup);
                f = j.filter(|p| snd(snd(p)) % 2 == 1);
                collect(f, "f");
                i = i + 1;
            }
        "#;
        let program = parse_and_lower(src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (mut g, _) = crate::compile_with(&program, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let out = PushdownPass.run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        assert!(out.changed > 0, "{:?}", out.details);
        for workers in [1usize, 3] {
            let res = run(&g, &ExecConfig { workers, ..Default::default() }).unwrap();
            let mut got = res.collected("f").to_vec();
            let mut want = oracle.collected("f").to_vec();
            got.sort();
            want.sort();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn expr_metadata_survives_roundtrip() {
        // The pushed predicate itself carries an expr (compile_udf1
        // attaches it), so cascaded pushes through stacked joins work.
        let (g, _) = pushed(
            "a = bag(1, 2).map(|v| pair(v, v)); b = bag(1, 2).map(|v| pair(v, v)); j = a.join(b); f = j.filter(|p| fst(p) == 1); collect(f, \"f\");",
        );
        let pushed_filter = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Filter { .. }))
            .expect("pushed filter exists");
        let Rhs::Filter { udf, .. } = &pushed_filter.op else { unreachable!() };
        assert!(udf.expr.is_some(), "pushed predicate keeps its lambda expr");
        // key(pair(1, 9)) == 1 → predicate `fst(p) == 1` holds.
        assert_eq!(
            udf.call(&Value::pair(Value::I64(1), Value::I64(9))),
            Value::Bool(true)
        );
    }
}
