//! Cross-loop fusion: collapse the per-step overhead of lifted scalar
//! chains by extending operator fusion ([`super::fuse`]) across the two
//! boundaries it deliberately refuses — **basic blocks** and **condition
//! nodes** — plus eliminating the `⨯`-with-a-literal nodes scalar lifting
//! leaves behind.
//!
//! Scalar lifting (`ssa::lift`) turns every binary scalar op into a
//! *three*-node group: `e = d + 100` becomes `BagLit([100])`, a `Cross`
//! pairing `d` with it, and a `Map` applying the operator to the pair.
//! Imperative control flow then fragments the resulting chains: every
//! `while` splits the surrounding block, compound loop conditions
//! (`while (d * 2 <= 10)`) feed the condition node through such groups,
//! and the plain fuse pass — which requires same-block elementwise edges
//! and never merges into condition nodes — cannot touch any of it. Each
//! surviving node costs a full bag lifecycle (open, close markers to
//! every consumer, coordination messages) per loop step — pure §6.3
//! overhead on the hot control path.
//!
//! Three rewrites:
//!
//! 0. **Literal-cross elimination**: a singleton `Cross` with a
//!    one-element [`Rhs::BagLit`] operand becomes a `Map` over the other
//!    operand whose UDF injects the compile-time constant into the pair
//!    (`|v| pair(v, c)` / `|v| pair(c, v)`). The literal's value is
//!    static, so dropping the edge cannot change what any firing reads;
//!    the orphaned literal is retired here (sole consumer) or by DCE.
//!    This is what turns lifted scalar groups into plain map chains the
//!    fuse passes can see.
//! 1. **Condition folding** (same block): a Map-only singleton chain
//!    that feeds only the loop's condition node merges into it — the
//!    condition node's op becomes [`Rhs::Fused`] and keeps its `cond`
//!    role (the runtime's condition handling keys on `Node::cond`, not
//!    the op type). Filter/flatMap stages are excluded: the condition
//!    bag must stay exactly a singleton boolean.
//! 2. **Cross-block fusion**: a singleton elementwise node `u` whose
//!    only consumer `v` sits in a *different* block fuses into `v` when
//!    the move is provably firing-equivalent (below). The merged node
//!    lives in `v`'s block and reads `u`'s input directly across the
//!    block boundary.
//!
//! **Soundness of the cross-block move.** Fusing `u` into `v` re-targets
//! the edge `src → u` to `src → v`, so the §6.3.3 bag selection must
//! agree: for every firing `t` of `v.block`,
//! `latest_src(t) == latest_src(latest_u(t))`. We require
//! `u.block` **dominates** `v.block` and both share the **same innermost
//! loop context** (equal loop membership, hence equal depth). Under this
//! language's structured CFGs (syntactic `while` nesting — every block
//! occupies one program-order position, loops are single-entry), two
//! same-context blocks with `u.block` dominating fire in lockstep within
//! each context iteration, and `src.block` — which dominates `u.block`
//! because SSA defs dominate their non-Φ uses — cannot fire between
//! `u.block`'s firing and `v.block`'s: re-firing `src.block` within the
//! iteration would need a cycle back through it, i.e. a shared enclosing
//! loop, whose back edge also re-fires `u.block` first. Elementwise ops
//! commute with bag selection (`u_i = f(in_i)` bag-by-bag), so reading
//! `src`'s selected bag and applying the stages in `v.block` yields
//! exactly the bag `v` read before. Shapes this check rejects — and must:
//! an if-branch producer feeding a join-block consumer (`u.block` does
//! not dominate), a loop-body producer read after the loop (exit reads
//! go through Φs, which are never elementwise), and an entry-block chain
//! feeding a loop body (contexts differ — fusing would also re-execute
//! the chain every iteration, a pessimization).
//!
//! All three rewrites count into `opt.cross_loop_fusions`
//! ([`super::ExplainReport::cross_loop_fusions`]). Hoisted nodes never
//! join a chain: merging one downstream would un-hoist it (and a
//! condition tail must never carry `hoisted_from` — integrity forbids
//! it); chains the hoist pass placed in preambles stay put. (Rewrite 0
//! does fold a *hoisted literal* away — its value is compile-time
//! constant, so where it fired never mattered.) Delta-annotated nodes
//! (workset semantics) are excluded throughout.

use super::analysis::PlanAnalysis;
use super::fuse::{elementwise, lineage_of, stages_of};
use super::{compact, refresh_edges, Pass, PassOutcome};
use crate::dataflow::{DataflowGraph, Node, NodeId, Route};
use crate::error::Result;
use crate::frontend::{BlockId, FusedStage, Rhs, Udf1};
use crate::value::Value;

/// The cross-loop fusion pass. Runs right after [`super::fuse::FusePass`]
/// (same `opt.fuse` gate): rewrite 0 exposes map chains, the fuse pass
/// collapses their same-block parts on the next round, and rewrites 1–2
/// merge across the boundaries fuse skips.
pub struct XfusePass;

/// Map-only op: its output bag always has exactly its input's length, so
/// a singleton stays a singleton — the condition-node requirement.
fn map_only(op: &Rhs) -> bool {
    match op {
        Rhs::Map { .. } => true,
        Rhs::Fused { stages, .. } => {
            stages.iter().all(|s| matches!(s, FusedStage::Map(_)))
        }
        _ => false,
    }
}

/// Equal loop membership (and therefore equal nesting depth): the blocks
/// fire the same number of times per enclosing-context iteration.
fn same_loop_context(a: &PlanAnalysis, b1: BlockId, b2: BlockId) -> bool {
    a.loops.depth[b1] == a.loops.depth[b2]
        && a.loops.loops.iter().all(|l| {
            l.body.binary_search(&b1).is_ok() == l.body.binary_search(&b2).is_ok()
        })
}

/// A one-element bag literal whose single `Value` rewrite 0 may bake
/// into a pair-injecting map UDF.
fn foldable_literal(n: &Node) -> Option<&Value> {
    if n.cond.is_some() || n.delta.is_some() {
        return None;
    }
    match &n.op {
        Rhs::BagLit(items) if items.len() == 1 => Some(&items[0]),
        _ => None,
    }
}

impl Pass for XfusePass {
    fn name(&self) -> &'static str {
        "xfuse"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        let n = g.nodes.len();
        let mut removed = vec![false; n];

        // ---- Rewrite 0: literal-cross elimination. ----
        // `a` stays valid across these op swaps: consumer lists, blocks,
        // dominators, and singleton flags are all untouched (a singleton
        // Cross becomes a singleton Map in the same block with the same
        // consumers), so rewrites 1–2 below may run in the same pass
        // invocation and already see the injected maps.
        for k in 0..n {
            let (left, right) = match &g.nodes[k].op {
                Rhs::Cross { left, right } => (*left, *right),
                _ => continue,
            };
            let kn = &g.nodes[k];
            if !kn.singleton
                || kn.cond.is_some()
                || kn.delta.is_some()
                || kn.inputs.len() != 2
                || kn.inputs.iter().any(|e| e.route != Route::Forward)
            {
                continue;
            }
            let (li, ri) = (kn.inputs[0].src, kn.inputs[1].src);
            // Prefer folding the right operand, so `c ⊕ c` (both sides
            // the same literal node) keeps its left edge intact.
            let (lit_id, lit_is_right, keep_var, keep_idx) =
                if foldable_literal(&g.nodes[ri]).is_some() {
                    (ri, true, left, 0)
                } else if foldable_literal(&g.nodes[li]).is_some() {
                    (li, false, right, 1)
                } else {
                    continue;
                };
            let c = foldable_literal(&g.nodes[lit_id]).expect("just matched").clone();
            let udf_name = format!("inject<{}>", g.nodes[lit_id].name);
            let udf = if lit_is_right {
                Udf1::new(udf_name, move |v: &Value| Value::pair(v.clone(), c.clone()))
            } else {
                Udf1::new(udf_name, move |v: &Value| Value::pair(c.clone(), v.clone()))
            };
            out.details.push(format!(
                "{} (bb{}): literal {} folded out of cross (pair-inject map)",
                g.nodes[k].name, g.nodes[k].block, g.nodes[lit_id].name
            ));
            let keep_edge = g.nodes[k].inputs[keep_idx].clone();
            let t = &mut g.nodes[k];
            t.op = Rhs::Map { input: keep_var, udf };
            t.inputs = vec![keep_edge];
            out.changed += 1;
            if a.consumers[lit_id].len() == 1 {
                removed[lit_id] = true; // this cross was its sole consumer
            }
        }

        // ---- Rewrites 1 + 2: chain folding across fuse's boundaries. ----
        for v_id in 0..n {
            if removed[v_id] {
                continue;
            }
            let (vb, cond_tail) = {
                let vn = &g.nodes[v_id];
                let cond_tail = vn.cond.is_some();
                let tail_ok = vn.singleton
                    && vn.delta.is_none()
                    && vn.hoisted_from.is_none()
                    && vn.inputs.len() == 1
                    && if cond_tail {
                        // Rewrite 1 tail: the condition node itself, when
                        // its op is map-shaped (a singleton-preserving
                        // transform the fused chain can legally end in).
                        map_only(&vn.op)
                    } else {
                        elementwise(vn)
                    };
                if !tail_ok {
                    continue;
                }
                (vn.block, cond_tail)
            };
            // Walk upstream from the tail, collecting mergeable producers
            // (nearest first). Condition folding takes same-block,
            // map-only hops (possibly several: rewrite 0 may have just
            // exposed a whole injected-map chain this same run).
            // Cross-block fusion may also take several hops (one per
            // block boundary), each independently proven against the
            // tail's block.
            let mut ups: Vec<NodeId> = Vec::new();
            let mut cur = v_id;
            loop {
                let e = &g.nodes[cur].inputs[0];
                let u = &g.nodes[e.src];
                if removed[u.id]
                    || !elementwise(u)
                    || !u.singleton
                    || u.hoisted_from.is_some()
                    || u.delta.is_some()
                    || a.consumers[u.id].len() != 1
                    || e.route != Route::Forward
                {
                    break;
                }
                let hop_ok = if cond_tail {
                    !e.conditional && map_only(&u.op)
                } else {
                    e.conditional
                        && a.dom.dominates(u.block, vb)
                        && same_loop_context(a, u.block, vb)
                };
                if !hop_ok {
                    break;
                }
                ups.push(u.id);
                cur = u.id;
            }
            if ups.is_empty() {
                continue;
            }
            // Tail replacement, exactly like the fuse pass — except the
            // tail keeps its own block (the whole point) and NEVER
            // inherits `hoisted_from` (heads with it are excluded above,
            // and a condition tail must never carry it).
            let chain: Vec<NodeId> =
                ups.iter().rev().copied().chain(std::iter::once(v_id)).collect();
            let stages: Vec<FusedStage> =
                chain.iter().flat_map(|&id| stages_of(&g.nodes[id].op)).collect();
            let lineage: Vec<String> =
                chain.iter().flat_map(|&id| lineage_of(&g.nodes[id])).collect();
            debug_assert_eq!(stages.len(), lineage.len());
            let head_id = chain[0];
            let input_var = g.nodes[head_id].op.input_vars()[0];
            let head_inputs = g.nodes[head_id].inputs.clone();
            out.details.push(format!(
                "{} ({}, {} stages): {}",
                g.nodes[v_id].name,
                if cond_tail { "into cond".to_string() } else { format!("into bb{vb}") },
                stages.len(),
                chain
                    .iter()
                    .map(|&id| format!("{}@bb{}", g.nodes[id].name, g.nodes[id].block))
                    .collect::<Vec<_>>()
                    .join(" -> ")
            ));
            let t = &mut g.nodes[v_id];
            t.op = Rhs::Fused { input: input_var, stages, lineage };
            t.inputs = head_inputs;
            for &id in &chain[..chain.len() - 1] {
                removed[id] = true;
                out.changed += 1;
            }
        }

        if out.changed > 0 {
            let keep: Vec<bool> = removed.iter().map(|&r| !r).collect();
            compact(g, &keep);
            // Moved head edges now terminate in the tail's block:
            // recompute every edge's src_block/conditional flags.
            refresh_edges(g);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::opt::fuse::FusePass;
    use crate::opt::{verify_integrity, OptConfig};

    /// Model the real pass-manager rounds for the fusion pair: fuse then
    /// xfuse, fresh analysis before each, until neither changes anything.
    /// Returns the xfuse outcomes summed.
    fn xfused(src: &str) -> (DataflowGraph, PassOutcome) {
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let mut total = PassOutcome::default();
        for _ in 0..4 {
            let a = PlanAnalysis::compute(&g);
            let f = FusePass.run(&mut g, &a).unwrap();
            verify_integrity(&g).unwrap();
            let a = PlanAnalysis::compute(&g);
            let x = XfusePass.run(&mut g, &a).unwrap();
            verify_integrity(&g).unwrap();
            total.changed += x.changed;
            total.details.extend(x.details);
            if f.changed + x.changed == 0 {
                break;
            }
        }
        (g, total)
    }

    #[test]
    fn literal_cross_elimination_removes_scalar_crosses() {
        let src = "d = 1; e = d + 2; out = bag(7).map(|x| x + e); collect(out, \"out\");";
        let program = parse_and_lower(src).unwrap();
        let oracle =
            crate::baselines::single_thread::run(&program, &Default::default()).unwrap();
        let (g, out) = xfused(src);
        assert!(out.changed > 0, "{:?}", out.details);
        // Every cross here pairs something with a one-element literal
        // (the lifted `+` and the captured-scalar broadcast of `e`), so
        // none survive.
        assert!(
            !g.nodes.iter().any(|n| matches!(n.op, Rhs::Cross { .. })),
            "literal crosses eliminated"
        );
        let run = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        assert_eq!(run.collected("out"), oracle.collected("out"));
    }

    #[test]
    fn compound_condition_chain_folds_into_cond_node() {
        let (g, out) = xfused(
            "d = 1; while (d * 2 <= 10) { d = d + 1; } collect(bag(1), \"x\");",
        );
        assert!(out.changed >= 3, "{:?}", out.details);
        let cond = g
            .nodes
            .iter()
            .find(|n| n.cond.is_some())
            .expect("condition node survives");
        let Rhs::Fused { ref stages, .. } = cond.op else {
            panic!("condition op folded to Fused, got {}", cond.op.mnemonic())
        };
        // inject<2>, lift<*>, inject<10>, lift<<=> — the whole lifted
        // condition expression in one node.
        assert_eq!(stages.len(), 4, "{}", cond.name);
        assert!(stages.iter().all(|s| matches!(s, FusedStage::Map(_))));
        assert!(cond.hoisted_from.is_none(), "cond tail never carries hoisted_from");
        assert!(cond.singleton);
        // Its only input is the loop Φ — zero interior chain nodes left.
        assert!(matches!(g.nodes[cond.inputs[0].src].op, Rhs::Phi(_)));
    }

    #[test]
    fn scalar_chain_fuses_across_a_loop_boundary() {
        // `e` (block after loop 1) feeds only `f` (block after loop 2):
        // same depth-0 context, e's block dominates f's, edge is
        // conditional — the canonical straight-line-code-split-by-loops
        // shape.
        let (g, out) = xfused(
            "d = 1; while (d <= 3) { d = d + 1; } \
             e = d + 100; \
             w = 1; while (w <= 2) { w = w + 1; } \
             f = e * 2; \
             out = bag(0).map(|x| x + f); collect(out, \"out\");",
        );
        assert!(
            out.details.iter().any(|d| d.contains("into bb")),
            "cross-block fusion fired: {:?}",
            out.details
        );
        // The merged node carries both e's and f's stages, reads the loop
        // Φ directly, and stays a plain (non-cond) singleton.
        let fused = g
            .nodes
            .iter()
            .find(|n| match &n.op {
                Rhs::Fused { lineage, .. } => {
                    lineage.iter().any(|l| l.starts_with('e'))
                        && lineage.iter().any(|l| l.starts_with('f'))
                }
                _ => false,
            })
            .expect("cross-block fused node");
        assert!(fused.cond.is_none() && fused.singleton);
        assert!(matches!(g.nodes[fused.inputs[0].src].op, Rhs::Phi(_)));
    }

    #[test]
    fn xfused_scalar_program_matches_oracle() {
        let src = "d = 1; while (d * 3 <= 9) { d = d + 1; } \
                   e = d + 10; \
                   w = 1; while (w <= 2) { w = w + 1; } \
                   f = e * 2; \
                   out = bag(1, 2).map(|x| x + f); collect(out, \"out\");";
        let program = parse_and_lower(src).unwrap();
        let oracle =
            crate::baselines::single_thread::run(&program, &Default::default()).unwrap();
        let (g, out) = xfused(src);
        assert!(out.changed > 0, "premise: xfuse fired");
        let run = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = run.collected("out").to_vec();
        let mut want = oracle.collected("out").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn entry_chain_never_fuses_into_a_loop_body() {
        // `k` (entry block) feeds the body's update chain: merging it
        // inward would recompute it per iteration AND change contexts —
        // the same_loop_context gate must reject it. (It may still fuse
        // with itself inside the entry block.)
        let (g, _) = xfused(
            "k = 5 * 3; d = 1; while (d <= 3) { d = d + k; } collect(bag(1), \"x\");",
        );
        for n in &g.nodes {
            if let Rhs::Fused { ref lineage, .. } = n.op {
                let has_k = lineage.iter().any(|l| l.starts_with('k'));
                let has_d = lineage.iter().any(|l| l.starts_with('d'));
                assert!(
                    !(has_k && has_d),
                    "entry chain `k` fused into the loop's `d` chain at {}",
                    n.name
                );
            }
        }
    }

    #[test]
    fn xfuse_is_idempotent() {
        let src = "d = 1; while (d * 2 <= 10) { d = d + 1; } \
                   e = d + 1; \
                   w = 1; while (w <= 2) { w = w + 1; } \
                   f = e * 2; out = bag(0).map(|x| x + f); collect(out, \"out\");";
        let (mut g, total) = xfused(src);
        assert!(total.changed > 0);
        let a = PlanAnalysis::compute(&g);
        let again = XfusePass.run(&mut g, &a).unwrap();
        assert_eq!(again.changed, 0, "{:?}", again.details);
        let a2 = PlanAnalysis::compute(&g);
        let fuse_again = FusePass.run(&mut g, &a2).unwrap();
        assert_eq!(fuse_again.changed, 0, "{:?}", fuse_again.details);
    }

    #[test]
    fn default_pipeline_reports_cross_loop_fusions() {
        let p = parse_and_lower(
            "d = 1; while (d * 2 <= 10) { d = d + 1; } collect(bag(1), \"x\");",
        )
        .unwrap();
        let (g, rep) = crate::compile_with(&p, &OptConfig::default()).unwrap();
        assert!(rep.cross_loop_fusions > 0, "{}", rep.render());
        assert!(g
            .opt_summary
            .iter()
            .any(|(k, v)| k == "opt.cross_loop_fusions" && *v > 0));
        verify_integrity(&g).unwrap();
    }
}
