//! Per-edge element-type inference + typed UDF compilation (`opt.columnar`).
//!
//! The dynamic engine moves uniform [`Value`]s; every hot kernel pays an
//! enum dispatch per element. This module is the static side of the typed
//! columnar plane (`docs/columnar.md`): it derives an [`ElemType`] for
//! every dataflow edge and compiles LabyLang lambdas whose shapes it can
//! prove into monomorphic scalar programs that run over raw `i64`/`f64`
//! lanes of a [`crate::bag::ColumnBatch`] — no `Value` allocation, no
//! parameter-name lookups, no per-element dispatch.
//!
//! **Inference** ([`infer`]) is a forward fixpoint over the dataflow
//! graph: sources contribute sampled hints (`Node::elem_hint`),
//! `readFile` is `Str`, operators transfer types per their signatures
//! (`count → I64`, `join → pair(k, pair(l, r))`, `filter` preserves, …),
//! `map` consults the compiled form of its UDF, and Φ-nodes join their
//! arms — optimistically across back-edges, so loop-carried bags keep
//! their type when every arm agrees. `Dyn` is the lattice top.
//!
//! **Compilation** mirrors `frontend::interp_expr` *exactly* — including
//! its warts: `+` on two statically-`I64` operands is integer addition,
//! mixed `I64`/`F64` arithmetic widens to `f64`, floats compare under the
//! IEEE total order (`NaN == NaN`, `0.0 != -0.0` — the same bit trick as
//! `Value`'s `Ord`), `&&`/`||` evaluate both sides. Anything the compiler
//! cannot prove equivalent (strings, mixed-type comparisons, which
//! rank-compare in the interpreter, exotic builtins) returns `None` and
//! the kernel keeps the dynamic path. Inference is optimistic end to end:
//! typed kernels re-verify every batch they decode
//! ([`crate::bag::ColumnBatch::from_values`]), so a wrong type here can
//! cost performance but never correctness.

use crate::bag::ColumnBatch;
use crate::dataflow::{DataflowGraph, Node};
use crate::error::Result;
use crate::frontend::ast::{BinOp, Expr, UnOp};
use crate::frontend::{FusedStage, Rhs, Udf1, Udf2};
use crate::value::{ElemType, Value};
use std::cmp::Ordering;

/// Policy for the typed columnar plane (config key `opt.columnar`, CLI
/// `--no-columnar`, env default `LABY_COLUMNAR`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnarGate {
    /// Typed kernels on batched channels (default): columnar decode/encode
    /// amortizes over a batch, so element-at-a-time channels (batch 1)
    /// stay on the dynamic path.
    Auto,
    /// Typed kernels wherever the inferred type allows, even at batch 1
    /// (differential tests force this to cover the conversion boundary).
    Always,
    /// Dynamic `Value` path everywhere.
    Never,
}

impl ColumnarGate {
    /// Parse a config/CLI/env value.
    pub fn parse(s: &str) -> Result<ColumnarGate> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(ColumnarGate::Auto),
            "always" => Ok(ColumnarGate::Always),
            "never" => Ok(ColumnarGate::Never),
            other => Err(crate::Error::Config(format!(
                "opt.columnar: expected auto|always|never, got {other:?}"
            ))),
        }
    }

    /// The process-wide default: `LABY_COLUMNAR` if set (invalid values
    /// fall back with a warning — a bad env var must not fail every
    /// compile), else [`ColumnarGate::Auto`]. Read once.
    pub fn default_from_env() -> ColumnarGate {
        static GATE: std::sync::OnceLock<ColumnarGate> = std::sync::OnceLock::new();
        *GATE.get_or_init(|| match std::env::var("LABY_COLUMNAR") {
            Err(_) => ColumnarGate::Auto,
            Ok(s) => ColumnarGate::parse(&s).unwrap_or_else(|e| {
                eprintln!("warning: LABY_COLUMNAR ignored: {e}");
                ColumnarGate::Auto
            }),
        })
    }

    /// Should typed kernels be installed for channel batch size `batch`?
    pub fn enabled(&self, batch: usize) -> bool {
        match self {
            ColumnarGate::Always => true,
            ColumnarGate::Never => false,
            ColumnarGate::Auto => batch > 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Typed scalar programs
// ---------------------------------------------------------------------------

/// Slot environment a compiled expression reads its parameters from.
/// Kernels fill only the slots the input layout defines: scalar inputs
/// load component 0 of their lane (`i[0]`/`f[0]`/`b[0]`), pair inputs
/// load the key into `i[0]` and the payload into component 1, and
/// two-parameter combiners load `a` into component 0 and `b` into
/// component 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct Slots {
    /// `i64` parameter lanes.
    pub i: [i64; 2],
    /// `f64` parameter lanes.
    pub f: [f64; 2],
    /// `bool` parameter lanes.
    pub b: [bool; 2],
}

/// Comparison operator of a compiled predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
}

impl CmpOp {
    fn of(op: BinOp) -> Option<CmpOp> {
        match op {
            BinOp::Eq => Some(CmpOp::Eq),
            BinOp::Ne => Some(CmpOp::Ne),
            BinOp::Lt => Some(CmpOp::Lt),
            BinOp::Le => Some(CmpOp::Le),
            BinOp::Gt => Some(CmpOp::Gt),
            BinOp::Ge => Some(CmpOp::Ge),
            _ => None,
        }
    }

    fn apply(self, o: Ordering) -> bool {
        match self {
            CmpOp::Eq => o == Ordering::Equal,
            CmpOp::Ne => o != Ordering::Equal,
            CmpOp::Lt => o == Ordering::Less,
            CmpOp::Le => o != Ordering::Greater,
            CmpOp::Gt => o == Ordering::Greater,
            CmpOp::Ge => o != Ordering::Less,
        }
    }
}

/// An `i64`-typed compiled expression.
#[derive(Clone, Debug)]
pub enum EI {
    /// Integer literal.
    Const(i64),
    /// Parameter lane `i[n]`.
    Var(u8),
    /// `a + b` (same overflow behavior as the interpreter's plain `+`).
    Add(Box<EI>, Box<EI>),
    /// `a - b`.
    Sub(Box<EI>, Box<EI>),
    /// `a * b`.
    Mul(Box<EI>, Box<EI>),
    /// `a / b` (panics on zero, like the interpreter).
    Div(Box<EI>, Box<EI>),
    /// `a % b`.
    Rem(Box<EI>, Box<EI>),
    /// `-a`.
    Neg(Box<EI>),
    /// `abs(a)`.
    Abs(Box<EI>),
    /// `min(a, b)`.
    Min(Box<EI>, Box<EI>),
    /// `max(a, b)`.
    Max(Box<EI>, Box<EI>),
    /// `int(f)` — truncating cast, the interpreter's `F64 → I64` rule.
    Trunc(Box<EF>),
}

/// An `f64`-typed compiled expression.
#[derive(Clone, Debug)]
pub enum EF {
    /// Float literal.
    Const(f64),
    /// Parameter lane `f[n]`.
    Var(u8),
    /// `a + b`.
    Add(Box<EF>, Box<EF>),
    /// `a - b`.
    Sub(Box<EF>, Box<EF>),
    /// `a * b`.
    Mul(Box<EF>, Box<EF>),
    /// `a / b`.
    Div(Box<EF>, Box<EF>),
    /// `-a`.
    Neg(Box<EF>),
    /// `abs(a)`.
    Abs(Box<EF>),
    /// `min(a, b)` under the IEEE total order (the interpreter compares
    /// `Value`s, which order floats by the total-order bit trick).
    Min(Box<EF>, Box<EF>),
    /// `max(a, b)` under the IEEE total order.
    Max(Box<EF>, Box<EF>),
    /// `float(i)` / implicit widening of a mixed-arithmetic operand.
    FromI(Box<EI>),
}

/// A `bool`-typed compiled expression.
#[derive(Clone, Debug)]
pub enum EB {
    /// Boolean literal.
    Const(bool),
    /// Parameter lane `b[n]`.
    Var(u8),
    /// `!a`.
    Not(Box<EB>),
    /// `a && b` — STRICT, both sides evaluate (interpreter semantics).
    And(Box<EB>, Box<EB>),
    /// `a || b` — strict.
    Or(Box<EB>, Box<EB>),
    /// Integer comparison.
    CmpI(CmpOp, Box<EI>, Box<EI>),
    /// Float comparison under the IEEE total order: `NaN == NaN` holds and
    /// `0.0 == -0.0` does NOT — exactly `Value`'s `Ord`, deliberately not
    /// IEEE `==`.
    CmpF(CmpOp, Box<EF>, Box<EF>),
    /// Boolean comparison (`false < true`).
    CmpB(CmpOp, Box<EB>, Box<EB>),
}

impl EI {
    /// Evaluate against a slot environment.
    pub fn eval(&self, s: &Slots) -> i64 {
        match self {
            EI::Const(v) => *v,
            EI::Var(n) => s.i[*n as usize],
            EI::Add(a, b) => a.eval(s) + b.eval(s),
            EI::Sub(a, b) => a.eval(s) - b.eval(s),
            EI::Mul(a, b) => a.eval(s) * b.eval(s),
            EI::Div(a, b) => a.eval(s) / b.eval(s),
            EI::Rem(a, b) => a.eval(s) % b.eval(s),
            EI::Neg(a) => -a.eval(s),
            EI::Abs(a) => a.eval(s).abs(),
            EI::Min(a, b) => a.eval(s).min(b.eval(s)),
            EI::Max(a, b) => a.eval(s).max(b.eval(s)),
            EI::Trunc(f) => f.eval(s) as i64,
        }
    }
}

impl EF {
    /// Evaluate against a slot environment.
    pub fn eval(&self, s: &Slots) -> f64 {
        match self {
            EF::Const(v) => *v,
            EF::Var(n) => s.f[*n as usize],
            EF::Add(a, b) => a.eval(s) + b.eval(s),
            EF::Sub(a, b) => a.eval(s) - b.eval(s),
            EF::Mul(a, b) => a.eval(s) * b.eval(s),
            EF::Div(a, b) => a.eval(s) / b.eval(s),
            EF::Neg(a) => -a.eval(s),
            EF::Abs(a) => a.eval(s).abs(),
            // `min(a, b)` in the interpreter is `if a <= b { a } else { b }`
            // over `Value`s, i.e. total order — NOT f64::min NaN handling.
            EF::Min(a, b) => {
                let (x, y) = (a.eval(s), b.eval(s));
                if x.total_cmp(&y) != Ordering::Greater { x } else { y }
            }
            EF::Max(a, b) => {
                let (x, y) = (a.eval(s), b.eval(s));
                if x.total_cmp(&y) != Ordering::Less { x } else { y }
            }
            EF::FromI(a) => a.eval(s) as f64,
        }
    }
}

impl EB {
    /// Evaluate against a slot environment.
    pub fn eval(&self, s: &Slots) -> bool {
        match self {
            EB::Const(v) => *v,
            EB::Var(n) => s.b[*n as usize],
            EB::Not(a) => !a.eval(s),
            // Strict: evaluate both sides (a panicking RHS must panic here
            // exactly as it does in the interpreter).
            EB::And(a, b) => {
                let (x, y) = (a.eval(s), b.eval(s));
                x && y
            }
            EB::Or(a, b) => {
                let (x, y) = (a.eval(s), b.eval(s));
                x || y
            }
            EB::CmpI(c, a, b) => c.apply(a.eval(s).cmp(&b.eval(s))),
            EB::CmpF(c, a, b) => c.apply(a.eval(s).total_cmp(&b.eval(s))),
            EB::CmpB(c, a, b) => c.apply(a.eval(s).cmp(&b.eval(s))),
        }
    }
}

/// A compiled scalar expression, tagged by its static type.
#[derive(Clone, Debug)]
pub enum ScalarExpr {
    /// Produces `i64`.
    I(EI),
    /// Produces `f64`.
    F(EF),
    /// Produces `bool`.
    B(EB),
}

/// Output shape of a compiled 1-parameter UDF. Pair outputs are
/// restricted to the SoA layouts [`ColumnBatch`] supports (`i64` key).
#[derive(Clone, Debug)]
pub enum OutShape {
    /// A scalar column.
    Scalar(ScalarExpr),
    /// `pair(i64, i64)` key/value columns.
    PairII(EI, EI),
    /// `pair(i64, f64)` key/value columns.
    PairIF(EI, EF),
}

/// A 1-parameter UDF compiled against a concrete input element type.
/// Produced by [`compile_udf1`]; applied batch-at-a-time by the typed
/// kernels in `ops::`.
#[derive(Clone, Debug)]
pub struct TypedUdf1 {
    in_ty: ElemType,
    shape: OutShape,
}

/// A 2-parameter combiner compiled against a concrete operand type. Only
/// type-preserving combiners compile (`(t, t) → t`) — the accumulator of
/// `reduceByKey`/`reduce` must keep its type across merges.
#[derive(Clone, Debug)]
pub enum TypedUdf2 {
    /// `(i64, i64) → i64`.
    I64(EI),
    /// `(f64, f64) → f64`.
    F64(EF),
}

impl TypedUdf1 {
    /// The input element type this UDF was compiled against.
    pub fn input_type(&self) -> &ElemType {
        &self.in_ty
    }

    /// The statically-known output element type.
    pub fn out_type(&self) -> ElemType {
        match &self.shape {
            OutShape::Scalar(ScalarExpr::I(_)) => ElemType::I64,
            OutShape::Scalar(ScalarExpr::F(_)) => ElemType::F64,
            OutShape::Scalar(ScalarExpr::B(_)) => ElemType::Bool,
            OutShape::PairII(..) => {
                ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::I64))
            }
            OutShape::PairIF(..) => {
                ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::F64))
            }
        }
    }

    /// Whether `batch` has the column layout this UDF's slot loader
    /// expects (the layout of [`Self::input_type`]).
    fn layout_matches(&self, batch: &ColumnBatch) -> bool {
        std::mem::discriminant(batch)
            == std::mem::discriminant(&ColumnBatch::empty_for(&self.in_ty))
            && !matches!(batch, ColumnBatch::Dyn(_))
    }

    /// Map a whole decoded batch through the compiled body. `None` when
    /// the batch's layout does not match the compiled input type (the
    /// caller falls back to the dynamic path).
    pub fn map_batch(&self, input: &ColumnBatch) -> Option<ColumnBatch> {
        if !self.layout_matches(input) {
            return None;
        }
        let n = input.len();
        let mut s = Slots::default();
        Some(match &self.shape {
            OutShape::Scalar(ScalarExpr::I(e)) => {
                let mut out = Vec::with_capacity(n);
                for r in 0..n {
                    load_row(input, r, &mut s);
                    out.push(e.eval(&s));
                }
                ColumnBatch::I64(out)
            }
            OutShape::Scalar(ScalarExpr::F(e)) => {
                let mut out = Vec::with_capacity(n);
                for r in 0..n {
                    load_row(input, r, &mut s);
                    out.push(e.eval(&s));
                }
                ColumnBatch::F64(out)
            }
            OutShape::Scalar(ScalarExpr::B(e)) => {
                let mut out = Vec::with_capacity(n);
                for r in 0..n {
                    load_row(input, r, &mut s);
                    out.push(e.eval(&s));
                }
                ColumnBatch::Bool(out)
            }
            OutShape::PairII(ke, ve) => {
                let (mut k, mut v) = (Vec::with_capacity(n), Vec::with_capacity(n));
                for r in 0..n {
                    load_row(input, r, &mut s);
                    k.push(ke.eval(&s));
                    v.push(ve.eval(&s));
                }
                ColumnBatch::PairII { k, v }
            }
            OutShape::PairIF(ke, ve) => {
                let (mut k, mut v) = (Vec::with_capacity(n), Vec::with_capacity(n));
                for r in 0..n {
                    load_row(input, r, &mut s);
                    k.push(ke.eval(&s));
                    v.push(ve.eval(&s));
                }
                ColumnBatch::PairIF { k, v }
            }
        })
    }

    /// Filter a decoded batch in place (compacting survivors to the
    /// front, preserving order). Returns the surviving count; `None` when
    /// this UDF is not a predicate or the layout does not match.
    pub fn filter_batch(&self, batch: &mut ColumnBatch) -> Option<usize> {
        let OutShape::Scalar(ScalarExpr::B(pred)) = &self.shape else {
            return None;
        };
        if !self.layout_matches(batch) {
            return None;
        }
        let mut s = Slots::default();
        let n = batch.len();
        let mut w = 0;
        // Per-variant compaction keeps parallel columns index-synchronized.
        match batch {
            ColumnBatch::I64(c) => {
                for r in 0..n {
                    s.i[0] = c[r];
                    if pred.eval(&s) {
                        c[w] = c[r];
                        w += 1;
                    }
                }
                c.truncate(w);
            }
            ColumnBatch::F64(c) => {
                for r in 0..n {
                    s.f[0] = c[r];
                    if pred.eval(&s) {
                        c[w] = c[r];
                        w += 1;
                    }
                }
                c.truncate(w);
            }
            ColumnBatch::Bool(c) => {
                for r in 0..n {
                    s.b[0] = c[r];
                    if pred.eval(&s) {
                        c[w] = c[r];
                        w += 1;
                    }
                }
                c.truncate(w);
            }
            ColumnBatch::PairII { k, v } => {
                for r in 0..n {
                    s.i[0] = k[r];
                    s.i[1] = v[r];
                    if pred.eval(&s) {
                        k[w] = k[r];
                        v[w] = v[r];
                        w += 1;
                    }
                }
                k.truncate(w);
                v.truncate(w);
            }
            ColumnBatch::PairIF { k, v } => {
                for r in 0..n {
                    s.i[0] = k[r];
                    s.f[1] = v[r];
                    if pred.eval(&s) {
                        k[w] = k[r];
                        v[w] = v[r];
                        w += 1;
                    }
                }
                k.truncate(w);
                v.truncate(w);
            }
            ColumnBatch::Dyn(_) => return None,
        }
        Some(w)
    }

    /// Selection-bitmap filter: evaluate the predicate over only the rows
    /// `mask` still selects, clearing the bits of rows it rejects —
    /// **no data movement**. Interior filters of a fused typed chain use
    /// this instead of [`Self::filter_batch`]; survivors are moved once,
    /// by [`ColumnBatch::compact`] at chain emission, however many filter
    /// stages the chain holds. Returns the surviving (selected) count;
    /// `None` when this UDF is not a predicate or the layout mismatches
    /// (the caller falls back to the dynamic path).
    ///
    /// `mask.len()` must equal `batch.len()`.
    pub fn filter_mask(&self, batch: &ColumnBatch, mask: &mut [bool]) -> Option<usize> {
        let OutShape::Scalar(ScalarExpr::B(pred)) = &self.shape else {
            return None;
        };
        if !self.layout_matches(batch) {
            return None;
        }
        debug_assert_eq!(mask.len(), batch.len(), "mask is row-parallel");
        let mut s = Slots::default();
        let mut kept = 0usize;
        for (r, m) in mask.iter_mut().enumerate() {
            if !*m {
                continue;
            }
            load_row(batch, r, &mut s);
            if pred.eval(&s) {
                kept += 1;
            } else {
                *m = false;
            }
        }
        Some(kept)
    }

    /// Masked map: evaluate the body only on the rows `mask` selects,
    /// writing a placeholder (zero/false) into dead lanes so the output
    /// column stays row-parallel with the mask. Dead lanes are never
    /// observed — downstream masked stages skip them and
    /// [`ColumnBatch::compact`] drops them at emission — so the
    /// placeholder value is irrelevant (it only keeps the lanes
    /// index-aligned without branching the writer). `None` on layout
    /// mismatch.
    pub fn map_batch_masked(
        &self,
        input: &ColumnBatch,
        mask: &[bool],
    ) -> Option<ColumnBatch> {
        if !self.layout_matches(input) {
            return None;
        }
        debug_assert_eq!(mask.len(), input.len(), "mask is row-parallel");
        let n = input.len();
        let mut s = Slots::default();
        Some(match &self.shape {
            OutShape::Scalar(ScalarExpr::I(e)) => {
                let mut out = Vec::with_capacity(n);
                for (r, &m) in mask.iter().enumerate() {
                    out.push(if m {
                        load_row(input, r, &mut s);
                        e.eval(&s)
                    } else {
                        0
                    });
                }
                ColumnBatch::I64(out)
            }
            OutShape::Scalar(ScalarExpr::F(e)) => {
                let mut out = Vec::with_capacity(n);
                for (r, &m) in mask.iter().enumerate() {
                    out.push(if m {
                        load_row(input, r, &mut s);
                        e.eval(&s)
                    } else {
                        0.0
                    });
                }
                ColumnBatch::F64(out)
            }
            OutShape::Scalar(ScalarExpr::B(e)) => {
                let mut out = Vec::with_capacity(n);
                for (r, &m) in mask.iter().enumerate() {
                    out.push(if m {
                        load_row(input, r, &mut s);
                        e.eval(&s)
                    } else {
                        false
                    });
                }
                ColumnBatch::Bool(out)
            }
            OutShape::PairII(ke, ve) => {
                let (mut k, mut v) = (Vec::with_capacity(n), Vec::with_capacity(n));
                for (r, &m) in mask.iter().enumerate() {
                    if m {
                        load_row(input, r, &mut s);
                        k.push(ke.eval(&s));
                        v.push(ve.eval(&s));
                    } else {
                        k.push(0);
                        v.push(0);
                    }
                }
                ColumnBatch::PairII { k, v }
            }
            OutShape::PairIF(ke, ve) => {
                let (mut k, mut v) = (Vec::with_capacity(n), Vec::with_capacity(n));
                for (r, &m) in mask.iter().enumerate() {
                    if m {
                        load_row(input, r, &mut s);
                        k.push(ke.eval(&s));
                        v.push(ve.eval(&s));
                    } else {
                        k.push(0);
                        v.push(0.0);
                    }
                }
                ColumnBatch::PairIF { k, v }
            }
        })
    }
}

/// Fill the parameter slots from row `r` of a decoded batch. The caller
/// guarantees the variant matches the compiled layout (`layout_matches`).
fn load_row(batch: &ColumnBatch, r: usize, s: &mut Slots) {
    match batch {
        ColumnBatch::I64(c) => s.i[0] = c[r],
        ColumnBatch::F64(c) => s.f[0] = c[r],
        ColumnBatch::Bool(c) => s.b[0] = c[r],
        ColumnBatch::PairII { k, v } => {
            s.i[0] = k[r];
            s.i[1] = v[r];
        }
        ColumnBatch::PairIF { k, v } => {
            s.i[0] = k[r];
            s.f[1] = v[r];
        }
        ColumnBatch::Dyn(_) => unreachable!("load_row on Dyn batch"),
    }
}

impl TypedUdf2 {
    /// Combine two dynamic values through the compiled body. `None` when
    /// the runtime variants do not match the compiled operand type — the
    /// caller falls back to `Udf2::call`.
    pub fn combine(&self, a: &Value, b: &Value) -> Option<Value> {
        match (self, a, b) {
            (TypedUdf2::I64(e), Value::I64(x), Value::I64(y)) => {
                let mut s = Slots::default();
                s.i[0] = *x;
                s.i[1] = *y;
                Some(Value::I64(e.eval(&s)))
            }
            (TypedUdf2::F64(e), Value::F64(x), Value::F64(y)) => {
                let mut s = Slots::default();
                s.f[0] = *x;
                s.f[1] = *y;
                Some(Value::F64(e.eval(&s)))
            }
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Compilation
// ---------------------------------------------------------------------------

/// Scalar lane kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sc {
    I,
    F,
    B,
}

fn scalar_sc(t: &ElemType) -> Option<Sc> {
    match t {
        ElemType::I64 => Some(Sc::I),
        ElemType::F64 => Some(Sc::F),
        ElemType::Bool => Some(Sc::B),
        _ => None,
    }
}

/// How a lambda parameter maps onto slot lanes.
#[derive(Clone, Copy, Debug)]
enum ParamShape {
    /// A scalar parameter in lane `(kind, index)`.
    Scalar(Sc, u8),
    /// A pair parameter: key lane + payload lane.
    PairKV(Sc, u8, Sc, u8),
}

struct Cx<'a> {
    params: &'a [String],
    shapes: Vec<ParamShape>,
}

impl Cx<'_> {
    fn lookup(&self, name: &str) -> Option<ParamShape> {
        let i = self.params.iter().position(|p| p == name)?;
        self.shapes.get(i).copied()
    }
}

fn sc_var(sc: Sc, slot: u8) -> ScalarExpr {
    match sc {
        Sc::I => ScalarExpr::I(EI::Var(slot)),
        Sc::F => ScalarExpr::F(EF::Var(slot)),
        Sc::B => ScalarExpr::B(EB::Var(slot)),
    }
}

fn widen_f(e: ScalarExpr) -> Option<EF> {
    match e {
        ScalarExpr::F(e) => Some(e),
        ScalarExpr::I(e) => Some(EF::FromI(Box::new(e))),
        ScalarExpr::B(_) => None,
    }
}

fn bx<T>(v: T) -> Box<T> {
    Box::new(v)
}

/// Compile a closed lambda body to a typed scalar expression; `None`
/// wherever the interpreter's dynamic semantics cannot be reproduced
/// monomorphically (strings, mixed-type comparisons, coercing builtins).
fn compile_scalar(e: &Expr, cx: &Cx) -> Option<ScalarExpr> {
    match e {
        Expr::Int(v) => Some(ScalarExpr::I(EI::Const(*v))),
        Expr::Float(v) => Some(ScalarExpr::F(EF::Const(*v))),
        Expr::Bool(v) => Some(ScalarExpr::B(EB::Const(*v))),
        Expr::Str(_) => None,
        Expr::Var(name) => match cx.lookup(name)? {
            ParamShape::Scalar(sc, slot) => Some(sc_var(sc, slot)),
            // A whole-pair reference is not a scalar (only valid as the
            // identity output shape, handled in `compile_out`).
            ParamShape::PairKV(..) => None,
        },
        Expr::Un(UnOp::Neg, x) => match compile_scalar(x, cx)? {
            ScalarExpr::I(e) => Some(ScalarExpr::I(EI::Neg(bx(e)))),
            ScalarExpr::F(e) => Some(ScalarExpr::F(EF::Neg(bx(e)))),
            ScalarExpr::B(_) => None,
        },
        Expr::Un(UnOp::Not, x) => match compile_scalar(x, cx)? {
            ScalarExpr::B(e) => Some(ScalarExpr::B(EB::Not(bx(e)))),
            _ => None,
        },
        Expr::Bin(op, l, r) => {
            let a = compile_scalar(l, cx)?;
            let b = compile_scalar(r, cx)?;
            compile_bin(*op, a, b)
        }
        Expr::Call(name, args) => compile_call(name, args, cx),
        Expr::Method(..) | Expr::Lambda(..) => None,
    }
}

fn compile_bin(op: BinOp, a: ScalarExpr, b: ScalarExpr) -> Option<ScalarExpr> {
    use BinOp::*;
    match op {
        Add | Sub | Mul | Div => match (a, b) {
            // Both statically I64: plain integer arithmetic (the
            // interpreter's `(I64, I64)` arm).
            (ScalarExpr::I(x), ScalarExpr::I(y)) => {
                let c = match op {
                    Add => EI::Add,
                    Sub => EI::Sub,
                    Mul => EI::Mul,
                    Div => EI::Div,
                    _ => unreachable!(),
                };
                Some(ScalarExpr::I(c(bx(x), bx(y))))
            }
            // Mixed numeric: widen both to f64 (the interpreter's
            // `as_f64` fallback arm). Bool operands would panic there —
            // bail so the dynamic path reproduces the panic.
            (a @ (ScalarExpr::I(_) | ScalarExpr::F(_)), b @ (ScalarExpr::I(_) | ScalarExpr::F(_))) => {
                let x = widen_f(a)?;
                let y = widen_f(b)?;
                let c = match op {
                    Add => EF::Add,
                    Sub => EF::Sub,
                    Mul => EF::Mul,
                    Div => EF::Div,
                    _ => unreachable!(),
                };
                Some(ScalarExpr::F(c(bx(x), bx(y))))
            }
            _ => None,
        },
        // The interpreter coerces via `as_i64` (which maps Bool → 0/1 and
        // panics on F64); only the statically-I64 case is compiled.
        Rem => match (a, b) {
            (ScalarExpr::I(x), ScalarExpr::I(y)) => Some(ScalarExpr::I(EI::Rem(bx(x), bx(y)))),
            _ => None,
        },
        Eq | Ne | Lt | Le | Gt | Ge => {
            let c = CmpOp::of(op)?;
            match (a, b) {
                (ScalarExpr::I(x), ScalarExpr::I(y)) => {
                    Some(ScalarExpr::B(EB::CmpI(c, bx(x), bx(y))))
                }
                (ScalarExpr::F(x), ScalarExpr::F(y)) => {
                    Some(ScalarExpr::B(EB::CmpF(c, bx(x), bx(y))))
                }
                (ScalarExpr::B(x), ScalarExpr::B(y)) => {
                    Some(ScalarExpr::B(EB::CmpB(c, bx(x), bx(y))))
                }
                // Mixed static types compare by discriminant RANK in the
                // `Value` total order (I64 < F64 always) — never compile.
                _ => None,
            }
        }
        And | Or => match (a, b) {
            (ScalarExpr::B(x), ScalarExpr::B(y)) => {
                let c = if op == And { EB::And } else { EB::Or };
                Some(ScalarExpr::B(c(bx(x), bx(y))))
            }
            _ => None,
        },
    }
}

fn compile_call(name: &str, args: &[Expr], cx: &Cx) -> Option<ScalarExpr> {
    match (name, args) {
        // Pair component access, only on a direct parameter reference.
        // `key` on a scalar parameter is the identity (the key of a
        // non-pair is the whole value); `fst`/`snd` on a scalar would
        // panic and `payload` would yield Unit — those bail.
        ("fst" | "key" | "snd" | "payload", [Expr::Var(p)]) => match (name, cx.lookup(p)?) {
            ("fst" | "key", ParamShape::PairKV(ks, ki, _, _)) => Some(sc_var(ks, ki)),
            ("snd" | "payload", ParamShape::PairKV(_, _, vs, vi)) => Some(sc_var(vs, vi)),
            ("key", ParamShape::Scalar(sc, slot)) => Some(sc_var(sc, slot)),
            _ => None,
        },
        ("abs", [x]) => match compile_scalar(x, cx)? {
            ScalarExpr::I(e) => Some(ScalarExpr::I(EI::Abs(bx(e)))),
            ScalarExpr::F(e) => Some(ScalarExpr::F(EF::Abs(bx(e)))),
            ScalarExpr::B(_) => None,
        },
        ("min" | "max", [a, b]) => {
            let a = compile_scalar(a, cx)?;
            let b = compile_scalar(b, cx)?;
            let mx = name == "max";
            match (a, b) {
                (ScalarExpr::I(x), ScalarExpr::I(y)) => {
                    let c = if mx { EI::Max } else { EI::Min };
                    Some(ScalarExpr::I(c(bx(x), bx(y))))
                }
                (ScalarExpr::F(x), ScalarExpr::F(y)) => {
                    let c = if mx { EF::Max } else { EF::Min };
                    Some(ScalarExpr::F(c(bx(x), bx(y))))
                }
                // Mixed operands rank-compare in the interpreter.
                _ => None,
            }
        }
        ("int", [x]) => match compile_scalar(x, cx)? {
            ScalarExpr::I(e) => Some(ScalarExpr::I(e)),
            ScalarExpr::F(e) => Some(ScalarExpr::I(EI::Trunc(bx(e)))),
            ScalarExpr::B(_) => None,
        },
        ("float", [x]) => match compile_scalar(x, cx)? {
            ScalarExpr::I(e) => Some(ScalarExpr::F(EF::FromI(bx(e)))),
            ScalarExpr::F(e) => Some(ScalarExpr::F(e)),
            ScalarExpr::B(_) => None,
        },
        // Everything else (str/hash/field/len/tuple/nth, nested pair) is
        // dynamic-only.
        _ => None,
    }
}

fn shape_of(t: &ElemType) -> Option<ParamShape> {
    match t {
        ElemType::I64 => Some(ParamShape::Scalar(Sc::I, 0)),
        ElemType::F64 => Some(ParamShape::Scalar(Sc::F, 0)),
        ElemType::Bool => Some(ParamShape::Scalar(Sc::B, 0)),
        ElemType::Pair(k, v) => {
            let ks = scalar_sc(k)?;
            let vs = scalar_sc(v)?;
            Some(ParamShape::PairKV(ks, 0, vs, 1))
        }
        _ => None,
    }
}

/// Compile a 1-parameter UDF against a concrete input element type.
/// Requires parser-attached expression metadata (`Udf1::expr`); opaque
/// Rust closures always return `None`.
pub fn compile_udf1(u: &Udf1, in_ty: &ElemType) -> Option<TypedUdf1> {
    let e = u.expr.as_ref()?;
    let (params, body) = (&e.0, &e.1);
    if params.len() != 1 {
        return None;
    }
    let cx = Cx { params, shapes: vec![shape_of(in_ty)?] };
    let shape = compile_out(body, &cx)?;
    Some(TypedUdf1 { in_ty: in_ty.clone(), shape })
}

fn compile_out(body: &Expr, cx: &Cx) -> Option<OutShape> {
    // Top-level `pair(k, v)` builds key/value columns directly; only the
    // SoA-supported layouts (i64 key) compile.
    if let Expr::Call(name, args) = body {
        if name == "pair" && args.len() == 2 {
            let k = compile_scalar(&args[0], cx)?;
            let v = compile_scalar(&args[1], cx)?;
            return match (k, v) {
                (ScalarExpr::I(k), ScalarExpr::I(v)) => Some(OutShape::PairII(k, v)),
                (ScalarExpr::I(k), ScalarExpr::F(v)) => Some(OutShape::PairIF(k, v)),
                _ => None,
            };
        }
    }
    // Identity over a pair parameter re-emits both components.
    if let Expr::Var(name) = body {
        if let Some(ParamShape::PairKV(ks, ki, vs, vi)) = cx.lookup(name) {
            return match (ks, vs) {
                (Sc::I, Sc::I) => Some(OutShape::PairII(EI::Var(ki), EI::Var(vi))),
                (Sc::I, Sc::F) => Some(OutShape::PairIF(EI::Var(ki), EF::Var(vi))),
                _ => None,
            };
        }
    }
    Some(OutShape::Scalar(compile_scalar(body, cx)?))
}

/// Compile a 2-parameter combiner against a concrete operand type. Only
/// type-preserving bodies compile (see [`TypedUdf2`]).
pub fn compile_udf2(u: &Udf2, operand: &ElemType) -> Option<TypedUdf2> {
    let e = u.expr.as_ref()?;
    let (params, body) = (&e.0, &e.1);
    if params.len() != 2 {
        return None;
    }
    let sc = scalar_sc(operand)?;
    let cx = Cx {
        params,
        shapes: vec![ParamShape::Scalar(sc, 0), ParamShape::Scalar(sc, 1)],
    };
    match (sc, compile_scalar(body, &cx)?) {
        (Sc::I, ScalarExpr::I(e)) => Some(TypedUdf2::I64(e)),
        (Sc::F, ScalarExpr::F(e)) => Some(TypedUdf2::F64(e)),
        _ => None,
    }
}

/// One compiled stage of a fused chain.
#[derive(Clone, Debug)]
pub enum TypedStage {
    /// A map stage.
    Map(TypedUdf1),
    /// A filter stage (in-place compaction).
    Filter(TypedUdf1),
}

/// Compile an entire fused chain against its input type. `None` unless
/// EVERY stage compiles (a flatMap stage, an opaque UDF, or an
/// unsupported intermediate type each sink the whole chain — partial
/// typed chains would re-encode mid-pipeline and lose the win). Returns
/// the stages plus the chain's output element type.
pub fn compile_chain(
    stages: &[FusedStage],
    in_ty: &ElemType,
) -> Option<(Vec<TypedStage>, ElemType)> {
    let mut t = in_ty.clone();
    let mut out = Vec::with_capacity(stages.len());
    for s in stages {
        match s {
            FusedStage::Map(u) => {
                let c = compile_udf1(u, &t)?;
                t = c.out_type();
                out.push(TypedStage::Map(c));
            }
            FusedStage::Filter(u) => {
                let c = compile_udf1(u, &t)?;
                if !matches!(c.out_type(), ElemType::Bool) {
                    return None;
                }
                out.push(TypedStage::Filter(c));
            }
            FusedStage::FlatMap(_) => return None,
        }
    }
    // Intermediate and output layouts must all be decodable columns.
    if !ColumnBatch::supports(in_ty) || !ColumnBatch::supports(&t) {
        return None;
    }
    Some((out, t))
}

// ---------------------------------------------------------------------------
// Per-edge inference
// ---------------------------------------------------------------------------

/// Derive the output element type of every node by forward fixpoint (see
/// the module docs). The result is indexed by [`crate::dataflow::NodeId`];
/// nodes the analysis cannot pin down get [`ElemType::Dyn`].
pub fn infer(g: &DataflowGraph) -> Vec<ElemType> {
    let n = g.nodes.len();
    // `None` = not yet computed (optimistic bottom, resolved through Φ
    // init arms before back-edges are consulted).
    let mut ty: Vec<Option<ElemType>> = vec![None; n];
    for _round in 0..=n {
        let mut changed = false;
        for node in &g.nodes {
            let computed = node_out_type(node, &ty);
            if computed != ty[node.id] {
                ty[node.id] = computed;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    ty.into_iter().map(|t| t.unwrap_or(ElemType::Dyn)).collect()
}

/// Number of edges whose source type is fully static (reported as
/// `opt.typed_edges`).
pub fn typed_edge_count(g: &DataflowGraph, types: &[ElemType]) -> usize {
    g.nodes
        .iter()
        .flat_map(|n| n.inputs.iter())
        .filter(|i| types.get(i.src).is_some_and(ElemType::is_typed))
        .count()
}

fn key_payload(t: &ElemType) -> (ElemType, ElemType) {
    match t {
        ElemType::Pair(k, v) => ((**k).clone(), (**v).clone()),
        // `Value::key()` of a non-empty tuple is its first component; of
        // anything else, the whole value (payload Unit → Dyn).
        ElemType::Tuple(ts) if !ts.is_empty() => (ts[0].clone(), ElemType::Dyn),
        ElemType::Dyn => (ElemType::Dyn, ElemType::Dyn),
        other => (other.clone(), ElemType::Dyn),
    }
}

fn map_out(udf: &Udf1, in_ty: &ElemType) -> ElemType {
    match compile_udf1(udf, in_ty) {
        Some(c) => c.out_type(),
        None => ElemType::Dyn,
    }
}

fn node_out_type(node: &Node, ty: &[Option<ElemType>]) -> Option<ElemType> {
    let input = |i: usize| -> Option<ElemType> { ty[node.inputs[i].src].clone() };
    Some(match &node.op {
        Rhs::Const(v) => ElemType::of_value(v),
        Rhs::BagLit(_) | Rhs::NamedSource(_) => {
            node.elem_hint.clone().unwrap_or(ElemType::Dyn)
        }
        Rhs::ReadFile { .. } => ElemType::Str,
        // Unit outputs (side-effect sinks) stay dynamic.
        Rhs::WriteFile { .. } | Rhs::Collect { .. } => ElemType::Dyn,
        Rhs::Map { udf, .. } => map_out(udf, &input(0)?),
        Rhs::Filter { .. } | Rhs::Distinct { .. } => input(0)?,
        Rhs::FlatMap { .. } => ElemType::Dyn, // UdfN carries no expr metadata
        Rhs::Fused { stages, .. } => {
            let mut t = input(0)?;
            for s in stages {
                t = match s {
                    FusedStage::Map(u) => map_out(u, &t),
                    FusedStage::Filter(_) => t, // predicate cannot change the type
                    FusedStage::FlatMap(_) => ElemType::Dyn,
                };
            }
            t
        }
        Rhs::Join { .. } => {
            let (lk, lv) = key_payload(&input(0)?);
            let (rk, rv) = key_payload(&input(1)?);
            ElemType::Pair(
                Box::new(lk.join(&rk)),
                Box::new(ElemType::Pair(Box::new(lv), Box::new(rv))),
            )
        }
        Rhs::ReduceByKey { udf, .. } => match input(0)? {
            ElemType::Pair(k, v) => {
                // The combiner must provably preserve the value type;
                // otherwise merged values may drift and only the key
                // column stays static.
                let v = if compile_udf2(udf, &v).is_some() { v } else { Box::new(ElemType::Dyn) };
                ElemType::Pair(k, v)
            }
            _ => ElemType::Dyn,
        },
        Rhs::Reduce { udf, .. } => {
            let t = input(0)?;
            if compile_udf2(udf, &t).is_some() { t } else { ElemType::Dyn }
        }
        Rhs::Count { .. } => ElemType::I64,
        Rhs::Union { .. } => input(0)?.join(&input(1)?),
        Rhs::Cross { .. } => {
            ElemType::Pair(Box::new(input(0)?), Box::new(input(1)?))
        }
        Rhs::Phi(_) => {
            // Optimistic join over the arms resolved so far; a Φ with no
            // resolved arm stays bottom this round.
            let resolved: Vec<ElemType> =
                node.inputs.iter().filter_map(|i| ty[i.src].clone()).collect();
            return resolved.into_iter().reduce(|a, b| a.join(&b));
        }
        Rhs::XlaCall { .. } => ElemType::Dyn,
        Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => ElemType::Dyn,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::interp_expr;
    use crate::frontend::lexer::lex;
    use crate::frontend::{ast, parser};

    fn lambda(src: &str) -> (Vec<String>, ast::Expr) {
        let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
        match &ast.stmts[0] {
            ast::Stmt::Assign(_, ast::Expr::Lambda(ps, body)) => (ps.clone(), (**body).clone()),
            other => panic!("{other:?}"),
        }
    }

    fn udf1(src: &str) -> Udf1 {
        let (ps, body) = lambda(src);
        interp_expr::compile_udf1(ps, body, "t".into()).unwrap()
    }

    fn udf2(src: &str) -> Udf2 {
        let (ps, body) = lambda(src);
        interp_expr::compile_udf2(ps, body, "t".into()).unwrap()
    }

    fn pair_ty(k: ElemType, v: ElemType) -> ElemType {
        ElemType::Pair(Box::new(k), Box::new(v))
    }

    #[test]
    fn gate_parses_and_gates() {
        assert_eq!(ColumnarGate::parse("ALWAYS").unwrap(), ColumnarGate::Always);
        assert!(ColumnarGate::parse("sometimes").is_err());
        assert!(ColumnarGate::Always.enabled(1));
        assert!(!ColumnarGate::Never.enabled(64));
        assert!(!ColumnarGate::Auto.enabled(1));
        assert!(ColumnarGate::Auto.enabled(64));
    }

    #[test]
    fn compiled_maps_agree_with_interpreter() {
        // (source, input type, inputs) triples; compiled map_batch must
        // agree element-for-element with the dynamic udf.call.
        let ints: Vec<Value> = (-4..8).map(Value::I64).collect();
        for src in [
            "|x| x * 2 + 1",
            "|x| x % 3",
            "|x| pair(x, x * x)",
            "|x| float(x) / 2.0",
            "|x| abs(x - 5)",
            "|x| min(x, 3)",
            "|x| max(0 - x, x)",
            "|x| int(float(x) * 1.5)",
            "|x| pair(x % 2, float(x))",
        ] {
            let u = udf1(src);
            let c = compile_udf1(&u, &ElemType::I64).unwrap_or_else(|| panic!("{src}"));
            let col = ColumnBatch::from_values(&ints, &ElemType::I64).unwrap();
            let got = c.map_batch(&col).unwrap().into_values();
            let want: Vec<Value> = ints.iter().map(|v| u.call(v)).collect();
            assert_eq!(got, want, "{src}");
        }
    }

    #[test]
    fn compiled_filters_agree_with_interpreter() {
        let ints: Vec<Value> = (-4..8).map(Value::I64).collect();
        for src in ["|x| x % 2 == 0", "|x| x > 1 && x < 6", "|x| !(x == 3) || x < 0"] {
            let u = udf1(src);
            let c = compile_udf1(&u, &ElemType::I64).unwrap_or_else(|| panic!("{src}"));
            let mut col = ColumnBatch::from_values(&ints, &ElemType::I64).unwrap();
            let kept = c.filter_batch(&mut col).unwrap();
            let want: Vec<Value> =
                ints.iter().filter(|v| u.call(v) == Value::Bool(true)).cloned().collect();
            assert_eq!(kept, want.len(), "{src}");
            assert_eq!(col.into_values(), want, "{src}");
        }
    }

    #[test]
    fn filter_mask_agrees_with_compacting_filter() {
        let ints: Vec<Value> = (-4..8).map(Value::I64).collect();
        for src in ["|x| x % 2 == 0", "|x| x > 1 && x < 6", "|x| !(x == 3) || x < 0"] {
            let u = udf1(src);
            let c = compile_udf1(&u, &ElemType::I64).unwrap_or_else(|| panic!("{src}"));
            let col = ColumnBatch::from_values(&ints, &ElemType::I64).unwrap();
            let mut mask = vec![true; col.len()];
            let kept = c.filter_mask(&col, &mut mask).unwrap();
            // The batch itself is untouched; only the mask changed.
            assert_eq!(col.len(), ints.len(), "{src}: no data movement");
            let mut compacted = col.clone();
            compacted.compact(&mask);
            let mut reference = ColumnBatch::from_values(&ints, &ElemType::I64).unwrap();
            let ref_kept = c.filter_batch(&mut reference).unwrap();
            assert_eq!(kept, ref_kept, "{src}");
            assert_eq!(compacted, reference, "{src}");
        }
        // A second predicate only narrows: pre-cleared bits stay cleared
        // and their rows are never evaluated.
        let even = compile_udf1(&udf1("|x| x % 2 == 0"), &ElemType::I64).unwrap();
        let small = compile_udf1(&udf1("|x| x < 4"), &ElemType::I64).unwrap();
        let col =
            ColumnBatch::from_values(&(0..10).map(Value::I64).collect::<Vec<_>>(), &ElemType::I64)
                .unwrap();
        let mut mask = vec![true; 10];
        assert_eq!(even.filter_mask(&col, &mut mask), Some(5));
        assert_eq!(small.filter_mask(&col, &mut mask), Some(2));
        let mut out = col.clone();
        out.compact(&mask);
        assert_eq!(out, ColumnBatch::I64(vec![0, 2]));
        // Non-predicate and layout-mismatch cases bail.
        let mapper = compile_udf1(&udf1("|x| x + 1"), &ElemType::I64).unwrap();
        assert!(mapper.filter_mask(&col, &mut mask).is_none());
        let f64s = ColumnBatch::F64(vec![1.0]);
        assert!(even.filter_mask(&f64s, &mut [true]).is_none());
    }

    #[test]
    fn masked_map_skips_dead_lanes_and_stays_row_parallel() {
        let ints: Vec<Value> = (0..8).map(Value::I64).collect();
        let col = ColumnBatch::from_values(&ints, &ElemType::I64).unwrap();
        let mask: Vec<bool> = (0..8).map(|r| r % 3 != 0).collect();
        for src in ["|x| x * 2 + 1", "|x| pair(x % 2, x)", "|x| float(x) / 2.0"] {
            let u = udf1(src);
            let c = compile_udf1(&u, &ElemType::I64).unwrap_or_else(|| panic!("{src}"));
            let mut got = c.map_batch_masked(&col, &mask).unwrap();
            assert_eq!(got.len(), 8, "{src}: row-parallel with the mask");
            got.compact(&mask);
            let want: Vec<Value> = ints
                .iter()
                .zip(&mask)
                .filter(|(_, &m)| m)
                .map(|(v, _)| u.call(v))
                .collect();
            assert_eq!(got.into_values(), want, "{src}");
        }
        // Layout mismatch bails.
        let c = compile_udf1(&udf1("|x| x + 1"), &ElemType::I64).unwrap();
        assert!(c.map_batch_masked(&ColumnBatch::Bool(vec![true]), &[true]).is_none());
    }

    #[test]
    fn pair_inputs_compile_and_agree() {
        let t = pair_ty(ElemType::I64, ElemType::F64);
        let pairs: Vec<Value> = (0..6)
            .map(|x| Value::pair(Value::I64(x % 3), Value::F64(x as f64 * 0.5)))
            .collect();
        for src in [
            "|p| pair(fst(p), snd(p) + 1.5)",
            "|p| pair(key(p), payload(p) * 2.0)",
            "|p| snd(p)",
            "|p| p",
        ] {
            let u = udf1(src);
            let c = compile_udf1(&u, &t).unwrap_or_else(|| panic!("{src}"));
            let col = ColumnBatch::from_values(&pairs, &t).unwrap();
            let got = c.map_batch(&col).unwrap().into_values();
            let want: Vec<Value> = pairs.iter().map(|v| u.call(v)).collect();
            assert_eq!(got, want, "{src}");
        }
    }

    #[test]
    fn float_total_order_semantics_preserved() {
        // Value equality on floats is bit-pattern total order: NaN == NaN,
        // 0.0 != -0.0. The compiled predicate must reproduce both.
        let u = udf1("|x| x == x * 1.0");
        let c = compile_udf1(&u, &ElemType::F64).unwrap();
        let vs = vec![Value::F64(f64::NAN), Value::F64(0.0), Value::F64(-0.0)];
        let mut col = ColumnBatch::from_values(&vs, &ElemType::F64).unwrap();
        let got_kept = c.filter_batch(&mut col).unwrap();
        let want: Vec<Value> = vs.iter().filter(|v| u.call(v) == Value::Bool(true)).cloned().collect();
        assert_eq!(got_kept, want.len());
        assert_eq!(col.into_values(), want);
    }

    #[test]
    fn untypable_bodies_bail() {
        // String concat, mixed-type comparison (rank compare!), unknown
        // builtin shapes, whole-pair arithmetic: all dynamic-only.
        for (src, t) in [
            ("|x| x + \"s\"", ElemType::I64),
            ("|x| x < 1.5", ElemType::I64), // I64 vs F64 rank-compares
            ("|x| len(x)", ElemType::Str),
            ("|p| p + 1", pair_ty(ElemType::I64, ElemType::I64)),
            ("|x| snd(x)", ElemType::I64), // snd on scalar panics dynamically
        ] {
            let u = udf1(src);
            assert!(compile_udf1(&u, &t).is_none(), "{src}");
        }
        // Opaque Rust closures never compile.
        let native = Udf1::new("native", |v: &Value| v.clone());
        assert!(compile_udf1(&native, &ElemType::I64).is_none());
    }

    #[test]
    fn combiners_compile_and_agree() {
        let u = udf2("|a, b| a + b");
        let c = compile_udf2(&u, &ElemType::I64).unwrap();
        assert_eq!(
            c.combine(&Value::I64(3), &Value::I64(4)),
            Some(u.call(&Value::I64(3), &Value::I64(4)))
        );
        // Runtime mismatch → None (caller falls back to the dynamic call).
        assert_eq!(c.combine(&Value::I64(3), &Value::F64(4.0)), None);

        let m = udf2("|a, b| max(a, b)");
        let cf = compile_udf2(&m, &ElemType::F64).unwrap();
        let (x, y) = (Value::F64(1.5), Value::F64(f64::NAN));
        assert_eq!(cf.combine(&x, &y), Some(m.call(&x, &y)));

        // Type-changing combiner must not compile for I64 operands.
        let d = udf2("|a, b| float(a) + float(b)");
        assert!(compile_udf2(&d, &ElemType::I64).is_none());
    }

    #[test]
    fn chain_compilation_is_all_or_nothing() {
        let stages = vec![
            FusedStage::Map(udf1("|x| x * 3")),
            FusedStage::Filter(udf1("|x| x % 2 == 1")),
            FusedStage::Map(udf1("|x| pair(x, x + 1)")),
        ];
        let (compiled, out) = compile_chain(&stages, &ElemType::I64).unwrap();
        assert_eq!(compiled.len(), 3);
        assert_eq!(out, pair_ty(ElemType::I64, ElemType::I64));

        let with_opaque = vec![
            FusedStage::Map(udf1("|x| x * 3")),
            FusedStage::Map(Udf1::new("native", |v: &Value| v.clone())),
        ];
        assert!(compile_chain(&with_opaque, &ElemType::I64).is_none());
    }

    #[test]
    fn inference_types_a_straight_chain() {
        let p = crate::frontend::parse_and_lower(
            "a = bag(1, 2, 3); b = a.map(|x| pair(x % 2, x)); c = b.filter(|p| snd(p) > 0); \
             n = c.count(); collect(c, \"c\");",
        )
        .unwrap();
        let (g, _) = crate::compile_with(&p, &crate::opt::OptConfig::none()).unwrap();
        let types = infer(&g);
        let by_name = |s: &str| {
            let n = g.nodes.iter().find(|n| n.name == s).unwrap();
            types[n.id].clone()
        };
        assert_eq!(by_name("a"), ElemType::I64);
        assert_eq!(by_name("b"), pair_ty(ElemType::I64, ElemType::I64));
        assert_eq!(by_name("c"), pair_ty(ElemType::I64, ElemType::I64));
        assert_eq!(by_name("n"), ElemType::I64);
        assert!(typed_edge_count(&g, &types) >= 3);
    }

    #[test]
    fn inference_fixpoints_across_phi_back_edges() {
        // Loop-carried scalar keeps I64 through the Φ; the loop-carried
        // bag of pairs keeps its type through union + reduceByKey.
        let p = crate::frontend::parse_and_lower(
            "total = bag(1).map(|x| pair(x, 0)); d = 1; \
             while (d <= 3) { \
               fresh = bag(1, 2).map(|x| pair(x, 1)); \
               total = total.union(fresh).reduceByKey(|a, b| a + b); \
               d = d + 1; \
             } collect(total, \"t\");",
        )
        .unwrap();
        let (g, _) = crate::compile_with(&p, &crate::opt::OptConfig::none()).unwrap();
        let types = infer(&g);
        // Every Φ over the loop-carried pair bag must resolve to the pair
        // type, not Dyn — the fixpoint crossed the back-edge.
        let phi = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Phi(_)) && !n.singleton)
            .expect("bag phi");
        assert_eq!(types[phi.id], pair_ty(ElemType::I64, ElemType::I64));
        // Scalar counter Φ is typed too.
        let counter = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Phi(_)) && n.singleton)
            .expect("counter phi");
        assert_eq!(types[counter.id], ElemType::I64);
    }
}
