//! Delta-incremental loop analysis + rewrite (`opt.delta`).
//!
//! Proves loop bodies *delta-safe* and annotates the qualifying nodes
//! ([`crate::dataflow::DeltaSpec`]) so the engine circulates only
//! changed rows per superstep and merges them into indexed solution
//! sets (`ops::state`). Two loop shapes are proven today; anything else
//! falls back to full recompute (annotation simply absent):
//!
//! **Upsert / re-aggregation** (`total = reduceByKey(total ∪ fresh)`):
//! the loop-header Φ's in-loop consumers reach the back-edge
//! `reduceByKey` through `union` nodes ONLY, and the `reduceByKey`
//! feeds nothing but the Φ. The reduceByKey then retains its
//! accumulator across supersteps (ingesting only fresh rows) and emits
//! only changed keys; the Φ holds a keyed upsert store and re-emits
//! arriving rows downstream only on its init bag. Correct because the
//! combiner is associative/commutative — already an engine-wide
//! assumption for `reduceByKey`.
//!
//! **Frontier / semi-naive** (`reached = distinct(reached ∪
//! f(reached))`): the Φ's in-loop consumers form a DAG of
//! element-local operators (map/filter/flatMap/fused/union, plus joins
//! probing with the Φ-derived side against a loop-invariant build)
//! terminating at the back-edge `distinct`, which feeds nothing but
//! the Φ. The distinct retains its seen-set, so per step only
//! globally-new elements circulate — textbook semi-naive evaluation.
//! Correct because every operator on the path is element-local
//! (`f(S ∪ T) = f(S) ∪ f(T)`) and the accumulation is monotone.
//!
//! Shared safety rules: exactly one back-edge arm; the back-edge
//! operator's only consumer is the Φ; no in-loop observation of the Φ
//! outside the proven paths (in particular, a loop condition derived
//! from the carried bag — e.g. `count`ing it — disqualifies the loop,
//! since the per-step delta would change what the condition sees).
//! Consumers *outside* the loop are always fine: the engine
//! materializes the full solution set on exit edges.
//!
//! The pass also rewrites every input edge of a delta-Φ to
//! [`Route::HashKey`]: the solution set is partitioned by key across
//! instances, and the init arm arrives with arbitrary partitioning —
//! without co-partitioning, a stale init row for key *k* on the wrong
//! instance would never be superseded. (For the back-edge arm this is
//! a no-op: its rows are already key-partitioned, and re-hashing maps
//! instance-compatibly.)
//!
//! Gating: under [`DeltaGate::Auto`] the `opt::cost` trip model must
//! predict ≥ 2 iterations — delta state only pays off when it
//! amortizes across supersteps. `Always` skips the gate (differential
//! tests force tiny literal loops into delta mode); `Never` uninstalls
//! the pass.

use super::analysis::PlanAnalysis;
use super::cost::TripCount;
use super::{Pass, PassOutcome};
use crate::cfg::loops::NaturalLoop;
use crate::dataflow::{DataflowGraph, DeltaMode, DeltaSpec, NodeId, Route};
use crate::error::Result;
use crate::frontend::Rhs;

/// Policy for the delta-incremental rewrite (config key `opt.delta`,
/// CLI `--no-delta`, env default `LABY_DELTA`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaGate {
    /// Cost-gated (default): rewrite proven loops whose estimated trip
    /// count is at least 2.
    Auto,
    /// Rewrite every proven loop regardless of the trip estimate.
    Always,
    /// Never rewrite (full recompute everywhere).
    Never,
}

impl DeltaGate {
    /// Parse a config/CLI/env value.
    pub fn parse(s: &str) -> Result<DeltaGate> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(DeltaGate::Auto),
            "always" => Ok(DeltaGate::Always),
            "never" => Ok(DeltaGate::Never),
            other => Err(crate::Error::Config(format!(
                "opt.delta: expected auto|always|never, got {other:?}"
            ))),
        }
    }

    /// The process-wide default: `LABY_DELTA` if set (invalid values
    /// fall back with a warning — a bad env var must not fail every
    /// compile), else [`DeltaGate::Auto`]. Read once.
    pub fn default_from_env() -> DeltaGate {
        static GATE: std::sync::OnceLock<DeltaGate> = std::sync::OnceLock::new();
        *GATE.get_or_init(|| match std::env::var("LABY_DELTA") {
            Err(_) => DeltaGate::Auto,
            Ok(s) => DeltaGate::parse(&s).unwrap_or_else(|e| {
                eprintln!("warning: LABY_DELTA ignored: {e}");
                DeltaGate::Auto
            }),
        })
    }
}

/// Number of loops currently in delta mode (counted by their Φ
/// anchors). Reported as `opt.delta_loops` — a state count, not a sum
/// of per-round rewrite events.
pub fn annotated_loops(g: &DataflowGraph) -> usize {
    g.nodes
        .iter()
        .filter(|n| n.delta.as_ref().is_some_and(|d| d.is_phi()))
        .count()
}

/// A proven delta loop: the Φ, its back-edge operator, and the mode pair.
struct Proven {
    phi: NodeId,
    back: NodeId,
    phi_mode: DeltaMode,
    back_mode: DeltaMode,
    kind: &'static str,
}

/// The pass. Recomputes annotations from scratch every run (so a graph
/// reshaped by earlier passes — e.g. a flipped join build side — never
/// keeps a stale delta annotation it no longer qualifies for).
pub struct DeltaPass {
    /// Gating policy ([`DeltaGate::Never`] is handled by not
    /// installing the pass at all).
    pub gate: DeltaGate,
    /// Trip count assumed for loops the cost model cannot bound.
    pub default_trips: u64,
}

impl Pass for DeltaPass {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let before: Vec<Option<DeltaSpec>> =
            g.nodes.iter().map(|n| n.delta.clone()).collect();
        for n in &mut g.nodes {
            n.delta = None;
        }
        let mut out = PassOutcome::default();
        for (li, l) in a.loops.loops.iter().enumerate() {
            let trips = a
                .cost
                .trips
                .get(li)
                .copied()
                .unwrap_or(TripCount::Unknown)
                .or_default(self.default_trips);
            let phis: Vec<NodeId> = g
                .nodes
                .iter()
                .filter(|n| {
                    n.block == l.header && matches!(n.op, Rhs::Phi(_)) && !n.singleton
                })
                .map(|n| n.id)
                .collect();
            for phi in phis {
                let Some(p) = classify(g, a, l, phi) else { continue };
                if self.gate == DeltaGate::Auto && trips < 2 {
                    out.skipped += 1;
                    out.details.push(format!(
                        "loop@b{}: Φ '{}' is {}-eligible but trip estimate {} < 2 — kept full",
                        l.header, g.nodes[p.phi].name, p.kind, trips
                    ));
                    continue;
                }
                let spec = |mode| DeltaSpec { mode, loop_blocks: l.body.clone() };
                g.nodes[p.phi].delta = Some(spec(p.phi_mode));
                g.nodes[p.back].delta = Some(spec(p.back_mode));
                // Co-partition the solution set: every Φ arm becomes
                // key-hashed (see module docs).
                for inp in &mut g.nodes[p.phi].inputs {
                    inp.route = Route::HashKey;
                }
                out.details.push(format!(
                    "loop@b{}: Φ '{}' → {} solution set; '{}' retains state, emits changed rows (trips≈{})",
                    l.header, g.nodes[p.phi].name, p.kind, g.nodes[p.back].name, trips
                ));
            }
        }
        out.changed =
            g.nodes.iter().filter(|n| n.delta != before[n.id]).count();
        Ok(out)
    }
}

/// Try to prove `phi` (a non-singleton Φ at the header of `l`) anchors
/// a delta-safe loop.
fn classify(
    g: &DataflowGraph,
    a: &PlanAnalysis,
    l: &NaturalLoop,
    phi: NodeId,
) -> Option<Proven> {
    let in_body = |b: usize| l.body.binary_search(&b).is_ok();
    let n = &g.nodes[phi];
    // Exactly one back-edge arm and one entry arm (self-arguments from
    // `continue`, and multi-latch headers, fall back to full recompute).
    if n.inputs.len() != 2 {
        return None;
    }
    let back_arms: Vec<usize> =
        (0..2).filter(|&i| in_body(n.inputs[i].src_block)).collect();
    if back_arms.len() != 1 {
        return None;
    }
    let back = n.inputs[back_arms[0]].src;
    if back == phi || g.nodes[back].cond.is_some() || g.nodes[back].singleton {
        return None;
    }
    // The back-edge operator must feed nothing but the Φ (its retained
    // state changes what it emits; any other consumer would observe
    // deltas instead of full per-step results).
    if a.consumers[back].is_empty() || a.consumers[back].iter().any(|&(c, _)| c != phi) {
        return None;
    }
    match g.nodes[back].op {
        Rhs::ReduceByKey { .. } => classify_upsert(g, a, l, phi, back),
        Rhs::Distinct { .. } => classify_frontier(g, a, l, phi, back),
        _ => None,
    }
}

/// Upsert class: Φ's in-loop consumers reach the back-edge reduceByKey
/// through union nodes only.
fn classify_upsert(
    g: &DataflowGraph,
    a: &PlanAnalysis,
    l: &NaturalLoop,
    phi: NodeId,
    back: NodeId,
) -> Option<Proven> {
    let in_body = |b: usize| l.body.binary_search(&b).is_ok();
    let mut dag: Vec<NodeId> = Vec::new();
    let mut work: Vec<NodeId> = Vec::new();
    let mut reached_back = false;
    for &(c, _) in &a.consumers[phi] {
        if !in_body(g.nodes[c].block) {
            continue; // exit consumer: materialized full set, always safe
        }
        if !matches!(g.nodes[c].op, Rhs::Union { .. }) || g.nodes[c].cond.is_some() {
            return None;
        }
        if !dag.contains(&c) {
            dag.push(c);
            work.push(c);
        }
    }
    while let Some(u) = work.pop() {
        if a.consumers[u].is_empty() {
            return None; // dead branch — cannot prove all rows reach the fold
        }
        for &(c, _) in &a.consumers[u] {
            if c == back {
                reached_back = true;
                continue;
            }
            if !in_body(g.nodes[c].block)
                || !matches!(g.nodes[c].op, Rhs::Union { .. })
                || g.nodes[c].cond.is_some()
            {
                return None;
            }
            if !dag.contains(&c) {
                dag.push(c);
                work.push(c);
            }
        }
    }
    reached_back.then_some(Proven {
        phi,
        back,
        phi_mode: DeltaMode::PhiUpsert,
        back_mode: DeltaMode::AccReduce,
        kind: "upsert",
    })
}

/// Frontier class: Φ's in-loop consumers form a DAG of element-local
/// operators terminating at the back-edge distinct.
fn classify_frontier(
    g: &DataflowGraph,
    a: &PlanAnalysis,
    l: &NaturalLoop,
    phi: NodeId,
    back: NodeId,
) -> Option<Proven> {
    let in_body = |b: usize| l.body.binary_search(&b).is_ok();
    let mut dag: Vec<NodeId> = Vec::new();
    let mut work: Vec<(NodeId, usize)> = Vec::new();
    let mut reached_back = false;
    // Admit `c` (discovered via its Φ-derived input `idx`) into the DAG.
    let admit = |c: NodeId, idx: usize, dag: &mut Vec<NodeId>, work: &mut Vec<(NodeId, usize)>| -> bool {
        let node = &g.nodes[c];
        if !in_body(node.block) || node.cond.is_some() {
            return false;
        }
        match node.op {
            Rhs::Map { .. }
            | Rhs::Filter { .. }
            | Rhs::FlatMap { .. }
            | Rhs::Fused { .. }
            | Rhs::Union { .. } => {}
            Rhs::Join { .. } => {
                // The Φ-derived side must probe; the build side must be
                // loop-invariant. A join discovered on both inputs
                // (frontier self-join) fails here on the second visit.
                let build = node.build_side.unwrap_or(0);
                if idx == build {
                    return false;
                }
                if in_body(node.inputs[build].src_block) {
                    return false;
                }
            }
            _ => return false,
        }
        if !dag.contains(&c) {
            dag.push(c);
            work.push((c, idx));
        }
        true
    };
    for &(c, idx) in &a.consumers[phi] {
        if !in_body(g.nodes[c].block) {
            continue; // exit consumer
        }
        if c == back {
            // Φ feeding the distinct directly carries no new work into
            // the loop — fall back rather than model the degenerate shape.
            return None;
        }
        if !admit(c, idx, &mut dag, &mut work) {
            return None;
        }
    }
    let mut i = 0;
    while i < work.len() {
        let (u, _) = work[i];
        i += 1;
        if a.consumers[u].is_empty() {
            return None; // dead branch
        }
        for &(c, idx) in &a.consumers[u] {
            if c == back {
                reached_back = true;
                continue;
            }
            if !admit(c, idx, &mut dag, &mut work) {
                return None;
            }
        }
    }
    reached_back.then_some(Proven {
        phi,
        back,
        phi_mode: DeltaMode::PhiFrontier,
        back_mode: DeltaMode::AccDistinct,
        kind: "frontier",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::opt::OptConfig;

    fn annotated(src: &str, gate: DeltaGate) -> (DataflowGraph, usize) {
        let p = parse_and_lower(src).unwrap();
        let cfg = OptConfig { delta: gate, ..OptConfig::none() };
        let (g, rep) = crate::compile_with(&p, &cfg).unwrap();
        (g, rep.delta_loops)
    }

    const UPSERT_SRC: &str = "total = bag(); d = 1; while (d <= 4) { \
         day = bag(1, 2, 1).map(|x| pair(x, 1)); \
         total = total.union(day).reduceByKey(|a, b| a + b); \
         d = d + 1; } collect(total, \"total\");";

    const FRONTIER_SRC: &str = "reach = bag(1); d = 1; while (d <= 4) { \
         reach = reach.union(reach.map(|x| x + 1)).distinct(); \
         d = d + 1; } collect(reach, \"reach\");";

    #[test]
    fn upsert_loop_is_annotated() {
        let (g, loops) = annotated(UPSERT_SRC, DeltaGate::Always);
        assert_eq!(loops, 1);
        let phi = g
            .nodes
            .iter()
            .find(|n| matches!(n.delta, Some(DeltaSpec { mode: DeltaMode::PhiUpsert, .. })))
            .expect("upsert Φ");
        assert!(phi.inputs.iter().all(|i| i.route == Route::HashKey));
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.delta, Some(DeltaSpec { mode: DeltaMode::AccReduce, .. }))));
    }

    #[test]
    fn frontier_loop_is_annotated() {
        let (g, loops) = annotated(FRONTIER_SRC, DeltaGate::Always);
        assert_eq!(loops, 1);
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.delta, Some(DeltaSpec { mode: DeltaMode::PhiFrontier, .. }))));
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.delta, Some(DeltaSpec { mode: DeltaMode::AccDistinct, .. }))));
    }

    #[test]
    fn observed_carried_bag_disqualifies() {
        // The carried bag is count()ed inside the loop: it is observed
        // outside the proven union→reduceByKey path — must fall back
        // (in delta mode the Φ circulates per-step deltas, so an
        // in-loop count would see delta rows, not the full set).
        let src = "total = bag(); d = 1; while (d <= 4) { \
             n = total.count(); \
             day = bag(1).map(|x| pair(x, 1)); \
             total = total.union(day).reduceByKey(|a, b| a + b); \
             d = d + n - n + 1; } collect(total, \"total\");";
        let (_, loops) = annotated(src, DeltaGate::Always);
        assert_eq!(loops, 0);
    }

    #[test]
    fn map_on_carried_bag_into_fold_disqualifies_upsert() {
        // total flows through a map before the reduceByKey: re-applying
        // the map to deltas is not proven for the upsert class.
        let src = "total = bag(); d = 1; while (d <= 4) { \
             total = total.map(|p| p).reduceByKey(|a, b| a + b); \
             d = d + 1; } collect(total, \"total\");";
        let (_, loops) = annotated(src, DeltaGate::Always);
        assert_eq!(loops, 0);
    }

    #[test]
    fn auto_gate_declines_single_trip_loops() {
        let one_trip = UPSERT_SRC.replace("d <= 4", "d <= 1");
        let (g, loops) = annotated(&one_trip, DeltaGate::Auto);
        assert_eq!(loops, 0, "1-trip loop must not pay for delta state");
        assert!(g.nodes.iter().all(|n| n.delta.is_none()));
        // The eligible-but-gated loop is surfaced in the report details.
        let p = parse_and_lower(&one_trip).unwrap();
        let cfg = OptConfig { delta: DeltaGate::Auto, ..OptConfig::none() };
        let (_, rep) = crate::compile_with(&p, &cfg).unwrap();
        assert!(rep.render().contains("kept full"), "{}", rep.render());
    }

    #[test]
    fn never_gate_uninstalls_the_pass() {
        let (g, loops) = annotated(UPSERT_SRC, DeltaGate::Never);
        assert_eq!(loops, 0);
        assert!(g.nodes.iter().all(|n| n.delta.is_none()));
    }

    #[test]
    fn frontier_with_invariant_join_probe_qualifies() {
        // Semi-naive reachability: probe the invariant adjacency with
        // the frontier. In `a.join(b)` the ARGUMENT is the build side,
        // so adj (defined in the preamble) builds once and the
        // Φ-derived side probes — exactly the admitted join shape.
        let src = "adj = bag(1, 2, 3).map(|x| pair(x, x + 1)); reach = bag(1); d = 1; \
             while (d <= 4) { \
             next = reach.map(|x| pair(x, x)).join(adj).map(|p| key(payload(p))); \
             reach = reach.union(next).distinct(); \
             d = d + 1; } collect(reach, \"reach\");";
        let p = parse_and_lower(src).unwrap();
        let cfg = OptConfig { delta: DeltaGate::Always, hoist: true, ..OptConfig::none() };
        let (g, rep) = crate::compile_with(&p, &cfg).unwrap();
        assert_eq!(rep.delta_loops, 1, "{}", rep.render());
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.delta, Some(DeltaSpec { mode: DeltaMode::PhiFrontier, .. }))));
    }

    #[test]
    fn delta_report_counts_and_tags() {
        let p = parse_and_lower(UPSERT_SRC).unwrap();
        let cfg = OptConfig { delta: DeltaGate::Always, ..OptConfig::none() };
        let (g, rep) = crate::compile_with(&p, &cfg).unwrap();
        assert_eq!(rep.delta_loops, 1);
        assert!(rep.render().contains("solution set"), "{}", rep.render());
        assert!(g.opt_summary.iter().any(|(k, v)| k == "opt.delta_loops" && *v == 1));
        // DOT render carries the mode=delta tag.
        let dot = crate::dataflow::dot::to_dot(&g);
        assert!(dot.contains("mode=delta"), "{dot}");
    }
}
