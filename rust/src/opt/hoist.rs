//! Loop-invariant hoisting: move nodes whose inputs are all invariant
//! w.r.t. an enclosing natural loop out of the cycle into the loop's
//! preamble block (the unique out-of-loop predecessor of the header), so
//! they compute **once per loop entry** instead of once per iteration.
//!
//! Coordination stays sound without new machinery because bag identity is
//! `(node, path prefix)` (§6.3.1): after the move, every in-loop consumer
//! resolves the *same* preamble bag via the §6.3.3 longest-prefix rule,
//! the conditional-output watcher ships it into the loop exactly once,
//! and the consumer-side buffer serves all later iterations locally. It
//! also *generalizes* the §7 build-side reuse: a hoisted build side keeps
//! a step-independent bag identity, so the join's hash table survives
//! every step without the runtime having to special-case joins.
//!
//! Loops are processed innermost-first; a node invariant w.r.t. several
//! nested loops migrates outward across pass-manager rounds (the preamble
//! of an inner loop is the outer loop's body).

use super::analysis::PlanAnalysis;
use super::{refresh_edges, Pass, PassOutcome, Speculate};
use crate::dataflow::DataflowGraph;
use crate::error::Result;

/// The hoisting pass. Speculative chains (`NamedSource` / `XlaCall`, see
/// [`super::analysis::is_speculative_op`]) are gated through the cost
/// model: with [`Speculate::Auto`] they hoist only when the enclosing
/// loop's estimated trip count × the chain's estimated rows clears
/// `threshold`, so a provably zero-trip loop never pays (or panics for)
/// speculated work.
pub struct HoistPass {
    /// Speculation policy (`opt.speculate`).
    pub speculate: Speculate,
    /// Minimum `trips × rows` for a speculative hoist
    /// (`opt.speculate_threshold`).
    pub threshold: f64,
    /// Trip-count fallback when the loop bound is data-dependent
    /// (`opt.default_trips`).
    pub default_trips: u64,
}

impl Default for HoistPass {
    fn default() -> Self {
        let d = super::OptConfig::default();
        HoistPass {
            speculate: d.speculate,
            threshold: d.speculate_threshold,
            default_trips: d.default_trips,
        }
    }
}

impl Pass for HoistPass {
    fn name(&self) -> &'static str {
        "hoist"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        // Innermost loops first (smallest body): nodes escape one nesting
        // level per iteration of this ordering, and what lands in an inner
        // preamble is immediately considered by the enclosing loop.
        let mut order: Vec<usize> = (0..a.loops.loops.len()).collect();
        order.sort_by_key(|&i| a.loops.loops[i].body.len());
        for &li in &order {
            let l = &a.loops.loops[li];
            let Some(pre) = a.preheader(g, l) else {
                continue; // no unique entry edge — skip this loop
            };
            let (hoistable, gated) = a.invariant_hoistable_gated(
                g,
                li,
                self.speculate,
                self.threshold,
                self.default_trips,
            );
            if gated > 0 {
                out.skipped += gated;
                out.details.push(format!(
                    "{gated} node(s) kept in loop hdr bb{}: speculative chain below cost gate",
                    l.header
                ));
            }
            for nid in hoistable {
                let n = &mut g.nodes[nid];
                out.details.push(format!(
                    "{} [{}] bb{} -> bb{pre} (loop hdr bb{})",
                    n.name,
                    n.op.mnemonic(),
                    n.block,
                    l.header
                ));
                if n.hoisted_from.is_none() {
                    n.hoisted_from = Some(n.block);
                }
                n.block = pre;
                out.changed += 1;
            }
        }
        if out.changed > 0 {
            refresh_edges(g);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{parse_and_lower, Rhs};
    use crate::opt::{verify_integrity, OptConfig};

    fn hoisted_graph(src: &str) -> (DataflowGraph, PassOutcome) {
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let out = HoistPass::default().run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        (g, out)
    }

    #[test]
    fn invariant_chain_moves_to_preamble() {
        let (g, out) = hoisted_graph(
            "d = 1; while (d <= 3) { v = bag(1, 2).map(|x| x * 10); collect(v, \"v\"); d = d + 1; }",
        );
        assert!(out.changed >= 2, "bag literal + map should hoist: {:?}", out.details);
        let map = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Map { .. }) && !n.singleton)
            .unwrap();
        let from = map.hoisted_from.expect("map marked hoisted");
        assert_ne!(map.block, from, "block actually changed");
        // The preamble block is outside every loop.
        let a = PlanAnalysis::compute(&g);
        assert_eq!(a.loops.depth[map.block], 0, "preamble is outside the loop");
        // The collect stayed in the loop and now reads cross-block.
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        assert!(col.hoisted_from.is_none());
        assert!(col.inputs[0].conditional);
        assert_eq!(col.inputs[0].src_block, map.block);
    }

    #[test]
    fn condition_and_phi_nodes_never_move() {
        let (g, _) = hoisted_graph(
            "d = 1; while (d <= 3) { v = bag(9).map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        );
        for n in &g.nodes {
            if n.cond.is_some() || matches!(n.op, Rhs::Phi(_)) {
                assert!(n.hoisted_from.is_none(), "{} must not move", n.name);
            }
        }
    }

    #[test]
    fn varying_nodes_stay_in_the_loop() {
        let (g, _) = hoisted_graph(
            "d = 1; while (d <= 3) { v = bag(1, 2).map(|x| x + d); collect(v, \"v\"); d = d + 1; }",
        );
        // The capture of `d` desugars into a cross with the loop counter;
        // the cross and everything downstream of it must stay put.
        for n in &g.nodes {
            if matches!(n.op, Rhs::Cross { .. }) && n.hoisted_from.is_some() {
                // A cross is only hoistable when BOTH sides are invariant.
                let a = PlanAnalysis::compute(&g);
                for inp in &n.inputs {
                    assert_eq!(a.loops.depth[g.nodes[inp.src].block], 0, "{}", n.name);
                }
            }
        }
    }

    #[test]
    fn straightline_program_is_untouched() {
        let (g, out) = hoisted_graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");");
        assert_eq!(out.changed, 0);
        assert!(g.nodes.iter().all(|n| n.hoisted_from.is_none()));
    }

    #[test]
    fn zero_trip_loop_gates_speculative_source() {
        // The loop provably never runs: the source (and its dependent
        // chain) must stay in the body under the default Auto gate...
        let src = "d = 9; while (d < 3) { v = source(\"hoist_gate_unregistered\").map(|x| x + 1); collect(v, \"v\"); d = d + 1; } collect(bag(1), \"ok\");";
        let (g, out) = hoisted_graph(src);
        assert!(out.skipped > 0, "gate should report skips: {:?}", out.details);
        for n in &g.nodes {
            if matches!(n.op, Rhs::NamedSource(_)) {
                assert!(n.hoisted_from.is_none(), "zero-trip source must not hoist");
            }
        }
        // ...while `always` restores the old speculation contract.
        let p = parse_and_lower(src).unwrap();
        let (mut g2, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g2);
        let always = HoistPass { speculate: crate::opt::Speculate::Always, ..HoistPass::default() };
        always.run(&mut g2, &a).unwrap();
        assert!(
            g2.nodes
                .iter()
                .any(|n| matches!(n.op, Rhs::NamedSource(_)) && n.hoisted_from.is_some()),
            "speculate=always hoists regardless of trip count"
        );
    }

    #[test]
    fn unknown_trip_loop_keeps_unregistered_source_lazy() {
        // The bound is data-dependent (count of an empty bag → 0 at
        // runtime), so the trip estimate is Unknown. An UNREGISTERED
        // source would panic if speculated — it must stay in the loop
        // even though the default-trips threshold test would pass.
        let (g, _) = hoisted_graph(
            "n = bag().count(); d = 0; while (d < n) { v = source(\"hoist_gate_unknown\").map(|x| x + 1); collect(v, \"v\"); d = d + 1; } collect(bag(1), \"ok\");",
        );
        for n in &g.nodes {
            if matches!(n.op, Rhs::NamedSource(_)) {
                assert!(n.hoisted_from.is_none(), "unknown-trip unregistered source must not hoist");
            }
        }
    }

    #[test]
    fn positive_trip_loop_still_hoists_sources() {
        crate::workload::registry::global()
            .put("hoist_gate_registered", vec![crate::value::Value::I64(1), crate::value::Value::I64(2)]);
        let (g, _) = hoisted_graph(
            "d = 1; while (d <= 3) { v = source(\"hoist_gate_registered\").map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        );
        assert!(
            g.nodes
                .iter()
                .any(|n| matches!(n.op, Rhs::NamedSource(_)) && n.hoisted_from.is_some()),
            "3-trip loop over a 2-row source clears the default gate"
        );
        crate::workload::registry::global().clear_prefix("hoist_gate_registered");
    }

    #[test]
    fn nested_loops_hoist_across_rounds() {
        // bag(5) is invariant w.r.t. BOTH loops; one HoistPass run moves it
        // out of the inner loop, and because loops are processed
        // innermost-first the same run carries it out of the outer loop.
        let src = r#"
            i = 0;
            while (i < 2) {
                j = 0;
                while (j < 2) {
                    z = bag(5).map(|v| v * 2);
                    collect(z, "z");
                    j = j + 1;
                }
                i = i + 1;
            }
        "#;
        let (g, out) = hoisted_graph(src);
        assert!(out.changed > 0, "{:?}", out.details);
        let a = PlanAnalysis::compute(&g);
        let map = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Map { .. }) && n.hoisted_from.is_some())
            .expect("hoisted map");
        assert_eq!(a.loops.depth[map.block], 0, "escaped both loops");
    }
}
