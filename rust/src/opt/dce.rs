//! Dead-operator elimination: drop dataflow nodes whose outputs reach no
//! sink (`collect`/`writeFile`), condition node, or Φ. Such nodes compute
//! bags nobody observes — every step they cost output-bag bookkeeping,
//! close markers, and (worst) retained conditional-output buffers.
//!
//! The SSA-level DCE already prunes most dead *variables*; this pass is
//! the graph-level safety net that catches operators orphaned by later
//! graph rewrites (and keeps the optimizer closed under composition).

use super::analysis::PlanAnalysis;
use super::{compact, Pass, PassOutcome};
use crate::dataflow::DataflowGraph;
use crate::error::Result;

/// The dead-operator elimination pass.
pub struct DcePass;

impl Pass for DcePass {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        if a.live.iter().all(|&l| l) {
            return Ok(out);
        }
        for n in &g.nodes {
            if !a.live[n.id] {
                out.details.push(format!("{} [{}] bb{}", n.name, n.op.mnemonic(), n.block));
                out.changed += 1;
            }
        }
        compact(g, &a.live);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{InputSpec, Node, Par, Route};
    use crate::frontend::{parse_and_lower, Rhs, Udf1};
    use crate::opt::{verify_integrity, OptConfig};
    use crate::value::Value;

    #[test]
    fn live_graph_is_untouched() {
        let p = parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");").unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let before = g.num_nodes();
        let a = PlanAnalysis::compute(&g);
        let out = DcePass.run(&mut g, &a).unwrap();
        assert_eq!(out.changed, 0);
        assert_eq!(g.num_nodes(), before);
    }

    #[test]
    fn orphaned_operator_chain_is_removed() {
        // SSA DCE never sees these: graft a dead map chain onto the built
        // graph, the way a (hypothetical buggy or future) rewrite might
        // leave operators behind.
        let p = parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");").unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let src = g.nodes.iter().find(|n| matches!(n.op, Rhs::BagLit(_))).unwrap();
        let (src_id, src_var, src_block) = (src.id, src.var, src.block);
        let dead_var = g.node_of_var.len() + 100; // fresh var id
        let id = g.nodes.len();
        g.nodes.push(Node {
            id,
            name: "dead".into(),
            var: dead_var,
            block: src_block,
            op: Rhs::Map { input: src_var, udf: Udf1::new("id", |v: &Value| v.clone()) },
            par: Par::All,
            inputs: vec![InputSpec {
                src: src_id,
                src_block,
                route: Route::Forward,
                conditional: false,
            }],
            cond: None,
            singleton: false,
            hoisted_from: None,
            size_hint: None,
            elem_hint: None,
            build_side: None,
            delta: None,
        });
        g.node_of_var.insert(dead_var, id);
        verify_integrity(&g).unwrap();

        let before = g.num_nodes();
        let a = PlanAnalysis::compute(&g);
        let out = DcePass.run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        assert_eq!(out.changed, 1, "{:?}", out.details);
        assert_eq!(g.num_nodes(), before - 1);
        assert!(!g.nodes.iter().any(|n| n.name == "dead"));
    }

    #[test]
    fn phi_and_condition_nodes_are_roots() {
        let p = parse_and_lower(
            "d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");",
        )
        .unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let before = g.num_nodes();
        let a = PlanAnalysis::compute(&g);
        DcePass.run(&mut g, &a).unwrap();
        assert_eq!(g.num_nodes(), before, "the loop-control machinery is all live");
    }
}
