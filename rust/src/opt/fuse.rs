//! Operator fusion: collapse maximal linear chains of pipelineable
//! element-wise operators into one fused physical operator
//! ([`crate::ops::fused::FusedT`]).
//!
//! A chain `a.map(f).filter(p).map(g)` costs, per iteration step, three
//! output bags (three opens/closes, three sets of coordination messages)
//! and a channel batch hop per stage. Fused, it is ONE node: one bag, one
//! set of closes, and per element a single dispatch through all stages.
//!
//! An edge `u -> v` is fusable when:
//! * both ends are element-wise (`map`/`filter`/`flatMap`, or an already
//!   fused chain) and not condition nodes,
//! * `v` is `u`'s only consumer and `u` is `v`'s only input,
//! * the edge stays inside one basic block (non-conditional) and routes
//!   `Forward` (same parallelism, partition-preserving).

use super::analysis::PlanAnalysis;
use super::{compact, Pass, PassOutcome};
use crate::dataflow::{DataflowGraph, Node, NodeId, Route};
use crate::error::Result;
use crate::frontend::{FusedStage, Rhs};

/// The fusion pass.
pub struct FusePass;

/// Shared with [`super::xfuse`]: a non-condition node computing a pure
/// per-element transformation of its single input.
pub(crate) fn elementwise(n: &Node) -> bool {
    n.cond.is_none()
        && n.inputs.len() == 1
        && matches!(
            n.op,
            Rhs::Map { .. } | Rhs::Filter { .. } | Rhs::FlatMap { .. } | Rhs::Fused { .. }
        )
}

/// The stages a node contributes to a fused chain (already-fused nodes
/// splice their stages, so repeated rounds stay flat).
pub(crate) fn stages_of(op: &Rhs) -> Vec<FusedStage> {
    match op {
        Rhs::Map { udf, .. } => vec![FusedStage::Map(udf.clone())],
        Rhs::Filter { udf, .. } => vec![FusedStage::Filter(udf.clone())],
        Rhs::FlatMap { udf, .. } => vec![FusedStage::FlatMap(udf.clone())],
        Rhs::Fused { stages, .. } => stages.clone(),
        other => unreachable!("non-elementwise op in chain: {}", other.mnemonic()),
    }
}

/// Per-stage lineage a node contributes: the pre-fusion SSA node name
/// producing each stage's output (parallel to [`stages_of`]). Adaptive
/// feedback uses it to map observed cardinalities back onto the fresh,
/// pre-fusion graph on a recompile.
pub(crate) fn lineage_of(n: &Node) -> Vec<String> {
    match &n.op {
        Rhs::Map { .. } | Rhs::Filter { .. } | Rhs::FlatMap { .. } => vec![n.name.clone()],
        Rhs::Fused { lineage, .. } => lineage.clone(),
        other => unreachable!("non-elementwise op in chain: {}", other.mnemonic()),
    }
}

fn fusable_edge(g: &DataflowGraph, up: NodeId, down: &Node) -> bool {
    let e = &down.inputs[0];
    e.src == up && !e.conditional && e.route == Route::Forward && g.nodes[up].block == down.block
}

impl Pass for FusePass {
    fn name(&self) -> &'static str {
        "fuse"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        let n = g.nodes.len();
        let mut removed = vec![false; n];
        for f in 0..n {
            if removed[f] || !elementwise(&g.nodes[f]) {
                continue;
            }
            // Chain head: the producer is not itself fusable into `f`.
            let p = g.nodes[f].inputs[0].src;
            let head = !(elementwise(&g.nodes[p])
                && a.consumers[p].len() == 1
                && fusable_edge(g, p, &g.nodes[f]));
            if !head {
                continue;
            }
            // Extend the maximal chain downstream of `f`.
            let mut chain = vec![f];
            let mut cur = f;
            loop {
                let [(c, _)] = a.consumers[cur].as_slice() else { break };
                let cn = &g.nodes[*c];
                if !elementwise(cn) || !fusable_edge(g, cur, cn) {
                    break;
                }
                chain.push(*c);
                cur = *c;
            }
            if chain.len() < 2 {
                continue;
            }
            // Replace the tail in place (its id/var stay valid for every
            // downstream consumer); the other members are merged away.
            let stages: Vec<FusedStage> =
                chain.iter().flat_map(|&id| stages_of(&g.nodes[id].op)).collect();
            let lineage: Vec<String> =
                chain.iter().flat_map(|&id| lineage_of(&g.nodes[id])).collect();
            debug_assert_eq!(stages.len(), lineage.len());
            let head_id = chain[0];
            let input_var = g.nodes[head_id].op.input_vars()[0];
            let head_inputs = g.nodes[head_id].inputs.clone();
            let head_hoisted = g.nodes[head_id].hoisted_from;
            out.details.push(format!(
                "{} (bb{}, {} stages): {}",
                g.nodes[*chain.last().unwrap()].name,
                g.nodes[head_id].block,
                stages.len(),
                chain.iter().map(|&id| g.nodes[id].name.clone()).collect::<Vec<_>>().join(" -> ")
            ));
            let tail = *chain.last().unwrap();
            let t = &mut g.nodes[tail];
            t.op = Rhs::Fused { input: input_var, stages, lineage };
            t.inputs = head_inputs;
            t.hoisted_from = t.hoisted_from.or(head_hoisted);
            for &id in &chain[..chain.len() - 1] {
                removed[id] = true;
                out.changed += 1;
            }
        }
        if out.changed > 0 {
            let keep: Vec<bool> = removed.iter().map(|&r| !r).collect();
            compact(g, &keep);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;
    use crate::opt::{verify_integrity, OptConfig};

    fn fused_graph(src: &str) -> (DataflowGraph, PassOutcome) {
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let out = FusePass.run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        (g, out)
    }

    #[test]
    fn linear_chain_collapses_to_one_node() {
        let (g, out) = fused_graph(
            "a = bag(1, 2, 3); b = a.map(|x| x + 1).filter(|x| x > 2).map(|x| x * 10); collect(b, \"b\");",
        );
        assert_eq!(out.changed, 2, "{:?}", out.details);
        assert_eq!(out.details.len(), 1);
        let fused = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Fused { .. }))
            .expect("fused node");
        let Rhs::Fused { ref stages, .. } = fused.op else { unreachable!() };
        assert_eq!(stages.len(), 3);
        // bagLit + fused + collect.
        assert_eq!(g.num_nodes(), 3);
        let col = g.nodes.iter().find(|n| matches!(n.op, Rhs::Collect { .. })).unwrap();
        assert_eq!(col.inputs[0].src, fused.id);
    }

    #[test]
    fn lineage_records_pre_fusion_names_in_stage_order() {
        let src = "a = bag(1, 2); b = a.map(|x| x + 1); c = b.filter(|x| x > 0); d = c.map(|x| x * 2); collect(d, \"d\");";
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        // Pre-fusion names of the chain, in order.
        let want: Vec<String> = g
            .nodes
            .iter()
            .filter(|n| {
                matches!(n.op, Rhs::Map { .. } | Rhs::Filter { .. }) && !n.singleton
            })
            .map(|n| n.name.clone())
            .collect();
        assert_eq!(want.len(), 3);
        let a = PlanAnalysis::compute(&g);
        FusePass.run(&mut g, &a).unwrap();
        let Rhs::Fused { ref stages, ref lineage, .. } = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Fused { .. }))
            .unwrap()
            .op
        else {
            unreachable!()
        };
        assert_eq!(stages.len(), lineage.len());
        assert_eq!(lineage, &want, "lineage is the pre-fusion names, stage-parallel");
        // Repeated fusion splices lineage flat alongside stages.
        let a2 = PlanAnalysis::compute(&g);
        FusePass.run(&mut g, &a2).unwrap();
    }

    #[test]
    fn shared_intermediate_blocks_fusion() {
        // `b` has two consumers — the chain must break there.
        let (g, _) = fused_graph(
            "a = bag(1, 2); b = a.map(|x| x + 1); c = b.map(|x| x * 2); collect(b, \"b\"); collect(c, \"c\");",
        );
        assert!(
            !g.nodes.iter().any(|n| matches!(n.op, Rhs::Fused { .. })),
            "no chain should fuse across a shared intermediate"
        );
    }

    #[test]
    fn condition_nodes_are_never_fused() {
        let (g, _) = fused_graph(
            "d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");",
        );
        for n in &g.nodes {
            if matches!(n.op, Rhs::Fused { .. }) {
                assert!(n.cond.is_none());
            }
        }
        assert_eq!(g.condition_nodes().len(), 1, "condition node survives fusion");
    }

    #[test]
    fn fused_graph_executes_like_the_oracle() {
        let src = "a = bag(1, 2, 3, 4, 5); b = a.map(|x| x + 1).filter(|x| x % 2 == 0).map(|x| x * 10); collect(b, \"b\");";
        let program = parse_and_lower(src).unwrap();
        let oracle = crate::baselines::single_thread::run(&program, &Default::default()).unwrap();
        let (g, out) = {
            let (mut g, _) = crate::compile_with(&program, &OptConfig::none()).unwrap();
            let a = PlanAnalysis::compute(&g);
            let out = FusePass.run(&mut g, &a).unwrap();
            (g, out)
        };
        assert!(out.changed > 0);
        let run = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = run.collected("b").to_vec();
        let mut want = oracle.collected("b").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn repeated_fusion_splices_stages_flat() {
        let src = "a = bag(1, 2); b = a.map(|x| x + 1).map(|x| x + 2).map(|x| x + 3).map(|x| x + 4); collect(b, \"b\");";
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        // Two consecutive runs: the second must find nothing left to do.
        let a = PlanAnalysis::compute(&g);
        FusePass.run(&mut g, &a).unwrap();
        let a2 = PlanAnalysis::compute(&g);
        let again = FusePass.run(&mut g, &a2).unwrap();
        assert_eq!(again.changed, 0);
        let Rhs::Fused { ref stages, .. } = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Fused { .. }))
            .unwrap()
            .op
        else {
            unreachable!()
        };
        assert_eq!(stages.len(), 4, "stages stay flat, not nested");
    }
}
