//! Join build-side selection: pick which input of each hash join becomes
//! the build side, from the `opt::cost` model. The logical left input is
//! the §5.3 default; this pass annotates `Node::build_side` when the
//! estimates say the other side is cheaper to build, `ExecPlan` copies
//! the annotation, and `ops::join::HashJoinT` honors it (output pair
//! order is unchanged, so the choice is invisible to program semantics).
//!
//! The cost of building on side `s` for a join executing inside a loop
//! with `T` estimated trips is
//!
//! ```text
//! cost(s) = BUILD_WEIGHT · rows(s) · (invariant(s) ? 1 : T)   (build)
//!         + rows(other)  · T                                  (probe)
//! ```
//!
//! Building is weighted heavier than probing (hash-table inserts +
//! per-step retention beat streaming), and a loop-invariant build side is
//! paid once per loop entry thanks to `opt::hoist` + the §7 runtime
//! reuse, while a loop-varying build side rebuilds every iteration. This
//! makes the pass prefer (a) the invariant side when one exists — keeping
//! the Fig. 8 cross-step hash-table reuse alive — and (b) the smaller
//! side outside loops, the classic textbook rule. A flip needs a clear
//! margin (`MARGIN`) so near-ties never oscillate.

use super::analysis::PlanAnalysis;
use super::{Pass, PassOutcome};
use crate::dataflow::DataflowGraph;
use crate::error::Result;
use crate::frontend::Rhs;

/// Relative cost advantage required before flipping away from the
/// current choice (hysteresis for estimate noise).
const MARGIN: f64 = 0.9;

/// Hash-table build cost per row, relative to streaming a probe row.
const BUILD_WEIGHT: f64 = 2.0;

/// The build-side selection pass.
pub struct JoinSidePass {
    /// Trip-count fallback for data-dependent loops
    /// (`opt.default_trips`).
    pub default_trips: u64,
}

impl Default for JoinSidePass {
    fn default() -> Self {
        JoinSidePass { default_trips: super::OptConfig::default().default_trips }
    }
}

impl Pass for JoinSidePass {
    fn name(&self) -> &'static str {
        "joinside"
    }

    fn run(&self, g: &mut DataflowGraph, a: &PlanAnalysis) -> Result<PassOutcome> {
        let mut out = PassOutcome::default();
        for id in 0..g.nodes.len() {
            if !matches!(g.nodes[id].op, Rhs::Join { .. }) {
                continue;
            }
            let n = &g.nodes[id];
            // Innermost loop the join executes in (smallest body wins).
            let enclosing = a
                .loops
                .loops
                .iter()
                .enumerate()
                .filter(|(_, l)| l.body.binary_search(&n.block).is_ok())
                .min_by_key(|(_, l)| l.body.len());
            let trips = match enclosing {
                None => 1.0,
                Some((li, _)) => a
                    .cost
                    .trips
                    .get(li)
                    .copied()
                    .unwrap_or(super::cost::TripCount::Unknown)
                    .or_default(self.default_trips)
                    .max(1) as f64,
            };
            let invariant = |side: usize| -> bool {
                match enclosing {
                    None => true,
                    Some((_, l)) => l.body.binary_search(&n.inputs[side].src_block).is_err(),
                }
            };
            let rows = |side: usize| a.cost.rows[n.inputs[side].src];
            let cost = |side: usize| -> f64 {
                let build = BUILD_WEIGHT * rows(side) * if invariant(side) { 1.0 } else { trips };
                let probe = rows(1 - side) * trips;
                build + probe
            };
            let current = n.build_side.unwrap_or(0);
            let flipped = 1 - current;
            let desired = if cost(flipped) < MARGIN * cost(current) { flipped } else { current };
            if desired == current {
                continue;
            }
            let detail = format!(
                "{}: build side {} -> {} (rows l≈{:.0} r≈{:.0}, trips≈{:.0})",
                n.name,
                if current == 0 { "left" } else { "right" },
                if desired == 0 { "left" } else { "right" },
                rows(0),
                rows(1),
                trips,
            );
            out.details.push(detail);
            out.changed += 1;
            g.nodes[id].build_side = Some(desired);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::single_thread;
    use crate::exec::{run, ExecConfig, ExecPlan};
    use crate::frontend::parse_and_lower;
    use crate::opt::{verify_integrity, OptConfig};
    use crate::value::Value;
    use std::sync::Arc;

    fn selected(src: &str) -> (DataflowGraph, PassOutcome) {
        let p = parse_and_lower(src).unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let out = JoinSidePass::default().run(&mut g, &a).unwrap();
        verify_integrity(&g).unwrap();
        (g, out)
    }

    fn put(name: &str, n: i64) {
        crate::workload::registry::global()
            .put(name, (0..n).map(Value::I64).collect());
    }

    #[test]
    fn big_build_side_flips_to_small() {
        put("js_big", 512);
        put("js_small", 8);
        // joinBuild: the receiver (big) is the build side — pathological.
        let (g, out) = selected(
            "big = source(\"js_big\").map(|v| pair(v % 8, v)); small = source(\"js_small\").map(|v| pair(v % 8, v)); j = big.joinBuild(small); collect(j, \"j\");",
        );
        assert_eq!(out.changed, 1, "{:?}", out.details);
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        assert_eq!(join.build_side, Some(1), "build moves to the small right side");
        // The exec plan copies the annotation.
        let plan = ExecPlan::new(Arc::new(g.clone()), 2);
        assert_eq!(plan.join_build[join.id], 1);
        crate::workload::registry::global().clear_prefix("js_");
    }

    #[test]
    fn small_build_side_is_kept() {
        put("js2_big", 512);
        put("js2_small", 8);
        // join: the argument (small) is already the build side.
        let (g, out) = selected(
            "big = source(\"js2_big\").map(|v| pair(v % 8, v)); small = source(\"js2_small\").map(|v| pair(v % 8, v)); j = big.join(small); collect(j, \"j\");",
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        assert_eq!(join.build_side, None);
        crate::workload::registry::global().clear_prefix("js2_");
    }

    #[test]
    fn invariant_side_preferred_inside_loops() {
        // Inside a 10-trip loop the invariant (even slightly larger)
        // side stays the build: rebuilding the varying side every step
        // would beat it only at implausible size ratios.
        put("js3_dim", 64);
        let (g, out) = selected(
            r#"
            dim = source("js3_dim").map(|v| pair(v % 8, v));
            i = 0;
            while (i < 10) {
                probe = bag(1, 2, 3, 4, 5, 6, 7, 8).map(|v| pair((v + i) % 8, v));
                j = probe.join(dim);
                collect(j, "j");
                i = i + 1;
            }
            "#,
        );
        assert_eq!(out.changed, 0, "{:?}", out.details);
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        assert_eq!(join.build_side, None, "invariant dim stays the build side");
        crate::workload::registry::global().clear_prefix("js3_");
    }

    #[test]
    fn decision_is_stable_across_reruns() {
        put("js4_big", 512);
        put("js4_small", 8);
        let p = parse_and_lower(
            "big = source(\"js4_big\").map(|v| pair(v % 8, v)); small = source(\"js4_small\").map(|v| pair(v % 8, v)); j = big.joinBuild(small); collect(j, \"j\");",
        )
        .unwrap();
        let (mut g, _) = crate::compile_with(&p, &OptConfig::none()).unwrap();
        let a = PlanAnalysis::compute(&g);
        let first = JoinSidePass::default().run(&mut g, &a).unwrap();
        assert_eq!(first.changed, 1);
        let a2 = PlanAnalysis::compute(&g);
        let second = JoinSidePass::default().run(&mut g, &a2).unwrap();
        assert_eq!(second.changed, 0, "no oscillation: {:?}", second.details);
        crate::workload::registry::global().clear_prefix("js4_");
    }

    #[test]
    fn flipped_build_side_matches_oracle() {
        put("js5_big", 64);
        put("js5_small", 4);
        let src = "big = source(\"js5_big\").map(|v| pair(v % 4, v)); small = source(\"js5_small\").map(|v| pair(v % 4, v * 10)); j = big.joinBuild(small); collect(j, \"j\");";
        let program = parse_and_lower(src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (g, out) = selected(src);
        assert_eq!(out.changed, 1);
        let res = run(&g, &ExecConfig::default()).unwrap();
        let mut got = res.collected("j").to_vec();
        let mut want = oracle.collected("j").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want, "pair order must survive the flip");
        crate::workload::registry::global().clear_prefix("js5_");
    }
}
