//! Baseline executors reproducing the systems Labyrinth is evaluated
//! against (§9): client-side control flow with one dataflow job per step
//! (Spark/Flink batch style — over the raw pre-SSA IR in
//! [`separate_jobs`], over the **optimized dataflow graph** in
//! [`graph_jobs`] so optimizer wins show in the comparisons),
//! in-dataflow *fixpoint-only* iteration (Flink iterate / Naiad style),
//! and the single-threaded COST baseline [McSherry et al.]. All run the
//! same IR over the same workloads as the Labyrinth engine, so
//! cross-executor results are directly comparable (and `single_thread`
//! doubles as the correctness oracle).

pub mod fixpoint;
pub mod graph_jobs;
pub mod separate_jobs;
pub mod single_thread;

use crate::value::Value;
use rustc_hash::FxHashMap;
use std::time::Duration;

/// Output of a baseline run.
#[derive(Debug, Default)]
pub struct BaselineRun {
    /// Collected bags by label (step order).
    pub collected: FxHashMap<String, Vec<Value>>,
    /// Total wall time.
    pub elapsed: Duration,
    /// Time spent in simulated job scheduling (separate-jobs only).
    pub sched_time: Duration,
    /// Number of dataflow jobs launched (separate-jobs only).
    pub jobs_launched: usize,
    /// Tasks dispatched per operator mnemonic across all jobs
    /// (`graph_jobs` only — Spark-stage-style accounting: every bag
    /// operator in a job fans out `workers × tasks_per_slot` tasks).
    pub tasks_by_op: FxHashMap<&'static str, u64>,
}

impl BaselineRun {
    /// Collected bag for a label.
    pub fn collected(&self, label: &str) -> &[Value] {
        self.collected.get(label).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total tasks dispatched across all operators (0 for executors
    /// that do not account tasks).
    pub fn tasks_launched(&self) -> u64 {
        self.tasks_by_op.values().sum()
    }
}
