//! Separate-dataflow-jobs executor (§3.2): control flow runs in the
//! *client*; every basic block that contains bag operations is submitted
//! as a fresh dataflow job through the centralized-scheduler substrate,
//! paying the per-job launch cost each time. Two styles:
//!
//! * **Spark-like** — datasets stay partitioned on the "cluster" between
//!   jobs (`.cache()`; the user must know to persist, §3.2).
//! * **Flink-like** — the paper's Flink batch setup has no cache: results
//!   are collected to the driver after each job and re-scattered into the
//!   next one, adding a copy per step (§9.1.2).
//!
//! No cross-job operator state exists, so a hash-join's build side is
//! rebuilt every step (the missed optimization of §3.2.2 / Fig. 8).

use super::BaselineRun;
use crate::error::{Error, Result};
use crate::frontend::{Program, Rhs, Terminator, VarId};
use crate::sched::LatencyModel;
use crate::value::Value;
use rustc_hash::FxHashMap;
use std::sync::Arc;
use std::time::Instant;

/// Cross-job dataset persistence style.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PersistStyle {
    /// Partitions stay on the cluster between jobs (Spark `.cache()`).
    SparkCache,
    /// Collect to the driver each job, re-scatter next job (Flink batch).
    FlinkCollect,
}

/// Executor configuration.
#[derive(Clone, Debug)]
pub struct SeparateJobsConfig {
    /// Simulated worker count.
    pub workers: usize,
    /// Scheduler latency model.
    pub model: LatencyModel,
    /// Persistence style.
    pub persist: PersistStyle,
    /// Safety bound on executed basic blocks.
    pub max_blocks: usize,
    /// Base directory for file I/O.
    pub io_dir: std::path::PathBuf,
}

impl SeparateJobsConfig {
    /// Spark-like defaults.
    pub fn spark(workers: usize) -> SeparateJobsConfig {
        SeparateJobsConfig {
            workers,
            model: LatencyModel::spark_like(),
            persist: PersistStyle::SparkCache,
            max_blocks: 10_000_000,
            io_dir: std::path::PathBuf::from("."),
        }
    }
    /// Flink-like defaults.
    pub fn flink(workers: usize) -> SeparateJobsConfig {
        SeparateJobsConfig {
            workers,
            model: LatencyModel::flink_like(),
            persist: PersistStyle::FlinkCollect,
            max_blocks: 10_000_000,
            io_dir: std::path::PathBuf::from("."),
        }
    }
}

/// A partitioned (cached) dataset.
pub(crate) type Partitions = Arc<Vec<Vec<Value>>>;

#[derive(Clone, Debug)]
enum Binding {
    Scalar(Value),
    /// Spark-like: resident partitioned dataset.
    Cached(Partitions),
    /// Flink-like: dataset held at the driver between jobs.
    AtDriver(Arc<Vec<Value>>),
}

/// Run a program with client-side control flow + per-block jobs.
pub fn run(program: &Program, cfg: &SeparateJobsConfig) -> Result<BaselineRun> {
    let start = Instant::now();
    let mut env: FxHashMap<VarId, Binding> = FxHashMap::default();
    let mut out = BaselineRun::default();
    let registry = crate::workload::registry::global();
    let w = cfg.workers.max(1);

    let mut block = program.entry;
    let mut executed = 0usize;
    loop {
        executed += 1;
        if executed > cfg.max_blocks {
            return Err(Error::Baseline("block budget exceeded".into()));
        }
        let blk = &program.blocks[block];
        let bag_ops = blk
            .instrs
            .iter()
            .filter(|i| is_bag_op(&i.rhs))
            .count();
        if bag_ops > 0 {
            // === submit one dataflow job for this step ===
            out.jobs_launched += 1;
            out.sched_time += cfg.model.simulate_job_launch(bag_ops, w);
        }
        for instr in &blk.instrs {
            let b = eval(&instr.rhs, &mut env, &registry, cfg, &mut out, w)?;
            env.insert(instr.var, b);
        }
        if bag_ops > 0 && cfg.persist == PersistStyle::FlinkCollect {
            // Flink batch: ship every dataset produced by this job back to
            // the driver (the paper "collected the bag to the driver at
            // each step", §9.1.2).
            for instr in &blk.instrs {
                if let Some(Binding::Cached(parts)) = env.get(&instr.var) {
                    let gathered: Vec<Value> =
                        parts.iter().flat_map(|p| p.iter().cloned()).collect();
                    env.insert(instr.var, Binding::AtDriver(Arc::new(gathered)));
                }
            }
        }
        match &blk.term {
            Terminator::End => break,
            Terminator::Jump(t) => block = *t,
            Terminator::Branch { cond, then_b, else_b } => {
                let v = match env.get(cond) {
                    Some(Binding::Scalar(v)) => v.clone(),
                    other => {
                        return Err(Error::Baseline(format!("branch on non-scalar {other:?}")))
                    }
                };
                block = if v.as_bool() { *then_b } else { *else_b };
            }
        }
    }
    out.elapsed = start.elapsed();
    Ok(out)
}

fn is_bag_op(rhs: &Rhs) -> bool {
    !matches!(
        rhs,
        Rhs::Const(_) | Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. }
    )
}

/// Materialize a binding as partitions for the next job (re-scattering
/// driver-resident data, which is where Flink-style pays its copy).
fn partitions_of(b: &Binding, w: usize) -> Result<Partitions> {
    match b {
        Binding::Cached(p) => Ok(p.clone()),
        Binding::AtDriver(items) => Ok(Arc::new(scatter(items, w))),
        Binding::Scalar(v) => Err(Error::Baseline(format!("expected bag, got scalar {v:?}"))),
    }
}

pub(crate) fn scatter(items: &[Value], w: usize) -> Vec<Vec<Value>> {
    let mut parts = vec![Vec::with_capacity(items.len() / w + 1); w];
    for (i, v) in items.iter().enumerate() {
        parts[i % w].push(v.clone());
    }
    parts
}

pub(crate) fn hash_repartition(parts: &[Vec<Value>], w: usize) -> Vec<Vec<Value>> {
    let mut out = vec![Vec::new(); w];
    for p in parts {
        for v in p {
            out[(v.key_hash() as usize) % w].push(v.clone());
        }
    }
    out
}

/// Run `f` over partitions in parallel (one thread per worker).
pub(crate) fn par_map_partitions(
    parts: &[Vec<Value>],
    f: impl Fn(&[Value]) -> Vec<Value> + Sync,
) -> Vec<Vec<Value>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = parts
            .iter()
            .map(|p| {
                let f = &f;
                s.spawn(move || f(p))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("partition thread")).collect()
    })
}

fn eval(
    rhs: &Rhs,
    env: &mut FxHashMap<VarId, Binding>,
    registry: &crate::workload::registry::Registry,
    cfg: &SeparateJobsConfig,
    out: &mut BaselineRun,
    w: usize,
) -> Result<Binding> {
    let getb = |env: &FxHashMap<VarId, Binding>, v: &VarId| -> Result<Partitions> {
        partitions_of(
            env.get(v).ok_or_else(|| Error::Baseline(format!("unbound var {v}")))?,
            w,
        )
    };
    let gets = |env: &FxHashMap<VarId, Binding>, v: &VarId| -> Result<Value> {
        match env.get(v) {
            Some(Binding::Scalar(x)) => Ok(x.clone()),
            other => Err(Error::Baseline(format!("expected scalar, got {other:?}"))),
        }
    };
    Ok(match rhs {
        Rhs::Const(v) => Binding::Scalar(v.clone()),
        Rhs::Copy(v) => env
            .get(v)
            .cloned()
            .ok_or_else(|| Error::Baseline(format!("copy of unbound {v}")))?,
        Rhs::ScalarUn { input, udf } => Binding::Scalar(udf.call(&gets(env, input)?)),
        Rhs::ScalarBin { left, right, udf } => {
            Binding::Scalar(udf.call(&gets(env, left)?, &gets(env, right)?))
        }
        Rhs::BagLit(items) => Binding::Cached(Arc::new(scatter(items, w))),
        Rhs::NamedSource(name) => {
            let data = registry
                .get(name)
                .ok_or_else(|| Error::Baseline(format!("named source '{name}' missing")))?;
            Binding::Cached(Arc::new(scatter(&data, w)))
        }
        Rhs::ReadFile { name } => {
            let fname = gets(env, name)?;
            if let Some(data) = registry.get(fname.as_str()) {
                Binding::Cached(Arc::new(scatter(&data, w)))
            } else {
                let text = std::fs::read_to_string(cfg.io_dir.join(fname.as_str()))?;
                let items: Vec<Value> = text.lines().map(Value::str).collect();
                Binding::Cached(Arc::new(scatter(&items, w)))
            }
        }
        Rhs::WriteFile { data, name } => {
            let parts = getb(env, data)?;
            let fname = gets(env, name)?;
            let path = cfg.io_dir.join(fname.as_str());
            if let Some(p) = path.parent() {
                let _ = std::fs::create_dir_all(p);
            }
            let mut s = String::new();
            for p in parts.iter() {
                for v in p {
                    s.push_str(&format!("{v}\n"));
                }
            }
            std::fs::write(path, s)?;
            Binding::Scalar(Value::Unit)
        }
        Rhs::Collect { input, label } => {
            let parts = getb(env, input)?;
            out.collected
                .entry(label.clone())
                .or_default()
                .extend(parts.iter().flat_map(|p| p.iter().cloned()));
            Binding::Scalar(Value::Unit)
        }
        Rhs::Map { input, udf } => {
            let parts = getb(env, input)?;
            let udf = udf.clone();
            Binding::Cached(Arc::new(par_map_partitions(&parts, |p| {
                p.iter().map(|v| udf.call(v)).collect()
            })))
        }
        Rhs::Filter { input, udf } => {
            let parts = getb(env, input)?;
            let udf = udf.clone();
            Binding::Cached(Arc::new(par_map_partitions(&parts, |p| {
                p.iter().filter(|v| udf.call(v).as_bool()).cloned().collect()
            })))
        }
        Rhs::FlatMap { input, udf } => {
            let parts = getb(env, input)?;
            let udf = udf.clone();
            Binding::Cached(Arc::new(par_map_partitions(&parts, |p| {
                p.iter().flat_map(|v| udf.call(v)).collect()
            })))
        }
        Rhs::Join { left, right } => {
            // Shuffle both sides, then per-partition hash join. The build
            // table is rebuilt EVERY job — no cross-job operator state
            // (§3.2.2).
            let l = hash_repartition(&getb(env, left)?, w);
            let r = hash_repartition(&getb(env, right)?, w);
            let joined: Vec<Vec<Value>> = std::thread::scope(|s| {
                let handles: Vec<_> = l
                    .iter()
                    .zip(r.iter())
                    .map(|(lp, rp)| {
                        s.spawn(move || {
                            let mut j = crate::ops::join::HashJoinT::new();
                            crate::ops::run_once(&mut j, &[lp, rp])
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("join thread")).collect()
            });
            Binding::Cached(Arc::new(joined))
        }
        Rhs::ReduceByKey { input, udf } => {
            let parts = hash_repartition(&getb(env, input)?, w);
            let udf = udf.clone();
            Binding::Cached(Arc::new(par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::ReduceByKeyT::new(udf.clone());
                crate::ops::run_once(&mut t, &[p])
            })))
        }
        Rhs::Distinct { input } => {
            let parts = hash_repartition(&getb(env, input)?, w);
            Binding::Cached(Arc::new(par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::DistinctT::new();
                crate::ops::run_once(&mut t, &[p])
            })))
        }
        Rhs::Reduce { input, udf } => {
            let parts = getb(env, input)?;
            // Parallel partial reduce, then driver-side final combine.
            let udf2 = udf.clone();
            let partials = par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::ReduceT::new(udf2.clone());
                crate::ops::run_once(&mut t, &[p])
            });
            let mut acc: Option<Value> = None;
            for p in partials.iter().flat_map(|p| p.iter()) {
                acc = Some(match acc.take() {
                    Some(a) => udf.call(&a, p),
                    None => p.clone(),
                });
            }
            Binding::Scalar(acc.ok_or_else(|| Error::Baseline("reduce of empty bag".into()))?)
        }
        Rhs::Count { input } => {
            let parts = getb(env, input)?;
            Binding::Scalar(Value::I64(parts.iter().map(|p| p.len() as i64).sum()))
        }
        Rhs::Union { left, right } => {
            let l = getb(env, left)?;
            let r = getb(env, right)?;
            let merged: Vec<Vec<Value>> = l
                .iter()
                .zip(r.iter())
                .map(|(a, b)| a.iter().chain(b.iter()).cloned().collect())
                .collect();
            Binding::Cached(Arc::new(merged))
        }
        Rhs::Cross { left, right } => {
            // Capture desugaring can cross a bag with a scalar (§5.2).
            let flat = |env: &FxHashMap<VarId, Binding>, v: &VarId| -> Result<Vec<Value>> {
                match env.get(v) {
                    Some(Binding::Scalar(x)) => Ok(vec![x.clone()]),
                    Some(_) => {
                        Ok(getb(env, v)?.iter().flatten().cloned().collect::<Vec<Value>>())
                    }
                    None => Err(Error::Baseline(format!("unbound var {v}"))),
                }
            };
            let l: Vec<Value> = flat(env, left)?;
            let r: Vec<Value> = flat(env, right)?;
            let mut res = Vec::with_capacity(l.len() * r.len());
            for a in &l {
                for b in &r {
                    res.push(Value::pair(a.clone(), b.clone()));
                }
            }
            Binding::Cached(Arc::new(scatter(&res, w)))
        }
        Rhs::XlaCall { inputs, spec } => {
            let mut t = crate::ops::xla::XlaCallT::new(spec.clone());
            let gathered: Vec<Vec<Value>> = inputs
                .iter()
                .map(|v| {
                    getb(env, v).map(|p| p.iter().flatten().cloned().collect::<Vec<Value>>())
                })
                .collect::<Result<_>>()?;
            let slices: Vec<&[Value]> = gathered.iter().map(|g| g.as_slice()).collect();
            let res = crate::ops::run_once(&mut t, &slices);
            Binding::Cached(Arc::new(scatter(&res, w)))
        }
        Rhs::Fused { input, stages, .. } => {
            // Produced only by `opt::fuse`; supported for completeness.
            let parts = getb(env, input)?;
            let stages = stages.clone();
            Binding::Cached(Arc::new(par_map_partitions(&parts, move |p| {
                let mut res = Vec::new();
                for v in p {
                    crate::ops::fused::apply_stages(&stages, v, &mut |x| res.push(x));
                }
                res
            })))
        }
        Rhs::Phi(_) => return Err(Error::Baseline("Φ in pre-SSA program".into())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    fn quick_model() -> LatencyModel {
        LatencyModel {
            job_setup: std::time::Duration::from_micros(5),
            rpc_dispatch: std::time::Duration::from_micros(1),
            result_fetch: std::time::Duration::from_micros(2),
            tasks_per_slot: 1,
        }
    }

    fn run_src(src: &str, persist: PersistStyle) -> BaselineRun {
        let p = parse_and_lower(src).unwrap();
        let cfg = SeparateJobsConfig {
            workers: 3,
            model: quick_model(),
            persist,
            max_blocks: 100_000,
            io_dir: std::path::PathBuf::from("."),
        };
        run(&p, &cfg).unwrap()
    }

    #[test]
    fn one_job_per_step() {
        let out = run_src(
            "d = 1; b = bag(1, 2); while (d <= 5) { b = b.map(|x| x + 1); d = d + 1; } collect(b, \"b\");",
            PersistStyle::SparkCache,
        );
        // initial block (bag lit) + 5 loop bodies + final collect block.
        assert_eq!(out.jobs_launched, 7);
        let mut got = out.collected("b").to_vec();
        got.sort();
        assert_eq!(got, vec![Value::I64(6), Value::I64(7)]);
        assert!(out.sched_time > std::time::Duration::ZERO);
    }

    #[test]
    fn flink_collect_matches_spark_cache_results() {
        let src = r#"
            a = bag(1, 2, 3, 4).map(|x| pair(x % 2, x));
            c = a.reduceByKey(|p, q| p + q);
            collect(c, "c");
        "#;
        let a = run_src(src, PersistStyle::SparkCache);
        let b = run_src(src, PersistStyle::FlinkCollect);
        let mut av = a.collected("c").to_vec();
        let mut bv = b.collected("c").to_vec();
        av.sort();
        bv.sort();
        assert_eq!(av, bv);
    }

    #[test]
    fn scalar_only_blocks_launch_no_job() {
        let out = run_src(
            "d = 1; while (d <= 100) { d = d + 1; } collect(bag(1), \"x\");",
            PersistStyle::SparkCache,
        );
        // Loop header/body are scalar-only: no jobs. Entry has no bag ops
        // either; only the final collect block launches.
        assert_eq!(out.jobs_launched, 1);
    }

    #[test]
    fn join_rebuilt_each_step_still_correct() {
        let out = run_src(
            r#"
            attrs = bag(1, 2).map(|x| pair(x, x * 10));
            d = 1;
            while (d <= 2) {
                v = bag(1, 2, 3).map(|x| pair(x, d));
                j = v.join(attrs);
                collect(j.map(|p| fst(snd(p))), "j");
                d = d + 1;
            }
            "#,
            PersistStyle::SparkCache,
        );
        let got = out.collected("j");
        assert_eq!(got.len(), 4);
        let sum: i64 = got.iter().map(|v| v.as_i64()).sum();
        assert_eq!(sum, 2 * 30);
    }
}
