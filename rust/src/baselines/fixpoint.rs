//! In-dataflow **fixpoint** iteration (Flink `iterate` / Naiad-style):
//! a single job whose loop executes as barrier-synchronized supersteps
//! over persistent workers — no per-step scheduling, but limited to plain
//! fixpoint loops (§3.2 footnote 3: "Flink allows for control flow inside
//! dataflows only in the case of fixpoint iterations"; nested/general
//! control flow still needs separate jobs, which is what Fig. 7 shows for
//! the outer loop).
//!
//! Each superstep: (1) parallel *scatter* over hash partitions emitting
//! keyed messages, (2) exchange by key, (3) parallel *combine* per key.
//! The per-step cost is a thread barrier — the same order of magnitude as
//! Labyrinth's coordination (Fig. 5's in-dataflow cluster of lines).

use crate::frontend::Udf2;
use crate::value::Value;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};

/// A superstep specification.
pub struct StepSpec {
    /// Per-element scatter: emit keyed messages (`Pair(k, v)`), given the
    /// element and the step index.
    pub scatter: Arc<dyn Fn(&Value, usize) -> Vec<Value> + Send + Sync>,
    /// Optional per-key combiner (None: messages pass through unchanged).
    pub combine: Option<Udf2>,
}

/// Fixpoint executor over persistent worker threads.
pub struct Fixpoint {
    /// Worker (thread) count.
    pub workers: usize,
}

impl Fixpoint {
    /// Create with `workers` threads.
    pub fn new(workers: usize) -> Fixpoint {
        Fixpoint { workers: workers.max(1) }
    }

    /// Run `steps` supersteps from `initial`; returns the final dataset
    /// and the number of barrier waits performed (for overhead metrics).
    pub fn run(&self, initial: Vec<Value>, steps: usize, spec: &StepSpec) -> (Vec<Value>, usize) {
        let w = self.workers;
        // Hash-partition the initial dataset.
        let mut parts: Vec<Vec<Value>> = vec![Vec::new(); w];
        for v in initial {
            parts[(v.key_hash() as usize) % w].push(v);
        }
        let parts = Arc::new(Mutex::new(parts));
        let barrier = Arc::new(Barrier::new(w));
        let barrier_waits = Arc::new(AtomicUsize::new(0));
        // Exchange staging: [src worker][dst worker] -> messages.
        let staging: Arc<Vec<Mutex<Vec<Vec<Value>>>>> =
            Arc::new((0..w).map(|_| Mutex::new(vec![Vec::new(); w])).collect());

        std::thread::scope(|s| {
            for me in 0..w {
                let parts = parts.clone();
                let barrier = barrier.clone();
                let staging = staging.clone();
                let waits = barrier_waits.clone();
                let scatter = spec.scatter.clone();
                let combine = spec.combine.clone();
                s.spawn(move || {
                    for step in 0..steps {
                        // Phase 1: scatter my partition into per-dst buffers.
                        let my_part = { parts.lock().unwrap()[me].clone() };
                        let mut outbox: Vec<Vec<Value>> = vec![Vec::new(); w];
                        for v in &my_part {
                            for m in scatter(v, step) {
                                outbox[(m.key_hash() as usize) % w].push(m);
                            }
                        }
                        *staging[me].lock().unwrap() = outbox;
                        waits.fetch_add(1, Ordering::Relaxed);
                        barrier.wait(); // superstep barrier (write visible)

                        // Phase 2: gather my inbox from all senders.
                        let mut inbox: Vec<Value> = Vec::new();
                        for src in 0..w {
                            let msgs = std::mem::take(&mut staging[src].lock().unwrap()[me]);
                            inbox.extend(msgs);
                        }
                        // Phase 3: combine per key.
                        let next = match &combine {
                            None => inbox,
                            Some(udf) => {
                                let mut t = crate::ops::agg::ReduceByKeyT::new(udf.clone());
                                crate::ops::run_once(&mut t, &[&inbox])
                            }
                        };
                        parts.lock().unwrap()[me] = next;
                        barrier.wait(); // everyone advances together
                    }
                });
            }
        });

        let final_parts = Arc::try_unwrap(parts).unwrap().into_inner().unwrap();
        (
            final_parts.into_iter().flatten().collect(),
            barrier_waits.load(Ordering::Relaxed),
        )
    }
}

/// PageRank via the fixpoint executor (the paper's Fig. 7 inner loop):
/// damping 0.85, `iters` supersteps over `Pair(page, rank)` state.
pub fn pagerank_fixpoint(
    edges: &[(usize, usize)],
    n: usize,
    iters: usize,
    workers: usize,
) -> Vec<f64> {
    let damping = 0.85;
    // Adjacency + out-degrees, shared read-only by the scatter closure.
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(s, d) in edges {
        adj[s].push(d);
    }
    let adj = Arc::new(adj);
    let initial: Vec<Value> = (0..n)
        .map(|p| Value::pair(Value::I64(p as i64), Value::F64(1.0 / n as f64)))
        .collect();
    let adj2 = adj.clone();
    let spec = StepSpec {
        scatter: Arc::new(move |v: &Value, _step| {
            let (page, rank) = match v {
                Value::Pair(p) => (p.0.as_i64() as usize, p.1.as_f64()),
                _ => unreachable!(),
            };
            let outs = &adj2[page];
            let mut msgs = Vec::with_capacity(outs.len() + 1);
            // Keep the vertex alive with its base rank.
            msgs.push(Value::pair(
                Value::I64(page as i64),
                Value::F64((1.0 - damping) / n as f64),
            ));
            if outs.is_empty() {
                // Dangling mass spreads uniformly: approximate by sending
                // to self (consistent with the Labyrinth dataflow version).
                msgs.push(Value::pair(
                    Value::I64(page as i64),
                    Value::F64(damping * rank),
                ));
            } else {
                let share = damping * rank / outs.len() as f64;
                for &d in outs {
                    msgs.push(Value::pair(Value::I64(d as i64), Value::F64(share)));
                }
            }
            msgs
        }),
        combine: Some(Udf2::new("+", |a, b| Value::F64(a.as_f64() + b.as_f64()))),
    };
    let (final_, _) = Fixpoint::new(workers).run(initial, iters, &spec);
    let mut ranks = vec![0.0; n];
    for v in final_ {
        if let Value::Pair(p) = v {
            ranks[p.0.as_i64() as usize] = p.1.as_f64();
        }
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_only_fixpoint_increments() {
        // bag of pairs (k, v); each step v += 1 — the Fig. 5 microbench.
        let initial: Vec<Value> =
            (0..20).map(|k| Value::pair(Value::I64(k), Value::I64(0))).collect();
        let spec = StepSpec {
            scatter: Arc::new(|v: &Value, _| {
                let Value::Pair(p) = v else { unreachable!() };
                vec![Value::pair(p.0.clone(), Value::I64(p.1.as_i64() + 1))]
            }),
            combine: None,
        };
        let (out, waits) = Fixpoint::new(3).run(initial, 10, &spec);
        assert_eq!(out.len(), 20);
        for v in &out {
            assert_eq!(v.val().as_i64(), 10);
        }
        assert_eq!(waits, 3 * 10);
    }

    #[test]
    fn pagerank_matches_reference_without_danglings() {
        // Strongly-connected graph: no dangling correction discrepancy.
        let edges = vec![(0, 1), (1, 2), (2, 0), (0, 2), (2, 1)];
        let got = pagerank_fixpoint(&edges, 3, 30, 2);
        let want = crate::workload::pagerank_reference(&edges, 3, 30);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let edges = vec![(0, 1), (1, 0), (1, 2), (2, 0)];
        let a = pagerank_fixpoint(&edges, 3, 15, 1);
        let b = pagerank_fixpoint(&edges, 3, 15, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }
}
