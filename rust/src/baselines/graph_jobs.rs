//! Separate-jobs baseline over the **optimized dataflow graph**.
//!
//! `baselines::separate_jobs` interprets the pre-SSA IR — faithful to
//! §3.2, but blind to everything `opt::optimize` does, so optimizer wins
//! never showed up in the Fig. 4/5 comparisons (ROADMAP open item). This
//! executor keeps the separate-jobs *execution model* (client-side
//! control flow, one dataflow job per basic block with bag work, per-job
//! scheduler cost, optional collect-to-driver between jobs) but runs the
//! **compiled plan**: fused chains execute as one operator, pushed-down
//! filters drop rows before shuffles, cost-chosen join build sides are
//! honored, DCE'd operators never run — and chains hoisted into a loop
//! *preamble* execute once per loop entry (the preamble is an ordinary
//! CFG block on the client's walk), so per-step jobs shrink exactly as
//! the optimizer intended.
//!
//! Φ nodes are resolved client-side: the walk executes blocks in path
//! order, so "the argument defined most recently" is just the input with
//! the highest definition timestamp.

use super::separate_jobs::{
    hash_repartition, par_map_partitions, scatter, Partitions, PersistStyle, SeparateJobsConfig,
};
use super::BaselineRun;
use crate::dataflow::{DataflowGraph, NodeId};
use crate::error::{Error, Result};
use crate::frontend::{Rhs, Terminator};
use crate::opt::OptConfig;
use crate::value::Value;
use rustc_hash::FxHashMap;
use std::sync::Arc;
use std::time::Instant;

/// Compile `program` with `opt_cfg`, then run the optimized graph with
/// client-side control flow + per-block jobs.
pub fn run_optimized(
    program: &crate::frontend::Program,
    cfg: &SeparateJobsConfig,
    opt_cfg: &OptConfig,
) -> Result<BaselineRun> {
    let (graph, _report) = crate::compile_with(program, opt_cfg)?;
    run_graph(&graph, cfg)
}

/// Run an already-compiled dataflow graph in the separate-jobs model.
pub fn run_graph(g: &DataflowGraph, cfg: &SeparateJobsConfig) -> Result<BaselineRun> {
    let start = Instant::now();
    let w = cfg.workers.max(1);
    let mut out = BaselineRun::default();
    let registry = crate::workload::registry::global();

    // Nodes per block, topologically ordered by intra-block edges (the
    // optimizer appends nodes out of order; Φ inputs are cross-block by
    // construction and do not constrain the intra-block order).
    let mut by_block: Vec<Vec<NodeId>> = vec![Vec::new(); g.cfg.num_blocks()];
    {
        let mut indegree: Vec<usize> = vec![0; g.nodes.len()];
        for n in &g.nodes {
            if matches!(n.op, Rhs::Phi(_)) {
                continue;
            }
            for inp in &n.inputs {
                if g.nodes[inp.src].block == n.block {
                    indegree[n.id] += 1;
                }
            }
        }
        let mut ready: Vec<Vec<NodeId>> = vec![Vec::new(); g.cfg.num_blocks()];
        for n in &g.nodes {
            if indegree[n.id] == 0 {
                ready[n.block].push(n.id);
            }
        }
        for b in 0..g.cfg.num_blocks() {
            // Kahn within the block; `ready` preserves id order for
            // determinism.
            let mut queue: std::collections::VecDeque<NodeId> =
                ready[b].iter().copied().collect();
            while let Some(nid) = queue.pop_front() {
                by_block[b].push(nid);
                for (c, _) in g.consumers(nid) {
                    if g.nodes[c].block == b && !matches!(g.nodes[c].op, Rhs::Phi(_)) {
                        indegree[c] -= 1;
                        if indegree[c] == 0 {
                            queue.push_back(c);
                        }
                    }
                }
            }
        }
        let placed: usize = by_block.iter().map(|v| v.len()).sum();
        if placed != g.nodes.len() {
            return Err(Error::Baseline(format!(
                "intra-block cycle: placed {placed} of {} nodes",
                g.nodes.len()
            )));
        }
    }

    let mut vals: FxHashMap<NodeId, Partitions> = FxHashMap::default();
    let mut def_time: FxHashMap<NodeId, u64> = FxHashMap::default();
    let mut clock = 0u64;

    let mut block = g.cfg.program.entry;
    let mut executed = 0usize;
    loop {
        executed += 1;
        if executed > cfg.max_blocks {
            return Err(Error::Baseline("block budget exceeded".into()));
        }
        // One dataflow job per block with parallel bag work; singleton
        // (lifted-scalar) chains run "in the client" like the pre-SSA
        // interpreter's scalar blocks. Sinks always count (collecting to
        // the driver is a job in the modeled systems even when the data
        // is a lifted scalar).
        let job_ops: Vec<NodeId> = by_block[block]
            .iter()
            .copied()
            .filter(|&nid| {
                let n = &g.nodes[nid];
                match n.op {
                    Rhs::Phi(_) => false,
                    Rhs::Collect { .. } | Rhs::WriteFile { .. } => true,
                    _ => !n.singleton,
                }
            })
            .collect();
        let bag_ops = job_ops.len();
        if bag_ops > 0 {
            out.jobs_launched += 1;
            out.sched_time += cfg.model.simulate_job_launch(bag_ops, w);
            // Per-operator task accounting (real Spark stages dispatch
            // `slots × tasks_per_slot` tasks per operator, every job):
            // this is where hoisting/DCE/fusion wins become visible as
            // fewer tasks, operator by operator.
            let tasks_per_op = (w * cfg.model.tasks_per_slot.max(1)) as u64;
            for &nid in &job_ops {
                *out.tasks_by_op.entry(op_kind(&g.nodes[nid].op)).or_insert(0) +=
                    tasks_per_op;
            }
        }
        for &nid in &by_block[block] {
            let v = eval_node(g, nid, &vals, &def_time, cfg, &registry, &mut out, w)?;
            clock += 1;
            vals.insert(nid, v);
            def_time.insert(nid, clock);
        }
        if bag_ops > 0 && cfg.persist == PersistStyle::FlinkCollect {
            // Flink batch: collect every dataset this job produced to the
            // driver and re-scatter it into the next job (§9.1.2 copy).
            for &nid in &by_block[block] {
                if g.nodes[nid].singleton {
                    continue;
                }
                if let Some(parts) = vals.get(&nid) {
                    let gathered: Vec<Value> =
                        parts.iter().flat_map(|p| p.iter().cloned()).collect();
                    vals.insert(nid, Arc::new(scatter(&gathered, w)));
                }
            }
        }
        match &g.cfg.program.blocks[block].term {
            Terminator::End => break,
            Terminator::Jump(t) => block = *t,
            Terminator::Branch { cond, then_b, else_b } => {
                let nid = *g
                    .node_of_var
                    .get(cond)
                    .ok_or_else(|| Error::Baseline(format!("branch var {cond} has no node")))?;
                let v = scalar_of(vals.get(&nid).ok_or_else(|| {
                    Error::Baseline(format!("branch on unevaluated node {}", g.nodes[nid].name))
                })?)?;
                block = if v.as_bool() { *then_b } else { *else_b };
            }
        }
    }
    out.elapsed = start.elapsed();
    Ok(out)
}

/// Operator-kind label for task accounting (stable across UDF names and
/// literal sizes, unlike [`Rhs::mnemonic`]).
fn op_kind(op: &Rhs) -> &'static str {
    match op {
        Rhs::BagLit(_) => "bagLit",
        Rhs::NamedSource(_) => "source",
        Rhs::ReadFile { .. } => "readFile",
        Rhs::WriteFile { .. } => "writeFile",
        Rhs::Collect { .. } => "collect",
        Rhs::Map { .. } => "map",
        Rhs::Filter { .. } => "filter",
        Rhs::FlatMap { .. } => "flatMap",
        Rhs::Fused { .. } => "fused",
        Rhs::Join { .. } => "join",
        Rhs::ReduceByKey { .. } => "reduceByKey",
        Rhs::Distinct { .. } => "distinct",
        Rhs::Reduce { .. } => "reduce",
        Rhs::Count { .. } => "count",
        Rhs::Union { .. } => "union",
        Rhs::Cross { .. } => "cross",
        Rhs::XlaCall { .. } => "xlaCall",
        Rhs::Phi(_) => "phi",
        Rhs::Const(_) | Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => {
            "scalar"
        }
    }
}

/// The single element of a singleton dataset.
fn scalar_of(parts: &Partitions) -> Result<Value> {
    let mut it = parts.iter().flat_map(|p| p.iter());
    let first = it
        .next()
        .ok_or_else(|| Error::Baseline("expected a singleton, got an empty bag".into()))?;
    if it.next().is_some() {
        return Err(Error::Baseline("expected a singleton, got multiple elements".into()));
    }
    Ok(first.clone())
}

#[allow(clippy::too_many_arguments)]
fn eval_node(
    g: &DataflowGraph,
    nid: NodeId,
    vals: &FxHashMap<NodeId, Partitions>,
    def_time: &FxHashMap<NodeId, u64>,
    cfg: &SeparateJobsConfig,
    registry: &crate::workload::registry::Registry,
    out: &mut BaselineRun,
    w: usize,
) -> Result<Partitions> {
    let n = &g.nodes[nid];
    let input = |i: usize| -> Result<Partitions> {
        let src = n.inputs[i].src;
        vals.get(&src)
            .cloned()
            .ok_or_else(|| Error::Baseline(format!("input '{}' unevaluated", g.nodes[src].name)))
    };
    let gather = |p: &Partitions| -> Vec<Value> {
        p.iter().flat_map(|x| x.iter().cloned()).collect()
    };
    let single = |v: Value| -> Partitions { Arc::new(scatter(&[v], w)) };

    Ok(match &n.op {
        Rhs::BagLit(items) => Arc::new(scatter(items, w)),
        Rhs::NamedSource(name) => {
            let data = registry
                .get(name)
                .ok_or_else(|| Error::Baseline(format!("named source '{name}' missing")))?;
            Arc::new(scatter(&data, w))
        }
        Rhs::ReadFile { .. } => {
            let fname = scalar_of(&input(0)?)?;
            if let Some(data) = registry.get(fname.as_str()) {
                Arc::new(scatter(&data, w))
            } else {
                let text = std::fs::read_to_string(cfg.io_dir.join(fname.as_str()))?;
                let items: Vec<Value> = text.lines().map(Value::str).collect();
                Arc::new(scatter(&items, w))
            }
        }
        Rhs::WriteFile { .. } => {
            let data = gather(&input(0)?);
            let fname = scalar_of(&input(1)?)?;
            let path = cfg.io_dir.join(fname.as_str());
            if let Some(p) = path.parent() {
                let _ = std::fs::create_dir_all(p);
            }
            let mut s = String::new();
            for v in &data {
                s.push_str(&format!("{v}\n"));
            }
            std::fs::write(path, s)?;
            single(Value::Unit)
        }
        Rhs::Collect { label, .. } => {
            let items = gather(&input(0)?);
            out.collected.entry(label.clone()).or_default().extend(items);
            single(Value::Unit)
        }
        Rhs::Map { udf, .. } => {
            let parts = input(0)?;
            let udf = udf.clone();
            Arc::new(par_map_partitions(&parts, |p| p.iter().map(|v| udf.call(v)).collect()))
        }
        Rhs::Filter { udf, .. } => {
            let parts = input(0)?;
            let udf = udf.clone();
            Arc::new(par_map_partitions(&parts, |p| {
                p.iter().filter(|v| udf.call(v).as_bool()).cloned().collect()
            }))
        }
        Rhs::FlatMap { udf, .. } => {
            let parts = input(0)?;
            let udf = udf.clone();
            Arc::new(par_map_partitions(&parts, |p| p.iter().flat_map(|v| udf.call(v)).collect()))
        }
        Rhs::Fused { stages, .. } => {
            let parts = input(0)?;
            let stages = stages.clone();
            Arc::new(par_map_partitions(&parts, move |p| {
                let mut res = Vec::new();
                for v in p {
                    crate::ops::fused::apply_stages(&stages, v, &mut |x| res.push(x));
                }
                res
            }))
        }
        Rhs::Join { .. } => {
            // Honor the cost model's build-side choice; the build table
            // is still rebuilt EVERY job (no cross-job operator state).
            let build_side = n.build_side.unwrap_or(0);
            let l = hash_repartition(&input(0)?, w);
            let r = hash_repartition(&input(1)?, w);
            let joined: Vec<Vec<Value>> = std::thread::scope(|s| {
                let handles: Vec<_> = l
                    .iter()
                    .zip(r.iter())
                    .map(|(lp, rp)| {
                        s.spawn(move || {
                            let mut j = crate::ops::join::HashJoinT::with_build(build_side);
                            crate::ops::run_once(&mut j, &[lp, rp])
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("join thread")).collect()
            });
            Arc::new(joined)
        }
        Rhs::ReduceByKey { udf, .. } => {
            let parts = hash_repartition(&input(0)?, w);
            let udf = udf.clone();
            Arc::new(par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::ReduceByKeyT::new(udf.clone());
                crate::ops::run_once(&mut t, &[p])
            }))
        }
        Rhs::Distinct { .. } => {
            let parts = hash_repartition(&input(0)?, w);
            Arc::new(par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::DistinctT::new();
                crate::ops::run_once(&mut t, &[p])
            }))
        }
        Rhs::Reduce { udf, .. } => {
            let parts = input(0)?;
            let udf2 = udf.clone();
            let partials = par_map_partitions(&parts, |p| {
                let mut t = crate::ops::agg::ReduceT::new(udf2.clone());
                crate::ops::run_once(&mut t, &[p])
            });
            let mut acc: Option<Value> = None;
            for p in partials.iter().flat_map(|p| p.iter()) {
                acc = Some(match acc.take() {
                    Some(a) => udf.call(&a, p),
                    None => p.clone(),
                });
            }
            single(acc.ok_or_else(|| Error::Baseline("reduce of empty bag".into()))?)
        }
        Rhs::Count { .. } => {
            let parts = input(0)?;
            single(Value::I64(parts.iter().map(|p| p.len() as i64).sum()))
        }
        Rhs::Union { .. } => {
            let l = input(0)?;
            let r = input(1)?;
            let merged: Vec<Vec<Value>> = l
                .iter()
                .zip(r.iter())
                .map(|(a, b)| a.iter().chain(b.iter()).cloned().collect())
                .collect();
            Arc::new(merged)
        }
        Rhs::Cross { .. } => {
            let l = gather(&input(0)?);
            let r = gather(&input(1)?);
            let mut res = Vec::with_capacity(l.len() * r.len());
            for a in &l {
                for b in &r {
                    res.push(Value::pair(a.clone(), b.clone()));
                }
            }
            Arc::new(scatter(&res, w))
        }
        Rhs::XlaCall { inputs, spec } => {
            let mut t = crate::ops::xla::XlaCallT::new(spec.clone());
            let gathered: Vec<Vec<Value>> =
                (0..inputs.len()).map(|i| input(i).map(|p| gather(&p))).collect::<Result<_>>()?;
            let slices: Vec<&[Value]> = gathered.iter().map(|g| g.as_slice()).collect();
            Arc::new(scatter(&crate::ops::run_once(&mut t, &slices), w))
        }
        Rhs::Phi(_) => {
            // Client-side Φ: the input whose producer ran most recently.
            let chosen = n
                .inputs
                .iter()
                .filter_map(|inp| def_time.get(&inp.src).map(|&t| (t, inp.src)))
                .max_by_key(|&(t, _)| t)
                .map(|(_, src)| src)
                .ok_or_else(|| {
                    Error::Baseline(format!("Φ '{}' has no evaluated input", n.name))
                })?;
            vals.get(&chosen).cloned().expect("def_time implies presence")
        }
        Rhs::Const(_) | Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => {
            return Err(Error::Baseline(format!(
                "operation {} should not survive SSA/lifting",
                n.op.mnemonic()
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{separate_jobs, single_thread};
    use crate::frontend::parse_and_lower;
    use crate::sched::LatencyModel;

    fn quick_cfg(persist: PersistStyle) -> SeparateJobsConfig {
        SeparateJobsConfig {
            workers: 3,
            model: LatencyModel {
                job_setup: std::time::Duration::from_micros(5),
                rpc_dispatch: std::time::Duration::from_micros(1),
                result_fetch: std::time::Duration::from_micros(2),
                tasks_per_slot: 1,
            },
            persist,
            max_blocks: 100_000,
            io_dir: std::path::PathBuf::from("."),
        }
    }

    fn check_against_oracle(src: &str, opt: &OptConfig) -> BaselineRun {
        let program = parse_and_lower(src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let got = run_optimized(&program, &quick_cfg(PersistStyle::SparkCache), opt).unwrap();
        let mut labels: Vec<&String> = oracle.collected.keys().collect();
        labels.sort();
        for label in labels {
            let mut a = got.collected(label).to_vec();
            let mut b = oracle.collected(label).to_vec();
            a.sort();
            b.sort();
            assert_eq!(a, b, "label '{label}' for:\n{src}");
        }
        got
    }

    #[test]
    fn optimized_graph_matches_oracle_on_loop_program() {
        check_against_oracle(
            "d = 1; b = bag(1, 2); while (d <= 5) { b = b.map(|x| x + 1); d = d + 1; } collect(b, \"b\");",
            &OptConfig::default(),
        );
    }

    #[test]
    fn optimized_graph_matches_oracle_on_join_program() {
        check_against_oracle(
            r#"
            attrs = bag(1, 2, 3).map(|x| pair(x, x * 100));
            d = 1;
            while (d <= 3) {
                v = bag(1, 2, 9).map(|x| pair(x, d));
                j = v.join(attrs);
                t = j.map(|p| fst(snd(p)));
                collect(t, "t");
                d = d + 1;
            }
            "#,
            &OptConfig::default(),
        );
    }

    #[test]
    fn hoisting_shrinks_per_step_jobs() {
        // The invariant chain (bag + map) hoists into the loop preamble:
        // the per-iteration job runs fewer operators, and the preamble
        // job pays them once. The unoptimized interpreter re-runs them
        // every step.
        let src = r#"
            d = 1;
            while (d <= 4) {
                v = bag(1, 2, 3, 4).map(|x| pair(x % 2, x));
                r = v.reduceByKey(|a, b| a + b);
                collect(r, "r");
                d = d + 1;
            }
            "#;
        let program = parse_and_lower(src).unwrap();
        let raw = separate_jobs::run(&program, &quick_cfg(PersistStyle::SparkCache)).unwrap();
        let opt = run_optimized(
            &program,
            &quick_cfg(PersistStyle::SparkCache),
            &OptConfig::default(),
        )
        .unwrap();
        // Same answers...
        let mut a = raw.collected("r").to_vec();
        let mut b = opt.collected("r").to_vec();
        a.sort();
        b.sort();
        assert_eq!(a, b);
        // ...but the optimizer's wins are visible in the job accounting.
        assert!(
            opt.sched_time <= raw.sched_time,
            "optimized per-step jobs must not be more expensive: {:?} vs {:?}",
            opt.sched_time,
            raw.sched_time
        );
    }

    #[test]
    fn per_operator_task_accounting_reflects_the_executed_plan() {
        // 4 iterations, one bagLit + map + reduceByKey + collect per
        // step with hoisting OFF: every operator dispatches
        // workers × tasks_per_slot tasks per job it appears in. The map
        // reads `d`, so only the literal is loop-invariant.
        let src = r#"
            d = 1;
            while (d <= 4) {
                v = bag(1, 2, 3, 4).map(|x| pair(x % 2, x + d));
                r = v.reduceByKey(|a, b| a + b);
                collect(r, "r");
                d = d + 1;
            }
            "#;
        let program = parse_and_lower(src).unwrap();
        let cfg = quick_cfg(PersistStyle::SparkCache);
        let per_op = (cfg.workers * cfg.model.tasks_per_slot) as u64;
        let raw = run_optimized(&program, &cfg, &OptConfig::none()).unwrap();
        assert_eq!(raw.tasks_by_op["reduceByKey"], 4 * per_op, "{:?}", raw.tasks_by_op);
        assert_eq!(raw.tasks_by_op["map"], 4 * per_op, "{:?}", raw.tasks_by_op);
        assert!(raw.tasks_launched() >= 16 * per_op, "{:?}", raw.tasks_by_op);
        // With the optimizer on, the invariant bagLit+map chain hoists
        // into the preamble: those operators' task counts drop from
        // once-per-step to once-per-loop-entry while the per-step
        // reduceByKey stays — visible operator by operator.
        let opt = run_optimized(&program, &cfg, &OptConfig::default()).unwrap();
        assert_eq!(opt.tasks_by_op["reduceByKey"], 4 * per_op, "{:?}", opt.tasks_by_op);
        assert!(
            opt.tasks_launched() < raw.tasks_launched(),
            "optimized plan should dispatch fewer tasks: {:?} vs {:?}",
            opt.tasks_by_op,
            raw.tasks_by_op
        );
    }

    #[test]
    fn flink_collect_style_matches_too() {
        let src = "a = bag(1, 2, 3, 4).map(|x| pair(x % 2, x)); c = a.reduceByKey(|p, q| p + q); collect(c, \"c\");";
        let program = parse_and_lower(src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let got =
            run_optimized(&program, &quick_cfg(PersistStyle::FlinkCollect), &OptConfig::default())
                .unwrap();
        let mut a = got.collected("c").to_vec();
        let mut b = oracle.collected("c").to_vec();
        a.sort();
        b.sort();
        assert_eq!(a, b);
        assert!(got.jobs_launched >= 1);
    }

    #[test]
    fn unoptimized_graph_also_runs() {
        // The executor is correct for the raw §5.3 translation too.
        check_against_oracle(
            "x = 5; y = bag(); if (x > 3) { y = bag(1); } else { y = bag(2); } collect(y, \"y\");",
            &OptConfig::none(),
        );
    }
}
