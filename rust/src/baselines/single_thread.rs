//! Single-threaded direct interpreter of the imperative IR — both the
//! paper's COST baseline (the hand-written C++/STL implementation of
//! §9.2.1, sort-based joins and aggregations, no framework overhead) and
//! the *specification* of program semantics (§6.3.1's non-parallel
//! execution): every other executor is tested against its output.

use super::BaselineRun;
use crate::error::{Error, Result};
use crate::frontend::{Program, Rhs, Terminator, VarId};
use crate::value::Value;
use rustc_hash::FxHashMap;
use std::sync::Arc;
use std::time::Instant;

/// A binding: scalar or materialized bag.
#[derive(Clone, Debug)]
enum Binding {
    Scalar(Value),
    Bag(Arc<Vec<Value>>),
}

/// Interpreter configuration.
#[derive(Clone, Debug)]
pub struct SingleThreadConfig {
    /// Safety bound on executed basic blocks.
    pub max_blocks: usize,
    /// Base directory for file I/O.
    pub io_dir: std::path::PathBuf,
}

impl Default for SingleThreadConfig {
    fn default() -> Self {
        SingleThreadConfig { max_blocks: 10_000_000, io_dir: std::path::PathBuf::from(".") }
    }
}

/// Run a program single-threaded.
pub fn run(program: &Program, cfg: &SingleThreadConfig) -> Result<BaselineRun> {
    let start = Instant::now();
    let mut env: FxHashMap<VarId, Binding> = FxHashMap::default();
    let mut out = BaselineRun::default();
    let registry = crate::workload::registry::global();

    let mut block = program.entry;
    let mut executed = 0usize;
    loop {
        executed += 1;
        if executed > cfg.max_blocks {
            return Err(Error::Baseline(format!(
                "exceeded {} blocks — non-terminating program?",
                cfg.max_blocks
            )));
        }
        for instr in &program.blocks[block].instrs {
            let bind = eval_rhs(&instr.rhs, &env, &registry, cfg, &mut out)?;
            env.insert(instr.var, bind);
        }
        match &program.blocks[block].term {
            Terminator::End => break,
            Terminator::Jump(t) => block = *t,
            Terminator::Branch { cond, then_b, else_b } => {
                let v = scalar(&env, *cond)?;
                block = if v.as_bool() { *then_b } else { *else_b };
            }
        }
    }
    out.elapsed = start.elapsed();
    Ok(out)
}

fn scalar(env: &FxHashMap<VarId, Binding>, v: VarId) -> Result<Value> {
    match env.get(&v) {
        Some(Binding::Scalar(x)) => Ok(x.clone()),
        other => Err(Error::Baseline(format!("expected scalar for var {v}, got {other:?}"))),
    }
}

fn bag(env: &FxHashMap<VarId, Binding>, v: VarId) -> Result<Arc<Vec<Value>>> {
    match env.get(&v) {
        Some(Binding::Bag(b)) => Ok(b.clone()),
        other => Err(Error::Baseline(format!("expected bag for var {v}, got {other:?}"))),
    }
}

fn bag_or_lifted(env: &FxHashMap<VarId, Binding>, v: VarId) -> Result<Arc<Vec<Value>>> {
    match env.get(&v) {
        Some(Binding::Bag(b)) => Ok(b.clone()),
        Some(Binding::Scalar(x)) => Ok(Arc::new(vec![x.clone()])),
        None => Err(Error::Baseline(format!("unbound var {v}"))),
    }
}

fn kv(v: &Value) -> (Value, Value) {
    match v {
        Value::Pair(p) => (p.0.clone(), p.1.clone()),
        other => (other.clone(), Value::Unit),
    }
}

fn eval_rhs(
    rhs: &Rhs,
    env: &FxHashMap<VarId, Binding>,
    registry: &crate::workload::registry::Registry,
    cfg: &SingleThreadConfig,
    out: &mut BaselineRun,
) -> Result<Binding> {
    Ok(match rhs {
        Rhs::Const(v) => Binding::Scalar(v.clone()),
        Rhs::Copy(v) => env
            .get(v)
            .cloned()
            .ok_or_else(|| Error::Baseline(format!("copy of unbound var {v}")))?,
        Rhs::ScalarUn { input, udf } => Binding::Scalar(udf.call(&scalar(env, *input)?)),
        Rhs::ScalarBin { left, right, udf } => {
            Binding::Scalar(udf.call(&scalar(env, *left)?, &scalar(env, *right)?))
        }
        Rhs::BagLit(items) => Binding::Bag(Arc::new(items.clone())),
        Rhs::NamedSource(name) => Binding::Bag(
            registry
                .get(name)
                .ok_or_else(|| Error::Baseline(format!("named source '{name}' missing")))?,
        ),
        Rhs::ReadFile { name } => {
            let fname = scalar(env, *name)?;
            if let Some(data) = registry.get(fname.as_str()) {
                Binding::Bag(data)
            } else {
                let path = cfg.io_dir.join(fname.as_str());
                let text = std::fs::read_to_string(&path)?;
                Binding::Bag(Arc::new(text.lines().map(Value::str).collect()))
            }
        }
        Rhs::WriteFile { data, name } => {
            let fname = scalar(env, *name)?;
            let path = cfg.io_dir.join(fname.as_str());
            if let Some(p) = path.parent() {
                let _ = std::fs::create_dir_all(p);
            }
            let mut s = String::new();
            for v in bag(env, *data)?.iter() {
                s.push_str(&format!("{v}\n"));
            }
            std::fs::write(path, s)?;
            Binding::Scalar(Value::Unit)
        }
        Rhs::Collect { input, label } => {
            let b = bag(env, *input)?;
            out.collected.entry(label.clone()).or_default().extend(b.iter().cloned());
            Binding::Scalar(Value::Unit)
        }
        Rhs::Map { input, udf } => {
            Binding::Bag(Arc::new(bag(env, *input)?.iter().map(|v| udf.call(v)).collect()))
        }
        Rhs::Filter { input, udf } => Binding::Bag(Arc::new(
            bag(env, *input)?.iter().filter(|v| udf.call(v).as_bool()).cloned().collect(),
        )),
        Rhs::FlatMap { input, udf } => Binding::Bag(Arc::new(
            bag(env, *input)?.iter().flat_map(|v| udf.call(v)).collect(),
        )),
        Rhs::Join { left, right } => {
            // Sort-merge join — like the paper's single-threaded C++ (§9.2.1).
            let mut l: Vec<(Value, Value)> = bag(env, *left)?.iter().map(kv).collect();
            let mut r: Vec<(Value, Value)> = bag(env, *right)?.iter().map(kv).collect();
            l.sort_by(|a, b| a.0.cmp(&b.0));
            r.sort_by(|a, b| a.0.cmp(&b.0));
            let mut res = Vec::new();
            let (mut i, mut j) = (0, 0);
            while i < l.len() && j < r.len() {
                match l[i].0.cmp(&r[j].0) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        let key = l[i].0.clone();
                        let i_end = l[i..].iter().take_while(|x| x.0 == key).count() + i;
                        let j_end = r[j..].iter().take_while(|x| x.0 == key).count() + j;
                        for li in i..i_end {
                            for rj in j..j_end {
                                res.push(Value::pair(
                                    key.clone(),
                                    Value::pair(l[li].1.clone(), r[rj].1.clone()),
                                ));
                            }
                        }
                        i = i_end;
                        j = j_end;
                    }
                }
            }
            Binding::Bag(Arc::new(res))
        }
        Rhs::ReduceByKey { input, udf } => {
            // Sort-based grouping (COST-style).
            let mut items: Vec<(Value, Value)> = bag(env, *input)?.iter().map(kv).collect();
            items.sort_by(|a, b| a.0.cmp(&b.0));
            let mut res: Vec<Value> = Vec::new();
            let mut cur: Option<(Value, Value)> = None;
            for (k, v) in items {
                match &mut cur {
                    Some((ck, acc)) if *ck == k => *acc = udf.call(acc, &v),
                    _ => {
                        if let Some((ck, acc)) = cur.take() {
                            res.push(Value::pair(ck, acc));
                        }
                        cur = Some((k, v));
                    }
                }
            }
            if let Some((ck, acc)) = cur {
                res.push(Value::pair(ck, acc));
            }
            Binding::Bag(Arc::new(res))
        }
        Rhs::Reduce { input, udf } => {
            let b = bag(env, *input)?;
            let mut it = b.iter();
            let first = it
                .next()
                .ok_or_else(|| Error::Baseline("reduce of empty bag".into()))?
                .clone();
            Binding::Scalar(it.fold(first, |acc, v| udf.call(&acc, v)))
        }
        Rhs::Count { input } => Binding::Scalar(Value::I64(bag(env, *input)?.len() as i64)),
        Rhs::Distinct { input } => {
            let mut items: Vec<Value> = bag(env, *input)?.as_ref().clone();
            items.sort();
            items.dedup();
            Binding::Bag(Arc::new(items))
        }
        Rhs::Union { left, right } => {
            let mut items = bag(env, *left)?.as_ref().clone();
            items.extend(bag(env, *right)?.iter().cloned());
            Binding::Bag(Arc::new(items))
        }
        Rhs::Cross { left, right } => {
            // Capture desugaring can cross a bag with a *scalar* (lifted
            // to a one-element bag only later, §5.2): accept both.
            let l = bag_or_lifted(env, *left)?;
            let r = bag_or_lifted(env, *right)?;
            let mut res = Vec::with_capacity(l.len() * r.len());
            for a in l.iter() {
                for b in r.iter() {
                    res.push(Value::pair(a.clone(), b.clone()));
                }
            }
            Binding::Bag(Arc::new(res))
        }
        Rhs::XlaCall { inputs, spec } => {
            let mut t = crate::ops::xla::XlaCallT::new(spec.clone());
            let in_bags: Vec<Arc<Vec<Value>>> =
                inputs.iter().map(|v| bag(env, *v)).collect::<Result<_>>()?;
            let slices: Vec<&[Value]> = in_bags.iter().map(|b| b.as_slice()).collect();
            Binding::Bag(Arc::new(crate::ops::run_once(&mut t, &slices)))
        }
        Rhs::Fused { input, stages, .. } => {
            // Only `opt::fuse` emits Fused, and the baselines interpret the
            // pre-optimizer IR — but the semantics are well-defined, so
            // support it anyway (differential tests may feed either form).
            let mut res = Vec::new();
            for v in bag(env, *input)?.iter() {
                crate::ops::fused::apply_stages(stages, v, &mut |x| res.push(x));
            }
            Binding::Bag(Arc::new(res))
        }
        Rhs::Phi(_) => {
            return Err(Error::Baseline(
                "Φ in pre-SSA program — the single-threaded baseline interprets the \
                 imperative IR, not SSA"
                    .into(),
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    fn run_src(src: &str) -> BaselineRun {
        run(&parse_and_lower(src).unwrap(), &SingleThreadConfig::default()).unwrap()
    }

    #[test]
    fn loop_semantics_match_imperative_expectation() {
        let out = run_src(
            "d = 1; s = 0; while (d <= 10) { s = s + d; d = d + 1; } collect(bag(1).map(|x| x * s), \"s\");",
        );
        assert_eq!(out.collected("s"), &[Value::I64(55)]);
    }

    #[test]
    fn visit_count_program_runs() {
        let w = crate::workload::VisitCountWorkload {
            days: 3,
            visits_per_day: 500,
            num_pages: 20,
            ..Default::default()
        };
        w.register("st_");
        let src = r#"
            day = 1;
            yesterday = bag();
            while (day <= 3) {
                visits = source("st_visits1");
                counts = visits.map(|x| pair(x, 1)).reduceByKey(|a, b| a + b);
                if (day != 1) {
                    diffs = counts.join(yesterday)
                        .map(|p| abs(fst(snd(p)) - snd(snd(p))));
                    total = diffs.reduce(|a, b| a + b);
                    collect(bag(0).map(|z| z + total), "totals");
                }
                yesterday = counts;
                day = day + 1;
            }
        "#;
        let out = run_src(src);
        // Same file every day -> identical counts -> diffs are all zero.
        assert_eq!(out.collected("totals"), &[Value::I64(0), Value::I64(0)]);
    }

    #[test]
    fn sort_merge_join_handles_duplicates() {
        let out = run_src(
            r#"
            a = bag(1, 1, 2).map(|x| pair(x, 10));
            b = bag(1, 2, 2).map(|x| pair(x, 20));
            j = a.joinBuild(b);
            n = j.count();
            collect(bag(0).map(|z| z + n), "n");
            "#,
        );
        // key 1: 2x1 matches; key 2: 1x2 matches -> 4 total.
        assert_eq!(out.collected("n"), &[Value::I64(4)]);
    }

    #[test]
    fn if_branch_untaken_has_no_side_effects() {
        let out = run_src("x = 1; if (x != 1) { collect(bag(9), \"never\"); }");
        assert!(out.collected("never").is_empty());
    }

    #[test]
    fn nonterminating_loop_detected() {
        let p = parse_and_lower("d = 1; while (d >= 0) { d = d + 1; } collect(bag(1), \"x\");")
            .unwrap();
        let cfg = SingleThreadConfig { max_blocks: 1000, ..Default::default() };
        assert!(run(&p, &cfg).is_err());
    }
}
