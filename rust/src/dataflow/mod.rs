//! SSA → logical dataflow graph (§5.3): one dataflow node per SSA
//! variable, one edge per variable reference, condition nodes for branch
//! variables, conditional output edges for cross-block references, and
//! Φ-nodes translated like any other transformation.

pub mod dot;

use crate::cfg::Cfg;
use crate::error::{Error, Result};
use crate::frontend::{BlockId, Rhs, Terminator, VarId};
use crate::ssa::SsaProgram;
use crate::value::ElemType;
use rustc_hash::FxHashMap;

/// Index of a dataflow node.
pub type NodeId = usize;

/// Parallelism class of a node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Par {
    /// One physical instance (lifted scalars, global sinks/aggregates).
    One,
    /// One physical instance per worker.
    All,
}

/// How elements are routed from the instances of a source node to the
/// instances of a target node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Instance `i` → instance `i` (same parallelism, partition-preserving).
    Forward,
    /// Hash of `Value::key()` selects the target instance (co-partitions
    /// keyed operations).
    HashKey,
    /// Every source instance sends everything to every target instance.
    Broadcast,
    /// Everything goes to target instance 0.
    Gather,
}

/// One logical input of a node.
#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Producing node.
    pub src: NodeId,
    /// Basic block of the producing node (b1 in §6.3.3).
    pub src_block: BlockId,
    /// Element routing.
    pub route: Route,
    /// True iff the edge crosses basic blocks — a *conditional output
    /// edge* (§5.3): whether a given bag is sent is decided by the
    /// execution path (§6.3.4).
    pub conditional: bool,
}

/// Condition-node role (§5.3): the boolean variable of a `Branch`
/// terminator. After its singleton output bag closes, the runtime appends
/// the decided chain of basic blocks to the execution path.
#[derive(Clone, Debug)]
pub struct CondSpec {
    /// Chain appended when the condition is true (§6.3.1 auto-append of
    /// single-successor blocks).
    pub then_chain: Vec<BlockId>,
    /// Chain appended when the condition is false.
    pub else_chain: Vec<BlockId>,
}

/// Delta-mode role assigned to a node by the `opt::delta` pass (the
/// incremental-iteration subsystem; see `docs/incremental.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaMode {
    /// Loop-header Φ of a re-aggregation loop: holds a keyed upsert
    /// solution set ([`crate::ops::state::KeyedStore`]); emits arriving
    /// rows downstream only on its init bag.
    PhiUpsert,
    /// Loop-header Φ of a semi-naive loop: holds a monotone frontier
    /// store ([`crate::ops::state::FrontierStore`]); arriving rows are
    /// the per-step frontier and are always re-emitted.
    PhiFrontier,
    /// Back-edge reduceByKey: retains its accumulator across supersteps
    /// and emits only the keys whose accumulator changed.
    AccReduce,
    /// Back-edge distinct: retains its seen-set across supersteps and
    /// emits only globally-new elements.
    AccDistinct,
}

/// Delta annotation on a node (set by the `opt::delta` pass, honored by
/// `ops::make_node` and [`crate::exec::ExecPlan`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaSpec {
    /// The node's role in the delta loop.
    pub mode: DeltaMode,
    /// Sorted basic blocks of the natural loop this node's delta state
    /// belongs to; the engine resets the state when the execution path
    /// leaves these blocks (outer-loop re-entry).
    pub loop_blocks: Vec<BlockId>,
}

impl DeltaSpec {
    /// Whether `b` belongs to the delta loop.
    pub fn in_loop(&self, b: BlockId) -> bool {
        self.loop_blocks.binary_search(&b).is_ok()
    }

    /// Whether this is one of the Φ (solution-set) roles.
    pub fn is_phi(&self) -> bool {
        matches!(self.mode, DeltaMode::PhiUpsert | DeltaMode::PhiFrontier)
    }
}

/// A logical dataflow node (one SSA variable).
#[derive(Clone, Debug)]
pub struct Node {
    /// Node id (dense).
    pub id: NodeId,
    /// SSA variable name (diagnostics).
    pub name: String,
    /// SSA variable this node computes.
    pub var: VarId,
    /// Basic block of the defining assignment.
    pub block: BlockId,
    /// The operation (the SSA right-hand side; `ops::make` instantiates the
    /// transformation).
    pub op: Rhs,
    /// Parallelism class.
    pub par: Par,
    /// Logical inputs, in operator-argument order.
    pub inputs: Vec<InputSpec>,
    /// Condition-node role, if this variable drives a branch.
    pub cond: Option<CondSpec>,
    /// Whether this node's output holds a lifted scalar (singleton bag).
    pub singleton: bool,
    /// The block this node lived in before `opt::hoist` moved it into a
    /// loop preamble (`None` = never hoisted). Kept for diagnostics and
    /// the DOT rendering of hoisted preambles.
    pub hoisted_from: Option<BlockId>,
    /// Known output size for source nodes (`bag(...)` literal length,
    /// registered dataset size for `source("name")`), filled by [`build`]
    /// and consumed by the `opt::cost` cardinality model. `None` when the
    /// size is unknowable at compile time (e.g. `readFile`).
    pub size_hint: Option<usize>,
    /// Known element type for source nodes (joined over a sample of a
    /// `bag(...)` literal or registered dataset), filled by [`build`] and
    /// consumed by the `opt::types` inference pass. Hints are advisory —
    /// the columnar runtime re-verifies every batch it decodes — so a
    /// sampled hint that misses a late heterogeneous element costs only
    /// the fast path, never correctness. `None` when nothing is known
    /// (e.g. `readFile` before reading, empty literals).
    pub elem_hint: Option<ElemType>,
    /// For `Rhs::Join` nodes: which logical input the hash join should use
    /// as its build side (`None` / `Some(0)` = left, the §5.3 default;
    /// `Some(1)` = right). Set by the `opt::joinside` pass from the cost
    /// model; honored by [`crate::exec::ExecPlan`] / `ops::join`. Output
    /// pair order is unaffected — this is a physical-plan choice only.
    pub build_side: Option<usize>,
    /// Delta-mode annotation (`opt::delta`): `None` = full recompute
    /// (the default); `Some` = this node participates in a
    /// delta-incremental loop and keeps solution-set state resident
    /// across supersteps.
    pub delta: Option<DeltaSpec>,
}

/// The compiled logical dataflow job.
#[derive(Clone, Debug)]
pub struct DataflowGraph {
    /// Nodes, topologically unordered (ids are dense).
    pub nodes: Vec<Node>,
    /// Map SSA var → node id.
    pub node_of_var: FxHashMap<VarId, NodeId>,
    /// The CFG (shared shape with the SSA program).
    pub cfg: Cfg,
    /// Blocks appended to the execution path at job start:
    /// `chain(entry)` (§6.3.1).
    pub entry_chain: Vec<BlockId>,
    /// Human-readable listing of the source SSA (diagnostics).
    pub ssa_listing: String,
    /// Optimizer summary counters (`opt.*` keys, filled by
    /// `opt::optimize`); the engine copies them into the run's metrics so
    /// per-pass effects are visible next to runtime counters.
    pub opt_summary: Vec<(String, u64)>,
    /// Inferred output element type per node (indexed by [`NodeId`]),
    /// filled by the `opt::types` inference pass after the plan shape is
    /// final. Empty until inference runs; [`DataflowGraph::elem_type`]
    /// degrades to [`ElemType::Dyn`] in that case.
    pub elem_types: Vec<ElemType>,
    /// Columnar-plane gate copied from `OptConfig` by `opt::optimize`;
    /// `ops::make_node` consults it (together with the inferred types)
    /// when deciding whether to install typed kernels. Defaults to
    /// `Never` so a graph that skipped the optimizer runs the dynamic
    /// `Value` path exactly as before.
    pub columnar: crate::opt::ColumnarGate,
}

impl DataflowGraph {
    /// Inferred output element type of a node; [`ElemType::Dyn`] when the
    /// `opt::types` pass has not run (or gave up on the node).
    pub fn elem_type(&self, n: NodeId) -> ElemType {
        self.elem_types.get(n).cloned().unwrap_or(ElemType::Dyn)
    }

    /// Downstream consumers of a node: `(consumer, input index)`.
    pub fn consumers(&self, n: NodeId) -> Vec<(NodeId, usize)> {
        let mut out = Vec::new();
        for node in &self.nodes {
            for (i, inp) in node.inputs.iter().enumerate() {
                if inp.src == n {
                    out.push((node.id, i));
                }
            }
        }
        out
    }

    /// Number of logical nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Condition nodes in the graph.
    pub fn condition_nodes(&self) -> Vec<NodeId> {
        self.nodes.iter().filter(|n| n.cond.is_some()).map(|n| n.id).collect()
    }

    /// For a Φ node, the defining blocks of the *other* inputs (the §6.3.4
    /// blockers when deciding whether to send a bag to this Φ on edge
    /// `input_idx`).
    pub fn phi_sibling_blocks(&self, node: NodeId, input_idx: usize) -> Vec<BlockId> {
        let n = &self.nodes[node];
        if !matches!(n.op, Rhs::Phi(_)) {
            return Vec::new();
        }
        n.inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != input_idx)
            .map(|(_, inp)| inp.src_block)
            .collect()
    }
}

/// Per-operation input routing requirement.
fn input_requirements(op: &Rhs) -> Vec<Req> {
    use Req::*;
    match op {
        Rhs::Join { .. } => vec![Key, Key],
        Rhs::ReduceByKey { .. } | Rhs::Distinct { .. } => vec![Key],
        Rhs::ReadFile { .. } => vec![Bcast],
        Rhs::WriteFile { .. } => vec![Any, Bcast],
        Rhs::XlaCall { inputs, .. } => vec![Any; inputs.len()],
        Rhs::Phi(args) => vec![Any; args.len()],
        Rhs::Union { .. } => vec![Any, Any],
        // Distributed cross: keep the left side partitioned, broadcast the
        // right side (which is a lifted scalar in §5.2 lifting and in
        // captured-scalar lambda desugaring).
        Rhs::Cross { .. } => vec![Any, Bcast],
        Rhs::Collect { .. }
        | Rhs::Map { .. }
        | Rhs::Filter { .. }
        | Rhs::FlatMap { .. }
        | Rhs::Fused { .. }
        | Rhs::Reduce { .. }
        | Rhs::Count { .. } => vec![Any],
        Rhs::Const(_) | Rhs::BagLit(_) | Rhs::NamedSource(_) => vec![],
        Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => {
            unreachable!("removed before dataflow build")
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Req {
    Key,
    Bcast,
    Any,
}

fn resolve_route(req: Req, src_par: Par, dst_par: Par) -> Route {
    match req {
        Req::Key => Route::HashKey,
        Req::Bcast => Route::Broadcast,
        Req::Any => match (src_par, dst_par) {
            (Par::One, Par::One) => Route::Forward,
            (Par::All, Par::All) => Route::Forward,
            (Par::One, Par::All) => Route::HashKey,
            (Par::All, Par::One) => Route::Gather,
        },
    }
}

/// Does this op produce a singleton (lifted-scalar) bag when its inputs
/// are singletons? Used by the parallelism-inference fixpoint.
fn singleton_out(op: &Rhs, input_singleton: &[bool]) -> bool {
    match op {
        Rhs::BagLit(items) => items.len() == 1,
        Rhs::Reduce { .. } | Rhs::Count { .. } => true,
        Rhs::WriteFile { .. } | Rhs::Collect { .. } => true, // Unit singleton
        Rhs::Map { .. } | Rhs::Filter { .. } => input_singleton[0],
        // A fused chain without flatMap stages never grows the bag.
        Rhs::Fused { stages, .. } => {
            stages.iter().all(|s| !s.expands()) && input_singleton[0]
        }
        Rhs::Cross { .. } => input_singleton.iter().all(|&s| s),
        Rhs::Phi(_) => input_singleton.iter().all(|&s| s),
        _ => false,
    }
}

/// Build the logical dataflow graph from lifted SSA, resolving
/// `source("name")` size hints against the process-global registry.
pub fn build(ssa: &SsaProgram) -> Result<DataflowGraph> {
    build_with(ssa, &crate::workload::registry::global())
}

/// [`build`] with an explicit named-source registry for size hints. The
/// `serve::` job service passes the request's registry overlay here so
/// per-request datasets inform the cost model of the compiled template.
pub fn build_with(
    ssa: &SsaProgram,
    registry: &crate::workload::registry::Registry,
) -> Result<DataflowGraph> {
    let cfg = ssa.cfg.clone();
    let mut nodes: Vec<Node> = Vec::new();
    let mut node_of_var: FxHashMap<VarId, NodeId> = FxHashMap::default();

    // Pass 1: create nodes (inputs resolved in pass 2 so forward references
    // from Φ back-edges work).
    for (bi, block) in ssa.blocks.iter().enumerate() {
        if !cfg.reachable(bi) {
            continue;
        }
        for instr in &block.instrs {
            let id = nodes.len();
            node_of_var.insert(instr.var, id);
            // Source size hints for the cost model: literal lengths are
            // exact; named sources resolve against the registry (benches
            // register datasets before compiling), else unknown. Element
            // types for `opt::types` come from the same data (a bounded
            // sample — hints are runtime-verified, see `Node::elem_hint`).
            let (size_hint, elem_hint) = match &instr.rhs {
                Rhs::BagLit(items) => (Some(items.len()), sample_elem_type(items)),
                Rhs::NamedSource(name) => match registry.get(name) {
                    Some(d) => (Some(d.len()), sample_elem_type(&d)),
                    None => (None, None),
                },
                _ => (None, None),
            };
            nodes.push(Node {
                id,
                name: ssa.vars[instr.var].name.clone(),
                var: instr.var,
                block: bi,
                op: instr.rhs.clone(),
                par: Par::All, // refined below
                inputs: Vec::new(),
                cond: None,
                singleton: false,
                hoisted_from: None,
                size_hint,
                elem_hint,
                build_side: None,
                delta: None,
            });
        }
    }

    // Pass 2: edges (one per variable reference, §5.3).
    for nid in 0..nodes.len() {
        let op = nodes[nid].op.clone();
        let input_vars: Vec<VarId> = op.input_vars();
        let mut inputs = Vec::with_capacity(input_vars.len());
        for v in &input_vars {
            let src = *node_of_var.get(v).ok_or_else(|| {
                Error::Dataflow(format!(
                    "node '{}' references variable '{}' with no dataflow node",
                    nodes[nid].name, ssa.vars[*v].name
                ))
            })?;
            let src_block = nodes[src].block;
            inputs.push(InputSpec {
                src,
                src_block,
                route: Route::Forward, // refined below
                conditional: src_block != nodes[nid].block,
            });
        }
        nodes[nid].inputs = inputs;
    }

    // Pass 3: singleton-ness fixpoint (optimistic start, monotone AND).
    let mut singleton = vec![true; nodes.len()];
    loop {
        let mut changed = false;
        for n in &nodes {
            let ins: Vec<bool> = n.inputs.iter().map(|i| singleton[i.src]).collect();
            let s = singleton_out(&n.op, &ins);
            if s != singleton[n.id] {
                singleton[n.id] = s;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    for n in &mut nodes {
        n.singleton = singleton[n.id];
        n.par = match &n.op {
            _ if singleton[n.id] => Par::One,
            Rhs::Reduce { .. }
            | Rhs::Count { .. }
            | Rhs::WriteFile { .. }
            | Rhs::Collect { .. }
            | Rhs::XlaCall { .. } => Par::One,
            _ => Par::All,
        };
    }

    // Pass 4: routes.
    for nid in 0..nodes.len() {
        let reqs = input_requirements(&nodes[nid].op);
        if reqs.len() != nodes[nid].inputs.len() {
            return Err(Error::Dataflow(format!(
                "node '{}' arity mismatch: {} inputs vs {} requirements",
                nodes[nid].name,
                nodes[nid].inputs.len(),
                reqs.len()
            )));
        }
        for (i, req) in reqs.iter().enumerate() {
            let src_par = nodes[nodes[nid].inputs[i].src].par;
            let dst_par = nodes[nid].par;
            nodes[nid].inputs[i].route = resolve_route(*req, src_par, dst_par);
        }
    }

    // Pass 5: condition nodes (§5.3) — the variable of each Branch.
    for (bi, block) in ssa.blocks.iter().enumerate() {
        if !cfg.reachable(bi) {
            continue;
        }
        if let Terminator::Branch { cond, then_b, else_b } = block.term {
            let nid = *node_of_var.get(&cond).ok_or_else(|| {
                Error::Dataflow(format!("branch condition var {cond} has no node"))
            })?;
            if nodes[nid].block != bi {
                return Err(Error::Dataflow(format!(
                    "condition node '{}' not in branching block",
                    nodes[nid].name
                )));
            }
            nodes[nid].cond = Some(CondSpec {
                then_chain: cfg.chain(then_b),
                else_chain: cfg.chain(else_b),
            });
        }
    }

    let entry_chain = cfg.chain(cfg.program.entry);
    Ok(DataflowGraph {
        nodes,
        node_of_var,
        cfg,
        entry_chain,
        ssa_listing: ssa.listing(),
        opt_summary: Vec::new(),
        elem_types: Vec::new(),
        columnar: crate::opt::ColumnarGate::Never,
    })
}

/// Join the element types of a bounded sample of a source dataset. The
/// cap keeps compile time flat for large registered datasets; a sample
/// that misses a heterogeneous tail yields an optimistic hint, which the
/// columnar runtime's verified decode demotes to the dynamic path at the
/// first non-conforming batch.
fn sample_elem_type(items: &[Value]) -> Option<ElemType> {
    const SAMPLE: usize = 256;
    items
        .iter()
        .take(SAMPLE)
        .map(ElemType::of_value)
        .reduce(|a, b| a.join(&b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    // These tests assert the RAW translation of §5.3; the optimizer may
    // legally restructure (hoist/fuse), so build without it.
    fn graph(src: &str) -> DataflowGraph {
        crate::compile_with(&parse_and_lower(src).unwrap(), &crate::opt::OptConfig::none())
            .unwrap()
            .0
    }

    #[test]
    fn node_per_variable_edge_per_reference() {
        let g = graph("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"out\");");
        // bagLit, map, collect
        assert_eq!(g.num_nodes(), 3);
        let map = g.nodes.iter().find(|n| matches!(n.op, Rhs::Map { .. })).unwrap();
        assert_eq!(map.inputs.len(), 1);
        assert!(!map.inputs[0].conditional, "same-block edge is unconditional");
    }

    #[test]
    fn loop_creates_condition_node_and_phi() {
        let g = graph("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");");
        let conds = g.condition_nodes();
        assert_eq!(conds.len(), 1);
        let cond = &g.nodes[conds[0]];
        let spec = cond.cond.as_ref().unwrap();
        assert!(!spec.then_chain.is_empty());
        assert!(!spec.else_chain.is_empty());
        // Phi node exists and has conditional inputs (cross-block).
        let phi = g.nodes.iter().find(|n| matches!(n.op, Rhs::Phi(_))).unwrap();
        assert_eq!(phi.inputs.len(), 2);
        assert!(phi.inputs.iter().all(|i| i.conditional));
        // Loop counter nodes are singletons with Par::One.
        assert_eq!(phi.par, Par::One);
        assert!(phi.singleton);
    }

    #[test]
    fn cross_block_edges_are_conditional() {
        let g = graph(
            "attrs = bag(1, 2); d = 1; while (d <= 3) { v = attrs.map(|x| x + 1); collect(v, \"v\"); d = d + 1; }",
        );
        // attrs (entry block) -> map (loop body): conditional edge.
        let map = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Map { .. }) && !n.singleton)
            .unwrap();
        assert!(map.inputs[0].conditional);
    }

    #[test]
    fn join_inputs_hash_routed() {
        let g = graph(
            "a = bag(1).map(|x| pair(x, x)); b = bag(1).map(|x| pair(x, x)); j = a.join(b); collect(j, \"j\");",
        );
        let join = g.nodes.iter().find(|n| matches!(n.op, Rhs::Join { .. })).unwrap();
        assert_eq!(join.inputs.len(), 2);
        for i in &join.inputs {
            assert_eq!(i.route, Route::HashKey);
        }
        assert_eq!(join.par, Par::All);
    }

    #[test]
    fn bag_phi_is_parallel() {
        let g = graph(
            "y = bag(); d = 1; while (d <= 3) { c = bag(1, 2).map(|x| pair(x, 1)); y = c; d = d + 1; } collect(y, \"y\");",
        );
        let phi = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Rhs::Phi(_)) && !n.singleton)
            .expect("bag phi");
        assert_eq!(phi.par, Par::All);
    }

    #[test]
    fn entry_chain_starts_at_entry() {
        let g = graph("a = bag(1); collect(a, \"x\");");
        assert_eq!(g.entry_chain, vec![g.cfg.program.entry]);
    }

    #[test]
    fn phi_sibling_blocks_reported() {
        let g = graph("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");");
        let phi = g.nodes.iter().find(|n| matches!(n.op, Rhs::Phi(_))).unwrap();
        let sib0 = g.phi_sibling_blocks(phi.id, 0);
        let sib1 = g.phi_sibling_blocks(phi.id, 1);
        assert_eq!(sib0.len(), 1);
        assert_eq!(sib1.len(), 1);
        assert_ne!(sib0[0], sib1[0]);
    }

    #[test]
    fn consumers_inverse_of_inputs() {
        let g = graph("a = bag(1, 2); b = a.map(|x| x + 1); c = a.filter(|x| x > 0); collect(b, \"b\"); collect(c, \"c\");");
        let src = g.nodes.iter().find(|n| matches!(n.op, Rhs::BagLit(ref v) if v.len() == 2)).unwrap();
        let cons = g.consumers(src.id);
        assert_eq!(cons.len(), 2);
    }
}
