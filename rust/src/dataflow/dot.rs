//! Graphviz DOT export of logical dataflow graphs — mirrors Fig. 3b of the
//! paper: basic blocks as dotted clusters, condition nodes colored,
//! conditional edges dashed, Φ-nodes with inverted colors. Optimizer
//! results are visually distinct: nodes hoisted by `opt::hoist` sit in a
//! nested "hoisted preamble" cluster inside their preamble block, fused
//! chains from `opt::fuse` are filled green with their stage count, every
//! node label carries the `opt::cost` row estimate (`~Nr`), joins
//! whose build side `opt::joinside` flipped are tagged `build=right`,
//! and nodes rewritten by `opt::delta` are tagged `mode=delta`.
//! See `docs/dot.md` for the full legend.

use super::{DataflowGraph, Node, Par};
use crate::frontend::Rhs;
use std::fmt::Write as _;

fn node_attrs(n: &Node, rows: f64) -> Vec<String> {
    let mut label = format!("{}\\n{}\\n~{}r", n.name, n.op.mnemonic(), rows.round() as u64);
    if matches!(n.op, Rhs::Join { .. }) && n.build_side == Some(1) {
        label.push_str("\\nbuild=right");
    }
    if n.delta.is_some() {
        // `opt::delta` put this node in delta-incremental mode: a Φ
        // holding a solution set or a back-edge operator emitting only
        // changed rows.
        label.push_str("\\nmode=delta");
    }
    let mut attrs = vec![format!("label=\"{label}\"")];
    if matches!(n.op, Rhs::Phi(_)) {
        attrs.push("style=filled".into());
        attrs.push("fillcolor=black".into());
        attrs.push("fontcolor=white".into());
    } else if n.cond.is_some() {
        attrs.push("style=filled".into());
        attrs.push("fillcolor=orange".into());
    } else if matches!(n.op, Rhs::Fused { .. }) {
        attrs.push("style=filled".into());
        attrs.push("fillcolor=palegreen".into());
    } else if n.hoisted_from.is_some() {
        attrs.push("style=filled".into());
        attrs.push("fillcolor=lightblue".into());
    }
    if n.par == Par::All {
        attrs.push("penwidth=2".into());
    }
    attrs
}

/// Render the dataflow graph as DOT.
pub fn to_dot(g: &DataflowGraph) -> String {
    // Row estimates for the `~Nr` label suffix (default cost parameters —
    // this is a diagnostic rendering, not the optimizer's own analysis).
    let rows = crate::opt::cost::estimate_rows(g, &crate::opt::cost::CostParams::default());
    let mut s = String::new();
    let _ = writeln!(s, "digraph labyrinth {{");
    let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontname=\"monospace\"];");
    // Cluster nodes by basic block.
    let mut blocks: Vec<Vec<usize>> = vec![Vec::new(); g.cfg.num_blocks()];
    for n in &g.nodes {
        blocks[n.block].push(n.id);
    }
    for (bi, ids) in blocks.iter().enumerate() {
        if ids.is_empty() {
            continue;
        }
        let _ = writeln!(s, "  subgraph cluster_bb{bi} {{");
        let _ = writeln!(s, "    label=\"bb{bi}\"; style=dotted;");
        let (hoisted, resident): (Vec<&usize>, Vec<&usize>) =
            ids.iter().partition(|&&id| g.nodes[id].hoisted_from.is_some());
        for &id in resident {
            let n = &g.nodes[id];
            let _ = writeln!(s, "    n{id} [{}];", node_attrs(n, rows[id]).join(", "));
        }
        if !hoisted.is_empty() {
            // Nested cluster: the loop preamble region executed once per
            // loop entry, before the loop's first step.
            let _ = writeln!(s, "    subgraph cluster_bb{bi}_preamble {{");
            let _ = writeln!(
                s,
                "      label=\"hoisted preamble\"; style=filled; color=lightgrey;"
            );
            for &id in hoisted {
                let n = &g.nodes[id];
                let mut attrs = node_attrs(n, rows[id]);
                attrs.push(format!(
                    "tooltip=\"hoisted from bb{}\"",
                    n.hoisted_from.expect("partitioned on hoisted_from")
                ));
                let _ = writeln!(s, "      n{id} [{}];", attrs.join(", "));
            }
            let _ = writeln!(s, "    }}");
        }
        let _ = writeln!(s, "  }}");
    }
    for n in &g.nodes {
        for inp in &n.inputs {
            let style = if inp.conditional { "dashed" } else { "solid" };
            // Inferred element type of the edge (`opt::types`): `type=dyn`
            // marks edges where inference gave up — the dynamic path.
            let _ = writeln!(
                s,
                "  n{} -> n{} [style={style}, label=\"{:?}\\ntype={}\"];",
                inp.src,
                n.id,
                inp.route,
                g.elem_type(inp.src)
            );
        }
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use crate::frontend::parse_and_lower;

    #[test]
    fn dot_contains_clusters_and_edges() {
        let g = crate::compile(
            &parse_and_lower("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");")
                .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.starts_with("digraph"));
        assert!(dot.contains("cluster_bb"));
        assert!(dot.contains("style=dashed"), "{dot}");
        assert!(dot.contains("fillcolor=orange"), "{dot}");
        assert!(dot.contains("fillcolor=black"), "{dot}");
    }

    #[test]
    fn hoisted_nodes_render_in_preamble_cluster() {
        let g = crate::compile(
            &parse_and_lower(
                "d = 1; while (d <= 3) { v = bag(1, 2).map(|x| x * 10); collect(v, \"v\"); d = d + 1; }",
            )
            .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.contains("hoisted preamble"), "{dot}");
        assert!(dot.contains("fillcolor=lightblue"), "{dot}");
        assert!(dot.contains("hoisted from bb"), "{dot}");
    }

    #[test]
    fn row_estimates_annotate_every_node() {
        let g = crate::compile(
            &parse_and_lower("a = bag(1, 2, 3); collect(a, \"a\");").unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.contains("~3r"), "source size hint rendered:\n{dot}");
    }

    #[test]
    fn flipped_join_build_side_is_tagged() {
        crate::workload::registry::global().put(
            "dot_big",
            (0..64).map(crate::value::Value::I64).collect(),
        );
        crate::workload::registry::global().put(
            "dot_small",
            (0..4).map(crate::value::Value::I64).collect(),
        );
        let g = crate::compile(
            &parse_and_lower(
                "big = source(\"dot_big\").map(|v| pair(v % 4, v)); small = source(\"dot_small\").map(|v| pair(v % 4, v)); j = big.joinBuild(small); collect(j, \"j\");",
            )
            .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.contains("build=right"), "{dot}");
        crate::workload::registry::global().clear_prefix("dot_");
    }

    #[test]
    fn edges_render_inferred_types() {
        let g = crate::compile(
            &parse_and_lower("a = bag(1, 2, 3); b = a.map(|x| x + 1); collect(b, \"b\");")
                .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        // The bag(1,2,3) source edge types as i64; every edge carries a
        // type label (dyn where inference gave up).
        assert!(dot.contains("type=i64"), "{dot}");
        let g2 = crate::compile(
            &parse_and_lower(
                "a = bag(1, \"s\"); b = a.map(|x| x); collect(b, \"b\");",
            )
            .unwrap(),
        )
        .unwrap();
        let dot2 = super::to_dot(&g2);
        assert!(dot2.contains("type=dyn"), "{dot2}");
    }

    #[test]
    fn fused_chains_render_green() {
        let g = crate::compile(
            &parse_and_lower(
                "a = bag(1, 2, 3); b = a.map(|x| x + 1).filter(|x| x > 1).map(|x| x * 2); collect(b, \"b\");",
            )
            .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.contains("fillcolor=palegreen"), "{dot}");
        assert!(dot.contains("fused[3]"), "{dot}");
    }
}
