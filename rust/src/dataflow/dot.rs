//! Graphviz DOT export of logical dataflow graphs — mirrors Fig. 3b of the
//! paper: basic blocks as dotted clusters, condition nodes colored,
//! conditional edges dashed, Φ-nodes with inverted colors.

use super::{DataflowGraph, Par};
use crate::frontend::Rhs;
use std::fmt::Write as _;

/// Render the dataflow graph as DOT.
pub fn to_dot(g: &DataflowGraph) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph labyrinth {{");
    let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontname=\"monospace\"];");
    // Cluster nodes by basic block.
    let mut blocks: Vec<Vec<usize>> = vec![Vec::new(); g.cfg.num_blocks()];
    for n in &g.nodes {
        blocks[n.block].push(n.id);
    }
    for (bi, ids) in blocks.iter().enumerate() {
        if ids.is_empty() {
            continue;
        }
        let _ = writeln!(s, "  subgraph cluster_bb{bi} {{");
        let _ = writeln!(s, "    label=\"bb{bi}\"; style=dotted;");
        for &id in ids {
            let n = &g.nodes[id];
            let mut attrs = vec![format!("label=\"{}\\n{}\"", n.name, n.op.mnemonic())];
            if matches!(n.op, Rhs::Phi(_)) {
                attrs.push("style=filled".into());
                attrs.push("fillcolor=black".into());
                attrs.push("fontcolor=white".into());
            } else if n.cond.is_some() {
                attrs.push("style=filled".into());
                attrs.push("fillcolor=orange".into());
            }
            if n.par == Par::All {
                attrs.push("penwidth=2".into());
            }
            let _ = writeln!(s, "    n{id} [{}];", attrs.join(", "));
        }
        let _ = writeln!(s, "  }}");
    }
    for n in &g.nodes {
        for inp in &n.inputs {
            let style = if inp.conditional { "dashed" } else { "solid" };
            let _ = writeln!(
                s,
                "  n{} -> n{} [style={style}, label=\"{:?}\"];",
                inp.src, n.id, inp.route
            );
        }
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use crate::frontend::parse_and_lower;

    #[test]
    fn dot_contains_clusters_and_edges() {
        let g = crate::compile(
            &parse_and_lower("d = 1; while (d <= 3) { d = d + 1; } collect(bag(1), \"x\");")
                .unwrap(),
        )
        .unwrap();
        let dot = super::to_dot(&g);
        assert!(dot.starts_with("digraph"));
        assert!(dot.contains("cluster_bb"));
        assert!(dot.contains("style=dashed"), "{dot}");
        assert!(dot.contains("fillcolor=orange"), "{dot}");
        assert!(dot.contains("fillcolor=black"), "{dot}");
    }
}
