//! Canonical experiment programs (shared by benches, integration tests,
//! and the CLI): the §9.1.2 iteration-step microbench, the §9.2.1 Visit
//! Count program with and without its loop-invariant join, and the §9.2.2
//! nested-loop PageRank. Each returns the *imperative IR*, runnable by
//! every executor.

use crate::frontend::builder::{udf1, udf2, ProgramBuilder};
use crate::frontend::Program;
use crate::value::Value;

/// §9.1.2 microbench: `numSteps` iterations of `bag.map(x => x + 1)` over
/// a 200-element bag, with the loop counter lifted into the dataflow.
pub fn step_overhead_microbench(num_steps: i64, bag_size: usize) -> Program {
    let mut b = ProgramBuilder::new();
    let init = b.bag_lit((0..bag_size as i64).map(Value::I64).collect());
    let bag = b.declare_bag("bag", init);
    let zero = b.scalar_i64(0);
    let i = b.declare_scalar("i", zero);
    b.while_(
        |b| b.scalar_lt_i64(i, num_steps),
        |b| {
            let mapped = b.map(bag, udf1(|v| Value::I64(v.as_i64() + 1)));
            // The paper makes the map a pipeline breaker for fairness with
            // Flink/Naiad supersteps; reduceByKey over a constant key plays
            // that role without changing the data volume.
            let keyed = b.map(mapped, udf1(|v| Value::pair(Value::I64(v.as_i64() % 64), v.clone())));
            let broken = b.reduce_by_key(keyed, udf2(|a, _b| a.clone()));
            let unkeyed = b.map(broken, udf1(|v| v.val().clone()));
            b.assign_bag(bag, unkeyed);
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    b.collect(bag, "bag");
    b.finish()
}

/// §9.2.1 Visit Count (without the invariant join — the Fig. 6 variant).
/// Expects named sources `{prefix}visits{day}` (1-based).
pub fn visit_count(days: i64, prefix: &str) -> Program {
    let mut b = ProgramBuilder::new();
    let one = b.scalar_i64(1);
    let day = b.declare_scalar("day", one);
    let empty = b.bag_lit(vec![]);
    let yesterday = b.declare_bag("yesterday", empty);
    let prefix = prefix.to_string();
    b.while_(
        |b| b.scalar_le_i64(day, days),
        |b| {
            let name = b.scalar_concat(&format!("{prefix}visits"), day);
            let visits = b.read_file(name);
            let keyed = b.map(visits, udf1(|v| Value::pair(v.clone(), Value::I64(1))));
            let counts =
                b.reduce_by_key(keyed, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
            let not_first = b.scalar_ne_i64(day, 1);
            b.if_then(not_first, |b| {
                let joined = b.join(yesterday, counts);
                let diffs = b.map(
                    joined,
                    udf1(|p| {
                        let lr = p.val();
                        Value::I64((lr.key().as_i64() - lr.val().as_i64()).abs())
                    }),
                );
                let total = b.reduce(diffs, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
                let out = b.lift_scalar(total);
                b.collect(out, "daily_diffs");
            });
            b.assign_bag(yesterday, counts);
            let d2 = b.scalar_add_i64(day, 1);
            b.assign_scalar(day, d2);
        },
    );
    b.finish()
}

/// §9.4 Visit Count WITH the loop-invariant attribute join (Fig. 8).
/// Expects `{prefix}visits{day}` and `{prefix}attrs` named sources.
pub fn visit_count_with_join(days: i64, prefix: &str) -> Program {
    let mut b = ProgramBuilder::new();
    let attrs = b.named_source(format!("{prefix}attrs"));
    let one = b.scalar_i64(1);
    let day = b.declare_scalar("day", one);
    let empty = b.bag_lit(vec![]);
    let yesterday = b.declare_bag("yesterday", empty);
    let prefix = prefix.to_string();
    b.while_(
        |b| b.scalar_le_i64(day, days),
        |b| {
            let name = b.scalar_concat(&format!("{prefix}visits"), day);
            let visits = b.read_file(name);
            let keyed = b.map(visits, udf1(|v| Value::pair(v.clone(), Value::I64(1))));
            // Invariant join: attrs is the build side, kept across steps.
            let joined = b.join(attrs, keyed);
            let typed = b.filter(joined, udf1(|p| Value::Bool(p.val().key().as_i64() == 0)));
            let rekeyed =
                b.map(typed, udf1(|p| Value::pair(p.key().clone(), Value::I64(1))));
            let counts =
                b.reduce_by_key(rekeyed, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
            let not_first = b.scalar_ne_i64(day, 1);
            b.if_then(not_first, |b| {
                let j2 = b.join(yesterday, counts);
                let diffs = b.map(
                    j2,
                    udf1(|p| {
                        let lr = p.val();
                        Value::I64((lr.key().as_i64() - lr.val().as_i64()).abs())
                    }),
                );
                let total = b.reduce(diffs, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
                let out = b.lift_scalar(total);
                b.collect(out, "daily_diffs");
            });
            b.assign_bag(yesterday, counts);
            let d2 = b.scalar_add_i64(day, 1);
            b.assign_scalar(day, d2);
        },
    );
    b.finish()
}

/// The Fig. 8 program as a user would naturally write it: the invariant
/// attribute dataset is referenced INSIDE the loop body, so nothing is
/// hand-hoisted. Without `opt::hoist` the build side's bag identity
/// changes every step (the source recomputes per iteration) and the §7
/// runtime reuse can never fire; with the pass, the source and its
/// consumers move to the loop preamble and the compiled plan is
/// equivalent to [`visit_count_with_join`]. Expects the same named
/// sources.
pub fn visit_count_with_join_in_loop(days: i64, prefix: &str) -> Program {
    let mut b = ProgramBuilder::new();
    let one = b.scalar_i64(1);
    let day = b.declare_scalar("day", one);
    let empty = b.bag_lit(vec![]);
    let yesterday = b.declare_bag("yesterday", empty);
    let prefix = prefix.to_string();
    b.while_(
        |b| b.scalar_le_i64(day, days),
        |b| {
            // The invariant join's build side, written inside the loop.
            let attrs = b.named_source(format!("{prefix}attrs"));
            let name = b.scalar_concat(&format!("{prefix}visits"), day);
            let visits = b.read_file(name);
            let keyed = b.map(visits, udf1(|v| Value::pair(v.clone(), Value::I64(1))));
            let joined = b.join(attrs, keyed);
            let typed = b.filter(joined, udf1(|p| Value::Bool(p.val().key().as_i64() == 0)));
            let rekeyed =
                b.map(typed, udf1(|p| Value::pair(p.key().clone(), Value::I64(1))));
            let counts =
                b.reduce_by_key(rekeyed, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
            let not_first = b.scalar_ne_i64(day, 1);
            b.if_then(not_first, |b| {
                let j2 = b.join(yesterday, counts);
                let diffs = b.map(
                    j2,
                    udf1(|p| {
                        let lr = p.val();
                        Value::I64((lr.key().as_i64() - lr.val().as_i64()).abs())
                    }),
                );
                let total = b.reduce(diffs, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
                let out = b.lift_scalar(total);
                b.collect(out, "daily_diffs");
            });
            b.assign_bag(yesterday, counts);
            let d2 = b.scalar_add_i64(day, 1);
            b.assign_scalar(day, d2);
        },
    );
    b.finish()
}

/// Incremental Visit Count: the running per-page total is the
/// loop-carried bag itself (`total = total.union(day_visits)
/// .reduceByKey(+)`), the shape `opt::delta` proves upsert-safe. Under
/// delta mode the Φ holds the totals as an indexed solution set and each
/// superstep circulates only the keys the day's visits actually touched;
/// without it every iteration re-reduces the full accumulated history.
/// Expects named sources `{prefix}visits{day}` (1-based).
pub fn visit_count_incremental(days: i64, prefix: &str) -> Program {
    let mut b = ProgramBuilder::new();
    let one = b.scalar_i64(1);
    let day = b.declare_scalar("day", one);
    let empty = b.bag_lit(vec![]);
    let total = b.declare_bag("total", empty);
    let prefix = prefix.to_string();
    b.while_(
        |b| b.scalar_le_i64(day, days),
        |b| {
            let name = b.scalar_concat(&format!("{prefix}visits"), day);
            let visits = b.read_file(name);
            let keyed = b.map(visits, udf1(|v| Value::pair(v.clone(), Value::I64(1))));
            let merged = b.union(total, keyed);
            let counts =
                b.reduce_by_key(merged, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
            b.assign_bag(total, counts);
            let d2 = b.scalar_add_i64(day, 1);
            b.assign_scalar(day, d2);
        },
    );
    b.collect(total, "totals");
    b.finish()
}

/// Semi-naive reachability over a static edge relation: `reach =
/// reach.union(step(reach)).distinct()`, the shape `opt::delta` proves
/// frontier-safe. The edge source sits outside the loop, so the join
/// builds it once (§7 reuse) and probes with the frontier; under delta
/// mode only newly discovered vertices circulate per superstep, the
/// classic semi-naive evaluation. The trip count bounds the explored
/// radius (a data-dependent fixpoint test would observe the carried bag
/// and — correctly — disqualify the loop). Expects a `{prefix}edges`
/// named source of `(src, dst)` pairs.
pub fn reachability(iters: i64, seeds: Vec<i64>, prefix: &str) -> Program {
    let mut b = ProgramBuilder::new();
    let edges = b.named_source(format!("{prefix}edges"));
    let init = b.bag_lit(seeds.into_iter().map(Value::I64).collect());
    let reach = b.declare_bag("reach", init);
    let zero = b.scalar_i64(0);
    let i = b.declare_scalar("i", zero);
    b.while_(
        |b| b.scalar_lt_i64(i, iters),
        |b| {
            let keyed = b.map(reach, udf1(|v| Value::pair(v.clone(), v.clone())));
            // (src, (dst, src)) — edges is the invariant build side.
            let hops = b.join(edges, keyed);
            let next = b.map(hops, udf1(|p| p.val().key().clone()));
            let merged = b.union(reach, next);
            let r2 = b.distinct(merged);
            b.assign_bag(reach, r2);
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    b.collect(reach, "reach");
    b.finish()
}

/// §9.2.2 nested-loop PageRank: outer loop over `days` transition logs
/// (`{prefix}adj{day}` named sources holding `(src, (dst, 1/outdeg))`),
/// inner fixpoint of `inner_iters` damped power-iteration steps.
pub fn pagerank_nested(days: i64, inner_iters: i64, num_pages: usize, prefix: &str) -> Program {
    let damping = 0.85;
    let teleport = (1.0 - damping) / num_pages as f64;
    let init: Vec<Value> = (0..num_pages as i64)
        .map(|p| Value::pair(Value::I64(p), Value::F64(1.0 / num_pages as f64)))
        .collect();
    let mut b = ProgramBuilder::new();
    let one = b.scalar_i64(1);
    let day = b.declare_scalar("day", one);
    let prefix = prefix.to_string();
    b.while_(
        |b| b.scalar_le_i64(day, days),
        |b| {
            let name = b.scalar_concat(&format!("{prefix}adj"), day);
            let adj = b.read_file(name);
            let r0 = b.bag_lit(init.clone());
            let ranks = b.declare_bag("ranks", r0);
            let zero = b.scalar_i64(0);
            let it = b.declare_scalar("it", zero);
            b.while_(
                |b| b.scalar_lt_i64(it, inner_iters),
                |b| {
                    let joined = b.join(adj, ranks);
                    let contribs = b.map(
                        joined,
                        udf1(move |v| {
                            let kv = v.val(); // ((dst, w), rank)
                            let dst_w = kv.key();
                            Value::pair(
                                dst_w.key().clone(),
                                Value::F64(
                                    damping * kv.val().as_f64() * dst_w.val().as_f64(),
                                ),
                            )
                        }),
                    );
                    let summed = b.reduce_by_key(
                        contribs,
                        udf2(|a, c| Value::F64(a.as_f64() + c.as_f64())),
                    );
                    let next = b.map(
                        summed,
                        udf1(move |v| {
                            Value::pair(v.key().clone(), Value::F64(v.val().as_f64() + teleport))
                        }),
                    );
                    b.assign_bag(ranks, next);
                    let i2 = b.scalar_add_i64(it, 1);
                    b.assign_scalar(it, i2);
                },
            );
            b.collect(ranks, "ranks");
            let d2 = b.scalar_add_i64(day, 1);
            b.assign_scalar(day, d2);
        },
    );
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::single_thread;

    #[test]
    fn microbench_runs_and_increments() {
        let p = step_overhead_microbench(5, 16);
        let out = single_thread::run(&p, &Default::default()).unwrap();
        let got = out.collected("bag");
        assert_eq!(got.len(), 16);
        // reduceByKey with keep-first over (x % 64) keys: with 16 distinct
        // inputs all keys are distinct, so the bag survives intact; 5 steps
        // of +1.
        let mut v: Vec<i64> = got.iter().map(|x| x.as_i64()).collect();
        v.sort();
        assert_eq!(v, (5..21).collect::<Vec<_>>());
    }

    #[test]
    fn visit_count_program_consistent_across_variants() {
        let w = crate::workload::VisitCountWorkload {
            days: 4,
            visits_per_day: 2_000,
            num_pages: 64,
            ..Default::default()
        };
        w.register("prog_");
        let plain = visit_count(4, "prog_");
        let st = single_thread::run(&plain, &Default::default()).unwrap();
        assert_eq!(st.collected("daily_diffs").len(), 3);
        let with_join = visit_count_with_join(4, "prog_");
        let st2 = single_thread::run(&with_join, &Default::default()).unwrap();
        assert_eq!(st2.collected("daily_diffs").len(), 3);
        // The join keeps only type-0 pages, so diffs differ from plain.
        // The in-loop variant is semantically identical to the
        // hand-hoisted one.
        let in_loop = visit_count_with_join_in_loop(4, "prog_");
        let st3 = single_thread::run(&in_loop, &Default::default()).unwrap();
        assert_eq!(st3.collected("daily_diffs"), st2.collected("daily_diffs"));
    }

    #[test]
    fn in_loop_join_variant_is_hoisted_by_the_optimizer() {
        let w = crate::workload::VisitCountWorkload {
            days: 3,
            visits_per_day: 500,
            num_pages: 32,
            ..Default::default()
        };
        w.register("hoistprog_");
        let p = visit_count_with_join_in_loop(3, "hoistprog_");
        let (g, report) =
            crate::compile_with(&p, &crate::opt::OptConfig::default()).unwrap();
        assert!(report.hoisted > 0, "{}", report.render());
        // The attrs source left the loop body.
        let src = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::frontend::Rhs::NamedSource(_)))
            .expect("attrs source");
        assert!(src.hoisted_from.is_some(), "{}", report.render());
        // And the optimized graph still computes the right answer.
        let oracle = single_thread::run(&p, &Default::default()).unwrap();
        let out = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = out.collected("daily_diffs").to_vec();
        let mut want = oracle.collected("daily_diffs").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn incremental_visit_count_is_delta_eligible_and_correct() {
        let w = crate::workload::VisitCountWorkload {
            days: 4,
            visits_per_day: 1_000,
            num_pages: 32,
            ..Default::default()
        };
        w.register("inc_");
        let p = visit_count_incremental(4, "inc_");
        let oracle = single_thread::run(&p, &Default::default()).unwrap();
        let cfg = crate::opt::OptConfig {
            delta: crate::opt::DeltaGate::Always,
            ..Default::default()
        };
        let (g, report) = crate::compile_with(&p, &cfg).unwrap();
        assert_eq!(report.delta_loops, 1, "{}", report.render());
        let out = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = out.collected("totals").to_vec();
        let mut want = oracle.collected("totals").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
        // The solution-set gauge is live: some node reports retained
        // state (the Φ's indexed totals and the reducer's partials).
        assert!(
            out.node_rows.iter().any(|r| r.state_size > 0),
            "expected a non-zero solution-set gauge"
        );
        // Delta-off compiles to a plain full-recompute loop and agrees.
        let off = crate::opt::OptConfig {
            delta: crate::opt::DeltaGate::Never,
            ..Default::default()
        };
        let (g2, r2) = crate::compile_with(&p, &off).unwrap();
        assert_eq!(r2.delta_loops, 0);
        let out2 = crate::exec::run(&g2, &crate::exec::ExecConfig::default()).unwrap();
        let mut got2 = out2.collected("totals").to_vec();
        got2.sort();
        assert_eq!(got2, want);
    }

    #[test]
    fn reachability_is_delta_eligible_and_matches_bfs() {
        // A 64-vertex graph: a long chain with shortcuts, seeded at 0.
        let n = 64i64;
        let mut edges = Vec::new();
        for v in 0..n - 1 {
            edges.push(Value::pair(Value::I64(v), Value::I64(v + 1)));
        }
        for v in (0..n).step_by(7) {
            edges.push(Value::pair(Value::I64(v), Value::I64((v * 3 + 5) % n)));
        }
        crate::workload::registry::global().put("reach_edges".to_string(), edges.clone());
        let p = reachability(8, vec![0], "reach_");
        let oracle = single_thread::run(&p, &Default::default()).unwrap();
        let cfg = crate::opt::OptConfig {
            delta: crate::opt::DeltaGate::Always,
            ..Default::default()
        };
        let (g, report) = crate::compile_with(&p, &cfg).unwrap();
        assert_eq!(report.delta_loops, 1, "{}", report.render());
        let out = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = out.collected("reach").to_vec();
        let mut want = oracle.collected("reach").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
        // Cross-check the oracle against a straight BFS to radius 8.
        let mut seen = std::collections::BTreeSet::from([0i64]);
        let mut frontier = vec![0i64];
        for _ in 0..8 {
            let mut next = Vec::new();
            for &u in &frontier {
                for e in &edges {
                    if e.key().as_i64() == u && seen.insert(e.val().as_i64()) {
                        next.push(e.val().as_i64());
                    }
                }
            }
            frontier = next;
        }
        let got_set: Vec<i64> = got.iter().map(|v| v.as_i64()).collect();
        assert_eq!(got_set, seen.into_iter().collect::<Vec<_>>());
    }

    #[test]
    fn nested_pagerank_matches_reference_per_day() {
        let w = crate::workload::PageRankWorkload {
            days: 2,
            num_pages: 40,
            edges_per_day: 400,
            ..Default::default()
        };
        // Register adjacency with weights.
        for day in 1..=2 {
            let edges = w.day_edges(day);
            let pairs: Vec<(usize, usize)> = edges
                .iter()
                .map(|v| (v.key().as_i64() as usize, v.val().as_i64() as usize))
                .collect();
            let mut outdeg = vec![0usize; w.num_pages];
            for &(s, _) in &pairs {
                outdeg[s] += 1;
            }
            let adj: Vec<Value> = pairs
                .iter()
                .map(|&(s, d)| {
                    Value::pair(
                        Value::I64(s as i64),
                        Value::pair(Value::I64(d as i64), Value::F64(1.0 / outdeg[s] as f64)),
                    )
                })
                .collect();
            crate::workload::registry::global().put(format!("prt_adj{day}"), adj);
        }
        let p = pagerank_nested(2, 10, 40, "prt_");
        let st = single_thread::run(&p, &Default::default()).unwrap();
        let ranks = st.collected("ranks");
        assert_eq!(ranks.len(), 2 * 40);
        // Compare day-2 ranks with the reference (assuming no danglings in
        // this dense random graph; teleport-only discrepancy is tolerated).
        let edges2: Vec<(usize, usize)> = w
            .day_edges(2)
            .iter()
            .map(|v| (v.key().as_i64() as usize, v.val().as_i64() as usize))
            .collect();
        let want = crate::workload::pagerank_reference(&edges2, 40, 10);
        let day2 = &ranks[40..];
        let mut got = vec![0.0; 40];
        for v in day2 {
            got[v.key().as_i64() as usize] = v.val().as_f64();
        }
        for i in 0..40 {
            assert!((got[i] - want[i]).abs() < 1e-6, "{i}: {} vs {}", got[i], want[i]);
        }
    }
}
