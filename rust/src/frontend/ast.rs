//! LabyLang abstract syntax tree.

/// Binary operators over scalars (and `+` over strings for concat).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    /// Addition / string concatenation.
    Add,
    /// Subtraction.
    Sub,
    /// Multiplication.
    Mul,
    /// Division.
    Div,
    /// Remainder.
    Rem,
    /// Equality.
    Eq,
    /// Inequality.
    Ne,
    /// Less-than.
    Lt,
    /// Less-or-equal.
    Le,
    /// Greater-than.
    Gt,
    /// Greater-or-equal.
    Ge,
    /// Logical and (strict — both sides evaluated).
    And,
    /// Logical or (strict).
    Or,
}

/// Unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    /// Numeric negation.
    Neg,
    /// Logical not.
    Not,
}

/// Expressions.
#[derive(Clone, Debug)]
pub enum Expr {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// String literal.
    Str(String),
    /// Boolean literal.
    Bool(bool),
    /// Variable (or lambda parameter) reference.
    Var(String),
    /// Binary operation.
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// Unary operation.
    Un(UnOp, Box<Expr>),
    /// Free-function call: `readFile(e)`, `pair(a,b)`, `abs(x)`, ...
    Call(String, Vec<Expr>),
    /// Method call on a bag: `b.map(|x| ...)`, `b.join(other)`, ...
    Method(Box<Expr>, String, Vec<Expr>),
    /// Lambda `|p1, p2| body` — only valid as an operator argument.
    Lambda(Vec<String>, Box<Expr>),
}

/// Statements.
#[derive(Clone, Debug)]
pub enum Stmt {
    /// `x = expr;`
    Assign(String, Expr),
    /// `while (cond) { body }`
    While(Expr, Vec<Stmt>),
    /// `if (cond) { then } else { els }`
    If(Expr, Vec<Stmt>, Vec<Stmt>),
    /// Expression statement (side-effecting call like `writeFile(...)`).
    ExprStmt(Expr),
    /// `break;` — jump past the innermost loop (unstructured control flow;
    /// SSA + the execution-path protocol handle it unchanged, §2.2).
    Break,
    /// `continue;` — jump to the innermost loop header.
    Continue,
}

/// A parsed program.
#[derive(Clone, Debug, Default)]
pub struct Ast {
    /// Top-level statements.
    pub stmts: Vec<Stmt>,
}
