//! Frontend: LabyLang (an external imperative analytics DSL) and a Rust
//! builder API, both producing the same pre-SSA three-address IR.
//!
//! The IR follows the paper's assumptions (§5.1): every intermediate value
//! is assigned to a variable; right-hand sides are single primitive bag
//! operations (or scalar operations, which the lifting pass of §5.2 turns
//! into bag operations); control flow is explicit as basic blocks with
//! `Jump` / `Branch` / `End` terminators.

pub mod ast;
pub mod builder;
pub mod interp_expr;
pub mod lexer;
pub mod lower;
pub mod parser;

use crate::value::Value;
use std::fmt;
use std::sync::Arc;

/// Index of a basic block.
pub type BlockId = usize;
/// Index of an IR variable.
pub type VarId = usize;

/// A unary element function (map/filter UDFs, lifted scalar functions).
#[derive(Clone)]
pub struct Udf1 {
    /// Debug name (shown in plans and DOT dumps).
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value) -> Value + Send + Sync>,
    /// The LabyLang lambda this closure was compiled from, when it came
    /// from the parser (`(params, body)`). Rust-builder UDFs are opaque
    /// closures and carry `None`. The `opt::pushdown` pass inspects and
    /// rewrites this to move predicates below joins / keyed aggregations;
    /// everything else ignores it.
    pub expr: Option<Arc<(Vec<String>, ast::Expr)>>,
}

/// A binary element function (reduce combiners, lifted binary scalars).
#[derive(Clone)]
pub struct Udf2 {
    /// Debug name.
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value, &Value) -> Value + Send + Sync>,
}

/// A unary function producing multiple elements (flatMap UDFs).
#[derive(Clone)]
pub struct UdfN {
    /// Debug name.
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value) -> Vec<Value> + Send + Sync>,
}

impl Udf1 {
    /// Wrap a closure with a debug name.
    pub fn new(name: impl Into<String>, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Udf1 {
        Udf1 { name: Arc::from(name.into().as_str()), f: Arc::new(f), expr: None }
    }
    /// Attach the lambda expression this UDF was compiled from (parser
    /// path only; enables structural rewrites like predicate pushdown).
    pub fn with_expr(mut self, params: Vec<String>, body: ast::Expr) -> Udf1 {
        self.expr = Some(Arc::new((params, body)));
        self
    }
    /// Apply.
    pub fn call(&self, v: &Value) -> Value {
        (self.f)(v)
    }
}
impl Udf2 {
    /// Wrap a closure with a debug name.
    pub fn new(
        name: impl Into<String>,
        f: impl Fn(&Value, &Value) -> Value + Send + Sync + 'static,
    ) -> Udf2 {
        Udf2 { name: Arc::from(name.into().as_str()), f: Arc::new(f) }
    }
    /// Apply.
    pub fn call(&self, a: &Value, b: &Value) -> Value {
        (self.f)(a, b)
    }
}
impl UdfN {
    /// Wrap a closure with a debug name.
    pub fn new(
        name: impl Into<String>,
        f: impl Fn(&Value) -> Vec<Value> + Send + Sync + 'static,
    ) -> UdfN {
        UdfN { name: Arc::from(name.into().as_str()), f: Arc::new(f) }
    }
    /// Apply.
    pub fn call(&self, v: &Value) -> Vec<Value> {
        (self.f)(v)
    }
}

impl fmt::Debug for Udf1 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udf1<{}>", self.name)
    }
}
impl fmt::Debug for Udf2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udf2<{}>", self.name)
    }
}
impl fmt::Debug for UdfN {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udfN<{}>", self.name)
    }
}

/// One stage of a fused element-wise chain. Produced only by the
/// `opt::fuse` pass (never by the frontends): a maximal pipeline of
/// map/filter/flatMap operators collapsed into a single physical operator
/// to cut per-element dispatch and per-bag coordination.
#[derive(Clone)]
pub enum FusedStage {
    /// One-to-one element transform.
    Map(Udf1),
    /// Keep elements whose predicate returns `Bool(true)`.
    Filter(Udf1),
    /// One-to-many element transform.
    FlatMap(UdfN),
}

impl FusedStage {
    /// Debug name of the stage's UDF.
    pub fn name(&self) -> &str {
        match self {
            FusedStage::Map(u) | FusedStage::Filter(u) => &u.name,
            FusedStage::FlatMap(u) => &u.name,
        }
    }

    /// Short mnemonic (`map<f>` / `filter<p>` / `flatMap<g>`).
    pub fn mnemonic(&self) -> String {
        match self {
            FusedStage::Map(u) => format!("map<{}>", u.name),
            FusedStage::Filter(u) => format!("filter<{}>", u.name),
            FusedStage::FlatMap(u) => format!("flatMap<{}>", u.name),
        }
    }

    /// A flatMap stage can expand one element into many; map/filter never
    /// grow the bag (used by singleton inference).
    pub fn expands(&self) -> bool {
        matches!(self, FusedStage::FlatMap(_))
    }
}

impl fmt::Debug for FusedStage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

/// Coarse IR types: parallel bags vs (to-be-lifted) scalars (§5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ty {
    /// A parallel collection.
    Bag,
    /// A non-bag value (loop counters, condition booleans, file names...).
    Scalar,
}

/// Metadata for one IR variable.
#[derive(Clone, Debug)]
pub struct VarInfo {
    /// Source-level or generated name.
    pub name: String,
    /// Bag or scalar.
    pub ty: Ty,
}

/// Right-hand side of an assignment: exactly one primitive operation, with
/// variable references only (§5.1 "intermediate representation").
#[derive(Clone, Debug)]
pub enum Rhs {
    /// A scalar constant.
    Const(Value),
    /// A literal bag source.
    BagLit(Vec<Value>),
    /// A synthetic in-memory source: `workload::registry` bag by name.
    /// Used by benches to avoid disk I/O noise.
    NamedSource(String),
    /// Read a text file (one element per line) named by a scalar variable.
    ReadFile {
        /// Scalar string variable holding the file name.
        name: VarId,
    },
    /// Write a bag to a file named by a scalar variable. Produces `Unit`.
    WriteFile {
        /// The bag to write.
        data: VarId,
        /// Scalar string variable holding the file name.
        name: VarId,
    },
    /// Deliver a bag to the driver under `label`. Produces `Unit`.
    Collect {
        /// The bag to collect.
        input: VarId,
        /// Output label.
        label: String,
    },
    /// Element-wise transformation.
    Map {
        /// Input bag.
        input: VarId,
        /// Per-element function.
        udf: Udf1,
    },
    /// Keep elements where `udf` returns `Bool(true)`.
    Filter {
        /// Input bag.
        input: VarId,
        /// Predicate.
        udf: Udf1,
    },
    /// Element-wise one-to-many transformation.
    FlatMap {
        /// Input bag.
        input: VarId,
        /// Per-element expansion.
        udf: UdfN,
    },
    /// Hash equi-join on `Value::key()`; emits `Pair(key, Pair(lv, rv))`.
    /// The LEFT input is the build side (kept in operator state across
    /// steps when loop-invariant — §7).
    Join {
        /// Build-side input.
        left: VarId,
        /// Probe-side input.
        right: VarId,
    },
    /// Per-key reduction of pair values: `Pair(k, v)` elements combined by
    /// `udf` over `v`.
    ReduceByKey {
        /// Input bag of pairs.
        input: VarId,
        /// Value combiner.
        udf: Udf2,
    },
    /// Full reduction to a single (scalar) value; empty input is an error.
    Reduce {
        /// Input bag.
        input: VarId,
        /// Combiner.
        udf: Udf2,
    },
    /// Number of elements, as a scalar i64.
    Count {
        /// Input bag.
        input: VarId,
    },
    /// Duplicate elimination.
    Distinct {
        /// Input bag.
        input: VarId,
    },
    /// Multiset union.
    Union {
        /// Left input.
        left: VarId,
        /// Right input.
        right: VarId,
    },
    /// Cartesian product; emits `Pair(l, r)`. (Used by §5.2 lifting of
    /// binary scalar functions; general cross of big bags is supported but
    /// expensive.)
    Cross {
        /// Left input.
        left: VarId,
        /// Right input.
        right: VarId,
    },
    /// A unary scalar computation (lifted to `Map` by §5.2).
    ScalarUn {
        /// Scalar input.
        input: VarId,
        /// Function.
        udf: Udf1,
    },
    /// A binary scalar computation (lifted to `Cross`+`Map` by §5.2).
    ScalarBin {
        /// Left scalar input.
        left: VarId,
        /// Right scalar input.
        right: VarId,
        /// Function.
        udf: Udf2,
    },
    /// Plain copy `a = b` (removed by copy propagation before SSA).
    Copy(VarId),
    /// Invoke an AOT-compiled XLA artifact on the input bag(s); see
    /// [`crate::runtime`]. The call spec describes the bag⇄tensor bridge.
    XlaCall {
        /// Input bags/scalars, in artifact parameter order.
        inputs: Vec<VarId>,
        /// Bridge description.
        spec: crate::runtime::XlaCallSpec,
    },
    /// A fused chain of element-wise stages — introduced by the `opt::fuse`
    /// pass only (the frontends never emit it). Elements of `input` are
    /// pushed through every stage in order inside one physical operator.
    Fused {
        /// Input bag (the first stage's input).
        input: VarId,
        /// Pipeline stages, in application order.
        stages: Vec<FusedStage>,
    },
    /// SSA Φ-function — introduced by the SSA pass only; each argument is
    /// (defining block of the argument at Φ-insertion time, variable).
    Phi(Vec<(BlockId, VarId)>),
}

impl Rhs {
    /// All variables referenced by this RHS.
    pub fn input_vars(&self) -> Vec<VarId> {
        match self {
            Rhs::Const(_) | Rhs::BagLit(_) | Rhs::NamedSource(_) => vec![],
            Rhs::ReadFile { name } => vec![*name],
            Rhs::WriteFile { data, name } => vec![*data, *name],
            Rhs::Collect { input, .. }
            | Rhs::Map { input, .. }
            | Rhs::Filter { input, .. }
            | Rhs::FlatMap { input, .. }
            | Rhs::ReduceByKey { input, .. }
            | Rhs::Reduce { input, .. }
            | Rhs::Count { input }
            | Rhs::Distinct { input }
            | Rhs::Fused { input, .. }
            | Rhs::ScalarUn { input, .. } => vec![*input],
            Rhs::Join { left, right }
            | Rhs::Union { left, right }
            | Rhs::Cross { left, right }
            | Rhs::ScalarBin { left, right, .. } => vec![*left, *right],
            Rhs::Copy(v) => vec![*v],
            Rhs::XlaCall { inputs, .. } => inputs.clone(),
            Rhs::Phi(args) => args.iter().map(|(_, v)| *v).collect(),
        }
    }

    /// Rewrite variable references through `f` (used by SSA renaming and
    /// copy propagation).
    pub fn map_inputs(&mut self, mut f: impl FnMut(VarId) -> VarId) {
        match self {
            Rhs::Const(_) | Rhs::BagLit(_) | Rhs::NamedSource(_) => {}
            Rhs::ReadFile { name } => *name = f(*name),
            Rhs::WriteFile { data, name } => {
                *data = f(*data);
                *name = f(*name);
            }
            Rhs::Collect { input, .. }
            | Rhs::Map { input, .. }
            | Rhs::Filter { input, .. }
            | Rhs::FlatMap { input, .. }
            | Rhs::ReduceByKey { input, .. }
            | Rhs::Reduce { input, .. }
            | Rhs::Count { input }
            | Rhs::Distinct { input }
            | Rhs::Fused { input, .. }
            | Rhs::ScalarUn { input, .. } => *input = f(*input),
            Rhs::Join { left, right }
            | Rhs::Union { left, right }
            | Rhs::Cross { left, right }
            | Rhs::ScalarBin { left, right, .. } => {
                *left = f(*left);
                *right = f(*right);
            }
            Rhs::Copy(v) => *v = f(*v),
            Rhs::XlaCall { inputs, .. } => {
                for v in inputs {
                    *v = f(*v);
                }
            }
            Rhs::Phi(args) => {
                for (_, v) in args {
                    *v = f(*v);
                }
            }
        }
    }

    /// Short operation mnemonic for plans/DOT.
    pub fn mnemonic(&self) -> String {
        match self {
            Rhs::Const(v) => format!("const {v:?}"),
            Rhs::BagLit(vs) => format!("bagLit[{}]", vs.len()),
            Rhs::NamedSource(n) => format!("source<{n}>"),
            Rhs::ReadFile { .. } => "readFile".into(),
            Rhs::WriteFile { .. } => "writeFile".into(),
            Rhs::Collect { label, .. } => format!("collect<{label}>"),
            Rhs::Map { udf, .. } => format!("map<{}>", udf.name),
            Rhs::Filter { udf, .. } => format!("filter<{}>", udf.name),
            Rhs::FlatMap { udf, .. } => format!("flatMap<{}>", udf.name),
            Rhs::Join { .. } => "join".into(),
            Rhs::ReduceByKey { udf, .. } => format!("reduceByKey<{}>", udf.name),
            Rhs::Reduce { udf, .. } => format!("reduce<{}>", udf.name),
            Rhs::Count { .. } => "count".into(),
            Rhs::Distinct { .. } => "distinct".into(),
            Rhs::Union { .. } => "union".into(),
            Rhs::Cross { .. } => "cross".into(),
            Rhs::ScalarUn { udf, .. } => format!("scalar<{}>", udf.name),
            Rhs::ScalarBin { udf, .. } => format!("scalar<{}>", udf.name),
            Rhs::Copy(_) => "copy".into(),
            Rhs::XlaCall { spec, .. } => format!("xla<{}>", spec.artifact),
            Rhs::Fused { stages, .. } => format!(
                "fused[{}]<{}>",
                stages.len(),
                stages.iter().map(|s| s.name().to_string()).collect::<Vec<_>>().join(";")
            ),
            Rhs::Phi(_) => "Φ".into(),
        }
    }

    /// The result type of this operation, given the variable table.
    pub fn result_ty(&self, vars: &[VarInfo]) -> Ty {
        match self {
            Rhs::Const(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => Ty::Scalar,
            Rhs::Reduce { .. } | Rhs::Count { .. } => Ty::Scalar,
            Rhs::WriteFile { .. } | Rhs::Collect { .. } => Ty::Scalar, // Unit
            Rhs::Copy(v) => vars[*v].ty,
            Rhs::Phi(args) => args.first().map(|(_, v)| vars[*v].ty).unwrap_or(Ty::Bag),
            _ => Ty::Bag,
        }
    }
}

/// One assignment statement: `var := rhs`.
#[derive(Clone, Debug)]
pub struct Instr {
    /// Target variable.
    pub var: VarId,
    /// Operation.
    pub rhs: Rhs,
}

/// Basic-block terminator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Terminator {
    /// Unconditional jump.
    Jump(BlockId),
    /// Two-way branch on a scalar boolean variable. That variable's
    /// dataflow node becomes a *condition node* (§5.3).
    Branch {
        /// Scalar boolean variable.
        cond: VarId,
        /// Successor when true.
        then_b: BlockId,
        /// Successor when false.
        else_b: BlockId,
    },
    /// Program end.
    End,
}

impl Terminator {
    /// Successor block ids.
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            Terminator::Jump(b) => vec![*b],
            Terminator::Branch { then_b, else_b, .. } => vec![*then_b, *else_b],
            Terminator::End => vec![],
        }
    }
}

/// A basic block: straight-line assignments plus a terminator.
#[derive(Clone, Debug, Default)]
pub struct Block {
    /// Assignments, in order.
    pub instrs: Vec<Instr>,
    /// Terminator (defaults to `End`).
    pub term: Terminator,
}

impl Default for Terminator {
    fn default() -> Self {
        Terminator::End
    }
}

/// A pre-SSA program: a CFG of three-address basic blocks over mutable
/// variables. Produced by the LabyLang lowerer or the builder API.
#[derive(Clone, Debug, Default)]
pub struct Program {
    /// Basic blocks, indexed by [`BlockId`].
    pub blocks: Vec<Block>,
    /// Entry block.
    pub entry: BlockId,
    /// Variable table.
    pub vars: Vec<VarInfo>,
}

impl Program {
    /// Allocate a fresh variable.
    pub fn new_var(&mut self, name: impl Into<String>, ty: Ty) -> VarId {
        self.vars.push(VarInfo { name: name.into(), ty });
        self.vars.len() - 1
    }

    /// Allocate a fresh (empty) block.
    pub fn new_block(&mut self) -> BlockId {
        self.blocks.push(Block::default());
        self.blocks.len() - 1
    }

    /// Render a readable listing (for `labyrinth compile --dump-ir`).
    pub fn listing(&self) -> String {
        let mut out = String::new();
        for (bi, b) in self.blocks.iter().enumerate() {
            out.push_str(&format!(
                "bb{}{}:\n",
                bi,
                if bi == self.entry { " (entry)" } else { "" }
            ));
            for i in &b.instrs {
                let ins = i
                    .rhs
                    .input_vars()
                    .iter()
                    .map(|v| self.vars[*v].name.clone())
                    .collect::<Vec<_>>()
                    .join(", ");
                out.push_str(&format!(
                    "  {} = {}({})\n",
                    self.vars[i.var].name,
                    i.rhs.mnemonic(),
                    ins
                ));
            }
            match &b.term {
                Terminator::Jump(t) => out.push_str(&format!("  jump bb{t}\n")),
                Terminator::Branch { cond, then_b, else_b } => out.push_str(&format!(
                    "  branch {} ? bb{} : bb{}\n",
                    self.vars[*cond].name, then_b, else_b
                )),
                Terminator::End => out.push_str("  end\n"),
            }
        }
        out
    }
}

/// Parse LabyLang source and lower it to the pre-SSA IR.
pub fn parse_and_lower(src: &str) -> crate::Result<Program> {
    let tokens = lexer::lex(src)?;
    let ast = parser::parse(&tokens)?;
    lower::lower(&ast)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_listing_smoke() {
        let mut p = Program::default();
        let b0 = p.new_block();
        p.entry = b0;
        let v = p.new_var("x", Ty::Scalar);
        p.blocks[b0].instrs.push(Instr { var: v, rhs: Rhs::Const(Value::I64(1)) });
        p.blocks[b0].term = Terminator::End;
        let l = p.listing();
        assert!(l.contains("x = const 1()"));
        assert!(l.contains("end"));
    }

    #[test]
    fn rhs_input_vars_cover_binary_ops() {
        let r = Rhs::Join { left: 3, right: 5 };
        assert_eq!(r.input_vars(), vec![3, 5]);
        let mut r2 = Rhs::ScalarBin {
            left: 1,
            right: 2,
            udf: Udf2::new("+", |a, b| Value::I64(a.as_i64() + b.as_i64())),
        };
        r2.map_inputs(|v| v + 10);
        assert_eq!(r2.input_vars(), vec![11, 12]);
    }

    #[test]
    fn terminator_successors() {
        assert_eq!(Terminator::Jump(3).successors(), vec![3]);
        assert_eq!(
            Terminator::Branch { cond: 0, then_b: 1, else_b: 2 }.successors(),
            vec![1, 2]
        );
        assert!(Terminator::End.successors().is_empty());
    }
}
