//! Frontend: LabyLang (an external imperative analytics DSL) and a Rust
//! builder API, both producing the same pre-SSA three-address IR.
//!
//! The IR follows the paper's assumptions (§5.1): every intermediate value
//! is assigned to a variable; right-hand sides are single primitive bag
//! operations (or scalar operations, which the lifting pass of §5.2 turns
//! into bag operations); control flow is explicit as basic blocks with
//! `Jump` / `Branch` / `End` terminators.

pub mod ast;
pub mod builder;
pub mod dsl;
pub mod interp_expr;
pub mod lexer;
pub mod lower;
pub mod parser;

use crate::value::Value;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// Index of a basic block.
pub type BlockId = usize;
/// Index of an IR variable.
pub type VarId = usize;

/// A unary element function (map/filter UDFs, lifted scalar functions).
#[derive(Clone)]
pub struct Udf1 {
    /// Debug name (shown in plans and DOT dumps).
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value) -> Value + Send + Sync>,
    /// The LabyLang lambda this closure was compiled from, when it came
    /// from the parser (`(params, body)`). Rust-builder UDFs are opaque
    /// closures and carry `None`. The `opt::pushdown` pass inspects and
    /// rewrites this to move predicates below joins / keyed aggregations;
    /// everything else ignores it.
    pub expr: Option<Arc<(Vec<String>, ast::Expr)>>,
}

/// A binary element function (reduce combiners, lifted binary scalars).
#[derive(Clone)]
pub struct Udf2 {
    /// Debug name.
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value, &Value) -> Value + Send + Sync>,
    /// The LabyLang lambda this closure was compiled from, when it came
    /// from the parser (`(params, body)`). Rust-builder UDFs are opaque
    /// closures and carry `None`. `opt::types` compiles this into
    /// monomorphic columnar combiners; everything else ignores it.
    pub expr: Option<Arc<(Vec<String>, ast::Expr)>>,
}

/// A unary function producing multiple elements (flatMap UDFs).
#[derive(Clone)]
pub struct UdfN {
    /// Debug name.
    pub name: Arc<str>,
    /// The function itself.
    pub f: Arc<dyn Fn(&Value) -> Vec<Value> + Send + Sync>,
}

impl Udf1 {
    /// Wrap a closure with a debug name.
    pub fn new(name: impl Into<String>, f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Udf1 {
        Udf1 { name: Arc::from(name.into().as_str()), f: Arc::new(f), expr: None }
    }
    /// Attach the lambda expression this UDF was compiled from (parser
    /// path only; enables structural rewrites like predicate pushdown).
    pub fn with_expr(mut self, params: Vec<String>, body: ast::Expr) -> Udf1 {
        self.expr = Some(Arc::new((params, body)));
        self
    }
    /// Apply.
    pub fn call(&self, v: &Value) -> Value {
        (self.f)(v)
    }
}
impl Udf2 {
    /// Wrap a closure with a debug name.
    pub fn new(
        name: impl Into<String>,
        f: impl Fn(&Value, &Value) -> Value + Send + Sync + 'static,
    ) -> Udf2 {
        Udf2 { name: Arc::from(name.into().as_str()), f: Arc::new(f), expr: None }
    }
    /// Attach the lambda expression this UDF was compiled from (parser
    /// path only; enables typed-kernel compilation, see `opt::types`).
    pub fn with_expr(mut self, params: Vec<String>, body: ast::Expr) -> Udf2 {
        self.expr = Some(Arc::new((params, body)));
        self
    }
    /// Apply.
    pub fn call(&self, a: &Value, b: &Value) -> Value {
        (self.f)(a, b)
    }
}
impl UdfN {
    /// Wrap a closure with a debug name.
    pub fn new(
        name: impl Into<String>,
        f: impl Fn(&Value) -> Vec<Value> + Send + Sync + 'static,
    ) -> UdfN {
        UdfN { name: Arc::from(name.into().as_str()), f: Arc::new(f) }
    }
    /// Apply.
    pub fn call(&self, v: &Value) -> Vec<Value> {
        (self.f)(v)
    }
}

impl fmt::Debug for Udf1 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udf1<{}>", self.name)
    }
}
impl fmt::Debug for Udf2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udf2<{}>", self.name)
    }
}
impl fmt::Debug for UdfN {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udfN<{}>", self.name)
    }
}

/// One stage of a fused element-wise chain. Produced only by the
/// `opt::fuse` pass (never by the frontends): a maximal pipeline of
/// map/filter/flatMap operators collapsed into a single physical operator
/// to cut per-element dispatch and per-bag coordination.
#[derive(Clone)]
pub enum FusedStage {
    /// One-to-one element transform.
    Map(Udf1),
    /// Keep elements whose predicate returns `Bool(true)`.
    Filter(Udf1),
    /// One-to-many element transform.
    FlatMap(UdfN),
}

impl FusedStage {
    /// Debug name of the stage's UDF.
    pub fn name(&self) -> &str {
        match self {
            FusedStage::Map(u) | FusedStage::Filter(u) => &u.name,
            FusedStage::FlatMap(u) => &u.name,
        }
    }

    /// Short mnemonic (`map<f>` / `filter<p>` / `flatMap<g>`).
    pub fn mnemonic(&self) -> String {
        match self {
            FusedStage::Map(u) => format!("map<{}>", u.name),
            FusedStage::Filter(u) => format!("filter<{}>", u.name),
            FusedStage::FlatMap(u) => format!("flatMap<{}>", u.name),
        }
    }

    /// A flatMap stage can expand one element into many; map/filter never
    /// grow the bag (used by singleton inference).
    pub fn expands(&self) -> bool {
        matches!(self, FusedStage::FlatMap(_))
    }
}

impl fmt::Debug for FusedStage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

/// Coarse IR types: parallel bags vs (to-be-lifted) scalars (§5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ty {
    /// A parallel collection.
    Bag,
    /// A non-bag value (loop counters, condition booleans, file names...).
    Scalar,
}

/// Metadata for one IR variable.
#[derive(Clone, Debug)]
pub struct VarInfo {
    /// Source-level or generated name.
    pub name: String,
    /// Bag or scalar.
    pub ty: Ty,
}

/// Right-hand side of an assignment: exactly one primitive operation, with
/// variable references only (§5.1 "intermediate representation").
#[derive(Clone, Debug)]
pub enum Rhs {
    /// A scalar constant.
    Const(Value),
    /// A literal bag source.
    BagLit(Vec<Value>),
    /// A synthetic in-memory source: `workload::registry` bag by name.
    /// Used by benches to avoid disk I/O noise.
    NamedSource(String),
    /// Read a text file (one element per line) named by a scalar variable.
    ReadFile {
        /// Scalar string variable holding the file name.
        name: VarId,
    },
    /// Write a bag to a file named by a scalar variable. Produces `Unit`.
    WriteFile {
        /// The bag to write.
        data: VarId,
        /// Scalar string variable holding the file name.
        name: VarId,
    },
    /// Deliver a bag to the driver under `label`. Produces `Unit`.
    Collect {
        /// The bag to collect.
        input: VarId,
        /// Output label.
        label: String,
    },
    /// Element-wise transformation.
    Map {
        /// Input bag.
        input: VarId,
        /// Per-element function.
        udf: Udf1,
    },
    /// Keep elements where `udf` returns `Bool(true)`.
    Filter {
        /// Input bag.
        input: VarId,
        /// Predicate.
        udf: Udf1,
    },
    /// Element-wise one-to-many transformation.
    FlatMap {
        /// Input bag.
        input: VarId,
        /// Per-element expansion.
        udf: UdfN,
    },
    /// Hash equi-join on `Value::key()`; emits `Pair(key, Pair(lv, rv))`.
    /// The LEFT input is the build side (kept in operator state across
    /// steps when loop-invariant — §7).
    Join {
        /// Build-side input.
        left: VarId,
        /// Probe-side input.
        right: VarId,
    },
    /// Per-key reduction of pair values: `Pair(k, v)` elements combined by
    /// `udf` over `v`.
    ReduceByKey {
        /// Input bag of pairs.
        input: VarId,
        /// Value combiner.
        udf: Udf2,
    },
    /// Full reduction to a single (scalar) value; empty input is an error.
    Reduce {
        /// Input bag.
        input: VarId,
        /// Combiner.
        udf: Udf2,
    },
    /// Number of elements, as a scalar i64.
    Count {
        /// Input bag.
        input: VarId,
    },
    /// Duplicate elimination.
    Distinct {
        /// Input bag.
        input: VarId,
    },
    /// Multiset union.
    Union {
        /// Left input.
        left: VarId,
        /// Right input.
        right: VarId,
    },
    /// Cartesian product; emits `Pair(l, r)`. (Used by §5.2 lifting of
    /// binary scalar functions; general cross of big bags is supported but
    /// expensive.)
    Cross {
        /// Left input.
        left: VarId,
        /// Right input.
        right: VarId,
    },
    /// A unary scalar computation (lifted to `Map` by §5.2).
    ScalarUn {
        /// Scalar input.
        input: VarId,
        /// Function.
        udf: Udf1,
    },
    /// A binary scalar computation (lifted to `Cross`+`Map` by §5.2).
    ScalarBin {
        /// Left scalar input.
        left: VarId,
        /// Right scalar input.
        right: VarId,
        /// Function.
        udf: Udf2,
    },
    /// Plain copy `a = b` (removed by copy propagation before SSA).
    Copy(VarId),
    /// Invoke an AOT-compiled XLA artifact on the input bag(s); see
    /// [`crate::runtime`]. The call spec describes the bag⇄tensor bridge.
    XlaCall {
        /// Input bags/scalars, in artifact parameter order.
        inputs: Vec<VarId>,
        /// Bridge description.
        spec: crate::runtime::XlaCallSpec,
    },
    /// A fused chain of element-wise stages — introduced by the `opt::fuse`
    /// pass only (the frontends never emit it). Elements of `input` are
    /// pushed through every stage in order inside one physical operator.
    Fused {
        /// Input bag (the first stage's input).
        input: VarId,
        /// Pipeline stages, in application order.
        stages: Vec<FusedStage>,
        /// Adaptive-feedback lineage, parallel to `stages`: the SSA node
        /// name that produced each stage's output before fusion. Observed
        /// runtime cardinalities are recorded against the fused node but
        /// must be pinned onto the *pre-fusion* graph on an adaptive
        /// recompile (`opt::cost::estimate_rows_seeded` pins by SSA
        /// name); the lineage maps them back (`serve::template`).
        lineage: Vec<String>,
    },
    /// SSA Φ-function — introduced by the SSA pass only; each argument is
    /// (defining block of the argument at Φ-insertion time, variable).
    Phi(Vec<(BlockId, VarId)>),
}

impl Rhs {
    /// All variables referenced by this RHS.
    pub fn input_vars(&self) -> Vec<VarId> {
        match self {
            Rhs::Const(_) | Rhs::BagLit(_) | Rhs::NamedSource(_) => vec![],
            Rhs::ReadFile { name } => vec![*name],
            Rhs::WriteFile { data, name } => vec![*data, *name],
            Rhs::Collect { input, .. }
            | Rhs::Map { input, .. }
            | Rhs::Filter { input, .. }
            | Rhs::FlatMap { input, .. }
            | Rhs::ReduceByKey { input, .. }
            | Rhs::Reduce { input, .. }
            | Rhs::Count { input }
            | Rhs::Distinct { input }
            | Rhs::Fused { input, .. }
            | Rhs::ScalarUn { input, .. } => vec![*input],
            Rhs::Join { left, right }
            | Rhs::Union { left, right }
            | Rhs::Cross { left, right }
            | Rhs::ScalarBin { left, right, .. } => vec![*left, *right],
            Rhs::Copy(v) => vec![*v],
            Rhs::XlaCall { inputs, .. } => inputs.clone(),
            Rhs::Phi(args) => args.iter().map(|(_, v)| *v).collect(),
        }
    }

    /// Rewrite variable references through `f` (used by SSA renaming and
    /// copy propagation).
    pub fn map_inputs(&mut self, mut f: impl FnMut(VarId) -> VarId) {
        match self {
            Rhs::Const(_) | Rhs::BagLit(_) | Rhs::NamedSource(_) => {}
            Rhs::ReadFile { name } => *name = f(*name),
            Rhs::WriteFile { data, name } => {
                *data = f(*data);
                *name = f(*name);
            }
            Rhs::Collect { input, .. }
            | Rhs::Map { input, .. }
            | Rhs::Filter { input, .. }
            | Rhs::FlatMap { input, .. }
            | Rhs::ReduceByKey { input, .. }
            | Rhs::Reduce { input, .. }
            | Rhs::Count { input }
            | Rhs::Distinct { input }
            | Rhs::Fused { input, .. }
            | Rhs::ScalarUn { input, .. } => *input = f(*input),
            Rhs::Join { left, right }
            | Rhs::Union { left, right }
            | Rhs::Cross { left, right }
            | Rhs::ScalarBin { left, right, .. } => {
                *left = f(*left);
                *right = f(*right);
            }
            Rhs::Copy(v) => *v = f(*v),
            Rhs::XlaCall { inputs, .. } => {
                for v in inputs {
                    *v = f(*v);
                }
            }
            Rhs::Phi(args) => {
                for (_, v) in args {
                    *v = f(*v);
                }
            }
        }
    }

    /// Short operation mnemonic for plans/DOT.
    pub fn mnemonic(&self) -> String {
        match self {
            Rhs::Const(v) => format!("const {v:?}"),
            Rhs::BagLit(vs) => format!("bagLit[{}]", vs.len()),
            Rhs::NamedSource(n) => format!("source<{n}>"),
            Rhs::ReadFile { .. } => "readFile".into(),
            Rhs::WriteFile { .. } => "writeFile".into(),
            Rhs::Collect { label, .. } => format!("collect<{label}>"),
            Rhs::Map { udf, .. } => format!("map<{}>", udf.name),
            Rhs::Filter { udf, .. } => format!("filter<{}>", udf.name),
            Rhs::FlatMap { udf, .. } => format!("flatMap<{}>", udf.name),
            Rhs::Join { .. } => "join".into(),
            Rhs::ReduceByKey { udf, .. } => format!("reduceByKey<{}>", udf.name),
            Rhs::Reduce { udf, .. } => format!("reduce<{}>", udf.name),
            Rhs::Count { .. } => "count".into(),
            Rhs::Distinct { .. } => "distinct".into(),
            Rhs::Union { .. } => "union".into(),
            Rhs::Cross { .. } => "cross".into(),
            Rhs::ScalarUn { udf, .. } => format!("scalar<{}>", udf.name),
            Rhs::ScalarBin { udf, .. } => format!("scalar<{}>", udf.name),
            Rhs::Copy(_) => "copy".into(),
            Rhs::XlaCall { spec, .. } => format!("xla<{}>", spec.artifact),
            Rhs::Fused { stages, .. } => format!(
                "fused[{}]<{}>",
                stages.len(),
                stages.iter().map(|s| s.name().to_string()).collect::<Vec<_>>().join(";")
            ),
            Rhs::Phi(_) => "Φ".into(),
        }
    }

    /// The result type of this operation, given the variable table.
    pub fn result_ty(&self, vars: &[VarInfo]) -> Ty {
        match self {
            Rhs::Const(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => Ty::Scalar,
            Rhs::Reduce { .. } | Rhs::Count { .. } => Ty::Scalar,
            Rhs::WriteFile { .. } | Rhs::Collect { .. } => Ty::Scalar, // Unit
            Rhs::Copy(v) => vars[*v].ty,
            Rhs::Phi(args) => args.first().map(|(_, v)| vars[*v].ty).unwrap_or(Ty::Bag),
            _ => Ty::Bag,
        }
    }
}

/// One assignment statement: `var := rhs`.
#[derive(Clone, Debug)]
pub struct Instr {
    /// Target variable.
    pub var: VarId,
    /// Operation.
    pub rhs: Rhs,
}

/// Basic-block terminator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Terminator {
    /// Unconditional jump.
    Jump(BlockId),
    /// Two-way branch on a scalar boolean variable. That variable's
    /// dataflow node becomes a *condition node* (§5.3).
    Branch {
        /// Scalar boolean variable.
        cond: VarId,
        /// Successor when true.
        then_b: BlockId,
        /// Successor when false.
        else_b: BlockId,
    },
    /// Program end.
    End,
}

impl Terminator {
    /// Successor block ids.
    pub fn successors(&self) -> Vec<BlockId> {
        match self {
            Terminator::Jump(b) => vec![*b],
            Terminator::Branch { then_b, else_b, .. } => vec![*then_b, *else_b],
            Terminator::End => vec![],
        }
    }
}

/// A basic block: straight-line assignments plus a terminator.
#[derive(Clone, Debug, Default)]
pub struct Block {
    /// Assignments, in order.
    pub instrs: Vec<Instr>,
    /// Terminator (defaults to `End`).
    pub term: Terminator,
}

impl Default for Terminator {
    fn default() -> Self {
        Terminator::End
    }
}

/// A pre-SSA program: a CFG of three-address basic blocks over mutable
/// variables. Produced by the LabyLang lowerer or the builder API.
#[derive(Clone, Debug, Default)]
pub struct Program {
    /// Basic blocks, indexed by [`BlockId`].
    pub blocks: Vec<Block>,
    /// Entry block.
    pub entry: BlockId,
    /// Variable table.
    pub vars: Vec<VarInfo>,
}

impl Program {
    /// Allocate a fresh variable.
    pub fn new_var(&mut self, name: impl Into<String>, ty: Ty) -> VarId {
        self.vars.push(VarInfo { name: name.into(), ty });
        self.vars.len() - 1
    }

    /// Allocate a fresh (empty) block.
    pub fn new_block(&mut self) -> BlockId {
        self.blocks.push(Block::default());
        self.blocks.len() - 1
    }

    /// Render a readable listing (for `labyrinth compile --dump-ir`).
    pub fn listing(&self) -> String {
        let mut out = String::new();
        for (bi, b) in self.blocks.iter().enumerate() {
            out.push_str(&format!(
                "bb{}{}:\n",
                bi,
                if bi == self.entry { " (entry)" } else { "" }
            ));
            for i in &b.instrs {
                let ins = i
                    .rhs
                    .input_vars()
                    .iter()
                    .map(|v| self.vars[*v].name.clone())
                    .collect::<Vec<_>>()
                    .join(", ");
                out.push_str(&format!(
                    "  {} = {}({})\n",
                    self.vars[i.var].name,
                    i.rhs.mnemonic(),
                    ins
                ));
            }
            match &b.term {
                Terminator::Jump(t) => out.push_str(&format!("  jump bb{t}\n")),
                Terminator::Branch { cond, then_b, else_b } => out.push_str(&format!(
                    "  branch {} ? bb{} : bb{}\n",
                    self.vars[*cond].name, then_b, else_b
                )),
                Terminator::End => out.push_str("  end\n"),
            }
        }
        out
    }
}

/// Parse LabyLang source and lower it to the pre-SSA IR.
pub fn parse_and_lower(src: &str) -> crate::Result<Program> {
    let tokens = lexer::lex(src)?;
    let ast = parser::parse(&tokens)?;
    lower::lower(&ast)
}

// ---- program identity (serve:: plan-template cache keys) ----------------

fn hash_udf1(u: &Udf1, h: &mut impl Hasher) {
    u.name.hash(h);
    match &u.expr {
        // Expression-carrying UDFs (parser path, `frontend::dsl`) hash
        // structurally: the same lambda source always fingerprints the
        // same, so re-parsed programs share a cache entry.
        Some(e) => {
            1u8.hash(h);
            e.0.hash(h);
            format!("{:?}", e.1).hash(h);
        }
        // Opaque native closures hash by identity (the Arc pointer): two
        // separately constructed closures never collide, at the cost of
        // re-built programs missing the cache. Conservative, never wrong.
        None => {
            0u8.hash(h);
            (Arc::as_ptr(&u.f).cast::<()>() as usize).hash(h);
        }
    }
}

fn hash_udf2(u: &Udf2, h: &mut impl Hasher) {
    u.name.hash(h);
    match &u.expr {
        // Same discriminated scheme as `hash_udf1`: parser-built
        // combiners hash structurally so re-parsed programs share a
        // cache entry; opaque closures hash by identity.
        Some(e) => {
            1u8.hash(h);
            e.0.hash(h);
            format!("{:?}", e.1).hash(h);
        }
        None => {
            0u8.hash(h);
            (Arc::as_ptr(&u.f).cast::<()>() as usize).hash(h);
        }
    }
}

fn hash_udfn(u: &UdfN, h: &mut impl Hasher) {
    u.name.hash(h);
    (Arc::as_ptr(&u.f).cast::<()>() as usize).hash(h);
}

fn hash_rhs(rhs: &Rhs, h: &mut impl Hasher) {
    match rhs {
        Rhs::Const(v) => {
            0u8.hash(h);
            v.hash(h);
        }
        Rhs::BagLit(items) => {
            1u8.hash(h);
            items.hash(h);
        }
        Rhs::NamedSource(name) => {
            2u8.hash(h);
            name.hash(h);
        }
        Rhs::ReadFile { name } => {
            3u8.hash(h);
            name.hash(h);
        }
        Rhs::WriteFile { data, name } => {
            4u8.hash(h);
            data.hash(h);
            name.hash(h);
        }
        Rhs::Collect { input, label } => {
            5u8.hash(h);
            input.hash(h);
            label.hash(h);
        }
        Rhs::Map { input, udf } => {
            6u8.hash(h);
            input.hash(h);
            hash_udf1(udf, h);
        }
        Rhs::Filter { input, udf } => {
            7u8.hash(h);
            input.hash(h);
            hash_udf1(udf, h);
        }
        Rhs::FlatMap { input, udf } => {
            8u8.hash(h);
            input.hash(h);
            hash_udfn(udf, h);
        }
        Rhs::Join { left, right } => {
            9u8.hash(h);
            left.hash(h);
            right.hash(h);
        }
        Rhs::ReduceByKey { input, udf } => {
            10u8.hash(h);
            input.hash(h);
            hash_udf2(udf, h);
        }
        Rhs::Reduce { input, udf } => {
            11u8.hash(h);
            input.hash(h);
            hash_udf2(udf, h);
        }
        Rhs::Count { input } => {
            12u8.hash(h);
            input.hash(h);
        }
        Rhs::Distinct { input } => {
            13u8.hash(h);
            input.hash(h);
        }
        Rhs::Union { left, right } => {
            14u8.hash(h);
            left.hash(h);
            right.hash(h);
        }
        Rhs::Cross { left, right } => {
            15u8.hash(h);
            left.hash(h);
            right.hash(h);
        }
        Rhs::ScalarUn { input, udf } => {
            16u8.hash(h);
            input.hash(h);
            hash_udf1(udf, h);
        }
        Rhs::ScalarBin { left, right, udf } => {
            17u8.hash(h);
            left.hash(h);
            right.hash(h);
            hash_udf2(udf, h);
        }
        Rhs::Copy(v) => {
            18u8.hash(h);
            v.hash(h);
        }
        Rhs::XlaCall { inputs, spec } => {
            19u8.hash(h);
            inputs.hash(h);
            format!("{spec:?}").hash(h);
        }
        // Lineage is derived bookkeeping (and the frontends never emit
        // Fused anyway) — excluded from the fingerprint.
        Rhs::Fused { input, stages, .. } => {
            20u8.hash(h);
            input.hash(h);
            for s in stages {
                match s {
                    FusedStage::Map(u) => {
                        0u8.hash(h);
                        hash_udf1(u, h);
                    }
                    FusedStage::Filter(u) => {
                        1u8.hash(h);
                        hash_udf1(u, h);
                    }
                    FusedStage::FlatMap(u) => {
                        2u8.hash(h);
                        hash_udfn(u, h);
                    }
                }
            }
        }
        Rhs::Phi(args) => {
            21u8.hash(h);
            args.hash(h);
        }
    }
}

/// Structural identity of a pre-SSA [`Program`] — the **hashable program
/// identity** used by the `serve::` job service as the plan-template
/// cache key for `Program`-based submissions (source-text submissions
/// hash the text itself).
///
/// Two programs fingerprint equal iff their block structure, variable
/// tables, operations, constants, and UDFs agree. UDFs compiled from
/// LabyLang lambdas (or the [`dsl`] combinators) carry their expression
/// and hash *structurally* — re-lowering identical source yields the same
/// fingerprint. Opaque builder closures hash by closure identity, so a
/// re-built program misses the cache rather than ever sharing a template
/// with a different function.
pub fn fingerprint(p: &Program) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    p.entry.hash(&mut h);
    p.vars.len().hash(&mut h);
    for v in &p.vars {
        v.name.hash(&mut h);
        match v.ty {
            Ty::Bag => 0u8.hash(&mut h),
            Ty::Scalar => 1u8.hash(&mut h),
        }
    }
    p.blocks.len().hash(&mut h);
    for b in &p.blocks {
        b.instrs.len().hash(&mut h);
        for i in &b.instrs {
            i.var.hash(&mut h);
            hash_rhs(&i.rhs, &mut h);
        }
        match &b.term {
            Terminator::Jump(t) => {
                0u8.hash(&mut h);
                t.hash(&mut h);
            }
            Terminator::Branch { cond, then_b, else_b } => {
                1u8.hash(&mut h);
                cond.hash(&mut h);
                then_b.hash(&mut h);
                else_b.hash(&mut h);
            }
            Terminator::End => 2u8.hash(&mut h),
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_listing_smoke() {
        let mut p = Program::default();
        let b0 = p.new_block();
        p.entry = b0;
        let v = p.new_var("x", Ty::Scalar);
        p.blocks[b0].instrs.push(Instr { var: v, rhs: Rhs::Const(Value::I64(1)) });
        p.blocks[b0].term = Terminator::End;
        let l = p.listing();
        assert!(l.contains("x = const 1()"));
        assert!(l.contains("end"));
    }

    #[test]
    fn rhs_input_vars_cover_binary_ops() {
        let r = Rhs::Join { left: 3, right: 5 };
        assert_eq!(r.input_vars(), vec![3, 5]);
        let mut r2 = Rhs::ScalarBin {
            left: 1,
            right: 2,
            udf: Udf2::new("+", |a, b| Value::I64(a.as_i64() + b.as_i64())),
        };
        r2.map_inputs(|v| v + 10);
        assert_eq!(r2.input_vars(), vec![11, 12]);
    }

    #[test]
    fn fingerprint_is_stable_for_reparsed_source() {
        let src = "a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"b\");";
        let p1 = parse_and_lower(src).unwrap();
        let p2 = parse_and_lower(src).unwrap();
        assert_eq!(fingerprint(&p1), fingerprint(&p2));
        let other =
            parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 2); collect(b, \"b\");").unwrap();
        assert_ne!(fingerprint(&p1), fingerprint(&other), "different lambda body");
        let other_label =
            parse_and_lower("a = bag(1, 2); b = a.map(|x| x + 1); collect(b, \"c\");").unwrap();
        assert_ne!(fingerprint(&p1), fingerprint(&other_label), "different collect label");
    }

    #[test]
    fn fingerprint_separates_distinct_native_closures() {
        use builder::{udf1, ProgramBuilder};
        let build = || {
            let mut b = ProgramBuilder::new();
            let bag = b.bag_lit(vec![Value::I64(1)]);
            let m = b.map(bag, udf1(|v| Value::I64(v.as_i64() * 2)));
            b.collect(m, "m");
            b.finish()
        };
        // Same structure but separately constructed opaque closures —
        // identity hashing must keep them apart.
        assert_ne!(fingerprint(&build()), fingerprint(&build()));
        // The same Program instance is stable with itself.
        let p = build();
        assert_eq!(fingerprint(&p), fingerprint(&p));
        assert_eq!(fingerprint(&p), fingerprint(&p.clone()), "clones share closures");
    }

    #[test]
    fn terminator_successors() {
        assert_eq!(Terminator::Jump(3).successors(), vec![3]);
        assert_eq!(
            Terminator::Branch { cond: 0, then_b: 1, else_b: 2 }.successors(),
            vec![1, 2]
        );
        assert!(Terminator::End.successors().is_empty());
    }
}
