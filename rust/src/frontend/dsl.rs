//! A small combinator DSL for builder-API UDFs that carry **expression
//! metadata** (`Udf1::expr`).
//!
//! Builder programs historically used opaque Rust closures
//! (`builder::udf1`), which the structural optimizer rewrites cannot
//! inspect — predicate pushdown fired for LabyLang programs only. These
//! combinators build the same [`super::ast::Expr`] tree the parser produces and
//! compile it through [`interp_expr::compile_udf1`], so the resulting UDF
//! both executes (closed-expression interpreter) and *explains itself* to
//! the optimizer: a predicate written with the DSL pushes below joins and
//! keyed aggregations exactly like its LabyLang twin, and it hashes
//! structurally in `frontend::fingerprint` (serve:: cache keys).
//!
//! ```
//! use labyrinth::frontend::dsl::{lit, p};
//! // |p| snd(snd(p)) > 10   — a probe-side join predicate.
//! let pred = p().snd().snd().gt(lit(10)).pred("probe_gt10").unwrap();
//! assert!(pred.expr.is_some(), "pushdown can inspect it");
//! ```

use super::ast::{BinOp, Expr, UnOp};
use super::{interp_expr, Udf1};
use crate::error::Result;
use crate::value::Value;

/// An expression under construction. Obtain the element parameter with
/// [`p`] and literals with [`lit`] / [`litf`] / [`lits`] / [`litb`];
/// combine with the builder methods; finish with [`ExprB::pred`] /
/// [`ExprB::udf`].
#[derive(Clone, Debug)]
pub struct ExprB(Expr);

/// The UDF's element parameter (the `p` in `|p| ...`).
pub fn p() -> ExprB {
    ExprB(Expr::Var(PARAM.into()))
}

/// Integer literal.
pub fn lit(v: i64) -> ExprB {
    ExprB(Expr::Int(v))
}

/// Float literal.
pub fn litf(v: f64) -> ExprB {
    ExprB(Expr::Float(v))
}

/// String literal.
pub fn lits(v: impl Into<String>) -> ExprB {
    ExprB(Expr::Str(v.into()))
}

/// Boolean literal.
pub fn litb(v: bool) -> ExprB {
    ExprB(Expr::Bool(v))
}

const PARAM: &str = "p";

impl ExprB {
    fn call(name: &str, args: Vec<ExprB>) -> ExprB {
        ExprB(Expr::Call(name.into(), args.into_iter().map(|a| a.0).collect()))
    }

    fn bin(self, op: BinOp, rhs: ExprB) -> ExprB {
        ExprB(Expr::Bin(op, Box::new(self.0), Box::new(rhs.0)))
    }

    // ---- projections / builtins -----------------------------------------

    /// `fst(e)` — first pair component (the key, on keyed elements).
    pub fn fst(self) -> ExprB {
        ExprB::call("fst", vec![self])
    }
    /// `snd(e)` — second pair component.
    pub fn snd(self) -> ExprB {
        ExprB::call("snd", vec![self])
    }
    /// `key(e)` — shape-total key projection (`ops::join` semantics).
    pub fn key(self) -> ExprB {
        ExprB::call("key", vec![self])
    }
    /// `payload(e)` — shape-total payload projection.
    pub fn payload(self) -> ExprB {
        ExprB::call("payload", vec![self])
    }
    /// `abs(e)`.
    pub fn abs(self) -> ExprB {
        ExprB::call("abs", vec![self])
    }
    /// `hash(e)`.
    pub fn hashv(self) -> ExprB {
        ExprB::call("hash", vec![self])
    }
    /// `pair(self, other)`.
    pub fn pair(self, other: ExprB) -> ExprB {
        ExprB::call("pair", vec![self, other])
    }
    /// `min(self, other)`.
    pub fn min(self, other: ExprB) -> ExprB {
        ExprB::call("min", vec![self, other])
    }
    /// `max(self, other)`.
    pub fn max(self, other: ExprB) -> ExprB {
        ExprB::call("max", vec![self, other])
    }

    // ---- arithmetic ------------------------------------------------------

    /// `self + rhs` (string concat on strings).
    pub fn add(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Add, rhs)
    }
    /// `self - rhs`.
    pub fn sub(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Sub, rhs)
    }
    /// `self * rhs`.
    pub fn mul(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Mul, rhs)
    }
    /// `self / rhs`.
    pub fn div(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Div, rhs)
    }
    /// `self % rhs`.
    pub fn rem(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Rem, rhs)
    }

    // ---- comparison / boolean --------------------------------------------

    /// `self == rhs`.
    pub fn eq(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Eq, rhs)
    }
    /// `self != rhs`.
    pub fn ne(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Ne, rhs)
    }
    /// `self < rhs`.
    pub fn lt(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Lt, rhs)
    }
    /// `self <= rhs`.
    pub fn le(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Le, rhs)
    }
    /// `self > rhs`.
    pub fn gt(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Gt, rhs)
    }
    /// `self >= rhs`.
    pub fn ge(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Ge, rhs)
    }
    /// `self && rhs` (strict).
    pub fn and(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::And, rhs)
    }
    /// `self || rhs` (strict).
    pub fn or(self, rhs: ExprB) -> ExprB {
        self.bin(BinOp::Or, rhs)
    }
    /// `!self`.
    pub fn not(self) -> ExprB {
        ExprB(Expr::Un(UnOp::Not, Box::new(self.0)))
    }
    /// `-self`.
    pub fn neg(self) -> ExprB {
        ExprB(Expr::Un(UnOp::Neg, Box::new(self.0)))
    }

    // ---- compilation -----------------------------------------------------

    /// Compile into a [`Udf1`] carrying the expression as metadata.
    /// Fails if the expression references anything but the parameter and
    /// known builtins (same closedness contract as LabyLang lambdas).
    pub fn udf(self, name: impl Into<String>) -> Result<Udf1> {
        interp_expr::compile_udf1(vec![PARAM.into()], self.0, name.into())
    }

    /// [`ExprB::udf`] under its most common role: a boolean predicate for
    /// `filter` that predicate pushdown can relocate.
    pub fn pred(self, name: impl Into<String>) -> Result<Udf1> {
        self.udf(name)
    }
}

/// Evaluate a built expression against one element (tests, debugging).
pub fn eval(e: &ExprB, v: &Value) -> Value {
    interp_expr::eval(&e.0, &[PARAM.to_string()], std::slice::from_ref(v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::builder::{udf2, ProgramBuilder};
    use crate::opt::OptConfig;

    #[test]
    fn combinators_compile_and_evaluate() {
        let udf = p().snd().snd().gt(lit(10)).pred("probe").unwrap();
        assert!(udf.expr.is_some());
        let elem = Value::pair(Value::I64(1), Value::pair(Value::I64(5), Value::I64(50)));
        assert_eq!(udf.call(&elem), Value::Bool(true));
        let elem2 = Value::pair(Value::I64(1), Value::pair(Value::I64(5), Value::I64(3)));
        assert_eq!(udf.call(&elem2), Value::Bool(false));

        let arith = p().mul(lit(3)).add(lit(1)).udf("affine").unwrap();
        assert_eq!(arith.call(&Value::I64(4)), Value::I64(13));
    }

    #[test]
    fn closedness_is_enforced() {
        // A stray variable is rejected like any non-closed lambda.
        let open = ExprB(super::Expr::Var("q".into()));
        assert!(open.udf("open").is_err());
    }

    #[test]
    fn builder_predicates_now_push_below_joins() {
        // The ROADMAP gap this module closes: a builder-API program whose
        // join-output filter is written with the DSL gets predicate
        // pushdown, exactly like its LabyLang twin.
        let mut b = ProgramBuilder::new();
        let left = b.bag_lit(
            (0..8).map(|v| Value::pair(Value::I64(v % 4), Value::I64(v))).collect(),
        );
        let right = b.bag_lit(
            (0..6).map(|v| Value::pair(Value::I64(v % 4), Value::I64(v * 10))).collect(),
        );
        let j = b.join(left, right);
        let f = b.filter(j, p().snd().snd().gt(lit(20)).pred("probe_gt20").unwrap());
        b.collect(f, "f");
        let program = b.finish();

        let (g, report) = crate::compile_with(&program, &OptConfig::default()).unwrap();
        assert!(report.pushed_filters > 0, "{}", report.render());

        // Semantics preserved vs the single-threaded oracle.
        let oracle = crate::baselines::single_thread::run(&program, &Default::default()).unwrap();
        let out = crate::exec::run(&g, &crate::exec::ExecConfig::default()).unwrap();
        let mut got = out.collected("f").to_vec();
        let mut want = oracle.collected("f").to_vec();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn dsl_udfs_fingerprint_structurally() {
        // Two separately built DSL predicates with the same shape hash
        // the same — unlike opaque closures (identity-hashed).
        let build = || {
            let mut b = ProgramBuilder::new();
            let bag = b.bag_lit(vec![Value::pair(Value::I64(1), Value::I64(2))]);
            let f = b.filter(bag, p().key().eq(lit(1)).pred("k1").unwrap());
            let r = b.reduce_by_key(f, udf2(|a, _| a.clone()));
            b.collect(r, "r");
            b.finish()
        };
        let (p1, p2) = (build(), build());
        // reduce_by_key uses an opaque udf2 → identity-hashed → programs
        // differ; but swapping ONLY the DSL predicate must change the
        // fingerprint deterministically.
        let fp = |prog: &crate::frontend::Program| crate::frontend::fingerprint(prog);
        assert_ne!(fp(&p1), fp(&p2), "opaque udf2 keeps identity semantics");

        let with_pred = |n: i64| {
            let mut b = ProgramBuilder::new();
            let bag = b.bag_lit(vec![Value::pair(Value::I64(1), Value::I64(2))]);
            let f = b.filter(bag, p().key().eq(lit(n)).pred("k".to_string()).unwrap());
            b.collect(f, "f");
            b.finish()
        };
        assert_eq!(fp(&with_pred(1)), fp(&with_pred(1)), "same DSL expr → same identity");
        assert_ne!(fp(&with_pred(1)), fp(&with_pred(2)), "different literal → different");
    }
}
