//! Compilation of LabyLang *lambda* expressions into executable UDF
//! closures. Lambdas are closed: they may reference only their parameters
//! and literals (the lowerer rejects captures — a captured dataset would be
//! a hidden dataflow edge).

use super::ast::{BinOp, Expr, UnOp};
use crate::error::{Error, Result};
use crate::value::Value;
use std::sync::Arc;

/// Evaluate a closed expression with parameters bound to `env`.
pub fn eval(e: &Expr, params: &[String], env: &[Value]) -> Value {
    match e {
        Expr::Int(v) => Value::I64(*v),
        Expr::Float(v) => Value::F64(*v),
        Expr::Str(s) => Value::str(s.clone()),
        Expr::Bool(b) => Value::Bool(*b),
        Expr::Var(name) => {
            let idx = params
                .iter()
                .position(|p| p == name)
                .unwrap_or_else(|| panic!("unbound lambda variable {name}"));
            env[idx].clone()
        }
        Expr::Un(op, x) => {
            let v = eval(x, params, env);
            match op {
                UnOp::Neg => match v {
                    Value::I64(i) => Value::I64(-i),
                    Value::F64(f) => Value::F64(-f),
                    other => panic!("neg on {other:?}"),
                },
                UnOp::Not => Value::Bool(!v.as_bool()),
            }
        }
        Expr::Bin(op, l, r) => {
            let a = eval(l, params, env);
            let b = eval(r, params, env);
            bin(*op, &a, &b)
        }
        Expr::Call(name, args) => {
            let vals: Vec<Value> = args.iter().map(|a| eval(a, params, env)).collect();
            builtin(name, &vals)
        }
        Expr::Method(..) | Expr::Lambda(..) => {
            panic!("bag operations are not allowed inside lambdas")
        }
    }
}

/// Apply a scalar binary operator.
pub fn bin(op: BinOp, a: &Value, b: &Value) -> Value {
    use BinOp::*;
    match op {
        Add => match (a, b) {
            (Value::I64(x), Value::I64(y)) => Value::I64(x + y),
            (Value::Str(_), _) | (_, Value::Str(_)) => Value::str(format!("{a}{b}")),
            _ => Value::F64(a.as_f64() + b.as_f64()),
        },
        Sub => match (a, b) {
            (Value::I64(x), Value::I64(y)) => Value::I64(x - y),
            _ => Value::F64(a.as_f64() - b.as_f64()),
        },
        Mul => match (a, b) {
            (Value::I64(x), Value::I64(y)) => Value::I64(x * y),
            _ => Value::F64(a.as_f64() * b.as_f64()),
        },
        Div => match (a, b) {
            (Value::I64(x), Value::I64(y)) => Value::I64(x / y),
            _ => Value::F64(a.as_f64() / b.as_f64()),
        },
        Rem => Value::I64(a.as_i64() % b.as_i64()),
        Eq => Value::Bool(a == b),
        Ne => Value::Bool(a != b),
        Lt => Value::Bool(a < b),
        Le => Value::Bool(a <= b),
        Gt => Value::Bool(a > b),
        Ge => Value::Bool(a >= b),
        And => Value::Bool(a.as_bool() && b.as_bool()),
        Or => Value::Bool(a.as_bool() || b.as_bool()),
    }
}

/// Scalar builtins usable inside lambdas (and on lifted scalars).
pub fn builtin(name: &str, args: &[Value]) -> Value {
    match (name, args) {
        ("pair", [a, b]) => Value::pair(a.clone(), b.clone()),
        ("tuple", _) => Value::tuple(args.to_vec()),
        ("fst", [Value::Pair(p)]) => p.0.clone(),
        ("snd", [Value::Pair(p)]) => p.1.clone(),
        ("nth", [Value::Tuple(t), Value::I64(i)]) => t[*i as usize].clone(),
        // `key` / `payload` mirror the shape handling of keyed operators
        // (`ops::join::key_and_payload`): the first pair component is the
        // key, anything non-pair keys on the whole value with a Unit
        // payload. Emitted by `opt::pushdown` when it moves a predicate
        // below a join (the pushed predicate sees one side's elements, not
        // the joined pairs); also available to user lambdas.
        ("key", [Value::Pair(p)]) => p.0.clone(),
        ("key", [v]) => v.clone(),
        ("payload", [Value::Pair(p)]) => p.1.clone(),
        ("payload", [_]) => Value::Unit,
        ("abs", [Value::I64(v)]) => Value::I64(v.abs()),
        ("abs", [Value::F64(v)]) => Value::F64(v.abs()),
        ("min", [a, b]) => if a <= b { a.clone() } else { b.clone() },
        ("max", [a, b]) => if a >= b { a.clone() } else { b.clone() },
        ("str", [v]) => Value::str(v.to_string()),
        ("int", [Value::Str(s)]) => Value::I64(
            s.trim().parse::<i64>().unwrap_or_else(|_| panic!("int() on non-integer {s:?}")),
        ),
        ("int", [Value::F64(f)]) => Value::I64(*f as i64),
        ("int", [Value::I64(v)]) => Value::I64(*v),
        ("float", [v]) => Value::F64(v.as_f64()),
        ("hash", [v]) => Value::I64(v.key_hash() as i64),
        ("field", [Value::Str(s), Value::I64(i)]) => Value::str(
            s.split_whitespace()
                .nth(*i as usize)
                .unwrap_or_else(|| panic!("field({i}) missing in {s:?}")),
        ),
        ("len", [Value::Str(s)]) => Value::I64(s.chars().count() as i64),
        (other, _) => panic!("unknown builtin {other}({} args)", args.len()),
    }
}

/// Validate that a lambda body references only its parameters and known
/// builtins; returns the set of referenced names for diagnostics.
pub fn check_closed(e: &Expr, params: &[String]) -> Result<()> {
    match e {
        Expr::Var(name) => {
            if params.iter().any(|p| p == name) {
                Ok(())
            } else {
                Err(Error::Type(format!(
                    "lambda refers to '{name}', which is not a parameter; \
                     lambdas must be closed (captures would hide dataflow edges)"
                )))
            }
        }
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) => Ok(()),
        Expr::Un(_, x) => check_closed(x, params),
        Expr::Bin(_, l, r) => {
            check_closed(l, params)?;
            check_closed(r, params)
        }
        Expr::Call(name, args) => {
            const BUILTINS: &[&str] = &[
                "pair", "tuple", "fst", "snd", "key", "payload", "nth", "abs", "min",
                "max", "str", "int", "float", "hash", "field", "len",
            ];
            if !BUILTINS.contains(&name.as_str()) {
                return Err(Error::Type(format!("unknown builtin '{name}' inside lambda")));
            }
            for a in args {
                check_closed(a, params)?;
            }
            Ok(())
        }
        Expr::Method(..) => Err(Error::Type(
            "bag operations are not allowed inside lambdas".into(),
        )),
        Expr::Lambda(..) => Err(Error::Type("nested lambdas are not supported".into())),
    }
}

/// Compile a 1-parameter lambda into a [`super::Udf1`]. The source
/// expression rides along on the UDF (`Udf1::expr`) so structural
/// optimizer rewrites (predicate pushdown) can inspect it.
pub fn compile_udf1(params: Vec<String>, body: Expr, name: String) -> Result<super::Udf1> {
    if params.len() != 1 {
        return Err(Error::Type(format!("expected 1-parameter lambda, got {}", params.len())));
    }
    check_closed(&body, &params)?;
    let expr_params = params.clone();
    let expr_body = body.clone();
    let body = Arc::new(body);
    let params = Arc::new(params);
    Ok(super::Udf1::new(name, move |v: &Value| {
        eval(&body, &params, std::slice::from_ref(v))
    })
    .with_expr(expr_params, expr_body))
}

/// Compile a 2-parameter lambda into a [`super::Udf2`]. As with
/// [`compile_udf1`], the source expression rides along (`Udf2::expr`) so
/// `opt::types` can compile monomorphic columnar combiners from it.
pub fn compile_udf2(params: Vec<String>, body: Expr, name: String) -> Result<super::Udf2> {
    if params.len() != 2 {
        return Err(Error::Type(format!("expected 2-parameter lambda, got {}", params.len())));
    }
    check_closed(&body, &params)?;
    let expr_params = params.clone();
    let expr_body = body.clone();
    let body = Arc::new(body);
    let params = Arc::new(params);
    Ok(super::Udf2::new(name, move |a: &Value, b: &Value| {
        eval(&body, &params, &[a.clone(), b.clone()])
    })
    .with_expr(expr_params, expr_body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::lexer::lex;
    use crate::frontend::parser;

    fn lambda(src: &str) -> (Vec<String>, Expr) {
        // Parse `x = <src>;` and pull out the lambda.
        let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
        match &ast.stmts[0] {
            crate::frontend::ast::Stmt::Assign(_, Expr::Lambda(ps, body)) => {
                (ps.clone(), (**body).clone())
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn udf1_evaluates() {
        let (ps, body) = lambda("|x| pair(x, 1)");
        let f = compile_udf1(ps, body, "kv".into()).unwrap();
        assert_eq!(f.call(&Value::I64(7)), Value::pair(Value::I64(7), Value::I64(1)));
    }

    #[test]
    fn udf2_evaluates() {
        let (ps, body) = lambda("|a, b| a + b");
        let f = compile_udf2(ps, body, "sum".into()).unwrap();
        assert_eq!(f.call(&Value::I64(2), &Value::I64(3)), Value::I64(5));
    }

    #[test]
    fn captures_rejected() {
        let (ps, body) = lambda("|x| x + y");
        assert!(compile_udf1(ps, body, "bad".into()).is_err());
    }

    #[test]
    fn string_concat_via_plus() {
        assert_eq!(
            bin(BinOp::Add, &Value::str("log"), &Value::I64(3)),
            Value::str("log3")
        );
    }

    #[test]
    fn builtins_cover_pairs() {
        let p = builtin("pair", &[Value::I64(1), Value::str("a")]);
        assert_eq!(builtin("fst", &[p.clone()]), Value::I64(1));
        assert_eq!(builtin("snd", &[p]), Value::str("a"));
        assert_eq!(builtin("abs", &[Value::I64(-4)]), Value::I64(4));
        assert_eq!(builtin("int", &[Value::str(" 42 ")]), Value::I64(42));
    }

    #[test]
    fn comparison_operators() {
        assert_eq!(bin(BinOp::Le, &Value::I64(2), &Value::I64(2)), Value::Bool(true));
        assert_eq!(bin(BinOp::Ne, &Value::I64(2), &Value::I64(3)), Value::Bool(true));
        assert_eq!(bin(BinOp::Lt, &Value::F64(1.5), &Value::F64(2.5)), Value::Bool(true));
    }

    #[test]
    fn mixed_arith_widens_to_float() {
        assert_eq!(bin(BinOp::Mul, &Value::I64(2), &Value::F64(0.5)), Value::F64(1.0));
    }
}
