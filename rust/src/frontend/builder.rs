//! Programmatic construction of imperative Labyrinth programs from Rust —
//! the "embedded DSL" frontend. Used by benches, tests, and examples that
//! need native-closure UDFs instead of LabyLang lambdas.
//!
//! The builder models the *imperative* (pre-SSA) language: variables are
//! mutable, `assign_*` re-assigns them, and `while_` / `if_` create real
//! control flow that the compiler pipeline lowers through SSA exactly like
//! parsed LabyLang programs.

use super::{BlockId, Instr, Program, Rhs, Terminator, Ty, Udf1, Udf2, UdfN, VarId};
use crate::value::Value;

/// Handle to a scalar-typed variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScalarHandle(pub(crate) VarId);

/// Handle to a bag-typed variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BagHandle(pub(crate) VarId);

/// Convenience constructor for unary UDFs.
pub fn udf1(f: impl Fn(&Value) -> Value + Send + Sync + 'static) -> Udf1 {
    Udf1::new("native", f)
}

/// Convenience constructor for binary UDFs.
pub fn udf2(f: impl Fn(&Value, &Value) -> Value + Send + Sync + 'static) -> Udf2 {
    Udf2::new("native", f)
}

/// Imperative program builder.
pub struct ProgramBuilder {
    prog: Program,
    cur: BlockId,
    finished: bool,
}

impl Default for ProgramBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramBuilder {
    /// Start an empty program.
    pub fn new() -> ProgramBuilder {
        let mut prog = Program::default();
        let entry = prog.new_block();
        prog.entry = entry;
        ProgramBuilder { prog, cur: entry, finished: false }
    }

    fn emit(&mut self, name: &str, ty: Ty, rhs: Rhs) -> VarId {
        let var = self.prog.new_var(name, ty);
        self.prog.blocks[self.cur].instrs.push(Instr { var, rhs });
        var
    }

    // ---- sources -------------------------------------------------------

    /// Scalar i64 constant.
    pub fn scalar_i64(&mut self, v: i64) -> ScalarHandle {
        ScalarHandle(self.emit("c", Ty::Scalar, Rhs::Const(Value::I64(v))))
    }

    /// Scalar f64 constant.
    pub fn scalar_f64(&mut self, v: f64) -> ScalarHandle {
        ScalarHandle(self.emit("c", Ty::Scalar, Rhs::Const(Value::F64(v))))
    }

    /// Scalar string constant.
    pub fn scalar_str(&mut self, v: impl Into<String>) -> ScalarHandle {
        ScalarHandle(self.emit("c", Ty::Scalar, Rhs::Const(Value::str(v.into()))))
    }

    /// Arbitrary scalar constant.
    pub fn scalar_const(&mut self, v: Value) -> ScalarHandle {
        ScalarHandle(self.emit("c", Ty::Scalar, Rhs::Const(v)))
    }

    /// Literal bag source.
    pub fn bag_lit(&mut self, items: Vec<Value>) -> BagHandle {
        BagHandle(self.emit("lit", Ty::Bag, Rhs::BagLit(items)))
    }

    /// In-memory named source (see [`crate::workload::registry`]).
    pub fn named_source(&mut self, name: impl Into<String>) -> BagHandle {
        BagHandle(self.emit("src", Ty::Bag, Rhs::NamedSource(name.into())))
    }

    /// Read a file (one `Str` element per line) named by a scalar.
    pub fn read_file(&mut self, name: ScalarHandle) -> BagHandle {
        BagHandle(self.emit("read", Ty::Bag, Rhs::ReadFile { name: name.0 }))
    }

    // ---- mutable variables ---------------------------------------------

    /// Declare a named mutable scalar initialized from `init`.
    pub fn declare_scalar(&mut self, name: &str, init: ScalarHandle) -> ScalarHandle {
        ScalarHandle(self.emit(name, Ty::Scalar, Rhs::Copy(init.0)))
    }

    /// Declare a named mutable bag initialized from `init`.
    pub fn declare_bag(&mut self, name: &str, init: BagHandle) -> BagHandle {
        BagHandle(self.emit(name, Ty::Bag, Rhs::Copy(init.0)))
    }

    /// Re-assign a mutable scalar (pre-SSA mutation).
    pub fn assign_scalar(&mut self, var: ScalarHandle, value: ScalarHandle) {
        self.prog.blocks[self.cur]
            .instrs
            .push(Instr { var: var.0, rhs: Rhs::Copy(value.0) });
    }

    /// Re-assign a mutable bag (pre-SSA mutation).
    pub fn assign_bag(&mut self, var: BagHandle, value: BagHandle) {
        self.prog.blocks[self.cur]
            .instrs
            .push(Instr { var: var.0, rhs: Rhs::Copy(value.0) });
    }

    // ---- bag operations --------------------------------------------------

    /// Element-wise map.
    pub fn map(&mut self, input: BagHandle, udf: Udf1) -> BagHandle {
        BagHandle(self.emit("map", Ty::Bag, Rhs::Map { input: input.0, udf }))
    }

    /// Filter by predicate.
    pub fn filter(&mut self, input: BagHandle, udf: Udf1) -> BagHandle {
        BagHandle(self.emit("filter", Ty::Bag, Rhs::Filter { input: input.0, udf }))
    }

    /// One-to-many map.
    pub fn flat_map(&mut self, input: BagHandle, udf: UdfN) -> BagHandle {
        BagHandle(self.emit("flatMap", Ty::Bag, Rhs::FlatMap { input: input.0, udf }))
    }

    /// Hash equi-join on `Value::key()`; `build` is the stateful build side
    /// (reused across steps when loop-invariant, §7).
    pub fn join(&mut self, build: BagHandle, probe: BagHandle) -> BagHandle {
        BagHandle(self.emit("join", Ty::Bag, Rhs::Join { left: build.0, right: probe.0 }))
    }

    /// Per-key reduction over `Pair(k, v)` elements.
    pub fn reduce_by_key(&mut self, input: BagHandle, udf: Udf2) -> BagHandle {
        BagHandle(self.emit("rbk", Ty::Bag, Rhs::ReduceByKey { input: input.0, udf }))
    }

    /// Full reduction to a scalar.
    pub fn reduce(&mut self, input: BagHandle, udf: Udf2) -> ScalarHandle {
        ScalarHandle(self.emit("reduce", Ty::Scalar, Rhs::Reduce { input: input.0, udf }))
    }

    /// Element count as a scalar.
    pub fn count(&mut self, input: BagHandle) -> ScalarHandle {
        ScalarHandle(self.emit("count", Ty::Scalar, Rhs::Count { input: input.0 }))
    }

    /// Duplicate elimination.
    pub fn distinct(&mut self, input: BagHandle) -> BagHandle {
        BagHandle(self.emit("distinct", Ty::Bag, Rhs::Distinct { input: input.0 }))
    }

    /// Multiset union.
    pub fn union(&mut self, left: BagHandle, right: BagHandle) -> BagHandle {
        BagHandle(self.emit("union", Ty::Bag, Rhs::Union { left: left.0, right: right.0 }))
    }

    /// Cartesian product.
    pub fn cross(&mut self, left: BagHandle, right: BagHandle) -> BagHandle {
        BagHandle(self.emit("cross", Ty::Bag, Rhs::Cross { left: left.0, right: right.0 }))
    }

    /// Write a bag to a file named by a scalar.
    pub fn write_file(&mut self, data: BagHandle, name: ScalarHandle) {
        self.emit("write", Ty::Scalar, Rhs::WriteFile { data: data.0, name: name.0 });
    }

    /// Deliver a bag to the driver under `label`.
    pub fn collect(&mut self, input: BagHandle, label: impl Into<String>) {
        self.emit(
            "collect",
            Ty::Scalar,
            Rhs::Collect { input: input.0, label: label.into() },
        );
    }

    /// Invoke an AOT-compiled XLA artifact (see [`crate::runtime`]).
    pub fn xla_call(
        &mut self,
        inputs: Vec<BagHandle>,
        spec: crate::runtime::XlaCallSpec,
    ) -> BagHandle {
        BagHandle(self.emit(
            "xla",
            Ty::Bag,
            Rhs::XlaCall { inputs: inputs.into_iter().map(|b| b.0).collect(), spec },
        ))
    }

    // ---- scalar operations ----------------------------------------------

    /// Apply a unary function to a scalar (lifted to `map`, §5.2).
    pub fn scalar_un(&mut self, input: ScalarHandle, udf: Udf1) -> ScalarHandle {
        ScalarHandle(self.emit("s", Ty::Scalar, Rhs::ScalarUn { input: input.0, udf }))
    }

    /// Apply a binary function to scalars (lifted to `cross`+`map`, §5.2).
    pub fn scalar_bin(&mut self, l: ScalarHandle, r: ScalarHandle, udf: Udf2) -> ScalarHandle {
        ScalarHandle(self.emit(
            "s",
            Ty::Scalar,
            Rhs::ScalarBin { left: l.0, right: r.0, udf },
        ))
    }

    /// `l + r` over i64 scalars.
    pub fn scalar_add_i64(&mut self, l: ScalarHandle, r: i64) -> ScalarHandle {
        let rc = self.scalar_i64(r);
        self.scalar_bin(l, rc, udf2(|a, b| Value::I64(a.as_i64() + b.as_i64())))
    }

    /// `l <= r` over i64 scalars.
    pub fn scalar_le_i64(&mut self, l: ScalarHandle, r: i64) -> ScalarHandle {
        let rc = self.scalar_i64(r);
        self.scalar_bin(l, rc, udf2(|a, b| Value::Bool(a.as_i64() <= b.as_i64())))
    }

    /// `l < r` over i64 scalars.
    pub fn scalar_lt_i64(&mut self, l: ScalarHandle, r: i64) -> ScalarHandle {
        let rc = self.scalar_i64(r);
        self.scalar_bin(l, rc, udf2(|a, b| Value::Bool(a.as_i64() < b.as_i64())))
    }

    /// `l != r` over i64 scalars.
    pub fn scalar_ne_i64(&mut self, l: ScalarHandle, r: i64) -> ScalarHandle {
        let rc = self.scalar_i64(r);
        self.scalar_bin(l, rc, udf2(|a, b| Value::Bool(a.as_i64() != b.as_i64())))
    }

    /// String concatenation `prefix + str(x)`.
    pub fn scalar_concat(&mut self, prefix: &str, x: ScalarHandle) -> ScalarHandle {
        let p = prefix.to_string();
        self.scalar_un(x, Udf1::new("concat", move |v: &Value| Value::str(format!("{p}{v}"))))
    }

    /// Lift a scalar into a one-element bag (§5.2 made explicit): a unit
    /// bag crossed with the scalar, then projected. Useful to `collect`
    /// scalar results.
    pub fn lift_scalar(&mut self, s: ScalarHandle) -> BagHandle {
        let unit = self.bag_lit(vec![Value::Unit]);
        let crossed = BagHandle(self.emit(
            "lift",
            Ty::Bag,
            Rhs::Cross { left: unit.0, right: s.0 },
        ));
        self.map(crossed, Udf1::new("snd", |v: &Value| v.val().clone()))
    }

    // ---- control flow ----------------------------------------------------

    /// `while (cond) { body }`. `cond` builds the condition *inside the
    /// header block* and returns the condition variable; `body` builds the
    /// loop body. Mutable variables assigned inside the body become loop
    /// variables through SSA Φ-insertion.
    pub fn while_(
        &mut self,
        cond: impl FnOnce(&mut ProgramBuilder) -> ScalarHandle,
        body: impl FnOnce(&mut ProgramBuilder),
    ) {
        let header = self.prog.new_block();
        let body_b = self.prog.new_block();
        let after = self.prog.new_block();
        self.prog.blocks[self.cur].term = Terminator::Jump(header);
        self.cur = header;
        let cond_var = cond(self);
        let cond_var = self.materialize_cond(cond_var);
        self.prog.blocks[self.cur].term =
            Terminator::Branch { cond: cond_var.0, then_b: body_b, else_b: after };
        self.cur = body_b;
        body(self);
        self.prog.blocks[self.cur].term = Terminator::Jump(header);
        self.cur = after;
    }

    /// `if (cond) { then_f() } else { else_f() }`. The condition must have
    /// been computed in the current block (or it is re-materialized here).
    pub fn if_(
        &mut self,
        cond: ScalarHandle,
        then_f: impl FnOnce(&mut ProgramBuilder),
        else_f: impl FnOnce(&mut ProgramBuilder),
    ) {
        let cond = self.materialize_cond(cond);
        let then_b = self.prog.new_block();
        let else_b = self.prog.new_block();
        let merge = self.prog.new_block();
        self.prog.blocks[self.cur].term =
            Terminator::Branch { cond: cond.0, then_b, else_b };
        self.cur = then_b;
        then_f(self);
        self.prog.blocks[self.cur].term = Terminator::Jump(merge);
        self.cur = else_b;
        else_f(self);
        self.prog.blocks[self.cur].term = Terminator::Jump(merge);
        self.cur = merge;
    }

    /// `if` without `else`.
    pub fn if_then(&mut self, cond: ScalarHandle, then_f: impl FnOnce(&mut ProgramBuilder)) {
        let cond = self.materialize_cond(cond);
        let then_b = self.prog.new_block();
        let merge = self.prog.new_block();
        self.prog.blocks[self.cur].term =
            Terminator::Branch { cond: cond.0, then_b, else_b: merge };
        self.cur = then_b;
        then_f(self);
        self.prog.blocks[self.cur].term = Terminator::Jump(merge);
        self.cur = merge;
    }

    fn materialize_cond(&mut self, v: ScalarHandle) -> ScalarHandle {
        let defined_here = self.prog.blocks[self.cur].instrs.iter().any(|i| i.var == v.0);
        if defined_here {
            v
        } else {
            self.scalar_un(v, Udf1::new("id", |x: &Value| x.clone()))
        }
    }

    /// Finish and return the IR program.
    pub fn finish(mut self) -> Program {
        assert!(!self.finished);
        self.finished = true;
        self.prog.blocks[self.cur].term = Terminator::End;
        self.prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straightline_builds_one_block() {
        let mut b = ProgramBuilder::new();
        let bag = b.bag_lit(vec![Value::I64(1), Value::I64(2)]);
        let mapped = b.map(bag, udf1(|v| Value::I64(v.as_i64() * 2)));
        b.collect(mapped, "out");
        let p = b.finish();
        assert_eq!(p.blocks.len(), 1);
        assert_eq!(p.blocks[0].instrs.len(), 3);
    }

    #[test]
    fn while_produces_four_blocks() {
        let mut b = ProgramBuilder::new();
        let one = b.scalar_i64(0);
        let i = b.declare_scalar("i", one);
        b.while_(
            |b| b.scalar_lt_i64(i, 3),
            |b| {
                let next = b.scalar_add_i64(i, 1);
                b.assign_scalar(i, next);
            },
        );
        let p = b.finish();
        assert_eq!(p.blocks.len(), 4);
        // Condition is defined in the header (branching block).
        let header = match p.blocks[p.entry].term {
            Terminator::Jump(h) => h,
            ref o => panic!("{o:?}"),
        };
        match &p.blocks[header].term {
            Terminator::Branch { cond, .. } => {
                assert!(p.blocks[header].instrs.iter().any(|ins| ins.var == *cond));
            }
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn if_materializes_cond_in_current_block() {
        let mut b = ProgramBuilder::new();
        let x = b.scalar_i64(1);
        let c = b.scalar_ne_i64(x, 1);
        b.while_(
            |b| b.scalar_lt_i64(x, 3),
            |b| {
                // `c` was defined in the entry block; using it as an if
                // condition inside the loop must re-materialize it here.
                b.if_then(c, |_| {});
            },
        );
        let p = b.finish();
        // Find the branch inside the loop body and check its condition is
        // defined in the same block.
        let mut found = false;
        for blk in &p.blocks {
            if let Terminator::Branch { cond, .. } = &blk.term {
                if blk.instrs.iter().any(|i| i.var == *cond) {
                    found = true;
                }
            }
        }
        assert!(found);
    }
}
