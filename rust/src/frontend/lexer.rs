//! LabyLang lexer: hand-written, produces position-tagged tokens.

use crate::error::{Error, Result};

/// Token kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword candidate.
    Ident(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// String literal (escapes resolved).
    Str(String),
    /// `while`
    While,
    /// `if`
    If,
    /// `else`
    Else,
    /// `break`
    Break,
    /// `continue`
    Continue,
    /// `true`
    True,
    /// `false`
    False,
    /// `=`
    Assign,
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
    /// `+`
    Plus,
    /// `-`
    Minus,
    /// `*`
    Star,
    /// `/`
    Slash,
    /// `%`
    Percent,
    /// `!`
    Bang,
    /// `&&`
    AndAnd,
    /// `||`
    OrOr,
    /// `(`
    LParen,
    /// `)`
    RParen,
    /// `{`
    LBrace,
    /// `}`
    RBrace,
    /// `,`
    Comma,
    /// `;`
    Semi,
    /// `.`
    Dot,
    /// `|` (lambda delimiter)
    Pipe,
    /// `=>` (unused, reserved)
    FatArrow,
    /// End of input sentinel.
    Eof,
}

/// A token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Token {
    /// Kind + payload.
    pub tok: Tok,
    /// 1-based line.
    pub line: usize,
    /// 1-based column.
    pub col: usize,
}

/// Tokenize LabyLang source. `//` line comments and `/* */` block comments
/// are skipped.
pub fn lex(src: &str) -> Result<Vec<Token>> {
    let mut out = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let mut i = 0;
    let (mut line, mut col) = (1usize, 1usize);
    macro_rules! err {
        ($($arg:tt)*) => {
            return Err(Error::Lex { line, col, msg: format!($($arg)*) })
        };
    }
    macro_rules! push {
        ($t:expr, $l:expr, $c:expr) => {
            out.push(Token { tok: $t, line: $l, col: $c })
        };
    }
    while i < bytes.len() {
        let c = bytes[i];
        let (tl, tc) = (line, col);
        let adv = |i: &mut usize, line: &mut usize, col: &mut usize, n: usize| {
            for k in 0..n {
                if bytes[*i + k] == '\n' {
                    *line += 1;
                    *col = 1;
                } else {
                    *col += 1;
                }
            }
            *i += n;
        };
        match c {
            ' ' | '\t' | '\r' | '\n' => adv(&mut i, &mut line, &mut col, 1),
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '/' => {
                while i < bytes.len() && bytes[i] != '\n' {
                    adv(&mut i, &mut line, &mut col, 1);
                }
            }
            '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                adv(&mut i, &mut line, &mut col, 2);
                loop {
                    if i + 1 >= bytes.len() {
                        err!("unterminated block comment");
                    }
                    if bytes[i] == '*' && bytes[i + 1] == '/' {
                        adv(&mut i, &mut line, &mut col, 2);
                        break;
                    }
                    adv(&mut i, &mut line, &mut col, 1);
                }
            }
            '"' => {
                adv(&mut i, &mut line, &mut col, 1);
                let mut s = String::new();
                loop {
                    if i >= bytes.len() {
                        err!("unterminated string literal");
                    }
                    match bytes[i] {
                        '"' => {
                            adv(&mut i, &mut line, &mut col, 1);
                            break;
                        }
                        '\\' => {
                            if i + 1 >= bytes.len() {
                                err!("dangling escape");
                            }
                            let e = bytes[i + 1];
                            s.push(match e {
                                'n' => '\n',
                                't' => '\t',
                                '\\' => '\\',
                                '"' => '"',
                                other => err!("unknown escape '\\{other}'"),
                            });
                            adv(&mut i, &mut line, &mut col, 2);
                        }
                        ch => {
                            s.push(ch);
                            adv(&mut i, &mut line, &mut col, 1);
                        }
                    }
                }
                push!(Tok::Str(s), tl, tc);
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    adv(&mut i, &mut line, &mut col, 1);
                }
                let mut is_float = false;
                if i + 1 < bytes.len() && bytes[i] == '.' && bytes[i + 1].is_ascii_digit() {
                    is_float = true;
                    adv(&mut i, &mut line, &mut col, 1);
                    while i < bytes.len() && bytes[i].is_ascii_digit() {
                        adv(&mut i, &mut line, &mut col, 1);
                    }
                }
                let text: String = bytes[start..i].iter().collect();
                if is_float {
                    match text.parse::<f64>() {
                        Ok(v) => push!(Tok::Float(v), tl, tc),
                        Err(_) => err!("bad float literal {text}"),
                    }
                } else {
                    match text.parse::<i64>() {
                        Ok(v) => push!(Tok::Int(v), tl, tc),
                        Err(_) => err!("bad int literal {text}"),
                    }
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == '_') {
                    adv(&mut i, &mut line, &mut col, 1);
                }
                let word: String = bytes[start..i].iter().collect();
                let tok = match word.as_str() {
                    "while" => Tok::While,
                    "if" => Tok::If,
                    "else" => Tok::Else,
                    "break" => Tok::Break,
                    "continue" => Tok::Continue,
                    "true" => Tok::True,
                    "false" => Tok::False,
                    _ => Tok::Ident(word),
                };
                push!(tok, tl, tc);
            }
            _ => {
                let two: String = bytes[i..(i + 2).min(bytes.len())].iter().collect();
                let (tok, n) = match two.as_str() {
                    "==" => (Tok::Eq, 2),
                    "!=" => (Tok::Ne, 2),
                    "<=" => (Tok::Le, 2),
                    ">=" => (Tok::Ge, 2),
                    "&&" => (Tok::AndAnd, 2),
                    "||" => (Tok::OrOr, 2),
                    "=>" => (Tok::FatArrow, 2),
                    _ => match c {
                        '=' => (Tok::Assign, 1),
                        '<' => (Tok::Lt, 1),
                        '>' => (Tok::Gt, 1),
                        '+' => (Tok::Plus, 1),
                        '-' => (Tok::Minus, 1),
                        '*' => (Tok::Star, 1),
                        '/' => (Tok::Slash, 1),
                        '%' => (Tok::Percent, 1),
                        '!' => (Tok::Bang, 1),
                        '(' => (Tok::LParen, 1),
                        ')' => (Tok::RParen, 1),
                        '{' => (Tok::LBrace, 1),
                        '}' => (Tok::RBrace, 1),
                        ',' => (Tok::Comma, 1),
                        ';' => (Tok::Semi, 1),
                        '.' => (Tok::Dot, 1),
                        '|' => (Tok::Pipe, 1),
                        other => err!("unexpected character '{other}'"),
                    },
                };
                adv(&mut i, &mut line, &mut col, n);
                push!(tok, tl, tc);
            }
        }
    }
    out.push(Token { tok: Tok::Eof, line, col });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_assignment() {
        assert_eq!(
            kinds("day = 1;"),
            vec![Tok::Ident("day".into()), Tok::Assign, Tok::Int(1), Tok::Semi, Tok::Eof]
        );
    }

    #[test]
    fn lexes_two_char_operators() {
        assert_eq!(
            kinds("a <= b == c != d && e || f"),
            vec![
                Tok::Ident("a".into()),
                Tok::Le,
                Tok::Ident("b".into()),
                Tok::Eq,
                Tok::Ident("c".into()),
                Tok::Ne,
                Tok::Ident("d".into()),
                Tok::AndAnd,
                Tok::Ident("e".into()),
                Tok::OrOr,
                Tok::Ident("f".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_strings_with_escapes() {
        assert_eq!(kinds(r#""a\nb""#), vec![Tok::Str("a\nb".into()), Tok::Eof]);
    }

    #[test]
    fn skips_comments() {
        assert_eq!(
            kinds("x = 1; // c\n/* block\ncomment */ y = 2;"),
            vec![
                Tok::Ident("x".into()),
                Tok::Assign,
                Tok::Int(1),
                Tok::Semi,
                Tok::Ident("y".into()),
                Tok::Assign,
                Tok::Int(2),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn float_vs_method_dot() {
        assert_eq!(kinds("1.5"), vec![Tok::Float(1.5), Tok::Eof]);
        assert_eq!(
            kinds("b.map"),
            vec![Tok::Ident("b".into()), Tok::Dot, Tok::Ident("map".into()), Tok::Eof]
        );
    }

    #[test]
    fn keywords_recognized() {
        assert_eq!(
            kinds("while if else true false"),
            vec![Tok::While, Tok::If, Tok::Else, Tok::True, Tok::False, Tok::Eof]
        );
    }

    #[test]
    fn error_position_reported() {
        let e = lex("x = @").unwrap_err();
        assert!(e.to_string().contains("1:5"), "{e}");
    }

    #[test]
    fn unterminated_string_errors() {
        assert!(lex("\"abc").is_err());
    }
}
