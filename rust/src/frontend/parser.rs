//! LabyLang recursive-descent parser.

use super::ast::{Ast, BinOp, Expr, Stmt, UnOp};
use super::lexer::{Tok, Token};
use crate::error::{Error, Result};

struct Parser<'a> {
    toks: &'a [Token],
    pos: usize,
}

/// Parse a token stream into an AST.
pub fn parse(toks: &[Token]) -> Result<Ast> {
    let mut p = Parser { toks, pos: 0 };
    let mut stmts = Vec::new();
    while !p.at(&Tok::Eof) {
        stmts.push(p.stmt()?);
    }
    Ok(Ast { stmts })
}

impl<'a> Parser<'a> {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }
    fn peek2(&self) -> &Tok {
        &self.toks[(self.pos + 1).min(self.toks.len() - 1)].tok
    }
    fn at(&self, t: &Tok) -> bool {
        self.peek() == t
    }
    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }
    fn err(&self, msg: impl Into<String>) -> Error {
        let t = &self.toks[self.pos];
        Error::Parse { line: t.line, col: t.col, msg: msg.into() }
    }
    fn expect(&mut self, t: Tok, what: &str) -> Result<()> {
        if self.at(&t) {
            self.bump();
            Ok(())
        } else {
            Err(self.err(format!("expected {what}, found {:?}", self.peek())))
        }
    }
    fn ident(&mut self) -> Result<String> {
        match self.peek().clone() {
            Tok::Ident(s) => {
                self.bump();
                Ok(s)
            }
            other => Err(self.err(format!("expected identifier, found {other:?}"))),
        }
    }

    fn block(&mut self) -> Result<Vec<Stmt>> {
        self.expect(Tok::LBrace, "'{'")?;
        let mut stmts = Vec::new();
        while !self.at(&Tok::RBrace) {
            if self.at(&Tok::Eof) {
                return Err(self.err("unterminated block"));
            }
            stmts.push(self.stmt()?);
        }
        self.bump();
        Ok(stmts)
    }

    fn stmt(&mut self) -> Result<Stmt> {
        match self.peek().clone() {
            Tok::While => {
                self.bump();
                self.expect(Tok::LParen, "'('")?;
                let cond = self.expr()?;
                self.expect(Tok::RParen, "')'")?;
                let body = self.block()?;
                Ok(Stmt::While(cond, body))
            }
            Tok::If => {
                self.bump();
                self.expect(Tok::LParen, "'('")?;
                let cond = self.expr()?;
                self.expect(Tok::RParen, "')'")?;
                let then_b = self.block()?;
                let else_b = if self.at(&Tok::Else) {
                    self.bump();
                    self.block()?
                } else {
                    Vec::new()
                };
                Ok(Stmt::If(cond, then_b, else_b))
            }
            Tok::Break => {
                self.bump();
                self.expect(Tok::Semi, "';'")?;
                Ok(Stmt::Break)
            }
            Tok::Continue => {
                self.bump();
                self.expect(Tok::Semi, "';'")?;
                Ok(Stmt::Continue)
            }
            Tok::Ident(name) if *self.peek2() == Tok::Assign => {
                self.bump();
                self.bump();
                let e = self.expr()?;
                self.expect(Tok::Semi, "';'")?;
                Ok(Stmt::Assign(name, e))
            }
            _ => {
                let e = self.expr()?;
                self.expect(Tok::Semi, "';'")?;
                Ok(Stmt::ExprStmt(e))
            }
        }
    }

    fn expr(&mut self) -> Result<Expr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while self.at(&Tok::OrOr) {
            self.bump();
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.cmp_expr()?;
        while self.at(&Tok::AndAnd) {
            self.bump();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Eq => Some(BinOp::Eq),
            Tok::Ne => Some(BinOp::Ne),
            Tok::Lt => Some(BinOp::Lt),
            Tok::Le => Some(BinOp::Le),
            Tok::Gt => Some(BinOp::Gt),
            Tok::Ge => Some(BinOp::Ge),
            _ => None,
        };
        if let Some(op) = op {
            self.bump();
            let rhs = self.add_expr()?;
            Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
        } else {
            Ok(lhs)
        }
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Rem,
                _ => break,
            };
            self.bump();
            let rhs = self.unary()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> Result<Expr> {
        match self.peek() {
            Tok::Minus => {
                self.bump();
                Ok(Expr::Un(UnOp::Neg, Box::new(self.unary()?)))
            }
            Tok::Bang => {
                self.bump();
                Ok(Expr::Un(UnOp::Not, Box::new(self.unary()?)))
            }
            _ => self.postfix(),
        }
    }

    fn postfix(&mut self) -> Result<Expr> {
        let mut e = self.primary()?;
        while self.at(&Tok::Dot) {
            self.bump();
            let name = self.ident()?;
            self.expect(Tok::LParen, "'(' after method name")?;
            let args = self.args()?;
            e = Expr::Method(Box::new(e), name, args);
        }
        Ok(e)
    }

    fn args(&mut self) -> Result<Vec<Expr>> {
        let mut args = Vec::new();
        if !self.at(&Tok::RParen) {
            loop {
                args.push(self.expr()?);
                if self.at(&Tok::Comma) {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.expect(Tok::RParen, "')'")?;
        Ok(args)
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(Expr::Int(v))
            }
            Tok::Float(v) => {
                self.bump();
                Ok(Expr::Float(v))
            }
            Tok::Str(s) => {
                self.bump();
                Ok(Expr::Str(s))
            }
            Tok::True => {
                self.bump();
                Ok(Expr::Bool(true))
            }
            Tok::False => {
                self.bump();
                Ok(Expr::Bool(false))
            }
            Tok::Pipe => {
                self.bump();
                let mut params = Vec::new();
                if !self.at(&Tok::Pipe) {
                    loop {
                        params.push(self.ident()?);
                        if self.at(&Tok::Comma) {
                            self.bump();
                        } else {
                            break;
                        }
                    }
                }
                self.expect(Tok::Pipe, "'|' closing lambda params")?;
                let body = self.expr()?;
                Ok(Expr::Lambda(params, Box::new(body)))
            }
            Tok::LParen => {
                self.bump();
                let e = self.expr()?;
                self.expect(Tok::RParen, "')'")?;
                Ok(e)
            }
            Tok::Ident(name) => {
                self.bump();
                if self.at(&Tok::LParen) {
                    self.bump();
                    let args = self.args()?;
                    Ok(Expr::Call(name, args))
                } else {
                    Ok(Expr::Var(name))
                }
            }
            other => Err(self.err(format!("expected expression, found {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::lexer::lex;

    fn parse_src(src: &str) -> Ast {
        parse(&lex(src).unwrap()).unwrap()
    }

    #[test]
    fn parses_assignment_chain() {
        let ast = parse_src("x = 1; y = x + 2 * 3;");
        assert_eq!(ast.stmts.len(), 2);
        match &ast.stmts[1] {
            Stmt::Assign(n, Expr::Bin(BinOp::Add, _, rhs)) => {
                assert_eq!(n, "y");
                assert!(matches!(**rhs, Expr::Bin(BinOp::Mul, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_while_if() {
        let ast = parse_src("while (d <= 365) { if (d != 1) { x = 2; } else { x = 3; } d = d + 1; }");
        match &ast.stmts[0] {
            Stmt::While(cond, body) => {
                assert!(matches!(cond, Expr::Bin(BinOp::Le, _, _)));
                assert_eq!(body.len(), 2);
                assert!(matches!(&body[0], Stmt::If(_, t, e) if t.len() == 1 && e.len() == 1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_method_chain_with_lambda() {
        let ast = parse_src(r#"c = v.map(|x| pair(x, 1)).reduceByKey(|a, b| a + b);"#);
        match &ast.stmts[0] {
            Stmt::Assign(_, Expr::Method(recv, name, args)) => {
                assert_eq!(name, "reduceByKey");
                assert!(matches!(args[0], Expr::Lambda(ref ps, _) if ps.len() == 2));
                assert!(matches!(**recv, Expr::Method(_, ref n, _) if n == "map"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_expr_stmt_call() {
        let ast = parse_src(r#"writeFile(diffs, "out" + day);"#);
        assert!(matches!(&ast.stmts[0], Stmt::ExprStmt(Expr::Call(n, args)) if n == "writeFile" && args.len() == 2));
    }

    #[test]
    fn reports_error_position() {
        let toks = lex("x = ;").unwrap();
        let e = parse(&toks).unwrap_err();
        assert!(e.to_string().contains("1:5"), "{e}");
    }

    #[test]
    fn unary_ops_bind_tightly() {
        let ast = parse_src("x = -a + !b;");
        match &ast.stmts[0] {
            Stmt::Assign(_, Expr::Bin(BinOp::Add, l, r)) => {
                assert!(matches!(**l, Expr::Un(UnOp::Neg, _)));
                assert!(matches!(**r, Expr::Un(UnOp::Not, _)));
            }
            other => panic!("{other:?}"),
        }
    }
}
