//! Lowering: LabyLang AST → pre-SSA three-address IR over basic blocks.
//!
//! Responsibilities:
//! - flatten nested expressions so every intermediate value is assigned to
//!   a variable (the paper's §5.1 IR assumption);
//! - build the CFG skeleton for `while` / `if` (header/body/after blocks);
//! - type every variable as `Bag` or `Scalar` and reject inconsistent use;
//! - compile lambda arguments into executable UDFs.

use super::ast::{Ast, Expr, Stmt, UnOp};
use super::interp_expr;
use super::{BlockId, Instr, Program, Rhs, Terminator, Ty, Udf1, Udf2, UdfN, VarId};
use crate::error::{Error, Result};
use crate::value::Value;
use rustc_hash::FxHashMap;

struct Lowerer {
    prog: Program,
    scope: FxHashMap<String, VarId>,
    cur: BlockId,
    tmp_count: usize,
    /// Innermost-first stack of (header, after) blocks for break/continue.
    loop_stack: Vec<(BlockId, BlockId)>,
}

/// Lower a parsed AST into the pre-SSA IR.
pub fn lower(ast: &Ast) -> Result<Program> {
    let mut lw = Lowerer {
        prog: Program::default(),
        scope: FxHashMap::default(),
        cur: 0,
        tmp_count: 0,
        loop_stack: Vec::new(),
    };
    let entry = lw.prog.new_block();
    lw.prog.entry = entry;
    lw.cur = entry;
    lw.stmts(&ast.stmts)?;
    lw.prog.blocks[lw.cur].term = Terminator::End;
    Ok(lw.prog)
}

impl Lowerer {
    fn fresh_tmp(&mut self, ty: Ty) -> VarId {
        self.tmp_count += 1;
        self.prog.new_var(format!("t{}", self.tmp_count), ty)
    }

    fn emit(&mut self, var: VarId, rhs: Rhs) {
        self.prog.blocks[self.cur].instrs.push(Instr { var, rhs });
    }

    fn emit_tmp(&mut self, rhs: Rhs, ty: Ty) -> VarId {
        let v = self.fresh_tmp(ty);
        self.emit(v, rhs);
        v
    }

    fn stmts(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            self.stmt(s)?;
        }
        Ok(())
    }

    fn stmt(&mut self, s: &Stmt) -> Result<()> {
        match s {
            Stmt::Assign(name, expr) => {
                let (tmp, ty) = self.expr(expr)?;
                match self.scope.get(name) {
                    Some(&var) => {
                        let declared = self.prog.vars[var].ty;
                        if declared != ty {
                            return Err(Error::Type(format!(
                                "variable '{name}' was {declared:?} but is re-assigned as {ty:?}"
                            )));
                        }
                        self.emit(var, Rhs::Copy(tmp));
                    }
                    None => {
                        // First assignment declares the variable. Retarget
                        // the just-emitted temp when it is in this block to
                        // avoid a copy.
                        let var = self.prog.new_var(name.clone(), ty);
                        self.scope.insert(name.clone(), var);
                        let retargeted = {
                            let blk = &mut self.prog.blocks[self.cur];
                            match blk.instrs.last_mut() {
                                Some(last) if last.var == tmp => {
                                    last.var = var;
                                    true
                                }
                                _ => false,
                            }
                        };
                        if !retargeted {
                            self.emit(var, Rhs::Copy(tmp));
                        }
                    }
                }
                Ok(())
            }
            Stmt::While(cond, body) => {
                let header = self.prog.new_block();
                let body_b = self.prog.new_block();
                let after = self.prog.new_block();
                self.prog.blocks[self.cur].term = Terminator::Jump(header);
                // Condition instructions live in the header block; the
                // condition variable's dataflow node becomes the loop's
                // condition node (§5.3).
                self.cur = header;
                let (cond_var, cond_ty) = self.expr(cond)?;
                if cond_ty != Ty::Scalar {
                    return Err(Error::Type("while-condition must be a scalar".into()));
                }
                let cond_var = self.materialize_cond(cond_var);
                self.prog.blocks[self.cur].term =
                    Terminator::Branch { cond: cond_var, then_b: body_b, else_b: after };
                self.cur = body_b;
                self.loop_stack.push((header, after));
                self.stmts(body)?;
                self.loop_stack.pop();
                self.prog.blocks[self.cur].term = Terminator::Jump(header);
                self.cur = after;
                Ok(())
            }
            Stmt::If(cond, then_s, else_s) => {
                let (cond_var, cond_ty) = self.expr(cond)?;
                if cond_ty != Ty::Scalar {
                    return Err(Error::Type("if-condition must be a scalar".into()));
                }
                let cond_var = self.materialize_cond(cond_var);
                let then_b = self.prog.new_block();
                let merge = self.prog.new_block();
                let else_b = if else_s.is_empty() { merge } else { self.prog.new_block() };
                self.prog.blocks[self.cur].term =
                    Terminator::Branch { cond: cond_var, then_b, else_b };
                self.cur = then_b;
                self.stmts(then_s)?;
                self.prog.blocks[self.cur].term = Terminator::Jump(merge);
                if !else_s.is_empty() {
                    self.cur = else_b;
                    self.stmts(else_s)?;
                    self.prog.blocks[self.cur].term = Terminator::Jump(merge);
                }
                self.cur = merge;
                Ok(())
            }
            Stmt::ExprStmt(e) => {
                self.expr(e)?;
                Ok(())
            }
            Stmt::Break | Stmt::Continue => {
                let &(header, after) = self.loop_stack.last().ok_or_else(|| {
                    Error::Type(format!("{s:?} outside of a loop"))
                })?;
                let target = if matches!(s, Stmt::Break) { after } else { header };
                self.prog.blocks[self.cur].term = Terminator::Jump(target);
                // Statements after break/continue in this block are
                // unreachable; park them in a fresh dead block (the CFG
                // treats unreachable blocks as absent).
                let dead = self.prog.new_block();
                self.cur = dead;
                Ok(())
            }
        }
    }

    /// A branch terminator references a *variable* (the paper requires the
    /// boolean condition to be a plain variable reference, §5.3). If the
    /// condition expression lowered to a variable defined in another block
    /// (plain `Var` reference), re-materialize it in this block through an
    /// identity scalar op so that the condition node lives in the block of
    /// the branch.
    fn materialize_cond(&mut self, v: VarId) -> VarId {
        let defined_here = self.prog.blocks[self.cur].instrs.iter().any(|i| i.var == v);
        if defined_here {
            v
        } else {
            let udf = Udf1::new("id", |x: &Value| x.clone())
                .with_expr(vec!["x".into()], Expr::Var("x".into()));
            self.emit_tmp(Rhs::ScalarUn { input: v, udf }, Ty::Scalar)
        }
    }

    fn lookup(&self, name: &str) -> Result<VarId> {
        self.scope
            .get(name)
            .copied()
            .ok_or_else(|| Error::Type(format!("use of undefined variable '{name}'")))
    }

    fn expr(&mut self, e: &Expr) -> Result<(VarId, Ty)> {
        match e {
            Expr::Int(v) => Ok((self.emit_tmp(Rhs::Const(Value::I64(*v)), Ty::Scalar), Ty::Scalar)),
            Expr::Float(v) => {
                Ok((self.emit_tmp(Rhs::Const(Value::F64(*v)), Ty::Scalar), Ty::Scalar))
            }
            Expr::Str(s) => {
                Ok((self.emit_tmp(Rhs::Const(Value::str(s.clone())), Ty::Scalar), Ty::Scalar))
            }
            Expr::Bool(b) => {
                Ok((self.emit_tmp(Rhs::Const(Value::Bool(*b)), Ty::Scalar), Ty::Scalar))
            }
            Expr::Var(name) => {
                let v = self.lookup(name)?;
                Ok((v, self.prog.vars[v].ty))
            }
            Expr::Un(op, x) => {
                let (xv, ty) = self.expr(x)?;
                if ty != Ty::Scalar {
                    return Err(Error::Type(format!("unary {op:?} needs a scalar")));
                }
                let op = *op;
                let udf = Udf1::new(format!("{op:?}"), move |v: &Value| match op {
                    UnOp::Neg => match v {
                        Value::I64(i) => Value::I64(-i),
                        Value::F64(f) => Value::F64(-f),
                        other => panic!("neg on {other:?}"),
                    },
                    UnOp::Not => Value::Bool(!v.as_bool()),
                })
                // Expression metadata so `opt::types` can type the lifted
                // scalar op (loop counters, branch conditions).
                .with_expr(vec!["x".into()], Expr::Un(op, Box::new(Expr::Var("x".into()))));
                Ok((self.emit_tmp(Rhs::ScalarUn { input: xv, udf }, Ty::Scalar), Ty::Scalar))
            }
            Expr::Bin(op, l, r) => {
                let (lv, lt) = self.expr(l)?;
                let (rv, rt) = self.expr(r)?;
                if lt != Ty::Scalar || rt != Ty::Scalar {
                    return Err(Error::Type(format!(
                        "operator {op:?} needs scalars (bags use .map/.join/...)"
                    )));
                }
                let op = *op;
                let udf = Udf2::new(format!("{op:?}"), move |a: &Value, b: &Value| {
                    interp_expr::bin(op, a, b)
                })
                .with_expr(
                    vec!["a".into(), "b".into()],
                    Expr::Bin(op, Box::new(Expr::Var("a".into())), Box::new(Expr::Var("b".into()))),
                );
                Ok((
                    self.emit_tmp(Rhs::ScalarBin { left: lv, right: rv, udf }, Ty::Scalar),
                    Ty::Scalar,
                ))
            }
            Expr::Lambda(..) => {
                Err(Error::Type("lambda is only valid as an operator argument".into()))
            }
            Expr::Call(name, args) => self.call(name, args),
            Expr::Method(recv, name, args) => self.method(recv, name, args),
        }
    }

    /// Free variables of a lambda body that are bound in the enclosing
    /// scope as *scalars* (captured scalars — e.g. the loop counter in
    /// `visits.map(|x| x + day)`).
    fn captured_scalars(&self, body: &Expr, params: &[String]) -> Result<Vec<String>> {
        let mut caps = Vec::new();
        collect_free(body, params, &mut caps);
        let mut out = Vec::new();
        for name in caps {
            match self.scope.get(&name) {
                Some(&v) if self.prog.vars[v].ty == Ty::Scalar => {
                    if !out.contains(&name) {
                        out.push(name);
                    }
                }
                Some(_) => {
                    return Err(Error::Type(format!(
                        "lambda captures bag '{name}'; only scalars can be captured \
                         (bags must flow through explicit operators)"
                    )))
                }
                None => {} // let compile_udf report the unbound name
            }
        }
        Ok(out)
    }

    /// Captured-scalar desugaring (the §5.2 lifting discipline applied to
    /// closures): `b.map(|x| f(x, s))` with captured scalar `s` becomes
    ///
    /// ```text
    /// t  = b cross s        -- one Pair(x, s) element per x (s broadcast)
    /// r  = t.map(|p| f(fst(p), snd(p)))
    /// ```
    ///
    /// Multiple captures nest pairs left-to-right. Returns the crossed
    /// input variable and the rewritten lambda body + parameter.
    fn desugar_captures(
        &mut self,
        input: VarId,
        params: &[String],
        body: &Expr,
        caps: &[String],
    ) -> Result<(VarId, String, Expr)> {
        debug_assert_eq!(params.len(), 1);
        let mut cur = input;
        for name in caps {
            let sv = self.scope[name];
            cur = self.emit_tmp(Rhs::Cross { left: cur, right: sv }, Ty::Bag);
        }
        // Access paths: innermost pair component is the original element.
        let p = "·p".to_string(); // not lexable: cannot collide with user names
        let mut elem_access = Expr::Var(p.clone());
        let mut subst: Vec<(String, Expr)> = Vec::new();
        for (i, name) in caps.iter().enumerate().rev() {
            // caps[i] is at depth (len-1-i) of fst-nesting, then one snd.
            let mut acc = Expr::Var(p.clone());
            for _ in 0..(caps.len() - 1 - i) {
                acc = Expr::Call("fst".into(), vec![acc]);
            }
            subst.push((name.clone(), Expr::Call("snd".into(), vec![acc])));
        }
        for _ in 0..caps.len() {
            elem_access = Expr::Call("fst".into(), vec![elem_access]);
        }
        subst.push((params[0].clone(), elem_access));
        let new_body = substitute(body, &subst);
        Ok((cur, p, new_body))
    }

    fn lambda2(&mut self, e: &Expr, op: &str) -> Result<Udf2> {
        match e {
            Expr::Lambda(ps, body) => {
                if !self.captured_scalars(body, ps)?.is_empty() {
                    return Err(Error::Type(format!(
                        "{op} combiner lambdas cannot capture outer variables \
                         (combiners must be associative element functions)"
                    )));
                }
                interp_expr::compile_udf2(ps.clone(), (**body).clone(), format!("{op}λ"))
            }
            _ => Err(Error::Type(format!("{op} expects a 2-parameter lambda"))),
        }
    }

    fn expect_bag(&mut self, e: &Expr, op: &str) -> Result<VarId> {
        let (v, ty) = self.expr(e)?;
        if ty != Ty::Bag {
            return Err(Error::Type(format!("{op} expects a bag operand")));
        }
        Ok(v)
    }

    fn expect_scalar(&mut self, e: &Expr, op: &str) -> Result<VarId> {
        let (v, ty) = self.expr(e)?;
        if ty != Ty::Scalar {
            return Err(Error::Type(format!("{op} expects a scalar operand")));
        }
        Ok(v)
    }

    fn call(&mut self, name: &str, args: &[Expr]) -> Result<(VarId, Ty)> {
        match (name, args.len()) {
            ("readFile", 1) => {
                let n = self.expect_scalar(&args[0], "readFile")?;
                Ok((self.emit_tmp(Rhs::ReadFile { name: n }, Ty::Bag), Ty::Bag))
            }
            ("writeFile", 2) => {
                let d = self.expect_bag(&args[0], "writeFile")?;
                let n = self.expect_scalar(&args[1], "writeFile")?;
                Ok((
                    self.emit_tmp(Rhs::WriteFile { data: d, name: n }, Ty::Scalar),
                    Ty::Scalar,
                ))
            }
            ("collect", 2) => {
                let d = self.expect_bag(&args[0], "collect")?;
                let label = match &args[1] {
                    Expr::Str(s) => s.clone(),
                    _ => return Err(Error::Type("collect label must be a string literal".into())),
                };
                Ok((
                    self.emit_tmp(Rhs::Collect { input: d, label }, Ty::Scalar),
                    Ty::Scalar,
                ))
            }
            ("source", 1) => {
                let n = match &args[0] {
                    Expr::Str(s) => s.clone(),
                    _ => return Err(Error::Type("source name must be a string literal".into())),
                };
                Ok((self.emit_tmp(Rhs::NamedSource(n), Ty::Bag), Ty::Bag))
            }
            ("bag", _) => {
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    match a {
                        Expr::Int(v) => vals.push(Value::I64(*v)),
                        Expr::Float(v) => vals.push(Value::F64(*v)),
                        Expr::Str(s) => vals.push(Value::str(s.clone())),
                        Expr::Bool(b) => vals.push(Value::Bool(*b)),
                        _ => {
                            return Err(Error::Type(
                                "bag(...) takes literal elements only".into(),
                            ))
                        }
                    }
                }
                Ok((self.emit_tmp(Rhs::BagLit(vals), Ty::Bag), Ty::Bag))
            }
            ("range", 2) => match (&args[0], &args[1]) {
                (Expr::Int(lo), Expr::Int(hi)) => {
                    let vals = (*lo..*hi).map(Value::I64).collect();
                    Ok((self.emit_tmp(Rhs::BagLit(vals), Ty::Bag), Ty::Bag))
                }
                _ => Err(Error::Type("range(lo, hi) takes integer literals".into())),
            },
            // Scalar builtins lift to ScalarUn / ScalarBin (§5.2).
            (b, 1) => {
                let x = self.expect_scalar(&args[0], b)?;
                let bname = b.to_string();
                let ename = bname.clone();
                let udf = Udf1::new(bname.clone(), move |v: &Value| {
                    interp_expr::builtin(&bname, std::slice::from_ref(v))
                })
                .with_expr(vec!["x".into()], Expr::Call(ename, vec![Expr::Var("x".into())]));
                Ok((self.emit_tmp(Rhs::ScalarUn { input: x, udf }, Ty::Scalar), Ty::Scalar))
            }
            (b, 2) => {
                let x = self.expect_scalar(&args[0], b)?;
                let y = self.expect_scalar(&args[1], b)?;
                let bname = b.to_string();
                let ename = bname.clone();
                let udf = Udf2::new(bname.clone(), move |a: &Value, v: &Value| {
                    interp_expr::builtin(&bname, &[a.clone(), v.clone()])
                })
                .with_expr(
                    vec!["a".into(), "b".into()],
                    Expr::Call(ename, vec![Expr::Var("a".into()), Expr::Var("b".into())]),
                );
                Ok((
                    self.emit_tmp(Rhs::ScalarBin { left: x, right: y, udf }, Ty::Scalar),
                    Ty::Scalar,
                ))
            }
            (other, n) => Err(Error::Type(format!("unknown function {other}/{n}"))),
        }
    }

    /// Resolve a unary lambda argument, desugaring captured scalars: the
    /// returned input variable is the (possibly crossed) bag and the UDF
    /// operates on its elements. `unwrap_depth` is the number of `fst`
    /// applications that recover the original element from a crossed one.
    fn unary_lambda_input(
        &mut self,
        input: VarId,
        arg: &Expr,
        op: &str,
    ) -> Result<(VarId, Udf1, usize)> {
        let Expr::Lambda(ps, body) = arg else {
            return Err(Error::Type(format!("{op} expects a 1-parameter lambda")));
        };
        if ps.len() != 1 {
            return Err(Error::Type(format!("{op} lambda takes exactly 1 parameter")));
        }
        let caps = self.captured_scalars(body, ps)?;
        if caps.is_empty() {
            let udf = interp_expr::compile_udf1(ps.clone(), (**body).clone(), format!("{op}λ"))?;
            return Ok((input, udf, 0));
        }
        let (crossed, param, new_body) = self.desugar_captures(input, ps, body, &caps)?;
        let udf = interp_expr::compile_udf1(
            vec![param],
            new_body,
            format!("{op}λ+{}cap", caps.len()),
        )?;
        Ok((crossed, udf, caps.len()))
    }

    fn method(&mut self, recv: &Expr, name: &str, args: &[Expr]) -> Result<(VarId, Ty)> {
        let input = self.expect_bag(recv, name)?;
        match (name, args.len()) {
            ("map", 1) => {
                let (input, udf, _) = self.unary_lambda_input(input, &args[0], "map")?;
                Ok((self.emit_tmp(Rhs::Map { input, udf }, Ty::Bag), Ty::Bag))
            }
            ("filter", 1) => {
                let (cin, udf, depth) = self.unary_lambda_input(input, &args[0], "filter")?;
                let filtered = self.emit_tmp(Rhs::Filter { input: cin, udf }, Ty::Bag);
                if depth == 0 {
                    Ok((filtered, Ty::Bag))
                } else {
                    // Unwrap the crossed pairs back to the original element.
                    let unwrap = Udf1::new("uncross", move |v: &Value| {
                        let mut cur = v.clone();
                        for _ in 0..depth {
                            cur = match cur {
                                Value::Pair(p) => p.0.clone(),
                                other => panic!("expected crossed pair, got {other:?}"),
                            };
                        }
                        cur
                    });
                    Ok((
                        self.emit_tmp(Rhs::Map { input: filtered, udf: unwrap }, Ty::Bag),
                        Ty::Bag,
                    ))
                }
            }
            ("flatMap", 1) => {
                let (input, udf1, _) = self.unary_lambda_input(input, &args[0], "flatMap")?;
                let name = udf1.name.clone();
                let udf = UdfN::new(name.to_string(), move |v: &Value| match udf1.call(v) {
                    Value::Tuple(t) => t.to_vec(),
                    single => vec![single],
                });
                Ok((self.emit_tmp(Rhs::FlatMap { input, udf }, Ty::Bag), Ty::Bag))
            }
            ("join", 1) => {
                let right = self.expect_bag(&args[0], "join")?;
                // Receiver is the probe side; the argument (typically the
                // smaller / loop-invariant dataset) is the build side.
                Ok((self.emit_tmp(Rhs::Join { left: right, right: input }, Ty::Bag), Ty::Bag))
            }
            ("joinBuild", 1) => {
                // Receiver is the build side (kept in state across steps
                // when loop-invariant, §7).
                let right = self.expect_bag(&args[0], "joinBuild")?;
                Ok((self.emit_tmp(Rhs::Join { left: input, right }, Ty::Bag), Ty::Bag))
            }
            ("reduceByKey", 1) => {
                let udf = self.lambda2(&args[0], "reduceByKey")?;
                Ok((self.emit_tmp(Rhs::ReduceByKey { input, udf }, Ty::Bag), Ty::Bag))
            }
            ("reduce", 1) => {
                let udf = self.lambda2(&args[0], "reduce")?;
                Ok((self.emit_tmp(Rhs::Reduce { input, udf }, Ty::Scalar), Ty::Scalar))
            }
            ("count", 0) => {
                Ok((self.emit_tmp(Rhs::Count { input }, Ty::Scalar), Ty::Scalar))
            }
            ("distinct", 0) => {
                Ok((self.emit_tmp(Rhs::Distinct { input }, Ty::Bag), Ty::Bag))
            }
            ("union", 1) => {
                let right = self.expect_bag(&args[0], "union")?;
                Ok((self.emit_tmp(Rhs::Union { left: input, right }, Ty::Bag), Ty::Bag))
            }
            ("cross", 1) => {
                let right = self.expect_bag(&args[0], "cross")?;
                Ok((self.emit_tmp(Rhs::Cross { left: input, right }, Ty::Bag), Ty::Bag))
            }
            (other, n) => Err(Error::Type(format!("unknown bag method {other}/{n}"))),
        }
    }
}

/// Collect free variable names of `e` (those not in `params`).
fn collect_free(e: &Expr, params: &[String], out: &mut Vec<String>) {
    match e {
        Expr::Var(name) => {
            if !params.iter().any(|p| p == name) {
                out.push(name.clone());
            }
        }
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) => {}
        Expr::Un(_, x) => collect_free(x, params, out),
        Expr::Bin(_, l, r) => {
            collect_free(l, params, out);
            collect_free(r, params, out);
        }
        Expr::Call(_, args) => {
            for a in args {
                collect_free(a, params, out);
            }
        }
        Expr::Method(recv, _, args) => {
            collect_free(recv, params, out);
            for a in args {
                collect_free(a, params, out);
            }
        }
        Expr::Lambda(ps, body) => {
            let mut inner: Vec<String> = params.to_vec();
            inner.extend(ps.iter().cloned());
            collect_free(body, &inner, out);
        }
    }
}

/// Substitute variables by expressions (capture desugaring rewrite).
fn substitute(e: &Expr, subst: &[(String, Expr)]) -> Expr {
    match e {
        Expr::Var(name) => subst
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, rep)| rep.clone())
            .unwrap_or_else(|| e.clone()),
        Expr::Int(_) | Expr::Float(_) | Expr::Str(_) | Expr::Bool(_) => e.clone(),
        Expr::Un(op, x) => Expr::Un(*op, Box::new(substitute(x, subst))),
        Expr::Bin(op, l, r) => Expr::Bin(
            *op,
            Box::new(substitute(l, subst)),
            Box::new(substitute(r, subst)),
        ),
        Expr::Call(n, args) => {
            Expr::Call(n.clone(), args.iter().map(|a| substitute(a, subst)).collect())
        }
        Expr::Method(recv, n, args) => Expr::Method(
            Box::new(substitute(recv, subst)),
            n.clone(),
            args.iter().map(|a| substitute(a, subst)).collect(),
        ),
        Expr::Lambda(ps, body) => {
            let filtered: Vec<(String, Expr)> = subst
                .iter()
                .filter(|(n, _)| !ps.contains(n))
                .cloned()
                .collect();
            Expr::Lambda(ps.clone(), Box::new(substitute(body, &filtered)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_and_lower;

    #[test]
    fn lowers_straightline() {
        let p = parse_and_lower("x = 1; y = x + 2;").unwrap();
        assert_eq!(p.blocks.len(), 1);
        let names: Vec<_> = p.blocks[0]
            .instrs
            .iter()
            .map(|i| p.vars[i.var].name.clone())
            .collect();
        assert!(names.contains(&"x".to_string()));
        assert!(names.contains(&"y".to_string()));
    }

    #[test]
    fn while_creates_header_body_after() {
        let p = parse_and_lower("d = 1; while (d <= 3) { d = d + 1; }").unwrap();
        // entry, header, body, after
        assert_eq!(p.blocks.len(), 4);
        let header = match p.blocks[p.entry].term {
            Terminator::Jump(h) => h,
            ref other => panic!("{other:?}"),
        };
        match p.blocks[header].term {
            Terminator::Branch { cond, .. } => {
                // condition defined in the header block itself
                assert!(p.blocks[header].instrs.iter().any(|i| i.var == cond));
            }
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn if_without_else_branches_to_merge() {
        let p = parse_and_lower("x = 1; if (x != 1) { x = 2; }").unwrap();
        let entry = &p.blocks[p.entry];
        match entry.term {
            Terminator::Branch { then_b, else_b, .. } => {
                assert_ne!(then_b, else_b);
                // else edge goes straight to the merge block
                assert!(matches!(p.blocks[then_b].term, Terminator::Jump(m) if m == else_b));
            }
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn captured_scalar_desugars_to_cross() {
        let p = parse_and_lower(
            "d = 7; v = bag(1, 2).map(|x| x + d); collect(v, \"v\");",
        )
        .unwrap();
        let listing = p.listing();
        assert!(listing.contains("cross"), "{listing}");
        // The rewritten lambda applies to pairs: evaluate it by hand.
        let map_udf = p
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter_map(|i| match &i.rhs {
                Rhs::Map { udf, .. } if udf.name.contains("cap") => Some(udf.clone()),
                _ => None,
            })
            .next()
            .expect("desugared map");
        let out = map_udf.call(&Value::pair(Value::I64(1), Value::I64(7)));
        assert_eq!(out, Value::I64(8));
    }

    #[test]
    fn captured_filter_unwraps_elements() {
        let p = parse_and_lower(
            "t = 2; v = bag(1, 2, 3).filter(|x| x > t); collect(v, \"v\");",
        )
        .unwrap();
        // filter is followed by an unwrap map.
        let l = p.listing();
        assert!(l.contains("filter"), "{l}");
        assert!(l.contains("uncross"), "{l}");
    }

    #[test]
    fn two_captures_nest_pairs() {
        let p = parse_and_lower(
            "a = 1; b = 2; v = bag(10).map(|x| x + a * b); collect(v, \"v\");",
        )
        .unwrap();
        let map_udf = p
            .blocks
            .iter()
            .flat_map(|bk| &bk.instrs)
            .filter_map(|i| match &i.rhs {
                Rhs::Map { udf, .. } if udf.name.contains("2cap") => Some(udf.clone()),
                _ => None,
            })
            .next()
            .expect("desugared map with 2 captures");
        // Crossed value shape: Pair(Pair(x, a), b).
        let v = Value::pair(
            Value::pair(Value::I64(10), Value::I64(1)),
            Value::I64(2),
        );
        assert_eq!(map_udf.call(&v), Value::I64(12));
    }

    #[test]
    fn bag_capture_rejected() {
        let err = parse_and_lower(
            "big = bag(1, 2); v = bag(3).map(|x| x + big); collect(v, \"v\");",
        )
        .unwrap_err();
        assert!(err.to_string().contains("captures bag"), "{err}");
    }

    #[test]
    fn combiner_capture_rejected() {
        let err = parse_and_lower(
            "s = 1; v = bag(1, 2).map(|x| pair(x, x)).reduceByKey(|a, b| a + b + s); collect(v, \"v\");",
        )
        .unwrap_err();
        assert!(err.to_string().contains("cannot capture"), "{err}");
    }

    #[test]
    fn bag_scalar_mix_rejected() {
        let err = parse_and_lower("v = bag(1, 2); y = v + 1;").unwrap_err();
        assert!(err.to_string().contains("scalar"), "{err}");
    }

    #[test]
    fn variable_type_is_stable() {
        let err = parse_and_lower("x = 1; x = bag(1);").unwrap_err();
        assert!(err.to_string().contains("re-assigned"), "{err}");
    }

    #[test]
    fn undefined_variable_rejected() {
        let err = parse_and_lower("y = x + 1;").unwrap_err();
        assert!(err.to_string().contains("undefined"), "{err}");
    }

    #[test]
    fn visit_count_program_lowers() {
        let src = r#"
            attrs = source("pageAttributes");
            day = 1;
            yesterday = bag();
            while (day <= 5) {
                visits = source("visits");
                joined = visits.map(|x| pair(x, x)).join(attrs);
                counts = joined.map(|p| pair(fst(p), 1)).reduceByKey(|a, b| a + b);
                if (day != 1) {
                    diffs = counts.join(yesterday)
                        .map(|p| abs(fst(snd(p)) - snd(snd(p))));
                    total = diffs.reduce(|a, b| a + b);
                    collect(diffs, "diffs");
                }
                yesterday = counts;
                day = day + 1;
            }
        "#;
        let p = parse_and_lower(src).unwrap();
        assert!(p.blocks.len() >= 6, "blocks: {}", p.blocks.len());
        let listing = p.listing();
        assert!(listing.contains("join"), "{listing}");
        assert!(listing.contains("reduceByKey"), "{listing}");
    }
}
